//! GraphBLAS containers: CSR sparse matrix (allocator-aware, persistent)
//! and a dense-with-mask vector (DRAM — vectors are short-lived
//! algorithm state).
//!
//! `GrbMatrix` mirrors GBTL's adjacency structure after the §7.3.1
//! adaptation: it "takes an allocator type in its template and an
//! allocator object in its constructor" — here, a `SegmentAlloc`
//! reference per call and persistent `PVec`s inside.

use crate::alloc::manager::Persist;
use crate::alloc::SegmentAlloc;
use crate::containers::PVec;
use crate::error::Result;

/// Persistent CSR matrix handle (`Persist`, reattachable via offset).
#[derive(Clone, Copy, Debug)]
#[repr(C)]
pub struct GrbMatrix {
    nrows: u64,
    ncols: u64,
    row_ptr: PVec<u64>,
    col_idx: PVec<u64>,
    vals: PVec<f64>,
}

unsafe impl Persist for GrbMatrix {}

impl GrbMatrix {
    /// Build from (possibly unsorted, possibly duplicated) triplets.
    /// Duplicates are summed (GraphBLAS build semantics).
    pub fn build<A: SegmentAlloc>(
        a: &A,
        nrows: usize,
        ncols: usize,
        triplets: &mut Vec<(u64, u64, f64)>,
    ) -> Result<Self> {
        triplets.sort_unstable_by_key(|&(r, c, _)| (r, c));
        // merge duplicates
        let mut merged: Vec<(u64, u64, f64)> = Vec::with_capacity(triplets.len());
        for &(r, c, v) in triplets.iter() {
            assert!((r as usize) < nrows && (c as usize) < ncols, "triplet out of range");
            match merged.last_mut() {
                Some(last) if last.0 == r && last.1 == c => last.2 += v,
                _ => merged.push((r, c, v)),
            }
        }
        let row_ptr = PVec::<u64>::create(a)?;
        let col_idx = PVec::<u64>::create(a)?;
        let vals = PVec::<f64>::create(a)?;
        let mut rp = Vec::with_capacity(nrows + 1);
        let mut ci = Vec::with_capacity(merged.len());
        let mut vv = Vec::with_capacity(merged.len());
        let mut cur = 0usize;
        rp.push(0u64);
        for row in 0..nrows as u64 {
            while cur < merged.len() && merged[cur].0 == row {
                ci.push(merged[cur].1);
                vv.push(merged[cur].2);
                cur += 1;
            }
            rp.push(ci.len() as u64);
        }
        row_ptr.extend_from_slice(a, &rp)?;
        col_idx.extend_from_slice(a, &ci)?;
        vals.extend_from_slice(a, &vv)?;
        Ok(Self { nrows: nrows as u64, ncols: ncols as u64, row_ptr, col_idx, vals })
    }

    /// Build the unweighted adjacency matrix of an edge list.
    pub fn from_edges<A: SegmentAlloc>(
        a: &A,
        n: usize,
        edges: &[(u64, u64)],
    ) -> Result<Self> {
        let mut trips: Vec<(u64, u64, f64)> =
            edges.iter().map(|&(s, d)| (s, d, 1.0)).collect();
        // duplicate edges collapse to weight 1 (simple graph semantics)
        trips.sort_unstable_by_key(|&(r, c, _)| (r, c));
        trips.dedup_by_key(|t| (t.0, t.1));
        Self::build(a, n, n, &mut trips)
    }

    pub fn nrows(&self) -> usize {
        self.nrows as usize
    }

    pub fn ncols(&self) -> usize {
        self.ncols as usize
    }

    pub fn nvals<A: SegmentAlloc>(&self, a: &A) -> usize {
        self.vals.len(a)
    }

    /// Visit row `r`'s entries.
    pub fn row_for_each<A: SegmentAlloc>(
        &self,
        a: &A,
        r: usize,
        mut f: impl FnMut(u64, f64),
    ) {
        let lo = self.row_ptr.get(a, r) as usize;
        let hi = self.row_ptr.get(a, r + 1) as usize;
        for i in lo..hi {
            f(self.col_idx.get(a, i), self.vals.get(a, i));
        }
    }

    pub fn out_degree<A: SegmentAlloc>(&self, a: &A, r: usize) -> usize {
        (self.row_ptr.get(a, r + 1) - self.row_ptr.get(a, r)) as usize
    }

    /// Transpose into (possibly another) allocator.
    pub fn transpose<A: SegmentAlloc, B: SegmentAlloc>(&self, a: &A, b: &B) -> Result<GrbMatrix> {
        let mut trips = Vec::with_capacity(self.nvals(a));
        for r in 0..self.nrows() {
            self.row_for_each(a, r, |c, v| trips.push((c, r as u64, v)));
        }
        GrbMatrix::build(b, self.ncols(), self.nrows(), &mut trips)
    }

    /// Extract the strictly lower-triangular part (triangle counting).
    pub fn tril<A: SegmentAlloc, B: SegmentAlloc>(&self, a: &A, b: &B) -> Result<GrbMatrix> {
        let mut trips = Vec::new();
        for r in 0..self.nrows() {
            self.row_for_each(a, r, |c, v| {
                if (c as usize) < r {
                    trips.push((r as u64, c, v));
                }
            });
        }
        GrbMatrix::build(b, self.nrows(), self.ncols(), &mut trips)
    }

    /// Materialize to dense (tests / tiny graphs only).
    pub fn to_dense<A: SegmentAlloc>(&self, a: &A) -> Vec<Vec<f64>> {
        let mut m = vec![vec![0.0; self.ncols()]; self.nrows()];
        for r in 0..self.nrows() {
            self.row_for_each(a, r, |c, v| m[r][c as usize] = v);
        }
        m
    }

    /// Free all storage.
    pub fn destroy<A: SegmentAlloc>(self, a: &A) -> Result<()> {
        self.row_ptr.destroy(a)?;
        self.col_idx.destroy(a)?;
        self.vals.destroy(a)
    }
}

/// Dense vector with a structural mask (GraphBLAS vectors are sparse;
/// for the graph sizes of §7.4 a dense representation with presence
/// flags is the pragmatic choice). DRAM-only: lives inside algorithms.
#[derive(Clone, Debug, PartialEq)]
pub struct GrbVector {
    pub vals: Vec<f64>,
    pub mask: Vec<bool>,
}

impl GrbVector {
    pub fn new(n: usize) -> Self {
        Self { vals: vec![0.0; n], mask: vec![false; n] }
    }

    pub fn filled(n: usize, v: f64) -> Self {
        Self { vals: vec![v; n], mask: vec![true; n] }
    }

    pub fn len(&self) -> usize {
        self.vals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    pub fn set(&mut self, i: usize, v: f64) {
        self.vals[i] = v;
        self.mask[i] = true;
    }

    pub fn get(&self, i: usize) -> Option<f64> {
        self.mask[i].then_some(self.vals[i])
    }

    pub fn nvals(&self) -> usize {
        self.mask.iter().filter(|&&m| m).count()
    }

    pub fn clear(&mut self) {
        self.vals.iter_mut().for_each(|v| *v = 0.0);
        self.mask.iter_mut().for_each(|m| *m = false);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::{ManagerOptions, MetallManager};
    use crate::gbtl::HeapAlloc;
    use crate::util::tmp::TempDir;

    #[test]
    fn build_csr_shape() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        let m = GrbMatrix::from_edges(&h, 4, &[(0, 1), (0, 2), (2, 3), (0, 1)]).unwrap();
        assert_eq!(m.nvals(&h), 3, "duplicate edge collapsed");
        assert_eq!(m.out_degree(&h, 0), 2);
        assert_eq!(m.out_degree(&h, 1), 0);
        let d = m.to_dense(&h);
        assert_eq!(d[0][1], 1.0);
        assert_eq!(d[2][3], 1.0);
        assert_eq!(d[1][0], 0.0);
    }

    #[test]
    fn duplicates_sum_in_build() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        let mut t = vec![(0u64, 0u64, 2.0), (0, 0, 3.0), (1, 1, 1.0)];
        let m = GrbMatrix::build(&h, 2, 2, &mut t).unwrap();
        assert_eq!(m.to_dense(&h)[0][0], 5.0);
    }

    #[test]
    fn transpose_and_tril() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        let m = GrbMatrix::from_edges(&h, 3, &[(0, 1), (1, 2), (2, 0)]).unwrap();
        let t = m.transpose(&h, &h).unwrap();
        assert_eq!(t.to_dense(&h)[1][0], 1.0);
        let l = m.tril(&h, &h).unwrap();
        assert_eq!(l.nvals(&h), 1); // only (2,0)
        assert_eq!(l.to_dense(&h)[2][0], 1.0);
    }

    #[test]
    fn matrix_is_persistent_and_reattachable() {
        let d = TempDir::new("grbm");
        let store = d.join("s");
        {
            let mg = MetallManager::create_with(&store, ManagerOptions::small_for_tests())
                .unwrap();
            let m = GrbMatrix::from_edges(&mg, 3, &[(0, 1), (1, 2)]).unwrap();
            mg.construct::<GrbMatrix>("matrix", m).unwrap();
            mg.close().unwrap();
        }
        let mg = MetallManager::open(&store).unwrap();
        let off = mg.find::<GrbMatrix>("matrix").unwrap().unwrap();
        let m: GrbMatrix = mg.read(off);
        assert_eq!(m.nvals(&mg), 2);
        assert_eq!(m.to_dense(&mg)[1][2], 1.0);
        mg.close().unwrap();
    }

    #[test]
    fn vector_mask_semantics() {
        let mut v = GrbVector::new(3);
        assert_eq!(v.nvals(), 0);
        v.set(1, 5.0);
        assert_eq!(v.get(0), None);
        assert_eq!(v.get(1), Some(5.0));
        assert_eq!(v.nvals(), 1);
        v.clear();
        assert_eq!(v.nvals(), 0);
    }
}

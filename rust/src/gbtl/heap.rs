//! `HeapAlloc` — the *fallback allocator adaptor* (paper §7.3.2).
//!
//! "GBTL implementations use temporary graph containers to store
//! intermediate results … Such temporary graphs need not be allocated in
//! the persistent store and can be left as a non-persistent data
//! structure in DRAM. … the fallback allocator adaptor *fallbacks* to a
//! normal memory allocator if its default constructor is called."
//!
//! Implementation: an anonymous reserved VM extent with a bump frontier —
//! arena semantics (deallocate is a no-op; everything is released when
//! the arena drops), which is exactly the lifetime profile of algorithm
//! temporaries. It implements [`SegmentAlloc`], so every persistent
//! container also runs, unchanged, on DRAM.

use std::sync::Mutex;

use crate::alloc::SegmentAlloc;
use crate::error::{Error, Result};
use crate::storage::mmap::page_size;
use crate::util::align_up;

/// DRAM arena allocator (non-persistent).
pub struct HeapAlloc {
    base: *mut u8,
    reserve: usize,
    inner: Mutex<Inner>,
}

struct Inner {
    top: usize,
    committed: usize,
}

unsafe impl Send for HeapAlloc {}
unsafe impl Sync for HeapAlloc {}

impl HeapAlloc {
    /// Reserve a DRAM arena (default 8 GiB of VM; physical pages are
    /// committed on demand).
    pub fn new() -> Result<Self> {
        Self::with_reserve(8 << 30)
    }

    pub fn with_reserve(reserve: usize) -> Result<Self> {
        let p = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                reserve,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_PRIVATE | libc::MAP_ANONYMOUS | libc::MAP_NORESERVE,
                -1,
                0,
            )
        };
        if p == libc::MAP_FAILED {
            return Err(Error::sys("mmap(heap arena)"));
        }
        Ok(Self {
            base: p as *mut u8,
            reserve,
            inner: Mutex::new(Inner { top: 0, committed: reserve }),
        })
    }

    pub fn used(&self) -> usize {
        self.inner.lock().unwrap().top
    }
}

impl Default for HeapAlloc {
    fn default() -> Self {
        Self::new().expect("heap arena")
    }
}

impl Drop for HeapAlloc {
    fn drop(&mut self) {
        unsafe {
            libc::munmap(self.base as *mut libc::c_void, self.reserve);
        }
    }
}

impl SegmentAlloc for HeapAlloc {
    fn allocate(&self, size: usize) -> Result<u64> {
        if size == 0 {
            return Err(Error::Alloc("zero-size allocation".into()));
        }
        let mut inner = self.inner.lock().unwrap();
        let off = align_up(inner.top, 8);
        let new_top = off + align_up(size, 8);
        if new_top > self.reserve {
            return Err(Error::Alloc(format!(
                "heap arena exhausted ({new_top} > {})",
                self.reserve
            )));
        }
        inner.top = new_top;
        Ok(off as u64)
    }

    /// Arena semantics: individual frees are no-ops.
    fn deallocate(&self, _offset: u64) -> Result<()> {
        Ok(())
    }

    fn base(&self) -> *mut u8 {
        self.base
    }

    fn mapped_len(&self) -> usize {
        // the full reserve is addressable (pages appear on demand)
        let _ = page_size();
        self.inner.lock().unwrap().committed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::containers::PVec;

    #[test]
    fn bump_and_containers_work_on_dram() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        let a = h.allocate(10).unwrap();
        let b = h.allocate(10).unwrap();
        assert!(b >= a + 16 - 8); // 8-aligned bump
        let v = PVec::<u64>::create(&h).unwrap();
        for i in 0..10_000u64 {
            v.push(&h, i).unwrap();
        }
        assert_eq!(v.len(&h), 10_000);
        assert_eq!(v.get(&h, 9_999), 9_999);
        assert!(h.used() > 80_000);
    }

    #[test]
    fn exhaustion_is_an_error() {
        let h = HeapAlloc::with_reserve(1 << 20).unwrap();
        assert!(h.allocate(2 << 20).is_err());
    }
}

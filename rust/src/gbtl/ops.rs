//! GraphBLAS primitive operations over [`GrbMatrix`]/[`GrbVector`].
//!
//! The set GBTL's algorithms need: `mxv`, `vxm`, `mxm` (masked), element-
//! wise add/multiply, `reduce`, and `apply` — all parameterized by a
//! [`Semiring`].

use crate::alloc::SegmentAlloc;
use crate::error::Result;
use crate::gbtl::semiring::Semiring;
use crate::gbtl::types::{GrbMatrix, GrbVector};

/// w = A ⊕.⊗ u  (matrix-vector product over semiring `S`).
pub fn mxv<S: Semiring, A: SegmentAlloc>(a: &A, m: &GrbMatrix, u: &GrbVector) -> GrbVector {
    assert_eq!(m.ncols(), u.len());
    let mut w = GrbVector::new(m.nrows());
    for r in 0..m.nrows() {
        let mut acc = S::ADD_IDENTITY;
        let mut any = false;
        m.row_for_each(a, r, |c, v| {
            if u.mask[c as usize] {
                acc = S::add(acc, S::mul(v, u.vals[c as usize]));
                any = true;
            }
        });
        if any {
            w.set(r, acc);
        }
    }
    w
}

/// w = u ⊕.⊗ A  (vector-matrix; equals `mxv` with the transpose, which
/// we compute on the fly column-push style).
pub fn vxm<S: Semiring, A: SegmentAlloc>(a: &A, u: &GrbVector, m: &GrbMatrix) -> GrbVector {
    assert_eq!(u.len(), m.nrows());
    let mut w = GrbVector::new(m.ncols());
    let mut acc: Vec<f64> = vec![S::ADD_IDENTITY; m.ncols()];
    let mut any = vec![false; m.ncols()];
    for r in 0..m.nrows() {
        if !u.mask[r] {
            continue;
        }
        let uv = u.vals[r];
        m.row_for_each(a, r, |c, v| {
            let c = c as usize;
            acc[c] = S::add(acc[c], S::mul(uv, v));
            any[c] = true;
        });
    }
    for c in 0..m.ncols() {
        if any[c] {
            w.set(c, acc[c]);
        }
    }
    w
}

/// C = A ⊕.⊗ B, optionally masked by `mask` (structural mask: entries of
/// C are kept only where `mask` has an entry). Row-by-row Gustavson;
/// the output is built into allocator `out_a`.
pub fn mxm<S: Semiring, A: SegmentAlloc, B: SegmentAlloc, O: SegmentAlloc>(
    a: &A,
    ma: &GrbMatrix,
    b: &B,
    mb: &GrbMatrix,
    out_a: &O,
    mask: Option<(&A, &GrbMatrix)>,
) -> Result<GrbMatrix> {
    assert_eq!(ma.ncols(), mb.nrows());
    let ncols = mb.ncols();
    let mut trips: Vec<(u64, u64, f64)> = Vec::new();
    let mut acc: Vec<f64> = vec![S::ADD_IDENTITY; ncols];
    let mut touched: Vec<usize> = Vec::new();
    let mut in_row: Vec<bool> = vec![false; ncols];
    for r in 0..ma.nrows() {
        touched.clear();
        ma.row_for_each(a, r, |k, av| {
            mb.row_for_each(b, k as usize, |c, bv| {
                let c = c as usize;
                if !in_row[c] {
                    in_row[c] = true;
                    acc[c] = S::ADD_IDENTITY;
                    touched.push(c);
                }
                acc[c] = S::add(acc[c], S::mul(av, bv));
            });
        });
        if let Some((mk_a, mk)) = mask {
            // keep only entries where the mask row has structure
            let mut allowed = vec![false; ncols];
            mk.row_for_each(mk_a, r, |c, _| allowed[c as usize] = true);
            for &c in &touched {
                if allowed[c] {
                    trips.push((r as u64, c as u64, acc[c]));
                }
                in_row[c] = false;
            }
        } else {
            for &c in &touched {
                trips.push((r as u64, c as u64, acc[c]));
                in_row[c] = false;
            }
        }
    }
    GrbMatrix::build(out_a, ma.nrows(), ncols, &mut trips)
}

/// Element-wise w = u ⊕ v (union of structures).
pub fn ewise_add<S: Semiring>(u: &GrbVector, v: &GrbVector) -> GrbVector {
    assert_eq!(u.len(), v.len());
    let mut w = GrbVector::new(u.len());
    for i in 0..u.len() {
        match (u.mask[i], v.mask[i]) {
            (true, true) => w.set(i, S::add(u.vals[i], v.vals[i])),
            (true, false) => w.set(i, u.vals[i]),
            (false, true) => w.set(i, v.vals[i]),
            (false, false) => {}
        }
    }
    w
}

/// Element-wise w = u ⊗ v (intersection of structures).
pub fn ewise_mult<S: Semiring>(u: &GrbVector, v: &GrbVector) -> GrbVector {
    assert_eq!(u.len(), v.len());
    let mut w = GrbVector::new(u.len());
    for i in 0..u.len() {
        if u.mask[i] && v.mask[i] {
            w.set(i, S::mul(u.vals[i], v.vals[i]));
        }
    }
    w
}

/// Reduce a vector with the semiring's ⊕.
pub fn reduce<S: Semiring>(u: &GrbVector) -> f64 {
    let mut acc = S::ADD_IDENTITY;
    for i in 0..u.len() {
        if u.mask[i] {
            acc = S::add(acc, u.vals[i]);
        }
    }
    acc
}

/// Reduce all stored matrix values with ⊕.
pub fn reduce_matrix<S: Semiring, A: SegmentAlloc>(a: &A, m: &GrbMatrix) -> f64 {
    let mut acc = S::ADD_IDENTITY;
    for r in 0..m.nrows() {
        m.row_for_each(a, r, |_, v| acc = S::add(acc, v));
    }
    acc
}

/// Apply a unary function to stored entries.
pub fn apply(u: &GrbVector, f: impl Fn(f64) -> f64) -> GrbVector {
    let mut w = GrbVector::new(u.len());
    for i in 0..u.len() {
        if u.mask[i] {
            w.set(i, f(u.vals[i]));
        }
    }
    w
}

/// Complement-masked assign: keep `u`'s entries only where `mask` has
/// **no** entry (the BFS "not yet visited" filter).
pub fn mask_complement(u: &GrbVector, mask: &GrbVector) -> GrbVector {
    assert_eq!(u.len(), mask.len());
    let mut w = GrbVector::new(u.len());
    for i in 0..u.len() {
        if u.mask[i] && !mask.mask[i] {
            w.set(i, u.vals[i]);
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbtl::semiring::{MinPlus, OrAnd, PlusTimes};
    use crate::gbtl::HeapAlloc;

    fn tri(h: &HeapAlloc) -> GrbMatrix {
        // 0→1, 1→2, 2→0 cycle + 0→2 chord
        GrbMatrix::from_edges(h, 3, &[(0, 1), (1, 2), (2, 0), (0, 2)]).unwrap()
    }

    #[test]
    fn mxv_plus_times_matches_dense() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        let m = tri(&h);
        let u = GrbVector { vals: vec![1.0, 2.0, 3.0], mask: vec![true; 3] };
        let w = mxv::<PlusTimes, _>(&h, &m, &u);
        // dense rows: r0 = [0,1,1]·u = 5; r1 = [0,0,1]·u = 3; r2 = [1,0,0]·u = 1
        assert_eq!(w.get(0), Some(5.0));
        assert_eq!(w.get(1), Some(3.0));
        assert_eq!(w.get(2), Some(1.0));
    }

    #[test]
    fn vxm_is_transpose_mxv() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        let m = tri(&h);
        let mt = m.transpose(&h, &h).unwrap();
        let u = GrbVector { vals: vec![1.0, 2.0, 3.0], mask: vec![true; 3] };
        let a = vxm::<PlusTimes, _>(&h, &u, &m);
        let b = mxv::<PlusTimes, _>(&h, &mt, &u);
        assert_eq!(a, b);
    }

    #[test]
    fn mxv_respects_input_mask() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        let m = tri(&h);
        let mut u = GrbVector::new(3);
        u.set(2, 1.0); // only vertex 2 present
        let w = mxv::<OrAnd, _>(&h, &m, &u);
        assert_eq!(w.get(0), Some(1.0)); // 0→2 edge sees it
        assert_eq!(w.get(1), Some(1.0)); // 1→2
        assert_eq!(w.get(2), None, "no in-edge from 2 to 2");
    }

    #[test]
    fn mxm_counts_paths() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        let m = tri(&h);
        let sq = mxm::<PlusTimes, _, _, _>(&h, &m, &h, &m, &h, None).unwrap();
        // paths of length 2: 0→1→2, 0→2→0, 1→2→0, 2→0→1, 2→0→2
        let d = sq.to_dense(&h);
        assert_eq!(d[0][2], 1.0);
        assert_eq!(d[0][0], 1.0);
        assert_eq!(d[1][0], 1.0);
        assert_eq!(d[2][1], 1.0);
        assert_eq!(d[2][2], 1.0);
    }

    #[test]
    fn masked_mxm_filters_structure() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        let m = tri(&h);
        let sq = mxm::<PlusTimes, _, _, _>(&h, &m, &h, &m, &h, Some((&h, &m))).unwrap();
        // only entries coinciding with edges of m survive:
        // m has (0,1),(0,2),(1,2),(2,0); sq has (0,0),(0,2),(1,0),(2,1),(2,2)
        // intersection: (0,2)
        assert_eq!(sq.nvals(&h), 1);
        assert_eq!(sq.to_dense(&h)[0][2], 1.0);
    }

    #[test]
    fn ewise_and_reduce() {
        let mut u = GrbVector::new(3);
        u.set(0, 2.0);
        u.set(1, 3.0);
        let mut v = GrbVector::new(3);
        v.set(1, 4.0);
        v.set(2, 5.0);
        let add = ewise_add::<PlusTimes>(&u, &v);
        assert_eq!(add.get(0), Some(2.0));
        assert_eq!(add.get(1), Some(7.0));
        assert_eq!(add.get(2), Some(5.0));
        let mult = ewise_mult::<PlusTimes>(&u, &v);
        assert_eq!(mult.nvals(), 1);
        assert_eq!(mult.get(1), Some(12.0));
        assert_eq!(reduce::<PlusTimes>(&add), 14.0);
        assert_eq!(reduce::<MinPlus>(&add), 2.0);
    }

    #[test]
    fn complement_mask() {
        let mut u = GrbVector::new(3);
        u.set(0, 1.0);
        u.set(1, 1.0);
        let mut seen = GrbVector::new(3);
        seen.set(1, 9.0);
        let w = mask_complement(&u, &seen);
        assert_eq!(w.get(0), Some(1.0));
        assert_eq!(w.get(1), None);
    }
}

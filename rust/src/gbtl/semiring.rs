//! Semirings (paper §7.1: "operations using an extended algebra of
//! semirings"). All over `f64` carriers; the identities are the
//! GraphBLAS-standard ones.

/// A GraphBLAS semiring: `(add, add_identity, mul)`.
pub trait Semiring: Copy + Send + Sync + 'static {
    fn add(a: f64, b: f64) -> f64;
    fn mul(a: f64, b: f64) -> f64;
    const ADD_IDENTITY: f64;
    const NAME: &'static str;
}

/// Arithmetic (+, ×) — PageRank, triangle counting.
#[derive(Clone, Copy, Debug)]
pub struct PlusTimes;

impl Semiring for PlusTimes {
    #[inline]
    fn add(a: f64, b: f64) -> f64 {
        a + b
    }
    #[inline]
    fn mul(a: f64, b: f64) -> f64 {
        a * b
    }
    const ADD_IDENTITY: f64 = 0.0;
    const NAME: &'static str = "plus-times";
}

/// Boolean (∨, ∧) on 0/1 — BFS reachability.
#[derive(Clone, Copy, Debug)]
pub struct OrAnd;

impl Semiring for OrAnd {
    #[inline]
    fn add(a: f64, b: f64) -> f64 {
        if a != 0.0 || b != 0.0 { 1.0 } else { 0.0 }
    }
    #[inline]
    fn mul(a: f64, b: f64) -> f64 {
        if a != 0.0 && b != 0.0 { 1.0 } else { 0.0 }
    }
    const ADD_IDENTITY: f64 = 0.0;
    const NAME: &'static str = "or-and";
}

/// Tropical (min, +) — single-source shortest paths.
#[derive(Clone, Copy, Debug)]
pub struct MinPlus;

impl Semiring for MinPlus {
    #[inline]
    fn add(a: f64, b: f64) -> f64 {
        a.min(b)
    }
    #[inline]
    fn mul(a: f64, b: f64) -> f64 {
        a + b
    }
    const ADD_IDENTITY: f64 = f64::INFINITY;
    const NAME: &'static str = "min-plus";
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identities() {
        assert_eq!(PlusTimes::add(PlusTimes::ADD_IDENTITY, 5.0), 5.0);
        assert_eq!(OrAnd::add(OrAnd::ADD_IDENTITY, 1.0), 1.0);
        assert_eq!(MinPlus::add(MinPlus::ADD_IDENTITY, 3.0), 3.0);
    }

    #[test]
    fn semiring_laws_sample() {
        // associativity + commutativity spot checks
        for (a, b, c) in [(1.0, 2.0, 3.0), (0.5, 0.0, 7.0)] {
            assert_eq!(PlusTimes::add(a, PlusTimes::add(b, c)), PlusTimes::add(PlusTimes::add(a, b), c));
            assert_eq!(MinPlus::add(a, b), MinPlus::add(b, a));
            assert_eq!(MinPlus::mul(a, MinPlus::mul(b, c)), MinPlus::mul(MinPlus::mul(a, b), c));
        }
    }

    #[test]
    fn orand_is_boolean() {
        assert_eq!(OrAnd::mul(1.0, 1.0), 1.0);
        assert_eq!(OrAnd::mul(1.0, 0.0), 0.0);
        assert_eq!(OrAnd::add(0.0, 0.0), 0.0);
        assert_eq!(OrAnd::add(7.0, 0.0), 1.0, "nonzero collapses to 1");
    }
}

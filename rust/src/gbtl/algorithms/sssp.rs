//! Single-source shortest paths: Bellman-Ford style min-plus `vxm`
//! relaxation sweeps.

use crate::alloc::SegmentAlloc;
use crate::gbtl::ops::{ewise_add, vxm};
use crate::gbtl::semiring::MinPlus;
use crate::gbtl::types::{GrbMatrix, GrbVector};

/// Distances from `source` (`f64::INFINITY` = unreachable). Edge weights
/// are the stored matrix values.
pub fn sssp<A: SegmentAlloc>(a: &A, m: &GrbMatrix, source: usize) -> Vec<f64> {
    let n = m.nrows();
    let mut dist = GrbVector::new(n);
    dist.set(source, 0.0);
    for _ in 0..n {
        let relaxed = vxm::<MinPlus, _>(a, &dist, m);
        let next = ewise_add::<MinPlus>(&dist, &relaxed);
        if next == dist {
            break; // fixed point
        }
        dist = next;
    }
    (0..n).map(|i| dist.get(i).unwrap_or(f64::INFINITY)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbtl::HeapAlloc;

    #[test]
    fn weighted_paths() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        let mut trips = vec![
            (0u64, 1u64, 4.0),
            (0, 2, 1.0),
            (2, 1, 2.0), // 0→2→1 (3) beats 0→1 (4)
            (1, 3, 1.0),
        ];
        let m = GrbMatrix::build(&h, 4, 4, &mut trips).unwrap();
        let d = sssp(&h, &m, 0);
        assert_eq!(d, vec![0.0, 3.0, 1.0, 4.0]);
    }

    #[test]
    fn unreachable_is_infinite() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        let m = GrbMatrix::from_edges(&h, 3, &[(1, 2)]).unwrap();
        let d = sssp(&h, &m, 0);
        assert_eq!(d[0], 0.0);
        assert!(d[1].is_infinite() && d[2].is_infinite());
    }

    #[test]
    fn unweighted_equals_bfs_levels() {
        use crate::gbtl::algorithms::bfs::bfs_level;
        use crate::graph::rmat::RmatGenerator;
        let h = HeapAlloc::with_reserve(256 << 20).unwrap();
        let edges = RmatGenerator::graph500(6, 4).seed(4).generate();
        let m = GrbMatrix::from_edges(&h, 64, &edges).unwrap();
        let d = sssp(&h, &m, 0);
        let l = bfs_level(&h, &m, 0);
        for i in 0..64 {
            if l[i] < 0 {
                assert!(d[i].is_infinite());
            } else {
                assert_eq!(d[i], l[i] as f64, "vertex {i}");
            }
        }
    }
}

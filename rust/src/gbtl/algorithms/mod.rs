//! High-level graph algorithms built on the GraphBLAS primitives — the
//! five the paper "metallized" (§7.3.2): breadth-first search, PageRank,
//! single-source shortest paths, triangle counting, and k-truss.
//!
//! Exactly as the paper found, "no changes were made inside the graph
//! algorithm functions" to support persistence: each takes the matrix's
//! allocator generically and uses DRAM ([`crate::gbtl::HeapAlloc`])
//! for temporaries.

pub mod bfs;
pub mod pagerank;
pub mod sssp;
pub mod triangle;
pub mod ktruss;

pub use bfs::bfs_level;
pub use ktruss::ktruss;
pub use pagerank::pagerank;
pub use sssp::sssp;
pub use triangle::triangle_count;

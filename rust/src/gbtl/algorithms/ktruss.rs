//! k-truss: iteratively prune edges supported by fewer than k-2
//! triangles (masked `mxm` support counting), the GraphBLAS k-truss of
//! Davis.

use crate::alloc::SegmentAlloc;
use crate::error::Result;
use crate::gbtl::ops::mxm;
use crate::gbtl::semiring::PlusTimes;
use crate::gbtl::types::GrbMatrix;
use crate::gbtl::HeapAlloc;

/// Return the edges (undirected, canonical `u < v`) of the k-truss of
/// the symmetrized input graph.
pub fn ktruss<A: SegmentAlloc>(a: &A, m: &GrbMatrix, k: usize) -> Result<Vec<(u64, u64)>> {
    assert!(k >= 3, "k-truss requires k >= 3");
    let h = HeapAlloc::new()?;
    // symmetrized simple adjacency in DRAM
    let mut trips = Vec::new();
    for r in 0..m.nrows() {
        m.row_for_each(a, r, |c, _| {
            if r as u64 != c {
                trips.push((r as u64, c, 1.0));
                trips.push((c, r as u64, 1.0));
            }
        });
    }
    trips.sort_unstable_by_key(|&(r, c, _)| (r, c));
    trips.dedup_by_key(|t| (t.0, t.1));
    let mut cur = GrbMatrix::build(&h, m.nrows(), m.ncols(), &mut trips)?;
    let support_needed = (k - 2) as f64;
    loop {
        // support of each edge = # of common neighbors = (A·A) masked by A
        let sup = mxm::<PlusTimes, _, _, _>(&h, &cur, &h, &cur, &h, Some((&h, &cur)))?;
        // keep edges with support >= k-2
        let mut keep = Vec::new();
        let mut dropped = 0usize;
        for r in 0..sup.nrows() {
            sup.row_for_each(&h, r, |c, v| {
                if v >= support_needed {
                    keep.push((r as u64, c, 1.0));
                } else {
                    dropped += 1;
                }
            });
        }
        // edges of cur without any support entry are dropped too
        let before = cur.nvals(&h);
        let next = GrbMatrix::build(&h, m.nrows(), m.ncols(), &mut keep)?;
        let after = next.nvals(&h);
        cur = next;
        if after == before {
            break;
        }
        if after == 0 {
            break;
        }
    }
    let mut out = Vec::new();
    for r in 0..cur.nrows() {
        cur.row_for_each(&h, r, |c, _| {
            if (r as u64) < c {
                out.push((r as u64, c));
            }
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k4_is_a_4_truss() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        let mut edges = Vec::new();
        for i in 0..4u64 {
            for j in (i + 1)..4 {
                edges.push((i, j));
            }
        }
        let m = GrbMatrix::from_edges(&h, 4, &edges).unwrap();
        let t3 = ktruss(&h, &m, 3).unwrap();
        assert_eq!(t3.len(), 6, "K4 entirely survives 3-truss");
        let t4 = ktruss(&h, &m, 4).unwrap();
        assert_eq!(t4.len(), 6, "K4 is a 4-truss (every edge in 2 triangles)");
        let t5 = ktruss(&h, &m, 5).unwrap();
        assert!(t5.is_empty(), "K4 has no 5-truss");
    }

    #[test]
    fn pendant_edges_pruned_from_3truss() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        // triangle 0-1-2 plus pendant 2-3
        let m = GrbMatrix::from_edges(&h, 4, &[(0, 1), (1, 2), (0, 2), (2, 3)]).unwrap();
        let t3 = ktruss(&h, &m, 3).unwrap();
        assert_eq!(t3, vec![(0, 1), (0, 2), (1, 2)]);
    }

    #[test]
    fn empty_result_when_no_triangles() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        let m = GrbMatrix::from_edges(&h, 4, &[(0, 1), (1, 2), (2, 3)]).unwrap();
        assert!(ktruss(&h, &m, 3).unwrap().is_empty());
    }
}

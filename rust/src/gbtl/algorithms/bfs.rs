//! Breadth-first search: masked or-and `vxm` level sweeps (the textbook
//! GraphBLAS BFS).

use crate::alloc::SegmentAlloc;
use crate::gbtl::ops::{mask_complement, vxm};
use crate::gbtl::semiring::OrAnd;
use crate::gbtl::types::{GrbMatrix, GrbVector};

/// Levels from `source` (-1 = unreachable), following out-edges.
pub fn bfs_level<A: SegmentAlloc>(a: &A, m: &GrbMatrix, source: usize) -> Vec<i64> {
    let n = m.nrows();
    let mut level = vec![-1i64; n];
    level[source] = 0;
    let mut visited = GrbVector::new(n);
    visited.set(source, 1.0);
    let mut frontier = GrbVector::new(n);
    frontier.set(source, 1.0);
    let mut depth = 0i64;
    while frontier.nvals() > 0 && depth < n as i64 {
        depth += 1;
        let next = vxm::<OrAnd, _>(a, &frontier, m);
        frontier = mask_complement(&next, &visited);
        for i in 0..n {
            if frontier.mask[i] {
                visited.set(i, 1.0);
                level[i] = depth;
            }
        }
    }
    level
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbtl::HeapAlloc;
    use crate::graph::ell::EllGraph;
    use crate::graph::rmat::RmatGenerator;

    #[test]
    fn diamond_levels() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        let m = GrbMatrix::from_edges(&h, 4, &[(0, 1), (0, 2), (1, 3), (2, 3)]).unwrap();
        assert_eq!(bfs_level(&h, &m, 0), vec![0, 1, 1, 2]);
        assert_eq!(bfs_level(&h, &m, 3), vec![-1, -1, -1, 0]);
    }

    #[test]
    fn matches_ell_native_on_rmat() {
        let h = HeapAlloc::with_reserve(256 << 20).unwrap();
        let edges = RmatGenerator::graph500(7, 6).seed(3).generate();
        // dedup like GrbMatrix does so comparisons see the same graph
        let g = EllGraph::from_edges(128, &edges, 16);
        let m = GrbMatrix::from_edges(&h, 128, &edges).unwrap();
        let a = bfs_level(&h, &m, 0);
        let b = g.bfs_native(0);
        assert_eq!(a, b);
    }
}

//! Triangle counting: the Azad/Buluç–style masked `mxm` formulation
//! `ntri = Σ (L ⊗ (L ⊕.⊗ L))` with `L` the strictly-lower-triangular
//! part of the undirected adjacency matrix.

use crate::alloc::SegmentAlloc;
use crate::gbtl::ops::{mxm, reduce_matrix};
use crate::gbtl::semiring::PlusTimes;
use crate::gbtl::types::GrbMatrix;
use crate::gbtl::HeapAlloc;
use crate::error::Result;

/// Count triangles of an *undirected* graph given as a symmetric
/// adjacency matrix (or any edge list — symmetrized internally).
pub fn triangle_count<A: SegmentAlloc>(a: &A, m: &GrbMatrix) -> Result<u64> {
    let h = HeapAlloc::new()?;
    // symmetrize into DRAM (GBTL's tmp_g pattern, §7.3.2)
    let mut trips = Vec::new();
    for r in 0..m.nrows() {
        m.row_for_each(a, r, |c, _| {
            if r as u64 != c {
                trips.push((r as u64, c, 1.0));
                trips.push((c, r as u64, 1.0));
            }
        });
    }
    trips.sort_unstable_by_key(|&(r, c, _)| (r, c));
    trips.dedup_by_key(|t| (t.0, t.1));
    let sym = GrbMatrix::build(&h, m.nrows(), m.ncols(), &mut trips)?;
    let l = sym.tril(&h, &h)?;
    // masked L·L — only entries where L has structure survive
    let b = mxm::<PlusTimes, _, _, _>(&h, &l, &h, &l, &h, Some((&h, &l)))?;
    Ok(reduce_matrix::<PlusTimes, _>(&h, &b) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_triangle() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        let m = GrbMatrix::from_edges(&h, 3, &[(0, 1), (1, 2), (2, 0)]).unwrap();
        assert_eq!(triangle_count(&h, &m).unwrap(), 1);
    }

    #[test]
    fn k4_has_four_triangles() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        let mut edges = Vec::new();
        for i in 0..4u64 {
            for j in (i + 1)..4 {
                edges.push((i, j));
            }
        }
        let m = GrbMatrix::from_edges(&h, 4, &edges).unwrap();
        assert_eq!(triangle_count(&h, &m).unwrap(), 4);
    }

    #[test]
    fn triangle_free_graph() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        // a path and a square (4-cycle): no triangles
        let m =
            GrbMatrix::from_edges(&h, 4, &[(0, 1), (1, 2), (2, 3), (3, 0)]).unwrap();
        assert_eq!(triangle_count(&h, &m).unwrap(), 0);
    }

    #[test]
    fn matches_brute_force_on_random() {
        use crate::graph::rmat::RmatGenerator;
        let h = HeapAlloc::with_reserve(256 << 20).unwrap();
        let edges = RmatGenerator::graph500(5, 4).seed(2).generate();
        let n = 32usize;
        let m = GrbMatrix::from_edges(&h, n, &edges).unwrap();
        // brute force on the symmetrized simple graph
        let mut adj = vec![vec![false; n]; n];
        for &(s, d) in &edges {
            if s != d {
                adj[s as usize][d as usize] = true;
                adj[d as usize][s as usize] = true;
            }
        }
        let mut want = 0u64;
        for i in 0..n {
            for j in (i + 1)..n {
                if !adj[i][j] {
                    continue;
                }
                for k in (j + 1)..n {
                    if adj[i][k] && adj[j][k] {
                        want += 1;
                    }
                }
            }
        }
        assert_eq!(triangle_count(&h, &m).unwrap(), want);
    }
}

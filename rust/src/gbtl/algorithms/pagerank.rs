//! PageRank over plus-times `vxm` with damping and dangling-mass
//! redistribution (matches the L2 JAX model bit-for-bit in the math).

use crate::alloc::SegmentAlloc;
use crate::gbtl::ops::vxm;
use crate::gbtl::semiring::PlusTimes;
use crate::gbtl::types::{GrbMatrix, GrbVector};

/// Power iteration until `tol` (L1 delta) or `max_iters`.
pub fn pagerank<A: SegmentAlloc>(
    a: &A,
    m: &GrbMatrix,
    alpha: f64,
    max_iters: usize,
    tol: f64,
) -> (Vec<f64>, usize) {
    let n = m.nrows();
    let outdeg: Vec<f64> = (0..n).map(|r| m.out_degree(a, r) as f64).collect();
    let mut ranks = vec![1.0 / n as f64; n];
    let mut iters = 0;
    for _ in 0..max_iters {
        iters += 1;
        // contribution vector: rank/outdeg on non-dangling vertices
        let mut contrib = GrbVector::new(n);
        let mut dangling_mass = 0.0;
        for i in 0..n {
            if outdeg[i] > 0.0 {
                contrib.set(i, ranks[i] / outdeg[i]);
            } else {
                dangling_mass += ranks[i];
            }
        }
        let pulled = vxm::<PlusTimes, _>(a, &contrib, m);
        let mut delta = 0.0;
        let mut next = vec![0.0; n];
        for i in 0..n {
            let v = (1.0 - alpha) / n as f64
                + alpha * (pulled.get(i).unwrap_or(0.0) + dangling_mass / n as f64);
            delta += (v - ranks[i]).abs();
            next[i] = v;
        }
        ranks = next;
        if delta < tol {
            break;
        }
    }
    (ranks, iters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbtl::HeapAlloc;
    use crate::graph::ell::EllGraph;
    use crate::graph::rmat::RmatGenerator;

    #[test]
    fn ranks_sum_to_one_and_order_sane() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        // star into vertex 3
        let m = GrbMatrix::from_edges(&h, 4, &[(0, 3), (1, 3), (2, 3)]).unwrap();
        let (r, _) = pagerank(&h, &m, 0.85, 100, 1e-12);
        assert!((r.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(r[3] > r[0]);
        assert!((r[0] - r[1]).abs() < 1e-12);
    }

    #[test]
    fn matches_ell_native_pagerank() {
        let h = HeapAlloc::with_reserve(256 << 20).unwrap();
        let mut edges = RmatGenerator::graph500(6, 6).seed(9).generate();
        edges.sort_unstable();
        edges.dedup(); // GrbMatrix::from_edges dedups; match it
        let g = EllGraph::from_edges(64, &edges, 16);
        let m = GrbMatrix::from_edges(&h, 64, &edges).unwrap();
        let (r, _) = pagerank(&h, &m, 0.85, 40, 0.0);
        let nat = g.pagerank_native(0.85, 40);
        for i in 0..64 {
            assert!(
                (r[i] - nat[i] as f64).abs() < 1e-4,
                "vertex {i}: {} vs {}",
                r[i],
                nat[i]
            );
        }
    }

    #[test]
    fn tolerance_early_exit() {
        let h = HeapAlloc::with_reserve(64 << 20).unwrap();
        let m = GrbMatrix::from_edges(&h, 3, &[(0, 1), (1, 2), (2, 0)]).unwrap();
        let (_, iters) = pagerank(&h, &m, 0.85, 10_000, 1e-10);
        assert!(iters < 200, "cycle converges fast, took {iters}");
    }
}

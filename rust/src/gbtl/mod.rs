//! GBTL — a GraphBLAS Template Library analogue (paper §7).
//!
//! Graphs are sparse matrices; algorithms are expressed over semirings
//! (Kepner et al., "Mathematical foundations of the GraphBLAS"). The
//! containers are **allocator-aware** exactly as §7.3.1 describes: the
//! persistent matrix takes a [`crate::alloc::SegmentAlloc`]; temporary
//! results inside algorithms use the [`heap::HeapAlloc`] fallback — the
//! rust rendition of the paper's *fallback allocator adaptor* (§7.3.2),
//! which routes default-constructed containers to DRAM.

pub mod heap;
pub mod semiring;
pub mod types;
pub mod ops;
pub mod algorithms;

pub use heap::HeapAlloc;
pub use semiring::{MinPlus, OrAnd, PlusTimes, Semiring};
pub use types::{GrbMatrix, GrbVector};

//! Baseline persistent allocators the paper evaluates against (§6.3.1).
//!
//! Each reimplements the *architecture* of its C++ counterpart (the
//! property Fig 4 actually measures) over the same
//! [`crate::storage::segment`] substrate and behind the same
//! [`crate::alloc::SegmentAlloc`] interface, so the identical banked
//! adjacency list runs over all of them:
//!
//! - [`bip`] — Boost.Interprocess `managed_mapped_file`: one ordered
//!   free-block tree behind **one global mutex**; never frees file space.
//! - [`pmemkind`] — memkind PMEM kind (jemalloc): per-thread arenas, no
//!   persistence (volatile file-backed), eager `madvise` purging of freed
//!   memory — with the `MADV_REMOVE` vs `MADV_DONTNEED` switch the paper
//!   flips on Optane.
//! - [`ralloc_like`] — Ralloc: lock-free per-class free lists whose links
//!   live inside the freed slots themselves (persistent), with per-thread
//!   bump blocks.

pub mod bip;
pub mod pmemkind;
pub mod ralloc_like;

use crate::alloc::SegmentAlloc;
use crate::error::Result;

/// Lifecycle facet the benchmarks need on top of [`SegmentAlloc`].
pub trait BenchAllocator: SegmentAlloc {
    fn name(&self) -> &'static str;
    /// Flush to the backing store (persistence point).
    fn sync_all(&self) -> Result<()>;
    /// Whether data can be reattached after close (pmemkind: no).
    fn supports_reattach(&self) -> bool;
}

impl BenchAllocator for crate::alloc::MetallManager {
    fn name(&self) -> &'static str {
        "metall"
    }

    fn sync_all(&self) -> Result<()> {
        self.sync()
    }

    fn supports_reattach(&self) -> bool {
        true
    }
}

//! memkind (PMEM kind) style baseline (paper §6.3.1).
//!
//! jemalloc architecture: **per-thread arenas** (size-classed bins,
//! reusing the same class math as Metall — both inherit jemalloc's
//! classes) with *eager purging*: freed memory is `madvise`d back
//! immediately. The paper's Optane finding is reproduced as a switch:
//! `MADV_REMOVE` (frees file space too — pathological on DAX) vs
//! `MADV_DONTNEED` (drops DRAM only — their fix).
//!
//! "Although PMEM kind allocates memory into a file, it uses persistent
//! memory as volatile memory — i.e., it cannot reattach data" — so no
//! persistence support ([`supports_reattach`] = false).

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::alloc::bin_dir::BinData;
use crate::alloc::chunk_dir::{ChunkDirectory, ChunkKind};
use crate::alloc::size_class::{bin_of, is_small, large_chunks, num_bins, size_of_bin, slots_per_chunk};
use crate::alloc::SegmentAlloc;
use crate::baselines::BenchAllocator;
use crate::error::{Error, Result};
use crate::storage::mmap::page_size;
use crate::storage::segment::{SegmentOptions, SegmentStorage};

/// The purge flavour used when memory is freed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MadvMode {
    /// `MADV_REMOVE`: frees DRAM **and** file space — memkind's default
    /// behaviour that caused "vital performance degradation" on Optane.
    Remove,
    /// `MADV_DONTNEED`: frees DRAM only — the paper's fix.
    DontNeed,
}

struct Arena {
    bins: Vec<BinData>,
}

/// jemalloc-style volatile file allocator.
pub struct PmemKindAllocator {
    segment: SegmentStorage,
    arenas: Vec<Mutex<Arena>>,
    /// chunk directory + chunk→arena ownership (one lock: jemalloc's
    /// chunk hooks are likewise centralized).
    chunks: Mutex<(ChunkDirectory, Vec<u32>)>,
    pub madv: MadvMode,
    next_arena: AtomicUsize,
    chunk_size: usize,
    _dir: PathBuf,
    /// number of madvise calls issued (perf instrumentation).
    pub madvise_calls: AtomicUsize,
}

thread_local! {
    static ARENA_SLOT: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
}

impl PmemKindAllocator {
    pub fn create(dir: impl Into<PathBuf>, madv: MadvMode) -> Result<Self> {
        Self::create_with(dir, madv, SegmentOptions::default(), 2 << 20)
    }

    pub fn create_with(
        dir: impl Into<PathBuf>,
        madv: MadvMode,
        opts: SegmentOptions,
        chunk_size: usize,
    ) -> Result<Self> {
        let dir = dir.into();
        assert!(opts.file_size % chunk_size == 0);
        let segment = SegmentStorage::create(dir.join("segment"), opts)?;
        let ncores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let narenas = (ncores * 2).max(2); // jemalloc defaults to ~2×cores
        let nb = num_bins(chunk_size);
        Ok(Self {
            segment,
            arenas: (0..narenas)
                .map(|_| Mutex::new(Arena { bins: (0..nb).map(|_| BinData::new()).collect() }))
                .collect(),
            chunks: Mutex::new((ChunkDirectory::new(), Vec::new())),
            madv,
            next_arena: AtomicUsize::new(0),
            chunk_size,
            _dir: dir,
            madvise_calls: AtomicUsize::new(0),
        })
    }

    fn arena_slot(&self) -> usize {
        ARENA_SLOT.with(|c| {
            let mut v = c.get();
            if v == usize::MAX {
                v = self.next_arena.fetch_add(1, Ordering::Relaxed) % self.arenas.len();
                c.set(v);
            }
            v % self.arenas.len()
        })
    }

    /// Eager purge of a byte range (jemalloc's decay with zero delay).
    fn purge(&self, offset: usize, len: usize) -> Result<()> {
        self.madvise_calls.fetch_add(1, Ordering::Relaxed);
        // page-align inward; skip sub-page frees
        let ps = page_size();
        let start = offset.div_ceil(ps) * ps;
        let end = (offset + len) / ps * ps;
        if start >= end {
            return Ok(());
        }
        match self.madv {
            MadvMode::Remove => {
                crate::storage::mmap::madvise_remove(
                    unsafe { self.segment.base().add(start) },
                    end - start,
                )
            }
            MadvMode::DontNeed => crate::storage::mmap::madvise_dontneed(
                unsafe { self.segment.base().add(start) },
                end - start,
            ),
        }
    }
}

impl SegmentAlloc for PmemKindAllocator {
    fn allocate(&self, size: usize) -> Result<u64> {
        if size == 0 {
            return Err(Error::Alloc("zero-size allocation".into()));
        }
        let cs = self.chunk_size;
        if !is_small(size, cs) {
            let n = large_chunks(size, cs) as u32;
            let mut ch = self.chunks.lock().unwrap();
            let head = ch.0.take_large(n);
            if ch.1.len() < ch.0.len() {
                let n = ch.0.len();
            ch.1.resize(n, u32::MAX);
            }
            self.segment.extend_to((head + n) as usize * cs)?;
            return Ok(head as u64 * cs as u64);
        }
        let bin = bin_of(size) as u32;
        let slot_idx = self.arena_slot();
        let mut arena = self.arenas[slot_idx].lock().unwrap();
        if let Some((chunk, slot)) = arena.bins[bin as usize].alloc_slot() {
            return Ok(chunk as u64 * cs as u64 + slot as u64 * size_of_bin(bin as usize) as u64);
        }
        let chunk = {
            let mut ch = self.chunks.lock().unwrap();
            let chunk = ch.0.take_small_chunk(bin);
            if ch.1.len() < ch.0.len() {
                let n = ch.0.len();
            ch.1.resize(n, u32::MAX);
            }
            ch.1[chunk as usize] = slot_idx as u32;
            self.segment.extend_to((chunk as usize + 1) * cs)?;
            chunk
        };
        let slot = arena.bins[bin as usize]
            .add_chunk_and_alloc(chunk, slots_per_chunk(bin as usize, cs) as u32);
        Ok(chunk as u64 * cs as u64 + slot as u64 * size_of_bin(bin as usize) as u64)
    }

    fn deallocate(&self, offset: u64) -> Result<()> {
        let cs = self.chunk_size as u64;
        let chunk = (offset / cs) as u32;
        let (kind, owner) = {
            let ch = self.chunks.lock().unwrap();
            if (chunk as usize) >= ch.0.len() {
                return Err(Error::Alloc(format!("deallocate: offset {offset} out of range")));
            }
            (ch.0.kind(chunk), *ch.1.get(chunk as usize).unwrap_or(&u32::MAX))
        };
        match kind {
            ChunkKind::Small { bin } => {
                let class = size_of_bin(bin as usize) as u64;
                let slot = ((offset % cs) / class) as u32;
                let arena_idx = owner as usize;
                let mut arena = self.arenas[arena_idx].lock().unwrap();
                let empty = arena.bins[bin as usize].free_slot(chunk, slot);
                // jemalloc-style eager purge: freed object ≥ page returns
                // its pages immediately (this is the madvise storm).
                if class as usize >= page_size() {
                    self.purge(offset as usize, class as usize)?;
                }
                if empty {
                    arena.bins[bin as usize].remove_chunk(chunk);
                    drop(arena);
                    let mut ch = self.chunks.lock().unwrap();
                    ch.0.free_small_chunk(chunk);
                    ch.1[chunk as usize] = u32::MAX;
                    drop(ch);
                    self.purge(chunk as usize * cs as usize, cs as usize)?;
                }
                Ok(())
            }
            ChunkKind::LargeHead { .. } => {
                let n = {
                    let mut ch = self.chunks.lock().unwrap();
                    ch.0.free_large(chunk)
                };
                self.purge(chunk as usize * cs as usize, n as usize * cs as usize)?;
                Ok(())
            }
            _ => Err(Error::Alloc(format!(
                "deallocate: offset {offset} is not a live allocation"
            ))),
        }
    }

    fn base(&self) -> *mut u8 {
        self.segment.base()
    }

    fn mapped_len(&self) -> usize {
        self.segment.mapped_len()
    }
}

impl BenchAllocator for PmemKindAllocator {
    fn name(&self) -> &'static str {
        match self.madv {
            MadvMode::Remove => "pmemkind",
            MadvMode::DontNeed => "pmemkind-dontneed",
        }
    }

    fn sync_all(&self) -> Result<()> {
        self.segment.sync(true)
    }

    fn supports_reattach(&self) -> bool {
        false // volatile: uses persistent memory as volatile memory
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn mk(d: &TempDir, madv: MadvMode) -> PmemKindAllocator {
        let opts = SegmentOptions::default().with_file_size(1 << 20).with_vm_reserve(1 << 30);
        PmemKindAllocator::create_with(d.join("s"), madv, opts, 64 << 10).unwrap()
    }

    #[test]
    fn alloc_free_roundtrip() {
        let d = TempDir::new("pk1");
        let a = mk(&d, MadvMode::DontNeed);
        let x = a.allocate(100).unwrap();
        a.write_pod::<u64>(x, 7);
        assert_eq!(a.read_pod::<u64>(x), 7);
        a.deallocate(x).unwrap();
        let y = a.allocate(100).unwrap();
        assert_eq!(x, y, "same-thread arena reuses the slot");
    }

    #[test]
    fn remove_mode_purges_file_space() {
        let d = TempDir::new("pk2");
        let a = mk(&d, MadvMode::Remove);
        let x = a.allocate(256 << 10).unwrap(); // large (4 chunks of 64K)
        unsafe { a.bytes_at_mut(x, 256 << 10).fill(1) };
        a.sync_all().unwrap();
        let before = a.segment.allocated_file_blocks().unwrap();
        a.deallocate(x).unwrap();
        let after = a.segment.allocated_file_blocks().unwrap();
        assert!(after < before, "REMOVE purge frees file space: {before}->{after}");
        assert!(a.madvise_calls.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn dontneed_mode_keeps_file_space() {
        let d = TempDir::new("pk3");
        let a = mk(&d, MadvMode::DontNeed);
        let x = a.allocate(256 << 10).unwrap();
        unsafe { a.bytes_at_mut(x, 256 << 10).fill(1) };
        a.sync_all().unwrap();
        let before = a.segment.allocated_file_blocks().unwrap();
        a.deallocate(x).unwrap();
        let after = a.segment.allocated_file_blocks().unwrap();
        assert!(after >= before, "DONTNEED keeps file space: {before}->{after}");
    }

    #[test]
    fn eager_purge_on_page_size_objects() {
        let d = TempDir::new("pk4");
        let a = mk(&d, MadvMode::DontNeed);
        let calls0 = a.madvise_calls.load(Ordering::Relaxed);
        let x = a.allocate(8192).unwrap();
        a.deallocate(x).unwrap();
        assert!(
            a.madvise_calls.load(Ordering::Relaxed) > calls0,
            "page-size free must trigger an eager madvise"
        );
        // tiny objects do not (keep a sibling allocated so the chunk
        // does not empty out, which would legitimately purge)
        let y = a.allocate(16).unwrap();
        let keep = a.allocate(16).unwrap();
        let calls1 = a.madvise_calls.load(Ordering::Relaxed);
        a.deallocate(y).unwrap();
        assert_eq!(a.madvise_calls.load(Ordering::Relaxed), calls1);
        let _ = keep;
    }

    #[test]
    fn concurrent_threads_use_separate_arenas() {
        use std::collections::HashSet;
        let d = TempDir::new("pk5");
        let a = mk(&d, MadvMode::DontNeed);
        let all: Vec<Vec<u64>> = std::thread::scope(|s| {
            (0..4)
                .map(|_| {
                    let a = &a;
                    s.spawn(move || {
                        let offs: Vec<u64> =
                            (0..300).map(|i| a.allocate(24 + (i % 64)).unwrap()).collect();
                        for &o in offs.iter().step_by(3) {
                            a.deallocate(o).unwrap();
                        }
                        offs.iter().copied().skip(1).step_by(3).collect()
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        let flat: Vec<u64> = all.into_iter().flatten().collect();
        let set: HashSet<u64> = flat.iter().copied().collect();
        assert_eq!(set.len(), flat.len(), "no overlap across arenas");
    }
}

//! Ralloc-style baseline (paper §6.3.1, §8.2): a *lock-free* persistent
//! allocator designed for byte-addressable NVRAM.
//!
//! Architecture reproduced: per-size-class **lock-free free lists** whose
//! next-links live inside the freed slots themselves (so they persist
//! with the heap), fed by per-thread bump blocks; only taking a fresh
//! chunk touches a global lock. ABA is handled with a 16-bit tag in the
//! head word. On close, live bump capacity is converted into free-list
//! entries so the entire allocator state round-trips through the heads +
//! chunk directory alone.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::alloc::chunk_dir::{ChunkDirectory, ChunkKind};
use crate::alloc::size_class::{bin_of, is_small, large_chunks, num_bins, size_of_bin, slots_per_chunk};
use crate::alloc::SegmentAlloc;
use crate::baselines::BenchAllocator;
use crate::error::{Error, Result};
use crate::storage::segment::{SegmentOptions, SegmentStorage};

const NONE: u64 = u64::MAX; // in-slot "no next" sentinel
const OFF_MASK: u64 = (1 << 48) - 1;

#[derive(Clone, Copy, Default)]
struct Bump {
    chunk: u32,
    next: u32,
    total: u32,
    live: bool,
}

/// Lock-free-ish persistent allocator.
pub struct RallocLike {
    segment: SegmentStorage,
    chunks: Mutex<ChunkDirectory>,
    /// Per-bin tagged head: 0 = empty, else (tag<<48) | (offset+1).
    heads: Vec<AtomicU64>,
    /// Per-thread-slot bump blocks, one per bin.
    bumps: Vec<Mutex<Vec<Bump>>>,
    next_slot: AtomicUsize,
    chunk_size: usize,
    dir: PathBuf,
}

thread_local! {
    static TL_SLOT: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
}

impl RallocLike {
    pub fn create(dir: impl Into<PathBuf>) -> Result<Self> {
        Self::create_with(dir, SegmentOptions::default(), 2 << 20)
    }

    pub fn create_with(
        dir: impl Into<PathBuf>,
        opts: SegmentOptions,
        chunk_size: usize,
    ) -> Result<Self> {
        let dir = dir.into();
        let segment = SegmentStorage::create(dir.join("segment"), opts)?;
        Ok(Self::build(segment, ChunkDirectory::new(), None, chunk_size, dir))
    }

    pub fn open(dir: impl Into<PathBuf>, opts: SegmentOptions, chunk_size: usize) -> Result<Self> {
        let dir = dir.into();
        let segment = SegmentStorage::open(dir.join("segment"), opts)?;
        let p = dir.join("ralloc_meta.bin");
        let buf = std::fs::read(&p).map_err(|e| Error::io(&p, e))?;
        let nb = num_bins(chunk_size);
        let bad = || Error::Datastore("corrupt ralloc_meta.bin".into());
        let (cd, used) = ChunkDirectory::deserialize_from(&buf).ok_or_else(bad)?;
        let rest = &buf[used..];
        if rest.len() != nb * 8 {
            return Err(bad());
        }
        let heads: Vec<u64> = rest
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Self::build(segment, cd, Some(heads), chunk_size, dir))
    }

    fn build(
        segment: SegmentStorage,
        chunks: ChunkDirectory,
        heads: Option<Vec<u64>>,
        chunk_size: usize,
        dir: PathBuf,
    ) -> Self {
        let nb = num_bins(chunk_size);
        let ncores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let heads = match heads {
            Some(h) => h.into_iter().map(AtomicU64::new).collect(),
            None => (0..nb).map(|_| AtomicU64::new(0)).collect(),
        };
        Self {
            segment,
            chunks: Mutex::new(chunks),
            heads,
            bumps: (0..(ncores * 2).max(2))
                .map(|_| Mutex::new(vec![Bump::default(); nb]))
                .collect(),
            next_slot: AtomicUsize::new(0),
            chunk_size,
            dir,
        }
    }

    fn tl_slot(&self) -> usize {
        TL_SLOT.with(|c| {
            let mut v = c.get();
            if v == usize::MAX {
                v = self.next_slot.fetch_add(1, Ordering::Relaxed);
                c.set(v);
            }
            v % self.bumps.len()
        })
    }

    /// Lock-free pop from the per-bin free list.
    fn pop_free(&self, bin: usize) -> Option<u64> {
        let head = &self.heads[bin];
        loop {
            let cur = head.load(Ordering::Acquire);
            if cur == 0 {
                return None;
            }
            let off = (cur & OFF_MASK) - 1;
            let next: u64 = self.read_pod(off);
            let tag = (cur >> 48).wrapping_add(1);
            let new = if next == NONE { 0 } else { (tag << 48) | (next + 1) };
            if head
                .compare_exchange_weak(cur, new, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return Some(off);
            }
        }
    }

    /// Lock-free push onto the per-bin free list.
    fn push_free(&self, bin: usize, off: u64) {
        let head = &self.heads[bin];
        loop {
            let cur = head.load(Ordering::Acquire);
            let next_off = if cur == 0 { NONE } else { (cur & OFF_MASK) - 1 };
            self.write_pod::<u64>(off, next_off);
            let tag = (cur >> 48).wrapping_add(1);
            let new = (tag << 48) | (off + 1);
            if head
                .compare_exchange_weak(cur, new, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return;
            }
        }
    }

    /// Flush bump-block remainders into the free lists and persist
    /// metadata (makes the store reattachable).
    pub fn close(&self) -> Result<()> {
        let cs = self.chunk_size as u64;
        for slot in &self.bumps {
            let mut bumps = slot.lock().unwrap();
            for (bin, b) in bumps.iter_mut().enumerate() {
                if b.live {
                    let class = size_of_bin(bin) as u64;
                    for s in b.next..b.total {
                        self.push_free(bin, b.chunk as u64 * cs + s as u64 * class);
                    }
                    b.live = false;
                }
            }
        }
        self.segment.sync(true)?;
        let mut buf = Vec::new();
        self.chunks.lock().unwrap().serialize_into(&mut buf);
        for h in &self.heads {
            buf.extend_from_slice(&h.load(Ordering::Acquire).to_le_bytes());
        }
        let p = self.dir.join("ralloc_meta.bin");
        std::fs::write(&p, &buf).map_err(|e| Error::io(&p, e))
    }
}

impl SegmentAlloc for RallocLike {
    fn allocate(&self, size: usize) -> Result<u64> {
        if size == 0 {
            return Err(Error::Alloc("zero-size allocation".into()));
        }
        let cs = self.chunk_size;
        if !is_small(size, cs) {
            let n = large_chunks(size, cs) as u32;
            let mut ch = self.chunks.lock().unwrap();
            let head = ch.take_large(n);
            self.segment.extend_to((head + n) as usize * cs)?;
            return Ok(head as u64 * cs as u64);
        }
        let bin = bin_of(size);
        // 1. lock-free free list
        if let Some(off) = self.pop_free(bin) {
            return Ok(off);
        }
        // 2. thread-local bump block
        let slot = self.tl_slot();
        let mut bumps = self.bumps[slot].lock().unwrap();
        let b = &mut bumps[bin];
        if b.live && b.next < b.total {
            let off = b.chunk as u64 * cs as u64 + b.next as u64 * size_of_bin(bin) as u64;
            b.next += 1;
            return Ok(off);
        }
        // 3. fresh chunk (global lock — the only locked path)
        let chunk = {
            let mut ch = self.chunks.lock().unwrap();
            let chunk = ch.take_small_chunk(bin as u32);
            self.segment.extend_to((chunk as usize + 1) * cs)?;
            chunk
        };
        *b = Bump { chunk, next: 1, total: slots_per_chunk(bin, cs) as u32, live: true };
        Ok(chunk as u64 * cs as u64)
    }

    fn deallocate(&self, offset: u64) -> Result<()> {
        let cs = self.chunk_size as u64;
        let chunk = (offset / cs) as u32;
        let kind = {
            let ch = self.chunks.lock().unwrap();
            if (chunk as usize) >= ch.len() {
                return Err(Error::Alloc(format!("deallocate: offset {offset} out of range")));
            }
            ch.kind(chunk)
        };
        match kind {
            ChunkKind::Small { bin } => {
                self.push_free(bin as usize, offset);
                Ok(())
            }
            ChunkKind::LargeHead { .. } => {
                let n = self.chunks.lock().unwrap().free_large(chunk);
                self.segment
                    .free_range(chunk as usize * cs as usize, n as usize * cs as usize)?;
                Ok(())
            }
            _ => Err(Error::Alloc(format!(
                "deallocate: offset {offset} is not a live allocation"
            ))),
        }
    }

    fn base(&self) -> *mut u8 {
        self.segment.base()
    }

    fn mapped_len(&self) -> usize {
        self.segment.mapped_len()
    }
}

impl BenchAllocator for RallocLike {
    fn name(&self) -> &'static str {
        "ralloc"
    }

    fn sync_all(&self) -> Result<()> {
        self.segment.sync(true)
    }

    fn supports_reattach(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn opts() -> SegmentOptions {
        SegmentOptions::default().with_file_size(1 << 20).with_vm_reserve(1 << 30)
    }

    fn mk(d: &TempDir) -> RallocLike {
        RallocLike::create_with(d.join("s"), opts(), 64 << 10).unwrap()
    }

    #[test]
    fn alloc_free_realloc_lifo() {
        let d = TempDir::new("ra1");
        let a = mk(&d);
        let x = a.allocate(40).unwrap();
        let y = a.allocate(40).unwrap();
        a.deallocate(x).unwrap();
        a.deallocate(y).unwrap();
        // free list is LIFO: y comes back first
        assert_eq!(a.allocate(40).unwrap(), y);
        assert_eq!(a.allocate(40).unwrap(), x);
    }

    #[test]
    fn lock_free_stress_no_overlap() {
        use std::collections::HashSet;
        let d = TempDir::new("ra2");
        let a = mk(&d);
        let live: Vec<Vec<u64>> = std::thread::scope(|s| {
            (0..8)
                .map(|t: u64| {
                    let a = &a;
                    s.spawn(move || {
                        let mut mine = Vec::new();
                        for i in 0..500u64 {
                            let off = a.allocate(16 + ((t + i) % 40) as usize).unwrap();
                            a.write_pod::<u64>(off, t * 1000 + i);
                            mine.push((off, t * 1000 + i));
                            if i % 3 == 0 {
                                let (o, _) = mine.swap_remove((i as usize / 3) % mine.len());
                                a.deallocate(o).unwrap();
                            }
                        }
                        // verify warm data then return survivors
                        mine.iter().for_each(|&(o, tag)| {
                            assert_eq!(a.read_pod::<u64>(o), tag);
                        });
                        mine.into_iter().map(|(o, _)| o).collect()
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        let flat: Vec<u64> = live.into_iter().flatten().collect();
        let set: HashSet<u64> = flat.iter().copied().collect();
        assert_eq!(set.len(), flat.len(), "live allocations must not overlap");
    }

    #[test]
    fn persistence_roundtrip() {
        let d = TempDir::new("ra3");
        let dir = d.join("s");
        let x;
        {
            let a = RallocLike::create_with(&dir, opts(), 64 << 10).unwrap();
            x = a.allocate(64).unwrap();
            a.write_pod::<u64>(x, 0xFEED);
            let y = a.allocate(64).unwrap();
            a.deallocate(y).unwrap();
            a.close().unwrap();
        }
        let a = RallocLike::open(&dir, opts(), 64 << 10).unwrap();
        assert_eq!(a.read_pod::<u64>(x), 0xFEED);
        // freed slot y is on the persistent free list → reused
        let z = a.allocate(64).unwrap();
        assert_ne!(z, x, "must not hand out live memory");
    }

    #[test]
    fn large_allocs() {
        let d = TempDir::new("ra4");
        let a = mk(&d);
        let x = a.allocate(200 << 10).unwrap();
        unsafe { a.bytes_at_mut(x, 200 << 10).fill(3) };
        a.deallocate(x).unwrap();
        let y = a.allocate(80 << 10).unwrap();
        assert_eq!(x, y, "freed large run is reused");
    }
}

//! Boost.Interprocess-style baseline (paper §6.3.1, §8.2).
//!
//! "BIP uses a single tree to manage memory allocations — such design
//! will suffer from many allocations and not scale well with multiple
//! threads due to lock contention; it is not capable of deallocating
//! file (persistent memory) space."
//!
//! Faithfully reproduced architecture: best-fit over an ordered free-
//! block set, boundary-tag headers in the segment, first-class
//! coalescing — all behind **one global mutex**; file space is never
//! punched.

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::sync::Mutex;

use crate::alloc::SegmentAlloc;
use crate::baselines::BenchAllocator;
use crate::error::{Error, Result};
use crate::storage::segment::{SegmentOptions, SegmentStorage};
use crate::util::align_up;

const HDR: u64 = 8; // per-block size header (boundary tag)
const MIN_BLOCK: u64 = 32;

struct Heap {
    /// offset → size of every *free* block (address-ordered, for
    /// coalescing).
    by_addr: BTreeMap<u64, u64>,
    /// (size, offset) of every free block (size-ordered, for best-fit).
    by_size: BTreeSet<(u64, u64)>,
    /// Bump frontier.
    top: u64,
}

impl Heap {
    fn insert_free(&mut self, off: u64, size: u64) {
        self.by_addr.insert(off, size);
        self.by_size.insert((size, off));
    }

    fn remove_free(&mut self, off: u64, size: u64) {
        self.by_addr.remove(&off);
        self.by_size.remove(&(size, off));
    }
}

/// The single-lock managed-mapped-file allocator.
pub struct BipAllocator {
    segment: SegmentStorage,
    heap: Mutex<Heap>,
    dir: PathBuf,
}

impl BipAllocator {
    pub fn create(dir: impl Into<PathBuf>) -> Result<Self> {
        Self::create_with(dir, SegmentOptions::default())
    }

    pub fn create_with(dir: impl Into<PathBuf>, opts: SegmentOptions) -> Result<Self> {
        let dir = dir.into();
        let segment = SegmentStorage::create(dir.join("segment"), opts)?;
        Ok(Self {
            segment,
            heap: Mutex::new(Heap { by_addr: BTreeMap::new(), by_size: BTreeSet::new(), top: 0 }),
            dir,
        })
    }

    /// Reattach. The free list is restored from `bip_free.bin` (written
    /// by [`Self::close`]).
    pub fn open(dir: impl Into<PathBuf>, opts: SegmentOptions) -> Result<Self> {
        let dir = dir.into();
        let segment = SegmentStorage::open(dir.join("segment"), opts)?;
        let p = dir.join("bip_free.bin");
        let buf = std::fs::read(&p).map_err(|e| Error::io(&p, e))?;
        if buf.len() < 16 || (buf.len() - 8) % 16 != 0 {
            return Err(Error::Datastore("corrupt bip_free.bin".into()));
        }
        let top = u64::from_le_bytes(buf[0..8].try_into().unwrap());
        let mut heap = Heap { by_addr: BTreeMap::new(), by_size: BTreeSet::new(), top };
        for rec in buf[8..].chunks_exact(16) {
            let off = u64::from_le_bytes(rec[0..8].try_into().unwrap());
            let size = u64::from_le_bytes(rec[8..16].try_into().unwrap());
            heap.insert_free(off, size);
        }
        Ok(Self { segment, heap: Mutex::new(heap), dir })
    }

    pub fn close(&self) -> Result<()> {
        self.sync_all()?;
        let heap = self.heap.lock().unwrap();
        let mut buf = Vec::with_capacity(8 + heap.by_addr.len() * 16);
        buf.extend_from_slice(&heap.top.to_le_bytes());
        for (&off, &size) in &heap.by_addr {
            buf.extend_from_slice(&off.to_le_bytes());
            buf.extend_from_slice(&size.to_le_bytes());
        }
        let p = self.dir.join("bip_free.bin");
        std::fs::write(&p, &buf).map_err(|e| Error::io(&p, e))
    }

    pub fn segment(&self) -> &SegmentStorage {
        &self.segment
    }
}

impl SegmentAlloc for BipAllocator {
    fn allocate(&self, size: usize) -> Result<u64> {
        if size == 0 {
            return Err(Error::Alloc("zero-size allocation".into()));
        }
        let need = align_up(size, 8) as u64 + HDR;
        let need = need.max(MIN_BLOCK);
        let mut heap = self.heap.lock().unwrap();
        // best fit: smallest free block that fits
        let found = heap.by_size.range((need, 0)..).next().copied();
        let (off, bsize) = match found {
            Some((bsize, off)) => {
                heap.remove_free(off, bsize);
                (off, bsize)
            }
            None => {
                // bump the frontier
                let off = heap.top;
                heap.top += need;
                self.segment.extend_to(heap.top as usize)?;
                (off, need)
            }
        };
        // split the remainder back into the tree
        if bsize - need >= MIN_BLOCK {
            heap.insert_free(off + need, bsize - need);
            self.segment_write_hdr(off, need);
        } else {
            self.segment_write_hdr(off, bsize);
        }
        Ok(off + HDR)
    }

    fn deallocate(&self, payload: u64) -> Result<()> {
        if payload < HDR {
            return Err(Error::Alloc("bad offset".into()));
        }
        let off = payload - HDR;
        let size = self.read_pod::<u64>(off);
        if size < MIN_BLOCK || size > self.segment.mapped_len() as u64 {
            return Err(Error::Alloc(format!("corrupt header at {off}: size {size}")));
        }
        let mut heap = self.heap.lock().unwrap();
        let mut off = off;
        let mut size = size;
        // coalesce with next
        if let Some(&nsize) = heap.by_addr.get(&(off + size)) {
            heap.remove_free(off + size, nsize);
            size += nsize;
        }
        // coalesce with previous
        if let Some((&poff, &psize)) = heap.by_addr.range(..off).next_back() {
            if poff + psize == off {
                heap.remove_free(poff, psize);
                off = poff;
                size += psize;
            }
        }
        // NOTE: no file-space freeing — BIP keeps the file fully sized.
        heap.insert_free(off, size);
        Ok(())
    }

    fn base(&self) -> *mut u8 {
        self.segment.base()
    }

    fn mapped_len(&self) -> usize {
        self.segment.mapped_len()
    }
}

impl BipAllocator {
    fn segment_write_hdr(&self, off: u64, size: u64) {
        self.write_pod(off, size);
    }
}

impl BenchAllocator for BipAllocator {
    fn name(&self) -> &'static str {
        "bip"
    }

    fn sync_all(&self) -> Result<()> {
        self.segment.sync(true)
    }

    fn supports_reattach(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn opts() -> SegmentOptions {
        SegmentOptions::default().with_file_size(1 << 20).with_vm_reserve(1 << 30)
    }

    #[test]
    fn alloc_write_free_reuse() {
        let d = TempDir::new("bip1");
        let a = BipAllocator::create_with(d.join("s"), opts()).unwrap();
        let x = a.allocate(100).unwrap();
        let y = a.allocate(100).unwrap();
        a.write_pod::<u64>(x, 1);
        a.write_pod::<u64>(y, 2);
        assert_eq!(a.read_pod::<u64>(x), 1);
        a.deallocate(x).unwrap();
        // best-fit reuses the freed block
        let z = a.allocate(64).unwrap();
        assert_eq!(z, x);
        assert_eq!(a.read_pod::<u64>(y), 2);
    }

    #[test]
    fn coalescing_merges_neighbors() {
        let d = TempDir::new("bip2");
        let a = BipAllocator::create_with(d.join("s"), opts()).unwrap();
        let x = a.allocate(1000).unwrap();
        let y = a.allocate(1000).unwrap();
        let z = a.allocate(1000).unwrap();
        let _guard = a.allocate(8).unwrap(); // block the frontier
        a.deallocate(x).unwrap();
        a.deallocate(z).unwrap();
        a.deallocate(y).unwrap(); // merges all three
        // a single allocation the size of all three fits in the hole
        let big = a.allocate(3000).unwrap();
        assert_eq!(big, x);
    }

    #[test]
    fn never_frees_file_space() {
        let d = TempDir::new("bip3");
        let a = BipAllocator::create_with(d.join("s"), opts()).unwrap();
        let x = a.allocate(512 * 1024).unwrap();
        unsafe { a.bytes_at_mut(x, 512 * 1024).fill(0xAA) };
        a.sync_all().unwrap();
        let before = a.segment().allocated_file_blocks().unwrap();
        a.deallocate(x).unwrap();
        a.sync_all().unwrap();
        let after = a.segment().allocated_file_blocks().unwrap();
        assert!(after >= before, "BIP must not punch holes: {before} -> {after}");
    }

    #[test]
    fn persistence_roundtrip() {
        let d = TempDir::new("bip4");
        let dir = d.join("s");
        let x;
        {
            let a = BipAllocator::create_with(&dir, opts()).unwrap();
            x = a.allocate(64).unwrap();
            a.write_pod::<u64>(x, 0xC0FFEE);
            let y = a.allocate(64).unwrap();
            a.deallocate(y).unwrap();
            a.close().unwrap();
        }
        let a = BipAllocator::open(&dir, opts()).unwrap();
        assert_eq!(a.read_pod::<u64>(x), 0xC0FFEE);
        // free list survived: the freed block is reused
        let z = a.allocate(64).unwrap();
        assert_eq!(z, x + 72); // y's old spot (64+8 header after x)
    }

    #[test]
    fn concurrent_allocs_do_not_overlap() {
        use std::collections::HashSet;
        let d = TempDir::new("bip5");
        let a = BipAllocator::create_with(d.join("s"), opts()).unwrap();
        let all: Vec<Vec<u64>> = std::thread::scope(|s| {
            (0..4)
                .map(|_| {
                    let a = &a;
                    s.spawn(move || (0..200).map(|i| a.allocate(8 + i % 100).unwrap()).collect())
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        let flat: Vec<u64> = all.into_iter().flatten().collect();
        let set: HashSet<u64> = flat.iter().copied().collect();
        assert_eq!(set.len(), flat.len());
    }
}

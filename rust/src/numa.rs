//! NUMA topology: detection, injection, and the cpu→node map the
//! allocator's shard placement is built on (ROADMAP "True NUMA
//! placement"; llfree-rs keeps per-core/per-node trees for the same
//! reason — cross-socket traffic on the allocation path dominates on
//! big-memory analytics).
//!
//! ## Design
//!
//! A [`Topology`] is an immutable cpu→node table plus each cpu's rank
//! within its node. It comes from one of three sources:
//!
//! - **Detected** — parsed from `/sys/devices/system/node/node<N>/cpulist`
//!   at manager creation. Only detected topologies are trusted for
//!   *kernel-truth* placement introspection (`move_pages` page queries).
//! - **Single-node fallback** — the sysfs tree is absent (non-NUMA
//!   kernels, sandboxed CI containers): one node owning every cpu. All
//!   placement machinery degrades to no-ops; nothing fails.
//! - **Injected** — tests and benches construct fake topologies
//!   ([`Topology::fake`]) so shard sizing, vcpu→shard routing, and the
//!   first-touch discipline are exercised on hosts with one real node.
//!   Under an injected topology, placement introspection attributes pages
//!   by their *recorded birth node* (the node the owning shard bound and
//!   first-touched the chunk on) instead of asking the kernel — the whole
//!   placement pipeline stays testable in a 1-node container.
//!
//! The topology is DRAM-only state, exactly like the shard count: nothing
//! about it is serialized, and a store written under any topology reopens
//! under any other.

use std::path::Path;

/// Where a [`Topology`] came from (module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologySource {
    /// Parsed from `/sys/devices/system/node`.
    Detected,
    /// Sysfs absent or unreadable: one node owning every cpu.
    SingleNode,
    /// Constructed by a test or bench ([`Topology::fake`]).
    Injected,
}

const UNKNOWN: u32 = u32::MAX;

/// An immutable cpu→node map (module docs). Cheap to clone.
///
/// Node ids are *dense* (`0..num_nodes`): sparse online-node sets and
/// memory-only (cpu-less, e.g. CXL) nodes are normalized away, because
/// the allocator deals shards and routes threads over nodes that can
/// actually run them. The kernel, however, speaks *physical* node ids —
/// [`Self::physical_node`] maps back for `mbind`/`move_pages`.
#[derive(Clone, Debug)]
pub struct Topology {
    /// Node of each cpu id (`UNKNOWN` for holes in sparse cpu sets).
    node_of_cpu: Vec<u32>,
    /// Rank of each cpu within its node's sorted cpu list.
    rank_in_node: Vec<u32>,
    /// Physical (kernel) node id per dense node (identity for injected
    /// and single-node topologies).
    phys: Vec<usize>,
    nnodes: usize,
    source: TopologySource,
}

impl Topology {
    /// Detect the machine topology from `/sys/devices/system/node`,
    /// falling back to a single node when the tree is absent (non-NUMA
    /// kernel) or unparsable.
    pub fn detect() -> Self {
        Self::detect_from("/sys/devices/system/node")
    }

    /// [`Self::detect`] with the sysfs root injectable (unit tests point
    /// this at a fake tree).
    pub fn detect_from(root: impl AsRef<Path>) -> Self {
        match Self::parse_sysfs(root.as_ref()) {
            Some(t) if t.num_cpus() > 0 => t,
            _ => Self::single_node(),
        }
    }

    fn parse_sysfs(root: &Path) -> Option<Self> {
        let entries = std::fs::read_dir(root).ok()?;
        let mut nodes: Vec<(usize, Vec<usize>)> = Vec::new();
        for entry in entries {
            let entry = entry.ok()?;
            let name = match entry.file_name().into_string() {
                Ok(n) => n,
                Err(_) => continue,
            };
            let id = match name.strip_prefix("node").and_then(|s| s.parse::<usize>().ok()) {
                Some(id) => id,
                None => continue, // `has_cpu`, `online`, `possible`, …
            };
            let list = std::fs::read_to_string(entry.path().join("cpulist")).ok()?;
            nodes.push((id, parse_cpulist(list.trim())?));
        }
        // Memory-only nodes (empty cpulist — CXL expanders, ballooned
        // nodes) are dropped: no thread is ever scheduled there, so
        // dealing them shards would create queues nobody drains and
        // deliberately bind chunks to far memory. (The interleave
        // follow-on is the right consumer for such nodes.)
        nodes.retain(|(_, l)| !l.is_empty());
        if nodes.is_empty() {
            return None;
        }
        // Renumber densely in sysfs-id order (sparse online node sets
        // exist on real machines; the allocator wants 0..nnodes), keeping
        // the physical id for the syscall layer.
        nodes.sort_unstable_by_key(|&(id, _)| id);
        let phys: Vec<usize> = nodes.iter().map(|&(id, _)| id).collect();
        let lists: Vec<Vec<usize>> = nodes.into_iter().map(|(_, l)| l).collect();
        Some(Self::build(&lists, phys, TopologySource::Detected))
    }

    /// One node owning every cpu the process can run on.
    pub fn single_node() -> Self {
        let ncpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::from_node_cpus(&[(0..ncpus).collect()], TopologySource::SingleNode)
    }

    /// Injectable fake: node `i` owns `cpus_per_node[i]` consecutive cpu
    /// ids (`fake(&[4, 4])` = 2 nodes × 4 cpus, cpus 0–3 on node 0).
    pub fn fake(cpus_per_node: &[usize]) -> Self {
        let mut lists = Vec::with_capacity(cpus_per_node.len());
        let mut next = 0usize;
        for &k in cpus_per_node {
            lists.push((next..next + k).collect());
            next += k;
        }
        Self::from_node_cpus(&lists, TopologySource::Injected)
    }

    /// Injectable fake with explicit per-node cpu lists (interleaved,
    /// sparse — whatever shape the test needs).
    pub fn inject(node_cpus: &[Vec<usize>]) -> Self {
        Self::from_node_cpus(node_cpus, TopologySource::Injected)
    }

    fn from_node_cpus(lists: &[Vec<usize>], source: TopologySource) -> Self {
        let phys = (0..lists.len().max(1)).collect();
        Self::build(lists, phys, source)
    }

    fn build(lists: &[Vec<usize>], mut phys: Vec<usize>, source: TopologySource) -> Self {
        let nnodes = lists.len().max(1);
        let table = lists.iter().flatten().max().map(|&m| m + 1).unwrap_or(0);
        let mut node_of_cpu = vec![UNKNOWN; table];
        let mut rank_in_node = vec![0u32; table];
        for (n, list) in lists.iter().enumerate() {
            let mut sorted = list.clone();
            sorted.sort_unstable();
            sorted.dedup();
            for (rank, &cpu) in sorted.iter().enumerate() {
                node_of_cpu[cpu] = n as u32;
                rank_in_node[cpu] = rank as u32;
            }
        }
        phys.resize(nnodes, 0);
        Self { node_of_cpu, rank_in_node, phys, nnodes, source }
    }

    pub fn source(&self) -> TopologySource {
        self.source
    }

    /// Only detected topologies may consult the kernel for page placement
    /// (injected ones describe a machine that does not exist here).
    pub fn is_detected(&self) -> bool {
        self.source == TopologySource::Detected
    }

    pub fn num_nodes(&self) -> usize {
        self.nnodes
    }

    /// Cpus the topology knows about (not necessarily contiguous ids).
    pub fn num_cpus(&self) -> usize {
        self.node_of_cpu.iter().filter(|&&n| n != UNKNOWN).count()
    }

    /// Node of a (virtual) cpu. Ids beyond the table — thread-id-hash
    /// vcpus, test pins past the fake cpu count — wrap deterministically
    /// so every vcpu always has a home node.
    pub fn node_of_cpu(&self, cpu: usize) -> usize {
        match self.node_of_cpu.get(cpu) {
            Some(&n) if n != UNKNOWN => n as usize,
            _ => cpu % self.nnodes,
        }
    }

    /// Rank of a cpu within its node (same wrap rule as
    /// [`Self::node_of_cpu`] for unknown ids).
    pub fn rank_in_node(&self, cpu: usize) -> usize {
        match self.node_of_cpu.get(cpu) {
            Some(&n) if n != UNKNOWN => self.rank_in_node[cpu] as usize,
            _ => cpu / self.nnodes,
        }
    }

    /// Physical (kernel) id of a dense node — what `mbind`/`move_pages`
    /// expect. Identity except on machines with sparse online-node sets
    /// or dropped memory-only nodes.
    pub fn physical_node(&self, node: usize) -> usize {
        self.phys.get(node).copied().unwrap_or(node)
    }

    /// Default allocator shard count for this topology: the pre-NUMA
    /// heuristic `min(num_cpus, 4)` rounded up to a multiple of the node
    /// count, so every node gets the same number of shards and the
    /// vcpu→shard map can keep threads on their own node's shards. On a
    /// single node this is exactly the old `min(num_cpus, 4)`.
    pub fn default_shards(&self) -> usize {
        let base = self.num_cpus().min(4).max(1);
        let n = self.nnodes.max(1);
        n * base.div_ceil(n)
    }
}

/// Parse a sysfs cpulist (`"0-3,8,10-11"`). Empty input (memory-only
/// nodes) is a valid empty list; malformed input is `None`.
pub fn parse_cpulist(s: &str) -> Option<Vec<usize>> {
    let mut cpus = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        match part.split_once('-') {
            Some((lo, hi)) => {
                let lo: usize = lo.trim().parse().ok()?;
                let hi: usize = hi.trim().parse().ok()?;
                if hi < lo {
                    return None;
                }
                cpus.extend(lo..=hi);
            }
            None => cpus.push(part.parse().ok()?),
        }
    }
    Some(cpus)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    #[test]
    fn cpulist_parsing() {
        assert_eq!(parse_cpulist(""), Some(vec![]));
        assert_eq!(parse_cpulist("0"), Some(vec![0]));
        assert_eq!(parse_cpulist("0-3"), Some(vec![0, 1, 2, 3]));
        assert_eq!(parse_cpulist("0-1,4,6-7"), Some(vec![0, 1, 4, 6, 7]));
        assert_eq!(parse_cpulist(" 2 , 5-6 "), Some(vec![2, 5, 6]));
        assert_eq!(parse_cpulist("x"), None);
        assert_eq!(parse_cpulist("3-1"), None);
        assert_eq!(parse_cpulist("1-"), None);
    }

    #[test]
    fn fake_two_node_eight_cpu() {
        let t = Topology::fake(&[4, 4]);
        assert_eq!(t.source(), TopologySource::Injected);
        assert!(!t.is_detected());
        assert_eq!(t.num_nodes(), 2);
        assert_eq!(t.num_cpus(), 8);
        for cpu in 0..4 {
            assert_eq!(t.node_of_cpu(cpu), 0);
            assert_eq!(t.rank_in_node(cpu), cpu);
        }
        for cpu in 4..8 {
            assert_eq!(t.node_of_cpu(cpu), 1);
            assert_eq!(t.rank_in_node(cpu), cpu - 4);
        }
        // vcpus beyond the table wrap deterministically
        assert_eq!(t.node_of_cpu(9), 1);
        assert_eq!(t.rank_in_node(9), 4);
    }

    #[test]
    fn injected_interleaved_cpus() {
        // even cpus on node 0, odd on node 1 (a real AMD layout)
        let t = Topology::inject(&[vec![0, 2, 4, 6], vec![1, 3, 5, 7]]);
        assert_eq!(t.num_nodes(), 2);
        assert_eq!(t.node_of_cpu(4), 0);
        assert_eq!(t.rank_in_node(4), 2);
        assert_eq!(t.node_of_cpu(3), 1);
        assert_eq!(t.rank_in_node(3), 1);
    }

    #[test]
    fn detect_falls_back_to_single_node() {
        let t = Topology::detect_from("/nonexistent/sysfs/node/tree");
        assert_eq!(t.source(), TopologySource::SingleNode);
        assert_eq!(t.num_nodes(), 1);
        assert!(t.num_cpus() >= 1);
        for cpu in 0..64 {
            assert_eq!(t.node_of_cpu(cpu), 0);
        }
        // detect() itself must never panic, whatever this host has
        assert!(Topology::detect().num_nodes() >= 1);
    }

    #[test]
    fn detect_parses_a_fake_sysfs_tree() {
        let d = TempDir::new("numa-sysfs");
        for (node, list) in [("node0", "0-2\n"), ("node2", "3,5\n")] {
            let p = d.join(node);
            std::fs::create_dir_all(&p).unwrap();
            std::fs::write(p.join("cpulist"), list).unwrap();
        }
        // decoy entries like the real tree has
        std::fs::write(d.join("possible"), "0,2\n").unwrap();
        let t = Topology::detect_from(d.path());
        assert_eq!(t.source(), TopologySource::Detected);
        assert!(t.is_detected());
        // node ids are renumbered densely: sysfs node2 becomes node 1…
        assert_eq!(t.num_nodes(), 2);
        assert_eq!(t.num_cpus(), 5);
        assert_eq!(t.node_of_cpu(1), 0);
        assert_eq!(t.node_of_cpu(3), 1);
        assert_eq!(t.node_of_cpu(5), 1);
        assert_eq!(t.rank_in_node(5), 1);
        // …but the syscall layer still sees the physical id 2
        assert_eq!(t.physical_node(0), 0);
        assert_eq!(t.physical_node(1), 2);
        // cpu 4 is a hole: wraps
        assert_eq!(t.node_of_cpu(4), 0);
    }

    #[test]
    fn detect_drops_memory_only_nodes() {
        let d = TempDir::new("numa-cxl");
        for (node, list) in [("node0", "0-3\n"), ("node1", "\n"), ("node3", "4-7\n")] {
            let p = d.join(node);
            std::fs::create_dir_all(&p).unwrap();
            std::fs::write(p.join("cpulist"), list).unwrap();
        }
        let t = Topology::detect_from(d.path());
        // the cpu-less node1 (a CXL-style memory expander) is not dealt
        // shards; the cpu nodes keep their physical ids for the kernel
        assert_eq!(t.num_nodes(), 2);
        assert_eq!(t.node_of_cpu(6), 1);
        assert_eq!(t.physical_node(1), 3);
        // injected topologies are identity-mapped
        assert_eq!(Topology::fake(&[2, 2]).physical_node(1), 1);
        // a tree with only memory nodes falls back to single-node
        let d2 = TempDir::new("numa-all-cxl");
        let p = d2.join("node0");
        std::fs::create_dir_all(&p).unwrap();
        std::fs::write(p.join("cpulist"), "\n").unwrap();
        assert_eq!(Topology::detect_from(d2.path()).source(), TopologySource::SingleNode);
    }

    #[test]
    fn detect_rejects_corrupt_tree() {
        let d = TempDir::new("numa-bad");
        let p = d.join("node0");
        std::fs::create_dir_all(&p).unwrap();
        std::fs::write(p.join("cpulist"), "not-a-list\n").unwrap();
        let t = Topology::detect_from(d.path());
        assert_eq!(t.source(), TopologySource::SingleNode);
    }

    #[test]
    fn default_shards_sizing() {
        // single node: the pre-NUMA heuristic min(cpus, 4)
        assert_eq!(Topology::fake(&[2]).default_shards(), 2);
        assert_eq!(Topology::fake(&[16]).default_shards(), 4);
        // 2 nodes × 4 cpus: min(8, 4) already a multiple of 2
        assert_eq!(Topology::fake(&[4, 4]).default_shards(), 4);
        // 2 nodes × 1 cpu: 2 shards, one per node
        assert_eq!(Topology::fake(&[1, 1]).default_shards(), 2);
        // 3 nodes: min(12, 4) = 4 rounds up to 6, a multiple of 3
        assert_eq!(Topology::fake(&[4, 4, 4]).default_shards(), 6);
        // a multiple of the node count in every case
        for shape in [&[1usize, 2][..], &[3, 3], &[2, 2, 2, 2]] {
            let t = Topology::fake(shape);
            assert_eq!(t.default_shards() % t.num_nodes(), 0, "{shape:?}");
        }
    }
}

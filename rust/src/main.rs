//! `metall` — CLI launcher for the metall-rs system.
//!
//! Subcommands (hand-rolled parser; the offline image carries no clap):
//!   create/inspect/snapshot datastores, run the ingestion pipeline, and
//!   run analytics through the PJRT engine. See `metall help`.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match metall_rs::coordinator::cli::run(&args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

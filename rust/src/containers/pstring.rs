//! `PString` — persistent byte string (labels, dataset metadata).

use crate::alloc::manager::Persist;
use crate::alloc::SegmentAlloc;
use crate::containers::oplog::{self, OpRecord};
use crate::error::Result;

#[derive(Clone, Copy, Debug)]
#[repr(C)]
struct StrHeader {
    data_off: u64,
    len: u64,
}

unsafe impl Persist for StrHeader {}

/// Handle to a persistent string (`Persist`, nestable).
#[derive(Clone, Copy, Debug)]
#[repr(transparent)]
pub struct PString {
    header_off: u64,
}

unsafe impl Persist for PString {}

impl PString {
    /// Allocate and store `s`.
    pub fn create<A: SegmentAlloc>(a: &A, s: &str) -> Result<Self> {
        let header_off = a.allocate(std::mem::size_of::<StrHeader>())?;
        let this = Self { header_off };
        let data_off = if s.is_empty() {
            u64::MAX
        } else {
            let off = a.allocate(s.len())?;
            a.write_bytes(off, s.as_bytes());
            off
        };
        a.write_pod(header_off, StrHeader { data_off, len: s.len() as u64 });
        Ok(this)
    }

    pub fn from_offset(header_off: u64) -> Self {
        Self { header_off }
    }

    pub fn offset(&self) -> u64 {
        self.header_off
    }

    pub fn len<A: SegmentAlloc>(&self, a: &A) -> usize {
        a.read_pod::<StrHeader>(self.header_off).len as usize
    }

    pub fn is_empty<A: SegmentAlloc>(&self, a: &A) -> bool {
        self.len(a) == 0
    }

    /// Copy the contents out as a `String` (lossy on invalid UTF-8,
    /// which only happens on corruption).
    pub fn to_string<A: SegmentAlloc>(&self, a: &A) -> String {
        let h: StrHeader = a.read_pod(self.header_off);
        if h.len == 0 {
            return String::new();
        }
        let bytes = unsafe { a.bytes_at(h.data_off, h.len as usize) };
        String::from_utf8_lossy(bytes).into_owned()
    }

    /// Replace the contents. Crash-safe order: fill the new extent, log
    /// the intent, publish the header, seal the commit — and only then
    /// retire the old bytes (the old code freed them first, leaving a
    /// dangling `data_off` for a kill in between).
    pub fn set<A: SegmentAlloc>(&self, a: &A, s: &str) -> Result<()> {
        let h: StrHeader = a.read_pod(self.header_off);
        let data_off = if s.is_empty() {
            u64::MAX
        } else {
            let off = a.allocate(s.len())?;
            a.write_bytes(off, s.as_bytes());
            off
        };
        let nh = StrHeader { data_off, len: s.len() as u64 };
        let mut rec = OpRecord::new(oplog::OP_STR_SET);
        rec.h1_off = self.header_off;
        rec.h1_old = oplog::image_of(&h);
        rec.h1_new = oplog::image_of(&nh);
        if data_off != u64::MAX {
            rec.alloc_off = data_off;
            rec.alloc_size = s.len() as u64;
        }
        if h.data_off != u64::MAX {
            rec.free_off = h.data_off;
        }
        rec.unit = 1;
        let tok = a.oplog_begin(rec)?;
        a.write_pod(self.header_off, nh);
        a.oplog_commit(tok)?;
        if h.data_off != u64::MAX {
            a.deallocate(h.data_off)?;
        }
        Ok(())
    }

    pub fn destroy<A: SegmentAlloc>(self, a: &A) -> Result<()> {
        let h: StrHeader = a.read_pod(self.header_off);
        if h.data_off != u64::MAX {
            a.deallocate(h.data_off)?;
        }
        a.deallocate(self.header_off)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::{ManagerOptions, MetallManager};
    use crate::util::tmp::TempDir;

    #[test]
    fn create_read_set_reattach() {
        let d = TempDir::new("pstr");
        let store = d.join("s");
        {
            let m = MetallManager::create_with(&store, ManagerOptions::small_for_tests())
                .unwrap();
            let s = PString::create(&m, "wikipedia-2017-07").unwrap();
            assert_eq!(s.to_string(&m), "wikipedia-2017-07");
            s.set(&m, "reddit").unwrap();
            m.construct::<u64>("label", s.offset()).unwrap();
            m.close().unwrap();
        }
        let m = MetallManager::open(&store).unwrap();
        let off = m.find::<u64>("label").unwrap().unwrap();
        let s = PString::from_offset(m.read::<u64>(off));
        assert_eq!(s.to_string(&m), "reddit");
        m.close().unwrap();
    }

    #[test]
    fn empty_string() {
        let d = TempDir::new("pstr2");
        let m = MetallManager::create_with(d.join("s"), ManagerOptions::small_for_tests())
            .unwrap();
        let s = PString::create(&m, "").unwrap();
        assert!(s.is_empty(&m));
        assert_eq!(s.to_string(&m), "");
        s.set(&m, "x").unwrap();
        assert_eq!(s.to_string(&m), "x");
        s.destroy(&m).unwrap();
    }
}

//! `PVec<T>` — persistent growable array (the `boost::container::vector`
//! equivalent of the paper's examples, Code 3).
//!
//! Layout: a 24-byte header `[data_off | len | cap]` lives at the
//! handle's offset (the header itself is usually nested inside another
//! persistent structure); elements live in a separate allocation. All
//! links are offsets; growth allocates a new extent, copies, frees the
//! old one.

use std::marker::PhantomData;

use crate::alloc::manager::Persist;
use crate::alloc::SegmentAlloc;
use crate::error::Result;

/// Persistent header (what actually lives in the segment).
#[derive(Clone, Copy, Debug)]
#[repr(C)]
pub struct PVecHeader {
    data_off: u64,
    len: u64,
    cap: u64,
}

unsafe impl Persist for PVecHeader {}

const NO_DATA: u64 = u64::MAX;

/// Handle to a persistent vector of `T` (a typed offset — itself
/// `Persist`, so it can nest inside other persistent structures).
#[derive(Debug)]
#[repr(transparent)]
pub struct PVec<T: Persist> {
    header_off: u64,
    _t: PhantomData<T>,
}

// Manual impls: `derive` would bound on `T: Clone/Copy` needlessly.
impl<T: Persist> Clone for PVec<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T: Persist> Copy for PVec<T> {}
unsafe impl<T: Persist> Persist for PVec<T> {}

impl<T: Persist> PVec<T> {
    const ELEM: usize = std::mem::size_of::<T>();

    /// Allocate an empty vector (header only), returning its handle.
    pub fn create<A: SegmentAlloc>(a: &A) -> Result<Self> {
        let header_off = a.allocate(std::mem::size_of::<PVecHeader>())?;
        let v = Self { header_off, _t: PhantomData };
        v.write_header(a, PVecHeader { data_off: NO_DATA, len: 0, cap: 0 });
        Ok(v)
    }

    /// Re-interpret an existing header offset as a handle (reattach).
    pub fn from_offset(header_off: u64) -> Self {
        Self { header_off, _t: PhantomData }
    }

    pub fn offset(&self) -> u64 {
        self.header_off
    }

    #[inline]
    fn header<A: SegmentAlloc>(&self, a: &A) -> PVecHeader {
        a.read_pod(self.header_off)
    }

    #[inline]
    fn write_header<A: SegmentAlloc>(&self, a: &A, h: PVecHeader) {
        a.write_pod(self.header_off, h);
    }

    pub fn len<A: SegmentAlloc>(&self, a: &A) -> usize {
        self.header(a).len as usize
    }

    pub fn is_empty<A: SegmentAlloc>(&self, a: &A) -> bool {
        self.len(a) == 0
    }

    pub fn capacity<A: SegmentAlloc>(&self, a: &A) -> usize {
        self.header(a).cap as usize
    }

    fn elem_off(h: &PVecHeader, i: usize) -> u64 {
        h.data_off + (i * Self::ELEM) as u64
    }

    pub fn get<A: SegmentAlloc>(&self, a: &A, i: usize) -> T {
        let h = self.header(a);
        assert!((i as u64) < h.len, "index {i} out of bounds (len {})", h.len);
        a.read_pod(Self::elem_off(&h, i))
    }

    pub fn set<A: SegmentAlloc>(&self, a: &A, i: usize, v: T) {
        let h = self.header(a);
        assert!((i as u64) < h.len, "index {i} out of bounds (len {})", h.len);
        a.write_pod(Self::elem_off(&h, i), v);
    }

    /// Grow capacity to at least `need` elements.
    fn grow<A: SegmentAlloc>(&self, a: &A, need: usize) -> Result<PVecHeader> {
        let mut h = self.header(a);
        if (need as u64) <= h.cap {
            return Ok(h);
        }
        let new_cap = need.max((h.cap as usize) * 2).max(4);
        let new_off = a.allocate(new_cap * Self::ELEM)?;
        if h.data_off != NO_DATA {
            a.copy_within(h.data_off, new_off, h.len as usize * Self::ELEM);
            a.deallocate(h.data_off)?;
        }
        h.data_off = new_off;
        h.cap = new_cap as u64;
        self.write_header(a, h);
        Ok(h)
    }

    pub fn push<A: SegmentAlloc>(&self, a: &A, v: T) -> Result<()> {
        let mut h = self.grow(a, self.len(a) + 1)?;
        a.write_pod(Self::elem_off(&h, h.len as usize), v);
        h.len += 1;
        self.write_header(a, h);
        Ok(())
    }

    /// Bulk append (single growth + memcpy — the ingestion hot path).
    pub fn extend_from_slice<A: SegmentAlloc>(&self, a: &A, vs: &[T]) -> Result<()> {
        if vs.is_empty() {
            return Ok(());
        }
        let mut h = self.grow(a, self.len(a) + vs.len())?;
        let bytes = unsafe {
            std::slice::from_raw_parts(vs.as_ptr() as *const u8, vs.len() * Self::ELEM)
        };
        a.write_bytes(Self::elem_off(&h, h.len as usize), bytes);
        h.len += vs.len() as u64;
        self.write_header(a, h);
        Ok(())
    }

    pub fn pop<A: SegmentAlloc>(&self, a: &A) -> Option<T> {
        let mut h = self.header(a);
        if h.len == 0 {
            return None;
        }
        h.len -= 1;
        let v = a.read_pod(Self::elem_off(&h, h.len as usize));
        self.write_header(a, h);
        Some(v)
    }

    /// Copy out as a std Vec (analytics export path).
    pub fn to_vec<A: SegmentAlloc>(&self, a: &A) -> Vec<T> {
        let h = self.header(a);
        let mut out = Vec::with_capacity(h.len as usize);
        for i in 0..h.len as usize {
            out.push(a.read_pod(Self::elem_off(&h, i)));
        }
        out
    }

    /// Iterate without materializing.
    pub fn for_each<A: SegmentAlloc>(&self, a: &A, mut f: impl FnMut(T)) {
        let h = self.header(a);
        for i in 0..h.len as usize {
            f(a.read_pod(Self::elem_off(&h, i)));
        }
    }

    /// Free the element storage and the header itself.
    pub fn destroy<A: SegmentAlloc>(self, a: &A) -> Result<()> {
        let h = self.header(a);
        if h.data_off != NO_DATA {
            a.deallocate(h.data_off)?;
        }
        a.deallocate(self.header_off)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::{ManagerOptions, MetallManager};
    use crate::util::tmp::TempDir;

    fn mgr(d: &TempDir) -> MetallManager {
        MetallManager::create_with(d.join("s"), ManagerOptions::small_for_tests()).unwrap()
    }

    #[test]
    fn push_get_pop() {
        let d = TempDir::new("pvec1");
        let m = mgr(&d);
        let v = PVec::<u64>::create(&m).unwrap();
        assert!(v.is_empty(&m));
        for i in 0..100u64 {
            v.push(&m, i * 3).unwrap();
        }
        assert_eq!(v.len(&m), 100);
        assert_eq!(v.get(&m, 0), 0);
        assert_eq!(v.get(&m, 99), 297);
        v.set(&m, 50, 7777);
        assert_eq!(v.get(&m, 50), 7777);
        assert_eq!(v.pop(&m), Some(297));
        assert_eq!(v.len(&m), 99);
    }

    #[test]
    fn persists_across_reattach() {
        let d = TempDir::new("pvec2");
        let store = d.join("s");
        let head;
        {
            let m = MetallManager::create_with(&store, ManagerOptions::small_for_tests())
                .unwrap();
            let v = PVec::<f64>::create(&m).unwrap();
            for i in 0..1000 {
                v.push(&m, i as f64 / 7.0).unwrap();
            }
            head = v.offset();
            m.construct::<u64>("vec_head", head).unwrap();
            m.close().unwrap();
        }
        {
            let m = MetallManager::open(&store).unwrap();
            let off = m.find::<u64>("vec_head").unwrap().unwrap();
            let v = PVec::<f64>::from_offset(m.read::<u64>(off));
            assert_eq!(v.len(&m), 1000);
            assert_eq!(v.get(&m, 700), 100.0);
            m.close().unwrap();
        }
    }

    #[test]
    fn extend_matches_push() {
        let d = TempDir::new("pvec3");
        let m = mgr(&d);
        let a = PVec::<u32>::create(&m).unwrap();
        let b = PVec::<u32>::create(&m).unwrap();
        let data: Vec<u32> = (0..500).map(|i| i * 17).collect();
        for &x in &data {
            a.push(&m, x).unwrap();
        }
        b.extend_from_slice(&m, &data).unwrap();
        assert_eq!(a.to_vec(&m), b.to_vec(&m));
    }

    #[test]
    fn destroy_releases_memory() {
        let d = TempDir::new("pvec4");
        let m = mgr(&d);
        let v = PVec::<u64>::create(&m).unwrap();
        for i in 0..10_000u64 {
            v.push(&m, i).unwrap();
        }
        let before = m.stats();
        v.destroy(&m).unwrap();
        let after = m.stats();
        assert_eq!(after.deallocs - before.deallocs, 2); // data + header
    }

    #[test]
    fn nested_vec_of_vec_handles() {
        // PVec<PVec<u64>> — handles are Persist, the adjacency-list shape
        let d = TempDir::new("pvec5");
        let m = mgr(&d);
        let outer = PVec::<PVec<u64>>::create(&m).unwrap();
        for i in 0..10u64 {
            let inner = PVec::<u64>::create(&m).unwrap();
            for j in 0..i {
                inner.push(&m, j).unwrap();
            }
            outer.push(&m, inner).unwrap();
        }
        let seventh = outer.get(&m, 7);
        assert_eq!(seventh.len(&m), 7);
        assert_eq!(seventh.to_vec(&m), (0..7u64).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn oob_get_panics() {
        let d = TempDir::new("pvec6");
        let m = mgr(&d);
        let v = PVec::<u64>::create(&m).unwrap();
        v.push(&m, 1).unwrap();
        v.get(&m, 1);
    }
}

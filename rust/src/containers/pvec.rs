//! `PVec<T>` — persistent growable array (the `boost::container::vector`
//! equivalent of the paper's examples, Code 3).
//!
//! Layout: a 24-byte header `[data_off | len | cap]` lives at the
//! handle's offset (the header itself is usually nested inside another
//! persistent structure); elements live in a separate allocation. All
//! links are offsets; growth allocates a new extent, copies, frees the
//! old one.

use std::marker::PhantomData;

use crate::alloc::manager::Persist;
use crate::alloc::SegmentAlloc;
use crate::containers::oplog::{self, OpRecord};
use crate::error::{Error, Result};
use crate::util::test_kill_point;

/// Persistent header (what actually lives in the segment).
#[derive(Clone, Copy, Debug)]
#[repr(C)]
pub struct PVecHeader {
    data_off: u64,
    len: u64,
    cap: u64,
}

unsafe impl Persist for PVecHeader {}

const NO_DATA: u64 = u64::MAX;

/// Handle to a persistent vector of `T` (a typed offset — itself
/// `Persist`, so it can nest inside other persistent structures).
#[derive(Debug)]
#[repr(transparent)]
pub struct PVec<T: Persist> {
    header_off: u64,
    _t: PhantomData<T>,
}

// Manual impls: `derive` would bound on `T: Clone/Copy` needlessly.
impl<T: Persist> Clone for PVec<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T: Persist> Copy for PVec<T> {}
unsafe impl<T: Persist> Persist for PVec<T> {}

impl<T: Persist> PVec<T> {
    const ELEM: usize = std::mem::size_of::<T>();

    /// Allocate an empty vector (header only), returning its handle.
    pub fn create<A: SegmentAlloc>(a: &A) -> Result<Self> {
        let header_off = a.allocate(std::mem::size_of::<PVecHeader>())?;
        let v = Self { header_off, _t: PhantomData };
        let init = PVecHeader { data_off: NO_DATA, len: 0, cap: 0 };
        let mut rec = OpRecord::new(oplog::OP_VEC_CREATE);
        rec.h1_off = header_off;
        rec.h1_old = oplog::image_of(&init);
        rec.h1_new = rec.h1_old;
        rec.alloc_off = header_off;
        rec.alloc_size = std::mem::size_of::<PVecHeader>() as u64;
        rec.unit = Self::ELEM as u32;
        let tok = a.oplog_begin(rec)?;
        v.write_header(a, init);
        a.oplog_commit(tok)?;
        Ok(v)
    }

    /// Re-interpret an existing header offset as a handle (reattach).
    pub fn from_offset(header_off: u64) -> Self {
        Self { header_off, _t: PhantomData }
    }

    pub fn offset(&self) -> u64 {
        self.header_off
    }

    #[inline]
    fn header<A: SegmentAlloc>(&self, a: &A) -> PVecHeader {
        a.read_pod(self.header_off)
    }

    #[inline]
    fn write_header<A: SegmentAlloc>(&self, a: &A, h: PVecHeader) {
        a.write_pod(self.header_off, h);
    }

    pub fn len<A: SegmentAlloc>(&self, a: &A) -> usize {
        self.header(a).len as usize
    }

    pub fn is_empty<A: SegmentAlloc>(&self, a: &A) -> bool {
        self.len(a) == 0
    }

    pub fn capacity<A: SegmentAlloc>(&self, a: &A) -> usize {
        self.header(a).cap as usize
    }

    fn elem_off(h: &PVecHeader, i: usize) -> u64 {
        h.data_off + (i * Self::ELEM) as u64
    }

    pub fn get<A: SegmentAlloc>(&self, a: &A, i: usize) -> T {
        let h = self.header(a);
        debug_assert!((i as u64) < h.len, "index {i} out of bounds (len {})", h.len);
        a.read_pod(Self::elem_off(&h, i))
    }

    /// Fallible [`Self::get`]: `Err(InvalidOp)` instead of a debug
    /// assertion when `i` is out of bounds.
    pub fn try_get<A: SegmentAlloc>(&self, a: &A, i: usize) -> Result<T> {
        let h = self.header(a);
        if (i as u64) >= h.len {
            return Err(Error::InvalidOp(format!(
                "pvec index {i} out of bounds (len {})",
                h.len
            )));
        }
        Ok(a.read_pod(Self::elem_off(&h, i)))
    }

    /// In-place element overwrite. NOT crash-atomic: the element bytes
    /// are mutated directly with no logged intent (a kill mid-write can
    /// tear the element, though never the container structure).
    pub fn set<A: SegmentAlloc>(&self, a: &A, i: usize, v: T) {
        let h = self.header(a);
        debug_assert!((i as u64) < h.len, "index {i} out of bounds (len {})", h.len);
        a.write_pod(Self::elem_off(&h, i), v);
    }

    /// Fallible [`Self::set`]: `Err(InvalidOp)` instead of a debug
    /// assertion when `i` is out of bounds.
    pub fn try_set<A: SegmentAlloc>(&self, a: &A, i: usize, v: T) -> Result<()> {
        let h = self.header(a);
        if (i as u64) >= h.len {
            return Err(Error::InvalidOp(format!(
                "pvec index {i} out of bounds (len {})",
                h.len
            )));
        }
        a.write_pod(Self::elem_off(&h, i), v);
        Ok(())
    }

    /// Grow capacity to at least `need` elements. Crash-safe order: fill
    /// the new extent, log the intent, publish the header, seal the
    /// commit — and only then retire the old extent. (The old code freed
    /// the extent *before* publishing the header that stops pointing at
    /// it, leaving a dangling `data_off` for a kill in between.)
    fn grow<A: SegmentAlloc>(&self, a: &A, need: usize) -> Result<PVecHeader> {
        let h = self.header(a);
        if (need as u64) <= h.cap {
            return Ok(h);
        }
        let new_cap = need.max((h.cap as usize) * 2).max(4);
        let new_off = a.allocate(new_cap * Self::ELEM)?;
        let mut nh = h;
        nh.data_off = new_off;
        nh.cap = new_cap as u64;
        let mut rec = OpRecord::new(oplog::OP_VEC_GROW);
        rec.h1_off = self.header_off;
        rec.h1_old = oplog::image_of(&h);
        rec.h1_new = oplog::image_of(&nh);
        rec.alloc_off = new_off;
        rec.alloc_size = (new_cap * Self::ELEM) as u64;
        if h.data_off != NO_DATA {
            rec.free_off = h.data_off;
        }
        rec.unit = Self::ELEM as u32;
        let tok = a.oplog_begin(rec)?;
        if h.data_off != NO_DATA {
            a.copy_within(h.data_off, new_off, h.len as usize * Self::ELEM);
        }
        self.write_header(a, nh);
        test_kill_point("pvec_grow_retire");
        a.oplog_commit(tok)?;
        if h.data_off != NO_DATA {
            a.deallocate(h.data_off)?;
        }
        Ok(nh)
    }

    /// Reserve capacity for at least `need` elements (public so callers
    /// composing multi-container ops can pre-grow before logging them).
    pub fn reserve<A: SegmentAlloc>(&self, a: &A, need: usize) -> Result<()> {
        self.grow(a, need)?;
        Ok(())
    }

    pub fn push<A: SegmentAlloc>(&self, a: &A, v: T) -> Result<()> {
        let mut h = self.grow(a, self.len(a) + 1)?;
        let at = h.len as usize;
        let mut rec = OpRecord::new(oplog::OP_VEC_PUSH);
        rec.h1_off = self.header_off;
        rec.h1_old = oplog::image_of(&h);
        h.len += 1;
        rec.h1_new = oplog::image_of(&h);
        rec.unit = Self::ELEM as u32;
        let tok = a.oplog_begin(rec)?;
        a.write_pod(Self::elem_off(&h, at), v);
        self.write_header(a, h);
        a.oplog_commit(tok)
    }

    /// Bulk append (single growth + memcpy — the ingestion hot path).
    pub fn extend_from_slice<A: SegmentAlloc>(&self, a: &A, vs: &[T]) -> Result<()> {
        if vs.is_empty() {
            return Ok(());
        }
        let mut h = self.grow(a, self.len(a) + vs.len())?;
        let at = h.len as usize;
        let mut rec = OpRecord::new(oplog::OP_VEC_EXTEND);
        rec.h1_off = self.header_off;
        rec.h1_old = oplog::image_of(&h);
        h.len += vs.len() as u64;
        rec.h1_new = oplog::image_of(&h);
        rec.aux = vs.len() as u64;
        rec.unit = Self::ELEM as u32;
        let tok = a.oplog_begin(rec)?;
        let bytes = unsafe {
            std::slice::from_raw_parts(vs.as_ptr() as *const u8, vs.len() * Self::ELEM)
        };
        a.write_bytes(Self::elem_off(&h, at), bytes);
        self.write_header(a, h);
        a.oplog_commit(tok)
    }

    /// Adjacency edge append: one [`oplog::OP_EDGE`] record covers both
    /// this vec's header and the caller's rider cell (the 16-byte
    /// `BankEntry` holding the bank's edge counter), so a kill between
    /// the two publishes rolls them back *together* — no half-linked
    /// row where the list grew but the counter didn't.
    pub(crate) fn push_edge<A: SegmentAlloc>(
        &self,
        a: &A,
        v: T,
        rider_off: u64,
        rider_old: [u8; oplog::IMAGE_SIZE],
        rider_new: [u8; oplog::IMAGE_SIZE],
        rider_len: u32,
    ) -> Result<()> {
        let mut h = self.grow(a, self.len(a) + 1)?;
        let at = h.len as usize;
        let mut rec = OpRecord::new(oplog::OP_EDGE);
        rec.h1_off = self.header_off;
        rec.h1_old = oplog::image_of(&h);
        h.len += 1;
        rec.h1_new = oplog::image_of(&h);
        rec.h2_off = rider_off;
        rec.h2_old = rider_old;
        rec.h2_new = rider_new;
        rec.h2_len = rider_len;
        rec.unit = Self::ELEM as u32;
        let tok = a.oplog_begin(rec)?;
        a.write_pod(Self::elem_off(&h, at), v);
        self.write_header(a, h);
        a.write_bytes(rider_off, &rider_new[..rider_len as usize]);
        a.oplog_commit(tok)
    }

    pub fn pop<A: SegmentAlloc>(&self, a: &A) -> Result<Option<T>> {
        let mut h = self.header(a);
        if h.len == 0 {
            return Ok(None);
        }
        let mut rec = OpRecord::new(oplog::OP_VEC_POP);
        rec.h1_off = self.header_off;
        rec.h1_old = oplog::image_of(&h);
        h.len -= 1;
        rec.h1_new = oplog::image_of(&h);
        rec.unit = Self::ELEM as u32;
        let tok = a.oplog_begin(rec)?;
        let v = a.read_pod(Self::elem_off(&h, h.len as usize));
        self.write_header(a, h);
        a.oplog_commit(tok)?;
        Ok(Some(v))
    }

    /// Copy out as a std Vec (analytics export path).
    pub fn to_vec<A: SegmentAlloc>(&self, a: &A) -> Vec<T> {
        let h = self.header(a);
        let mut out = Vec::with_capacity(h.len as usize);
        for i in 0..h.len as usize {
            out.push(a.read_pod(Self::elem_off(&h, i)));
        }
        out
    }

    /// Iterate without materializing.
    pub fn for_each<A: SegmentAlloc>(&self, a: &A, mut f: impl FnMut(T)) {
        let h = self.header(a);
        for i in 0..h.len as usize {
            f(a.read_pod(Self::elem_off(&h, i)));
        }
    }

    /// Free the element storage and the header itself.
    pub fn destroy<A: SegmentAlloc>(self, a: &A) -> Result<()> {
        let h = self.header(a);
        if h.data_off != NO_DATA {
            a.deallocate(h.data_off)?;
        }
        a.deallocate(self.header_off)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::{ManagerOptions, MetallManager};
    use crate::util::tmp::TempDir;

    fn mgr(d: &TempDir) -> MetallManager {
        MetallManager::create_with(d.join("s"), ManagerOptions::small_for_tests()).unwrap()
    }

    #[test]
    fn push_get_pop() {
        let d = TempDir::new("pvec1");
        let m = mgr(&d);
        let v = PVec::<u64>::create(&m).unwrap();
        assert!(v.is_empty(&m));
        for i in 0..100u64 {
            v.push(&m, i * 3).unwrap();
        }
        assert_eq!(v.len(&m), 100);
        assert_eq!(v.get(&m, 0), 0);
        assert_eq!(v.get(&m, 99), 297);
        v.set(&m, 50, 7777);
        assert_eq!(v.get(&m, 50), 7777);
        assert_eq!(v.pop(&m).unwrap(), Some(297));
        assert_eq!(v.len(&m), 99);
    }

    #[test]
    fn persists_across_reattach() {
        let d = TempDir::new("pvec2");
        let store = d.join("s");
        let head;
        {
            let m = MetallManager::create_with(&store, ManagerOptions::small_for_tests())
                .unwrap();
            let v = PVec::<f64>::create(&m).unwrap();
            for i in 0..1000 {
                v.push(&m, i as f64 / 7.0).unwrap();
            }
            head = v.offset();
            m.construct::<u64>("vec_head", head).unwrap();
            m.close().unwrap();
        }
        {
            let m = MetallManager::open(&store).unwrap();
            let off = m.find::<u64>("vec_head").unwrap().unwrap();
            let v = PVec::<f64>::from_offset(m.read::<u64>(off));
            assert_eq!(v.len(&m), 1000);
            assert_eq!(v.get(&m, 700), 100.0);
            m.close().unwrap();
        }
    }

    #[test]
    fn extend_matches_push() {
        let d = TempDir::new("pvec3");
        let m = mgr(&d);
        let a = PVec::<u32>::create(&m).unwrap();
        let b = PVec::<u32>::create(&m).unwrap();
        let data: Vec<u32> = (0..500).map(|i| i * 17).collect();
        for &x in &data {
            a.push(&m, x).unwrap();
        }
        b.extend_from_slice(&m, &data).unwrap();
        assert_eq!(a.to_vec(&m), b.to_vec(&m));
    }

    #[test]
    fn destroy_releases_memory() {
        let d = TempDir::new("pvec4");
        let m = mgr(&d);
        let v = PVec::<u64>::create(&m).unwrap();
        for i in 0..10_000u64 {
            v.push(&m, i).unwrap();
        }
        let before = m.stats();
        v.destroy(&m).unwrap();
        let after = m.stats();
        assert_eq!(after.deallocs - before.deallocs, 2); // data + header
    }

    #[test]
    fn nested_vec_of_vec_handles() {
        // PVec<PVec<u64>> — handles are Persist, the adjacency-list shape
        let d = TempDir::new("pvec5");
        let m = mgr(&d);
        let outer = PVec::<PVec<u64>>::create(&m).unwrap();
        for i in 0..10u64 {
            let inner = PVec::<u64>::create(&m).unwrap();
            for j in 0..i {
                inner.push(&m, j).unwrap();
            }
            outer.push(&m, inner).unwrap();
        }
        let seventh = outer.get(&m, 7);
        assert_eq!(seventh.len(&m), 7);
        assert_eq!(seventh.to_vec(&m), (0..7u64).collect::<Vec<_>>());
    }

    #[test]
    fn oob_access_is_fallible() {
        let d = TempDir::new("pvec6");
        let m = mgr(&d);
        let v = PVec::<u64>::create(&m).unwrap();
        v.push(&m, 1).unwrap();
        assert_eq!(v.try_get(&m, 0).unwrap(), 1);
        assert!(v.try_get(&m, 1).is_err());
        assert!(v.try_set(&m, 1, 9).is_err());
        v.try_set(&m, 0, 9).unwrap();
        assert_eq!(v.try_get(&m, 0).unwrap(), 9);
        // empty vec: every index is out of bounds
        assert!(v.pop(&m).unwrap().is_some());
        assert!(v.try_get(&m, 0).is_err());
    }

    #[test]
    fn pop_drains_to_none() {
        let d = TempDir::new("pvec7");
        let m = mgr(&d);
        let v = PVec::<u64>::create(&m).unwrap();
        v.push(&m, 5).unwrap();
        v.push(&m, 6).unwrap();
        assert_eq!(v.pop(&m).unwrap(), Some(6));
        assert_eq!(v.pop(&m).unwrap(), Some(5));
        assert_eq!(v.pop(&m).unwrap(), None);
    }
}

//! `PHashMapU64<V>` — persistent open-addressing hash map with `u64`
//! keys (the `unordered_map` of the paper's vertex table, §6.1).
//!
//! Linear probing, power-of-two capacity, grow at 70% load. The reserved
//! key `u64::MAX` ([`EMPTY_KEY`]) marks empty slots — inserting it would
//! be indistinguishable from an empty slot and silently corrupt probe
//! chains, so [`PHashMapU64::insert`] rejects it with
//! `Error::InvalidOp` (vertex IDs are 64-bit but real generators never
//! produce `u64::MAX`). No deletion — the graph workloads only insert —
//! keeping the probe sequences tombstone-free.

use std::marker::PhantomData;

use crate::alloc::manager::Persist;
use crate::alloc::SegmentAlloc;
use crate::containers::oplog::{self, OpRecord};
use crate::error::{Error, Result};
use crate::util::rng::mix64;
use crate::util::test_kill_point;

/// Reserved empty-slot marker.
pub const EMPTY_KEY: u64 = u64::MAX;

#[derive(Clone, Copy, Debug)]
#[repr(C)]
struct MapHeader {
    table_off: u64,
    cap: u64, // power of two, 0 = unallocated
    len: u64,
}

unsafe impl Persist for MapHeader {}

/// Handle to a persistent `u64 → V` hash map (`Persist`, nestable).
#[derive(Debug)]
#[repr(transparent)]
pub struct PHashMapU64<V: Persist> {
    header_off: u64,
    _v: PhantomData<V>,
}

impl<V: Persist> Clone for PHashMapU64<V> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<V: Persist> Copy for PHashMapU64<V> {}
unsafe impl<V: Persist> Persist for PHashMapU64<V> {}

impl<V: Persist> PHashMapU64<V> {
    /// Slot stride: key + value, 8-byte aligned.
    const STRIDE: usize = 8 + (std::mem::size_of::<V>() + 7) / 8 * 8;

    pub fn create<A: SegmentAlloc>(a: &A) -> Result<Self> {
        let header_off = a.allocate(std::mem::size_of::<MapHeader>())?;
        let m = Self { header_off, _v: PhantomData };
        let init = MapHeader { table_off: 0, cap: 0, len: 0 };
        let mut rec = OpRecord::new(oplog::OP_MAP_CREATE);
        rec.h1_off = header_off;
        rec.h1_old = oplog::image_of(&init);
        rec.h1_new = rec.h1_old;
        rec.alloc_off = header_off;
        rec.alloc_size = std::mem::size_of::<MapHeader>() as u64;
        rec.unit = Self::STRIDE as u32;
        let tok = a.oplog_begin(rec)?;
        m.write_header(a, init);
        a.oplog_commit(tok)?;
        Ok(m)
    }

    pub fn from_offset(header_off: u64) -> Self {
        Self { header_off, _v: PhantomData }
    }

    pub fn offset(&self) -> u64 {
        self.header_off
    }

    fn header<A: SegmentAlloc>(&self, a: &A) -> MapHeader {
        a.read_pod(self.header_off)
    }

    fn write_header<A: SegmentAlloc>(&self, a: &A, h: MapHeader) {
        a.write_pod(self.header_off, h);
    }

    pub fn len<A: SegmentAlloc>(&self, a: &A) -> usize {
        self.header(a).len as usize
    }

    pub fn is_empty<A: SegmentAlloc>(&self, a: &A) -> bool {
        self.len(a) == 0
    }

    pub fn capacity<A: SegmentAlloc>(&self, a: &A) -> usize {
        self.header(a).cap as usize
    }

    #[inline]
    fn slot_off(h: &MapHeader, slot: u64) -> u64 {
        h.table_off + slot * Self::STRIDE as u64
    }

    fn init_table<A: SegmentAlloc>(a: &A, cap: u64) -> Result<u64> {
        let table_off = a.allocate(cap as usize * Self::STRIDE)?;
        for s in 0..cap {
            a.write_pod(table_off + s * Self::STRIDE as u64, EMPTY_KEY);
        }
        Ok(table_off)
    }

    /// Double the table (rehash). Crash-safe order: build the new table
    /// in an unpublished extent, log the intent, publish the header,
    /// seal the commit — and only then retire the old table. (The old
    /// code deallocated the table *before* publishing the header that
    /// stops pointing at it, leaving a dangling `table_off` for a kill
    /// in between.)
    fn grow<A: SegmentAlloc>(&self, a: &A) -> Result<MapHeader> {
        let h = self.header(a);
        let new_cap = (h.cap * 2).max(8);
        let new_off = Self::init_table(a, new_cap)?;
        let mut nh = MapHeader { table_off: new_off, cap: new_cap, len: h.len };
        let mut rec = OpRecord::new(oplog::OP_MAP_GROW);
        rec.h1_off = self.header_off;
        rec.h1_old = oplog::image_of(&h);
        rec.h1_new = oplog::image_of(&nh);
        rec.alloc_off = new_off;
        rec.alloc_size = new_cap * Self::STRIDE as u64;
        if h.cap > 0 {
            rec.free_off = h.table_off;
        }
        rec.unit = Self::STRIDE as u32;
        let tok = a.oplog_begin(rec)?;
        // rehash into the (still unpublished) new table
        if h.cap > 0 {
            for s in 0..h.cap {
                let off = Self::slot_off(&h, s);
                let k: u64 = a.read_pod(off);
                if k != EMPTY_KEY {
                    let v: V = a.read_pod(off + 8);
                    Self::raw_insert(a, &mut nh, k, v);
                }
            }
        }
        self.write_header(a, nh);
        test_kill_point("pmap_grow_retire");
        a.oplog_commit(tok)?;
        if h.cap > 0 {
            a.deallocate(h.table_off)?;
        }
        Ok(nh)
    }

    /// Insert into a table known to have room; does not bump `len`.
    fn raw_insert<A: SegmentAlloc>(a: &A, h: &mut MapHeader, key: u64, value: V) {
        let mask = h.cap - 1;
        let mut s = mix64(key) & mask;
        loop {
            let off = Self::slot_off(h, s);
            let k: u64 = a.read_pod(off);
            if k == EMPTY_KEY {
                a.write_pod(off, key);
                a.write_pod(off + 8, value);
                return;
            }
            debug_assert_ne!(k, key, "raw_insert on existing key");
            s = (s + 1) & mask;
        }
    }

    /// Find the slot offset of `key`, if present.
    fn probe<A: SegmentAlloc>(&self, a: &A, key: u64) -> Option<u64> {
        let h = self.header(a);
        if h.cap == 0 {
            return None;
        }
        let mask = h.cap - 1;
        let mut s = mix64(key) & mask;
        loop {
            let off = Self::slot_off(&h, s);
            let k: u64 = a.read_pod(off);
            if k == key {
                return Some(off);
            }
            if k == EMPTY_KEY {
                return None;
            }
            s = (s + 1) & mask;
        }
    }

    pub fn get<A: SegmentAlloc>(&self, a: &A, key: u64) -> Option<V> {
        self.probe(a, key).map(|off| a.read_pod(off + 8))
    }

    pub fn contains<A: SegmentAlloc>(&self, a: &A, key: u64) -> bool {
        self.probe(a, key).is_some()
    }

    /// First empty slot on `key`'s probe chain (the table must have
    /// room — callers grow first).
    fn find_free_slot<A: SegmentAlloc>(a: &A, h: &MapHeader, key: u64) -> u64 {
        let mask = h.cap - 1;
        let mut s = mix64(key) & mask;
        loop {
            let off = Self::slot_off(h, s);
            let k: u64 = a.read_pod(off);
            if k == EMPTY_KEY {
                return off;
            }
            debug_assert_ne!(k, key, "find_free_slot on existing key");
            s = (s + 1) & mask;
        }
    }

    /// Insert or overwrite; returns true when the key was new. The
    /// reserved [`EMPTY_KEY`] (`u64::MAX`) is rejected with
    /// `Error::InvalidOp` — storing it would alias the empty-slot marker
    /// and corrupt every probe chain crossing its slot.
    ///
    /// Crash-atomicity: a new-key insert is fully logged (key + `len`
    /// publish roll back together). An *overwrite* logs old/new value
    /// images only when `V` fits a 24-byte log image; larger values are
    /// overwritten in place un-logged — a kill mid-write can tear the
    /// value (never the map structure).
    pub fn insert<A: SegmentAlloc>(&self, a: &A, key: u64, value: V) -> Result<bool> {
        if key == EMPTY_KEY {
            return Err(Error::InvalidOp(
                "key u64::MAX is reserved as the hash map's empty-slot marker".into(),
            ));
        }
        if let Some(off) = self.probe(a, key) {
            if std::mem::size_of::<V>() <= oplog::IMAGE_SIZE {
                let old: V = a.read_pod(off + 8);
                let h = self.header(a);
                let mut rec = OpRecord::new(oplog::OP_MAP_INSERT);
                rec.flags = oplog::FLAG_OVERWRITE;
                rec.h1_off = self.header_off;
                rec.h1_old = oplog::image_of(&h);
                rec.h1_new = rec.h1_old;
                rec.h2_off = off + 8;
                rec.h2_old = oplog::image_of(&old);
                rec.h2_new = oplog::image_of(&value);
                rec.h2_len = std::mem::size_of::<V>() as u32;
                rec.aux = off;
                rec.aux2 = key;
                rec.unit = Self::STRIDE as u32;
                let tok = a.oplog_begin(rec)?;
                a.write_pod(off + 8, value);
                a.oplog_commit(tok)?;
            } else {
                a.write_pod(off + 8, value);
            }
            return Ok(false);
        }
        let mut h = self.header(a);
        if h.cap == 0 || (h.len + 1) * 10 > h.cap * 7 {
            h = self.grow(a)?;
        }
        let slot = Self::find_free_slot(a, &h, key);
        let mut rec = OpRecord::new(oplog::OP_MAP_INSERT);
        rec.h1_off = self.header_off;
        rec.h1_old = oplog::image_of(&h);
        h.len += 1;
        rec.h1_new = oplog::image_of(&h);
        rec.aux = slot;
        rec.aux2 = key;
        rec.unit = Self::STRIDE as u32;
        let tok = a.oplog_begin(rec)?;
        a.write_pod(slot, key);
        a.write_pod(slot + 8, value);
        self.write_header(a, h);
        a.oplog_commit(tok)?;
        Ok(true)
    }

    /// Get the value for `key`, inserting `make()`'s result first if
    /// absent (the vertex-table "find-or-create edge list" operation).
    pub fn get_or_insert_with<A: SegmentAlloc>(
        &self,
        a: &A,
        key: u64,
        make: impl FnOnce(&A) -> Result<V>,
    ) -> Result<V> {
        if let Some(v) = self.get(a, key) {
            return Ok(v);
        }
        let v = make(a)?;
        self.insert(a, key, v)?;
        Ok(v)
    }

    /// Iterate `(key, value)` pairs (arbitrary order).
    pub fn for_each<A: SegmentAlloc>(&self, a: &A, mut f: impl FnMut(u64, V)) {
        let h = self.header(a);
        for s in 0..h.cap {
            let off = Self::slot_off(&h, s);
            let k: u64 = a.read_pod(off);
            if k != EMPTY_KEY {
                f(k, a.read_pod(off + 8));
            }
        }
    }

    /// Free the table and the header (does not touch values' own
    /// allocations — the caller owns value semantics).
    pub fn destroy<A: SegmentAlloc>(self, a: &A) -> Result<()> {
        let h = self.header(a);
        if h.cap > 0 {
            a.deallocate(h.table_off)?;
        }
        a.deallocate(self.header_off)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::{ManagerOptions, MetallManager};
    use crate::util::rng::Xoshiro256ss;
    use crate::util::tmp::TempDir;

    fn mgr(d: &TempDir) -> MetallManager {
        MetallManager::create_with(d.join("s"), ManagerOptions::small_for_tests()).unwrap()
    }

    #[test]
    fn insert_get_overwrite() {
        let d = TempDir::new("pmap1");
        let m = mgr(&d);
        let map = PHashMapU64::<u64>::create(&m).unwrap();
        assert_eq!(map.get(&m, 5), None);
        assert!(map.insert(&m, 5, 50).unwrap());
        assert!(!map.insert(&m, 5, 55).unwrap(), "overwrite returns false");
        assert_eq!(map.get(&m, 5), Some(55));
        assert_eq!(map.len(&m), 1);
    }

    #[test]
    fn survives_growth_against_model() {
        let d = TempDir::new("pmap2");
        let m = mgr(&d);
        let map = PHashMapU64::<u64>::create(&m).unwrap();
        let mut model = std::collections::HashMap::new();
        let mut rng = Xoshiro256ss::new(11);
        for _ in 0..5_000 {
            let k = rng.gen_range(2000);
            let v = rng.next_u64();
            let new = map.insert(&m, k, v).unwrap();
            assert_eq!(new, model.insert(k, v).is_none());
        }
        assert_eq!(map.len(&m), model.len());
        for (&k, &v) in &model {
            assert_eq!(map.get(&m, k), Some(v), "key {k}");
        }
        // iteration covers exactly the model
        let mut seen = std::collections::HashMap::new();
        map.for_each(&m, |k, v| {
            seen.insert(k, v);
        });
        assert_eq!(seen, model);
    }

    #[test]
    fn reattach() {
        let d = TempDir::new("pmap3");
        let store = d.join("s");
        {
            let m = MetallManager::create_with(&store, ManagerOptions::small_for_tests())
                .unwrap();
            let map = PHashMapU64::<u32>::create(&m).unwrap();
            for k in 0..500u64 {
                map.insert(&m, k, (k * 2) as u32).unwrap();
            }
            m.construct::<u64>("map", map.offset()).unwrap();
            m.close().unwrap();
        }
        let m = MetallManager::open(&store).unwrap();
        let off = m.find::<u64>("map").unwrap().unwrap();
        let map = PHashMapU64::<u32>::from_offset(m.read::<u64>(off));
        assert_eq!(map.len(&m), 500);
        assert_eq!(map.get(&m, 123), Some(246));
        m.close().unwrap();
    }

    #[test]
    fn get_or_insert_with_runs_once() {
        let d = TempDir::new("pmap4");
        let m = mgr(&d);
        let map = PHashMapU64::<u64>::create(&m).unwrap();
        let mut calls = 0;
        let v1 = map
            .get_or_insert_with(&m, 9, |_| {
                calls += 1;
                Ok(111)
            })
            .unwrap();
        let v2 = map
            .get_or_insert_with(&m, 9, |_| {
                calls += 1;
                Ok(222)
            })
            .unwrap();
        assert_eq!((v1, v2, calls), (111, 111, 1));
    }

    #[test]
    fn reserved_key_rejected() {
        let d = TempDir::new("pmap5");
        let m = mgr(&d);
        let map = PHashMapU64::<u64>::create(&m).unwrap();
        let err = map.insert(&m, EMPTY_KEY, 1).unwrap_err();
        assert!(err.to_string().contains("reserved"), "got: {err}");
        // the rejected insert left no trace
        assert_eq!(map.len(&m), 0);
        assert_eq!(map.get(&m, EMPTY_KEY), None);
        // and the map still works
        assert!(map.insert(&m, u64::MAX - 1, 7).unwrap());
        assert_eq!(map.get(&m, u64::MAX - 1), Some(7));
    }
}

//! Banked adjacency list (paper §6.1, Figure 3): "To support
//! multi-thread graph construction, we used m banks … A bank is a pair
//! of an adjacency list and a mutex object. We constructed a graph by
//! repeatedly inserting an edge, acquiring the bank's mutex associated
//! with the source vertex of the edge."
//!
//! Persistent layout: a header points at an array of `m` bank entries;
//! each bank holds a `PHashMapU64` vertex table mapping vertex id →
//! `PVec<u64>` edge list (the paper's `unordered_map` + `vector`
//! structure). Bank mutexes are runtime-only state, rebuilt on reattach.

use std::sync::Mutex;

use crate::alloc::manager::Persist;
use crate::alloc::SegmentAlloc;
use crate::containers::oplog;
use crate::containers::{PHashMapU64, PVec};
use crate::error::Result;
use crate::util::rng::mix64;

#[derive(Clone, Copy, Debug)]
#[repr(C)]
struct AdjHeader {
    nbanks: u64,
    banks_off: u64,
}

unsafe impl Persist for AdjHeader {}

#[derive(Clone, Copy, Debug)]
#[repr(C)]
struct BankEntry {
    map: PHashMapU64<PVec<u64>>,
    nedges: u64,
}

unsafe impl Persist for BankEntry {}

/// Runtime handle to a persistent banked adjacency list.
pub struct BankedAdjacency {
    header_off: u64,
    nbanks: u64,
    /// Cached from the header at open: the bank-entry array offset is
    /// immutable for the structure's lifetime (hot-path optimization —
    /// saves a header read per insert; see EXPERIMENTS.md §Perf).
    banks_off: u64,
    locks: Vec<Mutex<()>>,
}

impl BankedAdjacency {
    /// Create with `nbanks` banks (the paper uses m = 1024).
    pub fn create<A: SegmentAlloc>(a: &A, nbanks: usize) -> Result<Self> {
        assert!(nbanks >= 1);
        let header_off = a.allocate(std::mem::size_of::<AdjHeader>())?;
        let banks_off = a.allocate(nbanks * std::mem::size_of::<BankEntry>())?;
        for b in 0..nbanks {
            let map = PHashMapU64::<PVec<u64>>::create(a)?;
            a.write_pod(
                banks_off + (b * std::mem::size_of::<BankEntry>()) as u64,
                BankEntry { map, nedges: 0 },
            );
        }
        a.write_pod(header_off, AdjHeader { nbanks: nbanks as u64, banks_off });
        Ok(Self::open(a, header_off))
    }

    /// Reattach to an existing structure at `header_off`.
    pub fn open<A: SegmentAlloc>(a: &A, header_off: u64) -> Self {
        let h: AdjHeader = a.read_pod(header_off);
        Self {
            header_off,
            nbanks: h.nbanks,
            banks_off: h.banks_off,
            locks: (0..h.nbanks).map(|_| Mutex::new(())).collect(),
        }
    }

    pub fn offset(&self) -> u64 {
        self.header_off
    }

    pub fn nbanks(&self) -> usize {
        self.nbanks as usize
    }

    /// Bank owning `src` (mix64 of the source vertex, modulo banks).
    #[inline]
    pub fn bank_of(&self, src: u64) -> usize {
        (mix64(src) % self.nbanks) as usize
    }

    #[inline]
    fn bank_entry_off<A: SegmentAlloc>(&self, _a: &A, bank: usize) -> u64 {
        self.banks_off + (bank * std::mem::size_of::<BankEntry>()) as u64
    }

    /// Insert one directed edge (undirected graphs insert both
    /// directions, as the paper's benchmark does).
    pub fn insert_edge<A: SegmentAlloc>(&self, a: &A, src: u64, dst: u64) -> Result<()> {
        let bank = self.bank_of(src);
        let _guard = self.locks[bank].lock().unwrap();
        self.insert_locked(a, bank, src, dst)
    }

    fn insert_locked<A: SegmentAlloc>(
        &self,
        a: &A,
        bank: usize,
        src: u64,
        dst: u64,
    ) -> Result<()> {
        let entry_off = self.bank_entry_off(a, bank);
        let entry: BankEntry = a.read_pod(entry_off);
        let list = entry.map.get_or_insert_with(a, src, |a| PVec::<u64>::create(a))?;
        // One OP_EDGE record covers the list header *and* this bank
        // entry: the edge-list append and the `nedges` bump publish (and
        // roll back) atomically — the crash window where the old code
        // could persist a grown list with a stale counter is gone.
        let new_entry = BankEntry { map: entry.map, nedges: entry.nedges + 1 };
        list.push_edge(
            a,
            dst,
            entry_off,
            oplog::image_of(&entry),
            oplog::image_of(&new_entry),
            std::mem::size_of::<BankEntry>() as u32,
        )
    }

    /// Insert a batch: edges are grouped per bank so each bank mutex is
    /// taken once per run (the coordinator's batcher produces these
    /// groups). Allocation-free: the batch is key-sorted in place rather
    /// than scattered into per-bank Vecs (EXPERIMENTS.md §Perf: the
    /// original per-bank-Vec version allocated `nbanks` Vecs per batch
    /// and dominated the ingest profile).
    pub fn insert_batch<A: SegmentAlloc>(&self, a: &A, edges: &[(u64, u64)]) -> Result<()> {
        // counting sort by bank: O(n + nbanks), two allocations total
        let nb = self.nbanks as usize;
        let mut counts = vec![0u32; nb + 1];
        for &(s, _) in edges {
            counts[self.bank_of(s) + 1] += 1;
        }
        for b in 0..nb {
            counts[b + 1] += counts[b];
        }
        let mut placed: Vec<(u64, u64)> = vec![(0, 0); edges.len()];
        let mut cursor = counts.clone();
        for &(s, d) in edges {
            let b = self.bank_of(s);
            placed[cursor[b] as usize] = (s, d);
            cursor[b] += 1;
        }
        for b in 0..nb {
            let (lo, hi) = (counts[b] as usize, counts[b + 1] as usize);
            if lo == hi {
                continue;
            }
            let _guard = self.locks[b].lock().unwrap();
            for &(s, d) in &placed[lo..hi] {
                self.insert_locked(a, b, s, d)?;
            }
        }
        Ok(())
    }

    /// Total inserted (directed) edges.
    pub fn num_edges<A: SegmentAlloc>(&self, a: &A) -> u64 {
        (0..self.nbanks as usize)
            .map(|b| a.read_pod::<BankEntry>(self.bank_entry_off(a, b)).nedges)
            .sum()
    }

    /// Number of distinct source vertices.
    pub fn num_vertices<A: SegmentAlloc>(&self, a: &A) -> u64 {
        (0..self.nbanks as usize)
            .map(|b| {
                a.read_pod::<BankEntry>(self.bank_entry_off(a, b)).map.len(a) as u64
            })
            .sum()
    }

    /// Out-degree of `v` (0 when absent).
    pub fn degree<A: SegmentAlloc>(&self, a: &A, v: u64) -> usize {
        let entry: BankEntry = a.read_pod(self.bank_entry_off(a, self.bank_of(v)));
        entry.map.get(a, v).map(|l| l.len(a)).unwrap_or(0)
    }

    /// Copy out the neighbors of `v`.
    pub fn neighbors<A: SegmentAlloc>(&self, a: &A, v: u64) -> Vec<u64> {
        let entry: BankEntry = a.read_pod(self.bank_entry_off(a, self.bank_of(v)));
        entry.map.get(a, v).map(|l| l.to_vec(a)).unwrap_or_default()
    }

    /// Visit every `(vertex, neighbors)` pair.
    pub fn for_each_vertex<A: SegmentAlloc>(&self, a: &A, mut f: impl FnMut(u64, Vec<u64>)) {
        for b in 0..self.nbanks as usize {
            let entry: BankEntry = a.read_pod(self.bank_entry_off(a, b));
            entry.map.for_each(a, |v, list| f(v, list.to_vec(a)));
        }
    }

    /// Export as a flat directed edge list (analytics hand-off).
    pub fn to_edge_list<A: SegmentAlloc>(&self, a: &A) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        self.for_each_vertex(a, |v, nbrs| {
            for d in nbrs {
                out.push((v, d));
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::{ManagerOptions, MetallManager};
    use crate::util::tmp::TempDir;

    fn mgr(d: &TempDir) -> MetallManager {
        MetallManager::create_with(d.join("s"), ManagerOptions::small_for_tests()).unwrap()
    }

    #[test]
    fn insert_and_query() {
        let d = TempDir::new("adj1");
        let m = mgr(&d);
        let g = BankedAdjacency::create(&m, 16).unwrap();
        g.insert_edge(&m, 1, 2).unwrap();
        g.insert_edge(&m, 1, 3).unwrap();
        g.insert_edge(&m, 2, 3).unwrap();
        assert_eq!(g.num_edges(&m), 3);
        assert_eq!(g.num_vertices(&m), 2);
        assert_eq!(g.degree(&m, 1), 2);
        assert_eq!(g.neighbors(&m, 1), vec![2, 3]);
        assert_eq!(g.degree(&m, 9), 0);
    }

    #[test]
    fn reattach_preserves_graph() {
        let d = TempDir::new("adj2");
        let store = d.join("s");
        let head;
        {
            let m = MetallManager::create_with(&store, ManagerOptions::small_for_tests())
                .unwrap();
            let g = BankedAdjacency::create(&m, 8).unwrap();
            for s in 0..50u64 {
                for k in 0..(s % 5) {
                    g.insert_edge(&m, s, s + k + 1).unwrap();
                }
            }
            head = g.offset();
            m.construct::<u64>("graph", head).unwrap();
            m.close().unwrap();
        }
        let m = MetallManager::open(&store).unwrap();
        let off = m.find::<u64>("graph").unwrap().unwrap();
        let g = BankedAdjacency::open(&m, m.read::<u64>(off));
        assert_eq!(g.degree(&m, 4), 4);
        assert_eq!(g.neighbors(&m, 4), vec![5, 6, 7, 8]);
        let total: u64 = (0..50).map(|s| s % 5).sum();
        assert_eq!(g.num_edges(&m), total);
        m.close().unwrap();
    }

    #[test]
    fn multithreaded_construction_is_lossless() {
        let d = TempDir::new("adj3");
        let m = mgr(&d);
        let g = BankedAdjacency::create(&m, 64).unwrap();
        let nthreads = 8u64;
        let per = 400u64;
        std::thread::scope(|s| {
            for t in 0..nthreads {
                let (g, m) = (&g, &m);
                s.spawn(move || {
                    for i in 0..per {
                        // every thread inserts into overlapping vertices
                        g.insert_edge(m, i % 50, t * per + i).unwrap();
                    }
                });
            }
        });
        assert_eq!(g.num_edges(&m), nthreads * per);
        // each vertex v < 50 has nthreads * (per/50) edges
        for v in 0..50 {
            assert_eq!(g.degree(&m, v), (nthreads * per / 50) as usize, "vertex {v}");
        }
    }

    #[test]
    fn batch_equals_single_inserts() {
        let d = TempDir::new("adj4");
        let m = mgr(&d);
        let g1 = BankedAdjacency::create(&m, 8).unwrap();
        let g2 = BankedAdjacency::create(&m, 8).unwrap();
        let edges: Vec<(u64, u64)> =
            (0..300).map(|i| (i % 17, (i * 7) % 23)).collect();
        for &(s, dd) in &edges {
            g1.insert_edge(&m, s, dd).unwrap();
        }
        g2.insert_batch(&m, &edges).unwrap();
        assert_eq!(g1.num_edges(&m), g2.num_edges(&m));
        for v in 0..17 {
            let mut a = g1.neighbors(&m, v);
            let mut b = g2.neighbors(&m, v);
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "vertex {v}");
        }
    }

    #[test]
    fn edge_list_export() {
        let d = TempDir::new("adj5");
        let m = mgr(&d);
        let g = BankedAdjacency::create(&m, 4).unwrap();
        g.insert_edge(&m, 0, 1).unwrap();
        g.insert_edge(&m, 1, 0).unwrap();
        let mut el = g.to_edge_list(&m);
        el.sort_unstable();
        assert_eq!(el, vec![(0, 1), (1, 0)]);
    }
}

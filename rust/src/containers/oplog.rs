//! Container operation log: the persistent record format behind
//! crash-atomic container mutations (DGAP-style checksum-sealed records;
//! see the module docs in [`crate::containers`] for the full protocol).
//!
//! This module owns the *format* only — a fixed 192-byte little-endian
//! record codec, the 512-byte log header with its epoch cut table, and
//! the header-image decode helpers recovery uses. The runtime state
//! (sequence allocation, appending, sealing, replay) lives in
//! [`crate::alloc::manager`]; the containers produce [`OpRecord`]s and
//! hand them to [`crate::alloc::SegmentAlloc::oplog_begin`] /
//! [`oplog_commit`](crate::alloc::SegmentAlloc::oplog_commit).
//!
//! ## Record life cycle
//!
//! 1. The mutating container allocates any new extent it needs, then
//!    builds an [`OpRecord`] naming the op kind, the header cell(s) it
//!    will publish (`h1`/`h2` offset + old and new 24-byte images), the
//!    freshly allocated extent (`alloc_off`/`alloc_size`), and the extent
//!    the op will retire (`free_off`).
//! 2. `oplog_begin` assigns the record its ring sequence number, seals
//!    the **intent** checksum over the whole record, and writes it into
//!    the ring *before any user byte moves*.
//! 3. The container performs its data writes and publishes the new
//!    header image(s).
//! 4. `oplog_commit` seals the **commit** mark — a second checksum
//!    derived from the intent checksum — and only then does the
//!    container run its trailing `deallocate(free_off)`.
//!
//! A record whose intent checksum does not verify is garbage (torn
//! append or never-written ring slot) and is ignored. A record with a
//! valid intent but no commit mark was in flight at the kill: recovery
//! decides per record whether to roll it forward (finish publishing and
//! seal) or back (restore the old images and seal an **abort** mark).
//! Because the trailing deallocate runs strictly after the commit seal,
//! an unsealed record's `free_off` extent is still untouched — rollback
//! never resurrects a header into hole-punched space.

use crate::alloc::Persist;
use crate::util::fnv1a;

/// One ring slot, bytes on disk.
pub const RECORD_SIZE: usize = 192;
/// Log header (magic + geometry + cut table), bytes on disk.
pub const LOG_HEADER_SIZE: usize = 512;
/// Cut-table slots; epoch `e` writes slot `e % CUT_SLOTS`.
pub const CUT_SLOTS: usize = 16;
/// Default ring capacity in records (192 B each → 192 KiB + header).
pub const DEFAULT_CAPACITY: u32 = 1024;
/// Name-directory key of the per-manager log object (created lazily on
/// the first logged container mutation).
pub const OPLOG_NAME: &str = "__metall_oplog__";
/// "No offset here" sentinel for `h2_off`, `alloc_off`, `free_off`.
pub const NONE: u64 = u64::MAX;

/// `little-endian("METALLOG")`.
pub const OPLOG_MAGIC: u64 = u64::from_le_bytes(*b"METALLOG");
/// On-disk format version.
pub const OPLOG_VERSION: u32 = 1;

// ------------------------------------------------------------ op kinds --

/// `PVec::create` — `h1` is the fresh header cell itself (`alloc_off ==
/// h1_off`), old and new images both the init image.
pub const OP_VEC_CREATE: u32 = 1;
/// `PVec::push` — header-only (`len + 1`), element written below `len`.
pub const OP_VEC_PUSH: u32 = 2;
/// `PVec::extend_from_slice` — header-only (`len + n`), `aux = n`.
pub const OP_VEC_EXTEND: u32 = 3;
/// `PVec::pop` — header-only (`len - 1`).
pub const OP_VEC_POP: u32 = 4;
/// `PVec` capacity growth: `alloc_off` is the new extent, `free_off`
/// the retired one, `aux` the element size.
pub const OP_VEC_GROW: u32 = 5;
/// `PHashMap::create` — like [`OP_VEC_CREATE`].
pub const OP_MAP_CREATE: u32 = 6;
/// `PHashMap::insert` — new key: `aux` is the slot offset, `aux2` the
/// key (slot is keyed *before* the header publishes `len + 1`).
/// Overwrite ([`FLAG_OVERWRITE`]): `h1_old == h1_new`, and for values
/// ≤ 24 bytes `h2` carries the in-slot value cell's old/new images.
pub const OP_MAP_INSERT: u32 = 7;
/// `PHashMap` table growth/rehash: `alloc_off` new table, `free_off`
/// old table, `aux` the slot stride.
pub const OP_MAP_GROW: u32 = 8;
/// `BankedAdjacency::insert_edge` — the combined two-header publish:
/// `h1` is the per-source `PVec` header (`len + 1`), `h2` the bank's
/// `BankEntry` cell (`nedges + 1`). No alloc/free of its own (the
/// nested `get_or_insert_with`/`reserve` log their own records first).
pub const OP_EDGE: u32 = 9;
/// `PString::set` — `alloc_off` new bytes, `free_off` old bytes.
pub const OP_STR_SET: u32 = 10;

/// [`OP_MAP_INSERT`]: existing key, value overwritten in place.
pub const FLAG_OVERWRITE: u32 = 1;

/// Human-readable op-kind name for doctor/recovery reports.
pub fn kind_name(kind: u32) -> &'static str {
    match kind {
        OP_VEC_CREATE => "vec_create",
        OP_VEC_PUSH => "vec_push",
        OP_VEC_EXTEND => "vec_extend",
        OP_VEC_POP => "vec_pop",
        OP_VEC_GROW => "vec_grow",
        OP_MAP_CREATE => "map_create",
        OP_MAP_INSERT => "map_insert",
        OP_MAP_GROW => "map_grow",
        OP_EDGE => "edge_insert",
        OP_STR_SET => "str_set",
        _ => "unknown",
    }
}

// ----------------------------------------------------------- the record --

/// Serialized field offsets (little-endian, fixed layout — the codec is
/// field-by-field, never a struct memcpy, so the on-disk format is
/// independent of Rust layout decisions).
const SEQ_AT: usize = 0;
const KIND_AT: usize = 8;
const FLAGS_AT: usize = 12;
const H1_OFF_AT: usize = 16;
const H1_OLD_AT: usize = 24;
const H1_NEW_AT: usize = 48;
const H2_OFF_AT: usize = 72;
const H2_OLD_AT: usize = 80;
const H2_NEW_AT: usize = 104;
const ALLOC_OFF_AT: usize = 128;
const ALLOC_SIZE_AT: usize = 136;
const FREE_OFF_AT: usize = 144;
const AUX_AT: usize = 152;
const AUX2_AT: usize = 160;
const UNIT_AT: usize = 168;
const H2_LEN_AT: usize = 172;
const INTENT_CRC_AT: usize = 176;
/// Byte offset of the commit mark inside a ring slot — the commit seal
/// is an 8-byte write at `slot_off + COMMIT_CRC_AT`, nothing else.
pub const COMMIT_CRC_AT: usize = 184;

/// Header images are at most 24 bytes (the largest container header,
/// `PVecHeader`/`MapHeader`, is 3 × u64); smaller cells zero-pad.
pub const IMAGE_SIZE: usize = 24;

const COMMIT_TAG: u64 = 0x434f_4d4d_4954_4f4b; // "COMMITOK"
const ABORT_TAG: u64 = 0x41_424f_5254_4544; // "ABORTED"

/// One container-operation intent record (see module docs for the
/// protocol). All offsets are segment offsets; [`NONE`] marks an absent
/// `h2`/`alloc`/`free` member.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpRecord {
    /// Ring sequence number, assigned by `oplog_begin`.
    pub seq: u64,
    /// One of the `OP_*` constants.
    pub kind: u32,
    /// `FLAG_*` bits.
    pub flags: u32,
    /// Primary header cell this op publishes.
    pub h1_off: u64,
    pub h1_old: [u8; IMAGE_SIZE],
    pub h1_new: [u8; IMAGE_SIZE],
    /// Secondary cell ([`OP_EDGE`]'s `BankEntry`, overwrite value cell),
    /// or [`NONE`].
    pub h2_off: u64,
    pub h2_old: [u8; IMAGE_SIZE],
    pub h2_new: [u8; IMAGE_SIZE],
    /// Extent allocated *before* this record was appended, or [`NONE`].
    pub alloc_off: u64,
    pub alloc_size: u64,
    /// Extent deallocated *after* the commit seal, or [`NONE`].
    pub free_off: u64,
    /// Kind-specific operand (slot offset, element size, count…).
    pub aux: u64,
    /// Kind-specific operand ([`OP_MAP_INSERT`]: the key).
    pub aux2: u64,
    /// Element size (vec ops) / slot stride (map ops) — what
    /// `validate_containers` needs to size-check `data_off`/`table_off`
    /// extents and walk table slots.
    pub unit: u32,
    /// True byte length of the `h2` images (a `BankEntry` or `StrHeader`
    /// is 16 B, an overwrite value cell `stride - 8`); images zero-pad
    /// to [`IMAGE_SIZE`] but recovery compares and restores only this
    /// many bytes — writing the padding would clobber neighbours.
    pub h2_len: u32,
    /// FNV-1a over the record with both checksum fields zeroed.
    pub intent_crc: u64,
    /// Commit/abort mark derived from `intent_crc`, 0 while in flight.
    pub commit_crc: u64,
}

/// Seal state a valid-intent record is in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecordState {
    /// Intent written, op was in flight at the kill.
    Unsealed,
    /// Op fully published (recovery keeps / re-applies it).
    Committed,
    /// Recovery rolled it back.
    Aborted,
}

impl OpRecord {
    /// A zeroed skeleton with every optional member absent.
    pub fn new(kind: u32) -> Self {
        OpRecord {
            seq: 0,
            kind,
            flags: 0,
            h1_off: NONE,
            h1_old: [0; IMAGE_SIZE],
            h1_new: [0; IMAGE_SIZE],
            h2_off: NONE,
            h2_old: [0; IMAGE_SIZE],
            h2_new: [0; IMAGE_SIZE],
            alloc_off: NONE,
            alloc_size: 0,
            free_off: NONE,
            aux: 0,
            aux2: 0,
            unit: 0,
            h2_len: 0,
            intent_crc: 0,
            commit_crc: 0,
        }
    }

    pub fn to_bytes(&self) -> [u8; RECORD_SIZE] {
        let mut b = [0u8; RECORD_SIZE];
        b[SEQ_AT..SEQ_AT + 8].copy_from_slice(&self.seq.to_le_bytes());
        b[KIND_AT..KIND_AT + 4].copy_from_slice(&self.kind.to_le_bytes());
        b[FLAGS_AT..FLAGS_AT + 4].copy_from_slice(&self.flags.to_le_bytes());
        b[H1_OFF_AT..H1_OFF_AT + 8].copy_from_slice(&self.h1_off.to_le_bytes());
        b[H1_OLD_AT..H1_OLD_AT + IMAGE_SIZE].copy_from_slice(&self.h1_old);
        b[H1_NEW_AT..H1_NEW_AT + IMAGE_SIZE].copy_from_slice(&self.h1_new);
        b[H2_OFF_AT..H2_OFF_AT + 8].copy_from_slice(&self.h2_off.to_le_bytes());
        b[H2_OLD_AT..H2_OLD_AT + IMAGE_SIZE].copy_from_slice(&self.h2_old);
        b[H2_NEW_AT..H2_NEW_AT + IMAGE_SIZE].copy_from_slice(&self.h2_new);
        b[ALLOC_OFF_AT..ALLOC_OFF_AT + 8].copy_from_slice(&self.alloc_off.to_le_bytes());
        b[ALLOC_SIZE_AT..ALLOC_SIZE_AT + 8].copy_from_slice(&self.alloc_size.to_le_bytes());
        b[FREE_OFF_AT..FREE_OFF_AT + 8].copy_from_slice(&self.free_off.to_le_bytes());
        b[AUX_AT..AUX_AT + 8].copy_from_slice(&self.aux.to_le_bytes());
        b[AUX2_AT..AUX2_AT + 8].copy_from_slice(&self.aux2.to_le_bytes());
        b[UNIT_AT..UNIT_AT + 4].copy_from_slice(&self.unit.to_le_bytes());
        b[H2_LEN_AT..H2_LEN_AT + 4].copy_from_slice(&self.h2_len.to_le_bytes());
        b[INTENT_CRC_AT..INTENT_CRC_AT + 8].copy_from_slice(&self.intent_crc.to_le_bytes());
        b[COMMIT_CRC_AT..COMMIT_CRC_AT + 8].copy_from_slice(&self.commit_crc.to_le_bytes());
        b
    }

    pub fn from_bytes(b: &[u8; RECORD_SIZE]) -> Self {
        let u64_at = |at: usize| u64::from_le_bytes(b[at..at + 8].try_into().unwrap());
        let u32_at = |at: usize| u32::from_le_bytes(b[at..at + 4].try_into().unwrap());
        let img_at = |at: usize| -> [u8; IMAGE_SIZE] { b[at..at + IMAGE_SIZE].try_into().unwrap() };
        OpRecord {
            seq: u64_at(SEQ_AT),
            kind: u32_at(KIND_AT),
            flags: u32_at(FLAGS_AT),
            h1_off: u64_at(H1_OFF_AT),
            h1_old: img_at(H1_OLD_AT),
            h1_new: img_at(H1_NEW_AT),
            h2_off: u64_at(H2_OFF_AT),
            h2_old: img_at(H2_OLD_AT),
            h2_new: img_at(H2_NEW_AT),
            alloc_off: u64_at(ALLOC_OFF_AT),
            alloc_size: u64_at(ALLOC_SIZE_AT),
            free_off: u64_at(FREE_OFF_AT),
            aux: u64_at(AUX_AT),
            aux2: u64_at(AUX2_AT),
            unit: u32_at(UNIT_AT),
            h2_len: u32_at(H2_LEN_AT),
            intent_crc: u64_at(INTENT_CRC_AT),
            commit_crc: u64_at(COMMIT_CRC_AT),
        }
    }

    /// FNV-1a over the serialized record with both checksum fields
    /// zeroed — what `intent_crc` must equal for the intent to verify.
    pub fn body_crc(&self) -> u64 {
        let mut b = self.to_bytes();
        b[INTENT_CRC_AT..INTENT_CRC_AT + 8].fill(0);
        b[COMMIT_CRC_AT..COMMIT_CRC_AT + 8].fill(0);
        fnv1a(&b)
    }

    /// Seal the intent checksum (done by `oplog_begin` after assigning
    /// `seq`, before the ring write).
    pub fn seal_intent(&mut self) {
        self.intent_crc = self.body_crc();
    }

    /// Does the intent checksum verify? A zeroed ring slot fails (the
    /// FNV of 192 zero bytes is nonzero while its stored crc is zero),
    /// as does any torn append.
    pub fn intent_valid(&self) -> bool {
        self.intent_crc != 0 && self.intent_crc == self.body_crc()
    }

    /// Seal state; meaningless unless [`Self::intent_valid`].
    pub fn state(&self) -> RecordState {
        if self.commit_crc == commit_mark(self.intent_crc) {
            RecordState::Committed
        } else if self.commit_crc == abort_mark(self.intent_crc) {
            RecordState::Aborted
        } else {
            RecordState::Unsealed
        }
    }

    /// True byte length of the `h1` images: the full 24-byte
    /// `PVecHeader`/`MapHeader` for every kind except [`OP_STR_SET`],
    /// whose `StrHeader` is 16 bytes.
    pub fn h1_len(&self) -> usize {
        match self.kind {
            OP_STR_SET => 16,
            _ => IMAGE_SIZE,
        }
    }
}

/// The 8-byte commit mark for a record with this intent checksum.
pub fn commit_mark(intent_crc: u64) -> u64 {
    fnv1a(&(intent_crc ^ COMMIT_TAG).to_le_bytes())
}

/// The 8-byte abort mark recovery seals on a rolled-back record.
pub fn abort_mark(intent_crc: u64) -> u64 {
    fnv1a(&(intent_crc ^ ABORT_TAG).to_le_bytes())
}

/// Segment offset of the ring slot holding `seq`.
pub fn slot_off(log_off: u64, capacity: u32, seq: u64) -> u64 {
    log_off + LOG_HEADER_SIZE as u64 + (seq % capacity as u64) * RECORD_SIZE as u64
}

/// Total bytes of a log object with `capacity` ring slots.
pub fn log_size(capacity: u32) -> usize {
    LOG_HEADER_SIZE + capacity as usize * RECORD_SIZE
}

// ------------------------------------------------------------ log header --

const CAPACITY_AT: usize = 12;
const CUTS_AT: usize = 16;
const CUT_ENTRY_SIZE: usize = 24;

/// One epoch's cut: every record with `seq < cut_seq` was fully decided
/// (committed or aborted) *before* this management epoch's consistent
/// cut was taken — so recovery onto that epoch's manifest only ever
/// replays records at `seq >= cut_seq`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CutEntry {
    pub epoch: u64,
    pub cut_seq: u64,
}

fn cut_crc(epoch: u64, cut_seq: u64) -> u64 {
    let mut b = [0u8; 16];
    b[..8].copy_from_slice(&epoch.to_le_bytes());
    b[8..].copy_from_slice(&cut_seq.to_le_bytes());
    fnv1a(&b)
}

impl CutEntry {
    pub fn to_bytes(&self) -> [u8; CUT_ENTRY_SIZE] {
        let mut b = [0u8; CUT_ENTRY_SIZE];
        b[..8].copy_from_slice(&self.epoch.to_le_bytes());
        b[8..16].copy_from_slice(&self.cut_seq.to_le_bytes());
        b[16..].copy_from_slice(&cut_crc(self.epoch, self.cut_seq).to_le_bytes());
        b
    }

    /// Decode; `None` when the slot is empty or torn (bad crc).
    pub fn from_bytes(b: &[u8; CUT_ENTRY_SIZE]) -> Option<Self> {
        let epoch = u64::from_le_bytes(b[..8].try_into().unwrap());
        let cut_seq = u64::from_le_bytes(b[8..16].try_into().unwrap());
        let crc = u64::from_le_bytes(b[16..].try_into().unwrap());
        if epoch == 0 || crc != cut_crc(epoch, cut_seq) {
            return None;
        }
        Some(CutEntry { epoch, cut_seq })
    }
}

/// Segment offset of epoch `e`'s cut-table slot.
pub fn cut_entry_off(log_off: u64, epoch: u64) -> u64 {
    log_off + CUTS_AT as u64 + (epoch % CUT_SLOTS as u64) * CUT_ENTRY_SIZE as u64
}

/// Serialize a fresh log header (called once at lazy creation; the cut
/// table starts all-zero = no valid entries).
pub fn header_bytes(capacity: u32) -> [u8; LOG_HEADER_SIZE] {
    let mut b = [0u8; LOG_HEADER_SIZE];
    b[..8].copy_from_slice(&OPLOG_MAGIC.to_le_bytes());
    b[8..12].copy_from_slice(&OPLOG_VERSION.to_le_bytes());
    b[CAPACITY_AT..CAPACITY_AT + 4].copy_from_slice(&capacity.to_le_bytes());
    b
}

/// Decode magic/version/capacity from the first 16 header bytes;
/// `None` when the magic or version mismatches or capacity is silly.
pub fn decode_header(b: &[u8]) -> Option<u32> {
    if b.len() < CUTS_AT {
        return None;
    }
    let magic = u64::from_le_bytes(b[..8].try_into().unwrap());
    let version = u32::from_le_bytes(b[8..12].try_into().unwrap());
    let capacity = u32::from_le_bytes(b[CAPACITY_AT..CAPACITY_AT + 4].try_into().unwrap());
    if magic != OPLOG_MAGIC || version != OPLOG_VERSION || capacity == 0 {
        return None;
    }
    Some(capacity)
}

// -------------------------------------------------------- image helpers --

/// Snapshot a ≤ 24-byte POD header into a zero-padded image.
pub fn image_of<T: Persist>(v: &T) -> [u8; IMAGE_SIZE] {
    let n = std::mem::size_of::<T>();
    assert!(n <= IMAGE_SIZE, "container header exceeds the image size");
    let mut img = [0u8; IMAGE_SIZE];
    // Persist guarantees plain-old-data with no padding requirements
    let src = unsafe { std::slice::from_raw_parts(v as *const T as *const u8, n) };
    img[..n].copy_from_slice(src);
    img
}

/// Decoded [`OP_VEC_*`] header image (`PVecHeader` layout).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VecImage {
    pub data_off: u64,
    pub len: u64,
    pub cap: u64,
}

pub fn vec_image(img: &[u8; IMAGE_SIZE]) -> VecImage {
    VecImage {
        data_off: u64::from_le_bytes(img[..8].try_into().unwrap()),
        len: u64::from_le_bytes(img[8..16].try_into().unwrap()),
        cap: u64::from_le_bytes(img[16..].try_into().unwrap()),
    }
}

/// Decoded [`OP_MAP_*`] header image (`MapHeader` layout).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MapImage {
    pub table_off: u64,
    pub cap: u64,
    pub len: u64,
}

pub fn map_image(img: &[u8; IMAGE_SIZE]) -> MapImage {
    MapImage {
        table_off: u64::from_le_bytes(img[..8].try_into().unwrap()),
        cap: u64::from_le_bytes(img[8..16].try_into().unwrap()),
        len: u64::from_le_bytes(img[16..].try_into().unwrap()),
    }
}

/// Decoded [`OP_STR_SET`] header image (`StrHeader` layout).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StrImage {
    pub data_off: u64,
    pub len: u64,
}

pub fn str_image(img: &[u8; IMAGE_SIZE]) -> StrImage {
    StrImage {
        data_off: u64::from_le_bytes(img[..8].try_into().unwrap()),
        len: u64::from_le_bytes(img[8..16].try_into().unwrap()),
    }
}

/// Decoded [`OP_EDGE`] `h2` image (`BankEntry` layout: the bank map's
/// header offset + the edge counter).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BankImage {
    pub map_header_off: u64,
    pub nedges: u64,
}

pub fn bank_image(img: &[u8; IMAGE_SIZE]) -> BankImage {
    BankImage {
        map_header_off: u64::from_le_bytes(img[..8].try_into().unwrap()),
        nedges: u64::from_le_bytes(img[8..16].try_into().unwrap()),
    }
}

// ------------------------------------------------------- token + stats --

/// Handle `oplog_begin` returns and `oplog_commit` consumes: where the
/// record landed and the intent checksum the commit mark derives from.
#[derive(Clone, Copy, Debug)]
pub struct OpToken {
    /// Segment offset of the ring slot holding the record.
    pub slot_off: u64,
    /// Ring sequence number (the commit path retires it from the
    /// in-flight set that pins the reclaim horizon).
    pub seq: u64,
    pub intent_crc: u64,
}

/// Cumulative per-manager op-log counters (exported as `alloc.oplog.*`).
#[derive(Clone, Copy, Debug, Default)]
pub struct OpLogStats {
    /// Intent records appended.
    pub appended: u64,
    /// Commit marks sealed.
    pub committed: u64,
    /// Ring-full forced syncs (writers waited for a manifest commit to
    /// advance the reclaim horizon).
    pub forced_syncs: u64,
    /// … of which failed (a fault-stalled manifest commit). The append
    /// retries anyway: after three failed attempts the ring-full
    /// `InvalidOp` contract reports the stall to the caller.
    pub forced_sync_errors: u64,
    /// Recovery: unsealed records rolled forward (re-sealed).
    pub recovered_forward: u64,
    /// Recovery: unsealed records rolled back (old images restored).
    pub recovered_rollback: u64,
    /// Recovery: extents adopted into the recovered allocator.
    pub recovered_adopted: u64,
    /// Recovery: stale extents released back to the allocator.
    pub recovered_released: u64,
    /// Recovery: current header bytes matched neither image (restored
    /// the old image anyway; worth surfacing in doctor).
    pub recovery_anomalies: u64,
    /// Records the last `validate_containers` pass examined.
    pub validate_records: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> OpRecord {
        let mut r = OpRecord::new(OP_VEC_GROW);
        r.seq = 41;
        r.h1_off = 4096;
        r.h1_old[..8].copy_from_slice(&77u64.to_le_bytes());
        r.h1_new[..8].copy_from_slice(&99u64.to_le_bytes());
        r.alloc_off = 1 << 20;
        r.alloc_size = 256;
        r.free_off = 1 << 16;
        r.aux = 8;
        r.unit = 8;
        r.h2_len = 16;
        r
    }

    #[test]
    fn record_roundtrips_and_layout_is_stable() {
        let mut r = sample();
        r.seal_intent();
        r.commit_crc = commit_mark(r.intent_crc);
        let b = r.to_bytes();
        assert_eq!(OpRecord::from_bytes(&b), r);
        // the commit mark must live exactly at COMMIT_CRC_AT: the seal
        // path writes those 8 bytes directly into the ring slot
        assert_eq!(
            u64::from_le_bytes(b[COMMIT_CRC_AT..COMMIT_CRC_AT + 8].try_into().unwrap()),
            r.commit_crc
        );
    }

    #[test]
    fn intent_checksum_detects_torn_and_empty_slots() {
        let zero = OpRecord::from_bytes(&[0u8; RECORD_SIZE]);
        assert!(!zero.intent_valid(), "all-zero ring slot is not a record");
        let mut r = sample();
        assert!(!r.intent_valid(), "unsealed intent does not verify");
        r.seal_intent();
        assert!(r.intent_valid());
        let mut b = r.to_bytes();
        b[H1_NEW_AT] ^= 0xFF; // torn byte inside the body
        assert!(!OpRecord::from_bytes(&b).intent_valid());
    }

    #[test]
    fn seal_states_are_distinct() {
        let mut r = sample();
        r.seal_intent();
        assert_eq!(r.state(), RecordState::Unsealed);
        r.commit_crc = commit_mark(r.intent_crc);
        assert_eq!(r.state(), RecordState::Committed);
        r.commit_crc = abort_mark(r.intent_crc);
        assert_eq!(r.state(), RecordState::Aborted);
        assert_ne!(commit_mark(r.intent_crc), abort_mark(r.intent_crc));
    }

    #[test]
    fn cut_entries_roundtrip_and_reject_torn_slots() {
        let c = CutEntry { epoch: 7, cut_seq: 1234 };
        let b = c.to_bytes();
        assert_eq!(CutEntry::from_bytes(&b), Some(c));
        let mut torn = b;
        torn[9] ^= 0x55;
        assert_eq!(CutEntry::from_bytes(&torn), None);
        assert_eq!(CutEntry::from_bytes(&[0u8; CUT_ENTRY_SIZE]), None, "empty slot");
        // two epochs 16 apart share a table slot
        assert_eq!(cut_entry_off(0, 3), cut_entry_off(0, 19));
        assert_ne!(cut_entry_off(0, 3), cut_entry_off(0, 4));
    }

    #[test]
    fn header_roundtrips_and_ring_geometry() {
        let h = header_bytes(DEFAULT_CAPACITY);
        assert_eq!(decode_header(&h), Some(DEFAULT_CAPACITY));
        let mut bad = h;
        bad[0] ^= 1;
        assert_eq!(decode_header(&bad), None);
        assert_eq!(log_size(DEFAULT_CAPACITY), LOG_HEADER_SIZE + 1024 * RECORD_SIZE);
        // slots wrap at capacity
        assert_eq!(slot_off(0, 8, 3), slot_off(0, 8, 11));
        assert_eq!(slot_off(0, 8, 0), LOG_HEADER_SIZE as u64);
    }

    #[test]
    fn images_decode_container_headers() {
        let v = vec_image(&{
            let mut img = [0u8; IMAGE_SIZE];
            img[..8].copy_from_slice(&10u64.to_le_bytes());
            img[8..16].copy_from_slice(&3u64.to_le_bytes());
            img[16..].copy_from_slice(&4u64.to_le_bytes());
            img
        });
        assert_eq!(v, VecImage { data_off: 10, len: 3, cap: 4 });
        let m = map_image(&{
            let mut img = [0u8; IMAGE_SIZE];
            img[..8].copy_from_slice(&20u64.to_le_bytes());
            img[8..16].copy_from_slice(&8u64.to_le_bytes());
            img[16..].copy_from_slice(&5u64.to_le_bytes());
            img
        });
        assert_eq!(m, MapImage { table_off: 20, cap: 8, len: 5 });
    }
}

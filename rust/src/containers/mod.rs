//! Position-independent persistent containers.
//!
//! These are the rust analogue of using Boost.Container with Metall's
//! offset-pointer STL allocator (paper §3.2.3, §3.5): every internal
//! link is a **segment offset**, never a raw pointer, so a datastore can
//! be re-mapped at any base address in a later process. Each container
//! is "allocator-aware": it stores no allocator inside — methods take
//! the [`crate::alloc::SegmentAlloc`] explicitly, which also mirrors how
//! Metall's STL allocator rediscovers its manager through the segment
//! header (§4.4).

pub mod pvec;
pub mod phashmap;
pub mod pstring;
pub mod adjacency;

pub use adjacency::BankedAdjacency;
pub use phashmap::PHashMapU64;
pub use pstring::PString;
pub use pvec::PVec;

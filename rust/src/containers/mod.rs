//! Position-independent persistent containers, crash-atomic per
//! operation.
//!
//! These are the rust analogue of using Boost.Container with Metall's
//! offset-pointer STL allocator (paper §3.2.3, §3.5): every internal
//! link is a **segment offset**, never a raw pointer, so a datastore can
//! be re-mapped at any base address in a later process. Each container
//! is "allocator-aware": it stores no allocator inside — methods take
//! the [`crate::alloc::SegmentAlloc`] explicitly, which also mirrors how
//! Metall's STL allocator rediscovers its manager through the segment
//! header (§4.4).
//!
//! ## The op-log protocol (crash-consistent user data)
//!
//! Allocator *management* state recovers from the last committed
//! manifest epoch (Makalu-style split), but that alone leaves container
//! contents torn after a kill-9: a value written with `len` never
//! bumped, or a grow that retired the extent a recovered header still
//! points at. Every mutating container operation therefore routes
//! through a per-manager persistent **operation log** ([`oplog`]),
//! DGAP-style checksum-sealed:
//!
//! 1. **Allocate first.** Any new extent the op needs (`grow`'s bigger
//!    array, `insert`'s rehashed table) is allocated before anything is
//!    logged, so a crash can at worst leak it — never corrupt.
//! 2. **Intent before user bytes.** The op appends a 192-byte
//!    [`oplog::OpRecord`] — op kind, the header cell(s) it will publish
//!    with their old *and* new 24-byte images, the allocated and the
//!    to-be-freed extents — sealed by an intent checksum, via
//!    [`crate::alloc::SegmentAlloc::oplog_begin`].
//! 3. **Write, then publish.** Element/slot bytes land in space no
//!    reader traverses yet; the header image(s) named by the record are
//!    published last.
//! 4. **Commit seal, then retire.** [`oplog_commit`]
//!    (crate::alloc::SegmentAlloc::oplog_commit) seals the commit mark;
//!    only after it does the op `deallocate` the extent it replaced.
//!    An unsealed record's old extent is therefore always intact.
//!
//! Ring slots participate in the ordinary `DirtyChunkSet`/background
//! sync epochs, so the log is durable exactly with the data it
//! describes; each management epoch's consistent cut stamps the log's
//! cut table with the sequence horizon that epoch covers.
//!
//! ## Recovery contract
//!
//! `open_unclean` replays the newest-epoch log tail in sequence order
//! (see `recover_containers` in [`crate::alloc::manager`]): committed
//! records are kept — the extent each allocated is adopted into the
//! recovered allocator's bitsets (their *retired* extents are
//! deliberately leaked: a pre-cut reuse racing the epoch cut could make
//! that release free live data); unsealed records are rolled **forward**
//! (new images finished + commit-sealed, retired extent released — its
//! deallocate never ran, so nobody else can hold it) when the current
//! header bytes already match the new images, rolled **back** (old
//! images restored, half-keyed map slot cleared, abort-sealed, the
//! never-published allocation released) otherwise. A
//! `validate_containers()` pass — wired into `doctor` — then asserts
//! container invariants over every touched header: `len ≤ cap`, live
//! `data_off`/`table_off` extents large enough for `cap`, hash-table
//! key population matching `len`, and adjacency banks whose `nedges`
//! equals the sum of their per-vertex list lengths (no half-linked
//! rows).
//!
//! Scope: operations are crash-atomic **per container op** under the
//! containers' existing single-writer discipline (`PVec`/`PHashMap`
//! take `&self` but are not thread-safe for concurrent mutation;
//! [`BankedAdjacency`] serializes per bank). `PVec::set` overwrites in
//! place without logging (old bytes are gone by design), as do map
//! value overwrites larger than 24 bytes — both documented at the
//! method level.

pub mod oplog;
pub mod pvec;
pub mod phashmap;
pub mod pstring;
pub mod adjacency;

pub use adjacency::BankedAdjacency;
pub use phashmap::PHashMapU64;
pub use pstring::PString;
pub use pvec::PVec;

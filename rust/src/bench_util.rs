//! Tiny benchmark harness (the offline build image has no criterion).
//!
//! Provides warmup + repeated measurement with median/min/max reporting,
//! a paper-style table printer, and a JSONL sink so every bench emits both
//! the human-readable rows the paper reports and machine-readable records
//! under `bench_results/`.

use std::fs::{self, OpenOptions};
use std::io::Write as _;
use std::path::Path;
use std::time::Instant;

use crate::util::{human, jsonw::JsonObj};

/// One measured statistic set.
#[derive(Clone, Debug)]
pub struct Stats {
    pub samples: Vec<f64>, // seconds
}

impl Stats {
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        if n == 0 {
            return f64::NAN;
        }
        if n % 2 == 1 { s[n / 2] } else { 0.5 * (s[n / 2 - 1] + s[n / 2]) }
    }
    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }
    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(0.0, f64::max)
    }
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len().max(1) as f64
    }
}

/// Time `f` once, returning (seconds, result).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let t0 = Instant::now();
    let r = f();
    (t0.elapsed().as_secs_f64(), r)
}

/// Run `f` `warmup` times unmeasured then `iters` times measured.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    Stats { samples }
}

/// Fixed-width table printer that mimics the paper's result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        println!("\n== {title} ==");
        let line = |ws: &[usize]| {
            let mut s = String::from("+");
            for w in ws {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        println!("{}", line(&widths));
        let mut hdr = String::from("|");
        for (h, w) in self.headers.iter().zip(&widths) {
            hdr.push_str(&format!(" {h:<w$} |"));
        }
        println!("{hdr}");
        println!("{}", line(&widths));
        for row in &self.rows {
            let mut r = String::from("|");
            for (c, w) in row.iter().zip(&widths) {
                r.push_str(&format!(" {c:<w$} |"));
            }
            println!("{r}");
        }
        println!("{}", line(&widths));
        let _ = total;
    }
}

/// Append a JSON record to `bench_results/<bench>.jsonl`.
pub fn record(bench: &str, obj: JsonObj) {
    let dir = Path::new("bench_results");
    let _ = fs::create_dir_all(dir);
    let path = dir.join(format!("{bench}.jsonl"));
    if let Ok(mut f) = OpenOptions::new().create(true).append(true).open(&path) {
        let _ = writeln!(f, "{}", obj.finish());
    }
}

/// Convenience: format seconds + rate column pair.
pub fn time_and_rate(secs: f64, ops: u64) -> (String, String) {
    (human::duration(secs), human::rate(ops as f64 / secs))
}

/// Parse trailing `--key value` style args for bench binaries
/// (cargo bench passes `--bench`; ignore unknown flags gracefully).
pub struct BenchArgs {
    pairs: Vec<(String, String)>,
    pub bare: Vec<String>,
}

impl BenchArgs {
    pub fn parse() -> Self {
        let args: Vec<String> = std::env::args().skip(1).collect();
        Self::from_slice(&args)
    }

    /// Parse from an explicit argv slice (the CLI reuses this).
    pub fn from_slice(args: &[String]) -> Self {
        let mut pairs = vec![];
        let mut bare = vec![];
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    pairs.push((key.to_string(), args[i + 1].clone()));
                    i += 2;
                } else {
                    pairs.push((key.to_string(), String::from("true")));
                    i += 1;
                }
            } else {
                bare.push(a.clone());
                i += 1;
            }
        }
        Self { pairs, bare }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.pairs.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list of usizes (e.g. `--threads 1,2,4,8`).
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v.split(',').filter_map(|s| s.trim().parse().ok()).collect(),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.get(key).is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_median_odd_even() {
        let s = Stats { samples: vec![3.0, 1.0, 2.0] };
        assert_eq!(s.median(), 2.0);
        let s = Stats { samples: vec![4.0, 1.0, 2.0, 3.0] };
        assert_eq!(s.median(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.mean(), 2.5);
    }

    #[test]
    fn bench_runs_expected_iters() {
        let mut count = 0;
        let st = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(st.samples.len(), 5);
    }

    #[test]
    fn usize_list_parsing() {
        let a = BenchArgs::from_slice(&["--threads".into(), "1,2, 8".into()]);
        assert_eq!(a.get_usize_list("threads", &[4]), vec![1, 2, 8]);
        assert_eq!(a.get_usize_list("missing", &[4]), vec![4]);
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print("test");
    }
}

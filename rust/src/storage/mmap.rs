//! Thin wrappers over the raw memory-mapping system calls.
//!
//! Everything Metall does sits on four primitives (paper §2.2, §4.1):
//! *reserve* a large virtual-memory extent (`PROT_NONE` anonymous
//! mapping), *map* file ranges into it with `MAP_FIXED`, *sync* dirty
//! pages (`msync`), and *free* physical/file space (`madvise(MADV_REMOVE)`
//! / `fallocate(PUNCH_HOLE)`).

use std::fs::File;
use std::os::unix::io::AsRawFd;

use crate::error::{Error, Result};

/// System page size (cached).
pub fn page_size() -> usize {
    static PAGE: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *PAGE.get_or_init(|| unsafe { libc::sysconf(libc::_SC_PAGESIZE) as usize })
}

/// Protection mode for a mapping.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Prot {
    None,
    Read,
    ReadWrite,
}

impl Prot {
    fn flags(self) -> i32 {
        match self {
            Prot::None => libc::PROT_NONE,
            Prot::Read => libc::PROT_READ,
            Prot::ReadWrite => libc::PROT_READ | libc::PROT_WRITE,
        }
    }
}

/// Sharing mode for a file mapping.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Share {
    /// `MAP_SHARED`: the kernel writes dirty pages back to the file.
    Shared,
    /// `MAP_PRIVATE`: copy-on-write; dirty pages never reach the file
    /// unless *we* write them back (bs-mmap, paper §5.1).
    Private,
}

/// A reserved contiguous VM extent (anonymous `PROT_NONE` mapping).
///
/// Files are later mapped *into* this extent with `MAP_FIXED`, exploiting
/// Supermalloc's "VM is cheap, physical memory is dear" philosophy (§4).
/// Dropping unmaps the whole extent.
#[derive(Debug)]
pub struct VmReservation {
    base: *mut u8,
    len: usize,
}

// The reservation is an address range, not data; it is safe to hand
// between threads. Interior mutation happens through raw pointers whose
// safety is the segment layer's responsibility.
unsafe impl Send for VmReservation {}
unsafe impl Sync for VmReservation {}

impl VmReservation {
    /// Reserve `len` bytes of VM space (rounded up to page size).
    pub fn reserve(len: usize) -> Result<Self> {
        let len = crate::util::align_up(len.max(1), page_size());
        let p = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_NONE,
                libc::MAP_PRIVATE | libc::MAP_ANONYMOUS | libc::MAP_NORESERVE,
                -1,
                0,
            )
        };
        if p == libc::MAP_FAILED {
            return Err(Error::sys("mmap(reserve)"));
        }
        Ok(Self { base: p as *mut u8, len })
    }

    pub fn base(&self) -> *mut u8 {
        self.base
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Map `len` bytes of `file` starting at file offset `file_off` into
    /// this reservation at byte offset `at`, replacing the reservation
    /// pages (`MAP_FIXED`).
    pub fn map_file(
        &self,
        at: usize,
        file: &File,
        file_off: u64,
        len: usize,
        prot: Prot,
        share: Share,
        populate: bool,
    ) -> Result<()> {
        assert!(at + len <= self.len, "mapping outside reservation");
        assert_eq!(at % page_size(), 0);
        let mut flags = match share {
            Share::Shared => libc::MAP_SHARED,
            Share::Private => libc::MAP_PRIVATE,
        } | libc::MAP_FIXED;
        if populate {
            flags |= libc::MAP_POPULATE;
        }
        crate::storage::faults::check(crate::storage::faults::Site::Mmap)
            .map_err(|source| Error::Sys { call: "mmap(MAP_FIXED file)", source })?;
        let p = unsafe {
            libc::mmap(
                self.base.add(at) as *mut libc::c_void,
                len,
                prot.flags(),
                flags,
                file.as_raw_fd(),
                file_off as libc::off_t,
            )
        };
        if p == libc::MAP_FAILED {
            return Err(Error::sys("mmap(MAP_FIXED file)"));
        }
        Ok(())
    }

    /// Return a sub-range of the reservation back to `PROT_NONE` reserved
    /// state (used when unmapping a file region without releasing VM).
    pub fn re_reserve(&self, at: usize, len: usize) -> Result<()> {
        assert!(at + len <= self.len);
        let p = unsafe {
            libc::mmap(
                self.base.add(at) as *mut libc::c_void,
                len,
                libc::PROT_NONE,
                libc::MAP_PRIVATE | libc::MAP_ANONYMOUS | libc::MAP_NORESERVE | libc::MAP_FIXED,
                -1,
                0,
            )
        };
        if p == libc::MAP_FAILED {
            return Err(Error::sys("mmap(re-reserve)"));
        }
        Ok(())
    }
}

impl Drop for VmReservation {
    fn drop(&mut self) {
        unsafe {
            libc::munmap(self.base as *mut libc::c_void, self.len);
        }
    }
}

/// `msync(MS_SYNC)` a range: flush dirty pages of a shared mapping to the
/// backing file and wait for completion.
pub fn msync(addr: *mut u8, len: usize) -> Result<()> {
    crate::storage::faults::check(crate::storage::faults::Site::Msync)
        .map_err(|source| Error::Sys { call: "msync", source })?;
    let rc = unsafe { libc::msync(addr as *mut libc::c_void, len, libc::MS_SYNC) };
    if rc != 0 {
        return Err(Error::sys("msync"));
    }
    Ok(())
}

/// `madvise(MADV_DONTNEED)`: drop the range's pages from DRAM. For a
/// shared file mapping the page cache stays coherent (data is not lost);
/// for a private mapping dirty pages are discarded.
pub fn madvise_dontneed(addr: *mut u8, len: usize) -> Result<()> {
    let rc = unsafe { libc::madvise(addr as *mut libc::c_void, len, libc::MADV_DONTNEED) };
    if rc != 0 {
        return Err(Error::sys("madvise(MADV_DONTNEED)"));
    }
    Ok(())
}

/// `madvise(MADV_REMOVE)`: free the range in DRAM *and* punch the
/// corresponding hole in the backing file (Metall's chunk-granular
/// "free file space" operation, §4.1).
pub fn madvise_remove(addr: *mut u8, len: usize) -> Result<()> {
    let rc = unsafe { libc::madvise(addr as *mut libc::c_void, len, libc::MADV_REMOVE) };
    if rc != 0 {
        return Err(Error::sys("madvise(MADV_REMOVE)"));
    }
    Ok(())
}

/// `MPOL_PREFERRED`: allocate on the given node when possible, silently
/// fall back to other nodes under memory pressure — the graceful flavour
/// of `mbind` (`MPOL_BIND` can OOM a full node; placement is an
/// optimization here, never a correctness requirement).
const MPOL_PREFERRED: libc::c_long = 1;

/// `MPOL_MF_MOVE`: migrate pages already resident in the range that do
/// not conform to the policy. Needed for recycled extents — pages can
/// survive a free (page-cache residency under `MADV_DONTNEED`, the
/// `free_file_space: false` configs) still placed by their previous
/// owner, and neither a new policy alone nor writing to them would move
/// them.
const MPOL_MF_MOVE: libc::c_long = 1 << 1;

/// Best-effort NUMA bind: future page faults in `[addr, addr+len)` prefer
/// `node`, and pages already resident elsewhere are migrated
/// (`MPOL_MF_MOVE`, exclusively-mapped pages only — the kernel's rule).
/// Returns whether the policy took. Every failure mode of the raw
/// `mbind(2)` syscall (glibc does not export a wrapper) degrades to the
/// kernel's default first-touch policy instead of erroring: `ENOSYS` on
/// non-NUMA kernels, `EINVAL` when the node does not exist, `EPERM` in
/// locked-down containers.
pub fn mbind_preferred(addr: *mut u8, len: usize, node: usize) -> bool {
    let mask_bits = 8 * std::mem::size_of::<libc::c_ulong>();
    if node >= mask_bits {
        return false;
    }
    let nodemask: libc::c_ulong = 1 << node;
    let rc = unsafe {
        libc::syscall(
            libc::SYS_mbind,
            addr as *mut libc::c_void,
            len as libc::c_ulong,
            MPOL_PREFERRED,
            &nodemask as *const libc::c_ulong,
            mask_bits as libc::c_ulong,
            MPOL_MF_MOVE,
        )
    };
    rc == 0
}

/// `fallocate(FALLOC_FL_PUNCH_HOLE)` directly on a file.
pub fn punch_hole(file: &File, offset: u64, len: u64) -> Result<()> {
    let rc = unsafe {
        libc::fallocate(
            file.as_raw_fd(),
            libc::FALLOC_FL_PUNCH_HOLE | libc::FALLOC_FL_KEEP_SIZE,
            offset as libc::off_t,
            len as libc::off_t,
        )
    };
    if rc != 0 {
        return Err(Error::sys("fallocate(PUNCH_HOLE)"));
    }
    Ok(())
}

/// Number of 512-byte blocks actually allocated to `file` (how much
/// *file space* is in use — observable effect of `MADV_REMOVE`).
pub fn allocated_blocks(file: &File) -> Result<u64> {
    let mut st: libc::stat = unsafe { std::mem::zeroed() };
    let rc = unsafe { libc::fstat(file.as_raw_fd(), &mut st) };
    if rc != 0 {
        return Err(Error::sys("fstat"));
    }
    Ok(st.st_blocks as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    use crate::util::tmp::TempDir;

    fn tmpfile(len: usize) -> (TempDir, File) {
        let dir = TempDir::new("mmaptest");
        let path = dir.join("f");
        let mut f = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(&path)
            .unwrap();
        f.write_all(&vec![0u8; len]).unwrap();
        f.sync_all().unwrap();
        (dir, f)
    }

    #[test]
    fn reserve_and_map_roundtrip() {
        let ps = page_size();
        let (_d, f) = tmpfile(4 * ps);
        let vm = VmReservation::reserve(16 * ps).unwrap();
        vm.map_file(0, &f, 0, 4 * ps, Prot::ReadWrite, Share::Shared, false).unwrap();
        unsafe {
            *vm.base() = 0xAB;
            *vm.base().add(4 * ps - 1) = 0xCD;
            assert_eq!(*vm.base(), 0xAB);
        }
        msync(vm.base(), 4 * ps).unwrap();
        // read back through the file
        let data = {
            use std::io::{Read, Seek};
            let mut f2 = f.try_clone().unwrap();
            f2.seek(std::io::SeekFrom::Start(0)).unwrap();
            let mut buf = vec![0u8; 4 * ps];
            f2.read_exact(&mut buf).unwrap();
            buf
        };
        assert_eq!(data[0], 0xAB);
        assert_eq!(data[4 * ps - 1], 0xCD);
    }

    #[test]
    fn private_mapping_does_not_write_back() {
        let ps = page_size();
        let (_d, f) = tmpfile(ps);
        let vm = VmReservation::reserve(ps).unwrap();
        vm.map_file(0, &f, 0, ps, Prot::ReadWrite, Share::Private, false).unwrap();
        unsafe {
            *vm.base() = 0x77;
        }
        // msync on private mapping is a no-op for the file
        let _ = msync(vm.base(), ps);
        use std::io::{Read, Seek};
        let mut f2 = f.try_clone().unwrap();
        f2.seek(std::io::SeekFrom::Start(0)).unwrap();
        let mut b = [0u8; 1];
        f2.read_exact(&mut b).unwrap();
        assert_eq!(b[0], 0, "private write must not reach the file");
    }

    #[test]
    fn madv_remove_frees_file_space() {
        let ps = page_size();
        let len = 256 * ps;
        let (_d, f) = tmpfile(len);
        let vm = VmReservation::reserve(len).unwrap();
        vm.map_file(0, &f, 0, len, Prot::ReadWrite, Share::Shared, false).unwrap();
        unsafe {
            for i in 0..len {
                *vm.base().add(i) = 0xFF;
            }
        }
        msync(vm.base(), len).unwrap();
        let before = allocated_blocks(&f).unwrap();
        assert!(before > 0);
        madvise_remove(vm.base(), len).unwrap();
        let after = allocated_blocks(&f).unwrap();
        assert!(after < before, "MADV_REMOVE should punch file holes ({before} -> {after})");
        // data now reads back as zeros
        unsafe {
            assert_eq!(*vm.base(), 0);
        }
    }

    #[test]
    fn re_reserve_releases_mapping() {
        let ps = page_size();
        let (_d, f) = tmpfile(ps);
        let vm = VmReservation::reserve(2 * ps).unwrap();
        vm.map_file(ps, &f, 0, ps, Prot::ReadWrite, Share::Shared, false).unwrap();
        unsafe {
            *vm.base().add(ps) = 1;
        }
        vm.re_reserve(ps, ps).unwrap();
        // further mapping over the same spot works
        vm.map_file(ps, &f, 0, ps, Prot::Read, Share::Shared, false).unwrap();
        unsafe {
            assert_eq!(*vm.base().add(ps), 1);
        }
    }

    #[test]
    fn mbind_preferred_degrades_gracefully() {
        let ps = page_size();
        let (_d, f) = tmpfile(4 * ps);
        let vm = VmReservation::reserve(4 * ps).unwrap();
        vm.map_file(0, &f, 0, 4 * ps, Prot::ReadWrite, Share::Shared, false).unwrap();
        // node 0 exists everywhere NUMA does; on non-NUMA kernels the call
        // reports false instead of failing — either way the mapping stays
        // fully usable
        let bound = mbind_preferred(vm.base(), 4 * ps, 0);
        unsafe {
            *vm.base() = 0x5A;
            assert_eq!(*vm.base(), 0x5A);
        }
        // an impossible node is always a graceful no
        assert!(!mbind_preferred(vm.base(), ps, 4096));
        let _ = bound;
    }

    #[test]
    fn punch_hole_direct() {
        let ps = page_size();
        let (_d, f) = tmpfile(64 * ps);
        let before = allocated_blocks(&f).unwrap();
        punch_hole(&f, 0, (64 * ps) as u64).unwrap();
        assert!(allocated_blocks(&f).unwrap() <= before);
    }
}

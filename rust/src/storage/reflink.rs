//! Reflink-accelerated copies for snapshots (paper §3.4).
//!
//! "With reflink, a copied file shares the same data blocks with the
//! existing file; data blocks are copied only when they are modified
//! (copy-on-write). … In case reflink is not supported by the underlying
//! filesystem, Metall automatically falls back to a standard copy."
//!
//! We issue `ioctl(FICLONE)` and fall back to `std::fs::copy` on
//! `EOPNOTSUPP` / `EINVAL` / `EXDEV` / `ENOTTY` (the testbed's ext4 takes
//! the fallback branch; XFS/Btrfs/APFS would take the clone branch).

use std::fs::{File, OpenOptions};
use std::os::unix::io::AsRawFd;
use std::path::Path;

use crate::error::{Error, Result};
use crate::storage::faults;

/// `FICLONE` ioctl request code (linux/fs.h: `_IOW(0x94, 9, int)`).
const FICLONE: libc::c_ulong = 0x4004_9409;

/// `FICLONERANGE` ioctl request code
/// (linux/fs.h: `_IOW(0x94, 13, struct file_clone_range)`).
const FICLONERANGE: libc::c_ulong = 0x4020_940D;

/// linux/fs.h `struct file_clone_range`.
#[repr(C)]
struct FileCloneRange {
    src_fd: i64,
    src_offset: u64,
    src_length: u64,
    dest_offset: u64,
}

/// How a copy was performed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CopyMethod {
    Reflink,
    Fallback,
}

/// Copy `src` to `dst`, attempting a reflink clone first.
pub fn copy_file(src: &Path, dst: &Path) -> Result<CopyMethod> {
    faults::check(faults::Site::Reflink).map_err(|e| Error::io(dst, e))?;
    let sf = File::open(src).map_err(|e| Error::io(src, e))?;
    let df = OpenOptions::new()
        .write(true)
        .create(true)
        .truncate(true)
        .open(dst)
        .map_err(|e| Error::io(dst, e))?;
    let rc = unsafe { libc::ioctl(df.as_raw_fd(), FICLONE, sf.as_raw_fd()) };
    if rc == 0 {
        return Ok(CopyMethod::Reflink);
    }
    let errno = std::io::Error::last_os_error().raw_os_error().unwrap_or(0);
    match errno {
        libc::EOPNOTSUPP | libc::EINVAL | libc::EXDEV | libc::ENOTTY | libc::ENOSYS => {
            drop(df);
            std::fs::copy(src, dst).map_err(|e| Error::io(dst, e))?;
            Ok(CopyMethod::Fallback)
        }
        _ => Err(Error::sys("ioctl(FICLONE)")),
    }
}

/// Clone `len` bytes of `src` at `src_off` into `dst` at `dst_off`,
/// attempting a block-sharing `FICLONERANGE` first and falling back to
/// a `pread`/`pwrite` copy where the filesystem cannot reflink (or the
/// range is not block-aligned). The epoch-side chunk preservation
/// ([`crate::alloc::readers`]) is the caller: chunk-sized, chunk-aligned
/// ranges, so the clone path is eligible wherever the fs supports it.
pub fn clone_file_range(
    src: &File,
    src_off: u64,
    len: u64,
    dst: &File,
    dst_off: u64,
) -> Result<CopyMethod> {
    faults::check(faults::Site::Reflink)
        .map_err(|source| Error::Sys { call: "clone_file_range", source })?;
    let arg = FileCloneRange {
        src_fd: src.as_raw_fd() as i64,
        src_offset: src_off,
        src_length: len,
        dest_offset: dst_off,
    };
    let rc = unsafe { libc::ioctl(dst.as_raw_fd(), FICLONERANGE, &arg) };
    if rc == 0 {
        return Ok(CopyMethod::Reflink);
    }
    let errno = std::io::Error::last_os_error().raw_os_error().unwrap_or(0);
    match errno {
        libc::EOPNOTSUPP | libc::EINVAL | libc::EXDEV | libc::ENOTTY | libc::ENOSYS
        | libc::EBADF | libc::EPERM => {
            use std::os::unix::fs::FileExt;
            let mut buf = vec![0u8; (len as usize).min(1 << 20)];
            let mut done = 0u64;
            while done < len {
                let want = ((len - done) as usize).min(buf.len());
                // short reads past EOF come back zero-filled: the live
                // backing file is always chunk-granular here, but a hole
                // or race must not produce a short side copy
                let got = src
                    .read_at(&mut buf[..want], src_off + done)
                    .map_err(|e| Error::Sys { call: "pread(clone fallback)", source: e })?;
                if got == 0 {
                    buf[..want].fill(0);
                    dst.write_all_at(&buf[..want], dst_off + done)
                        .map_err(|e| Error::Sys { call: "pwrite(clone fallback)", source: e })?;
                    done += want as u64;
                } else {
                    dst.write_all_at(&buf[..got], dst_off + done)
                        .map_err(|e| Error::Sys { call: "pwrite(clone fallback)", source: e })?;
                    done += got as u64;
                }
            }
            Ok(CopyMethod::Fallback)
        }
        _ => Err(Error::sys("ioctl(FICLONERANGE)")),
    }
}

/// Recursively copy a directory tree (the Metall datastore layout is a
/// directory; §3.6 "one can easily duplicate or delete a Metall datastore,
/// even using normal file copy or remove commands").
///
/// Returns `(files_copied, bytes, method_of_last_file)`; the method is
/// uniform in practice since all files live on one filesystem.
pub fn copy_dir(src: &Path, dst: &Path) -> Result<(usize, u64, CopyMethod)> {
    std::fs::create_dir_all(dst).map_err(|e| Error::io(dst, e))?;
    let mut files = 0usize;
    let mut bytes = 0u64;
    let mut method = CopyMethod::Fallback;
    for entry in std::fs::read_dir(src).map_err(|e| Error::io(src, e))? {
        let entry = entry.map_err(|e| Error::io(src, e))?;
        let ty = entry.file_type().map_err(|e| Error::io(entry.path(), e))?;
        let from = entry.path();
        let to = dst.join(entry.file_name());
        if ty.is_dir() {
            let (f, b, m) = copy_dir(&from, &to)?;
            files += f;
            bytes += b;
            method = m;
        } else if ty.is_file() {
            method = copy_file(&from, &to)?;
            files += 1;
            bytes += entry.metadata().map_err(|e| Error::io(&from, e))?.len();
        }
    }
    Ok((files, bytes, method))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    #[test]
    fn copy_file_roundtrip() {
        let d = TempDir::new("reflink");
        let src = d.join("a");
        let dst = d.join("b");
        std::fs::write(&src, b"snapshot-me").unwrap();
        let method = copy_file(&src, &dst).unwrap();
        assert_eq!(std::fs::read(&dst).unwrap(), b"snapshot-me");
        // On this testbed (ext4) we expect the fallback branch, but the
        // result must be correct either way.
        let _ = method;
    }

    #[test]
    fn copy_file_truncates_existing_dst() {
        let d = TempDir::new("reflink2");
        let src = d.join("a");
        let dst = d.join("b");
        std::fs::write(&src, b"ab").unwrap();
        std::fs::write(&dst, b"longer-preexisting-content").unwrap();
        copy_file(&src, &dst).unwrap();
        assert_eq!(std::fs::read(&dst).unwrap(), b"ab");
    }

    #[test]
    fn clone_range_roundtrip() {
        let d = TempDir::new("reflink-range");
        let src = d.join("src");
        let dst = d.join("dst");
        let body: Vec<u8> = (0..8192u32).map(|i| (i % 251) as u8).collect();
        std::fs::write(&src, &body).unwrap();
        let sf = File::open(&src).unwrap();
        let df = OpenOptions::new().read(true).write(true).create(true).open(&dst).unwrap();
        clone_file_range(&sf, 4096, 4096, &df, 0).unwrap();
        assert_eq!(std::fs::read(&dst).unwrap(), &body[4096..8192]);
    }

    #[test]
    fn clone_range_past_eof_zero_fills() {
        let d = TempDir::new("reflink-range-eof");
        let src = d.join("src");
        let dst = d.join("dst");
        std::fs::write(&src, b"abc").unwrap();
        let sf = File::open(&src).unwrap();
        let df = OpenOptions::new().read(true).write(true).create(true).open(&dst).unwrap();
        // ext4 fallback path: reading past EOF must still produce a
        // full-length, zero-padded copy
        clone_file_range(&sf, 0, 16, &df, 0).unwrap();
        let got = std::fs::read(&dst).unwrap();
        assert_eq!(got.len(), 16);
        assert_eq!(&got[0..3], b"abc");
        assert!(got[3..].iter().all(|&b| b == 0));
    }

    #[test]
    fn copy_dir_recursive() {
        let d = TempDir::new("reflink3");
        let src = d.join("store");
        std::fs::create_dir_all(src.join("sub")).unwrap();
        std::fs::write(src.join("x"), b"1").unwrap();
        std::fs::write(src.join("sub/y"), b"22").unwrap();
        let dst = d.join("snap");
        let (files, bytes, _m) = copy_dir(&src, &dst).unwrap();
        assert_eq!(files, 2);
        assert_eq!(bytes, 3);
        assert_eq!(std::fs::read(dst.join("x")).unwrap(), b"1");
        assert_eq!(std::fs::read(dst.join("sub/y")).unwrap(), b"22");
    }
}

//! Batch-synchronized mmap — the paper's §5 contribution.
//!
//! A `MAP_PRIVATE` file mapping never writes back to the file on its own;
//! [`BsMsync`] implements the *user-level msync* that (1) finds dirty
//! pages via `/proc/self/pagemap` (§5.1's bit-61/62/63 predicate), (2)
//! coalesces consecutive dirty pages into runs, and (3) writes the runs
//! back with parallel flusher threads, one backing file per worker at a
//! time (§5.2), using `pwrite`.
//!
//! After a run is written back we *re-map* it from the backing file: the
//! pages return to clean file-backed state (identical content, zero
//! copies thanks to the page cache), so the next scan only sees genuinely
//! new writes. This keeps all state local to the mapping — no dependence
//! on the process-global soft-dirty mechanism — so multiple datastores in
//! one process do not interfere.

use std::ops::Range;

use crate::error::Result;
use crate::storage::mmap::page_size;
use crate::storage::pagemap::Pagemap;
use crate::storage::segment::SegmentStorage;

/// Statistics from one user-level msync invocation.
#[derive(Clone, Copy, Debug, Default)]
pub struct FlushStats {
    pub dirty_pages: usize,
    pub runs: usize,
    pub bytes_written: u64,
    pub files_touched: usize,
}

impl FlushStats {
    pub fn merge(&mut self, o: &FlushStats) {
        self.dirty_pages += o.dirty_pages;
        self.runs += o.runs;
        self.bytes_written += o.bytes_written;
        self.files_touched += o.files_touched;
    }
}

/// User-level msync engine for a [`SegmentStorage`] opened in
/// `Share::Private` mode.
pub struct BsMsync {
    /// Max number of concurrent flusher threads.
    pub max_flushers: usize,
}

impl Default for BsMsync {
    fn default() -> Self {
        Self::new()
    }
}

impl BsMsync {
    pub fn new() -> Self {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self { max_flushers: cores.max(2) }
    }

    /// Find dirty runs of the segment (page-index ranges), coalesced.
    pub fn dirty_runs(&self, seg: &SegmentStorage) -> Result<Vec<Range<usize>>> {
        let ps = page_size();
        let npages = seg.mapped_len() / ps;
        if npages == 0 {
            return Ok(vec![]);
        }
        let mut pm = Pagemap::open()?;
        pm.dirty_runs(seg.base() as usize, npages, false)
    }

    /// Write every dirty run back to its backing file, in parallel across
    /// files, then re-map the flushed ranges clean. Returns statistics.
    pub fn msync(&mut self, seg: &SegmentStorage) -> Result<FlushStats> {
        let ps = page_size();
        let runs = self.dirty_runs(seg)?;
        if runs.is_empty() {
            return Ok(FlushStats::default());
        }

        // Split runs at file boundaries so each piece belongs to one file.
        let fsz_pages = seg.file_size() / ps;
        let mut per_file: Vec<Vec<Range<usize>>> = vec![Vec::new(); seg.num_files()];
        let mut dirty_pages = 0usize;
        for r in &runs {
            dirty_pages += r.len();
            let mut start = r.start;
            while start < r.end {
                let file_idx = start / fsz_pages;
                let file_end_page = (file_idx + 1) * fsz_pages;
                let end = r.end.min(file_end_page);
                per_file[file_idx].push(start..end);
                start = end;
            }
        }

        // Per-file write-back on the shared flusher pool (one job per
        // backing file, worker count capped at `max_flushers`).
        let outcomes = crate::util::parallel_jobs_capped(
            per_file.len(),
            self.max_flushers,
            |fi| -> Result<(u64, bool)> {
                let file_runs = &per_file[fi];
                if file_runs.is_empty() {
                    return Ok((0, false));
                }
                let mut bytes = 0u64;
                for r in file_runs {
                    let off = r.start * ps;
                    let len = r.len() * ps;
                    let (file_idx, file_off) = seg.locate(off);
                    debug_assert_eq!(file_idx, fi);
                    // Safety: the run lies inside the mapped extent; the
                    // application is quiescent during an explicit msync
                    // (paper §5 semantics).
                    let data = unsafe { seg.slice(off, len) };
                    seg.pwrite_file(file_idx, file_off, data)?;
                    bytes += len as u64;
                }
                Ok((bytes, true))
            },
        );
        let mut bytes_written = 0u64;
        let mut files_touched = 0usize;
        for outcome in outcomes {
            let (b, touched) = outcome?;
            bytes_written += b;
            files_touched += usize::from(touched);
        }

        // Re-map flushed runs clean (content is now identical in the file).
        for r in &runs {
            seg.remap_range(r.start * ps, r.len() * ps)?;
        }

        Ok(FlushStats { dirty_pages, runs: runs.len(), bytes_written, files_touched })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::segment::{SegmentOptions, SegmentStorage};
    use crate::util::tmp::TempDir;

    fn private_seg(dir: &std::path::Path, nbytes: usize) -> SegmentStorage {
        let opts = SegmentOptions::default()
            .with_file_size(256 * 1024)
            .with_vm_reserve(64 << 20)
            .private_mode();
        let seg = SegmentStorage::create(dir, opts).unwrap();
        seg.extend_to(nbytes).unwrap();
        seg
    }

    fn read_file(path: &std::path::Path) -> Vec<u8> {
        std::fs::read(path).unwrap()
    }

    #[test]
    fn private_writes_reach_file_only_after_user_msync() {
        let d = TempDir::new("bsm");
        let dir = d.join("s");
        let seg = private_seg(&dir, 512 * 1024); // 2 files
        unsafe {
            seg.slice_mut(100, 5).copy_from_slice(b"hello");
            seg.slice_mut(300 * 1024, 5).copy_from_slice(b"world");
        }
        let f0 = dir.join("chunk-000000");
        assert_eq!(&read_file(&f0)[100..105], &[0; 5], "no kernel write-back");

        let mut bs = BsMsync::new();
        let st = bs.msync(&seg).unwrap();
        assert!(st.dirty_pages >= 2);
        assert_eq!(st.files_touched, 2);
        assert_eq!(&read_file(&f0)[100..105], b"hello");
        let f1 = dir.join("chunk-000001");
        let off = 300 * 1024 - 256 * 1024;
        assert_eq!(&read_file(&f1)[off..off + 5], b"world");
        // mapping still reads the same data after the clean re-map
        unsafe {
            assert_eq!(seg.slice(100, 5), b"hello");
            assert_eq!(seg.slice(300 * 1024, 5), b"world");
        }
    }

    #[test]
    fn second_msync_flushes_only_new_writes() {
        let d = TempDir::new("bsm2");
        let seg = private_seg(&d.join("s"), 256 * 1024);
        unsafe {
            seg.slice_mut(0, 4).copy_from_slice(b"aaaa");
        }
        let mut bs = BsMsync::new();
        let st1 = bs.msync(&seg).unwrap();
        assert!(st1.dirty_pages >= 1);

        // nothing new → nothing flushed
        let st2 = bs.msync(&seg).unwrap();
        assert_eq!(st2.dirty_pages, 0);
        assert_eq!(st2.bytes_written, 0);

        unsafe {
            seg.slice_mut(8192, 4).copy_from_slice(b"bbbb");
        }
        let st3 = bs.msync(&seg).unwrap();
        assert_eq!(st3.dirty_pages, 1, "only the newly dirtied page");
        // and the earlier data is still intact in file + mapping
        unsafe {
            assert_eq!(seg.slice(0, 4), b"aaaa");
        }
    }

    #[test]
    fn runs_are_coalesced() {
        let d = TempDir::new("bsm3");
        let seg = private_seg(&d.join("s"), 256 * 1024);
        let ps = page_size();
        // dirty pages 2,3,4 and 10
        unsafe {
            for p in [2usize, 3, 4, 10] {
                seg.slice_mut(p * ps, 1)[0] = 1;
            }
        }
        let bs = BsMsync::new();
        let runs = bs.dirty_runs(&seg).unwrap();
        assert_eq!(runs, vec![2..5, 10..11]);
    }

    #[test]
    fn flushed_data_survives_reopen_shared() {
        let d = TempDir::new("bsm4");
        let dir = d.join("s");
        {
            let seg = private_seg(&dir, 256 * 1024);
            unsafe {
                seg.slice_mut(4096, 7).copy_from_slice(b"persist");
            }
            BsMsync::new().msync(&seg).unwrap();
        }
        let opts = SegmentOptions::default()
            .with_file_size(256 * 1024)
            .with_vm_reserve(64 << 20)
            .read_only();
        let seg = SegmentStorage::open(&dir, opts).unwrap();
        unsafe {
            assert_eq!(seg.slice(4096, 7), b"persist");
        }
    }

    #[test]
    fn heavy_random_writes_roundtrip() {
        use crate::util::rng::Xoshiro256ss;
        let d = TempDir::new("bsm5");
        let dir = d.join("s");
        let nbytes = 1 << 20; // 4 files
        let mut model = vec![0u8; nbytes];
        {
            let seg = private_seg(&dir, nbytes);
            let mut rng = Xoshiro256ss::new(99);
            let mut bs = BsMsync::new();
            for round in 0..3 {
                for _ in 0..200 {
                    let off = rng.gen_range(nbytes as u64 - 8) as usize;
                    let val = rng.next_u64().to_le_bytes();
                    model[off..off + 8].copy_from_slice(&val);
                    unsafe {
                        seg.slice_mut(off, 8).copy_from_slice(&val);
                    }
                }
                let st = bs.msync(&seg).unwrap();
                assert!(st.dirty_pages > 0, "round {round} flushed nothing");
            }
        }
        let opts = SegmentOptions::default()
            .with_file_size(256 * 1024)
            .with_vm_reserve(64 << 20)
            .read_only();
        let seg = SegmentStorage::open(&dir, opts).unwrap();
        unsafe {
            assert_eq!(seg.slice(0, nbytes), &model[..], "file state == write model");
        }
    }
}

//! `/proc/self/pagemap` scanning — the dirty-page detector behind bs-mmap
//! (paper §5.1).
//!
//! The paper: "In the case of a private mapping, a page is no longer
//! file-backed once it becomes dirty; however, its status is either
//! *present* or *swapped*. Hence, a dirty page of a `MAP_PRIVATE` region
//! can be identified by checking if bit number 61 of its pagemap entry is
//! zero and the logical OR of bits 62 and 63 equals one."
//!
//! We additionally use the *soft-dirty* bit (55) together with
//! `/proc/self/clear_refs` so that pages already written back by a
//! previous user-level msync are not flushed again (an incremental
//! refinement the paper's batching implies).

use std::fs::File;
use std::io::{Read, Seek, SeekFrom, Write};
use std::ops::Range;

use crate::error::{Error, Result};
use crate::storage::mmap::page_size;

const PM_PRESENT: u64 = 1 << 63;
const PM_SWAPPED: u64 = 1 << 62;
const PM_FILE_SHARED: u64 = 1 << 61;
const PM_SOFT_DIRTY: u64 = 1 << 55;

/// Batched reader over the process's pagemap.
pub struct Pagemap {
    file: File,
}

impl Pagemap {
    pub fn open() -> Result<Self> {
        let file = File::open("/proc/self/pagemap")
            .map_err(|e| Error::io("/proc/self/pagemap", e))?;
        Ok(Self { file })
    }

    /// Read raw pagemap entries for `npages` pages starting at `addr`
    /// (page aligned).
    pub fn entries(&mut self, addr: usize, npages: usize) -> Result<Vec<u64>> {
        let ps = page_size();
        debug_assert_eq!(addr % ps, 0);
        let vpn = addr / ps;
        self.file
            .seek(SeekFrom::Start((vpn * 8) as u64))
            .map_err(|e| Error::io("/proc/self/pagemap", e))?;
        let mut buf = vec![0u8; npages * 8];
        self.file
            .read_exact(&mut buf)
            .map_err(|e| Error::io("/proc/self/pagemap", e))?;
        Ok(buf
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Indices (relative to `addr`) of pages of a `MAP_PRIVATE` file
    /// mapping that hold unwritten-back modifications.
    ///
    /// `soft_only` restricts detection to pages written since the last
    /// [`clear_soft_dirty`] call; used after the first write-back.
    pub fn dirty_pages(
        &mut self,
        addr: usize,
        npages: usize,
        soft_only: bool,
    ) -> Result<Vec<usize>> {
        let entries = self.entries(addr, npages)?;
        Ok(entries
            .iter()
            .enumerate()
            .filter(|(_, &e)| is_private_dirty(e) && (!soft_only || e & PM_SOFT_DIRTY != 0))
            .map(|(i, _)| i)
            .collect())
    }

    /// Like [`Self::dirty_pages`] but already coalesced into maximal runs
    /// of consecutive pages (paper §5.2: "writes back dirty pages in
    /// consecutive chunks when possible rather than page-by-page").
    pub fn dirty_runs(
        &mut self,
        addr: usize,
        npages: usize,
        soft_only: bool,
    ) -> Result<Vec<Range<usize>>> {
        let pages = self.dirty_pages(addr, npages, soft_only)?;
        Ok(coalesce(&pages))
    }
}

/// The paper's §5.1 dirty predicate for private mappings.
#[inline]
pub fn is_private_dirty(entry: u64) -> bool {
    entry & PM_FILE_SHARED == 0 && entry & (PM_PRESENT | PM_SWAPPED) != 0
}

/// Coalesce sorted page indices into maximal consecutive runs.
pub fn coalesce(pages: &[usize]) -> Vec<Range<usize>> {
    let mut runs: Vec<Range<usize>> = Vec::new();
    for &p in pages {
        match runs.last_mut() {
            Some(r) if r.end == p => r.end = p + 1,
            _ => runs.push(p..p + 1),
        }
    }
    runs
}

/// NUMA node of `npages` pages starting at `addr` (page aligned), via
/// `move_pages(2)` in query mode (a NULL `nodes` argument asks instead of
/// moves). Each entry is the node id (≥ 0) or a negative errno — notably
/// `-ENOENT` for pages not faulted in yet. Returns `None` when the kernel
/// cannot answer at all (non-NUMA builds, seccomp'd containers): the
/// caller degrades to recorded placement, the same graceful path the
/// binding side takes.
pub fn page_nodes(addr: usize, npages: usize) -> Option<Vec<i32>> {
    if npages == 0 {
        return Some(Vec::new());
    }
    let ps = page_size();
    debug_assert_eq!(addr % ps, 0);
    let pages: Vec<*const libc::c_void> =
        (0..npages).map(|i| (addr + i * ps) as *const libc::c_void).collect();
    let mut status = vec![i32::MIN; npages];
    let rc = unsafe {
        libc::syscall(
            libc::SYS_move_pages,
            0 as libc::c_long, // self
            npages as libc::c_ulong,
            pages.as_ptr(),
            std::ptr::null::<libc::c_int>(), // query, don't move
            status.as_mut_ptr(),
            0 as libc::c_long,
        )
    };
    if rc != 0 {
        return None;
    }
    Some(status)
}

/// Whether [`page_nodes`] works here (probed once on a present anonymous
/// page; placement introspection falls back to recorded birth nodes when
/// it does not).
pub fn page_node_query_supported() -> bool {
    static SUPPORTED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *SUPPORTED.get_or_init(|| {
        let ps = page_size();
        let p = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                ps,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_PRIVATE | libc::MAP_ANONYMOUS,
                -1,
                0,
            )
        };
        if p == libc::MAP_FAILED {
            return false;
        }
        unsafe { *(p as *mut u8) = 1 };
        let ok = matches!(page_nodes(p as usize, 1), Some(v) if v[0] >= 0);
        unsafe { libc::munmap(p, ps) };
        ok
    })
}

/// Whether this kernel actually tracks soft-dirty (CONFIG_MEM_SOFT_DIRTY).
/// Some kernels (including this testbed's) only have
/// `CONFIG_HAVE_ARCH_SOFT_DIRTY`; bit 55 then never gets set. bs-mmap
/// therefore does **not** rely on soft-dirty: it re-maps flushed runs
/// clean instead (see `bsmmap.rs`). The probe writes one anon page after
/// a clear and checks the bit.
pub fn soft_dirty_supported() -> bool {
    static SUPPORTED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *SUPPORTED.get_or_init(|| {
        (|| -> Result<bool> {
            let ps = page_size();
            let p = unsafe {
                libc::mmap(
                    std::ptr::null_mut(),
                    ps,
                    libc::PROT_READ | libc::PROT_WRITE,
                    libc::MAP_PRIVATE | libc::MAP_ANONYMOUS,
                    -1,
                    0,
                )
            };
            if p == libc::MAP_FAILED {
                return Ok(false);
            }
            clear_soft_dirty()?;
            unsafe { *(p as *mut u8) = 1 };
            let mut pm = Pagemap::open()?;
            let e = pm.entries(p as usize, 1)?[0];
            unsafe { libc::munmap(p, ps) };
            Ok(e & PM_SOFT_DIRTY != 0)
        })()
        .unwrap_or(false)
    })
}

/// Clear the soft-dirty bits of the whole process
/// (`echo 4 > /proc/self/clear_refs`).
pub fn clear_soft_dirty() -> Result<()> {
    let mut f = std::fs::OpenOptions::new()
        .write(true)
        .open("/proc/self/clear_refs")
        .map_err(|e| Error::io("/proc/self/clear_refs", e))?;
    f.write_all(b"4").map_err(|e| Error::io("/proc/self/clear_refs", e))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::mmap::{Prot, Share, VmReservation};
    use crate::util::tmp::TempDir;

    fn mapped_private(npages: usize) -> (TempDir, VmReservation) {
        let ps = page_size();
        let d = TempDir::new("pagemap");
        let path = d.join("f");
        let mut f = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(&path)
            .unwrap();
        f.write_all(&vec![7u8; npages * ps]).unwrap();
        f.sync_all().unwrap();
        let vm = VmReservation::reserve(npages * ps).unwrap();
        vm.map_file(0, &f, 0, npages * ps, Prot::ReadWrite, Share::Private, false).unwrap();
        (d, vm)
    }

    #[test]
    fn coalesce_runs() {
        assert_eq!(coalesce(&[]), vec![]);
        assert_eq!(coalesce(&[3]), vec![3..4]);
        assert_eq!(coalesce(&[0, 1, 2, 5, 6, 9]), vec![0..3, 5..7, 9..10]);
    }

    #[test]
    fn detects_exactly_written_pages() {
        let ps = page_size();
        let n = 16;
        let (_d, vm) = mapped_private(n);
        // fault in some pages read-only: they stay file-backed (clean)
        unsafe {
            let _ = std::ptr::read_volatile(vm.base().add(3 * ps));
            let _ = std::ptr::read_volatile(vm.base().add(4 * ps));
        }
        // write pages 1, 2 and 9
        unsafe {
            *vm.base().add(ps) = 1;
            *vm.base().add(2 * ps) = 2;
            *vm.base().add(9 * ps + 100) = 3;
        }
        let mut pm = Pagemap::open().unwrap();
        let dirty = pm.dirty_pages(vm.base() as usize, n, false).unwrap();
        assert_eq!(dirty, vec![1, 2, 9]);
        let runs = pm.dirty_runs(vm.base() as usize, n, false).unwrap();
        assert_eq!(runs, vec![1..3, 9..10]);
    }

    #[test]
    fn page_node_query_degrades_gracefully() {
        // the probe is stable (OnceLock) and, when the kernel answers at
        // all, a freshly written anon page reports a real node
        assert_eq!(page_node_query_supported(), page_node_query_supported());
        let ps = page_size();
        let n = 4;
        let (_d, vm) = mapped_private(n);
        unsafe {
            *vm.base() = 1; // page 0 present
        }
        match page_nodes(vm.base() as usize, n) {
            None => assert!(!page_node_query_supported(), "query works but probe says no"),
            Some(status) => {
                assert_eq!(status.len(), n);
                assert!(status[0] >= 0, "present page has a node: {status:?}");
            }
        }
    }

    #[test]
    fn soft_dirty_probe_is_stable() {
        // The probe must return the same answer twice (OnceLock) and not
        // error. On this testbed the kernel lacks CONFIG_MEM_SOFT_DIRTY,
        // so `false` is expected, but we only assert stability.
        assert_eq!(soft_dirty_supported(), soft_dirty_supported());
    }

    #[test]
    fn soft_dirty_tracks_new_writes_only() {
        if !soft_dirty_supported() {
            eprintln!("skipping: kernel lacks CONFIG_MEM_SOFT_DIRTY");
            return;
        }
        let ps = page_size();
        let n = 8;
        let (_d, vm) = mapped_private(n);
        unsafe {
            *vm.base() = 1; // page 0 dirty
        }
        clear_soft_dirty().unwrap();
        unsafe {
            *vm.base().add(5 * ps) = 1; // page 5 written after the clear
        }
        let mut pm = Pagemap::open().unwrap();
        // full detection sees both
        let all = pm.dirty_pages(vm.base() as usize, n, false).unwrap();
        assert!(all.contains(&0) && all.contains(&5));
        // soft-only sees just the new write
        let soft = pm.dirty_pages(vm.base() as usize, n, true).unwrap();
        assert_eq!(soft, vec![5]);
    }
}

//! Storage substrates for the persistent heap.
//!
//! - [`mmap`] — thin, safe-ish wrappers over `mmap(2)` / `msync(2)` /
//!   `madvise(2)` / `fallocate(2)`.
//! - [`segment`] — Metall's application-data segment: a large reserved VM
//!   region backed by multiple files created and mapped on demand (paper
//!   §3.6, §4.1).
//! - [`pagemap`] — `/proc/self/pagemap` scanning used by bs-mmap to find
//!   dirty pages of `MAP_PRIVATE` regions (paper §5.1).
//! - [`bsmmap`] — batch-synchronized mmap: private mapping + user-level
//!   msync with run coalescing and per-file parallel write-back (paper §5).
//! - [`reflink`] — `FICLONE`-based snapshot copy with a plain-copy
//!   fallback (paper §3.4).
//! - [`netfs`] — simulated network file systems (Lustre-like / VAST-like)
//!   and device profiles used by the Fig 5/6 reproduction; see DESIGN.md
//!   §3 (substitutions).

pub mod mmap;
pub mod segment;
pub mod pagemap;
pub mod bsmmap;
pub mod reflink;
pub mod netfs;

//! Storage substrates for the persistent heap.
//!
//! - [`mmap`] — thin, safe-ish wrappers over `mmap(2)` / `msync(2)` /
//!   `madvise(2)` / `fallocate(2)`.
//! - [`segment`] — Metall's application-data segment: a large reserved VM
//!   region backed by multiple files created and mapped on demand (paper
//!   §3.6, §4.1).
//! - [`pagemap`] — `/proc/self/pagemap` scanning used by bs-mmap to find
//!   dirty pages of `MAP_PRIVATE` regions (paper §5.1).
//! - [`bsmmap`] — batch-synchronized mmap: private mapping + user-level
//!   msync with run coalescing and per-file parallel write-back (paper §5).
//! - [`reflink`] — `FICLONE`-based snapshot copy with a plain-copy
//!   fallback (paper §3.4).
//! - [`netfs`] — simulated network file systems (Lustre-like / VAST-like)
//!   and device profiles used by the Fig 5/6 reproduction and, via
//!   [`crate::alloc::ManagerOptions::netfs_profile`], charged directly by
//!   the sync path itself; see DESIGN.md §3 (substitutions).
//! - [`faults`] — deterministic I/O fault injection (`FaultFs`): every
//!   durability syscall site in this layer and above asks it for
//!   permission, so the `it_faults.rs` ALICE-style sweep can fail the
//!   k-th write/fsync/msync/rename/… of a workload and assert recovery.
//!
//! ## How the sync protocol uses this layer
//!
//! [`crate::alloc::ManagerCore::sync`] persists in two phases, both of
//! which resolve to primitives here — and since the background engine
//! ([`crate::alloc::bg_sync`]) the phases run **pipelined across two
//! engine threads**, off the mutation path: the `metall-bgsync` flusher
//! takes each epoch's consistent cut and serializes its dirty sections
//! in memory, while the `metall-bgcommit` committer drains a bounded
//! FIFO of prepared epochs and makes each durable in strict epoch order
//! (data msync → section writes → manifest rename — epoch N+1's rename
//! never lands before epoch N's). `sync()` is `sync_async()` + an epoch
//! ticket wait, a dirty-byte watermark (fixed, or bandwidth-adaptive
//! from measured flush bandwidth × latency) or interval timer flushes
//! with no caller at all, and writers that outrun the device stall at a
//! hard backpressure ceiling — a stall that ends at the next *cut*, not
//! at the backend write behind it. The primitives below are therefore
//! routinely invoked from both engine threads while application threads
//! keep allocating and writing; when a [`netfs`] profile is active,
//! [`segment::SegmentStorage::sync_ranges`] and every
//! [`crate::alloc::mgmt_io`] section/manifest write additionally charge
//! the simulated backend's cost account:
//!
//! **Application data, two flush paths.** In the default *shared* mode
//! (`MAP_SHARED`) the kernel owns write-back and sync's job is to force
//! it: the allocator tracks which chunks were written since the last
//! sync and calls [`segment::SegmentStorage::sync_ranges`], which
//! `msync(MS_SYNC)`s only the union of dirty chunk ranges — in parallel
//! across ranges — instead of the whole mapped extent
//! ([`segment::SegmentStorage::sync`] remains the full-extent fallback).
//! In *private* (bs-mmap, §5) mode the kernel never writes back at all;
//! [`bsmmap::BsMsync`] finds dirty pages via [`pagemap`], coalesces them
//! into runs, `pwrite`s the runs to the backing files with a flusher
//! pool, and re-maps them clean — already a page-granular delta flush, so
//! the chunk-level narrowing does not apply there.
//!
//! **Management data** is written *above* this layer by
//! [`crate::alloc::mgmt_io`]: immutable per-section files plus a
//! checksummed manifest committed by fsync'd atomic rename (tmp file
//! fsync → rename → directory fsync — the directory fsync is what makes
//! the rename itself durable). Recovery reads the newest manifest whose
//! sections all verify; a torn sync therefore falls back to the previous
//! complete image, and the legacy monolithic `management.bin` is still
//! readable. The `CLEAN` marker and `meta.bin` go through the same
//! fsync-file-then-directory discipline.
//!
//! Crash model: `msync`/`pwrite`+`fsync` bound *data* loss to writes
//! since the last sync; the manifest commit bounds *management* state to
//! the last complete sync; and the transient cache section closes the
//! gap between them (free slots parked in DRAM caches at sync time are
//! recorded, and recovery returns them, so no slot leaks across a kill).
//! Pipelined background flushing changes none of this: with up to
//! `sync_pipeline_depth` epochs in flight, a kill-9 tears at most the
//! files those in-flight epochs were writing — and because manifests
//! commit strictly in epoch order, the newest *complete* manifest on
//! disk is always a consistent prefix of the epoch sequence; recovery
//! walks back to it exactly as for a torn foreground sync (the
//! `torn_pipeline_queue_matrix` integration test drives the full file
//! surgery). Shutdown is explicit — `close()`/`Drop` drain the queue,
//! join both engine threads, and run a final full sync before the
//! `CLEAN` marker is written; an engine that died refuses the marker so
//! the store is never falsely advertised as consistent.
//!
//! ## How reader attach uses this layer
//!
//! A live attach ([`crate::alloc::ReaderManager`]) pins one committed
//! manifest epoch while the owner keeps writing. This layer supplies the
//! two primitives that make the pinned view *stable*:
//!
//! **Different inodes, not timing.** A read-only mapping of the live
//! chunk files would share page-cache pages with the owner's
//! `MAP_SHARED` writable mapping, so the reader would see every store
//! the instant it happens — no msync ordering can prevent that. The
//! pinned view therefore resolves each live chunk to an immutable
//! **epoch-side file** (`epoch-side/side-c…-e….bin`): the flusher clones
//! dirty chunks aside *before* its in-place msync whenever a lease is
//! live, and an attach seeds the rest. [`reflink::clone_file_range`]
//! does the cloning — `FICLONERANGE` shares blocks copy-on-write where
//! the filesystem supports it (XFS/Btrfs/APFS), and a `pread`/`pwrite`
//! loop with zero-fill past EOF is the ext4 fallback, so a side copy is
//! always full-chunk-length.
//!
//! **Overlay mapping.** The reader opens the segment read-only and maps
//! each side file over its chunk's pages in the reserved extent
//! ([`segment::SegmentStorage::overlay_readonly`] — `MAP_FIXED` within
//! the reservation, refused on writable segments). Offsets computed
//! against `base()` resolve identically to the owner's, so containers
//! traverse the pinned epoch with unchanged code. POSIX keeps a mapped
//! inode alive past `unlink`, which gives the protocol its last-ditch
//! safety: even if a side file is collected the moment after a reader
//! mapped it, the reader's pages stay valid until it detaches.
//!
//! ## Error taxonomy & degraded mode
//!
//! Every primitive in this layer reports failures with the real errno
//! attached ([`crate::error::Error::Io`] /
//! [`Error::Sys`](crate::error::Error::Sys)), because the layers above
//! *classify* by it ([`faults::classify_errno`]):
//!
//! - **Transient** — `EIO`, `EAGAIN`, `EINTR`, `ENOSPC`, timeouts, and
//!   every unknown errno. A failed background flush/commit round is
//!   retried with the engine's exponential backoff; the mutation path
//!   never sees these unless it explicitly waits on a sync ticket.
//! - **ENOSPC on segment growth** is special-cased at its source:
//!   [`segment::SegmentStorage::extend_to`] rolls its own partial work
//!   back (created file removed, reservation stays intact) and
//!   surfaces a clean [`Error::Alloc`](crate::error::Error::Alloc), so
//!   an allocator caller releases its reserved chunk ids and a smaller
//!   allocation can still succeed. A full disk is an allocation
//!   failure, never a crash or a wound.
//! - **Permanent** — `EROFS`, `ENODEV`, `ENXIO`, `EBADF`, or
//!   transient failures repeated past the engine's consecutive-failure
//!   limit. The manager **wounds** itself: it atomically flips to
//!   degraded read-only, mutating APIs return
//!   [`Error::Degraded`](crate::error::Error::Degraded), in-flight
//!   sync tickets resolve with the failure attributed, the engine
//!   parks, live reader attaches keep serving the last committed
//!   epoch, and `close()` refuses the `CLEAN` marker (recovery replays
//!   from the last complete manifest). See [`crate::alloc`] for the
//!   API-level contract.

pub mod mmap;
pub mod segment;
pub mod pagemap;
pub mod bsmmap;
pub mod reflink;
pub mod netfs;
pub mod faults;

//! The application-data segment: a large reserved VM extent backed by
//! multiple files created and mapped on demand.
//!
//! Paper §3.6: "Metall uses multiple files to store application data …
//! breaking application data into multiple backing files increases
//! parallel I/O performance … Metall creates and maps new files on
//! demand. By default, Metall creates each file with 256 MB."
//!
//! Paper §4.1: "Metall *reserves* a large contiguous virtual memory space
//! … Applications can set the VM reservation size … Metall automatically
//! detects the necessary VM size when opening an existing datastore."

use std::fs::{self, File, OpenOptions};
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::error::{Error, Result};
use crate::storage::faults;
use crate::storage::mmap::{self, page_size, Prot, Share, VmReservation};
use crate::storage::netfs::SimNetFs;
use crate::util::{align_up, div_ceil};

/// Default backing-file size (the paper's 256 MB, here 64 MiB so that the
/// single-node CI-scale experiments still exercise multi-file behaviour).
pub const DEFAULT_FILE_SIZE: usize = 64 << 20;

/// Default VM reservation (paper default is "a few TB"; we reserve 64 GiB
/// which is plenty for this testbed and still enormously larger than
/// physical use — the Supermalloc philosophy).
pub const DEFAULT_VM_RESERVE: usize = 64 << 30;

/// Options controlling how a segment is created/opened.
#[derive(Clone, Debug)]
pub struct SegmentOptions {
    pub vm_reserve: usize,
    pub file_size: usize,
    pub share: Share,
    pub prot: Prot,
    /// `MAP_POPULATE` file mappings on open (bs-mmap configuration in
    /// §6.4.2 reads mapped files ahead).
    pub populate: bool,
    /// Whether `free_range` punches file holes (`MADV_REMOVE`) or only
    /// drops DRAM (`MADV_DONTNEED`). §6.4.2 disables file-space freeing on
    /// Lustre because hole punching is expensive there.
    pub free_file_space: bool,
}

impl Default for SegmentOptions {
    fn default() -> Self {
        Self {
            vm_reserve: DEFAULT_VM_RESERVE,
            file_size: DEFAULT_FILE_SIZE,
            share: Share::Shared,
            prot: Prot::ReadWrite,
            populate: false,
            free_file_space: true,
        }
    }
}

impl SegmentOptions {
    pub fn read_only(mut self) -> Self {
        self.prot = Prot::Read;
        self
    }

    pub fn private_mode(mut self) -> Self {
        self.share = Share::Private;
        self
    }

    pub fn with_file_size(mut self, sz: usize) -> Self {
        self.file_size = align_up(sz.max(page_size()), page_size());
        self
    }

    pub fn with_vm_reserve(mut self, sz: usize) -> Self {
        self.vm_reserve = sz;
        self
    }
}

/// Multi-file mmap-backed storage for one contiguous segment.
pub struct SegmentStorage {
    vm: VmReservation,
    dir: PathBuf,
    files: Mutex<Vec<File>>,
    mapped_len: AtomicUsize,
    opts: SegmentOptions,
    /// Optional simulated-backend account: when set, every range flush
    /// ([`Self::sync_ranges`]) charges the cost model so sync-path
    /// benches measure Lustre/VAST behaviour, not the local disk's.
    netfs: OnceLock<Arc<SimNetFs>>,
}

impl SegmentStorage {
    fn file_path(dir: &Path, idx: usize) -> PathBuf {
        dir.join(format!("chunk-{idx:06}"))
    }

    /// Create a fresh segment store in `dir` (must not already contain
    /// segment files).
    pub fn create(dir: impl Into<PathBuf>, opts: SegmentOptions) -> Result<Self> {
        let dir = dir.into();
        fs::create_dir_all(&dir).map_err(|e| Error::io(&dir, e))?;
        if Self::detect_files(&dir)?.next_idx != 0 {
            return Err(Error::Datastore(format!(
                "segment dir {dir:?} already contains backing files"
            )));
        }
        let vm = VmReservation::reserve(opts.vm_reserve)?;
        Ok(Self {
            vm,
            dir,
            files: Mutex::new(vec![]),
            mapped_len: AtomicUsize::new(0),
            opts,
            netfs: OnceLock::new(),
        })
    }

    /// Open an existing segment store, mapping every backing file found.
    /// The VM reservation automatically covers at least the existing data
    /// (paper §4.1 "automatically detects the necessary VM size").
    pub fn open(dir: impl Into<PathBuf>, opts: SegmentOptions) -> Result<Self> {
        let dir = dir.into();
        let det = Self::detect_files(&dir)?;
        let existing = det.next_idx;
        let total = existing * opts.file_size;
        let reserve = opts.vm_reserve.max(total);
        let vm = VmReservation::reserve(reserve)?;
        let mut files = Vec::with_capacity(existing);
        for i in 0..existing {
            let path = Self::file_path(&dir, i);
            // Writable fd whenever the segment is writable: the shared
            // mapping needs it for the kernel write-back, the private
            // (bs-mmap) mode for the user-level msync's pwrite path.
            let f = OpenOptions::new()
                .read(true)
                .write(opts.prot == Prot::ReadWrite)
                .open(&path)
                .map_err(|e| Error::io(&path, e))?;
            vm.map_file(
                i * opts.file_size,
                &f,
                0,
                opts.file_size,
                opts.prot,
                opts.share,
                opts.populate,
            )?;
            files.push(f);
        }
        Ok(Self {
            vm,
            dir,
            files: Mutex::new(files),
            mapped_len: AtomicUsize::new(total),
            opts,
            netfs: OnceLock::new(),
        })
    }

    /// Attach the simulated-backend account (once, right after
    /// create/open). Subsequent calls are ignored.
    pub fn set_netfs(&self, fs: Arc<SimNetFs>) {
        let _ = self.netfs.set(fs);
    }

    /// The attached simulated-backend account, if any.
    pub fn netfs(&self) -> Option<&SimNetFs> {
        self.netfs.get().map(Arc::as_ref)
    }

    fn detect_files(dir: &Path) -> Result<Detected> {
        let mut n = 0usize;
        while Self::file_path(dir, n).exists() {
            n += 1;
        }
        Ok(Detected { next_idx: n })
    }

    /// Base address of the segment in this process.
    pub fn base(&self) -> *mut u8 {
        self.vm.base()
    }

    /// Bytes currently backed by files.
    pub fn mapped_len(&self) -> usize {
        self.mapped_len.load(Ordering::Acquire)
    }

    /// Total VM reservation (the hard ceiling `extend_to` enforces; the
    /// allocator sizes its chunk-granular dirty map from this).
    pub fn vm_len(&self) -> usize {
        self.vm.len()
    }

    pub fn num_files(&self) -> usize {
        self.files.lock().unwrap().len()
    }

    pub fn file_size(&self) -> usize {
        self.opts.file_size
    }

    pub fn options(&self) -> &SegmentOptions {
        &self.opts
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Ensure at least `bytes` of the segment are file-backed, creating
    /// and mapping new backing files on demand.
    pub fn extend_to(&self, bytes: usize) -> Result<()> {
        if bytes <= self.mapped_len() {
            return Ok(());
        }
        if self.opts.prot != Prot::ReadWrite {
            return Err(Error::InvalidOp("cannot extend a read-only segment".into()));
        }
        let mut files = self.files.lock().unwrap();
        // re-check under the lock
        let have = files.len() * self.opts.file_size;
        if bytes <= have {
            return Ok(());
        }
        let want_files = div_ceil(bytes, self.opts.file_size);
        if want_files * self.opts.file_size > self.vm.len() {
            return Err(Error::Alloc(format!(
                "segment would exceed VM reservation ({} > {})",
                want_files * self.opts.file_size,
                self.vm.len()
            )));
        }
        for i in files.len()..want_files {
            let path = Self::file_path(&self.dir, i);
            faults::check(faults::Site::Create).map_err(|e| Error::io(&path, e))?;
            let f = match OpenOptions::new()
                .read(true)
                .write(true)
                .create_new(true)
                .open(&path)
            {
                Ok(f) => f,
                Err(e) => {
                    // nothing of ours to roll back; account the files that
                    // did complete before surfacing the failure
                    self.mapped_len.store(files.len() * self.opts.file_size, Ordering::Release);
                    return Err(enospc_to_alloc(Error::io(&path, e)));
                }
            };
            // From here the file exists but is not yet usable: any failure
            // of the ftruncate/mmap pair removes it again, so a retry (or
            // a recovery scan) never meets a zero-length backing file —
            // and the chunk reservation a failed allocation rolls back is
            // matched by an equally clean segment.
            let grown = faults::check(faults::Site::Truncate)
                .map_err(|e| Error::io(&path, e))
                .and_then(|()| {
                    f.set_len(self.opts.file_size as u64).map_err(|e| Error::io(&path, e))
                })
                .and_then(|()| {
                    self.vm.map_file(
                        i * self.opts.file_size,
                        &f,
                        0,
                        self.opts.file_size,
                        self.opts.prot,
                        self.opts.share,
                        false,
                    )
                });
            if let Err(e) = grown {
                let _ = fs::remove_file(&path);
                self.mapped_len.store(files.len() * self.opts.file_size, Ordering::Release);
                return Err(enospc_to_alloc(e));
            }
            files.push(f);
        }
        self.mapped_len.store(files.len() * self.opts.file_size, Ordering::Release);
        Ok(())
    }

    /// Flush dirty pages to the backing files (`msync`), optionally with
    /// one flusher thread per file (paper §5.2 assigns a thread per file).
    /// Only meaningful for `Share::Shared`; bs-mmap handles private mode.
    pub fn sync(&self, parallel: bool) -> Result<()> {
        if self.opts.share != Share::Shared || self.opts.prot != Prot::ReadWrite {
            return Ok(());
        }
        let n = self.num_files();
        let fsz = self.opts.file_size;
        // With a fault plan armed the per-file fan-out runs as one serial
        // msync so injected-operation indices stay deterministic.
        if !parallel || n <= 1 || faults::armed() {
            if n > 0 {
                mmap::msync(self.base(), n * fsz)?;
            }
            return Ok(());
        }
        let base = self.base() as usize;
        // Join EVERY worker, then report the first real msync error; a
        // panicking worker surfaces as Error::Runtime instead of tearing
        // the whole process down through a propagated join panic (the
        // same containment the pipeline workers got).
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|i| s.spawn(move || mmap::msync((base + i * fsz) as *mut u8, fsz)))
                .collect();
            let mut first: Option<Error> = None;
            for h in handles {
                match h.join() {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => {
                        first.get_or_insert(e);
                    }
                    Err(panic) => {
                        let msg = panic
                            .downcast_ref::<&str>()
                            .map(|s| s.to_string())
                            .or_else(|| panic.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "opaque panic payload".to_string());
                        first.get_or_insert(Error::Runtime(format!(
                            "segment sync worker panicked: {msg}"
                        )));
                    }
                }
            }
            match first {
                None => Ok(()),
                Some(e) => Err(e),
            }
        })?;
        Ok(())
    }

    /// Flush only the given byte ranges (`msync(MS_SYNC)` per range),
    /// optionally with a flusher pool — the narrowed data flush of the
    /// incremental sync path: when the allocator knows which chunks were
    /// written since the last sync, only their union goes to the kernel
    /// instead of the whole extent. Ranges must be page-aligned (chunk
    /// ranges are: chunk size ≥ 4 KiB and a power of two) and are clamped
    /// to the mapped extent; empty and out-of-range leftovers are
    /// skipped. No-op for private/read-only mappings, like [`Self::sync`].
    pub fn sync_ranges(&self, ranges: &[Range<usize>], parallel: bool) -> Result<()> {
        if self.opts.share != Share::Shared || self.opts.prot != Prot::ReadWrite {
            return Ok(());
        }
        let mapped = self.mapped_len();
        let todo: Vec<Range<usize>> = ranges
            .iter()
            .map(|r| r.start.min(mapped)..r.end.min(mapped))
            .filter(|r| !r.is_empty())
            .collect();
        if todo.is_empty() {
            return Ok(());
        }
        let base = self.base() as usize;
        let charge = |streams: usize| {
            if let Some(fs) = self.netfs() {
                let bytes: u64 = todo.iter().map(|r| r.len() as u64).sum();
                fs.charge_io(todo.len() as u64, bytes, streams);
            }
        };
        // serial when a fault plan is armed: deterministic op indices
        if !parallel || faults::armed() {
            for r in &todo {
                mmap::msync((base + r.start) as *mut u8, r.len())?;
            }
            charge(1);
            return Ok(());
        }
        // shared flusher pool; a single range runs inline
        crate::util::parallel_jobs(todo.len(), |i| {
            let r = &todo[i];
            mmap::msync((base + r.start) as *mut u8, r.len())
        })
        .into_iter()
        .collect::<Result<()>>()?;
        charge(todo.len());
        Ok(())
    }

    /// Free a range of the segment: drop DRAM pages and (configurably)
    /// punch the hole in the backing file — Metall frees space by chunk
    /// (§4.1).
    pub fn free_range(&self, offset: usize, len: usize) -> Result<()> {
        assert!(offset + len <= self.mapped_len(), "free_range outside mapped area");
        let addr = unsafe { self.base().add(offset) };
        match (self.opts.share, self.opts.free_file_space) {
            (Share::Shared, true) => mmap::madvise_remove(addr, len),
            _ => mmap::madvise_dontneed(addr, len),
        }
    }

    /// Best-effort NUMA bind of a mapped extent: future page faults in
    /// `[offset, offset+len)` prefer `node`
    /// ([`mmap::mbind_preferred`], `MPOL_PREFERRED`). Returns whether the
    /// policy took; unmapped ranges and NUMA-less kernels are a graceful
    /// `false` — the allocator's placement layer treats binding as an
    /// optimization over its owner-first-touch discipline, never as a
    /// requirement.
    pub fn bind_range(&self, offset: usize, len: usize, node: usize) -> bool {
        if len == 0 || offset + len > self.mapped_len() {
            return false;
        }
        mmap::mbind_preferred(unsafe { self.base().add(offset) }, len, node)
    }

    /// Total file blocks allocated across all backing files (512B units).
    pub fn allocated_file_blocks(&self) -> Result<u64> {
        let files = self.files.lock().unwrap();
        let mut total = 0;
        for f in files.iter() {
            total += mmap::allocated_blocks(f)?;
        }
        Ok(total)
    }

    /// Map a segment offset to (file index, offset inside the file).
    pub fn locate(&self, offset: usize) -> (usize, usize) {
        (offset / self.opts.file_size, offset % self.opts.file_size)
    }

    /// Run `f` against the backing file at `file_idx` (`None` when the
    /// segment has no such file yet). The epoch-side preservation path
    /// ([`crate::alloc::readers`]) reflinks chunk ranges out of the live
    /// files through this.
    pub(crate) fn with_file<R>(&self, file_idx: usize, f: impl FnOnce(&File) -> R) -> Option<R> {
        let files = self.files.lock().unwrap();
        files.get(file_idx).map(f)
    }

    /// Replace the mapping of `[at, at+len)` with a **read-only** shared
    /// mapping of `file` from offset 0 (`MAP_FIXED` over the
    /// reservation). This is how an attached reader resolves a pinned
    /// chunk to its epoch-side copy instead of the live backing file:
    /// the copy is a different inode, so the owner's page-cache writes
    /// and in-place msyncs never show through, and the mapping survives
    /// even if the copy is later unlinked. Only read-only segments may
    /// be overlaid — a writable segment's pages must keep writing back
    /// to the real backing files.
    pub fn overlay_readonly(&self, at: usize, file: &File, len: usize) -> Result<()> {
        if self.opts.prot != Prot::Read {
            return Err(Error::InvalidOp(
                "overlay_readonly: only read-only segments may resolve to side files".into(),
            ));
        }
        if at % page_size() != 0 || at + len > self.mapped_len() {
            return Err(Error::InvalidOp(format!(
                "overlay_readonly: bad range {at}+{len} (mapped {})",
                self.mapped_len()
            )));
        }
        self.vm.map_file(at, file, 0, len, Prot::Read, Share::Shared, false)
    }

    /// `pwrite` raw bytes directly into a backing file, bypassing the
    /// mapping — the bs-mmap user-level msync write-back path (§5.1).
    pub fn pwrite_file(&self, file_idx: usize, file_off: usize, data: &[u8]) -> Result<()> {
        let files = self.files.lock().unwrap();
        let f = files.get(file_idx).ok_or_else(|| {
            Error::Datastore(format!("pwrite: no backing file {file_idx}"))
        })?;
        // Clone the handle so the write happens outside the lock if this
        // ever becomes contended; pwrite needs no seek state.
        let f = f.try_clone().map_err(|e| Error::io(&self.dir, e))?;
        drop(files);
        faults::write_full_at(&f, data, file_off as u64, faults::Site::Write)
            .map_err(|e| Error::io(&self.dir, e))
    }

    /// Re-map `[offset, offset+len)` from the backing file(s), discarding
    /// any private (copy-on-write) pages in the range. Used by the
    /// bs-mmap user msync after a run has been written back: the pages
    /// return to *clean, file-backed* state so the next dirty scan does
    /// not see them again. Page-aligned range required.
    pub fn remap_range(&self, offset: usize, len: usize) -> Result<()> {
        let ps = page_size();
        assert_eq!(offset % ps, 0);
        assert_eq!(len % ps, 0);
        assert!(offset + len <= self.mapped_len());
        let files = self.files.lock().unwrap();
        let fsz = self.opts.file_size;
        let mut cur = offset;
        let end = offset + len;
        while cur < end {
            let fi = cur / fsz;
            let in_file = cur % fsz;
            let piece = (fsz - in_file).min(end - cur);
            self.vm.map_file(
                cur,
                &files[fi],
                in_file as u64,
                piece,
                self.opts.prot,
                self.opts.share,
                false,
            )?;
            cur += piece;
        }
        Ok(())
    }

    /// Slice accessors. Caller must respect allocation boundaries; the
    /// allocator layer guarantees non-overlap of live allocations.
    ///
    /// # Safety
    /// `offset + len` must lie within the mapped extent and not alias a
    /// concurrently-written region.
    pub unsafe fn slice(&self, offset: usize, len: usize) -> &[u8] {
        debug_assert!(offset + len <= self.mapped_len());
        std::slice::from_raw_parts(self.base().add(offset), len)
    }

    /// # Safety
    /// Same contract as [`Self::slice`], plus exclusive access to the range.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, offset: usize, len: usize) -> &mut [u8] {
        debug_assert!(offset + len <= self.mapped_len());
        std::slice::from_raw_parts_mut(self.base().add(offset), len)
    }
}

struct Detected {
    next_idx: usize,
}

/// ENOSPC while growing the segment is an *allocation* failure, not an
/// I/O catastrophe: `extend_to` already rolled its partial work back,
/// the caller releases its reserved chunk ids, and a smaller request
/// can still succeed — so surface it as a clean [`Error::Alloc`]. Any
/// other errno passes through unchanged for classification upstream.
fn enospc_to_alloc(e: Error) -> Error {
    let raw = match &e {
        Error::Io { source, .. } => source.raw_os_error(),
        Error::Sys { source, .. } => source.raw_os_error(),
        _ => None,
    };
    if raw == Some(libc::ENOSPC) {
        Error::Alloc(format!("segment extension failed: no space left on device ({e})"))
    } else {
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn opts_small() -> SegmentOptions {
        SegmentOptions::default()
            .with_file_size(1 << 20) // 1 MiB files for tests
            .with_vm_reserve(256 << 20)
    }

    #[test]
    fn create_extend_write_reopen() {
        let d = TempDir::new("seg");
        let dir = d.join("segment");
        {
            let seg = SegmentStorage::create(&dir, opts_small()).unwrap();
            assert_eq!(seg.mapped_len(), 0);
            seg.extend_to(3 << 20).unwrap(); // 3 files
            assert_eq!(seg.num_files(), 3);
            assert_eq!(seg.mapped_len(), 3 << 20);
            unsafe {
                seg.slice_mut(0, 8).copy_from_slice(b"metallrs");
                seg.slice_mut((2 << 20) + 5, 3).copy_from_slice(b"end");
            }
            seg.sync(true).unwrap();
        }
        {
            let seg = SegmentStorage::open(&dir, opts_small()).unwrap();
            assert_eq!(seg.num_files(), 3);
            unsafe {
                assert_eq!(seg.slice(0, 8), b"metallrs");
                assert_eq!(seg.slice((2 << 20) + 5, 3), b"end");
            }
        }
    }

    #[test]
    fn open_read_only_protects() {
        let d = TempDir::new("segro");
        let dir = d.join("segment");
        {
            let seg = SegmentStorage::create(&dir, opts_small()).unwrap();
            seg.extend_to(1 << 20).unwrap();
            unsafe { seg.slice_mut(0, 4).copy_from_slice(b"data") };
            seg.sync(false).unwrap();
        }
        let seg = SegmentStorage::open(&dir, opts_small().read_only()).unwrap();
        unsafe {
            assert_eq!(seg.slice(0, 4), b"data");
        }
        assert!(seg.extend_to(2 << 20).is_err());
    }

    #[test]
    fn extend_is_idempotent_and_monotonic() {
        let d = TempDir::new("segext");
        let seg = SegmentStorage::create(d.join("s"), opts_small()).unwrap();
        seg.extend_to(10).unwrap();
        assert_eq!(seg.num_files(), 1);
        seg.extend_to(5).unwrap();
        assert_eq!(seg.num_files(), 1);
        seg.extend_to((1 << 20) + 1).unwrap();
        assert_eq!(seg.num_files(), 2);
    }

    #[test]
    fn vm_reservation_guard() {
        let d = TempDir::new("segvm");
        let opts = opts_small().with_vm_reserve(2 << 20);
        let seg = SegmentStorage::create(d.join("s"), opts).unwrap();
        assert!(seg.extend_to(4 << 20).is_err());
    }

    #[test]
    fn sync_ranges_flushes_only_named_ranges() {
        let d = TempDir::new("segranges");
        let dir = d.join("s");
        let seg = SegmentStorage::create(&dir, opts_small()).unwrap();
        seg.extend_to(2 << 20).unwrap();
        unsafe {
            seg.slice_mut(0, 4).copy_from_slice(b"aaaa");
            seg.slice_mut(1 << 20, 4).copy_from_slice(b"bbbb");
        }
        // ranges spanning both files, sequential and parallel paths
        seg.sync_ranges(&[0..4096], false).unwrap();
        seg.sync_ranges(&[0..4096, (1 << 20)..(1 << 20) + 4096], true).unwrap();
        // clamped / empty / out-of-range inputs are tolerated
        seg.sync_ranges(&[], true).unwrap();
        seg.sync_ranges(&[(3 << 20)..(4 << 20)], true).unwrap();
        seg.sync_ranges(&[(2 << 20) - 4096..(3 << 20)], false).unwrap();
        unsafe {
            assert_eq!(seg.slice(0, 4), b"aaaa");
            assert_eq!(seg.slice(1 << 20, 4), b"bbbb");
        }
    }

    #[test]
    fn free_range_punches_holes() {
        let d = TempDir::new("segfree");
        let seg = SegmentStorage::create(d.join("s"), opts_small()).unwrap();
        seg.extend_to(2 << 20).unwrap();
        unsafe {
            seg.slice_mut(0, 1 << 20).fill(0xEE);
        }
        seg.sync(false).unwrap();
        let before = seg.allocated_file_blocks().unwrap();
        seg.free_range(0, 1 << 20).unwrap();
        let after = seg.allocated_file_blocks().unwrap();
        assert!(after < before, "{before} -> {after}");
        unsafe {
            assert_eq!(seg.slice(0, 1)[0], 0, "freed range reads as zeros");
        }
    }

    #[test]
    fn bind_range_is_best_effort() {
        let d = TempDir::new("segbind");
        let seg = SegmentStorage::create(d.join("s"), opts_small()).unwrap();
        seg.extend_to(1 << 20).unwrap();
        // node 0 on a NUMA kernel, graceful false otherwise — the extent
        // stays writable and durable either way
        let _ = seg.bind_range(0, 1 << 20, 0);
        unsafe { seg.slice_mut(0, 4).copy_from_slice(b"numa") };
        seg.sync(false).unwrap();
        unsafe { assert_eq!(seg.slice(0, 4), b"numa") };
        // out-of-range and empty binds are refused, not panics
        assert!(!seg.bind_range(0, 2 << 20, 0));
        assert!(!seg.bind_range(0, 0, 0));
    }

    #[test]
    fn locate_and_pwrite() {
        let d = TempDir::new("segloc");
        let seg = SegmentStorage::create(d.join("s"), opts_small()).unwrap();
        seg.extend_to(2 << 20).unwrap();
        assert_eq!(seg.locate(0), (0, 0));
        assert_eq!(seg.locate((1 << 20) + 7), (1, 7));
        seg.pwrite_file(1, 7, b"xyz").unwrap();
        // pwrite bypasses the mapping but the shared mapping is coherent
        unsafe {
            assert_eq!(seg.slice((1 << 20) + 7, 3), b"xyz");
        }
    }

    #[test]
    fn injected_enospc_on_truncate_rolls_back_and_reports_alloc() {
        let _g = faults::test_serial_guard();
        let d = TempDir::new("segenospc");
        let seg = SegmentStorage::create(d.join("s"), opts_small()).unwrap();
        seg.extend_to(1 << 20).unwrap();
        // next Truncate (the new file's ftruncate) reports a full disk
        faults::arm(faults::FaultPlan::nth_at(1, faults::Site::Truncate, faults::FaultKind::Enospc));
        let err = seg.extend_to(2 << 20).unwrap_err();
        faults::disarm();
        assert!(matches!(err, Error::Alloc(_)), "ENOSPC surfaces as Alloc: {err}");
        // the half-built backing file was removed and accounting is sane
        assert_eq!(seg.num_files(), 1);
        assert_eq!(seg.mapped_len(), 1 << 20);
        assert!(!SegmentStorage::file_path(seg.dir(), 1).exists(), "partial file rolled back");
        // the disk "recovers": the same extension now succeeds
        seg.extend_to(2 << 20).unwrap();
        assert_eq!(seg.num_files(), 2);
        unsafe { seg.slice_mut((1 << 20) + 8, 4).copy_from_slice(b"ok!!") };
        seg.sync(false).unwrap();
    }

    #[test]
    fn injected_mmap_failure_rolls_back_partial_file() {
        let _g = faults::test_serial_guard();
        let d = TempDir::new("segmmapfail");
        let seg = SegmentStorage::create(d.join("s"), opts_small()).unwrap();
        faults::arm(faults::FaultPlan::nth_at(1, faults::Site::Mmap, faults::FaultKind::Eio));
        let err = seg.extend_to(1 << 20).unwrap_err();
        faults::disarm();
        assert!(matches!(err, Error::Sys { .. }), "mmap failure stays a Sys error: {err}");
        assert_eq!(seg.num_files(), 0);
        assert!(!SegmentStorage::file_path(seg.dir(), 0).exists());
        seg.extend_to(1 << 20).unwrap();
        assert_eq!(seg.num_files(), 1);
    }

    #[test]
    fn create_refuses_existing_files() {
        let d = TempDir::new("segdup");
        let dir = d.join("s");
        {
            let seg = SegmentStorage::create(&dir, opts_small()).unwrap();
            seg.extend_to(1).unwrap();
        }
        assert!(SegmentStorage::create(&dir, opts_small()).is_err());
    }
}

//! Simulated network / device file-system cost model.
//!
//! The paper's Fig 5/6 experiments ran on LLNL's **Lustre** (throughput
//! oriented: high bandwidth, high per-op latency, high concurrency) and
//! **VAST** (latency oriented: low latency, lower bandwidth) parallel
//! file systems; Fig 4 ran on node-local NVMe and Optane NVDIMM. None of
//! those are attached to this testbed, so — per the substitution rule in
//! DESIGN.md §3 — we model them: all data physically lives on the local
//! disk (full fidelity for correctness), while every remote I/O operation
//! is *charged* against a [`NetFsProfile`] cost model:
//!
//! ```text
//! time(ops, bytes, streams) = ops * op_latency / min(streams, concurrency)
//!                           + bytes / bandwidth
//! ```
//!
//! The simulator keeps an accumulated simulated-time account (what the
//! benches report) and optionally sleeps a scaled-down real delay so that
//! thread-interleaving effects stay realistic.
//!
//! Profile constants derive from Table 1 and the text's qualitative
//! description (Lustre: throughput-oriented; VAST: latency-oriented over
//! 4×20 Gbps Ethernet).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Cost-model parameters for one file system / device.
#[derive(Clone, Copy, Debug)]
pub struct NetFsProfile {
    pub name: &'static str,
    /// Per-I/O-operation round-trip latency (seconds).
    pub op_latency: f64,
    /// Aggregate bandwidth in bytes/second.
    pub bandwidth: f64,
    /// Number of parallel streams that can overlap op latency.
    pub concurrency: usize,
    /// Per-metadata-operation latency (open/create/stat), seconds.
    pub metadata_latency: f64,
}

impl NetFsProfile {
    /// Bandwidth-delay product of the modelled backend: how many bytes a
    /// single flush must carry before the bandwidth term catches up with
    /// one op round trip. The adaptive watermark controller converges
    /// near this value (Lustre ≈ 4.5 MB, VAST ≈ 250 KB).
    pub fn bdp_bytes(&self) -> u64 {
        (self.bandwidth * self.op_latency) as u64
    }
}

/// Lustre-like: throughput-oriented parallel FS. High aggregate bandwidth
/// and good parallelism, but every RPC pays a hefty round trip and
/// metadata operations are notoriously expensive.
pub const LUSTRE: NetFsProfile = NetFsProfile {
    name: "lustre",
    op_latency: 1.5e-3,
    bandwidth: 3.0e9,
    concurrency: 16,
    metadata_latency: 4.0e-3,
};

/// VAST-like: latency-oriented NAS over 4×20 Gbps Ethernet. Low per-op
/// latency, modest bandwidth ceiling.
pub const VAST: NetFsProfile = NetFsProfile {
    name: "vast",
    op_latency: 2.5e-4,
    bandwidth: 1.0e9,
    concurrency: 8,
    metadata_latency: 5.0e-4,
};

/// Node-local NVMe SSD (Table 1: ~10 µs latency, 2.5/2.2 GB/s).
pub const NVME: NetFsProfile = NetFsProfile {
    name: "nvme",
    op_latency: 1.0e-5,
    bandwidth: 2.2e9,
    concurrency: 32,
    metadata_latency: 2.0e-5,
};

/// Intel Optane DC PM in App Direct / DAX mode (Table 1: ~400 ns write
/// latency, 3 GB/s write bandwidth; fine-grained I/O, page cache
/// bypassed).
pub const OPTANE: NetFsProfile = NetFsProfile {
    name: "optane",
    op_latency: 4.0e-7,
    bandwidth: 3.0e9,
    concurrency: 16,
    metadata_latency: 2.0e-6,
};

/// Every profile this module knows, for error messages and matrix benches.
pub const PROFILE_NAMES: &[&str] = &["lustre", "vast", "nvme", "optane"];

/// Resolve a profile by name, case-insensitively (`"LUSTRE"` and
/// `"Lustre"` both mean [`LUSTRE`]).
pub fn profile_by_name(name: &str) -> Option<NetFsProfile> {
    match name.to_ascii_lowercase().as_str() {
        "lustre" => Some(LUSTRE),
        "vast" => Some(VAST),
        "nvme" => Some(NVME),
        "optane" => Some(OPTANE),
        _ => None,
    }
}

/// [`profile_by_name`] that fails fast with the list of known profiles —
/// the CLI/bench entry points use this so a typo aborts the run instead
/// of silently leaving the I/O uncharged.
pub fn profile_by_name_strict(name: &str) -> crate::error::Result<NetFsProfile> {
    profile_by_name(name).ok_or_else(|| {
        crate::error::Error::Config(format!(
            "unknown netfs profile {name:?} (known: {})",
            PROFILE_NAMES.join(", ")
        ))
    })
}

/// A simulated file system account. Thread-safe; simulated time is
/// accumulated in nanoseconds.
pub struct SimNetFs {
    pub profile: NetFsProfile,
    /// Fraction of simulated time to actually sleep (0.0 = account only).
    pub sleep_scale: f64,
    sim_ns: AtomicU64,
    ops: AtomicU64,
    bytes: AtomicU64,
}

impl SimNetFs {
    pub fn new(profile: NetFsProfile) -> Self {
        Self {
            profile,
            sleep_scale: 0.0,
            sim_ns: AtomicU64::new(0),
            ops: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
        }
    }

    pub fn with_sleep_scale(mut self, s: f64) -> Self {
        self.sleep_scale = s;
        self
    }

    /// Charge `ops` I/O operations moving `bytes` bytes using `streams`
    /// parallel streams. Returns the simulated seconds charged.
    pub fn charge_io(&self, ops: u64, bytes: u64, streams: usize) -> f64 {
        let p = &self.profile;
        let eff = streams.clamp(1, p.concurrency) as f64;
        let t = ops as f64 * p.op_latency / eff + bytes as f64 / p.bandwidth;
        self.account(t, ops, bytes);
        t
    }

    /// Charge `n` metadata operations (open/create/stat/unlink).
    pub fn charge_metadata(&self, n: u64) -> f64 {
        let t = n as f64 * self.profile.metadata_latency;
        self.account(t, n, 0);
        t
    }

    fn account(&self, secs: f64, ops: u64, bytes: u64) {
        self.sim_ns.fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
        self.ops.fetch_add(ops, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
        if self.sleep_scale > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(secs * self.sleep_scale));
        }
    }

    /// Total simulated seconds charged so far.
    pub fn sim_seconds(&self) -> f64 {
        self.sim_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn total_ops(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.sim_ns.store(0, Ordering::Relaxed);
        self.ops.store(0, Ordering::Relaxed);
        self.bytes.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_formula() {
        let fs = SimNetFs::new(NetFsProfile {
            name: "t",
            op_latency: 1e-3,
            bandwidth: 1e6,
            concurrency: 4,
            metadata_latency: 1e-2,
        });
        // 10 ops, 1 MB, 1 stream: 10ms + 1s
        let t = fs.charge_io(10, 1_000_000, 1);
        assert!((t - 1.010).abs() < 1e-9);
        // 10 ops with 8 streams: latency divided by concurrency cap (4)
        let t2 = fs.charge_io(10, 0, 8);
        assert!((t2 - 0.0025).abs() < 1e-9);
        let t3 = fs.charge_metadata(3);
        assert!((t3 - 0.03).abs() < 1e-9);
        assert!((fs.sim_seconds() - (t + t2 + t3)).abs() < 1e-6);
        assert_eq!(fs.total_ops(), 23);
        assert_eq!(fs.total_bytes(), 1_000_000);
    }

    #[test]
    fn lustre_vs_vast_shape() {
        // The crossover the paper reports: many small ops → VAST wins;
        // bulk bytes → Lustre wins.
        let l = SimNetFs::new(LUSTRE);
        let v = SimNetFs::new(VAST);
        let small_ops_l = l.charge_io(10_000, 10_000 * 4096, 1);
        let small_ops_v = v.charge_io(10_000, 10_000 * 4096, 1);
        assert!(small_ops_v < small_ops_l, "VAST must win sparse small I/O");
        let bulk_l = l.charge_io(64, 8 << 30, 16);
        let bulk_v = v.charge_io(64, 8 << 30, 16);
        assert!(bulk_l < bulk_v, "Lustre must win bulk streaming");
    }

    #[test]
    fn profiles_resolvable() {
        for n in PROFILE_NAMES {
            assert!(profile_by_name(n).is_some());
        }
        assert!(profile_by_name("gpfs").is_none());
    }

    #[test]
    fn profile_lookup_is_case_insensitive_and_strict_lists_names() {
        assert_eq!(profile_by_name("LUSTRE").unwrap().name, "lustre");
        assert_eq!(profile_by_name("Vast").unwrap().name, "vast");
        assert_eq!(profile_by_name_strict("nVmE").unwrap().name, "nvme");
        let err = profile_by_name_strict("gpfs").unwrap_err().to_string();
        for n in PROFILE_NAMES {
            assert!(err.contains(n), "{err} should list {n}");
        }
    }

    #[test]
    fn bandwidth_delay_products_match_table1_shape() {
        // Lustre: high latency × high bandwidth → MB-scale BDP; VAST is
        // latency-oriented → sub-MB. The adaptive watermark keys off this.
        assert!(LUSTRE.bdp_bytes() > (1 << 20));
        assert!(VAST.bdp_bytes() < (1 << 20));
        assert!(NVME.bdp_bytes() < VAST.bdp_bytes());
    }
}

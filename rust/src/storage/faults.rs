//! Deterministic I/O fault injection (`FaultFs`): the standing
//! robustness harness behind `it_faults.rs`.
//!
//! Every durability-relevant syscall site in the store — file creation,
//! `write`/`pwrite` (including *short* writes), `fsync`, directory
//! fsync, `msync`, `ftruncate`/`fallocate`, `rename`, `mmap`, reflink
//! clones, and reader lease records — asks this layer for permission
//! before performing the real operation. With no plan armed the check
//! is one relaxed atomic load; with a plan armed every intercepted
//! operation is counted (globally and per [`Site`]) and the k-th
//! matching operation fails with the planned errno instead of running.
//!
//! Determinism is the whole point: the ALICE-style sweep first runs a
//! workload in counting mode ([`arm_counting`]) to learn how many
//! injectable operations it performs, then replays it once per index k
//! with `FaultPlan { nth: k, .. }` armed and asserts the recovery
//! oracles after each. Call sites that are normally parallel (the
//! per-file msync fan-out) serialize themselves when a plan is armed
//! ([`armed`]) so operation indices are stable across runs.
//!
//! The layer is process-global (faults must reach free functions in
//! `mgmt_io`/`readers`/`reflink`, not just methods that could carry a
//! handle) and always compiled — like
//! [`crate::util::test_kill_point`], it is env-triggerable in child
//! processes via `METALL_FAULT_PLAN` (`nth=K[;site=NAME][;kind=eio|
//! enospc|eagain|short][;sticky=1]`), and costs one atomic load per
//! I/O when disarmed.
//!
//! Besides injection, this module owns the **failure taxonomy** the
//! hardened error paths share: [`classify`] sorts an [`Error`] into
//! [`FaultClass::Transient`] (EIO/EAGAIN/EINTR/ENOSPC/timeouts —
//! retried by the background engine with its existing backoff) versus
//! [`FaultClass::Permanent`] (EROFS/ENODEV/ENXIO/EBADF — the backend
//! is gone; the manager flips to wounded degraded read-only mode, see
//! `alloc::manager`).

use std::io;
use std::os::unix::fs::FileExt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::error::Error;

/// Number of distinct injection sites (length of [`Site::ALL`]).
pub const SITE_COUNT: usize = 10;

/// One class of intercepted syscall. The sweep fails individual
/// operations by *index*, but per-site streams let a targeted test pin
/// a failure to, say, only manifest renames or only lease writes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    /// `open(O_CREAT | O_EXCL)` / `File::create` of segment chunk
    /// files, section files, manifest temporaries, side-copy
    /// temporaries.
    Create = 0,
    /// `write`/`pwrite` of file bytes (section files, manifest bodies,
    /// pwrite-based segment imports). Short-write capable.
    Write = 1,
    /// `fsync`/`fdatasync` (`File::sync_all`).
    Fsync = 2,
    /// `fsync` of a *directory* (the rename-durability barrier).
    DirFsync = 3,
    /// `msync(MS_SYNC)` of segment ranges.
    Msync = 4,
    /// `ftruncate`/`fallocate` (`File::set_len` growing a segment
    /// file) — the ENOSPC site.
    Truncate = 5,
    /// `rename(2)` (manifest commit, side-copy publish).
    Rename = 6,
    /// `mmap(MAP_FIXED)` of a segment file into the reservation.
    Mmap = 7,
    /// `FICLONERANGE`/`FICLONE` reflink clones and their pread/pwrite
    /// fallback (epoch-side copies, snapshots).
    Reflink = 8,
    /// Reader lease-record `pwrite` (torn-lease injection).
    Lease = 9,
}

impl Site {
    pub const ALL: [Site; SITE_COUNT] = [
        Site::Create,
        Site::Write,
        Site::Fsync,
        Site::DirFsync,
        Site::Msync,
        Site::Truncate,
        Site::Rename,
        Site::Mmap,
        Site::Reflink,
        Site::Lease,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Site::Create => "create",
            Site::Write => "write",
            Site::Fsync => "fsync",
            Site::DirFsync => "dirfsync",
            Site::Msync => "msync",
            Site::Truncate => "truncate",
            Site::Rename => "rename",
            Site::Mmap => "mmap",
            Site::Reflink => "reflink",
            Site::Lease => "lease",
        }
    }

    pub fn from_name(name: &str) -> Option<Site> {
        Site::ALL.iter().copied().find(|s| s.name() == name)
    }
}

/// What the injected operation reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// `EIO` — the canonical transient media error.
    Eio,
    /// `ENOSPC` — disk full (the `extend_to` hardening target).
    Enospc,
    /// `EAGAIN` — transient resource exhaustion.
    Eagain,
    /// Write sites only: write *half* the buffer for real, then fail
    /// with `EIO` — a torn write that partially reached the disk. At
    /// non-write sites this degrades to a plain `EIO`.
    ShortWrite,
}

impl FaultKind {
    fn errno(self) -> i32 {
        match self {
            FaultKind::Eio | FaultKind::ShortWrite => libc::EIO,
            FaultKind::Enospc => libc::ENOSPC,
            FaultKind::Eagain => libc::EAGAIN,
        }
    }

    fn from_name(name: &str) -> Option<FaultKind> {
        match name {
            "eio" => Some(FaultKind::Eio),
            "enospc" => Some(FaultKind::Enospc),
            "eagain" => Some(FaultKind::Eagain),
            "short" => Some(FaultKind::ShortWrite),
            _ => None,
        }
    }
}

/// A deterministic failure schedule: fail the `nth` (1-based)
/// intercepted operation of the selected stream.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    /// 1-based index into the operation stream; `0` never fires
    /// (counting only).
    pub nth: u64,
    /// Restrict the stream to one site; `None` = every intercepted
    /// operation in program order.
    pub site: Option<Site>,
    pub kind: FaultKind,
    /// Keep failing every matching operation after the trigger — a
    /// *permanently* failed backend. One-shot (transient glitch)
    /// otherwise.
    pub sticky: bool,
}

impl FaultPlan {
    /// Fail the k-th operation of the global stream, one-shot.
    pub fn nth_global(nth: u64, kind: FaultKind) -> Self {
        FaultPlan { nth, site: None, kind, sticky: false }
    }

    /// Fail the k-th operation at one site.
    pub fn nth_at(nth: u64, site: Site, kind: FaultKind) -> Self {
        FaultPlan { nth, site: Some(site), kind, sticky: false }
    }

    /// Permanently fail a site starting at its k-th operation.
    pub fn sticky_at(nth: u64, site: Site, kind: FaultKind) -> Self {
        FaultPlan { nth, site: Some(site), kind, sticky: true }
    }

    /// Parse the `METALL_FAULT_PLAN` env format:
    /// `nth=K[;site=NAME][;kind=eio|enospc|eagain|short][;sticky=1]`.
    pub fn parse(spec: &str) -> Option<Self> {
        let mut plan = FaultPlan { nth: 0, site: None, kind: FaultKind::Eio, sticky: false };
        for part in spec.split(';').filter(|p| !p.is_empty()) {
            let (k, v) = part.split_once('=')?;
            match k.trim() {
                "nth" => plan.nth = v.trim().parse().ok()?,
                "site" => plan.site = Some(Site::from_name(v.trim())?),
                "kind" => plan.kind = FaultKind::from_name(v.trim())?,
                "sticky" => plan.sticky = v.trim() == "1" || v.trim() == "true",
                _ => return None,
            }
        }
        (plan.nth > 0).then_some(plan)
    }
}

/// Counts observed between [`arm`]/[`arm_counting`] and [`disarm`] —
/// the failure-site manifest the sweep publishes as a CI artifact.
#[derive(Clone, Debug, Default)]
pub struct FaultReport {
    /// Every intercepted operation, program order.
    pub ops: u64,
    /// Per-site operation counts, indexed like [`Site::ALL`].
    pub site_ops: [u64; SITE_COUNT],
    /// Operations actually failed by the plan.
    pub injected: u64,
}

#[derive(Default)]
struct FaultState {
    plan: Option<FaultPlan>,
    report: FaultReport,
    tripped: bool,
    /// `Some(thread)`: only that thread's operations are intercepted
    /// (and counted). `None`: every thread in the process — what the
    /// dedicated `it_faults` binary uses so background engine threads
    /// are covered; unit tests inside the shared lib test process use
    /// the thread-scoped default so parallel unrelated tests neither
    /// perturb the counters nor trip someone else's plan.
    owner: Option<std::thread::ThreadId>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<FaultState> = Mutex::new(FaultState {
    plan: None,
    report: FaultReport { ops: 0, site_ops: [0; SITE_COUNT], injected: 0 },
    tripped: false,
    owner: None,
});

/// Arm a failure plan scoped to the **calling thread** (resets all
/// counters). The scoping makes arming safe inside a parallel test
/// harness; use [`arm_process_wide`] when background threads must be
/// covered too.
pub fn arm(plan: FaultPlan) {
    arm_scoped(Some(plan), Some(std::thread::current().id()));
}

/// Arm a failure plan covering **every thread** in the process
/// (background flusher/committer included). Callers must serialize
/// with anything else doing I/O in the process.
pub fn arm_process_wide(plan: FaultPlan) {
    arm_scoped(Some(plan), None);
}

/// Count every interceptable operation of the calling thread without
/// failing any — the dry run that sizes a single-threaded sweep.
pub fn arm_counting() {
    arm_scoped(None, Some(std::thread::current().id()));
}

/// Process-wide counting mode (the sweep's dry run: engine threads'
/// operations count too).
pub fn arm_counting_process_wide() {
    arm_scoped(None, None);
}

fn arm_scoped(plan: Option<FaultPlan>, owner: Option<std::thread::ThreadId>) {
    let mut st = STATE.lock().unwrap();
    *st = FaultState { plan, owner, ..Default::default() };
    ENABLED.store(true, Ordering::SeqCst);
}

/// Disarm and return what was observed.
pub fn disarm() -> FaultReport {
    ENABLED.store(false, Ordering::SeqCst);
    let mut st = STATE.lock().unwrap();
    let report = st.report.clone();
    *st = FaultState::default();
    report
}

/// Is a plan (or counting mode) armed? Parallel I/O fan-outs check
/// this and run serially so operation indices stay deterministic.
pub fn armed() -> bool {
    maybe_arm_from_env();
    ENABLED.load(Ordering::Relaxed)
}

/// Serialize tests that arm the fault layer: the plan/counter state is
/// one process-global slot, so two arming tests running on parallel
/// harness threads would clobber each other. Every test that calls
/// [`arm`]/[`arm_counting`]/… holds this guard for its whole body.
#[doc(hidden)]
pub fn test_serial_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Operations intercepted so far under the current arming.
pub fn op_count() -> u64 {
    STATE.lock().unwrap().report.ops
}

/// One-time env-var arming for child processes (`METALL_FAULT_PLAN`).
fn maybe_arm_from_env() {
    static ENV_ONCE: OnceLock<()> = OnceLock::new();
    ENV_ONCE.get_or_init(|| {
        if let Ok(spec) = std::env::var("METALL_FAULT_PLAN") {
            if let Some(plan) = FaultPlan::parse(&spec) {
                // a child process armed from the environment is dedicated
                // to the experiment: cover all of its threads
                arm_process_wide(plan);
            }
        }
    });
}

/// What a write-capable site should do.
enum WriteFate {
    Pass,
    /// Write only this prefix, then report the stashed error.
    Short(usize),
    Fail(io::Error),
}

/// The injected error is a plain `from_raw_os_error` so that
/// `raw_os_error()` survives — the ENOSPC hardening in
/// `SegmentStorage::extend_to` and [`classify_errno`] both key on the
/// real errno, and a wrapped custom error would hide it.
fn injected_error(kind: FaultKind, _site: Site) -> io::Error {
    io::Error::from_raw_os_error(kind.errno())
}

fn intercept(site: Site, write_len: Option<usize>) -> WriteFate {
    if !armed() {
        return WriteFate::Pass;
    }
    let mut st = STATE.lock().unwrap();
    if let Some(owner) = st.owner {
        if owner != std::thread::current().id() {
            return WriteFate::Pass;
        }
    }
    st.report.ops += 1;
    st.report.site_ops[site as usize] += 1;
    let Some(plan) = st.plan else { return WriteFate::Pass };
    if let Some(only) = plan.site {
        if only != site {
            return WriteFate::Pass;
        }
    }
    let idx = match plan.site {
        Some(_) => st.report.site_ops[site as usize],
        None => st.report.ops,
    };
    let fire = if plan.sticky { idx >= plan.nth } else { idx == plan.nth && !st.tripped };
    if !fire {
        return WriteFate::Pass;
    }
    st.tripped = true;
    st.report.injected += 1;
    match (plan.kind, write_len) {
        (FaultKind::ShortWrite, Some(len)) if len > 1 => WriteFate::Short(len / 2),
        (kind, _) => WriteFate::Fail(injected_error(kind, site)),
    }
}

/// Gate a non-write operation (fsync, rename, msync, truncate, mmap,
/// reflink, create). `Ok(())` means "go ahead".
pub fn check(site: Site) -> io::Result<()> {
    match intercept(site, None) {
        WriteFate::Fail(e) => Err(e),
        _ => Ok(()),
    }
}

/// Perform a full buffered write through the fault layer: passes the
/// bytes through untouched normally, simulates a torn (short) write or
/// fails outright when the armed plan says so.
pub fn write_full<W: io::Write>(w: &mut W, buf: &[u8], site: Site) -> io::Result<()> {
    match intercept(site, Some(buf.len())) {
        WriteFate::Pass => w.write_all(buf),
        WriteFate::Short(n) => {
            w.write_all(&buf[..n])?;
            Err(injected_error(FaultKind::ShortWrite, site))
        }
        WriteFate::Fail(e) => Err(e),
    }
}

/// Positioned variant of [`write_full`] (`pwrite` sites).
pub fn write_full_at(f: &std::fs::File, buf: &[u8], off: u64, site: Site) -> io::Result<()> {
    match intercept(site, Some(buf.len())) {
        WriteFate::Pass => f.write_all_at(buf, off),
        WriteFate::Short(n) => {
            f.write_all_at(&buf[..n], off)?;
            Err(injected_error(FaultKind::ShortWrite, site))
        }
        WriteFate::Fail(e) => Err(e),
    }
}

// ------------------------------------------------------ classification --

/// Transient failures are retried (the background engine's existing
/// backoff); permanent ones wound the manager into degraded read-only.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultClass {
    Transient,
    Permanent,
}

/// Classify a raw errno. The permanent set is deliberately small and
/// certain — "the backend is gone, retrying cannot help": read-only
/// remounts, vanished devices, invalidated descriptors. Everything
/// else (EIO flickers, EAGAIN, ENOSPC that an operator can free,
/// unknown codes) is transient; *repeated* transient failures are
/// promoted to permanent by the engine's consecutive-failure limit,
/// not by this table.
pub fn classify_errno(raw: i32) -> FaultClass {
    match raw {
        libc::EROFS | libc::ENODEV | libc::ENXIO | libc::EBADF => FaultClass::Permanent,
        _ => FaultClass::Transient,
    }
}

/// Classify a crate [`Error`] by walking to its underlying OS error,
/// if any. Errors with no errno (logic errors, poisoned state)
/// classify as transient — the consecutive-failure limit still
/// catches a persistently failing path.
pub fn classify(err: &Error) -> FaultClass {
    let source = match err {
        Error::Io { source, .. } => Some(source),
        Error::Sys { source, .. } => Some(source),
        _ => None,
    };
    match source.and_then(|s| s.raw_os_error()) {
        Some(raw) => classify_errno(raw),
        None => FaultClass::Transient,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    #[test]
    fn disarmed_is_passthrough() {
        let _g = test_serial_guard();
        let _ = disarm();
        assert!(check(Site::Fsync).is_ok());
        let mut buf = Vec::new();
        write_full(&mut buf, b"abc", Site::Write).unwrap();
        assert_eq!(buf, b"abc");
    }

    #[test]
    fn counting_mode_counts_without_failing() {
        let _g = test_serial_guard();
        arm_counting();
        assert!(check(Site::Fsync).is_ok());
        assert!(check(Site::Rename).is_ok());
        assert!(check(Site::Fsync).is_ok());
        let r = disarm();
        assert_eq!(r.ops, 3);
        assert_eq!(r.site_ops[Site::Fsync as usize], 2);
        assert_eq!(r.site_ops[Site::Rename as usize], 1);
        assert_eq!(r.injected, 0);
    }

    #[test]
    fn nth_global_fires_once_then_passes() {
        let _g = test_serial_guard();
        arm(FaultPlan::nth_global(2, FaultKind::Eio));
        assert!(check(Site::Msync).is_ok());
        let err = check(Site::Fsync).unwrap_err();
        assert_eq!(err.raw_os_error(), Some(libc::EIO));
        assert!(check(Site::Fsync).is_ok(), "one-shot plan passes after firing");
        let r = disarm();
        assert_eq!((r.ops, r.injected), (3, 1));
    }

    #[test]
    fn site_filtered_stream_ignores_other_sites() {
        let _g = test_serial_guard();
        arm(FaultPlan::nth_at(1, Site::Rename, FaultKind::Enospc));
        assert!(check(Site::Fsync).is_ok());
        assert!(check(Site::Msync).is_ok());
        let err = check(Site::Rename).unwrap_err();
        assert_eq!(err.raw_os_error(), Some(libc::ENOSPC));
        let _ = disarm();
    }

    #[test]
    fn sticky_plan_keeps_failing() {
        let _g = test_serial_guard();
        arm(FaultPlan::sticky_at(1, Site::Fsync, FaultKind::Eio));
        assert!(check(Site::Fsync).is_err());
        assert!(check(Site::Fsync).is_err());
        assert!(check(Site::Write).is_ok(), "other sites unaffected");
        let r = disarm();
        assert_eq!(r.injected, 2);
    }

    #[test]
    fn short_write_leaves_a_torn_prefix() {
        let _g = test_serial_guard();
        arm(FaultPlan::nth_at(1, Site::Write, FaultKind::ShortWrite));
        let mut buf = Vec::new();
        let err = write_full(&mut buf, &[7u8; 10], Site::Write).unwrap_err();
        assert_eq!(err.raw_os_error(), Some(libc::EIO));
        assert_eq!(buf.len(), 5, "half the buffer reached the 'disk'");
        let _ = disarm();
    }

    #[test]
    fn plan_parses_from_env_format() {
        let p = FaultPlan::parse("nth=7;site=msync;kind=enospc;sticky=1").unwrap();
        assert_eq!(p.nth, 7);
        assert_eq!(p.site, Some(Site::Msync));
        assert_eq!(p.kind, FaultKind::Enospc);
        assert!(p.sticky);
        assert!(FaultPlan::parse("nth=0").is_none(), "nth is 1-based");
        assert!(FaultPlan::parse("bogus=1").is_none());
        assert!(FaultPlan::parse("nth=3").is_some());
    }

    #[test]
    fn classification_taxonomy() {
        assert_eq!(classify_errno(libc::EIO), FaultClass::Transient);
        assert_eq!(classify_errno(libc::EAGAIN), FaultClass::Transient);
        assert_eq!(classify_errno(libc::ENOSPC), FaultClass::Transient);
        assert_eq!(classify_errno(libc::EROFS), FaultClass::Permanent);
        assert_eq!(classify_errno(libc::ENODEV), FaultClass::Permanent);
        let e = Error::io("/x", io::Error::from_raw_os_error(libc::EROFS));
        assert_eq!(classify(&e), FaultClass::Permanent);
        assert_eq!(classify(&Error::Alloc("no errno".into())), FaultClass::Transient);
    }

    #[test]
    fn write_full_at_short_write_is_positioned() {
        let _g = test_serial_guard();
        let dir = crate::util::tmp::TempDir::new("faults-wfa");
        let path = dir.join("f");
        let mut f = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .unwrap();
        f.write_all(&[0u8; 16]).unwrap();
        arm(FaultPlan::nth_at(1, Site::Lease, FaultKind::ShortWrite));
        let err = write_full_at(&f, &[9u8; 8], 4, Site::Lease).unwrap_err();
        assert_eq!(err.raw_os_error(), Some(libc::EIO));
        let _ = disarm();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[4..8], &[9u8; 4], "torn prefix landed at the offset");
        assert_eq!(&bytes[8..12], &[0u8; 4], "tail never written");
    }
}

//! Mergeable log-linear latency histograms (ISSUE 10 tentpole, layer 1).
//!
//! A [`Histogram`] is a fixed array of `AtomicU64` buckets covering the
//! whole `u64` nanosecond range with bounded relative error: values are
//! binned log-linearly — each power-of-two octave is split into
//! `2^SUB_BITS` equal-width sub-buckets — so a bucket's width is at most
//! `1/8` of its lower bound (HdrHistogram's scheme with 3 significant
//! bits). Recording is a single `fetch_add(Relaxed)` per bucket plus the
//! count/sum accumulators: lock-free, wait-free, and safe from any
//! thread or signal context.
//!
//! [`ShardedHistogram`] stripes records across per-CPU shards (selected
//! by [`crate::alloc::object_cache::current_vcpu`], the same affinity
//! key `AllocShard` uses) so concurrent recorders on different cores
//! never contend on one cache line; shards merge losslessly into one
//! [`HistogramSnapshot`] at read time. Merging is exact — buckets add —
//! so quantile estimates from a merged snapshot equal those from a
//! single histogram fed the union of samples, and merge order cannot
//! matter (associativity is tested below).

use std::sync::atomic::{AtomicU64, Ordering};

/// Sub-bucket resolution: each power-of-two octave is split into
/// `2^SUB_BITS = 8` linear sub-buckets (≤ 12.5 % relative bucket width).
pub const SUB_BITS: u32 = 3;
const SUB: usize = 1 << SUB_BITS;

/// Total bucket count. The largest index is reached at `v = u64::MAX`:
/// msb 63 ⇒ octave index 61 ⇒ `61 * 8 + 7 = 495`.
pub const NUM_BUCKETS: usize = 62 * SUB;

/// Bucket index for a value (total order, contiguous from 0).
#[inline]
pub fn bucket_of(v: u64) -> usize {
    if v < SUB as u64 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros();
    let shift = msb - SUB_BITS;
    let sub = ((v >> shift) & (SUB as u64 - 1)) as usize;
    ((shift + 1) as usize) * SUB + sub
}

/// Smallest value that lands in bucket `i`.
#[inline]
pub fn bucket_lower(i: usize) -> u64 {
    if i < SUB {
        return i as u64;
    }
    let oct = i / SUB;
    let sub = i % SUB;
    ((SUB + sub) as u64) << (oct - 1)
}

/// Largest value that lands in bucket `i`.
#[inline]
pub fn bucket_upper(i: usize) -> u64 {
    if i + 1 >= NUM_BUCKETS {
        u64::MAX
    } else {
        bucket_lower(i + 1) - 1
    }
}

/// Lock-free log-linear histogram; every method takes `&self`.
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: Vec<AtomicU64>,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        let mut buckets = Vec::with_capacity(NUM_BUCKETS);
        buckets.resize_with(NUM_BUCKETS, AtomicU64::default);
        Histogram { count: AtomicU64::new(0), sum: AtomicU64::new(0), buckets }
    }

    /// Record one value. Wait-free: three relaxed `fetch_add`s.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Fold this histogram's buckets into `snap` (exact, associative).
    pub fn merge_into(&self, snap: &mut HistogramSnapshot) {
        snap.count += self.count.load(Ordering::Relaxed);
        snap.sum += self.sum.load(Ordering::Relaxed);
        for (dst, src) in snap.buckets.iter_mut().zip(&self.buckets) {
            *dst += src.load(Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut s = HistogramSnapshot::empty();
        self.merge_into(&mut s);
        s
    }
}

/// An owned, plain-integer copy of a histogram (or a merge of several).
#[derive(Clone)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    pub buckets: Vec<u64>,
}

impl HistogramSnapshot {
    pub fn empty() -> Self {
        HistogramSnapshot { count: 0, sum: 0, buckets: vec![0; NUM_BUCKETS] }
    }

    /// Exact bucket-wise merge with another snapshot.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        self.count += other.count;
        self.sum += other.sum;
        for (dst, src) in self.buckets.iter_mut().zip(&other.buckets) {
            *dst += src;
        }
    }

    /// Quantile estimate: the upper bound of the bucket holding the
    /// rank-`⌈q·count⌉` sample, i.e. within one log-linear bucket
    /// (≤ 12.5 % relative error) of the exact order statistic.
    /// Returns 0 on an empty snapshot.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                return bucket_upper(i);
            }
        }
        bucket_upper(NUM_BUCKETS - 1)
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

/// Per-CPU sharded histogram: records go to the shard of the calling
/// thread's virtual CPU, reads merge every shard.
pub struct ShardedHistogram {
    shards: Vec<Histogram>,
    mask: usize,
}

impl ShardedHistogram {
    /// `nshards` is rounded up to a power of two (max 64) so shard
    /// selection is a mask, mirroring the object-cache slot mapping.
    pub fn new(nshards: usize) -> Self {
        let n = nshards.clamp(1, 64).next_power_of_two();
        let mut shards = Vec::with_capacity(n);
        shards.resize_with(n, Histogram::new);
        ShardedHistogram { shards, mask: n - 1 }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        let cpu = crate::alloc::object_cache::current_vcpu();
        self.shards[cpu & self.mask].record(v);
    }

    pub fn count(&self) -> u64 {
        self.shards.iter().map(Histogram::count).sum()
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut s = HistogramSnapshot::empty();
        for h in &self.shards {
            h.merge_into(&mut s);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic xorshift so the distribution tests are seeded.
    struct Rng(u64);
    impl Rng {
        fn next(&mut self) -> u64 {
            let mut x = self.0;
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            self.0 = x;
            x
        }
    }

    #[test]
    fn bucket_bounds_are_contiguous_and_ordered() {
        assert_eq!(bucket_lower(0), 0);
        for i in 1..NUM_BUCKETS {
            assert_eq!(
                bucket_lower(i),
                bucket_upper(i - 1) + 1,
                "gap/overlap at bucket {i}"
            );
        }
        assert_eq!(bucket_upper(NUM_BUCKETS - 1), u64::MAX);
        for &v in &[0u64, 1, 7, 8, 15, 16, 100, 1_000, 1 << 20, u64::MAX] {
            let b = bucket_of(v);
            assert!(bucket_lower(b) <= v && v <= bucket_upper(b), "v={v} b={b}");
        }
    }

    /// Quantile estimates stay within one bucket of the exact sorted-
    /// oracle order statistic across several seeded distributions.
    #[test]
    fn quantiles_within_one_bucket_of_oracle() {
        let distributions: Vec<(&str, Vec<u64>)> = {
            let mut rng = Rng(0x9e3779b97f4a7c15);
            let uniform: Vec<u64> = (0..10_000).map(|_| rng.next() % 1_000_000).collect();
            let exponentialish: Vec<u64> =
                (0..10_000).map(|_| 1u64 << (rng.next() % 30)).collect();
            // Bimodal: fast cache hits plus rare slow syncs — the shape
            // the tail metrics exist to expose.
            let bimodal: Vec<u64> = (0..10_000)
                .map(|_| {
                    if rng.next() % 100 < 95 {
                        200 + rng.next() % 300
                    } else {
                        2_000_000 + rng.next() % 1_000_000
                    }
                })
                .collect();
            vec![("uniform", uniform), ("exp", exponentialish), ("bimodal", bimodal)]
        };
        for (name, samples) in distributions {
            let h = Histogram::new();
            for &v in &samples {
                h.record(v);
            }
            let mut sorted = samples.clone();
            sorted.sort_unstable();
            let snap = h.snapshot();
            for &q in &[0.5, 0.9, 0.99, 0.999] {
                let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
                let exact = sorted[rank - 1];
                let est = snap.quantile(q);
                let db = bucket_of(est).abs_diff(bucket_of(exact));
                assert!(
                    db <= 1,
                    "{name} q={q}: est {est} vs exact {exact} ({db} buckets apart)"
                );
            }
        }
    }

    #[test]
    fn merge_is_associative_and_exact() {
        let mut rng = Rng(42);
        let mk = |rng: &mut Rng| {
            let h = Histogram::new();
            for _ in 0..5_000 {
                h.record(rng.next() % 10_000_000);
            }
            h.snapshot()
        };
        let (a, b, c) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));

        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);

        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);

        assert_eq!(ab_c.count, a_bc.count);
        assert_eq!(ab_c.sum, a_bc.sum);
        assert_eq!(ab_c.buckets, a_bc.buckets);
        assert_eq!(ab_c.count, a.count + b.count + c.count);
        for q in [0.5, 0.99, 0.999] {
            assert_eq!(ab_c.quantile(q), a_bc.quantile(q));
        }
    }

    /// N threads × M records: total count is exactly N·M regardless of
    /// interleaving (sharded recording loses nothing).
    #[test]
    fn concurrent_record_count_is_deterministic() {
        use std::sync::Arc;
        let h = Arc::new(ShardedHistogram::new(8));
        let threads = 8;
        let per = 20_000u64;
        let mut js = Vec::new();
        for t in 0..threads {
            let h = Arc::clone(&h);
            js.push(std::thread::spawn(move || {
                let mut rng = Rng(0xabcd + t as u64);
                for _ in 0..per {
                    h.record(rng.next() % 1_000_000);
                }
            }));
        }
        for j in js {
            j.join().unwrap();
        }
        let snap = h.snapshot();
        assert_eq!(snap.count, threads as u64 * per);
        assert_eq!(snap.buckets.iter().sum::<u64>(), snap.count);
    }

    #[test]
    fn empty_histogram_quantiles_are_zero() {
        let s = Histogram::new().snapshot();
        assert_eq!(s.quantile(0.5), 0);
        assert_eq!(s.quantile(0.999), 0);
        assert_eq!(s.mean(), 0.0);
    }
}

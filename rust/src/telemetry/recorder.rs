//! Crash-persisted flight recorder (ISSUE 10 tentpole, layer 2).
//!
//! A fixed-size lock-free ring of structured engine events, written
//! *through* an `mmap(MAP_SHARED)` file at `<store>/diag/flight-<pid>.bin`
//! from the moment the manager opens. Because every `record` lands
//! directly in the shared mapping, the kernel page cache owns the bytes
//! the instant they are written: a `kill -9` (which can run no handler)
//! still leaves the ring on disk, and an explicit [`FlightRecorder::flush`]
//! (`msync`) on wound / panic containment / failed close makes the tail
//! durable against machine loss too.
//!
//! Torn tails are expected, not fatal: each 64-byte slot carries its own
//! FNV-1a checksum, so a reader ([`load`]) keeps exactly the slots that
//! verify and orders them by sequence number. Writers never coordinate
//! beyond one `fetch_add` on the head counter; two writers can only
//! collide on a slot after the ring laps itself inside the race window,
//! and the loser is at worst one discarded (checksum-failing) slot.
//!
//! The ring is diagnostics, never a correctness input: every I/O error
//! downgrades to "no recorder" and the file set per store is bounded
//! ([`MAX_DIAG_FILES`] newest kept).

use std::fs;
use std::io::{self, Read as _, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::util::fnv1a;

const MAGIC: u64 = 0x4d54_4c5f_464c_5431; // "MTL_FLT1"
const VERSION: u32 = 1;
const HDR_SIZE: usize = 64;
const SLOT_SIZE: usize = 64;
/// Checksummed prefix of a slot (seq..c inclusive).
const SLOT_CRC_OVER: usize = 48;
/// Default ring capacity in events (64 KiB of slots).
pub const DEFAULT_CAPACITY: u32 = 1024;
/// Newest `flight-*.bin` files kept per store (`diag/` stays bounded).
pub const MAX_DIAG_FILES: usize = 8;

/// What happened. Stored as a `u32` in the slot; unknown values from a
/// newer writer render as `event#N` instead of failing the parse.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(u32)]
pub enum EventKind {
    /// Recorder created (code: 1 = read-write owner, 2 = reader attach).
    Open = 1,
    /// Epoch cut + serialized (a: epoch, b: data bytes, c: dirty sections).
    EpochPrepared = 2,
    /// Epoch manifest durably committed (a: epoch, b: data bytes).
    EpochCommitted = 3,
    /// Epoch aborted, dirty flags restored (a: epoch).
    EpochAborted = 4,
    /// Flusher woke on the dirty-byte watermark (a: dirty bytes, b: watermark).
    WatermarkKick = 5,
    /// Flusher woke on the interval timer.
    IntervalKick = 6,
    /// Writer stalled at the backpressure ceiling (a: stall µs, b: dirty bytes).
    CeilingStall = 7,
    /// A sync round failed (code: [`crate::storage::faults::FaultClass`]
    /// as 0 = transient / 1 = permanent; a: consecutive failures).
    FlushFailure = 8,
    /// Manager wounded → degraded read-only (a: consecutive failures).
    Wound = 9,
    /// Flusher or committer thread panicked; engine dead (code: 1 =
    /// flusher, 2 = committer).
    EngineDead = 10,
    /// Stale reader leases reaped at cut time (a: reaped count).
    LeaseReap = 11,
    /// Recovery rolled an unsealed op-log record forward (a: seq).
    RecoveryReplay = 12,
    /// Recovery rolled an unsealed op-log record back (a: seq).
    RecoveryRollback = 13,
    /// Recovery adopted a committed record's allocations (a: seq).
    RecoveryAdopt = 14,
    /// ENOSPC on the allocation path rolled back (a: chunks released).
    ExtendRollback = 15,
    /// `close()` failed; store left unclean (a: 0, see breadcrumbs).
    CloseFailed = 16,
}

impl EventKind {
    pub fn from_u32(v: u32) -> Option<EventKind> {
        use EventKind::*;
        Some(match v {
            1 => Open,
            2 => EpochPrepared,
            3 => EpochCommitted,
            4 => EpochAborted,
            5 => WatermarkKick,
            6 => IntervalKick,
            7 => CeilingStall,
            8 => FlushFailure,
            9 => Wound,
            10 => EngineDead,
            11 => LeaseReap,
            12 => RecoveryReplay,
            13 => RecoveryRollback,
            14 => RecoveryAdopt,
            15 => ExtendRollback,
            16 => CloseFailed,
            _ => return None,
        })
    }
}

/// One decoded ring slot.
#[derive(Clone, Copy, Debug)]
pub struct FlightEvent {
    pub seq: u64,
    /// Monotonic nanoseconds since the recorder was created.
    pub t_ns: u64,
    pub kind: u32,
    pub code: u32,
    pub a: u64,
    pub b: u64,
    pub c: u64,
}

impl FlightEvent {
    /// Human-readable one-liner (used by `metall trace` / `doctor`).
    pub fn describe(&self) -> String {
        let t = self.t_ns as f64 / 1e9;
        let body = match EventKind::from_u32(self.kind) {
            Some(EventKind::Open) => match self.code {
                1 => "open (read-write owner)".to_string(),
                2 => "open (reader attach)".to_string(),
                c => format!("open (mode {c})"),
            },
            Some(EventKind::EpochPrepared) => format!(
                "epoch {} prepared: {} data bytes, {} dirty sections",
                self.a, self.b, self.c
            ),
            Some(EventKind::EpochCommitted) => {
                format!("epoch {} committed ({} data bytes)", self.a, self.b)
            }
            Some(EventKind::EpochAborted) => {
                format!("epoch {} aborted; dirty flags restored", self.a)
            }
            Some(EventKind::WatermarkKick) => format!(
                "watermark kick: {} dirty bytes >= {} watermark",
                self.a, self.b
            ),
            Some(EventKind::IntervalKick) => "interval kick".to_string(),
            Some(EventKind::CeilingStall) => format!(
                "writer stalled {} us at backpressure ceiling ({} dirty bytes)",
                self.a, self.b
            ),
            Some(EventKind::FlushFailure) => format!(
                "flush failure #{} ({})",
                self.a,
                if self.code == 1 { "permanent" } else { "transient" }
            ),
            Some(EventKind::Wound) => format!(
                "WOUND: manager degraded read-only after {} consecutive failures",
                self.a
            ),
            Some(EventKind::EngineDead) => format!(
                "engine dead: {} thread panicked",
                if self.code == 2 { "committer" } else { "flusher" }
            ),
            Some(EventKind::LeaseReap) => {
                format!("reaped {} stale reader lease(s)", self.a)
            }
            Some(EventKind::RecoveryReplay) => {
                format!("recovery: op-log seq {} rolled forward", self.a)
            }
            Some(EventKind::RecoveryRollback) => {
                format!("recovery: op-log seq {} rolled back", self.a)
            }
            Some(EventKind::RecoveryAdopt) => {
                format!("recovery: op-log seq {} allocations adopted", self.a)
            }
            Some(EventKind::ExtendRollback) => {
                format!("ENOSPC: allocation rolled back ({} chunk(s) released)", self.a)
            }
            Some(EventKind::CloseFailed) => "close failed; store left unclean".to_string(),
            None => format!("event#{} code={} a={} b={} c={}", self.kind, self.code, self.a, self.b, self.c),
        };
        format!("[{t:>10.6}s #{:>4}] {body}", self.seq)
    }
}

/// A parsed dump: header fields plus the valid slots in sequence order.
pub struct FlightDump {
    pub pid: u32,
    pub capacity: u32,
    /// UNIX wall-clock nanoseconds when the recorder was created
    /// (anchors the events' relative timestamps).
    pub wall_anchor_ns: u64,
    pub events: Vec<FlightEvent>,
}

/// The live writer side: an `mmap(MAP_SHARED)` ring over the dump file.
pub struct FlightRecorder {
    map: *mut u8,
    len: usize,
    capacity: u64,
    head: AtomicU64,
    start: Instant,
    path: PathBuf,
}

// The raw pointer is to a private shared mapping written only through
// atomic head reservation; see module docs for the collision story.
unsafe impl Send for FlightRecorder {}
unsafe impl Sync for FlightRecorder {}

fn le64(buf: &mut [u8], off: usize, v: u64) {
    buf[off..off + 8].copy_from_slice(&v.to_le_bytes());
}
fn le32(buf: &mut [u8], off: usize, v: u32) {
    buf[off..off + 4].copy_from_slice(&v.to_le_bytes());
}
fn rd64(buf: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(buf[off..off + 8].try_into().unwrap())
}
fn rd32(buf: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(buf[off..off + 4].try_into().unwrap())
}

impl FlightRecorder {
    /// Create the per-process ring under `<store>/diag/`, pruning the
    /// oldest dump files beyond [`MAX_DIAG_FILES`]. `mode` is stamped
    /// into the `Open` event (1 = rw owner, 2 = reader).
    pub fn create(store: &Path, mode: u32) -> io::Result<FlightRecorder> {
        Self::create_with_capacity(store, mode, DEFAULT_CAPACITY)
    }

    pub fn create_with_capacity(
        store: &Path,
        mode: u32,
        capacity: u32,
    ) -> io::Result<FlightRecorder> {
        let capacity = capacity.max(8);
        let diag = store.join("diag");
        fs::create_dir_all(&diag)?;
        prune_old_dumps(&diag, MAX_DIAG_FILES.saturating_sub(1));

        let pid = std::process::id();
        let path = diag.join(format!("flight-{pid}.bin"));
        let len = HDR_SIZE + capacity as usize * SLOT_SIZE;

        let mut header = [0u8; HDR_SIZE];
        le64(&mut header, 0, MAGIC);
        le32(&mut header, 8, VERSION);
        le32(&mut header, 12, capacity);
        le32(&mut header, 16, pid);
        let wall = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        le64(&mut header, 24, wall);
        let crc = fnv1a(&header[..56]);
        le64(&mut header, 56, crc);

        let file = fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)?;
        file.set_len(len as u64)?;
        {
            let mut f = &file;
            f.write_all(&header)?;
        }

        let map = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                std::os::unix::io::AsRawFd::as_raw_fd(&file),
                0,
            )
        };
        if map == libc::MAP_FAILED {
            return Err(io::Error::last_os_error());
        }
        let rec = FlightRecorder {
            map: map as *mut u8,
            len,
            capacity: capacity as u64,
            head: AtomicU64::new(0),
            start: Instant::now(),
            path,
        };
        rec.record(EventKind::Open, mode, 0, 0, 0);
        Ok(rec)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one event. Lock-free; safe from any thread.
    #[inline]
    pub fn record(&self, kind: EventKind, code: u32, a: u64, b: u64, c: u64) {
        let seq = self.head.fetch_add(1, Ordering::Relaxed);
        let t_ns = self.start.elapsed().as_nanos() as u64;
        let mut slot = [0u8; SLOT_SIZE];
        le64(&mut slot, 0, seq);
        le64(&mut slot, 8, t_ns);
        le32(&mut slot, 16, kind as u32);
        le32(&mut slot, 20, code);
        le64(&mut slot, 24, a);
        le64(&mut slot, 32, b);
        le64(&mut slot, 40, c);
        let crc = fnv1a(&slot[..SLOT_CRC_OVER]);
        le64(&mut slot, 48, crc);
        let off = HDR_SIZE + (seq % self.capacity) as usize * SLOT_SIZE;
        // In-bounds by construction; the mapping lives as long as self.
        unsafe {
            std::ptr::copy_nonoverlapping(slot.as_ptr(), self.map.add(off), SLOT_SIZE);
        }
    }

    /// `msync` the whole ring — called on wound, panic containment, and
    /// failed close. Best-effort: an error here must never mask the
    /// failure being recorded.
    pub fn flush(&self) {
        unsafe {
            libc::msync(self.map as *mut libc::c_void, self.len, libc::MS_SYNC);
        }
    }
}

impl Drop for FlightRecorder {
    fn drop(&mut self) {
        unsafe {
            libc::msync(self.map as *mut libc::c_void, self.len, libc::MS_ASYNC);
            libc::munmap(self.map as *mut libc::c_void, self.len);
        }
    }
}

fn prune_old_dumps(diag: &Path, keep: usize) {
    let mut dumps: Vec<(std::time::SystemTime, PathBuf)> = Vec::new();
    let Ok(rd) = fs::read_dir(diag) else { return };
    for ent in rd.flatten() {
        let name = ent.file_name();
        let name = name.to_string_lossy();
        if name.starts_with("flight-") && name.ends_with(".bin") {
            let mtime = ent
                .metadata()
                .and_then(|m| m.modified())
                .unwrap_or(std::time::UNIX_EPOCH);
            dumps.push((mtime, ent.path()));
        }
    }
    if dumps.len() <= keep {
        return;
    }
    dumps.sort_by_key(|(t, _)| *t);
    let excess = dumps.len() - keep;
    for (_, p) in dumps.into_iter().take(excess) {
        let _ = fs::remove_file(p);
    }
}

/// Parse a dump file: validate the header, keep every slot whose
/// checksum verifies, order by sequence number. Torn or zero slots are
/// silently skipped — a post-crash ring is expected to have a ragged
/// tail.
pub fn load(path: &Path) -> io::Result<FlightDump> {
    let mut bytes = Vec::new();
    fs::File::open(path)?.read_to_end(&mut bytes)?;
    if bytes.len() < HDR_SIZE {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "flight dump truncated"));
    }
    if rd64(&bytes, 0) != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad flight dump magic"));
    }
    if rd64(&bytes, 56) != fnv1a(&bytes[..56]) {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "flight header checksum"));
    }
    let capacity = rd32(&bytes, 12);
    let pid = rd32(&bytes, 16);
    let wall_anchor_ns = rd64(&bytes, 24);
    let nslots = ((bytes.len() - HDR_SIZE) / SLOT_SIZE).min(capacity as usize);
    let mut events = Vec::new();
    for i in 0..nslots {
        let off = HDR_SIZE + i * SLOT_SIZE;
        let slot = &bytes[off..off + SLOT_SIZE];
        let kind = rd32(slot, 16);
        if kind == 0 {
            continue; // never written
        }
        if rd64(slot, 48) != fnv1a(&slot[..SLOT_CRC_OVER]) {
            continue; // torn write
        }
        events.push(FlightEvent {
            seq: rd64(slot, 0),
            t_ns: rd64(slot, 8),
            kind,
            code: rd32(slot, 20),
            a: rd64(slot, 24),
            b: rd64(slot, 32),
            c: rd64(slot, 40),
        });
    }
    events.sort_by_key(|e| e.seq);
    Ok(FlightDump { pid, capacity, wall_anchor_ns, events })
}

/// The newest `flight-*.bin` under `<store>/diag/`, if any.
pub fn newest_dump(store: &Path) -> Option<PathBuf> {
    let diag = store.join("diag");
    let mut best: Option<(std::time::SystemTime, PathBuf)> = None;
    for ent in fs::read_dir(diag).ok()?.flatten() {
        let name = ent.file_name();
        let name = name.to_string_lossy().into_owned();
        if !(name.starts_with("flight-") && name.ends_with(".bin")) {
            continue;
        }
        let mtime = ent
            .metadata()
            .and_then(|m| m.modified())
            .unwrap_or(std::time::UNIX_EPOCH);
        if best.as_ref().map(|(t, _)| mtime >= *t).unwrap_or(true) {
            best = Some((mtime, ent.path()));
        }
    }
    best.map(|(_, p)| p)
}

/// Render the last `tail` events of a dump as human-readable lines.
pub fn render_tail(dump: &FlightDump, tail: usize) -> Vec<String> {
    let skip = dump.events.len().saturating_sub(tail);
    dump.events[skip..].iter().map(FlightEvent::describe).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_roundtrip_and_wrap() {
        let dir = tempdir("flt-roundtrip");
        let rec = FlightRecorder::create_with_capacity(&dir, 1, 16).unwrap();
        for i in 0..40u64 {
            rec.record(EventKind::EpochCommitted, 0, i, i * 10, 0);
        }
        rec.flush();
        let path = rec.path().to_path_buf();
        drop(rec);

        let dump = load(&path).unwrap();
        assert_eq!(dump.pid, std::process::id());
        // 41 events written (Open + 40); ring holds the newest 16.
        assert_eq!(dump.events.len(), 16);
        let seqs: Vec<u64> = dump.events.iter().map(|e| e.seq).collect();
        let expect: Vec<u64> = (25..41).collect();
        assert_eq!(seqs, expect);
        let last = dump.events.last().unwrap();
        assert_eq!(EventKind::from_u32(last.kind), Some(EventKind::EpochCommitted));
        assert_eq!(last.a, 39);
        assert!(last.describe().contains("epoch 39 committed"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_slot_is_skipped_not_fatal() {
        let dir = tempdir("flt-torn");
        let rec = FlightRecorder::create_with_capacity(&dir, 1, 16).unwrap();
        rec.record(EventKind::Wound, 0, 3, 0, 0);
        rec.flush();
        let path = rec.path().to_path_buf();
        drop(rec);

        // Corrupt the second slot (the Wound event) on disk.
        let mut bytes = fs::read(&path).unwrap();
        bytes[HDR_SIZE + SLOT_SIZE + 24] ^= 0xff;
        fs::write(&path, &bytes).unwrap();

        let dump = load(&path).unwrap();
        assert_eq!(dump.events.len(), 1, "only the Open event survives");
        assert_eq!(EventKind::from_u32(dump.events[0].kind), Some(EventKind::Open));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn diag_dir_is_bounded() {
        let dir = tempdir("flt-bound");
        let diag = dir.join("diag");
        fs::create_dir_all(&diag).unwrap();
        for i in 0..20 {
            fs::write(diag.join(format!("flight-{i}.bin")), b"x").unwrap();
        }
        let rec = FlightRecorder::create(&dir, 1).unwrap();
        drop(rec);
        let n = fs::read_dir(&diag).unwrap().count();
        assert!(n <= MAX_DIAG_FILES, "diag holds {n} files");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    fn tempdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "metall-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }
}

//! Prometheus / JSON rendering (ISSUE 10 tentpole, layer 3).
//!
//! A [`StatsBundle`] is the flat export view of a store: the
//! `coordinator::metrics` counter/timer snapshot, per-op latency
//! quantiles from [`crate::telemetry::Telemetry::snapshot`], and the
//! flight-recorder tail. Renderers are pure string builders — no I/O —
//! so `metall stats --watch` can re-render cheaply and tests can
//! validate the exposition line-by-line.
//!
//! Prometheus text-format rules honored here: metric names match
//! `[a-zA-Z_:][a-zA-Z0-9_:]*` (our dotted keys are sanitized and
//! prefixed `metall_`), every sample is `name{labels} value`, summaries
//! expose `{quantile="…"}` series plus `_sum`/`_count`, and `# TYPE`
//! precedes the first sample of each metric.

use crate::telemetry::{histogram::HistogramSnapshot, Op};
use crate::util::jsonw::{quote, JsonObj};

/// Per-op latency quantiles (nanoseconds), precomputed from a
/// [`HistogramSnapshot`] so renderers and bridges share one shape.
#[derive(Clone, Copy)]
pub struct OpLatency {
    pub op: &'static str,
    pub count: u64,
    pub sum_ns: u64,
    pub p50: u64,
    pub p90: u64,
    pub p99: u64,
    pub p999: u64,
}

impl OpLatency {
    pub fn from_snapshot(op: Op, snap: &HistogramSnapshot) -> OpLatency {
        OpLatency {
            op: op.name(),
            count: snap.count,
            sum_ns: snap.sum,
            p50: snap.quantile(0.50),
            p90: snap.quantile(0.90),
            p99: snap.quantile(0.99),
            p999: snap.quantile(0.999),
        }
    }
}

/// Everything `metall stats` exports, already flattened.
#[derive(Default)]
pub struct StatsBundle {
    /// `coordinator::metrics` counters (`alloc.allocs`, …), sorted.
    pub counters: Vec<(String, u64)>,
    /// `coordinator::metrics` timers in seconds, sorted.
    pub timers: Vec<(String, f64)>,
    /// One entry per [`Op`], in [`Op::ALL`] order.
    pub latencies: Vec<OpLatency>,
    /// Human-readable flight-recorder tail (may be empty).
    pub events: Vec<String>,
}

impl StatsBundle {
    pub fn with_latencies(snaps: &[(Op, HistogramSnapshot)]) -> StatsBundle {
        StatsBundle {
            latencies: snaps
                .iter()
                .map(|(op, s)| OpLatency::from_snapshot(*op, s))
                .collect(),
            ..StatsBundle::default()
        }
    }
}

/// Sanitize a dotted metric key into a Prometheus metric name:
/// `alloc.lat.alloc_small.p99` → `metall_alloc_lat_alloc_small_p99`.
pub fn prom_name(key: &str) -> String {
    let mut out = String::with_capacity(key.len() + 7);
    out.push_str("metall_");
    for ch in key.chars() {
        if ch.is_ascii_alphanumeric() || ch == '_' || ch == ':' {
            out.push(ch);
        } else {
            out.push('_');
        }
    }
    out
}

/// Prometheus text exposition (version 0.0.4).
pub fn render_prometheus(b: &StatsBundle) -> String {
    let mut out = String::new();
    for (k, v) in &b.counters {
        let name = prom_name(k);
        out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
    }
    for (k, v) in &b.timers {
        let name = format!("{}_seconds", prom_name(&format!("time.{k}")));
        out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
    }
    for l in &b.latencies {
        let name = prom_name(&format!("alloc.lat.{}.ns", l.op));
        out.push_str(&format!("# TYPE {name} summary\n"));
        for (q, v) in [("0.5", l.p50), ("0.9", l.p90), ("0.99", l.p99), ("0.999", l.p999)] {
            out.push_str(&format!("{name}{{quantile=\"{q}\"}} {v}\n"));
        }
        out.push_str(&format!("{name}_sum {}\n{name}_count {}\n", l.sum_ns, l.count));
    }
    out
}

/// JSON rendering (single object; stable key order).
pub fn render_json(b: &StatsBundle) -> String {
    let mut counters = String::from("{");
    for (i, (k, v)) in b.counters.iter().enumerate() {
        if i > 0 {
            counters.push(',');
        }
        counters.push_str(&format!("{}:{}", quote(k), v));
    }
    counters.push('}');

    let mut timers = String::from("{");
    for (i, (k, v)) in b.timers.iter().enumerate() {
        if i > 0 {
            timers.push(',');
        }
        timers.push_str(&format!("{}:{}", quote(k), v));
    }
    timers.push('}');

    let mut lats = String::from("{");
    for (i, l) in b.latencies.iter().enumerate() {
        if i > 0 {
            lats.push(',');
        }
        let obj = JsonObj::new()
            .int("count", l.count as i64)
            .int("sum_ns", l.sum_ns as i64)
            .int("p50_ns", l.p50 as i64)
            .int("p90_ns", l.p90 as i64)
            .int("p99_ns", l.p99 as i64)
            .int("p999_ns", l.p999 as i64)
            .finish();
        lats.push_str(&format!("{}:{}", quote(l.op), obj));
    }
    lats.push('}');

    let mut events = String::from("[");
    for (i, e) in b.events.iter().enumerate() {
        if i > 0 {
            events.push(',');
        }
        events.push_str(&quote(e));
    }
    events.push(']');

    JsonObj::new()
        .raw("counters", &counters)
        .raw("timers_s", &timers)
        .raw("latency", &lats)
        .raw("events", &events)
        .finish()
}

/// Minimal Prometheus text-format checker used by tests and
/// `metall stats --check`: every line is a comment or
/// `name[{labels}] value`, names are legal, and every sample's metric
/// was introduced by a `# TYPE` line.
pub fn validate_prometheus(text: &str) -> Result<(), String> {
    let mut typed: Vec<String> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let n = lineno + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().ok_or(format!("line {n}: TYPE without name"))?;
            let kind = it.next().ok_or(format!("line {n}: TYPE without kind"))?;
            if !matches!(kind, "counter" | "gauge" | "summary" | "histogram" | "untyped") {
                return Err(format!("line {n}: bad TYPE kind {kind}"));
            }
            typed.push(name.to_string());
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        let (name_part, value_part) = match line.find(' ') {
            Some(sp) => (&line[..sp], line[sp + 1..].trim()),
            None => return Err(format!("line {n}: no value")),
        };
        let bare = match name_part.find('{') {
            Some(br) => {
                if !name_part.ends_with('}') {
                    return Err(format!("line {n}: unterminated labels"));
                }
                &name_part[..br]
            }
            None => name_part,
        };
        let mut chars = bare.chars();
        let ok_first = chars
            .next()
            .map(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
            .unwrap_or(false);
        if !ok_first || !chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':') {
            return Err(format!("line {n}: illegal metric name {bare}"));
        }
        if value_part.parse::<f64>().is_err() {
            return Err(format!("line {n}: non-numeric value {value_part}"));
        }
        // A summary's _sum/_count series belong to the base family.
        let family = bare
            .strip_suffix("_sum")
            .or_else(|| bare.strip_suffix("_count"))
            .unwrap_or(bare);
        if !typed.iter().any(|t| t == bare || t == family) {
            return Err(format!("line {n}: sample {bare} without # TYPE"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Telemetry;

    fn bundle() -> StatsBundle {
        let t = Telemetry::new(1, 1);
        t.record_ns(Op::AllocSmall, 500);
        t.record_ns(Op::AllocSmall, 900);
        t.record_ns(Op::EpochCommit, 40_000);
        t.record_ns(Op::Attach, 7_000);
        let mut b = StatsBundle::with_latencies(&t.snapshot());
        b.counters = vec![("alloc.allocs".into(), 2), ("alloc.shard0.claims".into(), 1)];
        b.timers = vec![("sync".into(), 0.125)];
        b.events = vec!["[  0.000001s #   0] open (read-write owner)".into()];
        b
    }

    #[test]
    fn prometheus_output_is_valid_and_complete() {
        let text = render_prometheus(&bundle());
        validate_prometheus(&text).unwrap();
        assert!(text.contains("metall_alloc_allocs 2"));
        assert!(text.contains("metall_time_sync_seconds 0.125"));
        // Every op appears with p99/p999 quantiles even when empty.
        for op in Op::ALL {
            let name = format!("metall_alloc_lat_{}_ns", op.name());
            assert!(text.contains(&format!("{name}{{quantile=\"0.99\"}}")), "{name} p99");
            assert!(text.contains(&format!("{name}{{quantile=\"0.999\"}}")), "{name} p999");
            assert!(text.contains(&format!("{name}_count")), "{name} count");
        }
    }

    #[test]
    fn validator_rejects_bad_exposition() {
        assert!(validate_prometheus("metall_x 1").is_err(), "sample without TYPE");
        assert!(validate_prometheus("# TYPE 9bad gauge\n9bad 1").is_err(), "bad name");
        assert!(
            validate_prometheus("# TYPE metall_x gauge\nmetall_x abc").is_err(),
            "bad value"
        );
        assert!(validate_prometheus("# TYPE metall_x gauge\nmetall_x 1\n").is_ok());
    }

    #[test]
    fn json_output_parses_key_structure() {
        let j = render_json(&bundle());
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"counters\""));
        assert!(j.contains("\"alloc.allocs\":2"));
        assert!(j.contains("\"latency\""));
        assert!(j.contains("\"alloc_small\""));
        assert!(j.contains("\"p999_ns\""));
        assert!(j.contains("\"events\""));
    }
}

//! Always-on telemetry (ISSUE 10): lock-free latency histograms, a
//! crash-persisted flight recorder, and Prometheus/JSON export.
//!
//! Three layers, each usable alone:
//!
//! 1. [`histogram`] — mergeable log-linear histograms with per-CPU
//!    sharded recording; the [`Telemetry`] facade owns one per
//!    instrumented [`Op`] and gates the *hot* ops (alloc/dealloc,
//!    op-log append) behind a cheap 1-in-N sampler
//!    ([`ManagerOptions::telemetry_sample`](crate::alloc::ManagerOptions::telemetry_sample),
//!    default 1-in-64, `0` = off). Rare ops (epoch phases, stalls,
//!    attach/refresh) are recorded unsampled — they are the tail the
//!    ROADMAP `serving_tail` item needs.
//! 2. [`recorder`] — a fixed-size ring of structured engine events
//!    written through an mmap'd file under `<store>/diag/`, so even a
//!    `kill -9` leaves a parseable post-mortem.
//! 3. [`export`] — renders counters + histograms + events as Prometheus
//!    text exposition or JSON for `metall stats` / `metall trace`.
//!
//! The sampler is a thread-local counter, not a RNG: with the default
//! power-of-two rate the hot-path cost of an *unsampled* op is one TLS
//! increment and a mask test. Sampled ops pay two `Instant::now()`
//! calls and three relaxed `fetch_add`s.

pub mod export;
pub mod histogram;
pub mod recorder;

use std::cell::Cell;
use std::path::Path;
use std::time::Instant;

use histogram::{HistogramSnapshot, ShardedHistogram};
use recorder::{EventKind, FlightRecorder};

/// Every instrumented operation. The `name()` strings are the stable
/// metric identities (`alloc.lat.<name>.*` — catalogued in
/// `docs/METRICS.md`); treat them like an on-disk format.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Op {
    /// Small-object allocation (cache pop / bitset claim / fresh chunk).
    AllocSmall,
    /// Large (multi-chunk) allocation.
    AllocLarge,
    /// Deallocation (either size class).
    Dealloc,
    /// `mark_data_dirty` backpressure stall at the sync ceiling.
    Stall,
    /// Container op-log intent append (`oplog_begin`).
    OplogAppend,
    /// Background flusher: consistent cut + serialize (whole
    /// `prepare_epoch`).
    EpochCut,
    /// The management-section serialization portion of the cut.
    EpochSerialize,
    /// Committer: whole `commit_epoch` (data msync + section writes +
    /// manifest).
    EpochCommit,
    /// The manifest build + atomic-rename portion of the commit.
    EpochManifest,
    /// `ReaderManager::attach`.
    Attach,
    /// `ReaderManager::refresh`.
    Refresh,
}

impl Op {
    pub const ALL: [Op; 11] = [
        Op::AllocSmall,
        Op::AllocLarge,
        Op::Dealloc,
        Op::Stall,
        Op::OplogAppend,
        Op::EpochCut,
        Op::EpochSerialize,
        Op::EpochCommit,
        Op::EpochManifest,
        Op::Attach,
        Op::Refresh,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Op::AllocSmall => "alloc_small",
            Op::AllocLarge => "alloc_large",
            Op::Dealloc => "dealloc",
            Op::Stall => "stall",
            Op::OplogAppend => "oplog_append",
            Op::EpochCut => "epoch_cut",
            Op::EpochSerialize => "epoch_serialize",
            Op::EpochCommit => "epoch_commit",
            Op::EpochManifest => "epoch_manifest",
            Op::Attach => "attach",
            Op::Refresh => "refresh",
        }
    }
}

thread_local! {
    static SAMPLE_CTR: Cell<u32> = const { Cell::new(0) };
}

/// Facade owned by `ManagerCore` (with a flight recorder) and
/// `ReaderManager` (histograms only). All methods take `&self` and are
/// callable from any thread.
pub struct Telemetry {
    /// 1-in-`rate` sampling of hot ops; 0 disables all histograms.
    rate: u32,
    /// `rate - 1` when `rate` is a power of two, else 0 (modulo path).
    mask: u32,
    hists: Vec<ShardedHistogram>,
    recorder: Option<FlightRecorder>,
}

impl Telemetry {
    /// Histograms only (readers, tests, benches).
    pub fn new(sample_rate: u32, shards: usize) -> Telemetry {
        let mask = if sample_rate.is_power_of_two() { sample_rate - 1 } else { 0 };
        let mut hists = Vec::with_capacity(Op::ALL.len());
        hists.resize_with(Op::ALL.len(), || ShardedHistogram::new(shards));
        Telemetry { rate: sample_rate, mask, hists, recorder: None }
    }

    /// Histograms plus a flight recorder under `<store>/diag/`.
    /// Recorder creation is best-effort: an I/O failure leaves the
    /// telemetry working without one — diagnostics never fail an open.
    pub fn with_recorder(sample_rate: u32, shards: usize, store: &Path, mode: u32) -> Telemetry {
        let mut t = Telemetry::new(sample_rate, shards);
        t.recorder = FlightRecorder::create(store, mode).ok();
        t
    }

    pub fn sample_rate(&self) -> u32 {
        self.rate
    }

    /// Should this hot-path call be timed? One TLS increment + mask.
    #[inline]
    pub fn sample(&self) -> bool {
        if self.rate <= 1 {
            return self.rate == 1;
        }
        SAMPLE_CTR.with(|c| {
            let v = c.get().wrapping_add(1);
            c.set(v);
            if self.mask != 0 { v & self.mask == 0 } else { v % self.rate == 0 }
        })
    }

    /// `Some(now)` on sampled calls — pair with [`Telemetry::record`].
    #[inline]
    pub fn maybe_start(&self) -> Option<Instant> {
        if self.sample() { Some(Instant::now()) } else { None }
    }

    /// Record the elapsed time since `t0` under `op`.
    #[inline]
    pub fn record(&self, op: Op, t0: Instant) {
        self.record_ns(op, t0.elapsed().as_nanos() as u64);
    }

    /// Record a raw nanosecond value under `op` (no sampling — used by
    /// the rare ops, which must not miss tail events).
    #[inline]
    pub fn record_ns(&self, op: Op, ns: u64) {
        if self.rate == 0 {
            return;
        }
        self.hists[op as usize].record(ns);
    }

    /// Append a structured event to the flight recorder (no-op without
    /// one). Event recording ignores the sampler: events are rare and
    /// are exactly what a post-mortem needs complete.
    #[inline]
    pub fn event(&self, kind: EventKind, code: u32, a: u64, b: u64, c: u64) {
        if let Some(r) = &self.recorder {
            r.record(kind, code, a, b, c);
        }
    }

    /// `msync` the flight ring — call when recording a failure that may
    /// be the process's last act (wound, contained panic, failed close).
    pub fn flush_recorder(&self) {
        if let Some(r) = &self.recorder {
            r.flush();
        }
    }

    pub fn recorder_path(&self) -> Option<&Path> {
        self.recorder.as_ref().map(FlightRecorder::path)
    }

    /// Merged per-op snapshots (shards folded), in [`Op::ALL`] order.
    pub fn snapshot(&self) -> Vec<(Op, HistogramSnapshot)> {
        Op::ALL
            .iter()
            .map(|&op| (op, self.hists[op as usize].snapshot()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_honors_rate() {
        let t = Telemetry::new(4, 1);
        let hits = (0..4000).filter(|_| t.sample()).count();
        assert_eq!(hits, 1000, "1-in-4 sampling is exact per thread");
        let off = Telemetry::new(0, 1);
        assert!((0..100).all(|_| !off.sample()));
        off.record_ns(Op::AllocSmall, 123);
        assert_eq!(off.snapshot()[0].1.count, 0, "rate 0 disables histograms");
        let always = Telemetry::new(1, 1);
        assert!((0..100).all(|_| always.sample()));
    }

    #[test]
    fn snapshot_orders_ops_and_records() {
        let t = Telemetry::new(1, 2);
        t.record_ns(Op::Attach, 1_000);
        t.record_ns(Op::Attach, 2_000);
        t.record_ns(Op::EpochCommit, 5_000);
        let snap = t.snapshot();
        assert_eq!(snap.len(), Op::ALL.len());
        let attach = snap.iter().find(|(op, _)| *op == Op::Attach).unwrap();
        assert_eq!(attach.1.count, 2);
        let commit = snap.iter().find(|(op, _)| *op == Op::EpochCommit).unwrap();
        assert_eq!(commit.1.count, 1);
        assert!(commit.1.quantile(0.99) >= 5_000);
    }

    #[test]
    fn events_reach_the_ring() {
        let dir = std::env::temp_dir().join(format!("metall-telev-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let t = Telemetry::with_recorder(64, 1, &dir, 1);
        t.event(EventKind::Wound, 0, 7, 0, 0);
        t.flush_recorder();
        let path = t.recorder_path().unwrap().to_path_buf();
        drop(t);
        let dump = recorder::load(&path).unwrap();
        assert!(dump
            .events
            .iter()
            .any(|e| recorder::EventKind::from_u32(e.kind) == Some(EventKind::Wound) && e.a == 7));
        let _ = std::fs::remove_dir_all(&dir);
    }
}

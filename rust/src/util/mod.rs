//! Small shared utilities: deterministic RNG, bit math, human-readable
//! formatting, a minimal JSON writer for metrics output, and the shared
//! flusher-pool primitive ([`parallel_jobs`]).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

pub mod rng;
pub mod bits;
pub mod human;
pub mod jsonw;
pub mod tmp;

/// Round `v` up to the next multiple of `align` (power of two).
#[inline]
pub fn align_up(v: usize, align: usize) -> usize {
    debug_assert!(align.is_power_of_two());
    (v + align - 1) & !(align - 1)
}

/// Integer ceiling division.
#[inline]
pub fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// FNV-1a over `bytes`: the crate's shared non-cryptographic hash (type
/// fingerprints, management-section checksums). Detects corruption and
/// torn writes; not collision-resistant against an adversary.
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Crash-injection hook for the kill-9 integration tests: when the
/// `METALL_KILL_POINT` environment variable names this call site, the
/// process SIGKILLs itself on the spot — no unwinding, no destructors,
/// exactly the crash model the recovery paths must survive. Always
/// compiled (a `#[cfg(test)]` gate would not reach the re-exec'd child
/// processes the crash tests spawn); the env lookup is the only cost on
/// the hot path when unset.
#[inline]
pub fn test_kill_point(name: &str) {
    if std::env::var_os("METALL_KILL_POINT").is_some_and(|v| v == name) {
        unsafe {
            libc::raise(libc::SIGKILL);
        }
    }
}

/// Run `n` independent jobs on a scoped worker pool and return their
/// results in job order — the atomic-cursor flusher pattern (one worker
/// per available core, capped at `n`; job `i` is claimed with a
/// `fetch_add`, so no worker idles while work remains) shared by the
/// sync paths: the management section writer, the range-narrowed msync,
/// and the bs-mmap per-file write-back ([`parallel_jobs_capped`] when a
/// caller bounds the pool). `n <= 1` runs inline on the caller — no
/// thread spawn on the single-job latency path.
pub fn parallel_jobs<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_jobs_capped(n, usize::MAX, f)
}

/// [`parallel_jobs`] with an explicit upper bound on the worker count
/// (e.g. `BsMsync::max_flushers`).
pub fn parallel_jobs_capped<T, F>(n: usize, max_workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(1)
        .min(n)
        .min(max_workers.max(1));
    if n == 0 {
        return Vec::new();
    }
    if n == 1 || workers == 1 {
        return (0..n).map(f).collect();
    }
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            let results = &results;
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    return;
                }
                *results[i].lock().unwrap() = Some(f(i));
            });
        }
    });
    results
        .into_iter()
        .map(|c| c.into_inner().unwrap().expect("every job ran"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn align_up_basics() {
        assert_eq!(align_up(0, 8), 0);
        assert_eq!(align_up(1, 8), 8);
        assert_eq!(align_up(8, 8), 8);
        assert_eq!(align_up(9, 8), 16);
        assert_eq!(align_up(4095, 4096), 4096);
        assert_eq!(align_up(4097, 4096), 8192);
    }

    #[test]
    fn div_ceil_basics() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
    }

    #[test]
    fn parallel_jobs_ordered_complete_and_inline_for_one() {
        assert_eq!(parallel_jobs(0, |i| i), Vec::<usize>::new());
        // n == 1 runs on the calling thread
        let caller = std::thread::current().id();
        let ran_on = parallel_jobs(1, |_| std::thread::current().id());
        assert_eq!(ran_on, vec![caller]);
        // results come back in job order whatever the claim order was
        let out = parallel_jobs(100, |i| i * 3);
        assert_eq!(out, (0..100).map(|i| i * 3).collect::<Vec<_>>());
        // mixed Ok/Err results pass through untouched
        let r = parallel_jobs(4, |i| if i % 2 == 0 { Ok(i) } else { Err(i) });
        assert_eq!(r, vec![Ok(0), Err(1), Ok(2), Err(3)]);
        // a worker cap of 1 degenerates to an in-order sequential run
        let seq = parallel_jobs_capped(8, 1, |i| i);
        assert_eq!(seq, (0..8).collect::<Vec<_>>());
    }
}

//! Small shared utilities: deterministic RNG, bit math, human-readable
//! formatting, and a minimal JSON writer for metrics output.

pub mod rng;
pub mod bits;
pub mod human;
pub mod jsonw;
pub mod tmp;

/// Round `v` up to the next multiple of `align` (power of two).
#[inline]
pub fn align_up(v: usize, align: usize) -> usize {
    debug_assert!(align.is_power_of_two());
    (v + align - 1) & !(align - 1)
}

/// Integer ceiling division.
#[inline]
pub fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn align_up_basics() {
        assert_eq!(align_up(0, 8), 0);
        assert_eq!(align_up(1, 8), 8);
        assert_eq!(align_up(8, 8), 8);
        assert_eq!(align_up(9, 8), 16);
        assert_eq!(align_up(4095, 4096), 4096);
        assert_eq!(align_up(4097, 4096), 8192);
    }

    #[test]
    fn div_ceil_basics() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
    }
}

//! Deterministic pseudo-random number generation.
//!
//! The offline build image carries no `rand` crate, so we implement the
//! two small, well-known generators the benchmarks need:
//! [`SplitMix64`] for seeding / hashing and [`Xoshiro256ss`]
//! (xoshiro256**) as the workhorse generator. Both are reproducible across
//! runs, which the experiment harness relies on.

/// SplitMix64 — used for seed expansion and integer mixing/scrambling.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        mix64(self.state)
    }
}

/// The SplitMix64 finalizer as a standalone mixing function. Also used as
/// the paper's "vertex scrambling" hash (§6.3.2 scrambles R-MAT vertex IDs
/// to remove locality).
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** 1.0 by Blackman & Vigna — fast, high quality, tiny.
#[derive(Clone, Debug)]
pub struct Xoshiro256ss {
    s: [u64; 4],
}

impl Xoshiro256ss {
    /// Seed via SplitMix64 as the authors recommend.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `u64` in `[0, bound)` (Lemire's multiply-shift; bias is
    /// negligible for bench workloads and determinism is what matters).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 (from the public splitmix64.c)
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        // deterministic across runs
        let mut sm2 = SplitMix64::new(1234567);
        let v2: Vec<u64> = (0..3).map(|_| sm2.next_u64()).collect();
        assert_eq!(v, v2);
        assert_ne!(v[0], v[1]);
    }

    #[test]
    fn xoshiro_deterministic_and_spread() {
        let mut a = Xoshiro256ss::new(42);
        let mut b = Xoshiro256ss::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Xoshiro256ss::new(43);
        let same = (0..1000).filter(|_| a.next_u64() == c.next_u64()).count();
        assert!(same < 5);
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Xoshiro256ss::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn next_f64_unit_interval() {
        let mut r = Xoshiro256ss::new(9);
        for _ in 0..10_000 {
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256ss::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn mix64_scramble_is_injective_sample() {
        use std::collections::HashSet;
        let set: HashSet<u64> = (0..100_000u64).map(mix64).collect();
        assert_eq!(set.len(), 100_000);
    }
}

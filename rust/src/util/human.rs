//! Human-readable formatting for the bench harness output.

/// Format a byte count: "512 B", "2.0 MiB", "1.50 GiB".
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    if n < 1024 {
        return format!("{n} B");
    }
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

/// Format a duration in adaptive units.
pub fn duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.2} s")
    }
}

/// Format a rate (ops/sec) with SI prefixes.
pub fn rate(ops_per_sec: f64) -> String {
    if ops_per_sec >= 1e9 {
        format!("{:.2} Gop/s", ops_per_sec / 1e9)
    } else if ops_per_sec >= 1e6 {
        format!("{:.2} Mop/s", ops_per_sec / 1e6)
    } else if ops_per_sec >= 1e3 {
        format!("{:.2} Kop/s", ops_per_sec / 1e3)
    } else {
        format!("{ops_per_sec:.1} op/s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_fmt() {
        assert_eq!(bytes(0), "0 B");
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(2 * 1024 * 1024), "2.00 MiB");
        assert_eq!(bytes(3 * 1024 * 1024 * 1024 / 2), "1.50 GiB");
    }

    #[test]
    fn duration_fmt() {
        assert_eq!(duration(2.5), "2.50 s");
        assert_eq!(duration(0.0025), "2.50 ms");
        assert!(duration(2.5e-7).ends_with("ns"));
    }

    #[test]
    fn rate_fmt() {
        assert_eq!(rate(1_500_000.0), "1.50 Mop/s");
        assert_eq!(rate(12.0), "12.0 op/s");
    }
}

//! A minimal JSON *writer* (the offline image carries no serde). Used by
//! the metrics/bench harness to emit machine-readable result rows next to
//! the human tables. Only what we need: objects, arrays, strings, numbers,
//! bools.

use std::fmt::Write as _;

/// Incremental JSON object writer.
#[derive(Default)]
pub struct JsonObj {
    buf: String,
    first: bool,
}

impl JsonObj {
    pub fn new() -> Self {
        Self { buf: String::from("{"), first: true }
    }

    fn key(&mut self, k: &str) {
        if !self.first {
            self.buf.push(',');
        }
        self.first = false;
        let _ = write!(self.buf, "{}:", quote(k));
    }

    pub fn str(mut self, k: &str, v: &str) -> Self {
        self.key(k);
        self.buf.push_str(&quote(v));
        self
    }

    pub fn num(mut self, k: &str, v: f64) -> Self {
        self.key(k);
        if v.is_finite() {
            let _ = write!(self.buf, "{v}");
        } else {
            self.buf.push_str("null");
        }
        self
    }

    pub fn int(mut self, k: &str, v: i64) -> Self {
        self.key(k);
        let _ = write!(self.buf, "{v}");
        self
    }

    pub fn bool(mut self, k: &str, v: bool) -> Self {
        self.key(k);
        let _ = write!(self.buf, "{v}");
        self
    }

    /// Raw pre-serialized JSON value (e.g. a nested object or array).
    pub fn raw(mut self, k: &str, v: &str) -> Self {
        self.key(k);
        self.buf.push_str(v);
        self
    }

    pub fn finish(mut self) -> String {
        self.buf.push('}');
        self.buf
    }
}

/// JSON-escape and quote a string.
pub fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Serialize a slice of f64 as a JSON array.
pub fn array_f64(xs: &[f64]) -> String {
    let mut out = String::from("[");
    for (i, x) in xs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{x}");
    }
    out.push(']');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_roundtrip_shape() {
        let s = JsonObj::new()
            .str("name", "fig4")
            .int("scale", 22)
            .num("secs", 1.25)
            .bool("ok", true)
            .raw("xs", &array_f64(&[1.0, 2.5]))
            .finish();
        assert_eq!(
            s,
            r#"{"name":"fig4","scale":22,"secs":1.25,"ok":true,"xs":[1,2.5]}"#
        );
    }

    #[test]
    fn escaping() {
        assert_eq!(quote("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
    }
}

//! Scratch-directory helper (the offline image has no `tempfile` crate).
//! Used by tests, benches and examples for datastore locations.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A uniquely named directory removed on drop.
pub struct TempDir(PathBuf);

impl TempDir {
    /// Create under the system temp dir.
    pub fn new(tag: &str) -> Self {
        Self::new_in(std::env::temp_dir(), tag)
    }

    /// Create under an explicit parent (e.g. a specific mount point).
    pub fn new_in(parent: impl AsRef<Path>, tag: &str) -> Self {
        let p = parent.as_ref().join(format!(
            "metallrs-{tag}-{}-{}",
            std::process::id(),
            COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&p).expect("create temp dir");
        TempDir(p)
    }

    pub fn path(&self) -> &Path {
        &self.0
    }

    /// Path of an entry inside the directory.
    pub fn join(&self, name: &str) -> PathBuf {
        self.0.join(name)
    }

    /// Keep the directory on drop (debugging escape hatch).
    pub fn into_path(self) -> PathBuf {
        let p = self.0.clone();
        std::mem::forget(self);
        p
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_and_cleanup() {
        let p;
        {
            let d = TempDir::new("tmptest");
            p = d.path().to_path_buf();
            std::fs::write(d.join("x"), b"hi").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }

    #[test]
    fn unique_names() {
        let a = TempDir::new("u");
        let b = TempDir::new("u");
        assert_ne!(a.path(), b.path());
    }
}

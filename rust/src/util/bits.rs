//! Bit-math helpers used by the size-class tables and the multi-layer
//! bitset (§4.2, §4.3.1 of the paper).

/// Next power of two ≥ `v` (v > 0).
#[inline]
pub fn next_pow2(v: u64) -> u64 {
    debug_assert!(v > 0);
    v.next_power_of_two()
}

/// floor(log2(v)) for v > 0.
#[inline]
pub fn log2_floor(v: u64) -> u32 {
    debug_assert!(v > 0);
    63 - v.leading_zeros()
}

/// ceil(log2(v)) for v > 0.
#[inline]
pub fn log2_ceil(v: u64) -> u32 {
    if v <= 1 { 0 } else { 64 - (v - 1).leading_zeros() }
}

/// Index of the lowest zero bit of `w`, or `None` when `w == u64::MAX`.
/// This is the "built-in bit operation" the paper's multi-layer bitset
/// uses to find a free slot (at most 3 of these per allocation).
#[inline]
pub fn lowest_zero(w: u64) -> Option<u32> {
    if w == u64::MAX { None } else { Some((!w).trailing_zeros()) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn next_pow2_table() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(2), 2);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(4), 4);
        assert_eq!(next_pow2(5), 8);
        assert_eq!(next_pow2((1 << 20) + 1), 1 << 21);
        assert_eq!(next_pow2(1 << 21), 1 << 21);
    }

    #[test]
    fn log2_pair() {
        for k in 0..62u32 {
            let v = 1u64 << k;
            assert_eq!(log2_floor(v), k);
            assert_eq!(log2_ceil(v), k);
            if v > 1 {
                assert_eq!(log2_floor(v + 1), k);
                assert_eq!(log2_ceil(v + 1), k + 1);
            }
        }
    }

    #[test]
    fn lowest_zero_cases() {
        assert_eq!(lowest_zero(0), Some(0));
        assert_eq!(lowest_zero(0b1), Some(1));
        assert_eq!(lowest_zero(0b1011), Some(2));
        assert_eq!(lowest_zero(u64::MAX), None);
        assert_eq!(lowest_zero(u64::MAX >> 1), Some(63));
    }
}

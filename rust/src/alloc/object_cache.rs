//! Per-core free-object caches (paper §4.5.2).
//!
//! "Since Metall is designed to deal with larger data than existing
//! memory allocators, we decided to employ free-object caches at the CPU
//! core level only to simplify its implementation."
//!
//! A deallocated small object lands in the cache slot of the CPU core the
//! calling thread runs on; a subsequent allocation of the same bin pops
//! it without touching the bin or chunk directories. Each (core, bin)
//! queue is bounded; overflow spills half the queue back to the bin
//! directory through the manager.
//!
//! ## Virtual CPU and shard affinity
//!
//! Slot selection and the manager's shard selection both key off
//! [`current_vcpu`]: the thread's *virtual CPU* — `sched_getcpu` when
//! available, a stable thread-id hash otherwise, or a per-thread pinned
//! value ([`pin_thread_vcpu`], used by tests and benchmarks to make shard
//! placement deterministic). Because cache slot (`vcpu % ncores`) and home
//! shard ([`super::bin_dir::ShardMap::shard_of_vcpu`] — `vcpu % nshards`
//! on a single NUMA node, node-aware routing on multi-node topologies)
//! derive from the same value, each cache slot is bound to a fixed shard
//! whenever `ncores` is a multiple of the shard count — objects parked on
//! a core refill allocations that the same shard's bins would serve.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

thread_local! {
    static VCPU_PIN: Cell<Option<usize>> = const { Cell::new(None) };
}

/// Pin (or with `None` unpin) the calling thread to a fixed virtual CPU
/// for object-cache slot and allocator-shard selection. Test/bench
/// utility: real workloads rely on `sched_getcpu` affinity.
pub fn pin_thread_vcpu(vcpu: Option<usize>) {
    VCPU_PIN.with(|p| p.set(vcpu));
}

/// The calling thread's virtual CPU (module docs): pinned value, else
/// `sched_getcpu`, else a stable hash of the thread id.
#[inline]
pub fn current_vcpu() -> usize {
    if let Some(v) = VCPU_PIN.with(|p| p.get()) {
        return v;
    }
    let cpu = unsafe { libc::sched_getcpu() };
    if cpu >= 0 {
        return cpu as usize;
    }
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    std::thread::current().id().hash(&mut h);
    h.finish() as usize
}

/// Max objects cached per (core, bin).
pub const PER_BIN_CAP: usize = 64;

/// Slots claimed per lock-free refill on a cache miss (the manager claims
/// a word-level batch from the bin bitsets and parks the surplus here, so
/// the next `REFILL_BATCH - 1` same-bin allocations on this core are pure
/// cache pops).
pub const REFILL_BATCH: usize = 16;

struct CoreCache {
    by_bin: Vec<Vec<u64>>, // offsets
}

/// The cache array: one slot per CPU core.
pub struct ObjectCache {
    cores: Vec<Mutex<CoreCache>>,
    /// DRAM-only dirty-epoch mark: set whenever the cached set changes
    /// (pop, push, drain), cleared when the sync path serializes the
    /// transient cache section. Lets a no-op sync skip the section.
    dirty: AtomicBool,
}

impl ObjectCache {
    pub fn new(num_bins: usize) -> Self {
        let ncores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::with_cores(ncores, num_bins)
    }

    pub fn with_cores(ncores: usize, num_bins: usize) -> Self {
        let cores = (0..ncores.max(1))
            .map(|_| Mutex::new(CoreCache { by_bin: vec![Vec::new(); num_bins] }))
            .collect();
        Self { cores, dirty: AtomicBool::new(false) }
    }

    /// Has the cached set changed since the last [`Self::take_dirty`]?
    pub fn peek_dirty(&self) -> bool {
        self.dirty.load(Ordering::Relaxed)
    }

    pub fn mark_dirty(&self) {
        self.dirty.store(true, Ordering::Relaxed);
    }

    /// Read-and-clear the dirty mark (cache-section serialization point).
    pub fn take_dirty(&self) -> bool {
        self.dirty.swap(false, Ordering::Relaxed)
    }

    /// Cache slot for a virtual CPU (clamped to the slot count).
    #[inline]
    pub fn slot_for(&self, vcpu: usize) -> usize {
        vcpu % self.cores.len()
    }

    /// Cache slot for the current thread.
    fn core_slot(&self) -> usize {
        self.slot_for(current_vcpu())
    }

    /// Try to pop a cached object of `bin`.
    pub fn pop(&self, bin: u32) -> Option<u64> {
        self.pop_at(self.core_slot(), bin)
    }

    /// [`Self::pop`] with the slot precomputed (the manager resolves the
    /// virtual CPU once per allocation for both slot and shard).
    pub fn pop_at(&self, slot: usize, bin: u32) -> Option<u64> {
        let mut c = self.cores[slot].lock().unwrap();
        let got = c.by_bin[bin as usize].pop();
        if got.is_some() {
            self.dirty.store(true, Ordering::Relaxed);
        }
        got
    }

    /// Push a freed object. Returns the overflow spill (possibly empty):
    /// offsets the caller must return to the bin directory.
    pub fn push(&self, bin: u32, offset: u64) -> Vec<u64> {
        self.push_batch_at(self.core_slot(), bin, &[offset])
    }

    /// Push a batch of objects (refill path: slots just claimed through
    /// the lock-free bitset path, or a bulk free). Returns the overflow
    /// spill (possibly empty): offsets the caller must return to the bin
    /// directory.
    pub fn push_batch(&self, bin: u32, offsets: &[u64]) -> Vec<u64> {
        self.push_batch_at(self.core_slot(), bin, offsets)
    }

    /// [`Self::push_batch`] with the slot precomputed.
    pub fn push_batch_at(&self, slot: usize, bin: u32, offsets: &[u64]) -> Vec<u64> {
        let mut c = self.cores[slot].lock().unwrap();
        let q = &mut c.by_bin[bin as usize];
        q.extend_from_slice(offsets);
        if !offsets.is_empty() {
            // mark AFTER the mutation (like pop/drain): a sync that
            // consumed the flag just before this push either saw the new
            // entries in its snapshot or the re-set flag forces the next
            // sync to rewrite the cache section — never a clean flag over
            // an unrecorded parked slot
            self.dirty.store(true, Ordering::Relaxed);
        }
        if q.len() > PER_BIN_CAP {
            // spill the older half (keep the hot top of the LIFO)
            let keep = PER_BIN_CAP / 2;
            let spill: Vec<u64> = q.drain(..q.len() - keep).collect();
            return spill;
        }
        Vec::new()
    }

    /// Drain everything (manager close / explicit cache-flush path; the
    /// incremental sync preserves the cache and snapshots it instead).
    pub fn drain_all(&self) -> Vec<(u32, u64)> {
        let mut out = Vec::new();
        for core in &self.cores {
            let mut c = core.lock().unwrap();
            for (bin, q) in c.by_bin.iter_mut().enumerate() {
                out.extend(q.drain(..).map(|off| (bin as u32, off)));
            }
        }
        if !out.is_empty() {
            self.dirty.store(true, Ordering::Relaxed);
        }
        out
    }

    /// Non-draining copy of every cached `(bin, offset)` — the sync
    /// path's cache-section snapshot. Core order then LIFO order; the
    /// byte image is deterministic for a deterministic trace.
    pub fn snapshot_all(&self) -> Vec<(u32, u64)> {
        let mut out = Vec::new();
        for core in &self.cores {
            let c = core.lock().unwrap();
            for (bin, q) in c.by_bin.iter().enumerate() {
                out.extend(q.iter().map(|&off| (bin as u32, off)));
            }
        }
        out
    }

    /// Total cached objects (stats).
    pub fn len(&self) -> usize {
        self.cores
            .iter()
            .map(|c| c.lock().unwrap().by_bin.iter().map(Vec::len).sum::<usize>())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pop_hits_lifo() {
        let c = ObjectCache::with_cores(1, 4);
        assert!(c.pop(0).is_none());
        assert!(c.push(0, 100).is_empty());
        assert!(c.push(0, 200).is_empty());
        assert_eq!(c.pop(0), Some(200));
        assert_eq!(c.pop(0), Some(100));
        assert!(c.pop(0).is_none());
    }

    #[test]
    fn bins_are_separate() {
        let c = ObjectCache::with_cores(1, 4);
        c.push(1, 11);
        c.push(2, 22);
        assert!(c.pop(0).is_none());
        assert_eq!(c.pop(2), Some(22));
        assert_eq!(c.pop(1), Some(11));
    }

    #[test]
    fn overflow_spills_older_half() {
        let c = ObjectCache::with_cores(1, 1);
        let mut spilled = Vec::new();
        for i in 0..(PER_BIN_CAP as u64 + 1) {
            spilled.extend(c.push(0, i));
        }
        assert_eq!(spilled.len(), PER_BIN_CAP + 1 - PER_BIN_CAP / 2);
        // oldest offsets are the ones spilled
        assert_eq!(spilled[0], 0);
        // the hot top is still cached
        assert_eq!(c.pop(0), Some(PER_BIN_CAP as u64));
    }

    #[test]
    fn push_batch_spills_once_over_cap() {
        let c = ObjectCache::with_cores(1, 1);
        let offs: Vec<u64> = (0..PER_BIN_CAP as u64 + 10).collect();
        let spilled = c.push_batch(0, &offs);
        assert_eq!(spilled.len(), PER_BIN_CAP + 10 - PER_BIN_CAP / 2);
        assert_eq!(spilled[0], 0, "oldest spilled first");
        assert_eq!(c.pop(0), Some(PER_BIN_CAP as u64 + 9), "hot top kept");
    }

    #[test]
    fn pinned_vcpu_selects_a_fixed_slot() {
        let c = ObjectCache::with_cores(2, 1);
        pin_thread_vcpu(Some(0));
        assert!(c.push(0, 100).is_empty());
        pin_thread_vcpu(Some(1));
        assert!(c.pop(0).is_none(), "slot 1 does not see slot 0's object");
        assert!(c.push(0, 200).is_empty());
        pin_thread_vcpu(Some(0));
        assert_eq!(c.pop(0), Some(100));
        pin_thread_vcpu(Some(3)); // wraps: 3 % 2 == slot 1
        assert_eq!(c.pop(0), Some(200));
        pin_thread_vcpu(None);
    }

    #[test]
    fn snapshot_preserves_contents_and_dirty_tracks_changes() {
        let c = ObjectCache::with_cores(2, 2);
        assert!(!c.peek_dirty());
        assert!(c.pop(0).is_none());
        assert!(!c.peek_dirty(), "failed pop is not a change");
        c.push(0, 100);
        assert!(c.take_dirty());
        assert!(!c.peek_dirty());
        // snapshot does not drain or dirty
        let snap = c.snapshot_all();
        assert_eq!(snap, vec![(0, 100)]);
        assert!(!c.peek_dirty());
        assert_eq!(c.pop(0), Some(100), "snapshot left the object cached");
        assert!(c.take_dirty(), "pop dirties");
        c.push(1, 7);
        let _ = c.take_dirty();
        assert!(!c.drain_all().is_empty());
        assert!(c.peek_dirty(), "drain dirties");
    }

    #[test]
    fn drain_returns_everything() {
        let c = ObjectCache::with_cores(2, 3);
        c.push(0, 1);
        c.push(1, 2);
        c.push(2, 3);
        let mut drained = c.drain_all();
        drained.sort_unstable();
        assert_eq!(drained, vec![(0, 1), (1, 2), (2, 3)]);
        assert!(c.is_empty());
    }
}

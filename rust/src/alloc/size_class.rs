//! Internal allocation sizes (paper §4.2).
//!
//! "Metall rounds up a small object to the nearest internal allocation
//! size … uses allocation sizes proposed by Supermalloc and jemalloc …
//! can keep internal fragmentations equal to or less than 25% and convert
//! a small object size to the corresponding internal allocation size
//! quickly. Metall also assigns a *bin number* for each internal
//! allocation size."
//!
//! Scheme: quantum spacing of 8 bytes up to 32, then four classes per
//! power-of-two group (2^k + i·2^(k-2), i = 1..4) — worst-case internal
//! fragmentation 1/(4+1) = 20% < 25%, O(1) in both directions via
//! leading-zero counts.
//!
//! Large objects (> half a chunk) are rounded up to the next power of
//! two (§4.2: wastes VM, not physical memory, thanks to demand paging;
//! worst case 1.6% *physical* waste for (1M+1) B on 4 KiB pages).

use crate::util::bits::next_pow2;

/// Smallest allocation size.
pub const MIN_SIZE: usize = 8;

/// Bin number for a small request of `size` bytes (1 ≤ size ≤ max_small).
#[inline]
pub fn bin_of(size: usize) -> usize {
    debug_assert!(size > 0);
    if size <= 32 {
        (size + 7) / 8 - 1 // 0..=3 → 8, 16, 24, 32
    } else {
        let l = usize::BITS - 1 - (size - 1).leading_zeros(); // log2_floor(size-1)
        let l = l as usize; // group: sizes in (2^l, 2^(l+1)]
        let spacing = 1usize << (l - 2);
        let within = (size - (1 << l) + spacing - 1) / spacing; // 1..=4
        4 + 4 * (l - 5) + within - 1
    }
}

/// Allocation size of bin `bin` (inverse of [`bin_of`]).
#[inline]
pub fn size_of_bin(bin: usize) -> usize {
    if bin < 4 {
        (bin + 1) * 8
    } else {
        let group = (bin - 4) / 4; // l - 5
        let within = (bin - 4) % 4 + 1; // 1..=4
        let l = group + 5;
        (1 << l) + within * (1 << (l - 2))
    }
}

/// Number of small bins for a given chunk size (largest small class is
/// chunk_size / 2, which is always a power of two and therefore the last
/// class of its group).
#[inline]
pub fn num_bins(chunk_size: usize) -> usize {
    debug_assert!(chunk_size.is_power_of_two());
    bin_of(chunk_size / 2) + 1
}

/// Is `size` a small allocation for this chunk size?
#[inline]
pub fn is_small(size: usize, chunk_size: usize) -> bool {
    size <= chunk_size / 2
}

/// Rounded size for a large allocation (next power of two), in bytes.
#[inline]
pub fn large_rounded(size: usize) -> usize {
    next_pow2(size as u64) as usize
}

/// Number of chunks a large allocation occupies.
#[inline]
pub fn large_chunks(size: usize, chunk_size: usize) -> usize {
    crate::util::div_ceil(large_rounded(size), chunk_size)
}

/// Number of slots a chunk holds for a bin.
#[inline]
pub fn slots_per_chunk(bin: usize, chunk_size: usize) -> usize {
    chunk_size / size_of_bin(bin)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_classes() {
        assert_eq!(size_of_bin(0), 8);
        assert_eq!(size_of_bin(1), 16);
        assert_eq!(size_of_bin(2), 24);
        assert_eq!(size_of_bin(3), 32);
        assert_eq!(size_of_bin(4), 40);
        assert_eq!(size_of_bin(5), 48);
        assert_eq!(size_of_bin(6), 56);
        assert_eq!(size_of_bin(7), 64);
        assert_eq!(size_of_bin(8), 80);
        assert_eq!(size_of_bin(11), 128);
        assert_eq!(size_of_bin(12), 160);
    }

    #[test]
    fn roundtrip_all_sizes() {
        // every size in [1, 1 MiB]: bin size >= size, bin_of(bin size) == bin
        for size in 1..=(1 << 20) {
            let b = bin_of(size);
            let s = size_of_bin(b);
            assert!(s >= size, "size {size} got class {s}");
            assert_eq!(bin_of(s), b, "class size {s} must map to its own bin");
            if b > 0 {
                assert!(
                    size_of_bin(b - 1) < size,
                    "not the tightest class for {size}: {} also fits",
                    size_of_bin(b - 1)
                );
            }
        }
    }

    #[test]
    fn fragmentation_bound_25_percent() {
        // paper §4.2: internal fragmentation ≤ 25%. In the geometric
        // region (size > 32) the spacing ratio bounds waste at 20% of the
        // class size; in the quantum region absolute waste is < 8 bytes.
        for size in MIN_SIZE..=(1 << 20) {
            let s = size_of_bin(bin_of(size));
            if size > 32 {
                let frag = (s - size) as f64 / s as f64;
                assert!(frag <= 0.25, "size {size} class {s} frag {frag}");
            } else {
                assert!(s - size < 8, "size {size} class {s}");
            }
        }
    }

    #[test]
    fn bins_monotone_and_contiguous() {
        let n = num_bins(1 << 21); // 2 MiB chunks → max small 1 MiB
        assert_eq!(size_of_bin(n - 1), 1 << 20);
        for b in 1..n {
            assert!(size_of_bin(b) > size_of_bin(b - 1));
        }
    }

    #[test]
    fn large_rounding() {
        assert_eq!(large_rounded((1 << 20) + 1), 1 << 21);
        assert_eq!(large_rounded(1 << 21), 1 << 21);
        assert_eq!(large_chunks((1 << 20) + 1, 1 << 21), 1);
        assert_eq!(large_chunks((1 << 21) + 1, 1 << 21), 2);
        // 3·2 MiB = 6 MiB rounds to 8 MiB = 4 chunks
        assert_eq!(large_chunks(3 << 21, 1 << 21), 4);
    }

    #[test]
    fn worst_case_physical_waste_large() {
        // paper: (1M+1) B allocation wastes ≤ 1.6% physical memory on
        // 4 KiB pages: rounded VM is 2 MiB but only ceil((1M+1)/4K) pages
        // are touched.
        let size = (1 << 20) + 1;
        let touched_pages = crate::util::div_ceil(size, 4096);
        let physical = touched_pages * 4096;
        let waste = (physical - size) as f64 / physical as f64;
        assert!(waste < 0.016, "physical waste {waste}");
    }

    #[test]
    fn slots_per_chunk_sane() {
        // 2 MiB chunk, 8 B objects → 2^18 slots (the paper's 64^3 bound)
        assert_eq!(slots_per_chunk(0, 1 << 21), 1 << 18);
        assert_eq!(slots_per_chunk(bin_of(1 << 20), 1 << 21), 2);
    }
}

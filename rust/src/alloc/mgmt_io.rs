//! Segmented management-data format: the on-disk protocol behind the
//! incremental [`super::manager::MetallManager::sync`].
//!
//! The monolithic `management.bin` of earlier versions serialized the
//! whole chunk directory, every bin bitset, and the name directory into
//! one file on every sync — O(entire store) even when one object changed.
//! This module replaces it with **per-section files** plus a small,
//! self-checksummed **manifest** that is the single commit point:
//!
//! ```text
//! <dir>/
//!   manifest-<epoch>.bin        committed by fsync'd atomic rename
//!   mgmt-chunks-<epoch>.bin     chunk directory
//!   mgmt-bins<g>-<epoch>.bin    bin group g (BINS_PER_GROUP bins each)
//!   mgmt-names-<epoch>.bin      name directory
//!   mgmt-cache-<epoch>.bin      transient: free slots parked in the
//!                               per-core object caches / remote queues
//! ```
//!
//! ## Protocol invariants
//!
//! - **Sections are immutable.** A section file, once written and
//!   fsync'd, is never rewritten: a dirty section gets a *new* file named
//!   with the committing epoch, clean sections are carried forward by
//!   reference (the manifest lists the exact file name, length, and
//!   FNV-1a checksum of every section).
//! - **The manifest is the commit point.** It is written to a per-epoch
//!   temp file (`manifest-<epoch>.tmp`), fsync'd, renamed into place, and
//!   the directory is fsync'd — so a crash at any instant leaves either
//!   the new manifest complete or the previous one untouched (every file
//!   either manifest references still exists, because garbage collection
//!   never removes files referenced by the two most recent manifests).
//! - **Commits are strictly epoch-ordered.** The pipelined background
//!   engine may *write section files* for epoch N+1 while epoch N's data
//!   flush is still in flight, but `manifest-<N+1>.bin` is never renamed
//!   into place before `manifest-<N>.bin` — the committer drains its
//!   queue in FIFO epoch order, so the newest complete manifest always
//!   dominates every older one.
//! - **Recovery walks manifests newest-first** and loads the first one
//!   that parses, whose trailer checksum matches, and whose sections all
//!   exist with matching checksums — "the last complete manifest". A
//!   store that has never done a segmented sync falls back to the legacy
//!   monolithic `management.bin`.
//!
//! The manager layer decides *which* sections are dirty (DRAM-only dirty
//! flags set at the allocator's serialization points) and writes them
//! with a flusher pool; this module owns only the bytes and the files.

use std::collections::{HashMap, HashSet};
use std::fs::{self, File};
use std::ops::Range;
use std::path::Path;

use crate::error::{Error, Result};
use crate::storage::faults;

const MANIFEST_MAGIC: &[u8; 8] = b"METALLMF";
const MANIFEST_VERSION: u32 = 1;

/// Bins serialized per `mgmt-bins<g>` section. Grouping keeps the file
/// count bounded while still letting a sync that touched one size class
/// rewrite ~1/8th of the bin data instead of all of it. The value is
/// recorded in every manifest, so it can change between versions without
/// breaking old stores.
pub const BINS_PER_GROUP: usize = 8;

/// Identity of one management section.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SectionId {
    /// The chunk directory.
    Chunks,
    /// Bin group `g`: bins `[g*BINS_PER_GROUP, (g+1)*BINS_PER_GROUP)`.
    Bins(u32),
    /// The name directory.
    Names,
    /// Transient free-slot snapshot (object caches + remote-free queues):
    /// slots that are *claimed* in the serialized bitsets but actually
    /// free. Recovery returns them to the bitsets so a crash between
    /// syncs leaks nothing.
    Cache,
}

impl SectionId {
    fn tag(self) -> u8 {
        match self {
            SectionId::Chunks => 0,
            SectionId::Bins(_) => 1,
            SectionId::Names => 2,
            SectionId::Cache => 3,
        }
    }

    fn group(self) -> u32 {
        match self {
            SectionId::Bins(g) => g,
            _ => 0,
        }
    }

    fn from_tag(tag: u8, group: u32) -> Option<Self> {
        match tag {
            0 => Some(SectionId::Chunks),
            1 => Some(SectionId::Bins(group)),
            2 => Some(SectionId::Names),
            3 => Some(SectionId::Cache),
            _ => None,
        }
    }

    /// File name for this section when (re)written at `epoch`.
    pub fn file_name(self, epoch: u64) -> String {
        match self {
            SectionId::Chunks => format!("mgmt-chunks-{epoch:012}.bin"),
            SectionId::Bins(g) => format!("mgmt-bins{g:03}-{epoch:012}.bin"),
            SectionId::Names => format!("mgmt-names-{epoch:012}.bin"),
            SectionId::Cache => format!("mgmt-cache-{epoch:012}.bin"),
        }
    }
}

/// One committed section: exact file, length, and content checksum.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SectionRecord {
    pub id: SectionId,
    pub file: String,
    pub len: u64,
    pub checksum: u64,
}

/// A parsed manifest: the complete management state at one epoch.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Manifest {
    pub epoch: u64,
    pub num_bins: u32,
    pub bins_per_group: u32,
    pub sections: Vec<SectionRecord>,
}

/// The section/manifest content checksum: the crate-wide FNV-1a. Not
/// cryptographic; it detects the torn/truncated/bit-rotted files the
/// recovery walk must skip.
pub use crate::util::fnv1a;

/// Number of bin-group sections for `num_bins` bins.
pub fn num_groups(num_bins: usize) -> usize {
    num_bins.div_ceil(BINS_PER_GROUP)
}

/// The bin indices group `g` serializes (using `bpg` bins per group).
pub fn group_bins_with(g: usize, num_bins: usize, bpg: usize) -> Range<usize> {
    let start = g * bpg;
    start..((g + 1) * bpg).min(num_bins)
}

/// [`group_bins_with`] at the current [`BINS_PER_GROUP`] (the write path).
pub fn group_bins(g: usize, num_bins: usize) -> Range<usize> {
    group_bins_with(g, num_bins, BINS_PER_GROUP)
}

pub fn manifest_file_name(epoch: u64) -> String {
    format!("manifest-{epoch:012}.bin")
}

/// Parse `manifest-NNNN.bin` → epoch.
pub fn parse_manifest_epoch(name: &str) -> Option<u64> {
    let rest = name.strip_prefix("manifest-")?.strip_suffix(".bin")?;
    rest.parse().ok()
}

/// All manifest epochs present in `dir`, ascending.
pub fn list_manifest_epochs(dir: &Path) -> Result<Vec<u64>> {
    let mut epochs = Vec::new();
    let rd = match fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(epochs),
        Err(e) => return Err(Error::io(dir, e)),
    };
    for entry in rd {
        let entry = entry.map_err(|e| Error::io(dir, e))?;
        if let Some(name) = entry.file_name().to_str() {
            if let Some(e) = parse_manifest_epoch(name) {
                epochs.push(e);
            }
        }
    }
    epochs.sort_unstable();
    Ok(epochs)
}

impl Manifest {
    pub fn section(&self, id: SectionId) -> Option<&SectionRecord> {
        self.sections.iter().find(|r| r.id == id)
    }

    pub fn serialize(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(MANIFEST_MAGIC);
        buf.extend_from_slice(&MANIFEST_VERSION.to_le_bytes());
        buf.extend_from_slice(&self.epoch.to_le_bytes());
        buf.extend_from_slice(&self.num_bins.to_le_bytes());
        buf.extend_from_slice(&self.bins_per_group.to_le_bytes());
        buf.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        for r in &self.sections {
            buf.push(r.id.tag());
            buf.extend_from_slice(&r.id.group().to_le_bytes());
            let nb = r.file.as_bytes();
            buf.extend_from_slice(&(nb.len() as u16).to_le_bytes());
            buf.extend_from_slice(nb);
            buf.extend_from_slice(&r.len.to_le_bytes());
            buf.extend_from_slice(&r.checksum.to_le_bytes());
        }
        let trailer = fnv1a(&buf);
        buf.extend_from_slice(&trailer.to_le_bytes());
        buf
    }

    /// Parse + verify a manifest image. `None` on any structural problem
    /// or trailer-checksum mismatch (the recovery walk then tries the
    /// next-older manifest).
    pub fn deserialize(buf: &[u8]) -> Option<Self> {
        fn take<'a>(body: &'a [u8], pos: &mut usize, n: usize) -> Option<&'a [u8]> {
            let s = body.get(*pos..*pos + n)?;
            *pos += n;
            Some(s)
        }
        if buf.len() < 8 + 4 + 8 + 4 + 4 + 4 + 8 || &buf[0..8] != MANIFEST_MAGIC {
            return None;
        }
        let body = &buf[..buf.len() - 8];
        let trailer = u64::from_le_bytes(buf[buf.len() - 8..].try_into().ok()?);
        if fnv1a(body) != trailer {
            return None;
        }
        let pos = &mut 8usize;
        let version = u32::from_le_bytes(take(body, pos, 4)?.try_into().ok()?);
        if version != MANIFEST_VERSION {
            return None;
        }
        let epoch = u64::from_le_bytes(take(body, pos, 8)?.try_into().ok()?);
        let num_bins = u32::from_le_bytes(take(body, pos, 4)?.try_into().ok()?);
        let bins_per_group = u32::from_le_bytes(take(body, pos, 4)?.try_into().ok()?);
        let nsec = u32::from_le_bytes(take(body, pos, 4)?.try_into().ok()?) as usize;
        let mut sections = Vec::with_capacity(nsec.min(1024));
        for _ in 0..nsec {
            let tag = take(body, pos, 1)?[0];
            let group = u32::from_le_bytes(take(body, pos, 4)?.try_into().ok()?);
            let id = SectionId::from_tag(tag, group)?;
            let name_len = u16::from_le_bytes(take(body, pos, 2)?.try_into().ok()?) as usize;
            let file = std::str::from_utf8(take(body, pos, name_len)?).ok()?.to_string();
            let len = u64::from_le_bytes(take(body, pos, 8)?.try_into().ok()?);
            let checksum = u64::from_le_bytes(take(body, pos, 8)?.try_into().ok()?);
            sections.push(SectionRecord { id, file, len, checksum });
        }
        if *pos != body.len() || bins_per_group == 0 {
            return None;
        }
        Some(Self { epoch, num_bins, bins_per_group, sections })
    }
}

/// fsync a directory so renames/creates inside it are durable (on Linux a
/// directory opens read-only and `fsync` flushes its dirents).
pub fn fsync_dir(dir: &Path) -> Result<()> {
    faults::check(faults::Site::DirFsync)
        .and_then(|()| File::open(dir))
        .and_then(|f| f.sync_all())
        .map_err(|e| Error::io(dir, e))
}

/// Write `dir/name` and fsync the file (NOT the directory — callers batch
/// one directory fsync after the manifest commit). Section files have
/// epoch-unique names, so no tmp+rename dance is needed: a torn write can
/// only tear a file no committed manifest references yet.
pub fn write_section_file(dir: &Path, name: &str, bytes: &[u8]) -> Result<()> {
    write_section_file_charged(dir, name, bytes, None)
}

/// [`write_section_file`] that charges the simulated backend when a
/// [`SimNetFs`](crate::storage::netfs::SimNetFs) profile is active: one
/// write op for the body plus one metadata op for the create.
pub fn write_section_file_charged(
    dir: &Path,
    name: &str,
    bytes: &[u8],
    netfs: Option<&crate::storage::netfs::SimNetFs>,
) -> Result<()> {
    let path = dir.join(name);
    faults::check(faults::Site::Create).map_err(|e| Error::io(&path, e))?;
    let mut f = File::create(&path).map_err(|e| Error::io(&path, e))?;
    faults::write_full(&mut f, bytes, faults::Site::Write).map_err(|e| Error::io(&path, e))?;
    faults::check(faults::Site::Fsync)
        .and_then(|()| f.sync_all())
        .map_err(|e| Error::io(&path, e))?;
    if let Some(fs) = netfs {
        fs.charge_metadata(1);
        fs.charge_io(1, bytes.len() as u64, 1);
    }
    Ok(())
}

/// Name of the per-epoch staging file a manifest commit writes before the
/// atomic rename. Epoch-unique so pipelined commits never share a tmp.
pub fn manifest_tmp_name(epoch: u64) -> String {
    format!("manifest-{epoch:012}.tmp")
}

/// Commit a manifest: per-epoch tmp file + fsync + atomic rename +
/// directory fsync. After this returns, `manifest-<epoch>.bin` is durably
/// the newest complete manifest.
pub fn commit_manifest(dir: &Path, m: &Manifest) -> Result<()> {
    commit_manifest_charged(dir, m, None)
}

/// [`commit_manifest`] that charges the simulated backend when a
/// [`SimNetFs`](crate::storage::netfs::SimNetFs) profile is active: one
/// write op for the image plus metadata ops for the create/rename/dir
/// fsync round trips.
pub fn commit_manifest_charged(
    dir: &Path,
    m: &Manifest,
    netfs: Option<&crate::storage::netfs::SimNetFs>,
) -> Result<()> {
    let bytes = m.serialize();
    let tmp = dir.join(manifest_tmp_name(m.epoch));
    {
        faults::check(faults::Site::Create).map_err(|e| Error::io(&tmp, e))?;
        let mut f = File::create(&tmp).map_err(|e| Error::io(&tmp, e))?;
        faults::write_full(&mut f, &bytes, faults::Site::Write)
            .map_err(|e| Error::io(&tmp, e))?;
        faults::check(faults::Site::Fsync)
            .and_then(|()| f.sync_all())
            .map_err(|e| Error::io(&tmp, e))?;
    }
    let fin = dir.join(manifest_file_name(m.epoch));
    faults::check(faults::Site::Rename)
        .and_then(|()| fs::rename(&tmp, &fin))
        .map_err(|e| Error::io(&fin, e))?;
    fsync_dir(dir)?;
    if let Some(fs) = netfs {
        fs.charge_metadata(3);
        fs.charge_io(1, bytes.len() as u64, 1);
    }
    Ok(())
}

/// Read + verify one manifest; `None` if missing, torn, or corrupt.
pub fn read_manifest(dir: &Path, epoch: u64) -> Option<Manifest> {
    let buf = fs::read(dir.join(manifest_file_name(epoch))).ok()?;
    let m = Manifest::deserialize(&buf)?;
    (m.epoch == epoch).then_some(m)
}

/// Read + verify one section's bytes; `None` on missing file, length
/// mismatch, or checksum mismatch.
pub fn read_section(dir: &Path, rec: &SectionRecord) -> Option<Vec<u8>> {
    let buf = fs::read(dir.join(&rec.file)).ok()?;
    (buf.len() as u64 == rec.len && fnv1a(&buf) == rec.checksum).then_some(buf)
}

/// Load every section of `m`; `None` if any is missing or corrupt.
pub fn load_sections(dir: &Path, m: &Manifest) -> Option<HashMap<SectionId, Vec<u8>>> {
    let mut out = HashMap::with_capacity(m.sections.len());
    for rec in &m.sections {
        out.insert(rec.id, read_section(dir, rec)?);
    }
    Some(out)
}

/// Best-effort garbage collection after a manifest commit: remove every
/// `manifest-*.bin` / `mgmt-*.bin` not referenced by the manifests in
/// `keep` (the committer passes the new manifest and its predecessor, so
/// the fallback chain stays intact), plus the legacy monolithic
/// `management.bin` the segmented format supersedes. Errors are swallowed
/// — orphans are retried on the next sync and are ignored by recovery.
///
/// Deletion is gated on the reader pin registry
/// ([`crate::alloc::readers`]): the epoch a live lease pins — and every
/// section file that epoch's manifest references — survives, however
/// many commits supersede it. Stale leases (dead readers) are reaped by
/// the same scan. If any live lease is mid-transition or unreadable, or
/// a pinned manifest cannot be read back, **nothing** epoch-like is
/// deleted this round: deletion is the unrecoverable direction, and the
/// next commit retries.
pub fn gc(dir: &Path, keep: &[&Manifest]) {
    let mut referenced: HashSet<String> = HashSet::new();
    let mut protected_epochs: Vec<u64> = Vec::new();
    for m in keep {
        referenced.insert(manifest_file_name(m.epoch));
        for r in &m.sections {
            referenced.insert(r.file.clone());
        }
        protected_epochs.push(m.epoch);
    }
    let pins = crate::alloc::readers::scan_pins(dir);
    let mut conservative = pins.pin_all;
    for &e in &pins.epochs {
        if !protected_epochs.contains(&e) {
            protected_epochs.push(e);
        }
        if referenced.contains(&manifest_file_name(e)) {
            continue;
        }
        match read_manifest(dir, e) {
            Some(m) => {
                referenced.insert(manifest_file_name(e));
                for r in &m.sections {
                    referenced.insert(r.file.clone());
                }
            }
            // the pinned manifest should exist (it was protected when
            // pinned); if it cannot be read, delete nothing
            None => conservative = true,
        }
    }
    let Ok(rd) = fs::read_dir(dir) else { return };
    for entry in rd.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let stale_mgmt = !conservative
            && (name.starts_with("mgmt-") || name.starts_with("manifest-"))
            && name.ends_with(".bin")
            && !referenced.contains(name);
        let legacy = name == "management.bin" || name == "management.bin.tmp";
        // a manifest tmp (legacy shared name or a per-epoch
        // `manifest-<e>.tmp`) can only be a leftover from a commit that
        // crashed between write and rename (the current commit already
        // renamed its own tmp before gc runs, and pipelined commits are
        // strictly ordered, so no later epoch's tmp is in flight here)
        let orphan_tmp = name == "manifest.tmp"
            || (name.starts_with("manifest-") && name.ends_with(".tmp"));
        if stale_mgmt || legacy || orphan_tmp {
            let _ = fs::remove_file(entry.path());
        }
    }
    // the epoch-side chunk copies follow the same protection set
    if !conservative {
        crate::alloc::readers::gc_side_copies(dir, &protected_epochs);
    }
}

// ---- transient cache section codec ----

/// Encode the free-slot snapshot (`(bin, offset)` pairs).
pub fn encode_cache_section(entries: &[(u32, u64)]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(8 + entries.len() * 12);
    buf.extend_from_slice(&(entries.len() as u64).to_le_bytes());
    for &(bin, off) in entries {
        buf.extend_from_slice(&bin.to_le_bytes());
        buf.extend_from_slice(&off.to_le_bytes());
    }
    buf
}

pub fn decode_cache_section(buf: &[u8]) -> Option<Vec<(u32, u64)>> {
    let n = u64::from_le_bytes(buf.get(0..8)?.try_into().ok()?);
    // derive the count from the actual body length (no arithmetic on the
    // untrusted header: a crafted n must not overflow or pre-allocate)
    let body = buf.len().checked_sub(8)?;
    if body % 12 != 0 || n != (body / 12) as u64 {
        return None;
    }
    let n = body / 12;
    let mut out = Vec::with_capacity(n);
    let mut pos = 8;
    for _ in 0..n {
        let bin = u32::from_le_bytes(buf.get(pos..pos + 4)?.try_into().ok()?);
        let off = u64::from_le_bytes(buf.get(pos + 4..pos + 12)?.try_into().ok()?);
        out.push((bin, off));
        pos += 12;
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn sample_manifest(epoch: u64) -> Manifest {
        Manifest {
            epoch,
            num_bins: 44,
            bins_per_group: BINS_PER_GROUP as u32,
            sections: vec![
                SectionRecord {
                    id: SectionId::Chunks,
                    file: SectionId::Chunks.file_name(epoch),
                    len: 10,
                    checksum: 99,
                },
                SectionRecord {
                    id: SectionId::Bins(2),
                    file: SectionId::Bins(2).file_name(epoch),
                    len: 7,
                    checksum: 5,
                },
                SectionRecord {
                    id: SectionId::Cache,
                    file: SectionId::Cache.file_name(epoch),
                    len: 8,
                    checksum: 1,
                },
            ],
        }
    }

    #[test]
    fn manifest_roundtrip_and_checksum_rejects() {
        let m = sample_manifest(7);
        let bytes = m.serialize();
        assert_eq!(Manifest::deserialize(&bytes), Some(m.clone()));
        // any single-byte flip is caught by the trailer checksum
        for i in [0usize, 9, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[i] ^= 0xFF;
            assert!(Manifest::deserialize(&bad).is_none(), "flip at {i}");
        }
        // truncation at every length is rejected
        for cut in 0..bytes.len() {
            assert!(Manifest::deserialize(&bytes[..cut]).is_none(), "cut at {cut}");
        }
    }

    #[test]
    fn file_names_parse_back() {
        assert_eq!(parse_manifest_epoch(&manifest_file_name(42)), Some(42));
        assert_eq!(parse_manifest_epoch("manifest-.bin"), None);
        assert_eq!(parse_manifest_epoch("mgmt-chunks-000000000001.bin"), None);
        assert_eq!(SectionId::Bins(3).file_name(1), "mgmt-bins003-000000000001.bin");
    }

    #[test]
    fn group_partition_covers_all_bins() {
        for nb in [1usize, 7, 8, 9, 44, 64] {
            let mut seen = vec![false; nb];
            for g in 0..num_groups(nb) {
                for b in group_bins(g, nb) {
                    assert!(!seen[b]);
                    seen[b] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "nb={nb}");
        }
    }

    #[test]
    fn commit_read_gc_cycle() {
        let d = TempDir::new("mgmtio");
        let dir = d.path();
        // epoch 1: write its sections + manifest
        let mut m1 = sample_manifest(1);
        for r in &mut m1.sections {
            let data = vec![r.id.tag(); 4];
            r.len = data.len() as u64;
            r.checksum = fnv1a(&data);
            write_section_file(dir, &r.file, &data).unwrap();
        }
        commit_manifest(dir, &m1).unwrap();
        assert_eq!(list_manifest_epochs(dir).unwrap(), vec![1]);
        assert_eq!(read_manifest(dir, 1), Some(m1.clone()));
        assert!(load_sections(dir, &m1).is_some());

        // epoch 2 rewrites only the cache section; chunks/bins carried over
        let mut m2 = m1.clone();
        m2.epoch = 2;
        let cache = encode_cache_section(&[(3, 64), (0, 128)]);
        let rec = m2.sections.iter_mut().find(|r| r.id == SectionId::Cache).unwrap();
        rec.file = SectionId::Cache.file_name(2);
        rec.len = cache.len() as u64;
        rec.checksum = fnv1a(&cache);
        write_section_file(dir, &rec.file, &cache).unwrap();
        commit_manifest(dir, &m2).unwrap();
        gc(dir, &[&m2, &m1]);
        // both manifests and all referenced sections survive GC
        assert_eq!(list_manifest_epochs(dir).unwrap(), vec![1, 2]);
        assert!(load_sections(dir, &m2).is_some());
        assert!(load_sections(dir, &m1).is_some());

        // epoch 3: carry everything; GC keeping {3, 2} drops manifest 1
        let mut m3 = m2.clone();
        m3.epoch = 3;
        commit_manifest(dir, &m3).unwrap();
        gc(dir, &[&m3, &m2]);
        assert_eq!(list_manifest_epochs(dir).unwrap(), vec![2, 3]);
        // epoch 1's cache section is unreferenced now and was collected
        assert!(!dir.join(SectionId::Cache.file_name(1)).exists());
        // the shared chunks section (still referenced) survives
        assert!(dir.join(SectionId::Chunks.file_name(1)).exists());
    }

    #[test]
    fn gc_removes_legacy_monolith_and_orphans() {
        let d = TempDir::new("mgmtio-gc");
        let dir = d.path();
        std::fs::write(dir.join("management.bin"), b"legacy").unwrap();
        std::fs::write(dir.join("mgmt-names-000000000009.bin"), b"orphan").unwrap();
        std::fs::write(dir.join("manifest.tmp"), b"torn commit leftover").unwrap();
        std::fs::write(dir.join(manifest_tmp_name(4)), b"torn pipelined commit").unwrap();
        std::fs::write(dir.join("meta.bin"), b"keepme").unwrap();
        let m = sample_manifest(10);
        gc(dir, &[&m]);
        assert!(!dir.join("management.bin").exists());
        assert!(!dir.join("mgmt-names-000000000009.bin").exists());
        assert!(!dir.join("manifest.tmp").exists(), "crashed-commit tmp collected");
        assert!(!dir.join(manifest_tmp_name(4)).exists(), "per-epoch tmp collected");
        assert!(dir.join("meta.bin").exists(), "non-management files untouched");
    }

    #[test]
    fn torn_section_invalidates_manifest() {
        let d = TempDir::new("mgmtio-torn");
        let dir = d.path();
        let data = b"section-bytes".to_vec();
        let mut m = sample_manifest(5);
        m.sections.truncate(1);
        m.sections[0].len = data.len() as u64;
        m.sections[0].checksum = fnv1a(&data);
        write_section_file(dir, &m.sections[0].file, &data).unwrap();
        commit_manifest(dir, &m).unwrap();
        assert!(load_sections(dir, &m).is_some());
        // truncate the section: checksum/length mismatch → unusable
        std::fs::write(dir.join(&m.sections[0].file), &data[..4]).unwrap();
        assert!(load_sections(dir, &m).is_none());
        // delete it: missing → unusable
        std::fs::remove_file(dir.join(&m.sections[0].file)).unwrap();
        assert!(load_sections(dir, &m).is_none());
    }

    #[test]
    fn cache_section_roundtrip() {
        let entries = vec![(0u32, 8u64), (7, 4096), (3, 123456)];
        let buf = encode_cache_section(&entries);
        assert_eq!(decode_cache_section(&buf), Some(entries));
        assert_eq!(decode_cache_section(&encode_cache_section(&[])), Some(vec![]));
        assert!(decode_cache_section(&buf[..buf.len() - 1]).is_none());
        assert!(decode_cache_section(&[]).is_none());
        // a crafted header count must be rejected without overflow or a
        // giant pre-allocation (checksums are not collision-resistant)
        let mut evil = u64::MAX.to_le_bytes().to_vec();
        assert!(decode_cache_section(&evil).is_none());
        evil.extend_from_slice(&[0u8; 12]);
        assert!(decode_cache_section(&evil).is_none(), "count/body mismatch");
    }
}

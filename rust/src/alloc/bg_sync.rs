//! Background sync engine: an epoch-pipelined, watermark-driven
//! asynchronous flusher with epoch tickets, layered on the incremental
//! (segmented-manifest) persist path.
//!
//! The PR-4 sync made persistence O(delta); this module takes it **off
//! the mutation path** entirely — and overlaps it with itself. A
//! [`SyncEngine`] owned by every read-write
//! [`super::manager::MetallManager`] runs two dedicated threads:
//!
//! - the **flusher** (`metall-bgsync`) takes consistent cuts
//!   ([`ManagerCore::prepare_epoch`]): it drains remote frees, swaps out
//!   the dirty data chunks, serializes every dirty management section
//!   *to memory* under one simultaneous lock acquisition, and assigns
//!   the cut its epoch number;
//! - the **committer** (`metall-bgcommit`) makes cuts durable
//!   ([`ManagerCore::commit_epoch`]): data msync, section-file writes,
//!   the fsync'd atomic manifest rename — where the time goes on a slow
//!   (Lustre/VAST-like) backend.
//!
//! Prepared cuts travel through a bounded in-memory queue
//! ([`super::manager::ManagerOptions::sync_pipeline_depth`], default 2
//! in-flight epochs): while epoch N's msync and section writes are still
//! in flight, the flusher may already take the cut for epoch N+1. The
//! queue is FIFO and the committer is single, so **manifests commit
//! strictly in epoch order** — N+1's rename never lands before N's (and
//! [`ManagerCore::commit_epoch`] refuses a non-monotone epoch outright).
//! Side-copy freezing for pinned readers keys off the epoch assigned at
//! cut time, so multiple uncommitted tags may briefly coexist; see
//! `alloc/readers`.
//!
//! Three triggers start a flush round:
//!
//! 1. **Dirty-byte watermark**: the chunk-granular `DirtyChunkSet` keeps
//!    a running count of un-synced data bytes; crossing the watermark
//!    kicks the flusher with one atomic swap + condvar signal — the
//!    writer never waits. The threshold is the **bandwidth-adaptive**
//!    value below when active, else the configured
//!    [`super::manager::ManagerOptions::sync_watermark_bytes`].
//! 2. **Interval timer**
//!    ([`super::manager::ManagerOptions::sync_interval_ms`]): the
//!    flusher's idle wait times out and flushes if anything — data *or*
//!    management sections — is dirty.
//! 3. **Explicit request**: `sync_async()` returns a [`SyncTicket`];
//!    `SyncTicket::wait()` blocks until the flush *epoch* covering the
//!    request has its manifest durably committed. `sync()` is exactly
//!    `sync_async()` + `wait()` — the durability contract of the old
//!    inline sync is unchanged.
//!
//! ## The adaptive watermark
//!
//! A fixed watermark is wrong on every backend but the one it was tuned
//! for: too low on Lustre (each flush pays a multi-ms round trip for few
//! bytes), too high on NVMe (data sits volatile for no reason). The
//! engine therefore measures, per committed epoch, the **effective flush
//! bandwidth** and the **fixed per-flush round-trip delay** (from the
//! [`crate::storage::netfs::SimNetFs`] charge account when a profile is
//! active, else measured wall time) and EWMA-smooths both
//! ([`EWMA_ALPHA`]). After [`MIN_ADAPTIVE_SAMPLES`] data-carrying
//! flushes the watermark is set near the measured **bandwidth-delay
//! product** — the batch size at which the bandwidth term catches up
//! with one op round trip — clamped to `[`[`ADAPTIVE_FLOOR`]`,
//! ceiling/2]` (or [`ADAPTIVE_CEILING_DEFAULT`] when no ceiling is
//! configured). The adaptive value only *arms the trigger* when a
//! watermark was configured at all and
//! [`super::manager::ManagerOptions::sync_watermark_adaptive`] is set;
//! it is always exported via [`BgSyncStats::adaptive_watermark_bytes`].
//!
//! ## Generations, riders, and the cheap quiesce point
//!
//! The engine counts *flush generations*: every explicit request bumps
//! `requested`; each cut captures `covered = requested` before it starts
//! — one cut coalesces every request made before it began, because those
//! callers' mutations (and their dirty marks) strictly precede the cut's
//! section serialization. `completed` advances to a cut's `covered` only
//! when its manifest is durable (commit order makes that monotone). A
//! round that finds **nothing dirty** while earlier epochs are still in
//! flight cannot advance `completed` yet — its requests are durable only
//! once those epochs land — so their generations *ride* (`riders`) and
//! are folded into `completed` when the queue drains.
//!
//! The quiesce point is the consistent cut
//! (`ManagerCore::serialize_sections_cut`): the flusher briefly holds
//! every management lock at once — in the allocator's own bin → chunks
//! order, so no serialization point can deadlock against it — while it
//! swaps out the dirty marks and serializes the dirty sections to
//! memory; a committed epoch is therefore the exact management state of
//! a single instant even with mutators running. All file I/O happens on
//! the committer, after the cut is released; per-core cache hits and
//! data writes are never paused at all.
//!
//! ## Backpressure
//!
//! Unbounded dirtying with a slow disk would let DRAM run arbitrarily
//! far ahead of the store. Above a hard ceiling
//! ([`super::manager::ManagerOptions::sync_ceiling_bytes`], default 4×
//! the watermark) the *writer* that crosses it stalls — kicking the
//! flusher and waiting on the flush-done condvar until the dirty
//! estimate drops — and every stall is counted
//! ([`BgSyncStats::writer_stalls`], `writer_stall_micros`). Stalls never
//! happen while the writer holds allocator locks (only the lock-free
//! `mark_data_dirty` path stalls), so the flusher can always make
//! progress; under the pipeline a stall ends as soon as the *cut* drains
//! the dirty set, not when the commit lands.
//!
//! ## Panic containment, failure attribution, and shutdown
//!
//! Both thread bodies run under `catch_unwind`: a panicking flusher or
//! committer marks the engine **dead**, wakes every waiter with an
//! error, and every subsequent `sync()`/`sync_async()`/`close()` returns
//! [`Error::BgSync`] — never a silent no-op. A dead engine also refuses
//! to write the `CLEAN` marker, so recovery falls back to the last
//! complete manifest. Attribution is per *epoch*, not per engine: if the
//! committer dies with epoch N committed and N+1 queued, tickets covered
//! by N still resolve `Ok` (their manifest is durable) and only tickets
//! mapping onto N+1 surface the error. `close()`/`Drop` drain the engine
//! (the flusher hands its last cuts to the committer, the committer
//! drains the queue), join both threads, and only then run the inline
//! close sync.
//!
//! I/O *errors* (as opposed to panics) are classified
//! ([`crate::storage::faults::classify`]) rather than uniformly fatal:
//! a **transiently** failed cut or commit re-marks everything it
//! cleared ([`ManagerCore::abort_epoch`]), a commit failure aborts
//! every *later* queued epoch too (their manifests would carry forward
//! section files the failed epoch never durably referenced), the
//! merged error span is recorded so exactly the covered tickets see
//! it, and the next flush retries with exponential backoff. A
//! **permanently** classified error (EROFS/ENODEV/ENXIO/EBADF), or
//! transients repeated past
//! [`super::manager::ManagerOptions::sync_fail_limit`] consecutive
//! rounds, instead **wounds** the manager
//! ([`ManagerCore::wound`]): the store flips to degraded read-only,
//! the engine parks ([`SyncEngine::park`] — dead-engine semantics with
//! the wound as the attributed reason), and `close()` refuses the
//! CLEAN marker.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock, RwLockReadGuard, RwLockWriteGuard, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::alloc::manager::{ManagerCore, PreparedEpoch};
use crate::error::{Error, Result};
use crate::storage::faults::FaultClass;

/// Error spans kept for ticket waiters; beyond this many *failed*
/// flushes, the oldest spans are evicted (a ticket can only outlive that
/// many flushes if nobody ever waited on it).
const MAX_ERROR_SPANS: usize = 32;

/// How long a stalled writer sleeps between dirty-estimate re-checks.
const STALL_RECHECK: Duration = Duration::from_millis(10);

/// Lower clamp of the adaptive watermark: never flush-batch less than
/// this, however low the measured bandwidth-delay product (64 KiB — one
/// default chunk).
pub(crate) const ADAPTIVE_FLOOR: u64 = 64 << 10;

/// Upper clamp of the adaptive watermark when no backpressure ceiling is
/// configured (256 MiB). With a ceiling, the clamp is `ceiling / 2` so
/// the trigger always fires well before writers stall.
pub(crate) const ADAPTIVE_CEILING_DEFAULT: u64 = 256 << 20;

/// EWMA smoothing factor for the measured bandwidth / delay.
const EWMA_ALPHA: f64 = 0.3;

/// Data-carrying flushes observed before the adaptive value overrides
/// the configured watermark (one sample is noise).
const MIN_ADAPTIVE_SAMPLES: u64 = 2;

/// Observability snapshot of the background engine
/// ([`super::manager::MetallManager::bg_sync_stats`]), exported as
/// `alloc.bgsync.*` by
/// [`crate::coordinator::metrics::record_bg_sync_stats`]. All counters
/// are cumulative over the engine's lifetime.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BgSyncStats {
    /// Flush rounds the flusher ran (any trigger; a round is one cut,
    /// whether it found work or not).
    pub flushes: u64,
    /// … of which failed — a cut or commit error (the dirty state was
    /// re-marked and the next flush retries; covered tickets see it).
    pub flush_failures: u64,
    /// Flushes triggered by the dirty-byte watermark.
    pub watermark_triggers: u64,
    /// Flushes triggered by the backpressure ceiling alone (ceiling-only
    /// configurations; when a watermark is also crossed the flush counts
    /// as a watermark trigger).
    pub ceiling_triggers: u64,
    /// Flushes triggered by the interval timer.
    pub interval_triggers: u64,
    /// Explicit `sync_async()` / `sync()` requests.
    pub explicit_requests: u64,
    /// Management-section bytes written by background flushes.
    pub section_bytes_flushed: u64,
    /// Application-data bytes flushed by background flushes.
    pub data_bytes_flushed: u64,
    /// Times a writer stalled at the backpressure ceiling.
    pub writer_stalls: u64,
    /// Total microseconds writers spent stalled.
    pub writer_stall_micros: u64,
    /// Configured watermark (bytes; 0 = trigger disabled).
    pub watermark_bytes: u64,
    /// Configured backpressure ceiling (bytes; 0 = disabled).
    pub ceiling_bytes: u64,
    /// Configured pipeline depth (maximum in-flight epochs).
    pub pipeline_depth: u64,
    /// Highest number of epochs ever simultaneously in flight
    /// (committing + queued). ≥ 2 means pipelining actually overlapped.
    pub pipeline_peak_in_flight: u64,
    /// Current bandwidth-adaptive watermark (bytes; 0 until
    /// [`MIN_ADAPTIVE_SAMPLES`] data flushes were measured).
    pub adaptive_watermark_bytes: u64,
    /// EWMA of the measured effective flush bandwidth (bytes/second,
    /// with the fixed per-flush delay removed; 0 until measured).
    pub measured_bandwidth_bps: u64,
    /// Manifest-bearing epochs durably committed by the committer.
    pub epochs_committed: u64,
    /// Is the flusher thread currently running?
    pub engine_running: bool,
    /// Did a background thread die (panic)? Every sync call errors from
    /// then on.
    pub engine_dead: bool,
}

/// A claim on one background flush epoch, returned by
/// [`super::manager::MetallManager::sync_async`]. [`Self::wait`] blocks
/// until the manifest of the flush covering this request is durably
/// committed and returns that flush's result. Dropping a ticket without
/// waiting is allowed (fire-and-forget); the flush still runs.
#[must_use = "a dropped ticket gives no durability signal; call wait()"]
pub struct SyncTicket<'e> {
    engine: Option<&'e SyncEngine>,
    gen: u64,
}

impl<'e> SyncTicket<'e> {
    /// A pre-completed ticket (read-only stores: nothing to flush).
    pub(crate) fn completed() -> Self {
        Self { engine: None, gen: 0 }
    }

    pub(crate) fn pending(engine: &'e SyncEngine, gen: u64) -> Self {
        Self { engine: Some(engine), gen }
    }

    /// The flush generation this ticket waits for (0 for pre-completed
    /// tickets). Monotonically increasing per manager.
    pub fn generation(&self) -> u64 {
        self.gen
    }

    /// Has the covering flush already committed **successfully**
    /// (non-blocking probe)? A covering flush that *failed* reports
    /// `false` — nothing was durably committed and the dirty state was
    /// restored for retry; call [`Self::wait`] to obtain the error.
    pub fn is_complete(&self) -> bool {
        match self.engine {
            None => true,
            Some(e) => e.is_covered(self.gen),
        }
    }

    /// Block until the flush epoch covering this request is durably
    /// committed; returns the flush's result. An engine that died
    /// (panicked flusher/committer) or shut down before covering the
    /// request returns [`Error::BgSync`] — but a generation whose epoch
    /// committed *before* the death still resolves `Ok`. A failed flush
    /// also surfaces as [`Error::BgSync`] carrying the original error's
    /// message: the concrete variant is flattened to a string because
    /// one flush may cover many coalesced waiters and the underlying
    /// errors are not cloneable.
    pub fn wait(self) -> Result<()> {
        match self.engine {
            None => Ok(()),
            Some(e) => e.wait_for(self.gen),
        }
    }
}

/// Bandwidth/delay estimator state behind the adaptive watermark.
struct AdaptiveCtl {
    /// EWMA of effective flush bandwidth (bytes/sec, delay removed).
    ewma_bw: f64,
    /// EWMA of the fixed per-flush round-trip delay (seconds).
    ewma_delay: f64,
    /// Data-carrying samples folded in so far.
    samples: u64,
}

/// Flusher/committer bookkeeping, all behind one mutex.
struct EngineState {
    /// Highest explicit flush generation requested.
    requested: u64,
    /// Highest generation durably covered by a committed epoch (or
    /// terminally failed — error spans carry the distinction).
    completed: u64,
    /// Highest generation a flush round has picked up (its cut is taken
    /// or in progress). Keeps the flusher from re-cutting generations
    /// whose epochs merely haven't committed yet.
    handled: u64,
    /// Generations handled by rounds that found nothing dirty while
    /// earlier epochs were still in flight: durable only once the queue
    /// drains, at which point they fold into `completed`.
    riders: u64,
    /// Watermark kick pending (set by writers, consumed by the flusher).
    kicked: bool,
    shutdown: bool,
    /// Panic payload of a dead background thread; sticky.
    dead: Option<String>,
    /// Failed-flush spans `(from_exclusive, to_inclusive, message)` for
    /// ticket waiters; bounded by [`MAX_ERROR_SPANS`].
    errors: VecDeque<(u64, u64, String)>,
    /// Prepared cuts awaiting commit, in strictly increasing epoch
    /// order; bounded by the pipeline depth (together with
    /// `committing`).
    queue: VecDeque<PreparedEpoch>,
    /// Generation of the cut the committer is currently making durable.
    committing: Option<u64>,
    /// Has the flusher thread returned? The committer may only exit a
    /// shutdown once this is set — the flusher pushes its final cuts
    /// while draining, and abandoning them un-committed and un-aborted
    /// would silently drop their changes (dirty flags were cleared at
    /// cut time).
    flusher_exited: bool,
    /// The flusher thread.
    thread: Option<JoinHandle<()>>,
    /// The committer thread.
    committer: Option<JoinHandle<()>>,
}

impl EngineState {
    fn in_flight(&self) -> usize {
        self.queue.len() + usize::from(self.committing.is_some())
    }

    /// Record a failed span for ticket waiters, merging the two oldest
    /// spans instead of evicting when full (over-approximating across
    /// the gap — a stale ticket may see a false *failure*, never a false
    /// durability Ok).
    fn push_error_span(&mut self, from: u64, to: u64, msg: String) {
        self.errors.push_back((from, to, msg));
        while self.errors.len() > MAX_ERROR_SPANS {
            let (f1, _, m1) = self.errors.pop_front().unwrap();
            let (_, t2, _) = self.errors.pop_front().unwrap();
            self.errors.push_front((f1, t2, m1));
        }
    }
}

/// The background sync engine: one per manager, lazily started (or at
/// open when a watermark/interval is configured). See the module docs.
pub(crate) struct SyncEngine {
    /// The manager this engine flushes. `Weak` breaks the ownership
    /// cycle: the *threads* hold strong `Arc`s for their lifetime, and
    /// `shutdown_and_join` always runs before the last strong reference
    /// outside the threads drops.
    target: Mutex<Weak<ManagerCore>>,
    state: Mutex<EngineState>,
    /// Wakes the flusher (request / kick / shutdown / freed pipeline
    /// slot).
    work_cv: Condvar,
    /// Wakes the committer (cut queued / shutdown).
    commit_cv: Condvar,
    /// Signalled after every finished round or commit (ticket waiters,
    /// stalled writers).
    done_cv: Condvar,
    /// Shared-held by the flusher across a cut and by the committer
    /// across a commit — the two may overlap each other (that is the
    /// pipeline). `snapshot()`/`doctor()`/the inline close sync take it
    /// exclusively so they never observe a half-committed epoch.
    flush_gate: RwLock<()>,
    watermark: AtomicU64,
    ceiling: AtomicU64,
    interval_ms: AtomicU64,
    /// Maximum in-flight epochs (committing + queued); ≥ 1.
    depth: usize,
    /// Does the adaptive value arm the watermark trigger?
    adaptive: bool,
    /// Consecutive failed flush rounds before the manager is wounded
    /// (degraded read-only); 0 = never auto-wound on transients.
    /// Permanently-classified errors wound regardless.
    fail_limit: u64,
    /// Consecutive failed flush rounds so far (reset by any success or
    /// no-op round).
    consec_failures: AtomicU64,
    /// Current adaptive watermark (0 until enough samples).
    adaptive_wm: AtomicU64,
    /// EWMA'd effective bandwidth for stats export (bytes/sec).
    measured_bw_bps: AtomicU64,
    ctl: Mutex<AdaptiveCtl>,
    /// Failed-flush retry backoff in ms (0 = none pending), shared
    /// between flusher (uses it in its idle wait) and committer (bumps
    /// it on commit failure). The watermark trigger is edge-driven by
    /// writes: without this, a transient I/O failure after the last
    /// write would leave dirty data volatile indefinitely on a
    /// watermark-only engine.
    retry_ms: AtomicU64,
    /// Collapses redundant watermark kicks to one condvar signal.
    kick_pending: AtomicBool,
    /// Test hook: makes the next cut panic inside the flusher thread.
    panic_inject: AtomicBool,
    /// Test hook: makes the next commit panic inside the committer.
    commit_panic_inject: AtomicBool,
    // -- cumulative counters (see BgSyncStats) --
    flushes: AtomicU64,
    flush_failures: AtomicU64,
    watermark_triggers: AtomicU64,
    ceiling_triggers: AtomicU64,
    interval_triggers: AtomicU64,
    explicit_requests: AtomicU64,
    section_bytes_flushed: AtomicU64,
    data_bytes_flushed: AtomicU64,
    writer_stalls: AtomicU64,
    writer_stall_micros: AtomicU64,
    pipeline_peak: AtomicU64,
    epochs_committed: AtomicU64,
}

impl SyncEngine {
    pub(crate) fn new(
        watermark_bytes: u64,
        ceiling_bytes: u64,
        interval_ms: u64,
        pipeline_depth: usize,
        adaptive: bool,
        fail_limit: u64,
    ) -> Self {
        Self {
            target: Mutex::new(Weak::new()),
            state: Mutex::new(EngineState {
                requested: 0,
                completed: 0,
                handled: 0,
                riders: 0,
                kicked: false,
                shutdown: false,
                dead: None,
                errors: VecDeque::new(),
                queue: VecDeque::new(),
                committing: None,
                flusher_exited: false,
                thread: None,
                committer: None,
            }),
            work_cv: Condvar::new(),
            commit_cv: Condvar::new(),
            done_cv: Condvar::new(),
            flush_gate: RwLock::new(()),
            watermark: AtomicU64::new(watermark_bytes),
            ceiling: AtomicU64::new(ceiling_bytes),
            interval_ms: AtomicU64::new(interval_ms),
            depth: pipeline_depth.max(1),
            adaptive,
            fail_limit,
            consec_failures: AtomicU64::new(0),
            adaptive_wm: AtomicU64::new(0),
            measured_bw_bps: AtomicU64::new(0),
            ctl: Mutex::new(AdaptiveCtl { ewma_bw: 0.0, ewma_delay: 0.0, samples: 0 }),
            retry_ms: AtomicU64::new(0),
            kick_pending: AtomicBool::new(false),
            panic_inject: AtomicBool::new(false),
            commit_panic_inject: AtomicBool::new(false),
            flushes: AtomicU64::new(0),
            flush_failures: AtomicU64::new(0),
            watermark_triggers: AtomicU64::new(0),
            ceiling_triggers: AtomicU64::new(0),
            interval_triggers: AtomicU64::new(0),
            explicit_requests: AtomicU64::new(0),
            section_bytes_flushed: AtomicU64::new(0),
            data_bytes_flushed: AtomicU64::new(0),
            writer_stalls: AtomicU64::new(0),
            writer_stall_micros: AtomicU64::new(0),
            pipeline_peak: AtomicU64::new(0),
            epochs_committed: AtomicU64::new(0),
        }
    }

    /// Bind the engine to its manager (called once, while the manager is
    /// being wrapped in its `Arc`).
    pub(crate) fn bind(&self, target: Weak<ManagerCore>) {
        *self.target.lock().unwrap() = target;
    }

    /// Should the flusher start at open (before any explicit request)?
    /// Any configured trigger or limit needs the thread: the watermark
    /// and interval flush on their own, and a (possibly ceiling-only)
    /// backpressure stall can only drain if a flusher exists to kick.
    pub(crate) fn auto_start(&self) -> bool {
        self.watermark.load(Ordering::Relaxed) > 0
            || self.interval_ms.load(Ordering::Relaxed) > 0
            || self.ceiling.load(Ordering::Relaxed) > 0
    }

    /// The exclusive flush gate: blocks both pipeline stages.
    /// `snapshot()`/`doctor()` hold it to exclude half-committed
    /// background epochs; the inline close sync holds it for uniformity.
    pub(crate) fn gate(&self) -> RwLockWriteGuard<'_, ()> {
        // A thread that panicked mid-flush poisons the gate; the store
        // is still recoverable (manifest protocol), so don't propagate
        // the poison to snapshot/doctor/close.
        self.flush_gate.write().unwrap_or_else(|p| p.into_inner())
    }

    /// The shared flush gate: held by the flusher across one cut and by
    /// the committer across one commit, so the two overlap each other
    /// but never an exclusive-gate holder.
    fn gate_shared(&self) -> RwLockReadGuard<'_, ()> {
        self.flush_gate.read().unwrap_or_else(|p| p.into_inner())
    }

    /// The watermark the trigger actually compares against: the
    /// adaptive estimate once armed, the configured value otherwise
    /// (and always 0 = disabled when no watermark was configured).
    pub(crate) fn effective_watermark(&self) -> u64 {
        let cfg = self.watermark.load(Ordering::Relaxed);
        if cfg == 0 || !self.adaptive {
            return cfg;
        }
        match self.adaptive_wm.load(Ordering::Relaxed) {
            0 => cfg,
            adaptive => adaptive,
        }
    }

    /// Fold one committed epoch's measurements into the bandwidth/delay
    /// estimator: `bytes` flushed, the seconds of I/O they took
    /// (simulated seconds when a netfs profile is active), and the fixed
    /// per-flush round-trip `delay_secs` (the bandwidth-independent
    /// term). Called by [`ManagerCore::commit_epoch`] for data-carrying
    /// epochs only.
    pub(crate) fn record_flush_sample(&self, bytes: u64, io_secs: f64, delay_secs: f64) {
        if bytes == 0 || io_secs <= 0.0 {
            return;
        }
        let bw_raw = bytes as f64 / (io_secs - delay_secs).max(1e-9);
        let delay = delay_secs.max(0.0);
        let mut c = self.ctl.lock().unwrap();
        if c.samples == 0 {
            c.ewma_bw = bw_raw;
            c.ewma_delay = delay;
        } else {
            c.ewma_bw = EWMA_ALPHA * bw_raw + (1.0 - EWMA_ALPHA) * c.ewma_bw;
            c.ewma_delay = EWMA_ALPHA * delay + (1.0 - EWMA_ALPHA) * c.ewma_delay;
        }
        c.samples += 1;
        self.measured_bw_bps.store(c.ewma_bw as u64, Ordering::Relaxed);
        if c.samples >= MIN_ADAPTIVE_SAMPLES {
            let ceiling = self.ceiling.load(Ordering::Relaxed);
            let hi = if ceiling > 0 {
                (ceiling / 2).max(ADAPTIVE_FLOOR)
            } else {
                ADAPTIVE_CEILING_DEFAULT
            };
            let bdp = (c.ewma_bw * c.ewma_delay) as u64;
            self.adaptive_wm.store(bdp.clamp(ADAPTIVE_FLOOR, hi), Ordering::Relaxed);
        }
    }

    /// Spawn the flusher + committer threads if not running. Idempotent.
    pub(crate) fn ensure_started(&self) -> Result<()> {
        {
            let st = self.state.lock().unwrap();
            if st.thread.is_some() {
                return Ok(());
            }
            if let Some(d) = &st.dead {
                return Err(Error::BgSync(format!("background flusher died: {d}")));
            }
            if st.shutdown {
                return Err(Error::BgSync("sync engine is shut down".into()));
            }
        }
        let weak = self.target.lock().unwrap().clone();
        let Some(mgr) = weak.upgrade() else {
            return Err(Error::BgSync("sync engine is not bound to a manager".into()));
        };
        let mut st = self.state.lock().unwrap();
        if st.thread.is_none() {
            let spawn = |name: &str, f: fn(Arc<ManagerCore>)| {
                let mgr = mgr.clone();
                std::thread::Builder::new()
                    .name(name.into())
                    .spawn(move || f(mgr))
                    .map_err(|e| Error::BgSync(format!("cannot spawn {name} thread: {e}")))
            };
            st.committer = Some(spawn("metall-bgcommit", Self::run_committer)?);
            match spawn("metall-bgsync", Self::run) {
                Ok(h) => st.thread = Some(h),
                Err(e) => {
                    // a committer with no flusher would wait forever;
                    // mark the engine dead so it drains and exits
                    st.dead = Some(e.to_string());
                    st.flusher_exited = true;
                    self.commit_cv.notify_all();
                    return Err(e);
                }
            }
        }
        Ok(())
    }

    /// Register an explicit flush request; returns its generation.
    pub(crate) fn request(&self) -> Result<u64> {
        self.ensure_started()?;
        let mut st = self.state.lock().unwrap();
        if let Some(d) = &st.dead {
            return Err(Error::BgSync(format!("background flusher died: {d}")));
        }
        if st.shutdown {
            return Err(Error::BgSync("sync engine is shut down".into()));
        }
        st.requested += 1;
        let gen = st.requested;
        self.explicit_requests.fetch_add(1, Ordering::Relaxed);
        self.work_cv.notify_one();
        Ok(gen)
    }

    /// Is `gen` covered by a *successful* flush? A failed covering flush
    /// (recorded error span) must not read as durable.
    fn is_covered(&self, gen: u64) -> bool {
        let st = self.state.lock().unwrap();
        st.completed >= gen && !st.errors.iter().any(|(from, to, _)| gen > *from && gen <= *to)
    }

    /// Block until generation `gen` is covered; return the covering
    /// flush's result. Checked **before** the dead flag so a generation
    /// whose epoch committed before a later panic still resolves `Ok`.
    pub(crate) fn wait_for(&self, gen: u64) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.completed >= gen {
                for (from, to, msg) in &st.errors {
                    if gen > *from && gen <= *to {
                        return Err(Error::BgSync(msg.clone()));
                    }
                }
                return Ok(());
            }
            if let Some(d) = &st.dead {
                return Err(Error::BgSync(format!("background flusher died: {d}")));
            }
            if st.shutdown && st.thread.is_none() && st.committer.is_none() {
                return Err(Error::BgSync(
                    "sync engine shut down before the flush completed".into(),
                ));
            }
            st = self.done_cv.wait(st).unwrap();
        }
    }

    /// Hot-path hook, called by `mark_data_dirty` after marking: kicks
    /// the flusher when the dirty estimate crosses the (adaptive)
    /// watermark (or an explicitly configured ceiling — backpressure
    /// works even without a watermark trigger) and stalls the calling
    /// writer above the hard ceiling. Two relaxed atomic loads when
    /// neither is configured.
    #[inline]
    pub(crate) fn on_data_marked(&self, mgr: &ManagerCore) {
        let wm = self.effective_watermark();
        let ceiling = self.ceiling.load(Ordering::Relaxed);
        if wm == 0 && ceiling == 0 {
            return;
        }
        let dirty = mgr.dirty_data_bytes();
        let over_wm = wm > 0 && dirty >= wm;
        let over_ceiling = ceiling > 0 && dirty >= ceiling;
        // load-before-swap: in the steady state (kick already pending)
        // every writer takes the read-only branch, keeping the shared
        // line out of RMW ping-pong — same discipline as DirtyChunkSet
        if (over_wm || over_ceiling)
            && !self.kick_pending.load(Ordering::Relaxed)
            && !self.kick_pending.swap(true, Ordering::Relaxed)
        {
            // retry a failed open-time spawn here: watermark/interval-only
            // workloads may never call sync(), and this branch (rare —
            // kick_pending collapses it) is their only trigger edge. A
            // running engine returns immediately.
            let _ = self.ensure_started();
            let mut st = self.state.lock().unwrap();
            st.kicked = true;
            self.work_cv.notify_one();
        }
        if over_ceiling {
            self.stall_writer(mgr, ceiling);
        }
    }

    /// Backpressure: hold the writer until the flusher drains the dirty
    /// estimate below the ceiling — or stops making progress. Called
    /// with no allocator locks held. A flush that *fails* while we wait
    /// ends the stall (the dirty set was re-marked and cannot drain
    /// right now; hanging the infallible write APIs on a broken disk
    /// would be worse — the failure surfaces on the next `sync()`),
    /// so each write is stalled at most one failed-flush round-trip.
    /// Under the pipeline the stall ends at the *cut* (which drains the
    /// dirty set), not at the commit.
    fn stall_writer(&self, mgr: &ManagerCore, ceiling: u64) {
        let t0 = Instant::now();
        let failures0 = self.flush_failures.load(Ordering::Relaxed);
        let mut waited = false;
        let mut st = self.state.lock().unwrap();
        while st.dead.is_none()
            && !st.shutdown
            && st.thread.is_some()
            && self.flush_failures.load(Ordering::Relaxed) == failures0
            && mgr.dirty_data_bytes() >= ceiling
        {
            st.kicked = true;
            self.work_cv.notify_one();
            waited = true;
            let (guard, _) = self.done_cv.wait_timeout(st, STALL_RECHECK).unwrap();
            st = guard;
        }
        drop(st);
        if waited {
            let ns = t0.elapsed().as_nanos() as u64;
            let micros = ns / 1_000;
            self.writer_stalls.fetch_add(1, Ordering::Relaxed);
            self.writer_stall_micros.fetch_add(micros, Ordering::Relaxed);
            // Stalls ARE the tail the telemetry exists for: always
            // recorded (no sampling), plus a flight-recorder breadcrumb.
            mgr.telemetry().record_ns(crate::telemetry::Op::Stall, ns);
            mgr.telemetry().event(
                crate::telemetry::recorder::EventKind::CeilingStall,
                0,
                micros,
                mgr.dirty_data_bytes(),
                0,
            );
        }
    }

    /// Stop both threads: signal shutdown, join the flusher (it hands
    /// any outstanding requests to the committer as final cuts first),
    /// then join the committer (it drains the queue), and report a dead
    /// engine as an error. Idempotent.
    pub(crate) fn shutdown_and_join(&self) -> Result<()> {
        let flusher = {
            let mut st = self.state.lock().unwrap();
            st.shutdown = true;
            self.work_cv.notify_all();
            self.commit_cv.notify_all();
            st.thread.take()
        };
        if let Some(h) = flusher {
            // A panic is already captured in `dead` via catch_unwind;
            // join only fails if the unwind escaped it, which the Err
            // below reports through the same channel.
            if h.join().is_err() {
                let mut st = self.state.lock().unwrap();
                if st.dead.is_none() {
                    st.dead = Some("flusher thread aborted".into());
                }
            }
        }
        let committer = {
            let mut st = self.state.lock().unwrap();
            self.commit_cv.notify_all();
            st.committer.take()
        };
        if let Some(h) = committer {
            if h.join().is_err() {
                let mut st = self.state.lock().unwrap();
                if st.dead.is_none() {
                    st.dead = Some("committer thread aborted".into());
                }
            }
        }
        self.done_cv.notify_all();
        let st = self.state.lock().unwrap();
        match &st.dead {
            Some(d) => Err(Error::BgSync(format!("background flusher died: {d}"))),
            None => Ok(()),
        }
    }

    pub(crate) fn stats(&self) -> BgSyncStats {
        let ld = |c: &AtomicU64| c.load(Ordering::Relaxed);
        let st = self.state.lock().unwrap();
        BgSyncStats {
            flushes: ld(&self.flushes),
            flush_failures: ld(&self.flush_failures),
            watermark_triggers: ld(&self.watermark_triggers),
            ceiling_triggers: ld(&self.ceiling_triggers),
            interval_triggers: ld(&self.interval_triggers),
            explicit_requests: ld(&self.explicit_requests),
            section_bytes_flushed: ld(&self.section_bytes_flushed),
            data_bytes_flushed: ld(&self.data_bytes_flushed),
            writer_stalls: ld(&self.writer_stalls),
            writer_stall_micros: ld(&self.writer_stall_micros),
            watermark_bytes: self.watermark.load(Ordering::Relaxed),
            ceiling_bytes: self.ceiling.load(Ordering::Relaxed),
            pipeline_depth: self.depth as u64,
            pipeline_peak_in_flight: ld(&self.pipeline_peak),
            adaptive_watermark_bytes: ld(&self.adaptive_wm),
            measured_bandwidth_bps: ld(&self.measured_bw_bps),
            epochs_committed: ld(&self.epochs_committed),
            // a dead flusher's JoinHandle lingers until shutdown takes
            // it; "running" must mean alive AND able to flush
            engine_running: st.thread.is_some() && st.dead.is_none(),
            engine_dead: st.dead.is_some(),
        }
    }

    /// Test hook: the next cut panics inside the flusher thread.
    #[allow(dead_code)]
    pub(crate) fn inject_panic_for_tests(&self) {
        self.panic_inject.store(true, Ordering::Relaxed);
    }

    /// Test hook: the next commit panics inside the committer thread.
    #[allow(dead_code)]
    pub(crate) fn inject_commit_panic_for_tests(&self) {
        self.commit_panic_inject.store(true, Ordering::Relaxed);
    }

    /// Exponential failed-flush backoff: 50 ms → 5 s, cleared by any
    /// successful commit or no-op round.
    fn bump_retry(&self) {
        let r = self.retry_ms.load(Ordering::Relaxed);
        self.retry_ms.store((r.max(25) * 2).min(5000), Ordering::Relaxed);
    }

    /// Park the engine on behalf of a wounded manager: both threads
    /// drain what they already hold and exit, every waiter (tickets,
    /// stalled writers) is woken with the reason attributed, and all
    /// subsequent `request()`/`wait_for()`/`shutdown_and_join()` calls
    /// error — so `close()` refuses the CLEAN marker. Reuses the dead
    /// channel: a parked engine behaves exactly like one whose thread
    /// died, except the reason names the wound instead of a panic.
    pub(crate) fn park(&self, reason: String) {
        let mut st = self.state.lock().unwrap();
        if st.dead.is_none() {
            st.dead = Some(reason);
        }
        drop(st);
        self.done_cv.notify_all();
        self.work_cv.notify_all();
        self.commit_cv.notify_all();
    }

    /// Classify one failed flush/commit round and decide whether the
    /// manager must flip to degraded read-only: immediately for a
    /// [`FaultClass::Permanent`] error (the backend is gone), or after
    /// [`Self::fail_limit`] consecutive transient failures (the
    /// existing backoff retried and the backend never came back).
    /// Returns the wound reason; the caller invokes `mgr.wound()` with
    /// it **outside** the engine state lock (wound parks the engine,
    /// which re-takes it).
    fn note_round_failure(&self, mgr: &ManagerCore, e: &Error) -> Option<String> {
        let consec = self.consec_failures.fetch_add(1, Ordering::Relaxed) + 1;
        let class = crate::storage::faults::classify(e);
        mgr.count_flush_failure(class);
        match class {
            FaultClass::Permanent => Some(format!("permanent backend failure: {e}")),
            FaultClass::Transient if self.fail_limit > 0 && consec >= self.fail_limit => {
                Some(format!(
                    "{consec} consecutive failed flush rounds (limit {}), last: {e}",
                    self.fail_limit
                ))
            }
            FaultClass::Transient => None,
        }
    }

    /// The flusher thread body: decide a trigger, wait for a pipeline
    /// slot, take one consistent cut, hand it to the committer. Holds a
    /// strong `Arc` for its whole life; exits on shutdown (after every
    /// outstanding request's cut is taken — the committer finishes the
    /// queue) or when the engine is dead.
    fn run(mgr: Arc<ManagerCore>) {
        let eng = mgr.engine();
        loop {
            // Decide what to flush under the state lock.
            let covered;
            let prev_handled;
            {
                let mut st = eng.state.lock().unwrap();
                loop {
                    if st.dead.is_some() {
                        st.flusher_exited = true;
                        eng.commit_cv.notify_all();
                        return;
                    }
                    let slot_free = st.in_flight() < eng.depth;
                    if !slot_free {
                        // full pipeline: wait for the committer to pop
                        st = eng.work_cv.wait(st).unwrap();
                        continue;
                    }
                    if st.requested > st.handled {
                        covered = st.requested;
                        break;
                    }
                    if st.shutdown {
                        // every request has its cut: clean exit — the
                        // committer finishes the queued ones
                        st.flusher_exited = true;
                        eng.commit_cv.notify_all();
                        return;
                    }
                    if st.kicked {
                        st.kicked = false;
                        eng.kick_pending.store(false, Ordering::Relaxed);
                        let wm = eng.effective_watermark();
                        let ceiling = eng.ceiling.load(Ordering::Relaxed);
                        let dirty = mgr.dirty_data_bytes();
                        // flush when either limit is crossed: a stalled
                        // writer at a ceiling-only configuration must
                        // still be drained
                        let over_wm = wm > 0 && dirty >= wm;
                        let over_ceiling = ceiling > 0 && dirty >= ceiling;
                        if over_wm || over_ceiling {
                            if over_wm {
                                eng.watermark_triggers.fetch_add(1, Ordering::Relaxed);
                            } else {
                                eng.ceiling_triggers.fetch_add(1, Ordering::Relaxed);
                            }
                            mgr.telemetry().event(
                                crate::telemetry::recorder::EventKind::WatermarkKick,
                                if over_wm { 0 } else { 1 },
                                dirty,
                                if over_wm { wm } else { ceiling },
                                0,
                            );
                            covered = st.requested; // == handled: pure bg flush
                            break;
                        }
                        continue;
                    }
                    let iv = eng.interval_ms.load(Ordering::Relaxed);
                    let retry = eng.retry_ms.load(Ordering::Relaxed);
                    let wait_ms = match (iv, retry) {
                        (0, 0) => 0, // no timer: wait indefinitely
                        (0, r) => r,
                        (i, 0) => i,
                        (i, r) => i.min(r),
                    };
                    if wait_ms == 0 {
                        st = eng.work_cv.wait(st).unwrap();
                    } else {
                        let (guard, timeout) = eng
                            .work_cv
                            .wait_timeout(st, Duration::from_millis(wait_ms))
                            .unwrap();
                        st = guard;
                        if timeout.timed_out() && mgr.anything_dirty() {
                            if iv > 0 && (retry == 0 || iv <= retry) {
                                eng.interval_triggers.fetch_add(1, Ordering::Relaxed);
                                mgr.telemetry().event(
                                    crate::telemetry::recorder::EventKind::IntervalKick,
                                    0,
                                    iv,
                                    mgr.dirty_data_bytes(),
                                    0,
                                );
                            }
                            // (a pure failed-flush retry gets no trigger
                            // attribution; `flushes` still counts it)
                            covered = st.requested;
                            break;
                        }
                    }
                }
                prev_handled = st.handled;
                st.handled = covered;
            }
            // Take the cut outside the state lock: requests arriving
            // from here on get a generation > `covered` and trigger the
            // next round — their mutations may postdate this cut's
            // section snapshots. The shared gate lets an in-flight
            // commit overlap the cut but excludes snapshot/doctor.
            let result = catch_unwind(AssertUnwindSafe(|| {
                if eng.panic_inject.swap(false, Ordering::Relaxed) {
                    panic!("injected flusher panic (test hook)");
                }
                let _g = eng.gate_shared();
                mgr.prepare_epoch()
            }));
            let mut noop = false;
            let mut wound_reason: Option<String> = None;
            let mut st = eng.state.lock().unwrap();
            match result {
                Ok(cut) => {
                    eng.flushes.fetch_add(1, Ordering::Relaxed);
                    match cut {
                        Ok(Some(mut prep)) => {
                            prep.gen = covered;
                            st.queue.push_back(prep);
                            eng.pipeline_peak
                                .fetch_max(st.in_flight() as u64, Ordering::Relaxed);
                            eng.commit_cv.notify_all();
                            // `completed` advances when the commit lands
                        }
                        Ok(None) => {
                            // nothing dirty: requests up to `covered` are
                            // durable once every in-flight epoch lands
                            eng.retry_ms.store(0, Ordering::Relaxed);
                            eng.consec_failures.store(0, Ordering::Relaxed);
                            noop = true;
                            if st.in_flight() == 0 {
                                st.completed = st.completed.max(covered);
                            } else {
                                st.riders = st.riders.max(covered);
                            }
                        }
                        Err(e) => {
                            eng.flush_failures.fetch_add(1, Ordering::Relaxed);
                            eng.bump_retry();
                            wound_reason = eng.note_round_failure(&mgr, &e);
                            // prepare_epoch re-marked everything it had
                            // cleared; record the span so exactly the
                            // generations this round picked up see the
                            // failure (epochs already in the queue keep
                            // their own, earlier generations), then let
                            // the next round retry.
                            if covered > prev_handled {
                                st.push_error_span(prev_handled, covered, e.to_string());
                                if st.in_flight() == 0 {
                                    st.completed = st.completed.max(covered);
                                } else {
                                    st.riders = st.riders.max(covered);
                                }
                            }
                        }
                    }
                }
                Err(payload) => {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "flusher panicked".into());
                    st.dead = Some(msg);
                    st.flusher_exited = true;
                    drop(st);
                    mgr.telemetry().event(
                        crate::telemetry::recorder::EventKind::EngineDead,
                        1,
                        0,
                        0,
                        0,
                    );
                    mgr.telemetry().flush_recorder();
                    eng.done_cv.notify_all();
                    eng.commit_cv.notify_all(); // committer drains + exits
                    return;
                }
            }
            drop(st);
            if let Some(reason) = wound_reason {
                // parks the engine: the loop's next pass sees dead and
                // exits; the committer drains its queue first
                mgr.wound(reason);
            }
            if noop {
                // outside the state lock: the counter update takes
                // manager-side locks
                mgr.record_noop_sync();
            }
            eng.done_cv.notify_all();
        }
    }

    /// The committer thread body: pop cuts FIFO — hence strictly
    /// ascending epochs — and make each durable. Exits when the queue is
    /// empty and the engine is shut down or dead (a dead *flusher* does
    /// not abandon already-taken cuts: they still commit).
    fn run_committer(mgr: Arc<ManagerCore>) {
        let eng = mgr.engine();
        loop {
            let prep = {
                let mut st = eng.state.lock().unwrap();
                loop {
                    if let Some(p) = st.queue.pop_front() {
                        st.committing = Some(p.gen);
                        break p;
                    }
                    // exit only when no more cuts can arrive: the
                    // flusher pushes its final cuts while draining a
                    // shutdown
                    if st.dead.is_some() || (st.shutdown && st.flusher_exited) {
                        return;
                    }
                    st = eng.commit_cv.wait(st).unwrap();
                }
            };
            eng.work_cv.notify_all(); // a pipeline slot freed
            let result = catch_unwind(AssertUnwindSafe(|| {
                if eng.commit_panic_inject.swap(false, Ordering::Relaxed) {
                    panic!("injected committer panic (test hook)");
                }
                let _g = eng.gate_shared();
                mgr.commit_epoch(&prep)
            }));
            // Post-process under the state lock; aborts of later queued
            // epochs run after release (they take allocator locks).
            let mut aborted: Vec<PreparedEpoch> = Vec::new();
            let mut died = false;
            let mut wound_reason: Option<String> = None;
            {
                let mut st = eng.state.lock().unwrap();
                st.committing = None;
                match result {
                    Ok(Ok(())) => {
                        eng.retry_ms.store(0, Ordering::Relaxed);
                        eng.consec_failures.store(0, Ordering::Relaxed);
                        eng.epochs_committed.fetch_add(1, Ordering::Relaxed);
                        // last_sync describes this commit (written by
                        // commit_epoch just before returning Ok)
                        let s = mgr.sync_stats();
                        eng.section_bytes_flushed
                            .fetch_add(s.section_bytes_written, Ordering::Relaxed);
                        eng.data_bytes_flushed
                            .fetch_add(s.data_bytes_flushed, Ordering::Relaxed);
                        st.completed = st.completed.max(prep.gen);
                        if st.queue.is_empty() {
                            // rider generations (no-op rounds while this
                            // epoch was in flight) are durable now
                            st.completed = st.completed.max(st.riders);
                            st.riders = 0;
                        }
                    }
                    Ok(Err(e)) => {
                        eng.flush_failures.fetch_add(1, Ordering::Relaxed);
                        eng.bump_retry();
                        wound_reason = eng.note_round_failure(&mgr, &e);
                        // commit_epoch aborted this cut; every *later*
                        // queued epoch must abort too — committing it
                        // would carry forward section files this failed
                        // epoch never durably referenced. One merged
                        // span covers them all; the next round retries
                        // the union of their re-marked changes.
                        let mut maxg = prep.gen.max(st.riders);
                        while let Some(p) = st.queue.pop_front() {
                            maxg = maxg.max(p.gen);
                            aborted.push(p);
                        }
                        if maxg > st.completed {
                            let from = st.completed;
                            st.push_error_span(from, maxg, e.to_string());
                            st.completed = maxg;
                        }
                        st.riders = 0;
                        // retry edge for watermark-only configurations:
                        // the re-marked bytes re-arm the trigger path
                        st.kicked = true;
                    }
                    Err(payload) => {
                        let msg = payload
                            .downcast_ref::<&str>()
                            .map(|s| s.to_string())
                            .or_else(|| payload.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "committer panicked".into());
                        while let Some(p) = st.queue.pop_front() {
                            aborted.push(p);
                        }
                        st.dead = Some(msg);
                        died = true;
                    }
                }
            }
            for p in &aborted {
                mgr.abort_epoch(p);
            }
            if let Some(reason) = wound_reason {
                // outside the state lock (wound parks the engine). The
                // failed epoch and everything queued behind it were
                // already aborted above, so nothing is abandoned.
                mgr.wound(reason);
            }
            eng.done_cv.notify_all();
            eng.work_cv.notify_all();
            if died {
                mgr.telemetry().event(
                    crate::telemetry::recorder::EventKind::EngineDead,
                    2,
                    0,
                    0,
                    0,
                );
                mgr.telemetry().flush_recorder();
                eng.commit_cv.notify_all();
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::manager::{ManagerOptions, MetallManager};
    use crate::util::tmp::TempDir;

    fn opts() -> ManagerOptions {
        ManagerOptions::small_for_tests()
    }

    /// Poll `f` for up to ~5 s; panics with `what` on timeout.
    fn wait_until(what: &str, mut f: impl FnMut() -> bool) {
        for _ in 0..500 {
            if f() {
                return;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        panic!("timed out waiting for {what}");
    }

    #[test]
    fn explicit_ticket_commits_a_durable_manifest() {
        let d = TempDir::new("bg-ticket");
        let store = d.join("s");
        let m = MetallManager::create_with(&store, opts()).unwrap();
        m.construct::<u64>("x", 7).unwrap();
        let t = m.sync_async().unwrap();
        let gen = t.generation();
        assert!(gen >= 1);
        t.wait().unwrap();
        assert!(
            !crate::alloc::mgmt_io::list_manifest_epochs(&store).unwrap().is_empty(),
            "ticket resolved only after a manifest committed"
        );
        assert_eq!(m.sync_stats().manifest_commits, 1);
        // a second ticket on an unchanged store is a no-op flush
        let t2 = m.sync_async().unwrap();
        assert!(t2.generation() > gen);
        t2.wait().unwrap();
        assert_eq!(m.sync_stats().manifest_commits, 1, "no-op flush commits nothing");
        let bg = m.bg_sync_stats();
        assert!(bg.engine_running);
        assert_eq!(bg.explicit_requests, 2);
        assert!(bg.flushes >= 2);
        m.close().unwrap();
    }

    #[test]
    fn sync_is_sync_async_plus_wait() {
        let d = TempDir::new("bg-sync-eq");
        let m = MetallManager::create_with(d.join("s"), opts()).unwrap();
        m.construct::<u64>("v", 1).unwrap();
        m.sync().unwrap();
        let st = m.sync_stats();
        assert_eq!(st.syncs, 1);
        assert_eq!(st.manifest_commits, 1);
        assert_eq!(m.bg_sync_stats().explicit_requests, 1);
        m.close().unwrap();
    }

    #[test]
    fn watermark_flushes_without_an_explicit_sync() {
        let d = TempDir::new("bg-wm");
        let mut o = opts();
        // one dirty chunk (64 KiB test geometry) crosses the watermark
        o.sync_watermark_bytes = o.chunk_size;
        let m = MetallManager::create_with(d.join("s"), o).unwrap();
        assert!(m.bg_sync_stats().engine_running, "watermark config auto-starts the engine");
        // dirty several chunks' worth of data, never calling sync()
        let off = m.allocate(4 * m.chunk_size()).unwrap();
        unsafe { m.bytes_mut(off, 4 * m.chunk_size()).fill(0xAB) };
        wait_until("watermark-driven background flush", || {
            m.sync_stats().manifest_commits >= 1
        });
        let bg = m.bg_sync_stats();
        assert!(bg.watermark_triggers >= 1, "{bg:?}");
        assert_eq!(bg.explicit_requests, 0, "no explicit sync was issued");
        m.close().unwrap();
    }

    #[test]
    fn interval_timer_flushes_dirty_state() {
        let d = TempDir::new("bg-iv");
        let mut o = opts();
        o.sync_interval_ms = 10;
        let m = MetallManager::create_with(d.join("s"), o).unwrap();
        m.construct::<u64>("tick", 1).unwrap(); // management-only dirt
        wait_until("interval-driven background flush", || {
            m.sync_stats().manifest_commits >= 1
        });
        assert!(m.bg_sync_stats().interval_triggers >= 1);
        m.close().unwrap();
    }

    #[test]
    fn ceiling_stalls_writers_and_counts_it() {
        let d = TempDir::new("bg-stall");
        let mut o = opts();
        o.sync_watermark_bytes = 1; // any dirty byte kicks the flusher
        o.sync_ceiling_bytes = 1; // …and stalls the writer until drained
        let m = MetallManager::create_with(d.join("s"), o).unwrap();
        let off = m.allocate(4 * m.chunk_size()).unwrap();
        // every write re-dirties a chunk past the ceiling: each one must
        // stall until the flusher drains (64 rounds close the tiny
        // mark-vs-flush race window deterministically)
        for i in 0..64u64 {
            m.write::<u64>(off + (i % 4) * m.chunk_size() as u64, i);
        }
        let bg = m.bg_sync_stats();
        assert!(bg.writer_stalls >= 1, "ceiling must stall at least one write: {bg:?}");
        assert!(bg.writer_stall_micros > 0);
        assert!(bg.flushes >= 1, "the stall is resolved by a real flush");
        m.close().unwrap();
    }

    #[test]
    fn flusher_panic_is_contained_and_close_refuses_clean() {
        let d = TempDir::new("bg-panic");
        let store = d.join("s");
        {
            let m = MetallManager::create_with(&store, opts()).unwrap();
            m.construct::<u64>("pre", 1).unwrap();
            m.sync().unwrap(); // engine up, epoch 1 durable
            m.engine().inject_panic_for_tests();
            let err = m.sync().expect_err("a panicking flusher must surface as an error");
            assert!(format!("{err}").contains("died"), "{err}");
            // every subsequent sync call errors too — never a silent no-op
            assert!(m.sync_async().is_err());
            // close refuses to mark the store clean over a dead flusher
            assert!(m.close().is_err());
        }
        assert!(!store.join("CLEAN").exists(), "no CLEAN marker after a dead flusher");
        // recovery falls back to the last complete manifest
        let m = MetallManager::open_unclean(&store).unwrap();
        assert_eq!(m.read::<u64>(m.find::<u64>("pre").unwrap().unwrap()), 1);
        assert!(m.doctor().unwrap().is_empty());
        m.close().unwrap();
    }

    #[test]
    fn concurrent_tickets_coalesce_into_few_flushes() {
        let d = TempDir::new("bg-coalesce");
        let m = MetallManager::create_with(d.join("s"), opts()).unwrap();
        m.construct::<u64>("base", 0).unwrap();
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let m = &m;
                s.spawn(move || {
                    for i in 0..16u64 {
                        let off = m.allocate(64).unwrap();
                        m.write::<u64>(off, t * 1000 + i);
                        m.sync().unwrap();
                    }
                });
            }
        });
        let bg = m.bg_sync_stats();
        assert_eq!(bg.explicit_requests, 64);
        assert!(bg.flushes <= bg.explicit_requests, "one flush may cover many requests: {bg:?}");
        // Forced pile-up: with the flush gate held exclusively no cut can
        // start, so queued requests MUST coalesce — at most one in-flight
        // round (decided before we took the gate) plus one covering the
        // rest.
        let before = m.bg_sync_stats();
        let tickets: Vec<_> = {
            let gate = m.engine().gate();
            let t: Vec<_> = (0..10).map(|_| m.sync_async().unwrap()).collect();
            drop(gate);
            t
        };
        for t in tickets {
            t.wait().unwrap();
        }
        let after = m.bg_sync_stats();
        assert_eq!(after.explicit_requests - before.explicit_requests, 10);
        assert!(
            after.flushes - before.flushes <= 2,
            "10 gate-queued requests must coalesce into ≤ 2 flushes: {before:?} -> {after:?}"
        );
        m.close().unwrap();
    }

    #[test]
    fn private_mode_rejects_background_triggers() {
        // BsMsync's user-level msync reads + remaps pages under a
        // quiescent-writers contract; a background flush racing live
        // stores could remap stale file bytes over them. The combination
        // must be refused loudly at create *and* open.
        let d = TempDir::new("bg-private");
        for (wm, iv, ceil) in [(1usize, 0u64, 0usize), (0, 5, 0), (0, 0, 1)] {
            let mut o = opts();
            o.private_mode = true;
            o.sync_watermark_bytes = wm;
            o.sync_interval_ms = iv;
            o.sync_ceiling_bytes = ceil;
            let err = MetallManager::create_with(d.join("s"), o)
                .expect_err("private mode + background trigger must be rejected");
            assert!(format!("{err}").contains("bs-mmap"), "{err}");
        }
        // private mode without triggers still works, and a private store
        // reopened with triggers is rejected at open time too
        let mut o = opts();
        o.private_mode = true;
        let m = MetallManager::create_with(d.join("s"), o).unwrap();
        m.construct::<u64>("x", 1).unwrap();
        m.close().unwrap();
        let mut o = opts();
        o.private_mode = true;
        o.sync_watermark_bytes = 1;
        assert!(MetallManager::open_with(d.join("s"), o, false, false).is_err());
    }

    #[test]
    fn read_only_tickets_complete_immediately() {
        let d = TempDir::new("bg-ro");
        let store = d.join("s");
        {
            let m = MetallManager::create_with(&store, opts()).unwrap();
            m.construct::<u64>("x", 1).unwrap();
            m.close().unwrap();
        }
        let m = MetallManager::open_read_only(&store).unwrap();
        let t = m.sync_async().unwrap();
        assert!(t.is_complete());
        assert_eq!(t.generation(), 0);
        t.wait().unwrap();
        m.sync().unwrap();
        assert!(!m.bg_sync_stats().engine_running, "read-only stores run no flusher");
    }

    #[test]
    fn pipelined_commits_overlap_and_stay_epoch_ordered() {
        let d = TempDir::new("bg-pipe");
        let store = d.join("s");
        let mut o = opts();
        // slow modelled backend, really slept: each commit takes the
        // charged ~20 ms, so cuts run ahead of in-flight commits. The
        // upper-case name also exercises case-insensitive resolution.
        o.netfs_profile = Some("LUSTRE".into());
        o.netfs_sleep_scale = 1.0;
        let m = MetallManager::create_with(&store, o).unwrap();
        let cs = m.chunk_size();
        let off = m.allocate(8 * cs).unwrap();
        let mut tickets = Vec::new();
        for i in 0..6u64 {
            unsafe { m.bytes_mut(off + (i % 8) * cs as u64, cs).fill(i as u8 + 1) };
            tickets.push(m.sync_async().unwrap());
            // give the flusher time to cut this epoch while the previous
            // commit is still sleeping on the simulated backend
            std::thread::sleep(Duration::from_millis(3));
        }
        for t in tickets {
            t.wait().unwrap();
        }
        let bg = m.bg_sync_stats();
        assert_eq!(bg.pipeline_depth, 2, "default depth resolves to 2");
        assert!(
            bg.pipeline_peak_in_flight >= 2,
            "cuts must overlap in-flight commits: {bg:?}"
        );
        assert!(bg.epochs_committed >= 3, "{bg:?}");
        m.close().unwrap();
        // the surviving manifests are a strictly monotone tail of the
        // committed chain
        let epochs = crate::alloc::mgmt_io::list_manifest_epochs(&store).unwrap();
        assert!(!epochs.is_empty());
        assert!(epochs.windows(2).all(|w| w[0] < w[1]), "{epochs:?}");
        let m = MetallManager::open(&store).unwrap();
        assert!(m.doctor().unwrap().is_empty());
        m.close().unwrap();
    }

    #[test]
    fn adaptive_watermark_tracks_the_backend_bdp() {
        let d = TempDir::new("bg-adaptive");
        let mut o = opts();
        o.netfs_profile = Some("lustre".into()); // account only: no sleeps
        o.sync_watermark_bytes = 1 << 20;
        // keep ceiling/2 well above the Lustre BDP so the clamp is inert
        o.sync_ceiling_bytes = 64 << 20;
        let m = MetallManager::create_with(d.join("s"), o).unwrap();
        let cs = m.chunk_size();
        let off = m.allocate(4 * cs).unwrap();
        for round in 0..3u8 {
            unsafe { m.bytes_mut(off, 4 * cs).fill(round + 1) };
            m.sync().unwrap();
        }
        let bg = m.bg_sync_stats();
        let profile = crate::storage::netfs::LUSTRE;
        let bdp = profile.bdp_bytes();
        assert!(
            bg.adaptive_watermark_bytes >= bdp / 2 && bg.adaptive_watermark_bytes <= bdp * 2,
            "adaptive watermark {} should sit near the profile BDP {bdp}",
            bg.adaptive_watermark_bytes
        );
        let bw = bg.measured_bandwidth_bps as f64;
        assert!(
            bw >= profile.bandwidth / 2.0 && bw <= profile.bandwidth * 2.0,
            "measured bandwidth {bw} vs modelled {}",
            profile.bandwidth
        );
        m.close().unwrap();
    }

    #[test]
    fn committed_epochs_resolve_ok_after_a_committer_death() {
        let d = TempDir::new("bg-commit-death");
        let store = d.join("s");
        {
            let m = MetallManager::create_with(&store, opts()).unwrap();
            m.construct::<u64>("a", 1).unwrap();
            let t1 = m.sync_async().unwrap();
            wait_until("epoch 1 durably committed", || t1.is_complete());
            m.engine().inject_commit_panic_for_tests();
            m.construct::<u64>("b", 2).unwrap();
            let t2 = m.sync_async().unwrap();
            let err = t2.wait().expect_err("the queued epoch died with the committer");
            assert!(format!("{err}").contains("died"), "{err}");
            // the generation whose epoch committed before the death still
            // resolves Ok — failure attribution is per epoch, not per
            // engine
            assert!(t1.is_complete());
            t1.wait().unwrap();
            assert!(m.sync_async().is_err(), "dead engine refuses new work");
            assert!(m.close().is_err());
        }
        assert!(!store.join("CLEAN").exists());
        let m = MetallManager::open_unclean(&store).unwrap();
        assert_eq!(m.read::<u64>(m.find::<u64>("a").unwrap().unwrap()), 1);
        assert!(m.find::<u64>("b").unwrap().is_none(), "epoch 2 never committed");
        m.close().unwrap();
    }
}

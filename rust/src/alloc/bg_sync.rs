//! Background sync engine: a watermark-driven asynchronous flusher with
//! epoch tickets, layered on the incremental (segmented-manifest) persist
//! path.
//!
//! The PR-4 sync made persistence O(delta); this module takes it **off
//! the mutation path** entirely. A [`SyncEngine`] owned by every
//! read-write [`super::manager::MetallManager`] runs one dedicated
//! flusher thread (which in turn drives the existing flusher *pool* for
//! section writes and the range-narrowed data msync). Three triggers
//! start a flush:
//!
//! 1. **Dirty-byte high watermark**
//!    ([`super::manager::ManagerOptions::sync_watermark_bytes`]): the
//!    chunk-granular `DirtyChunkSet` keeps a running count of un-synced
//!    data bytes; crossing the watermark kicks the flusher with one
//!    atomic swap + condvar signal — the writer never waits.
//! 2. **Interval timer**
//!    ([`super::manager::ManagerOptions::sync_interval_ms`]): the
//!    flusher's idle wait times out and flushes if anything — data *or*
//!    management sections — is dirty.
//! 3. **Explicit request**: `sync_async()` returns a [`SyncTicket`];
//!    `SyncTicket::wait()` blocks until the flush *epoch* covering the
//!    request has its manifest durably committed (fsync'd atomic
//!    rename). `sync()` is exactly `sync_async()` + `wait()` — the
//!    durability contract of the old inline sync is unchanged.
//!
//! ## Epochs and the cheap quiesce point
//!
//! The engine counts *flush generations*: every explicit request bumps
//! `requested`; each flush captures `covered = requested` before it
//! starts and, on success, advances `completed` to it — one flush
//! coalesces every request made before it began, because those callers'
//! mutations (and their dirty-epoch marks) strictly precede the flush's
//! section serialization. The quiesce point is a **consistent cut**
//! (`ManagerCore::serialize_sections_cut`): the flusher briefly holds
//! every management lock at once — in the allocator's own bin → chunks
//! order, so no serialization point can deadlock against it — while it
//! swaps out the dirty marks and serializes the dirty sections *to
//! memory*; a committed epoch is therefore the exact management state
//! of a single instant even with mutators running (per-section lock
//! scopes would let a fresh chunk slip between two sections and commit
//! a bin that references a chunk the chunk section calls Free). All
//! file I/O — section writes, data msync, the manifest commit — happens
//! after the cut is released, which is where the time goes; per-core
//! cache hits and data writes are never paused at all.
//!
//! ## Backpressure
//!
//! Unbounded dirtying with a slow disk would let DRAM run arbitrarily
//! far ahead of the store. Above a hard ceiling
//! ([`super::manager::ManagerOptions::sync_ceiling_bytes`], default 4×
//! the watermark) the *writer* that crosses it stalls — kicking the
//! flusher and waiting on the flush-done condvar until the dirty
//! estimate drops — and every stall is counted
//! ([`BgSyncStats::writer_stalls`], `writer_stall_micros`). Stalls never
//! happen while the writer holds allocator locks (only the lock-free
//! `mark_data_dirty` path stalls), so the flusher can always make
//! progress.
//!
//! ## Panic containment and shutdown
//!
//! The flush body runs under `catch_unwind`: a panicking flusher marks
//! the engine **dead**, wakes every waiter with an error, and every
//! subsequent `sync()`/`sync_async()`/`close()` returns
//! [`Error::BgSync`] — never a silent no-op. A dead engine also refuses
//! to write the `CLEAN` marker, so recovery falls back to the last
//! complete manifest instead of trusting a store the flusher abandoned.
//! `close()`/`Drop` drain the engine (a final flush resolves any
//! outstanding tickets), join the thread, and only then run the inline
//! close sync.
//!
//! I/O *errors* (as opposed to panics) are not fatal: the failing flush
//! re-marks everything it cleared (`sync_now`'s existing contract), the
//! error span is recorded so the tickets it covered see it, and the next
//! flush retries.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::alloc::manager::ManagerCore;
use crate::error::{Error, Result};

/// Error spans kept for ticket waiters; beyond this many *failed*
/// flushes, the oldest spans are evicted (a ticket can only outlive that
/// many flushes if nobody ever waited on it).
const MAX_ERROR_SPANS: usize = 32;

/// How long a stalled writer sleeps between dirty-estimate re-checks.
const STALL_RECHECK: Duration = Duration::from_millis(10);

/// Observability snapshot of the background engine
/// ([`super::manager::MetallManager::bg_sync_stats`]), exported as
/// `alloc.bgsync.*` by
/// [`crate::coordinator::metrics::record_bg_sync_stats`]. All counters
/// are cumulative over the engine's lifetime.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BgSyncStats {
    /// Flushes the background thread ran (any trigger).
    pub flushes: u64,
    /// … of which returned an error (the dirty state was re-marked and
    /// the next flush retries; covered tickets see the failure).
    pub flush_failures: u64,
    /// Flushes triggered by the dirty-byte watermark.
    pub watermark_triggers: u64,
    /// Flushes triggered by the backpressure ceiling alone (ceiling-only
    /// configurations; when a watermark is also crossed the flush counts
    /// as a watermark trigger).
    pub ceiling_triggers: u64,
    /// Flushes triggered by the interval timer.
    pub interval_triggers: u64,
    /// Explicit `sync_async()` / `sync()` requests.
    pub explicit_requests: u64,
    /// Management-section bytes written by background flushes.
    pub section_bytes_flushed: u64,
    /// Application-data bytes flushed by background flushes.
    pub data_bytes_flushed: u64,
    /// Times a writer stalled at the backpressure ceiling.
    pub writer_stalls: u64,
    /// Total microseconds writers spent stalled.
    pub writer_stall_micros: u64,
    /// Configured watermark (bytes; 0 = trigger disabled).
    pub watermark_bytes: u64,
    /// Configured backpressure ceiling (bytes; 0 = disabled).
    pub ceiling_bytes: u64,
    /// Is the flusher thread currently running?
    pub engine_running: bool,
    /// Did the flusher die (panic)? Every sync call errors from then on.
    pub engine_dead: bool,
}

/// A claim on one background flush epoch, returned by
/// [`super::manager::MetallManager::sync_async`]. [`Self::wait`] blocks
/// until the manifest of the flush covering this request is durably
/// committed and returns that flush's result. Dropping a ticket without
/// waiting is allowed (fire-and-forget); the flush still runs.
#[must_use = "a dropped ticket gives no durability signal; call wait()"]
pub struct SyncTicket<'e> {
    engine: Option<&'e SyncEngine>,
    gen: u64,
}

impl<'e> SyncTicket<'e> {
    /// A pre-completed ticket (read-only stores: nothing to flush).
    pub(crate) fn completed() -> Self {
        Self { engine: None, gen: 0 }
    }

    pub(crate) fn pending(engine: &'e SyncEngine, gen: u64) -> Self {
        Self { engine: Some(engine), gen }
    }

    /// The flush generation this ticket waits for (0 for pre-completed
    /// tickets). Monotonically increasing per manager.
    pub fn generation(&self) -> u64 {
        self.gen
    }

    /// Has the covering flush already committed **successfully**
    /// (non-blocking probe)? A covering flush that *failed* reports
    /// `false` — nothing was durably committed and the dirty state was
    /// restored for retry; call [`Self::wait`] to obtain the error.
    pub fn is_complete(&self) -> bool {
        match self.engine {
            None => true,
            Some(e) => e.is_covered(self.gen),
        }
    }

    /// Block until the flush epoch covering this request is durably
    /// committed; returns the flush's result. An engine that died
    /// (panicked flusher) or shut down before covering the request
    /// returns [`Error::BgSync`]. A failed flush also surfaces as
    /// [`Error::BgSync`] carrying the original error's message: the
    /// concrete variant is flattened to a string because one flush may
    /// cover many coalesced waiters and the underlying errors are not
    /// cloneable.
    pub fn wait(self) -> Result<()> {
        match self.engine {
            None => Ok(()),
            Some(e) => e.wait_for(self.gen),
        }
    }
}

/// Flusher-thread bookkeeping, all behind one mutex.
struct EngineState {
    /// Highest explicit flush generation requested.
    requested: u64,
    /// Highest generation durably covered by a finished flush.
    completed: u64,
    /// Watermark kick pending (set by writers, consumed by the flusher).
    kicked: bool,
    shutdown: bool,
    /// Panic payload of a dead flusher; sticky.
    dead: Option<String>,
    /// Failed-flush spans `(from_exclusive, to_inclusive, message)` for
    /// ticket waiters; bounded by [`MAX_ERROR_SPANS`].
    errors: VecDeque<(u64, u64, String)>,
    thread: Option<JoinHandle<()>>,
}

/// The background sync engine: one per manager, lazily started (or at
/// open when a watermark/interval is configured). See the module docs.
pub(crate) struct SyncEngine {
    /// The manager this engine flushes. `Weak` breaks the ownership
    /// cycle: the *thread* holds a strong `Arc` for its lifetime, and
    /// `shutdown_and_join` always runs before the last strong reference
    /// outside the thread drops.
    target: Mutex<Weak<ManagerCore>>,
    state: Mutex<EngineState>,
    /// Wakes the flusher (request / kick / shutdown / interval).
    work_cv: Condvar,
    /// Signalled after every finished flush (ticket waiters, stalled
    /// writers).
    done_cv: Condvar,
    /// Held for the duration of one flush. `snapshot()`/`doctor()` take
    /// it so they never observe a half-committed background epoch.
    flush_gate: Mutex<()>,
    watermark: AtomicU64,
    ceiling: AtomicU64,
    interval_ms: AtomicU64,
    /// Collapses redundant watermark kicks to one condvar signal.
    kick_pending: AtomicBool,
    /// Test hook: makes the next flush panic inside the flusher thread.
    panic_inject: AtomicBool,
    // -- cumulative counters (see BgSyncStats) --
    flushes: AtomicU64,
    flush_failures: AtomicU64,
    watermark_triggers: AtomicU64,
    ceiling_triggers: AtomicU64,
    interval_triggers: AtomicU64,
    explicit_requests: AtomicU64,
    section_bytes_flushed: AtomicU64,
    data_bytes_flushed: AtomicU64,
    writer_stalls: AtomicU64,
    writer_stall_micros: AtomicU64,
}

impl SyncEngine {
    pub(crate) fn new(watermark_bytes: u64, ceiling_bytes: u64, interval_ms: u64) -> Self {
        Self {
            target: Mutex::new(Weak::new()),
            state: Mutex::new(EngineState {
                requested: 0,
                completed: 0,
                kicked: false,
                shutdown: false,
                dead: None,
                errors: VecDeque::new(),
                thread: None,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            flush_gate: Mutex::new(()),
            watermark: AtomicU64::new(watermark_bytes),
            ceiling: AtomicU64::new(ceiling_bytes),
            interval_ms: AtomicU64::new(interval_ms),
            kick_pending: AtomicBool::new(false),
            panic_inject: AtomicBool::new(false),
            flushes: AtomicU64::new(0),
            flush_failures: AtomicU64::new(0),
            watermark_triggers: AtomicU64::new(0),
            ceiling_triggers: AtomicU64::new(0),
            interval_triggers: AtomicU64::new(0),
            explicit_requests: AtomicU64::new(0),
            section_bytes_flushed: AtomicU64::new(0),
            data_bytes_flushed: AtomicU64::new(0),
            writer_stalls: AtomicU64::new(0),
            writer_stall_micros: AtomicU64::new(0),
        }
    }

    /// Bind the engine to its manager (called once, while the manager is
    /// being wrapped in its `Arc`).
    pub(crate) fn bind(&self, target: Weak<ManagerCore>) {
        *self.target.lock().unwrap() = target;
    }

    /// Should the flusher start at open (before any explicit request)?
    /// Any configured trigger or limit needs the thread: the watermark
    /// and interval flush on their own, and a (possibly ceiling-only)
    /// backpressure stall can only drain if a flusher exists to kick.
    pub(crate) fn auto_start(&self) -> bool {
        self.watermark.load(Ordering::Relaxed) > 0
            || self.interval_ms.load(Ordering::Relaxed) > 0
            || self.ceiling.load(Ordering::Relaxed) > 0
    }

    /// The flush gate: held by the flusher across one whole flush
    /// (section writes + manifest commit). `snapshot()`/`doctor()` hold
    /// it to exclude half-committed background epochs; the inline close
    /// sync holds it for uniformity.
    pub(crate) fn gate(&self) -> MutexGuard<'_, ()> {
        // A flusher that panicked mid-flush poisons the gate; the store
        // is still recoverable (manifest protocol), so don't propagate
        // the poison to snapshot/doctor/close.
        self.flush_gate.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Spawn the flusher thread if it is not running. Idempotent.
    pub(crate) fn ensure_started(&self) -> Result<()> {
        {
            let st = self.state.lock().unwrap();
            if st.thread.is_some() {
                return Ok(());
            }
            if let Some(d) = &st.dead {
                return Err(Error::BgSync(format!("background flusher died: {d}")));
            }
            if st.shutdown {
                return Err(Error::BgSync("sync engine is shut down".into()));
            }
        }
        let weak = self.target.lock().unwrap().clone();
        let Some(mgr) = weak.upgrade() else {
            return Err(Error::BgSync("sync engine is not bound to a manager".into()));
        };
        let mut st = self.state.lock().unwrap();
        if st.thread.is_none() {
            let handle = std::thread::Builder::new()
                .name("metall-bgsync".into())
                .spawn(move || Self::run(mgr))
                .map_err(|e| Error::BgSync(format!("cannot spawn flusher thread: {e}")))?;
            st.thread = Some(handle);
        }
        Ok(())
    }

    /// Register an explicit flush request; returns its generation.
    pub(crate) fn request(&self) -> Result<u64> {
        self.ensure_started()?;
        let mut st = self.state.lock().unwrap();
        if let Some(d) = &st.dead {
            return Err(Error::BgSync(format!("background flusher died: {d}")));
        }
        if st.shutdown {
            return Err(Error::BgSync("sync engine is shut down".into()));
        }
        st.requested += 1;
        let gen = st.requested;
        self.explicit_requests.fetch_add(1, Ordering::Relaxed);
        self.work_cv.notify_one();
        Ok(gen)
    }

    /// Is `gen` covered by a *successful* flush? A failed covering flush
    /// (recorded error span) must not read as durable.
    fn is_covered(&self, gen: u64) -> bool {
        let st = self.state.lock().unwrap();
        st.completed >= gen && !st.errors.iter().any(|(from, to, _)| gen > *from && gen <= *to)
    }

    /// Block until generation `gen` is covered; return the covering
    /// flush's result.
    pub(crate) fn wait_for(&self, gen: u64) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.completed >= gen {
                for (from, to, msg) in &st.errors {
                    if gen > *from && gen <= *to {
                        return Err(Error::BgSync(msg.clone()));
                    }
                }
                return Ok(());
            }
            if let Some(d) = &st.dead {
                return Err(Error::BgSync(format!("background flusher died: {d}")));
            }
            if st.shutdown && st.thread.is_none() {
                return Err(Error::BgSync(
                    "sync engine shut down before the flush completed".into(),
                ));
            }
            st = self.done_cv.wait(st).unwrap();
        }
    }

    /// Hot-path hook, called by `mark_data_dirty` after marking: kicks
    /// the flusher when the dirty estimate crosses the watermark (or an
    /// explicitly configured ceiling — backpressure works even without a
    /// watermark trigger) and stalls the calling writer above the hard
    /// ceiling. Two relaxed atomic loads when neither is configured.
    #[inline]
    pub(crate) fn on_data_marked(&self, mgr: &ManagerCore) {
        let wm = self.watermark.load(Ordering::Relaxed);
        let ceiling = self.ceiling.load(Ordering::Relaxed);
        if wm == 0 && ceiling == 0 {
            return;
        }
        let dirty = mgr.dirty_data_bytes();
        let over_wm = wm > 0 && dirty >= wm;
        let over_ceiling = ceiling > 0 && dirty >= ceiling;
        // load-before-swap: in the steady state (kick already pending)
        // every writer takes the read-only branch, keeping the shared
        // line out of RMW ping-pong — same discipline as DirtyChunkSet
        if (over_wm || over_ceiling)
            && !self.kick_pending.load(Ordering::Relaxed)
            && !self.kick_pending.swap(true, Ordering::Relaxed)
        {
            // retry a failed open-time spawn here: watermark/interval-only
            // workloads may never call sync(), and this branch (rare —
            // kick_pending collapses it) is their only trigger edge. A
            // running engine returns immediately.
            let _ = self.ensure_started();
            let mut st = self.state.lock().unwrap();
            st.kicked = true;
            self.work_cv.notify_one();
        }
        if over_ceiling {
            self.stall_writer(mgr, ceiling);
        }
    }

    /// Backpressure: hold the writer until the flusher drains the dirty
    /// estimate below the ceiling — or stops making progress. Called
    /// with no allocator locks held. A flush that *fails* while we wait
    /// ends the stall (the dirty set was re-marked and cannot drain
    /// right now; hanging the infallible write APIs on a broken disk
    /// would be worse — the failure surfaces on the next `sync()`),
    /// so each write is stalled at most one failed-flush round-trip.
    fn stall_writer(&self, mgr: &ManagerCore, ceiling: u64) {
        let t0 = Instant::now();
        let failures0 = self.flush_failures.load(Ordering::Relaxed);
        let mut waited = false;
        let mut st = self.state.lock().unwrap();
        while st.dead.is_none()
            && !st.shutdown
            && st.thread.is_some()
            && self.flush_failures.load(Ordering::Relaxed) == failures0
            && mgr.dirty_data_bytes() >= ceiling
        {
            st.kicked = true;
            self.work_cv.notify_one();
            waited = true;
            let (guard, _) = self.done_cv.wait_timeout(st, STALL_RECHECK).unwrap();
            st = guard;
        }
        drop(st);
        if waited {
            let micros = t0.elapsed().as_micros() as u64;
            self.writer_stalls.fetch_add(1, Ordering::Relaxed);
            self.writer_stall_micros.fetch_add(micros, Ordering::Relaxed);
        }
    }

    /// Stop the flusher: signal shutdown, join the thread (it drains any
    /// outstanding requests with one final flush first), and report a
    /// dead engine as an error. Idempotent.
    pub(crate) fn shutdown_and_join(&self) -> Result<()> {
        let handle = {
            let mut st = self.state.lock().unwrap();
            st.shutdown = true;
            self.work_cv.notify_all();
            st.thread.take()
        };
        if let Some(h) = handle {
            // A panic is already captured in `dead` via catch_unwind;
            // join only fails if the unwind escaped it, which the Err
            // below reports through the same channel.
            if h.join().is_err() {
                let mut st = self.state.lock().unwrap();
                if st.dead.is_none() {
                    st.dead = Some("flusher thread aborted".into());
                }
            }
        }
        self.done_cv.notify_all();
        let st = self.state.lock().unwrap();
        match &st.dead {
            Some(d) => Err(Error::BgSync(format!("background flusher died: {d}"))),
            None => Ok(()),
        }
    }

    pub(crate) fn stats(&self) -> BgSyncStats {
        let ld = |c: &AtomicU64| c.load(Ordering::Relaxed);
        let st = self.state.lock().unwrap();
        BgSyncStats {
            flushes: ld(&self.flushes),
            flush_failures: ld(&self.flush_failures),
            watermark_triggers: ld(&self.watermark_triggers),
            ceiling_triggers: ld(&self.ceiling_triggers),
            interval_triggers: ld(&self.interval_triggers),
            explicit_requests: ld(&self.explicit_requests),
            section_bytes_flushed: ld(&self.section_bytes_flushed),
            data_bytes_flushed: ld(&self.data_bytes_flushed),
            writer_stalls: ld(&self.writer_stalls),
            writer_stall_micros: ld(&self.writer_stall_micros),
            watermark_bytes: self.watermark.load(Ordering::Relaxed),
            ceiling_bytes: self.ceiling.load(Ordering::Relaxed),
            // a dead flusher's JoinHandle lingers until shutdown takes
            // it; "running" must mean alive AND able to flush
            engine_running: st.thread.is_some() && st.dead.is_none(),
            engine_dead: st.dead.is_some(),
        }
    }

    /// Test hook: the next background flush panics inside the flusher.
    #[allow(dead_code)]
    pub(crate) fn inject_panic_for_tests(&self) {
        self.panic_inject.store(true, Ordering::Relaxed);
    }

    /// The flusher thread body. Holds a strong `Arc` for its whole life;
    /// exits on shutdown (after draining outstanding requests) or on a
    /// panic in the flush body (marking the engine dead).
    fn run(mgr: Arc<ManagerCore>) {
        let eng = mgr.engine();
        // Failed-flush retry backoff in ms (0 = none pending). The
        // watermark trigger is edge-driven by writes: without this, a
        // transient I/O failure after the last write would leave dirty
        // data volatile indefinitely on a watermark-only engine.
        let mut retry_ms: u64 = 0;
        loop {
            // Decide what to flush under the state lock.
            let covered;
            {
                let mut st = eng.state.lock().unwrap();
                loop {
                    if st.requested > st.completed {
                        covered = st.requested;
                        break;
                    }
                    if st.shutdown {
                        return; // nothing outstanding: clean exit
                    }
                    if st.kicked {
                        st.kicked = false;
                        eng.kick_pending.store(false, Ordering::Relaxed);
                        let wm = eng.watermark.load(Ordering::Relaxed);
                        let ceiling = eng.ceiling.load(Ordering::Relaxed);
                        let dirty = mgr.dirty_data_bytes();
                        // flush when either limit is crossed: a stalled
                        // writer at a ceiling-only configuration must
                        // still be drained
                        let over_wm = wm > 0 && dirty >= wm;
                        let over_ceiling = ceiling > 0 && dirty >= ceiling;
                        if over_wm || over_ceiling {
                            if over_wm {
                                eng.watermark_triggers.fetch_add(1, Ordering::Relaxed);
                            } else {
                                eng.ceiling_triggers.fetch_add(1, Ordering::Relaxed);
                            }
                            covered = st.requested; // == completed: pure bg flush
                            break;
                        }
                        continue;
                    }
                    let iv = eng.interval_ms.load(Ordering::Relaxed);
                    let wait_ms = match (iv, retry_ms) {
                        (0, 0) => 0, // no timer: wait indefinitely
                        (0, r) => r,
                        (i, 0) => i,
                        (i, r) => i.min(r),
                    };
                    if wait_ms == 0 {
                        st = eng.work_cv.wait(st).unwrap();
                    } else {
                        let (guard, timeout) = eng
                            .work_cv
                            .wait_timeout(st, Duration::from_millis(wait_ms))
                            .unwrap();
                        st = guard;
                        if timeout.timed_out() && mgr.anything_dirty() {
                            if iv > 0 && (retry_ms == 0 || iv <= retry_ms) {
                                eng.interval_triggers.fetch_add(1, Ordering::Relaxed);
                            }
                            // (a pure failed-flush retry gets no trigger
                            // attribution; `flushes` still counts it)
                            covered = st.requested;
                            break;
                        }
                    }
                }
            }
            // Run the flush outside the state lock: requests arriving
            // from here on get a generation > `covered` and trigger the
            // next round — their mutations may postdate this flush's
            // section snapshots.
            let result = catch_unwind(AssertUnwindSafe(|| {
                if eng.panic_inject.swap(false, Ordering::Relaxed) {
                    panic!("injected flusher panic (test hook)");
                }
                mgr.sync_now()
            }));
            let mut st = eng.state.lock().unwrap();
            match result {
                Ok(flush) => {
                    eng.flushes.fetch_add(1, Ordering::Relaxed);
                    // exponential retry backoff: 50ms → 5s on repeated
                    // failures, cleared by any success
                    retry_ms = match &flush {
                        Ok(()) => 0,
                        Err(_) => (retry_ms.max(25) * 2).min(5000),
                    };
                    match flush {
                        Ok(()) => {
                            // last_sync describes this flush only when it
                            // succeeded (a failed sync_now returns before
                            // rewriting it — reading it then would re-add
                            // the previous flush's bytes)
                            let s = mgr.sync_stats();
                            let sb = s.section_bytes_written;
                            eng.section_bytes_flushed.fetch_add(sb, Ordering::Relaxed);
                            eng.data_bytes_flushed
                                .fetch_add(s.data_bytes_flushed, Ordering::Relaxed);
                        }
                        Err(e) => {
                            eng.flush_failures.fetch_add(1, Ordering::Relaxed);
                            // sync_now re-marked everything it had cleared;
                            // record the span so covered tickets see the
                            // failure, then let the next flush retry.
                            if covered > st.completed {
                                let from = st.completed;
                                st.errors.push_back((from, covered, e.to_string()));
                                while st.errors.len() > MAX_ERROR_SPANS {
                                    // never evict: merge the two oldest
                                    // spans (over-approximating across the
                                    // gap — a stale ticket may see a false
                                    // *failure*, never a false durability
                                    // Ok)
                                    let (f1, _, m1) = st.errors.pop_front().unwrap();
                                    let (_, t2, _) = st.errors.pop_front().unwrap();
                                    st.errors.push_front((f1, t2, m1));
                                }
                            }
                        }
                    }
                    st.completed = st.completed.max(covered);
                }
                Err(payload) => {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "flusher panicked".into());
                    st.dead = Some(msg);
                    eng.done_cv.notify_all();
                    return;
                }
            }
            eng.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::manager::{ManagerOptions, MetallManager};
    use crate::util::tmp::TempDir;

    fn opts() -> ManagerOptions {
        ManagerOptions::small_for_tests()
    }

    /// Poll `f` for up to ~5 s; panics with `what` on timeout.
    fn wait_until(what: &str, mut f: impl FnMut() -> bool) {
        for _ in 0..500 {
            if f() {
                return;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        panic!("timed out waiting for {what}");
    }

    #[test]
    fn explicit_ticket_commits_a_durable_manifest() {
        let d = TempDir::new("bg-ticket");
        let store = d.join("s");
        let m = MetallManager::create_with(&store, opts()).unwrap();
        m.construct::<u64>("x", 7).unwrap();
        let t = m.sync_async().unwrap();
        let gen = t.generation();
        assert!(gen >= 1);
        t.wait().unwrap();
        assert!(
            !crate::alloc::mgmt_io::list_manifest_epochs(&store).unwrap().is_empty(),
            "ticket resolved only after a manifest committed"
        );
        assert_eq!(m.sync_stats().manifest_commits, 1);
        // a second ticket on an unchanged store is a no-op flush
        let t2 = m.sync_async().unwrap();
        assert!(t2.generation() > gen);
        t2.wait().unwrap();
        assert_eq!(m.sync_stats().manifest_commits, 1, "no-op flush commits nothing");
        let bg = m.bg_sync_stats();
        assert!(bg.engine_running);
        assert_eq!(bg.explicit_requests, 2);
        assert!(bg.flushes >= 2);
        m.close().unwrap();
    }

    #[test]
    fn sync_is_sync_async_plus_wait() {
        let d = TempDir::new("bg-sync-eq");
        let m = MetallManager::create_with(d.join("s"), opts()).unwrap();
        m.construct::<u64>("v", 1).unwrap();
        m.sync().unwrap();
        let st = m.sync_stats();
        assert_eq!(st.syncs, 1);
        assert_eq!(st.manifest_commits, 1);
        assert_eq!(m.bg_sync_stats().explicit_requests, 1);
        m.close().unwrap();
    }

    #[test]
    fn watermark_flushes_without_an_explicit_sync() {
        let d = TempDir::new("bg-wm");
        let mut o = opts();
        // one dirty chunk (64 KiB test geometry) crosses the watermark
        o.sync_watermark_bytes = o.chunk_size;
        let m = MetallManager::create_with(d.join("s"), o).unwrap();
        assert!(m.bg_sync_stats().engine_running, "watermark config auto-starts the engine");
        // dirty several chunks' worth of data, never calling sync()
        let off = m.allocate(4 * m.chunk_size()).unwrap();
        unsafe { m.bytes_mut(off, 4 * m.chunk_size()).fill(0xAB) };
        wait_until("watermark-driven background flush", || {
            m.sync_stats().manifest_commits >= 1
        });
        let bg = m.bg_sync_stats();
        assert!(bg.watermark_triggers >= 1, "{bg:?}");
        assert_eq!(bg.explicit_requests, 0, "no explicit sync was issued");
        m.close().unwrap();
    }

    #[test]
    fn interval_timer_flushes_dirty_state() {
        let d = TempDir::new("bg-iv");
        let mut o = opts();
        o.sync_interval_ms = 10;
        let m = MetallManager::create_with(d.join("s"), o).unwrap();
        m.construct::<u64>("tick", 1).unwrap(); // management-only dirt
        wait_until("interval-driven background flush", || {
            m.sync_stats().manifest_commits >= 1
        });
        assert!(m.bg_sync_stats().interval_triggers >= 1);
        m.close().unwrap();
    }

    #[test]
    fn ceiling_stalls_writers_and_counts_it() {
        let d = TempDir::new("bg-stall");
        let mut o = opts();
        o.sync_watermark_bytes = 1; // any dirty byte kicks the flusher
        o.sync_ceiling_bytes = 1; // …and stalls the writer until drained
        let m = MetallManager::create_with(d.join("s"), o).unwrap();
        let off = m.allocate(4 * m.chunk_size()).unwrap();
        // every write re-dirties a chunk past the ceiling: each one must
        // stall until the flusher drains (64 rounds close the tiny
        // mark-vs-flush race window deterministically)
        for i in 0..64u64 {
            m.write::<u64>(off + (i % 4) * m.chunk_size() as u64, i);
        }
        let bg = m.bg_sync_stats();
        assert!(bg.writer_stalls >= 1, "ceiling must stall at least one write: {bg:?}");
        assert!(bg.writer_stall_micros > 0);
        assert!(bg.flushes >= 1, "the stall is resolved by a real flush");
        m.close().unwrap();
    }

    #[test]
    fn flusher_panic_is_contained_and_close_refuses_clean() {
        let d = TempDir::new("bg-panic");
        let store = d.join("s");
        {
            let m = MetallManager::create_with(&store, opts()).unwrap();
            m.construct::<u64>("pre", 1).unwrap();
            m.sync().unwrap(); // engine up, epoch 1 durable
            m.engine().inject_panic_for_tests();
            let err = m.sync().expect_err("a panicking flusher must surface as an error");
            assert!(format!("{err}").contains("died"), "{err}");
            // every subsequent sync call errors too — never a silent no-op
            assert!(m.sync_async().is_err());
            // close refuses to mark the store clean over a dead flusher
            assert!(m.close().is_err());
        }
        assert!(!store.join("CLEAN").exists(), "no CLEAN marker after a dead flusher");
        // recovery falls back to the last complete manifest
        let m = MetallManager::open_unclean(&store).unwrap();
        assert_eq!(m.read::<u64>(m.find::<u64>("pre").unwrap().unwrap()), 1);
        assert!(m.doctor().unwrap().is_empty());
        m.close().unwrap();
    }

    #[test]
    fn concurrent_tickets_coalesce_into_few_flushes() {
        let d = TempDir::new("bg-coalesce");
        let m = MetallManager::create_with(d.join("s"), opts()).unwrap();
        m.construct::<u64>("base", 0).unwrap();
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let m = &m;
                s.spawn(move || {
                    for i in 0..16u64 {
                        let off = m.allocate(64).unwrap();
                        m.write::<u64>(off, t * 1000 + i);
                        m.sync().unwrap();
                    }
                });
            }
        });
        let bg = m.bg_sync_stats();
        assert_eq!(bg.explicit_requests, 64);
        assert!(bg.flushes <= bg.explicit_requests, "one flush may cover many requests: {bg:?}");
        // Forced pile-up: with the flush gate held no flush can complete,
        // so queued requests MUST coalesce — at most one in-flight flush
        // (decided before we took the gate) plus one covering the rest.
        let before = m.bg_sync_stats();
        let tickets: Vec<_> = {
            let gate = m.engine().gate();
            let t: Vec<_> = (0..10).map(|_| m.sync_async().unwrap()).collect();
            drop(gate);
            t
        };
        for t in tickets {
            t.wait().unwrap();
        }
        let after = m.bg_sync_stats();
        assert_eq!(after.explicit_requests - before.explicit_requests, 10);
        assert!(
            after.flushes - before.flushes <= 2,
            "10 gate-queued requests must coalesce into ≤ 2 flushes: {before:?} -> {after:?}"
        );
        m.close().unwrap();
    }

    #[test]
    fn private_mode_rejects_background_triggers() {
        // BsMsync's user-level msync reads + remaps pages under a
        // quiescent-writers contract; a background flush racing live
        // stores could remap stale file bytes over them. The combination
        // must be refused loudly at create *and* open.
        let d = TempDir::new("bg-private");
        for (wm, iv, ceil) in [(1usize, 0u64, 0usize), (0, 5, 0), (0, 0, 1)] {
            let mut o = opts();
            o.private_mode = true;
            o.sync_watermark_bytes = wm;
            o.sync_interval_ms = iv;
            o.sync_ceiling_bytes = ceil;
            let err = MetallManager::create_with(d.join("s"), o)
                .expect_err("private mode + background trigger must be rejected");
            assert!(format!("{err}").contains("bs-mmap"), "{err}");
        }
        // private mode without triggers still works, and a private store
        // reopened with triggers is rejected at open time too
        let mut o = opts();
        o.private_mode = true;
        let m = MetallManager::create_with(d.join("s"), o).unwrap();
        m.construct::<u64>("x", 1).unwrap();
        m.close().unwrap();
        let mut o = opts();
        o.private_mode = true;
        o.sync_watermark_bytes = 1;
        assert!(MetallManager::open_with(d.join("s"), o, false, false).is_err());
    }

    #[test]
    fn read_only_tickets_complete_immediately() {
        let d = TempDir::new("bg-ro");
        let store = d.join("s");
        {
            let m = MetallManager::create_with(&store, opts()).unwrap();
            m.construct::<u64>("x", 1).unwrap();
            m.close().unwrap();
        }
        let m = MetallManager::open_read_only(&store).unwrap();
        let t = m.sync_async().unwrap();
        assert!(t.is_complete());
        assert_eq!(t.generation(), 0);
        t.wait().unwrap();
        m.sync().unwrap();
        assert!(!m.bg_sync_stats().engine_running, "read-only stores run no flusher");
    }
}

//! `MetallManager` — the paper's `metall::manager` (§3.2, Table 2).
//!
//! Owns the application-data segment (multi-file mmap), the three DRAM
//! management directories, and the per-core object caches; provides
//! `allocate/deallocate`, the named-object API
//! (`construct/find/destroy`), snapshotting (§3.4) and snapshot-
//! consistent persistence (§3.3).
//!
//! ## Datastore layout (§3.6)
//! ```text
//! <dir>/
//!   meta.bin          immutable geometry (magic, chunk & file size)
//!   CLEAN             marker: present iff the store was closed cleanly
//!   management.bin    chunk dir + bin bitsets + name dir (written on sync)
//!   segment/chunk-NNNNNN   application data backing files
//! ```
//!
//! ## Concurrency model (§4.5.1, sharded with a lock-free fast path)
//!
//! The bin directory is split into N [`AllocShard`]s (option
//! [`ManagerOptions::shards`]): each shard holds one `RwLock<BinData>`
//! per size class over the chunks it owns, a remote-free queue, and
//! contention counters. A thread's home shard is its virtual CPU modulo
//! N ([`crate::alloc::bin_dir::ShardMap`]); the per-core object caches
//! key off the same virtual CPU, binding each cache slot to its shard.
//! The small-allocation hot path:
//!
//! 1. Per-core object cache pop (no directory locks at all).
//! 2. On a cache miss, the *shared* (read) side of the home shard's bin
//!    lock is taken and a word-level CAS claim runs against an active
//!    chunk's atomic bitset ([`crate::alloc::mlbitset::MlBitset`]). The
//!    claim grabs a batch ([`crate::alloc::object_cache::REFILL_BATCH`])
//!    in one CAS and parks the surplus in this core's cache, so same-bin
//!    allocations from different threads proceed concurrently — and
//!    threads on different shards touch disjoint locks entirely.
//! 3. Only when every active chunk of the home shard is full does a
//!    thread take the *exclusive* (write) side — the paper's
//!    serialization point #1 (registering a fresh chunk, with the chunk
//!    directory nested inside), now contended per shard rather than per
//!    manager. Serialization point #2 (releasing an emptied chunk) also
//!    runs under the owner shard's write lock, on the free/spill path.
//!
//! Frees always go through the per-core cache; spills are routed to the
//! owning shard — home-shard slots under the exclusive bin lock, foreign
//! slots onto the owner's remote-free queue (a plain mutex push; the
//! foreign shard's bin locks are never touched on the hot path). Each
//! shard drains its queue when it next reaches a serialization point,
//! and `sync`/`close` drain everything. Nesting order is always bin →
//! chunks; the chunk lock never nests inside a bin lock.
//!
//! Shard count is DRAM-only: the persistent format is identical for
//! every N, a store written with N shards reopens with M ≠ N (ownership
//! is re-dealt as `chunk % M`), and N = 1 reproduces the unsharded
//! allocator's on-disk layout bit-for-bit.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};

use crate::alloc::bin_dir::{
    serialize_merged_into, AllocShard, BinData, ShardMap, ShardStatsSnapshot,
};
use crate::alloc::object_cache::current_vcpu;
use crate::alloc::chunk_dir::{ChunkDirectory, ChunkKind};
use crate::alloc::name_dir::{type_fingerprint, NameDirectory, NamedEntry};
use crate::alloc::object_cache::{ObjectCache, REFILL_BATCH};
use crate::alloc::size_class::{
    bin_of, is_small, large_chunks, num_bins, size_of_bin, slots_per_chunk,
};
use crate::error::{Error, Result};
use crate::numa::Topology;
use crate::storage::bsmmap::BsMsync;
use crate::storage::mmap::page_size;
use crate::storage::pagemap;
use crate::storage::reflink::{self, CopyMethod};
use crate::storage::segment::{SegmentOptions, SegmentStorage};

const META_MAGIC: &[u8; 8] = b"METALLV1";
const MGMT_MAGIC: &[u8; 8] = b"METALLMG";
const CLEAN_MARKER: &str = "CLEAN";

/// Geometry and behaviour options. Geometry (chunk/file size) is fixed at
/// create time and read back from `meta.bin` on open.
#[derive(Clone, Debug)]
pub struct ManagerOptions {
    /// Chunk size (paper default 2 MiB).
    pub chunk_size: usize,
    /// Backing-file size (paper default 256 MB; our scaled default 64 MiB).
    pub file_size: usize,
    /// VM reservation (paper default "a few TB"; ours 64 GiB).
    pub vm_reserve: usize,
    /// bs-mmap mode: MAP_PRIVATE + user-level msync (§5).
    pub private_mode: bool,
    /// MAP_POPULATE on open.
    pub populate: bool,
    /// Punch file holes when freeing chunks (§6.4.2 disables on Lustre).
    pub free_file_space: bool,
    /// Parallel per-file msync on sync (§5.2).
    pub parallel_sync: bool,
    /// Allocator shard count (DRAM-only; `0` = auto: sized from the NUMA
    /// topology — [`Topology::default_shards`], which is
    /// `min(available_parallelism, 4)` rounded up to a multiple of the
    /// node count, and exactly `min(available_parallelism, 4)` on a
    /// single node). `1` reproduces the unsharded allocator's on-disk
    /// layout bit-for-bit; every count reads every other count's
    /// datastore — the persistent format does not change.
    pub shards: usize,
    /// NUMA topology override (DRAM-only, like the shard count). `None`
    /// detects the machine topology from `/sys/devices/system/node`
    /// (single-node fallback when absent); tests and benches inject fakes
    /// ([`Topology::fake`]) to exercise multi-node placement on any host.
    pub topology: Option<Topology>,
}

impl Default for ManagerOptions {
    fn default() -> Self {
        Self {
            chunk_size: 2 << 20,
            file_size: 64 << 20,
            vm_reserve: 64 << 30,
            private_mode: false,
            populate: false,
            free_file_space: true,
            parallel_sync: true,
            shards: 0,
            topology: None,
        }
    }
}

impl ManagerOptions {
    /// Small geometry for tests: 64 KiB chunks, 1 MiB files. Single shard
    /// for deterministic slot placement.
    pub fn small_for_tests() -> Self {
        Self {
            chunk_size: 64 << 10,
            file_size: 1 << 20,
            vm_reserve: 1 << 30,
            shards: 1,
            ..Self::default()
        }
    }

    fn resolved_topology(&self) -> Topology {
        self.topology.clone().unwrap_or_else(Topology::detect)
    }

    fn resolved_shards(&self, topo: &Topology) -> usize {
        if self.shards > 0 {
            return self.shards;
        }
        topo.default_shards()
    }

    fn segment_options(&self, read_only: bool) -> SegmentOptions {
        let mut o = SegmentOptions::default()
            .with_file_size(self.file_size)
            .with_vm_reserve(self.vm_reserve);
        o.populate = self.populate;
        o.free_file_space = self.free_file_space;
        if self.private_mode {
            o = o.private_mode();
        }
        if read_only {
            o = o.read_only();
        }
        o
    }
}

/// Running manager-wide counters (perf instrumentation; see
/// EXPERIMENTS.md §Perf). Small-object path counters (`fast_claims`,
/// `fresh_chunks`, small-chunk releases) live in the per-shard
/// [`crate::alloc::bin_dir::ShardStats`] and are aggregated into
/// [`StatsSnapshot`] by [`MetallManager::stats`].
#[derive(Default)]
pub struct AllocStats {
    pub allocs: AtomicU64,
    pub deallocs: AtomicU64,
    pub cache_hits: AtomicU64,
    /// Chunks freed through the *large*-object path (small-chunk releases
    /// are counted per shard).
    pub freed_large_chunks: AtomicU64,
    pub large_allocs: AtomicU64,
}

/// Snapshot of the allocator counters: manager-wide totals with the
/// per-shard counters aggregated in (same field set as before sharding —
/// consumers of the totals are unaffected by the shard count).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub allocs: u64,
    pub deallocs: u64,
    pub cache_hits: u64,
    pub fast_claims: u64,
    pub fresh_chunks: u64,
    pub freed_chunks: u64,
    pub large_allocs: u64,
}

/// Where [`PlacementReport`] got its node-per-page attribution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementSource {
    /// Kernel truth via `move_pages(2)` page queries — used only when the
    /// topology was *detected* on this machine (an injected topology
    /// describes sockets the kernel has never heard of).
    Kernel,
    /// Recorded birth nodes (the node the owning shard bound and
    /// first-touched each chunk on). Used for injected topologies and on
    /// kernels without NUMA page queries.
    Recorded,
}

/// Placement of one shard's small chunks (all figures in pages).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardPlacement {
    pub shard: usize,
    /// The shard's home memory node ([`ShardMap::node_of_shard`]).
    pub node: usize,
    /// Mapped pages of small chunks this shard owns.
    pub pages: u64,
    /// … of which reside on the shard's home node.
    pub node_local_pages: u64,
    /// … of which reside on some other node.
    pub remote_pages: u64,
    /// … of which could not be attributed (not faulted in yet, or placed
    /// before this session — recovered stores know no birth nodes).
    pub unknown_pages: u64,
}

/// Node-per-page histogram of the whole mapped segment, grouped by
/// owning shard (see [`MetallManager::placement_report`]). Total: every
/// mapped page is accounted exactly once — small-chunk pages under their
/// owner's [`ShardPlacement`], the rest under `large_pages`/`free_pages`.
#[derive(Clone, Debug)]
pub struct PlacementReport {
    pub per_shard: Vec<ShardPlacement>,
    /// Pages of large-allocation chunks (not placed per shard; the
    /// ROADMAP follow-on is an interleave policy for these).
    pub large_pages: u64,
    /// Pages of free chunks and the unused tail of the last backing file.
    pub free_pages: u64,
    /// `mapped_len / page_size` — the invariant the report is checked
    /// against.
    pub total_pages: u64,
    pub source: PlacementSource,
}

impl PlacementReport {
    /// Pages accounted by the report (must equal `total_pages`).
    pub fn accounted_pages(&self) -> u64 {
        self.large_pages
            + self.free_pages
            + self.per_shard.iter().map(|s| s.pages).sum::<u64>()
    }

    /// Fraction of attributed small-chunk pages that are node-local
    /// (`None` when nothing is attributed yet).
    pub fn node_local_fraction(&self) -> Option<f64> {
        let local: u64 = self.per_shard.iter().map(|s| s.node_local_pages).sum();
        let known: u64 = local + self.per_shard.iter().map(|s| s.remote_pages).sum::<u64>();
        (known > 0).then(|| local as f64 / known as f64)
    }
}

/// Batch error policy for the free paths: process every slot (a partial
/// failure must not leak the rest of the batch), report the first error.
fn keep_first_err(result: &mut Result<()>, r: Result<()>) {
    if result.is_ok() {
        *result = r;
    }
}

/// Marker for types that may live inside the persistent segment: plain
/// old data only — no pointers/references/niches (paper §3.5: replace raw
/// pointers with offset pointers; remove references & virtual functions).
///
/// # Safety
/// Implementors guarantee `Self` is valid for any bit pattern written by
/// a previous process (fixed layout, no padding-sensitive invariants, no
/// pointers).
pub unsafe trait Persist: Copy + 'static {}

macro_rules! persist_pod {
    ($($t:ty),*) => { $(unsafe impl Persist for $t {})* };
}
persist_pod!(u8, u16, u32, u64, i8, i16, i32, i64, f32, f64, usize, isize);
unsafe impl<T: Persist, const N: usize> Persist for [T; N] {}
unsafe impl<A: Persist, B: Persist> Persist for (A, B) {}

/// The Metall manager. `Sync`: share it behind `&` across threads.
pub struct MetallManager {
    dir: PathBuf,
    opts: ManagerOptions,
    read_only: bool,
    segment: SegmentStorage,
    /// Read-mostly: `kind`/`owner` lookups take the shared side; chunk
    /// state changes (the rare serialization points) take the exclusive
    /// side.
    chunks: RwLock<ChunkDirectory>,
    shards: Vec<AllocShard>,
    shard_map: ShardMap,
    cache: ObjectCache,
    names: Mutex<NameDirectory>,
    bs: Option<Mutex<BsMsync>>,
    stats: AllocStats,
    closed: AtomicBool,
}

impl MetallManager {
    // ------------------------------------------------------ lifecycle --

    /// Create a fresh datastore at `dir` with default options.
    pub fn create(dir: impl Into<PathBuf>) -> Result<Self> {
        Self::create_with(dir, ManagerOptions::default())
    }

    pub fn create_with(dir: impl Into<PathBuf>, opts: ManagerOptions) -> Result<Self> {
        let dir = dir.into();
        if dir.join("meta.bin").exists() {
            return Err(Error::Datastore(format!("datastore already exists at {dir:?}")));
        }
        std::fs::create_dir_all(&dir).map_err(|e| Error::io(&dir, e))?;
        if !opts.chunk_size.is_power_of_two() || opts.chunk_size < 4096 {
            return Err(Error::Config("chunk_size must be a power of two ≥ 4096".into()));
        }
        if opts.file_size % opts.chunk_size != 0 {
            return Err(Error::Config("file_size must be a multiple of chunk_size".into()));
        }
        let segment = SegmentStorage::create(dir.join("segment"), opts.segment_options(false))?;
        let nb = num_bins(opts.chunk_size);
        let topo = opts.resolved_topology();
        let nshards = opts.resolved_shards(&topo);
        let mgr = Self {
            shards: (0..nshards).map(|_| AllocShard::new(nb)).collect(),
            shard_map: ShardMap::with_topology(nshards, topo),
            cache: ObjectCache::new(nb),
            chunks: RwLock::new(ChunkDirectory::with_shards(nshards)),
            names: Mutex::new(NameDirectory::new()),
            bs: opts.private_mode.then(|| Mutex::new(BsMsync::new())),
            segment,
            read_only: false,
            stats: AllocStats::default(),
            closed: AtomicBool::new(false),
            opts,
            dir,
        };
        mgr.write_meta()?;
        // store starts dirty; becomes clean on close()
        Ok(mgr)
    }

    /// Open an existing, cleanly closed datastore read-write.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        Self::open_with(dir, ManagerOptions::default(), false, false)
    }

    /// Open read-only (paper: `metall::open_read_only` — writes to the
    /// mapping SIGSEGV; mutating APIs return errors). Multiple processes
    /// may open the same store read-only (§3.6).
    pub fn open_read_only(dir: impl Into<PathBuf>) -> Result<Self> {
        Self::open_with(dir, ManagerOptions::default(), true, false)
    }

    /// Open even if the store was not closed cleanly (the paper §3.3:
    /// after a crash the backing files may be inconsistent — the
    /// application should work on a duplicate).
    pub fn open_unclean(dir: impl Into<PathBuf>) -> Result<Self> {
        Self::open_with(dir, ManagerOptions::default(), false, true)
    }

    pub fn open_with(
        dir: impl Into<PathBuf>,
        mut opts: ManagerOptions,
        read_only: bool,
        allow_unclean: bool,
    ) -> Result<Self> {
        let dir = dir.into();
        let (chunk_size, file_size) = Self::read_meta(&dir)?;
        opts.chunk_size = chunk_size;
        opts.file_size = file_size;
        let clean = dir.join(CLEAN_MARKER).exists();
        if !clean && !allow_unclean {
            return Err(Error::Datastore(format!(
                "datastore {dir:?} was not closed cleanly; reattach a snapshot \
                 or use open_unclean() after duplicating it (paper §3.3)"
            )));
        }
        let segment = SegmentStorage::open(dir.join("segment"), opts.segment_options(read_only))?;
        let nb = num_bins(opts.chunk_size);
        let (mut chunks, bins, names) = Self::load_management(&dir, nb)?;
        // Rebuild the DRAM-only shard state: ownership is re-dealt
        // deterministically (`chunk % nshards`), so any shard count — and
        // any topology — reopens any store.
        let topo = opts.resolved_topology();
        let nshards = opts.resolved_shards(&topo);
        chunks.set_shards(nshards);
        let shard_map = ShardMap::with_topology(nshards, topo);
        let shards: Vec<AllocShard> = (0..nshards).map(|_| AllocShard::new(nb)).collect();
        for (bin, data) in bins.into_iter().enumerate() {
            for (chunk, bs) in data.into_chunks() {
                let s = shard_map.recovery_shard_of_chunk(chunk);
                shards[s].bins[bin].write().unwrap().insert_chunk(chunk, bs);
            }
        }
        let mgr = Self {
            shards,
            shard_map,
            cache: ObjectCache::new(nb),
            chunks: RwLock::new(chunks),
            names: Mutex::new(names),
            bs: (opts.private_mode && !read_only).then(|| Mutex::new(BsMsync::new())),
            segment,
            read_only,
            stats: AllocStats::default(),
            closed: AtomicBool::new(false),
            opts,
            dir,
        };
        mgr.validate_consistency()?;
        if !read_only {
            // mark dirty while we hold it read-write
            let _ = std::fs::remove_file(mgr.dir.join(CLEAN_MARKER));
        }
        Ok(mgr)
    }

    fn write_meta(&self) -> Result<()> {
        let mut buf = Vec::new();
        buf.extend_from_slice(META_MAGIC);
        buf.extend_from_slice(&(self.opts.chunk_size as u64).to_le_bytes());
        buf.extend_from_slice(&(self.opts.file_size as u64).to_le_bytes());
        let p = self.dir.join("meta.bin");
        std::fs::write(&p, &buf).map_err(|e| Error::io(&p, e))
    }

    fn read_meta(dir: &Path) -> Result<(usize, usize)> {
        let p = dir.join("meta.bin");
        let buf = std::fs::read(&p).map_err(|e| Error::io(&p, e))?;
        if buf.len() != 24 || &buf[0..8] != META_MAGIC {
            return Err(Error::Datastore(format!("bad meta.bin in {dir:?}")));
        }
        let cs = u64::from_le_bytes(buf[8..16].try_into().unwrap()) as usize;
        let fs = u64::from_le_bytes(buf[16..24].try_into().unwrap()) as usize;
        Ok((cs, fs))
    }

    /// Flush application data and management data to the backing store
    /// (the paper's snapshot-consistency point, §3.3).
    pub fn sync(&self) -> Result<()> {
        if self.read_only {
            return Ok(());
        }
        // Return cached free objects to their bitsets so the serialized
        // management data does not leak them.
        self.flush_cache()?;
        // 1. application data
        match &self.bs {
            Some(bs) => {
                bs.lock().unwrap().msync(&self.segment)?;
            }
            None => self.segment.sync(self.opts.parallel_sync)?,
        }
        // 2. management data (atomic tmp+rename). The shard count is
        // DRAM-only: each bin is written as the merged union of its
        // per-shard parts, byte-identical to an unsharded bin.
        let nb = self.num_bins();
        let mut buf = Vec::new();
        buf.extend_from_slice(MGMT_MAGIC);
        buf.extend_from_slice(&(nb as u32).to_le_bytes());
        self.chunks.read().unwrap().serialize_into(&mut buf);
        for bin in 0..nb {
            // exclusive on this bin in every shard: quiesce in-flight
            // shared-path claims (lock order shard 0..N, consistently)
            let guards: Vec<_> =
                self.shards.iter().map(|s| s.bins[bin].write().unwrap()).collect();
            let parts: Vec<&BinData> = guards.iter().map(|g| &**g).collect();
            serialize_merged_into(&parts, &mut buf);
        }
        self.names.lock().unwrap().serialize_into(&mut buf);
        let tmp = self.dir.join("management.bin.tmp");
        let fin = self.dir.join("management.bin");
        std::fs::write(&tmp, &buf).map_err(|e| Error::io(&tmp, e))?;
        std::fs::rename(&tmp, &fin).map_err(|e| Error::io(&fin, e))?;
        Ok(())
    }

    fn load_management(
        dir: &Path,
        nb: usize,
    ) -> Result<(ChunkDirectory, Vec<BinData>, NameDirectory)> {
        let p = dir.join("management.bin");
        if !p.exists() {
            // never synced: empty store
            return Ok((ChunkDirectory::new(), (0..nb).map(|_| BinData::new()).collect(), NameDirectory::new()));
        }
        let buf = std::fs::read(&p).map_err(|e| Error::io(&p, e))?;
        let bad = || Error::Datastore(format!("corrupt management.bin in {dir:?}"));
        if buf.len() < 12 || &buf[0..8] != MGMT_MAGIC {
            return Err(bad());
        }
        let file_nb = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
        if file_nb != nb {
            return Err(bad());
        }
        let mut pos = 12;
        let (chunks, used) = ChunkDirectory::deserialize_from(&buf[pos..]).ok_or_else(bad)?;
        pos += used;
        let mut bins = Vec::with_capacity(nb);
        for _ in 0..nb {
            let (b, used) = BinData::deserialize_from(&buf[pos..]).ok_or_else(bad)?;
            pos += used;
            bins.push(b);
        }
        let (names, used) = NameDirectory::deserialize_from(&buf[pos..]).ok_or_else(bad)?;
        pos += used;
        if pos != buf.len() {
            return Err(bad());
        }
        Ok((chunks, bins, names))
    }

    /// Cross-check chunk directory against the sharded bin data (run on
    /// open and by `doctor`). Works on a snapshot of the chunk directory
    /// so the chunk lock is never held while bin locks are taken (the
    /// alloc path nests bin → chunks; holding them in the opposite order
    /// here could deadlock a live store).
    fn validate_consistency(&self) -> Result<()> {
        let chunks = self.chunks.read().unwrap().clone();
        let err = |m: String| Error::Datastore(format!("inconsistent management data: {m}"));
        for (id, kind) in chunks.iter() {
            if let ChunkKind::Small { bin } = kind {
                let owner = chunks.owner(id) as usize;
                let sh = self
                    .shards
                    .get(owner)
                    .ok_or_else(|| err(format!("chunk {id} has invalid shard {owner}")))?;
                let b = sh
                    .bins
                    .get(bin as usize)
                    .ok_or_else(|| err(format!("chunk {id} has invalid bin {bin}")))?;
                if b.read().unwrap().bitset(id).is_none() {
                    return Err(err(format!(
                        "chunk {id} missing bitset in shard {owner} bin {bin}"
                    )));
                }
            }
        }
        for (s, sh) in self.shards.iter().enumerate() {
            for (bin, b) in sh.bins.iter().enumerate() {
                for cid in b.read().unwrap().chunk_ids() {
                    match chunks.kind(cid) {
                        ChunkKind::Small { bin: kb }
                            if kb as usize == bin && chunks.owner(cid) as usize == s => {}
                        k => {
                            return Err(err(format!(
                                "shard {s} bin {bin} owns chunk {cid} but chunk dir says \
                                 {k:?} owned by shard {}",
                                chunks.owner(cid)
                            )))
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Snapshot the datastore to `dst` (reflink when the filesystem
    /// supports it, §3.4). The snapshot is marked CLEAN — it is
    /// consistent by construction.
    pub fn snapshot(&self, dst: impl AsRef<Path>) -> Result<CopyMethod> {
        let dst = dst.as_ref();
        self.sync()?;
        let (_files, _bytes, method) = reflink::copy_dir(&self.dir, dst)?;
        std::fs::write(dst.join(CLEAN_MARKER), b"").map_err(|e| Error::io(dst, e))?;
        Ok(method)
    }

    /// Sync, serialize, and mark the store cleanly closed.
    pub fn close(self) -> Result<()> {
        self.close_inner()
    }

    fn close_inner(&self) -> Result<()> {
        if self.closed.swap(true, Ordering::SeqCst) || self.read_only {
            return Ok(());
        }
        self.sync()?;
        let p = self.dir.join(CLEAN_MARKER);
        std::fs::write(&p, b"").map_err(|e| Error::io(&p, e))?;
        Ok(())
    }

    // ------------------------------------------------------ accessors --

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn chunk_size(&self) -> usize {
        self.opts.chunk_size
    }

    pub fn is_read_only(&self) -> bool {
        self.read_only
    }

    pub fn segment(&self) -> &SegmentStorage {
        &self.segment
    }

    /// Manager-wide totals with the per-shard counters aggregated in (the
    /// shard count never changes the meaning of a total).
    pub fn stats(&self) -> StatsSnapshot {
        let per_shard = self.shard_stats();
        StatsSnapshot {
            allocs: self.stats.allocs.load(Ordering::Relaxed),
            deallocs: self.stats.deallocs.load(Ordering::Relaxed),
            cache_hits: self.stats.cache_hits.load(Ordering::Relaxed),
            fast_claims: per_shard.iter().map(|s| s.fast_claims).sum(),
            fresh_chunks: per_shard.iter().map(|s| s.fresh_chunks).sum(),
            freed_chunks: self.stats.freed_large_chunks.load(Ordering::Relaxed)
                + per_shard.iter().map(|s| s.freed_chunks).sum::<u64>(),
            large_allocs: self.stats.large_allocs.load(Ordering::Relaxed),
        }
    }

    /// Per-shard contention counters.
    pub fn shard_stats(&self) -> Vec<ShardStatsSnapshot> {
        self.shards.iter().enumerate().map(|(i, s)| s.stats_snapshot(i)).collect()
    }

    /// Number of allocator shards (DRAM-only; see [`ManagerOptions::shards`]).
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The NUMA topology this manager was opened under (DRAM-only; see
    /// [`ManagerOptions::topology`]).
    pub fn topology(&self) -> &Topology {
        self.shard_map.topology()
    }

    /// Node-per-page histogram of the mapped segment, grouped by owning
    /// shard. Every mapped page is accounted exactly once
    /// ([`PlacementReport::accounted_pages`] == `total_pages`): small
    /// chunks under their owner, everything else under the large/free
    /// buckets. Attribution is kernel truth (`move_pages`) when the
    /// topology was detected and the kernel answers, else the recorded
    /// birth nodes — so the ≥ 95 %-node-local acceptance check runs
    /// identically under an injected test topology on a 1-node host. On
    /// single-node topologies every attributed page is trivially local.
    pub fn placement_report(&self) -> PlacementReport {
        let ps = page_size();
        let cs = self.opts.chunk_size;
        let pages_per_chunk = (cs / ps).max(1) as u64;
        let mapped = self.segment.mapped_len();
        let topo = self.shard_map.topology();
        let rows = self.chunks.read().unwrap().placement_rows();
        let use_kernel = topo.is_detected() && pagemap::page_node_query_supported();
        let mut per_shard: Vec<ShardPlacement> = (0..self.shards.len())
            .map(|s| ShardPlacement {
                shard: s,
                node: self.shard_map.node_of_shard(s),
                ..Default::default()
            })
            .collect();
        // One bounded-window scan of the whole extent up front: the
        // syscall count stays O(pages / 4096), not O(chunks), however
        // many chunks the store holds.
        let kernel_status: Option<Vec<i32>> = if use_kernel {
            let base = self.segment.base() as usize;
            let total = mapped / ps;
            let mut all = Vec::with_capacity(total);
            while all.len() < total {
                let n = (total - all.len()).min(4096);
                match pagemap::page_nodes(base + all.len() * ps, n) {
                    Some(mut v) => all.append(&mut v),
                    None => break,
                }
            }
            (all.len() == total).then_some(all)
        } else {
            None
        };
        let mut large_pages = 0u64;
        let mut free_pages = 0u64;
        let mapped_chunks = mapped / cs;
        for chunk in 0..mapped_chunks {
            let (kind, owner, birth) = match rows.get(chunk) {
                Some(&row) => row,
                None => (ChunkKind::Free, 0, None),
            };
            match kind {
                ChunkKind::Small { .. } => {
                    let p = &mut per_shard[owner as usize];
                    p.pages += pages_per_chunk;
                    let home = p.node;
                    match &kernel_status {
                        Some(status) => {
                            // the kernel reports physical node ids
                            let home_phys = topo.physical_node(home);
                            let start = chunk * pages_per_chunk as usize;
                            for &n in &status[start..start + pages_per_chunk as usize] {
                                if n < 0 {
                                    p.unknown_pages += 1; // not faulted in
                                } else if n as usize == home_phys {
                                    p.node_local_pages += 1;
                                } else {
                                    p.remote_pages += 1;
                                }
                            }
                        }
                        None => match birth {
                            Some(n) if n as usize == home => p.node_local_pages += pages_per_chunk,
                            Some(_) => p.remote_pages += pages_per_chunk,
                            // single node: there is nowhere else to be
                            None if topo.num_nodes() <= 1 => p.node_local_pages += pages_per_chunk,
                            None => p.unknown_pages += pages_per_chunk,
                        },
                    }
                }
                ChunkKind::LargeHead { .. } | ChunkKind::LargeBody => large_pages += pages_per_chunk,
                ChunkKind::Free => free_pages += pages_per_chunk,
            }
        }
        // file-size granularity can map a partial trailing chunk
        free_pages += ((mapped - mapped_chunks * cs) / ps) as u64;
        let source = if kernel_status.is_some() {
            PlacementSource::Kernel
        } else {
            PlacementSource::Recorded
        };
        PlacementReport {
            per_shard,
            large_pages,
            free_pages,
            total_pages: (mapped / ps) as u64,
            source,
        }
    }

    fn num_bins(&self) -> usize {
        self.shards[0].bins.len()
    }

    /// Occupied chunks × chunk size (VM-level usage).
    pub fn used_segment_bytes(&self) -> usize {
        self.chunks.read().unwrap().used_chunks() * self.opts.chunk_size
    }

    // ----------------------------------------------------- allocation --

    fn check_writable(&self) -> Result<()> {
        if self.read_only {
            return Err(Error::InvalidOp("datastore is open read-only".into()));
        }
        Ok(())
    }

    /// Allocate `size` bytes; returns the segment offset.
    pub fn allocate(&self, size: usize) -> Result<u64> {
        self.check_writable()?;
        if size == 0 {
            return Err(Error::Alloc("zero-size allocation".into()));
        }
        self.stats.allocs.fetch_add(1, Ordering::Relaxed);
        let cs = self.opts.chunk_size;
        if !is_small(size, cs) {
            return self.allocate_large(size);
        }
        let bin = bin_of(size) as u32;
        // one virtual-CPU resolution drives both the cache slot and the
        // home shard (the cache-slot ↔ shard binding)
        let vcpu = current_vcpu();
        if let Some(off) = self.cache.pop_at(self.cache.slot_for(vcpu), bin) {
            self.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(off);
        }
        let shard = self.shard_map.shard_of_vcpu(vcpu);
        let sh = &self.shards[shard];
        // Fast path: shared bin lock of the home shard + lock-free CAS
        // claim in an active chunk; a word-level batch is taken and the
        // surplus refills this core's object cache, so same-bin allocators
        // never serialize while any active chunk of their shard has room.
        let claims = {
            let b = sh.bins[bin as usize].read().unwrap();
            let mut claims: Vec<(u32, u32)> = Vec::with_capacity(REFILL_BATCH);
            b.try_claim_batch(REFILL_BATCH, &mut claims);
            claims
        };
        if let Some(&(chunk, slot)) = claims.first() {
            sh.stats.fast_claims.fetch_add(claims.len() as u64, Ordering::Relaxed);
            let first = self.slot_offset(chunk, bin, slot);
            if claims.len() > 1 {
                // reversed: the cache pops LIFO, so the lowest (first-fit)
                // slot must land on top and come back out first
                let extra: Vec<u64> = claims[1..]
                    .iter()
                    .rev()
                    .map(|&(c, s)| self.slot_offset(c, bin, s))
                    .collect();
                let spill = self.cache.push_batch_at(self.cache.slot_for(vcpu), bin, &extra);
                if !spill.is_empty() {
                    // Read lock is already released — routing takes write
                    // locks. Best-effort: the allocation itself already
                    // succeeded, and a spill failure (hole-punch I/O on an
                    // emptied chunk) must not turn it into a phantom error
                    // that leaks the whole claimed batch.
                    let _ = self.route_frees(bin, &spill);
                }
            }
            return Ok(first);
        }
        // Slow path (serialization point #1, per shard): drain frees other
        // shards parked for us while we are here anyway, then exclusive
        // bin lock — heal the non-full LIFO, retry (another thread may
        // have registered a chunk while we waited), else take a fresh
        // chunk (bin → chunks lock order). Drain errors are hole-punch
        // I/O, not allocation failures.
        let _ = self.drain_remote(shard);
        sh.stats.exclusive_acquires.fetch_add(1, Ordering::Relaxed);
        let mut b = sh.bins[bin as usize].write().unwrap();
        b.prune_full();
        if let Some((chunk, slot)) = b.alloc_slot() {
            return Ok(self.slot_offset(chunk, bin, slot));
        }
        let chunk = {
            let mut chunks = self.chunks.write().unwrap();
            let chunk = chunks.take_small_chunk_on(bin, shard as u32);
            if let Err(e) = self.segment.extend_to((chunk as usize + 1) * cs) {
                chunks.free_small_chunk_on(chunk, shard as u32);
                return Err(e);
            }
            chunk
        };
        sh.stats.fresh_chunks.fetch_add(1, Ordering::Relaxed);
        self.place_fresh_chunk(chunk, shard);
        let slots = slots_per_chunk(bin as usize, cs) as u32;
        let slot = b.add_chunk_and_alloc(chunk, slots);
        Ok(self.slot_offset(chunk, bin, slot))
    }

    /// NUMA placement of a fresh small chunk (multi-node topologies only;
    /// single-node managers skip this entirely — kernel first-touch is
    /// already local there). Two layers; exactly one places each chunk:
    ///
    /// 1. `mbind(MPOL_PREFERRED | MPOL_MF_MOVE)` the chunk's extent to
    ///    the owning shard's node (its *physical* kernel id): every later
    ///    fault — whichever thread triggers it — lands there, and pages
    ///    still resident from the chunk's previous life (page-cache
    ///    survivors under `free_file_space: false`) are migrated. When
    ///    the bind takes, nothing needs touching: zeroing 2 MiB here
    ///    would only dirty every page (full-chunk write amplification on
    ///    the next sync/snapshot) to establish what the policy already
    ///    guarantees.
    /// 2. **Owner first touch**, only when `mbind` is unavailable
    ///    (non-NUMA kernel under an injected test topology, seccomp'd
    ///    container): zero the whole chunk from the allocating thread —
    ///    which is homed on the owning shard, hence on the target node —
    ///    before any slot becomes visible. Without this, the kernel
    ///    places each page on whatever socket first *writes an object*
    ///    into it, which under cross-shard frees and cache refills is
    ///    routinely the wrong one. Zero-filling is safe: the chunk holds
    ///    no live allocations, and freed chunks were hole-punched (or
    ///    contain garbage from a dead life), so no data can be clobbered.
    ///    Known limit: pages still resident from a previous life are
    ///    *written*, not migrated, by this fallback — only the `mbind`
    ///    layer (or a hole punch at free time) can re-place those.
    ///
    /// The birth node recorded for [`Self::placement_report`] is the
    /// bind target in layer 1 but the *toucher's own node* in layer 2 —
    /// so if routing ever hands a shard's fresh chunk to a thread on the
    /// wrong node, the report shows real `remote_pages` instead of
    /// echoing the expectation back. Runs under the owner's exclusive
    /// bin lock, before `add_chunk_and_alloc` publishes the chunk, so no
    /// other thread can touch these pages first (bin → chunks lock order
    /// for the record).
    fn place_fresh_chunk(&self, chunk: u32, shard: usize) {
        let topo = self.shard_map.topology();
        if topo.num_nodes() <= 1 {
            return;
        }
        let cs = self.opts.chunk_size;
        let node = self.shard_map.node_of_shard(shard);
        let sh = &self.shards[shard];
        let birth;
        if self.segment.bind_range(chunk as usize * cs, cs, topo.physical_node(node)) {
            sh.stats.bound_chunks.fetch_add(1, Ordering::Relaxed);
            birth = node;
        } else {
            unsafe { self.segment.slice_mut(chunk as usize * cs, cs).fill(0) };
            sh.stats.first_touch_chunks.fetch_add(1, Ordering::Relaxed);
            birth = topo.node_of_cpu(current_vcpu());
        }
        // Deliberately a second (brief) chunk-lock acquisition rather
        // than folding into the take/extend critical section: mbind may
        // migrate resident pages and the zero-fill writes a whole chunk —
        // neither belongs under the directory-wide write lock, and the
        // birth value depends on which layer placed the chunk.
        self.chunks.write().unwrap().set_birth_node(chunk, birth as u32);
    }

    fn allocate_large(&self, size: usize) -> Result<u64> {
        let cs = self.opts.chunk_size;
        let n = large_chunks(size, cs) as u32;
        self.stats.large_allocs.fetch_add(1, Ordering::Relaxed);
        let mut chunks = self.chunks.write().unwrap();
        let head = chunks.take_large(n);
        if let Err(e) = self.segment.extend_to((head + n) as usize * cs) {
            chunks.free_large(head);
            return Err(e);
        }
        Ok(head as u64 * cs as u64)
    }

    #[inline]
    fn slot_offset(&self, chunk: u32, bin: u32, slot: u32) -> u64 {
        chunk as u64 * self.opts.chunk_size as u64
            + slot as u64 * size_of_bin(bin as usize) as u64
    }

    /// Deallocate a previously allocated offset. Like `free(3)`, the
    /// size is derived from the allocator's own metadata.
    pub fn deallocate(&self, offset: u64) -> Result<()> {
        self.check_writable()?;
        self.stats.deallocs.fetch_add(1, Ordering::Relaxed);
        let cs = self.opts.chunk_size as u64;
        let chunk = (offset / cs) as u32;
        let kind = {
            let chunks = self.chunks.read().unwrap();
            if (chunk as usize) >= chunks.len() {
                return Err(Error::Alloc(format!("deallocate: offset {offset} out of range")));
            }
            chunks.kind(chunk)
        };
        match kind {
            ChunkKind::Small { bin } => {
                let class = size_of_bin(bin as usize) as u64;
                if (offset % cs) % class != 0 {
                    return Err(Error::Alloc(format!(
                        "deallocate: offset {offset} not on a slot boundary"
                    )));
                }
                let spill = self.cache.push(bin, offset);
                if !spill.is_empty() {
                    self.route_frees(bin, &spill)?;
                }
                Ok(())
            }
            ChunkKind::LargeHead { .. } => {
                if offset % cs != 0 {
                    return Err(Error::Alloc(format!(
                        "deallocate: large offset {offset} not chunk-aligned"
                    )));
                }
                let n = {
                    let mut chunks = self.chunks.write().unwrap();
                    chunks.free_large(chunk)
                };
                // Large deallocations free physical + file space
                // immediately (§4.1).
                self.segment
                    .free_range(chunk as usize * cs as usize, n as usize * cs as usize)?;
                self.stats.freed_large_chunks.fetch_add(n as u64, Ordering::Relaxed);
                Ok(())
            }
            ChunkKind::Free | ChunkKind::LargeBody => Err(Error::Alloc(format!(
                "deallocate: offset {offset} is not the start of a live allocation"
            ))),
        }
    }

    /// Usable bytes of the allocation starting at `offset` (its internal
    /// size class for small objects, its chunk-run footprint for large
    /// ones). Errors if `offset` is not the start of an allocation.
    pub fn usable_size(&self, offset: u64) -> Result<usize> {
        let cs = self.opts.chunk_size as u64;
        let chunk = (offset / cs) as u32;
        let (kind, owner) = {
            let chunks = self.chunks.read().unwrap();
            if (chunk as usize) >= chunks.len() {
                return Err(Error::Alloc(format!("usable_size: offset {offset} out of range")));
            }
            (chunks.kind(chunk), chunks.owner(chunk) as usize)
        };
        match kind {
            ChunkKind::Small { bin } => {
                let class = size_of_bin(bin as usize) as u64;
                if (offset % cs) % class != 0 {
                    return Err(Error::Alloc(format!(
                        "usable_size: offset {offset} not on a slot boundary"
                    )));
                }
                // the slot must be claimed in the owning shard's bitset
                // (live, parked in an object cache, or queued as a remote
                // free — all count as allocated); this rejects
                // already-freed and never-allocated slots
                let slot = ((offset % cs) / class) as u32;
                let used = self.shards[owner].bins[bin as usize]
                    .read()
                    .unwrap()
                    .is_slot_used(chunk, slot);
                if !used {
                    return Err(Error::Alloc(format!(
                        "usable_size: offset {offset} is not a live allocation"
                    )));
                }
                Ok(class as usize)
            }
            ChunkKind::LargeHead { nchunks } => {
                if offset % cs != 0 {
                    return Err(Error::Alloc(format!(
                        "usable_size: large offset {offset} not chunk-aligned"
                    )));
                }
                Ok(nchunks as usize * cs as usize)
            }
            ChunkKind::Free | ChunkKind::LargeBody => Err(Error::Alloc(format!(
                "usable_size: offset {offset} is not the start of a live allocation"
            ))),
        }
    }

    /// Resize an allocation (the `realloc(3)` analogue the persistent
    /// containers' growth paths want). Returns the — possibly moved —
    /// offset; contents up to `min(old usable, new_size)` bytes are
    /// preserved. In place whenever the internal size class (small) or
    /// chunk-run footprint (large) is unchanged.
    pub fn reallocate(&self, offset: u64, new_size: usize) -> Result<u64> {
        self.check_writable()?;
        if new_size == 0 {
            return Err(Error::Alloc("zero-size reallocation".into()));
        }
        let old_usable = self.usable_size(offset)?;
        let cs = self.opts.chunk_size;
        let in_place = if is_small(new_size, cs) {
            is_small(old_usable, cs) && size_of_bin(bin_of(new_size)) == old_usable
        } else {
            !is_small(old_usable, cs) && large_chunks(new_size, cs) * cs == old_usable
        };
        if in_place {
            return Ok(offset);
        }
        let new_off = self.allocate(new_size)?;
        let copy = old_usable.min(new_size);
        // distinct live allocations never overlap
        unsafe {
            std::ptr::copy_nonoverlapping(self.ptr(offset), self.ptr(new_off), copy);
        }
        self.deallocate(offset)?;
        Ok(new_off)
    }

    /// Route freed slots of one bin to their owning shards (cache spill
    /// path): home-shard slots are returned under the exclusive bin lock
    /// (serialization point #2), foreign slots are parked on the owner's
    /// remote-free queue — a plain mutex push, never the foreign shard's
    /// bin locks.
    fn route_frees(&self, bin: u32, offsets: &[u64]) -> Result<()> {
        if self.shards.len() == 1 {
            return self.return_slots(0, bin, offsets);
        }
        let cs = self.opts.chunk_size as u64;
        let home = self.shard_map.home_shard();
        let mut mine: Vec<u64> = Vec::new();
        let mut foreign: Vec<(usize, u64)> = Vec::new();
        {
            let chunks = self.chunks.read().unwrap();
            for &off in offsets {
                let owner = chunks.owner((off / cs) as u32) as usize;
                if owner == home {
                    mine.push(off);
                } else {
                    foreign.push((owner, off));
                }
            }
        }
        for &(owner, off) in &foreign {
            let sh = &self.shards[owner];
            sh.remote_free.lock().unwrap().push((bin, off));
            sh.stats.remote_frees.fetch_add(1, Ordering::Relaxed);
        }
        let mut result = Ok(());
        if !mine.is_empty() {
            keep_first_err(&mut result, self.return_slots(home, bin, &mine));
            // we are at our own serialization point anyway: drain what
            // other shards parked for us (no-op when the queue is empty)
            keep_first_err(&mut result, self.drain_remote(home));
        }
        result
    }

    /// Drain the cross-shard frees parked for `shard` back into its
    /// bitsets. Called by the shard itself at its serialization points
    /// and by the sync/close flush.
    fn drain_remote(&self, shard: usize) -> Result<()> {
        let sh = &self.shards[shard];
        let drained: Vec<(u32, u64)> = {
            let mut q = sh.remote_free.lock().unwrap();
            if q.is_empty() {
                return Ok(());
            }
            std::mem::take(&mut *q)
        };
        sh.stats.remote_drained.fetch_add(drained.len() as u64, Ordering::Relaxed);
        let mut by_bin: HashMap<u32, Vec<u64>> = HashMap::new();
        for (bin, off) in drained {
            by_bin.entry(bin).or_default().push(off);
        }
        let mut result = Ok(());
        for (bin, offs) in by_bin {
            keep_first_err(&mut result, self.return_slots(shard, bin, &offs));
        }
        result
    }

    /// Return freed slots of one bin — all owned by `shard` — to their
    /// bitsets (spill / remote-drain / close path). Runs under the owner
    /// shard's exclusive bin lock: chunk-empty detection and release
    /// (serialization point #2) must not race shared-path claims. Every
    /// slot is returned even if a chunk release hits hole-punch I/O
    /// errors; the first error is reported after the batch.
    fn return_slots(&self, shard: usize, bin: u32, offsets: &[u64]) -> Result<()> {
        let cs = self.opts.chunk_size as u64;
        let class = size_of_bin(bin as usize) as u64;
        let sh = &self.shards[shard];
        sh.stats.exclusive_acquires.fetch_add(1, Ordering::Relaxed);
        let mut b = sh.bins[bin as usize].write().unwrap();
        let mut result = Ok(());
        for &off in offsets {
            let chunk = (off / cs) as u32;
            let slot = ((off % cs) / class) as u32;
            let empty = b.free_slot(chunk, slot);
            if empty {
                // release the chunk entirely (bin → chunks order)
                b.remove_chunk(chunk);
                let mut chunks = self.chunks.write().unwrap();
                chunks.free_small_chunk_on(chunk, shard as u32);
                drop(chunks);
                sh.stats.freed_chunks.fetch_add(1, Ordering::Relaxed);
                keep_first_err(
                    &mut result,
                    self.segment.free_range(chunk as usize * cs as usize, cs as usize),
                );
            }
        }
        result
    }

    fn flush_cache(&self) -> Result<()> {
        let drained = self.cache.drain_all();
        // group by (owner shard, bin) to take each bin lock once
        let cs = self.opts.chunk_size as u64;
        let mut by_key: HashMap<(usize, u32), Vec<u64>> = HashMap::new();
        {
            let chunks = self.chunks.read().unwrap();
            for (bin, off) in drained {
                let owner = chunks.owner((off / cs) as u32) as usize;
                by_key.entry((owner, bin)).or_default().push(off);
            }
        }
        let mut result = Ok(());
        for ((shard, bin), offs) in by_key {
            keep_first_err(&mut result, self.return_slots(shard, bin, &offs));
        }
        for shard in 0..self.shards.len() {
            keep_first_err(&mut result, self.drain_remote(shard));
        }
        result
    }

    // -------------------------------------------------- memory access --

    /// Raw pointer to a segment offset.
    ///
    /// # Safety
    /// `offset` must be inside a live allocation large enough for the
    /// intended access, and aliasing rules are the caller's burden (the
    /// persistent containers uphold them structurally).
    pub unsafe fn ptr(&self, offset: u64) -> *mut u8 {
        debug_assert!((offset as usize) < self.segment.mapped_len());
        self.segment.base().add(offset as usize)
    }

    /// Read a POD value at `offset`.
    pub fn read<T: Persist>(&self, offset: u64) -> T {
        assert!(offset as usize + std::mem::size_of::<T>() <= self.segment.mapped_len());
        unsafe { std::ptr::read_unaligned(self.ptr(offset) as *const T) }
    }

    /// Write a POD value at `offset`.
    pub fn write<T: Persist>(&self, offset: u64, value: T) {
        assert!(!self.read_only, "write on read-only datastore");
        assert!(offset as usize + std::mem::size_of::<T>() <= self.segment.mapped_len());
        unsafe { std::ptr::write_unaligned(self.ptr(offset) as *mut T, value) }
    }

    /// Byte-slice view of an allocation.
    ///
    /// # Safety
    /// Same contract as [`Self::ptr`] plus no concurrent writer.
    pub unsafe fn bytes(&self, offset: u64, len: usize) -> &[u8] {
        self.segment.slice(offset as usize, len)
    }

    /// # Safety
    /// Same as [`Self::bytes`] plus exclusivity.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn bytes_mut(&self, offset: u64, len: usize) -> &mut [u8] {
        self.segment.slice_mut(offset as usize, len)
    }

    // ---------------------------------------------------- named (§3.2) --

    /// Allocate, zero, and register `sizeof(T)` bytes under `name`
    /// (Table 2: `construct<T>(name)`), returning the offset. Fails if
    /// the name exists.
    pub fn construct<T: Persist>(&self, name: &str, value: T) -> Result<u64> {
        self.check_writable()?;
        if std::mem::align_of::<T>() > 8 {
            return Err(Error::Alloc(format!(
                "construct: alignment {} > 8 unsupported",
                std::mem::align_of::<T>()
            )));
        }
        let size = std::mem::size_of::<T>().max(1);
        let offset = self.allocate(size)?;
        unsafe {
            self.bytes_mut(offset, size).fill(0);
        }
        self.write(offset, value);
        let entry = NamedEntry {
            offset,
            size: size as u64,
            type_fp: type_fingerprint::<T>(),
        };
        let inserted = self.names.lock().unwrap().insert(name, entry);
        if !inserted {
            self.deallocate(offset)?;
            return Err(Error::Name(format!("name {name:?} already exists")));
        }
        Ok(offset)
    }

    /// Find a previously constructed object (Table 2: `find<T>(name)`).
    pub fn find<T: Persist>(&self, name: &str) -> Result<Option<u64>> {
        let names = self.names.lock().unwrap();
        match names.get(name) {
            None => Ok(None),
            Some(e) => {
                if e.type_fp != type_fingerprint::<T>() {
                    return Err(Error::Name(format!(
                        "find: type mismatch for {name:?} (stored fingerprint differs)"
                    )));
                }
                Ok(Some(e.offset))
            }
        }
    }

    /// Destroy a named object (Table 2: `destroy(name)`): deallocates and
    /// unregisters. Returns false if the name does not exist.
    pub fn destroy(&self, name: &str) -> Result<bool> {
        self.check_writable()?;
        let entry = self.names.lock().unwrap().remove(name);
        match entry {
            None => Ok(false),
            Some(e) => {
                self.deallocate(e.offset)?;
                Ok(true)
            }
        }
    }

    /// Number of named objects.
    pub fn num_named(&self) -> usize {
        self.names.lock().unwrap().len()
    }

    /// List named objects (for the `inspect` CLI).
    pub fn named_list(&self) -> Vec<(String, u64, u64)> {
        self.names
            .lock()
            .unwrap()
            .iter()
            .map(|(n, e)| (n.to_string(), e.offset, e.size))
            .collect()
    }

    /// Datastore health check (`metall doctor`): re-runs the management
    /// consistency validation and audits every named object. Returns a
    /// list of findings (empty = healthy). This is the "program that
    /// assesses compatibility / integrity" the paper's §3.5 sketches as
    /// future work.
    pub fn doctor(&self) -> Result<Vec<String>> {
        let mut findings = Vec::new();
        if let Err(e) = self.validate_consistency() {
            findings.push(format!("management data: {e}"));
        }
        let mapped = self.segment.mapped_len() as u64;
        let cs = self.opts.chunk_size as u64;
        let chunks = self.chunks.read().unwrap();
        for (name, e) in self.names.lock().unwrap().iter() {
            if e.offset + e.size > mapped {
                findings.push(format!(
                    "named object {name:?} [{}..{}] exceeds mapped segment ({mapped})",
                    e.offset,
                    e.offset + e.size
                ));
                continue;
            }
            // the owning chunk must be live
            let chunk = (e.offset / cs) as u32;
            match chunks.kind(chunk) {
                ChunkKind::Free => findings.push(format!(
                    "named object {name:?} points into a FREE chunk {chunk}"
                )),
                ChunkKind::LargeBody => findings.push(format!(
                    "named object {name:?} points into a large-body chunk {chunk}"
                )),
                ChunkKind::Small { bin } => {
                    let class = size_of_bin(bin as usize) as u64;
                    if e.size > class {
                        findings.push(format!(
                            "named object {name:?} ({}B) larger than its slot class ({class}B)",
                            e.size
                        ));
                    }
                }
                ChunkKind::LargeHead { .. } => {}
            }
        }
        // chunk accounting must be structurally valid
        if !chunks.validate() {
            findings.push("chunk directory structure invalid".into());
        }
        Ok(findings)
    }

    /// Explicit user-level msync statistics (bs-mmap mode only).
    pub fn bs_msync(&self) -> Result<crate::storage::bsmmap::FlushStats> {
        match &self.bs {
            Some(bs) => bs.lock().unwrap().msync(&self.segment),
            None => Err(Error::InvalidOp("not in bs-mmap (private) mode".into())),
        }
    }
}

impl Drop for MetallManager {
    fn drop(&mut self) {
        // Best-effort clean close (explicit close() is preferred and
        // reports errors).
        let _ = self.close_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn mk(dir: &Path) -> MetallManager {
        MetallManager::create_with(dir, ManagerOptions::small_for_tests()).unwrap()
    }

    #[test]
    fn allocate_roundtrip_and_reattach() {
        let d = TempDir::new("mgr1");
        let store = d.join("store");
        let off;
        {
            let m = mk(&store);
            off = m.allocate(16).unwrap();
            m.write::<u64>(off, 0xDEADBEEF);
            m.write::<u64>(off + 8, 42);
            m.close().unwrap();
        }
        {
            let m = MetallManager::open(&store).unwrap();
            assert_eq!(m.read::<u64>(off), 0xDEADBEEF);
            assert_eq!(m.read::<u64>(off + 8), 42);
            m.close().unwrap();
        }
    }

    #[test]
    fn small_allocations_share_chunk_and_classes_separate() {
        let d = TempDir::new("mgr2");
        let m = mk(&d.join("s"));
        let a = m.allocate(8).unwrap();
        let b = m.allocate(8).unwrap();
        let c = m.allocate(16).unwrap();
        // same class → same chunk, adjacent slots
        assert_eq!(b - a, 8);
        // different class → different chunk
        assert_ne!(c / 65536, a / 65536);
    }

    #[test]
    fn cache_hit_on_realloc() {
        let d = TempDir::new("mgr3");
        let m = mk(&d.join("s"));
        let a = m.allocate(64).unwrap();
        m.deallocate(a).unwrap();
        let b = m.allocate(64).unwrap();
        assert_eq!(a, b, "object cache must return the freed slot (LIFO)");
        assert_eq!(m.stats().cache_hits, 1);
    }

    #[test]
    fn large_allocation_and_free_releases_file_space() {
        let d = TempDir::new("mgr4");
        let m = mk(&d.join("s"));
        let cs = m.chunk_size();
        let off = m.allocate(3 * cs).unwrap(); // rounds to 4 chunks
        assert_eq!(off % cs as u64, 0);
        unsafe { m.bytes_mut(off, 3 * cs).fill(0xAB) };
        m.sync().unwrap();
        let before = m.segment().allocated_file_blocks().unwrap();
        m.deallocate(off).unwrap();
        let after = m.segment().allocated_file_blocks().unwrap();
        assert!(after < before, "{before} -> {after}");
        // next large alloc reuses the hole
        let off2 = m.allocate(2 * cs).unwrap();
        assert_eq!(off2, off);
    }

    #[test]
    fn named_construct_find_destroy() {
        let d = TempDir::new("mgr5");
        let store = d.join("s");
        {
            let m = mk(&store);
            let off = m.construct::<u64>("answer", 42).unwrap();
            assert_eq!(m.read::<u64>(off), 42);
            assert!(m.construct::<u64>("answer", 43).is_err(), "duplicate name");
            m.close().unwrap();
        }
        {
            let m = MetallManager::open(&store).unwrap();
            let off = m.find::<u64>("answer").unwrap().expect("must exist");
            assert_eq!(m.read::<u64>(off), 42);
            // wrong type is rejected
            assert!(m.find::<u32>("answer").is_err());
            assert!(m.destroy("answer").unwrap());
            assert!(!m.destroy("answer").unwrap());
            assert_eq!(m.find::<u64>("answer").unwrap(), None);
            m.close().unwrap();
        }
    }

    #[test]
    fn read_only_mode_blocks_mutation() {
        let d = TempDir::new("mgr6");
        let store = d.join("s");
        {
            let m = mk(&store);
            m.construct::<u64>("x", 7).unwrap();
            m.close().unwrap();
        }
        let m = MetallManager::open_read_only(&store).unwrap();
        let off = m.find::<u64>("x").unwrap().unwrap();
        assert_eq!(m.read::<u64>(off), 7);
        assert!(m.allocate(8).is_err());
        assert!(m.destroy("x").is_err());
        assert!(m.construct::<u64>("y", 1).is_err());
        // two read-only opens may coexist (§3.6)
        let m2 = MetallManager::open_read_only(&store).unwrap();
        assert_eq!(m2.read::<u64>(off), 7);
    }

    #[test]
    fn unclean_store_is_refused() {
        let d = TempDir::new("mgr7");
        let store = d.join("s");
        {
            let m = mk(&store);
            m.allocate(8).unwrap();
            m.sync().unwrap();
            // simulate crash: forget without close
            std::mem::forget(m);
        }
        assert!(MetallManager::open(&store).is_err(), "dirty store must be refused");
        let m = MetallManager::open_unclean(&store).unwrap();
        m.close().unwrap();
        // now clean again
        MetallManager::open(&store).unwrap().close().unwrap();
    }

    #[test]
    fn snapshot_is_clean_and_independent() {
        let d = TempDir::new("mgr8");
        let store = d.join("s");
        let snap = d.join("snap");
        let m = mk(&store);
        let off = m.construct::<u64>("v", 1).unwrap();
        m.snapshot(&snap).unwrap();
        // mutate original after snapshot
        m.write::<u64>(off, 2);
        m.sync().unwrap();
        // snapshot opens clean and sees the old value
        let s = MetallManager::open(&snap).unwrap();
        let soff = s.find::<u64>("v").unwrap().unwrap();
        assert_eq!(s.read::<u64>(soff), 1);
        s.close().unwrap();
        assert_eq!(m.read::<u64>(off), 2);
    }

    #[test]
    fn multithreaded_alloc_dealloc_stress() {
        let d = TempDir::new("mgr9");
        let m = mk(&d.join("s"));
        let nthreads = 8;
        let per = 500;
        std::thread::scope(|s| {
            for t in 0..nthreads {
                let m = &m;
                s.spawn(move || {
                    let mut offs = Vec::new();
                    for i in 0..per {
                        let size = 8 + ((t * 13 + i * 7) % 500);
                        let off = m.allocate(size).unwrap();
                        // write a tag, verify later
                        m.write::<u64>(off, (t * per + i) as u64);
                        offs.push((off, (t * per + i) as u64, size));
                    }
                    // verify all, free half
                    for (j, &(off, tag, _)) in offs.iter().enumerate() {
                        assert_eq!(m.read::<u64>(off), tag, "thread {t} obj {j}");
                    }
                    for &(off, _, _) in offs.iter().step_by(2) {
                        m.deallocate(off).unwrap();
                    }
                });
            }
        });
        let st = m.stats();
        assert_eq!(st.allocs, (nthreads * per) as u64);
        assert_eq!(st.deallocs, (nthreads * per / 2) as u64);
        m.close().unwrap();
    }

    #[test]
    fn no_overlap_under_concurrency() {
        use std::collections::HashSet;
        let d = TempDir::new("mgr10");
        let m = mk(&d.join("s"));
        let results: Vec<Vec<(u64, usize)>> = std::thread::scope(|s| {
            (0..4)
                .map(|_t| {
                    let m = &m;
                    s.spawn(move || {
                        (0..300)
                            .map(|i| {
                                let size = 8 << (i % 4); // 8,16,32,64
                                (m.allocate(size).unwrap(), size)
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        let mut seen = HashSet::new();
        for (off, size) in results.into_iter().flatten() {
            // class-rounded extent must not overlap any other allocation
            let class = size_of_bin(bin_of(size));
            for b in (off..off + class as u64).step_by(8) {
                assert!(seen.insert(b), "overlap at {b}");
            }
        }
        m.close().unwrap();
    }

    #[test]
    fn empty_chunk_is_released() {
        let d = TempDir::new("mgr11");
        let m = mk(&d.join("s"));
        // fill exactly one chunk of 32 KiB-class objects (64 KiB chunk → 2 slots)
        let a = m.allocate(32 << 10).unwrap();
        let b = m.allocate(32 << 10).unwrap();
        m.deallocate(a).unwrap();
        m.deallocate(b).unwrap();
        // force the cache out
        m.sync().unwrap();
        assert!(m.stats().freed_chunks >= 1);
        assert_eq!(m.used_segment_bytes(), 0);
        m.close().unwrap();
    }

    #[test]
    fn bad_deallocates_are_rejected() {
        let d = TempDir::new("mgr12");
        let m = mk(&d.join("s"));
        let off = m.allocate(8).unwrap();
        assert!(m.deallocate(off + 4).is_err(), "mid-slot offset");
        assert!(m.deallocate(10 << 20).is_err(), "out of range");
        m.deallocate(off).unwrap();
        m.close().unwrap();
    }

    #[test]
    fn zero_size_alloc_rejected() {
        let d = TempDir::new("mgr13");
        let m = mk(&d.join("s"));
        assert!(m.allocate(0).is_err());
    }

    #[test]
    fn fast_path_claims_batch_and_refills_cache() {
        let d = TempDir::new("mgr16");
        let m = mk(&d.join("s"));
        let a = m.allocate(64).unwrap(); // fresh chunk via slow path
        let b = m.allocate(64).unwrap(); // lock-free claim + batch refill
        assert_eq!(b - a, 64, "adjacent slot from the same chunk");
        let st = m.stats();
        assert!(st.fast_claims >= 2, "batch claim recorded: {}", st.fast_claims);
        // the parked surplus now serves allocations as pure cache hits
        let c = m.allocate(64).unwrap();
        assert_eq!(c - b, 64);
        assert!(m.stats().cache_hits >= 1);
        m.close().unwrap();
    }

    #[test]
    fn reallocate_in_place_and_moving() {
        let d = TempDir::new("mgr17");
        let m = mk(&d.join("s"));
        let off = m.allocate(50).unwrap(); // class 56
        m.write::<u64>(off, 0xAA55);
        // still inside the same class → in place
        let same = m.reallocate(off, 56).unwrap();
        assert_eq!(same, off);
        // grow to another class → moves, contents preserved
        let moved = m.reallocate(off, 500).unwrap();
        assert_ne!(moved, off);
        assert_eq!(m.read::<u64>(moved), 0xAA55);
        // grow to a large allocation → moves again, contents preserved
        let cs = m.chunk_size();
        let large = m.reallocate(moved, cs).unwrap();
        assert_eq!(m.read::<u64>(large), 0xAA55);
        assert_eq!(m.usable_size(large).unwrap() % cs, 0);
        // shrink back to small
        let small = m.reallocate(large, 8).unwrap();
        assert_eq!(m.read::<u64>(small), 0xAA55);
        m.deallocate(small).unwrap();
        assert!(m.reallocate(1 << 40, 8).is_err(), "bogus offset rejected");
        m.close().unwrap();
    }

    #[test]
    fn doctor_reports_healthy_after_churn() {
        let d = TempDir::new("mgr15");
        let m = mk(&d.join("s"));
        for i in 0..100u64 {
            m.construct::<u64>(&format!("k{i}"), i).unwrap();
        }
        for i in (0..100u64).step_by(2) {
            m.destroy(&format!("k{i}")).unwrap();
        }
        let big = m.allocate(200 << 10).unwrap();
        m.deallocate(big).unwrap();
        assert!(m.doctor().unwrap().is_empty(), "healthy store, no findings");
        m.close().unwrap();
    }

    #[test]
    fn shard1_layout_is_deterministic() {
        use crate::alloc::object_cache::pin_thread_vcpu;
        // Two identical traces at shards=1 must produce byte-identical
        // stores — the shard=1 equivalence guarantee (every sharded path
        // collapses to the unsharded one: pools bypassed, remote queues
        // empty, merged serialization of one part is the identity).
        let d = TempDir::new("mgr-shard-det");
        let run = |store: &Path| {
            pin_thread_vcpu(Some(0));
            let m = mk(store);
            let mut offs = Vec::new();
            for i in 0..600usize {
                let off = m.allocate(8 + (i * 37) % 2000).unwrap();
                m.write::<u64>(off, i as u64);
                offs.push(off);
                if i % 3 == 0 {
                    let victim = offs.remove((i * 7) % offs.len());
                    m.deallocate(victim).unwrap();
                }
            }
            let big = m.allocate(100 << 10).unwrap(); // large (> chunk/2)
            m.deallocate(big).unwrap();
            m.close().unwrap();
            pin_thread_vcpu(None);
        };
        run(&d.join("a"));
        run(&d.join("b"));
        let mgmt_a = std::fs::read(d.join("a").join("management.bin")).unwrap();
        let mgmt_b = std::fs::read(d.join("b").join("management.bin")).unwrap();
        assert_eq!(mgmt_a, mgmt_b, "management data bit-identical");
        let files = |p: &Path| {
            let mut v: Vec<_> = std::fs::read_dir(p.join("segment"))
                .unwrap()
                .map(|e| e.unwrap().path())
                .collect();
            v.sort();
            v
        };
        let (fa, fb) = (files(&d.join("a")), files(&d.join("b")));
        assert_eq!(fa.len(), fb.len(), "same backing files");
        for (a, b) in fa.iter().zip(&fb) {
            assert_eq!(a.file_name(), b.file_name());
            assert_eq!(
                std::fs::read(a).unwrap(),
                std::fs::read(b).unwrap(),
                "segment file {a:?} bit-identical"
            );
        }
    }

    #[test]
    fn cross_shard_free_routes_through_remote_queue() {
        use crate::alloc::object_cache::{pin_thread_vcpu, PER_BIN_CAP};
        let d = TempDir::new("mgr-xshard");
        let store = d.join("s");
        let mut o = ManagerOptions::small_for_tests();
        o.shards = 2;
        // explicit single-node topology: vcpu → shard stays the plain
        // modulo wherever this test runs (a detected multi-node topology
        // would route both pinned vcpus by node instead)
        o.topology = Some(Topology::fake(&[2]));
        let m = MetallManager::create_with(&store, o).unwrap();
        // allocate on shard 0…
        pin_thread_vcpu(Some(0));
        let n = 2 * PER_BIN_CAP;
        let offs: Vec<u64> = (0..n).map(|_| m.allocate(64).unwrap()).collect();
        pin_thread_vcpu(None);
        // …free everything from a thread homed on shard 1: spills must be
        // parked on shard 0's remote queue, never shard 0's bin locks
        std::thread::scope(|s| {
            let (m, offs) = (&m, &offs);
            s.spawn(move || {
                pin_thread_vcpu(Some(1));
                for &off in offs {
                    m.deallocate(off).unwrap();
                }
            });
        });
        let ss = m.shard_stats();
        assert!(ss[0].remote_frees > 0, "cross-shard frees queued: {ss:?}");
        // sync drains caches and remote queues: nothing may leak
        m.sync().unwrap();
        assert_eq!(m.used_segment_bytes(), 0, "no leaked slots");
        let agg = m.stats();
        assert_eq!(agg.allocs, n as u64);
        assert_eq!(agg.deallocs, n as u64);
        assert_eq!(
            agg.fast_claims,
            ss.iter().map(|s| s.fast_claims).sum::<u64>(),
            "totals aggregate the per-shard counters"
        );
        assert!(m.doctor().unwrap().is_empty());
        m.close().unwrap();
        let m = MetallManager::open(&store).unwrap();
        assert_eq!(m.used_segment_bytes(), 0);
        m.close().unwrap();
    }

    #[test]
    fn reopen_with_different_shard_count() {
        use crate::alloc::object_cache::pin_thread_vcpu;
        let d = TempDir::new("mgr-reshard");
        let store = d.join("s");
        let mut live: Vec<(u64, u64)> = Vec::new();
        {
            let mut o = ManagerOptions::small_for_tests();
            o.shards = 4;
            let m = MetallManager::create_with(&store, o).unwrap();
            assert_eq!(m.num_shards(), 4);
            for i in 0..400u64 {
                // rotate home shards so chunks of every bin spread over
                // all four shards and frees cross shards
                pin_thread_vcpu(Some((i % 4) as usize));
                let off = m.allocate(16 + (i as usize % 700)).unwrap();
                m.write::<u64>(off, i);
                live.push((off, i));
                if i % 4 == 3 {
                    let (voff, _) = live.remove((i as usize * 13) % live.len());
                    m.deallocate(voff).unwrap();
                }
            }
            pin_thread_vcpu(None);
            m.close().unwrap();
        }
        let golden = std::fs::read(store.join("management.bin")).unwrap();
        // a store written with 4 shards reopens and validates with any
        // shard count; closing again rewrites identical management bytes
        for reopen_shards in [1usize, 2, 4, 3] {
            let mut o = ManagerOptions::small_for_tests();
            o.shards = reopen_shards;
            let m = MetallManager::open_with(&store, o, false, false)
                .unwrap_or_else(|e| panic!("reopen with {reopen_shards} shards: {e}"));
            assert_eq!(m.num_shards(), reopen_shards);
            for &(off, tag) in &live {
                assert_eq!(m.read::<u64>(off), tag, "shards={reopen_shards} offset {off}");
                assert!(m.usable_size(off).unwrap() >= 8);
            }
            assert!(m.doctor().unwrap().is_empty());
            m.close().unwrap();
            assert_eq!(
                std::fs::read(store.join("management.bin")).unwrap(),
                golden,
                "shards={reopen_shards}: persistent image unchanged by reopen"
            );
        }
        // everything frees cleanly under yet another shard count
        let mut o = ManagerOptions::small_for_tests();
        o.shards = 2;
        let m = MetallManager::open_with(&store, o, false, false).unwrap();
        pin_thread_vcpu(Some(1));
        for &(off, _) in &live {
            m.deallocate(off).unwrap();
        }
        pin_thread_vcpu(None);
        m.sync().unwrap();
        assert_eq!(m.used_segment_bytes(), 0, "no leaked slots after reshard churn");
        m.close().unwrap();
    }

    #[test]
    fn topology_sizes_default_shard_count() {
        let d = TempDir::new("mgr-topo-size");
        // 2 nodes × 4 cpus → 4 shards (min(8, 4), already a multiple of 2)
        let mut o = ManagerOptions::small_for_tests();
        o.shards = 0;
        o.topology = Some(Topology::fake(&[4, 4]));
        let m = MetallManager::create_with(d.join("a"), o).unwrap();
        assert_eq!(m.num_shards(), 4);
        assert_eq!(m.topology().num_nodes(), 2);
        m.close().unwrap();
        // 3 nodes × 1 cpu → 3 shards, one per node
        let mut o = ManagerOptions::small_for_tests();
        o.shards = 0;
        o.topology = Some(Topology::fake(&[1, 1, 1]));
        let m = MetallManager::create_with(d.join("b"), o).unwrap();
        assert_eq!(m.num_shards(), 3);
        m.close().unwrap();
        // an explicit shard count always wins over the topology
        let mut o = ManagerOptions::small_for_tests();
        o.shards = 2;
        o.topology = Some(Topology::fake(&[4, 4]));
        let m = MetallManager::create_with(d.join("c"), o).unwrap();
        assert_eq!(m.num_shards(), 2);
        m.close().unwrap();
    }

    #[test]
    fn fake_two_node_fresh_chunks_first_touched_by_owner() {
        use crate::alloc::object_cache::pin_thread_vcpu;
        let d = TempDir::new("mgr-numa-ft");
        let mut o = ManagerOptions::small_for_tests();
        o.shards = 4;
        o.topology = Some(Topology::fake(&[4, 4])); // satellite shape
        let m = MetallManager::create_with(d.join("s"), o).unwrap();
        // vcpu 0 is node 0 → shard 0; vcpu 4 is node 1 → shard 1
        pin_thread_vcpu(Some(0));
        let a = m.allocate(64).unwrap();
        pin_thread_vcpu(Some(4));
        let b = m.allocate(64).unwrap();
        // the foreign-node thread writing into shard 0's chunk must not
        // steal its placement: the owner already first-touched every page
        m.write::<u64>(a, 0xF00D);
        pin_thread_vcpu(None);
        let ss = m.shard_stats();
        assert!(ss[0].fresh_chunks >= 1 && ss[1].fresh_chunks >= 1, "{ss:?}");
        // every fresh chunk was placed by exactly one layer: mbind when
        // the kernel has it, else owner zeroing — never left to whatever
        // foreign thread faults it first
        for s in &ss {
            assert_eq!(
                s.bound_chunks + s.first_touch_chunks,
                s.fresh_chunks,
                "shard {}: every fresh chunk bound or owner-touched",
                s.shard
            );
        }
        let r = m.placement_report();
        assert_eq!(r.source, PlacementSource::Recorded, "injected topology");
        assert_eq!(r.accounted_pages(), r.total_pages, "report is total");
        for s in &r.per_shard {
            assert_eq!(s.remote_pages, 0, "shard {}: all chunks born local", s.shard);
            assert_eq!(s.unknown_pages, 0, "shard {}: all chunks attributed", s.shard);
        }
        let frac = r.node_local_fraction().expect("live chunks attributed");
        assert!(frac >= 0.95, "≥95% node-local, got {frac}");
        // shard homes alternate nodes (round-robin deal)
        assert_eq!(
            r.per_shard.iter().map(|s| s.node).collect::<Vec<_>>(),
            vec![0, 1, 0, 1]
        );
        assert_eq!(m.read::<u64>(a), 0xF00D);
        let _ = b;
        m.close().unwrap();
    }

    #[test]
    fn single_node_skips_first_touch_and_reports_local() {
        let d = TempDir::new("mgr-numa-1n");
        let mut o = ManagerOptions::small_for_tests();
        o.topology = Some(Topology::fake(&[2]));
        let m = MetallManager::create_with(d.join("s"), o).unwrap();
        let off = m.allocate(64).unwrap();
        let big = m.allocate(3 * m.chunk_size()).unwrap();
        let ss = m.shard_stats();
        assert_eq!(ss[0].first_touch_chunks, 0, "single node: no zeroing pass");
        assert_eq!(ss[0].bound_chunks, 0, "single node: no binding either");
        let r = m.placement_report();
        assert_eq!(r.accounted_pages(), r.total_pages);
        assert!(r.large_pages > 0 && r.per_shard[0].pages > 0);
        assert_eq!(r.per_shard[0].node, 0);
        assert_eq!(r.per_shard[0].pages, r.per_shard[0].node_local_pages);
        assert_eq!(r.node_local_fraction(), Some(1.0));
        m.deallocate(big).unwrap();
        m.deallocate(off).unwrap();
        m.close().unwrap();
    }

    #[test]
    fn private_mode_persists_via_user_msync() {
        let d = TempDir::new("mgr14");
        let store = d.join("s");
        {
            let mut o = ManagerOptions::small_for_tests();
            o.private_mode = true;
            let m = MetallManager::create_with(&store, o).unwrap();
            let off = m.construct::<u64>("bs", 99).unwrap();
            let st = m.bs_msync().unwrap();
            assert!(st.dirty_pages > 0);
            let _ = off;
            m.close().unwrap();
        }
        let m = MetallManager::open(&store).unwrap();
        let off = m.find::<u64>("bs").unwrap().unwrap();
        assert_eq!(m.read::<u64>(off), 99);
        m.close().unwrap();
    }
}

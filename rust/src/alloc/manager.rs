//! `MetallManager` — the paper's `metall::manager` (§3.2, Table 2).
//!
//! Owns the application-data segment (multi-file mmap), the three DRAM
//! management directories, and the per-core object caches; provides
//! `allocate/deallocate`, the named-object API
//! (`construct/find/destroy`), snapshotting (§3.4) and snapshot-
//! consistent persistence (§3.3).
//!
//! ## Datastore layout (§3.6, segmented management format)
//! ```text
//! <dir>/
//!   meta.bin                immutable geometry (magic, chunk & file size)
//!   CLEAN                   marker: present iff the store closed cleanly
//!   manifest-<epoch>.bin    checksummed section index, the sync commit
//!                           point (fsync'd atomic rename)
//!   mgmt-chunks-<e>.bin     chunk directory          ┐ per-section files;
//!   mgmt-bins<g>-<e>.bin    bin bitsets, 8-bin groups│ only *dirty*
//!   mgmt-names-<e>.bin      name directory           │ sections are
//!   mgmt-cache-<e>.bin      parked-free slot snapshot┘ rewritten per sync
//!   segment/chunk-NNNNNN    application data backing files
//! ```
//! (Legacy stores with a monolithic `management.bin` are still read; the
//! first segmented sync supersedes and removes it. See
//! [`crate::alloc::mgmt_io`] for the format and its crash invariants.)
//!
//! ## Incremental sync (persist-path scaling)
//!
//! [`ManagerCore::sync`] is proportional to what changed, not to the
//! store: DRAM-only dirty-epoch marks (per-shard per-bin flags, chunk- /
//! name-directory marks, a chunk-granular map of data writes) tell it
//! exactly which management sections to re-serialize and which chunk
//! ranges of the mapped extent to `msync`; dirty sections are written by
//! a flusher pool and committed atomically by the manifest rename. The
//! per-core object caches are *preserved* across a sync — the cached
//! free slots are serialized into the transient cache section instead of
//! being drained, so a sync costs no cache warmth; recovery returns
//! those slots to the bitsets. A sync with no changes writes zero bytes.
//!
//! ## Background sync (off the mutation path)
//!
//! Every read-write manager owns a [`crate::alloc::bg_sync::SyncEngine`]:
//! a dedicated flusher thread that runs the incremental sync above off
//! the allocation path. `sync()` is now `sync_async()` + ticket wait
//! (unchanged durability semantics: it returns after the covering
//! manifest is durably committed); a configurable dirty-byte watermark
//! ([`ManagerOptions::sync_watermark_bytes`]) and optional interval
//! timer flush *without* any caller, and a hard backpressure ceiling
//! stalls writers that outrun the disk. The `MetallManager` handle is a
//! thin wrapper around an [`Arc<ManagerCore>`] so the flusher thread can
//! safely share the core; all of the manager API lives on
//! [`ManagerCore`] and is reached through `Deref`. See
//! [`crate::alloc::bg_sync`] for the engine's epoch/ticket protocol,
//! panic containment, and shutdown drain.
//!
//! ## Concurrency model (§4.5.1, sharded with a lock-free fast path)
//!
//! The bin directory is split into N [`AllocShard`]s (option
//! [`ManagerOptions::shards`]): each shard holds one `RwLock<BinData>`
//! per size class over the chunks it owns, a remote-free queue, and
//! contention counters. A thread's home shard is its virtual CPU modulo
//! N ([`crate::alloc::bin_dir::ShardMap`]); the per-core object caches
//! key off the same virtual CPU, binding each cache slot to its shard.
//! The small-allocation hot path:
//!
//! 1. Per-core object cache pop (no directory locks at all).
//! 2. On a cache miss, the *shared* (read) side of the home shard's bin
//!    lock is taken and a word-level CAS claim runs against an active
//!    chunk's atomic bitset ([`crate::alloc::mlbitset::MlBitset`]). The
//!    claim grabs a batch ([`crate::alloc::object_cache::REFILL_BATCH`])
//!    in one CAS and parks the surplus in this core's cache, so same-bin
//!    allocations from different threads proceed concurrently — and
//!    threads on different shards touch disjoint locks entirely.
//! 3. Only when every active chunk of the home shard is full does a
//!    thread take the *exclusive* (write) side — the paper's
//!    serialization point #1 (registering a fresh chunk, with the chunk
//!    directory nested inside), now contended per shard rather than per
//!    manager. Serialization point #2 (releasing an emptied chunk) also
//!    runs under the owner shard's write lock, on the free/spill path.
//!
//! Frees always go through the per-core cache; spills are routed to the
//! owning shard — home-shard slots under the exclusive bin lock, foreign
//! slots onto the owner's remote-free queue (a plain mutex push; the
//! foreign shard's bin locks are never touched on the hot path). Each
//! shard drains its queue when it next reaches a serialization point,
//! and `sync`/`close` drain everything. Nesting order is always bin →
//! chunks; the chunk lock never nests inside a bin lock.
//!
//! Shard count is DRAM-only: the persistent format is identical for
//! every N, a store written with N shards reopens with M ≠ N (ownership
//! is re-dealt as `chunk % M`), and N = 1 reproduces the unsharded
//! allocator's on-disk layout bit-for-bit.

use std::collections::{BTreeSet, HashMap, HashSet};
use std::ops::Deref;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::time::Instant;

use crate::alloc::bg_sync::{BgSyncStats, SyncEngine, SyncTicket};
use crate::alloc::bin_dir::{
    serialize_merged_into, AllocShard, BinData, ShardMap, ShardStatsSnapshot,
};
use crate::alloc::mgmt_io::{self, Manifest, SectionId, SectionRecord};
use crate::alloc::object_cache::current_vcpu;
use crate::alloc::chunk_dir::{ChunkDirectory, ChunkKind};
use crate::alloc::name_dir::{type_fingerprint, NameDirectory, NamedEntry};
use crate::alloc::object_cache::{ObjectCache, REFILL_BATCH};
use crate::alloc::readers::{self, ReaderLease};
use crate::alloc::mlbitset::MlBitset;
use crate::alloc::size_class::{
    bin_of, is_small, large_chunks, num_bins, size_of_bin, slots_per_chunk,
};
use crate::containers::oplog::{self, OpLogStats, OpRecord, OpToken, RecordState};
use crate::error::{Error, Result};
use crate::numa::Topology;
use crate::storage::bsmmap::BsMsync;
use crate::storage::faults::FaultClass;
use crate::storage::mmap::page_size;
use crate::storage::netfs::SimNetFs;
use crate::storage::pagemap;
use crate::storage::reflink::{self, CopyMethod};
use crate::storage::segment::{SegmentOptions, SegmentStorage};
use crate::telemetry::{recorder::EventKind, Op as TelOp, Telemetry};

const META_MAGIC: &[u8; 8] = b"METALLV1";
const MGMT_MAGIC: &[u8; 8] = b"METALLMG";
const CLEAN_MARKER: &str = "CLEAN";
/// Advisory marker a **wounded** manager drops in the store directory
/// (best-effort: the backend just failed). `metall doctor` reads it to
/// report the degradation cross-process; any successful read-write
/// open removes it — recovery from the last committed manifest is what
/// resolves a wound, and that is exactly what a rw open performs.
pub const WOUNDED_MARKER: &str = "WOUNDED";
/// Inter-process store lock file (held via `flock` for the lifetime of
/// a manager: exclusive by writers, shared by read-only opens).
const STORE_LOCK: &str = "LOCK";

/// Geometry and behaviour options. Geometry (chunk/file size) is fixed at
/// create time and read back from `meta.bin` on open.
#[derive(Clone, Debug)]
pub struct ManagerOptions {
    /// Chunk size (paper default 2 MiB).
    pub chunk_size: usize,
    /// Backing-file size (paper default 256 MB; our scaled default 64 MiB).
    pub file_size: usize,
    /// VM reservation (paper default "a few TB"; ours 64 GiB).
    pub vm_reserve: usize,
    /// bs-mmap mode: MAP_PRIVATE + user-level msync (§5).
    pub private_mode: bool,
    /// MAP_POPULATE on open.
    pub populate: bool,
    /// Punch file holes when freeing chunks (§6.4.2 disables on Lustre).
    pub free_file_space: bool,
    /// Parallel per-file msync on sync (§5.2).
    pub parallel_sync: bool,
    /// Allocator shard count (DRAM-only; `0` = auto: sized from the NUMA
    /// topology — [`Topology::default_shards`], which is
    /// `min(available_parallelism, 4)` rounded up to a multiple of the
    /// node count, and exactly `min(available_parallelism, 4)` on a
    /// single node). `1` reproduces the unsharded allocator's on-disk
    /// layout bit-for-bit; every count reads every other count's
    /// datastore — the persistent format does not change.
    pub shards: usize,
    /// NUMA topology override (DRAM-only, like the shard count). `None`
    /// detects the machine topology from `/sys/devices/system/node`
    /// (single-node fallback when absent); tests and benches inject fakes
    /// ([`Topology::fake`]) to exercise multi-node placement on any host.
    pub topology: Option<Topology>,
    /// Background sync: dirty-data high watermark in bytes. When the
    /// chunk-granular estimate of un-synced application data crosses it,
    /// the background flusher runs an incremental sync without any
    /// caller — fig5-style incremental workloads never stall on the
    /// persist path. `0` (default) disables the watermark trigger;
    /// explicit `sync()`/`sync_async()` still run on the engine.
    /// Incompatible with `private_mode` (the bs-mmap user-level msync
    /// requires quiescent writers): create/open rejects the combination.
    /// Durability sharp edge when enabled: the unsafe
    /// [`ManagerCore::bytes_mut`] view marks its range dirty at handout
    /// (mark-before-write), so a background flush racing the caller's
    /// stores can consume the mark mid-fill — bulk writers that need
    /// ticket-grade durability must use the marking write APIs or
    /// re-mark with [`ManagerCore::mark_data_dirty`] after writing (see
    /// `bytes_mut`'s docs).
    pub sync_watermark_bytes: usize,
    /// Background sync: optional interval timer in milliseconds. When
    /// non-zero, the flusher wakes at this cadence and flushes if
    /// anything (data or management sections) is dirty. `0` disables.
    pub sync_interval_ms: u64,
    /// Backpressure hard ceiling in bytes: a writer whose dirty-data
    /// mark pushes the estimate to or past this stalls (counted in
    /// [`BgSyncStats`]) until the flusher drains below it. `0` = auto:
    /// 4 × the watermark when a watermark is set, otherwise disabled.
    pub sync_ceiling_bytes: usize,
    /// Background sync: how many epochs may be in flight at once
    /// (serialized-but-uncommitted in the manifest queue plus the one the
    /// committer is writing). `0` = auto (2: one committing, one queued).
    /// `1` reproduces the strictly serial one-epoch-at-a-time engine of
    /// earlier versions. The flusher blocks (backpressure) rather than
    /// queue a cut beyond this depth.
    pub sync_pipeline_depth: usize,
    /// Background sync: adapt the watermark to measured flush bandwidth.
    /// When `true` (default) and a watermark is configured, the engine
    /// keeps an EWMA of per-epoch effective flush bandwidth and fixed
    /// per-flush latency (including [`SimNetFs`] charged time when a
    /// profile is active) and moves the trigger toward the measured
    /// bandwidth-delay product, clamped to `[64 KiB, ceiling/2]` — fast
    /// NVMe stores flush eagerly, Lustre stores batch up to what one
    /// in-flight epoch can absorb. `false` pins the configured value.
    pub sync_watermark_adaptive: bool,
    /// Consecutive failed background flush rounds tolerated before the
    /// manager **wounds** itself (flips to degraded read-only; see the
    /// module-level "Error taxonomy & degraded mode" notes). Transient
    /// failures (EIO/EAGAIN/ENOSPC/…) below the limit are retried with
    /// the engine's exponential backoff and never surface on the
    /// mutation path; permanently classified errors
    /// (EROFS/ENODEV/ENXIO/EBADF) wound immediately regardless. `0`
    /// disables the consecutive-transient wound (permanent errors still
    /// wound). Default 16.
    pub sync_fail_limit: usize,
    /// Simulated-backend profile name (`"lustre"`, `"vast"`, `"nvme"`,
    /// `"optane"`, case-insensitive; see [`crate::storage::netfs`]).
    /// When set, the sync path — data-range msync, section writes, and
    /// manifest commits — charges the cost model, and
    /// [`MetallManager::netfs`] exposes the account. Unknown names fail
    /// fast at create/open with the list of known profiles.
    pub netfs_profile: Option<String>,
    /// Fraction of simulated backend time to actually sleep (`0.0` =
    /// account only). Benches use `1.0` so thread interleaving against
    /// the modelled backend is realistic.
    pub netfs_sleep_scale: f64,
    /// Latency-telemetry sampling rate for the *hot* paths
    /// (allocate/deallocate, op-log append): 1 in `telemetry_sample`
    /// calls is timed into the [`crate::telemetry`] histograms. Rare
    /// ops (epoch phases, backpressure stalls, reader attach/refresh)
    /// are always recorded. Default 64 (≈ 1.6 % of hot ops pay two
    /// clock reads); `1` times everything, `0` disables all latency
    /// histograms. The flight recorder is independent of this rate.
    pub telemetry_sample: u32,
}

impl Default for ManagerOptions {
    fn default() -> Self {
        Self {
            chunk_size: 2 << 20,
            file_size: 64 << 20,
            vm_reserve: 64 << 30,
            private_mode: false,
            populate: false,
            free_file_space: true,
            parallel_sync: true,
            shards: 0,
            topology: None,
            sync_watermark_bytes: 0,
            sync_interval_ms: 0,
            sync_ceiling_bytes: 0,
            sync_pipeline_depth: 0,
            sync_watermark_adaptive: true,
            sync_fail_limit: 16,
            netfs_profile: None,
            netfs_sleep_scale: 0.0,
            telemetry_sample: 64,
        }
    }
}

impl ManagerOptions {
    /// Small geometry for tests: 64 KiB chunks, 1 MiB files. Single shard
    /// for deterministic slot placement.
    pub fn small_for_tests() -> Self {
        Self {
            chunk_size: 64 << 10,
            file_size: 1 << 20,
            vm_reserve: 1 << 30,
            shards: 1,
            ..Self::default()
        }
    }

    fn resolved_topology(&self) -> Topology {
        self.topology.clone().unwrap_or_else(Topology::detect)
    }

    fn resolved_shards(&self, topo: &Topology) -> usize {
        if self.shards > 0 {
            return self.shards;
        }
        topo.default_shards()
    }

    /// Effective backpressure ceiling (see [`Self::sync_ceiling_bytes`]).
    fn resolved_sync_ceiling(&self) -> usize {
        if self.sync_ceiling_bytes > 0 {
            self.sync_ceiling_bytes
        } else if self.sync_watermark_bytes > 0 {
            self.sync_watermark_bytes.saturating_mul(4)
        } else {
            0
        }
    }

    /// Effective pipeline depth (see [`Self::sync_pipeline_depth`]).
    fn resolved_pipeline_depth(&self) -> usize {
        if self.sync_pipeline_depth > 0 {
            self.sync_pipeline_depth
        } else {
            2
        }
    }

    /// Resolve the simulated-backend account for these options; fails
    /// fast on an unknown profile name.
    fn resolved_netfs(&self) -> Result<Option<Arc<SimNetFs>>> {
        match &self.netfs_profile {
            None => Ok(None),
            Some(name) => {
                let p = crate::storage::netfs::profile_by_name_strict(name)?;
                Ok(Some(Arc::new(SimNetFs::new(p).with_sleep_scale(self.netfs_sleep_scale))))
            }
        }
    }

    /// The engine sized for these options (read-only managers get a
    /// fully disabled engine: no triggers, never started).
    fn sync_engine(&self, read_only: bool) -> SyncEngine {
        if read_only {
            return SyncEngine::new(0, 0, 0, 1, false, 0);
        }
        SyncEngine::new(
            self.sync_watermark_bytes as u64,
            self.resolved_sync_ceiling() as u64,
            self.sync_interval_ms,
            self.resolved_pipeline_depth(),
            self.sync_watermark_adaptive,
            self.sync_fail_limit as u64,
        )
    }

    fn segment_options(&self, read_only: bool) -> SegmentOptions {
        let mut o = SegmentOptions::default()
            .with_file_size(self.file_size)
            .with_vm_reserve(self.vm_reserve);
        o.populate = self.populate;
        o.free_file_space = self.free_file_space;
        if self.private_mode {
            o = o.private_mode();
        }
        if read_only {
            o = o.read_only();
        }
        o
    }
}

/// Running manager-wide counters (perf instrumentation; see
/// EXPERIMENTS.md §Perf). Small-object path counters (`fast_claims`,
/// `fresh_chunks`, small-chunk releases) live in the per-shard
/// [`crate::alloc::bin_dir::ShardStats`] and are aggregated into
/// [`StatsSnapshot`] by [`MetallManager::stats`].
#[derive(Default)]
pub struct AllocStats {
    pub allocs: AtomicU64,
    pub deallocs: AtomicU64,
    pub cache_hits: AtomicU64,
    /// Chunks freed through the *large*-object path (small-chunk releases
    /// are counted per shard).
    pub freed_large_chunks: AtomicU64,
    pub large_allocs: AtomicU64,
}

/// Snapshot of the allocator counters: manager-wide totals with the
/// per-shard counters aggregated in (same field set as before sharding —
/// consumers of the totals are unaffected by the shard count).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub allocs: u64,
    pub deallocs: u64,
    pub cache_hits: u64,
    pub fast_claims: u64,
    pub fresh_chunks: u64,
    pub freed_chunks: u64,
    pub large_allocs: u64,
}

/// Where [`PlacementReport`] got its node-per-page attribution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementSource {
    /// Kernel truth via `move_pages(2)` page queries — used only when the
    /// topology was *detected* on this machine (an injected topology
    /// describes sockets the kernel has never heard of).
    Kernel,
    /// Recorded birth nodes (the node the owning shard bound and
    /// first-touched each chunk on). Used for injected topologies and on
    /// kernels without NUMA page queries.
    Recorded,
}

/// Placement of one shard's small chunks (all figures in pages).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardPlacement {
    pub shard: usize,
    /// The shard's home memory node ([`ShardMap::node_of_shard`]).
    pub node: usize,
    /// Mapped pages of small chunks this shard owns.
    pub pages: u64,
    /// … of which reside on the shard's home node.
    pub node_local_pages: u64,
    /// … of which reside on some other node.
    pub remote_pages: u64,
    /// … of which could not be attributed (not faulted in yet, or placed
    /// before this session — recovered stores know no birth nodes).
    pub unknown_pages: u64,
}

/// Node-per-page histogram of the whole mapped segment, grouped by
/// owning shard (see [`MetallManager::placement_report`]). Total: every
/// mapped page is accounted exactly once — small-chunk pages under their
/// owner's [`ShardPlacement`], the rest under `large_pages`/`free_pages`.
#[derive(Clone, Debug)]
pub struct PlacementReport {
    pub per_shard: Vec<ShardPlacement>,
    /// Pages of large-allocation chunks (not placed per shard; the
    /// ROADMAP follow-on is an interleave policy for these).
    pub large_pages: u64,
    /// Pages of free chunks and the unused tail of the last backing file.
    pub free_pages: u64,
    /// `mapped_len / page_size` — the invariant the report is checked
    /// against.
    pub total_pages: u64,
    pub source: PlacementSource,
}

impl PlacementReport {
    /// Pages accounted by the report (must equal `total_pages`).
    pub fn accounted_pages(&self) -> u64 {
        self.large_pages
            + self.free_pages
            + self.per_shard.iter().map(|s| s.pages).sum::<u64>()
    }

    /// Fraction of attributed small-chunk pages that are node-local
    /// (`None` when nothing is attributed yet).
    pub fn node_local_fraction(&self) -> Option<f64> {
        let local: u64 = self.per_shard.iter().map(|s| s.node_local_pages).sum();
        let known: u64 = local + self.per_shard.iter().map(|s| s.remote_pages).sum::<u64>();
        (known > 0).then(|| local as f64 / known as f64)
    }
}

/// Batch error policy for the free paths: process every slot (a partial
/// failure must not leak the rest of the batch), report the first error.
fn keep_first_err(result: &mut Result<()>, r: Result<()>) {
    if result.is_ok() {
        *result = r;
    }
}

/// Observability snapshot of the incremental sync path
/// ([`MetallManager::sync_stats`]): cumulative counters plus the shape of
/// the *last* sync. Exported as `alloc.sync.*` by
/// [`crate::coordinator::metrics::record_sync_stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SyncStats {
    /// Cumulative `sync()` calls on this manager.
    pub syncs: u64,
    /// Cumulative syncs that committed a new manifest (a no-op sync
    /// commits nothing).
    pub manifest_commits: u64,
    /// Last sync: management sections re-serialized and rewritten.
    pub dirty_sections: u64,
    /// Last sync: total sections the store has (chunk dir + bin groups +
    /// names + cache).
    pub total_sections: u64,
    /// Last sync: bytes of section files written (0 for a no-op sync).
    pub section_bytes_written: u64,
    /// Last sync: data granules flushed — dirty *chunks* msync'd in
    /// shared mode, dirty *pages* written back in private (bs-mmap) mode.
    pub data_chunks_flushed: u64,
    /// Last sync: bytes of application data flushed.
    pub data_bytes_flushed: u64,
    /// Last sync: wall-clock duration in microseconds, *including* the
    /// un-slept portion of simulated backend time when a
    /// [`SimNetFs`] profile is active — the effective-bandwidth input of
    /// the adaptive watermark.
    pub flush_micros: u64,
    /// Last sync: simulated backend time charged by the [`SimNetFs`]
    /// cost model, in microseconds (0 when no profile is active).
    pub sim_flush_micros: u64,
    /// Last sync: free slots left parked in the per-core caches (warmth
    /// preserved instead of drained; serialized to the cache section).
    pub cache_slots_preserved: u64,
}

/// Chunk-granular dirty map of the application-data segment: a fixed
/// lock-free bitmap sized to the VM reservation (1 bit per chunk — 4 KiB
/// per TiB at 2 MiB chunks). The write APIs mark, `sync` swaps the words
/// to zero and flushes only the marked chunks' union. Raw-pointer writers
/// outside the manager's APIs must call [`MetallManager::mark_data_dirty`]
/// themselves (all in-repo containers go through the marking APIs).
struct DirtyChunkSet {
    words: Vec<AtomicU64>,
    /// Running count of set bits — the background engine's dirty-byte
    /// watermark input (`count × chunk_size`), maintained so the hot
    /// write path never scans the bitmap.
    count: AtomicU64,
}

impl DirtyChunkSet {
    fn new(max_chunks: usize) -> Self {
        Self {
            words: (0..max_chunks.div_ceil(64)).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
        }
    }

    #[inline]
    fn mark(&self, chunk: usize) {
        if let Some(w) = self.words.get(chunk / 64) {
            let bit = 1u64 << (chunk % 64);
            // already-set is the steady state on hot container writes: a
            // relaxed load keeps the shared cache line out of RMW
            // ping-pong between writer threads
            if w.load(Ordering::Relaxed) & bit == 0 {
                let prev = w.fetch_or(bit, Ordering::Relaxed);
                if prev & bit == 0 {
                    // this thread freshly set the bit (the fetch_or
                    // settles races): keep the watermark count exact
                    self.count.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// Chunks currently marked dirty (watermark estimate).
    #[inline]
    fn dirty_chunks(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Clear every bit below `limit` without collecting indices (the
    /// bs-mmap flush covers all writes page-granularly and only needs
    /// the watermark estimate reset). Same preservation rule as
    /// [`Self::take_dirty`] for bits at or past `limit`.
    fn clear_to(&self, limit: usize) {
        let mut cleared = 0u64;
        for (wi, w) in self.words.iter().enumerate() {
            if wi * 64 >= limit {
                break;
            }
            let mut bits = w.swap(0, Ordering::Relaxed);
            let keep_from = limit - wi * 64;
            if keep_from < 64 {
                let hi = bits & (!0u64 << keep_from);
                if hi != 0 {
                    let prev = w.fetch_or(hi, Ordering::Relaxed);
                    let dup = (prev & hi).count_ones() as u64;
                    if dup > 0 {
                        self.count.fetch_sub(dup, Ordering::Relaxed);
                    }
                }
                bits &= !(!0u64 << keep_from);
            }
            cleared += bits.count_ones() as u64;
        }
        if cleared > 0 {
            self.count.fetch_sub(cleared, Ordering::Relaxed);
        }
    }

    /// Dirty chunk indices below `limit`, ascending, clearing their
    /// bits. Bits at or past `limit` are *preserved* — a concurrent
    /// segment extension can mark a chunk past the caller's snapshot of
    /// the mapped length, and that mark must survive for the next sync,
    /// including in the word that straddles the limit.
    fn take_dirty(&self, limit: usize) -> Vec<usize> {
        let mut out = Vec::new();
        for (wi, w) in self.words.iter().enumerate() {
            if wi * 64 >= limit {
                // wholly past the limit: leave the word untouched
                break;
            }
            let mut bits = w.swap(0, Ordering::Relaxed);
            let keep_from = limit - wi * 64; // first out-of-range bit index
            if keep_from < 64 {
                // straddling word: put the out-of-range bits back
                let hi = bits & (!0u64 << keep_from);
                if hi != 0 {
                    // a mark racing between the swap and this restore may
                    // have re-set (and re-counted) one of these bits; the
                    // overlap was counted twice for a single set bit, so
                    // settle the watermark estimate here
                    let prev = w.fetch_or(hi, Ordering::Relaxed);
                    let dup = (prev & hi).count_ones() as u64;
                    if dup > 0 {
                        self.count.fetch_sub(dup, Ordering::Relaxed);
                    }
                }
                bits &= !(!0u64 << keep_from);
            }
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                out.push(wi * 64 + b);
            }
        }
        // bits preserved past the limit stay counted; only taken ones
        // leave the watermark estimate
        self.count.fetch_sub(out.len() as u64, Ordering::Relaxed);
        out
    }
}

/// In-DRAM bookkeeping of the committed segmented-management state: the
/// last committed epoch and, per section, the exact file/len/checksum the
/// newest manifest references (clean sections are carried forward from
/// here). `legacy` marks a store loaded from the monolithic
/// `management.bin` — the next sync rewrites every section.
struct MgmtState {
    epoch: u64,
    sections: HashMap<SectionId, SectionRecord>,
    legacy: bool,
    /// Bin-group width of the manifest the sections were loaded from.
    /// When it differs from the build's [`mgmt_io::BINS_PER_GROUP`], the
    /// next sync must rewrite every section (carried-forward bin groups
    /// would otherwise be partitioned under the wrong width).
    bins_per_group: usize,
    /// Next epoch number to hand to a consistent cut. Runs ahead of
    /// `epoch` while pipelined cuts are in flight (`epoch` only advances
    /// when the committer lands a manifest, strictly in cut order).
    next_epoch: u64,
}

/// DRAM bookkeeping of the persistent container op log. The log bytes
/// themselves live in an ordinary named allocation inside the segment
/// ([`oplog::OPLOG_NAME`], created lazily by the first logged container
/// mutation); this tracks the ring geometry and sequence horizons.
///
/// Lock discipline: this mutex is leaf-level and is never held across a
/// `mark_data_dirty` (whose backpressure stall can wait on the flusher)
/// — the flusher itself takes it briefly in `prepare_epoch` to stamp
/// the cut table.
struct OpLogDram {
    /// Segment offset of the log object; [`oplog::NONE`] until it exists.
    log_off: u64,
    /// Ring capacity in records (from the persistent log header).
    capacity: u32,
    /// Next ring sequence number to assign.
    next_seq: u64,
    /// Reclaim horizon: every record below it is decided *and* covered
    /// by a durably committed management epoch, so its ring slot may be
    /// overwritten. Advances when the committer lands a manifest.
    safe_seq: u64,
    /// Sequence numbers of ops begun but not yet committed. The minimum
    /// pins the epoch cut horizon: a cut must not claim coverage of a
    /// record whose op is still in flight.
    inflight: BTreeSet<u64>,
    /// Horizon of the last cut-table stamp (dedup: an unchanged horizon
    /// is not re-stamped, or the stamp's own dirty mark would feed a
    /// perpetual flush loop).
    last_cut_seq: u64,
}

impl OpLogDram {
    fn absent() -> Self {
        OpLogDram {
            log_off: oplog::NONE,
            capacity: oplog::DEFAULT_CAPACITY,
            next_seq: 0,
            safe_seq: 0,
            inflight: BTreeSet::new(),
            last_cut_seq: 0,
        }
    }

    /// The sequence horizon an epoch cut taken *now* may claim: every
    /// record below it is decided (committed or aborted).
    fn cut_horizon(&self) -> u64 {
        self.inflight.iter().next().copied().unwrap_or(self.next_seq)
    }
}

/// Failure-health counters behind [`ManagerCore::health_stats`].
#[derive(Default)]
struct HealthCounters {
    /// Background flush/commit rounds that failed with a transiently
    /// classified error (retried by the engine's backoff).
    transient_failures: AtomicU64,
    /// … with a permanently classified error (each one wounds).
    permanent_failures: AtomicU64,
    /// Segment extensions rolled back on the allocation path (reserved
    /// chunk ids returned to the free pool; ENOSPC surfaces as a clean
    /// `Error::Alloc` and a smaller allocation can still succeed).
    extend_rollbacks: AtomicU64,
}

/// Failure-health snapshot ([`ManagerCore::health_stats`]), exported as
/// `alloc.faults.*` / `alloc.health.degraded` by
/// [`crate::coordinator::metrics::record_health_stats`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HealthStats {
    /// Background flush rounds failed with a transient classification.
    pub transient_failures: u64,
    /// Background flush rounds failed with a permanent classification.
    pub permanent_failures: u64,
    /// Allocation-path segment extensions rolled back (ENOSPC etc.).
    pub extend_rollbacks: u64,
    /// Is the manager wounded (degraded read-only)?
    pub degraded: bool,
    /// The originating failure when wounded.
    pub degraded_reason: Option<String>,
}

/// Cumulative op-log counters (mirrored into [`OpLogStats`]).
#[derive(Default)]
struct OpLogCounters {
    appended: AtomicU64,
    committed: AtomicU64,
    forced_syncs: AtomicU64,
    forced_sync_errors: AtomicU64,
    recovered_forward: AtomicU64,
    recovered_rollback: AtomicU64,
    recovered_adopted: AtomicU64,
    recovered_released: AtomicU64,
    recovery_anomalies: AtomicU64,
    validate_records: AtomicU64,
}

/// One consistent cut the flusher prepared and the committer will make
/// durable: the assigned epoch, the dirty data ranges taken from the
/// chunk map, and the serialized dirty sections. Epochs commit strictly
/// in `epoch` order; a cut that fails to commit is *aborted* — its data
/// chunks and section dirty flags are re-marked so the next cut retries
/// them ([`ManagerCore::abort_epoch`]).
pub(crate) struct PreparedEpoch {
    /// The epoch this cut will commit as (assigned at cut time from
    /// [`MgmtState::next_epoch`]).
    epoch: u64,
    /// The ticket generation this cut covers (every request up to it).
    pub(crate) gen: u64,
    /// Coalesced dirty data ranges to msync (shared mode; empty when the
    /// bs-mmap path already flushed at prepare time).
    ranges: Vec<std::ops::Range<usize>>,
    /// The dirty chunk indices behind `ranges` (for re-mark on abort and
    /// the granule count in stats).
    data_chunks: Vec<usize>,
    /// Private (bs-mmap) mode flushes at prepare time under the cut's
    /// quiescence contract; this carries its `(granules, bytes)` result.
    data_flushed: Option<(u64, u64)>,
    /// Dirty section ids and their serialized images, parallel vectors.
    ids: Vec<SectionId>,
    buffers: Vec<Vec<u8>>,
    /// This cut re-serialized *every* section (first segmented sync /
    /// legacy upgrade / bin-group width change): its manifest must not
    /// carry forward any previously committed section.
    rewrite_all: bool,
    /// Free slots parked in the per-core caches at cut time.
    cache_slots: u64,
    /// Total sections the store has (for stats).
    total_sections: u64,
    /// Op-log sequence horizon this cut covers (0 when no log exists):
    /// becomes the reclaim horizon `safe_seq` when the cut commits.
    cut_seq: u64,
}

/// Everything recovered from the on-disk management image (segmented
/// manifest, legacy monolith, or the empty never-synced state).
struct LoadedManagement {
    chunks: ChunkDirectory,
    bins: Vec<BinData>,
    names: NameDirectory,
    /// Transient cache-section entries: `(bin, offset)` slots that are
    /// claimed in `bins` but were parked free when the image was written.
    cache: Vec<(u32, u64)>,
    epoch: u64,
    sections: HashMap<SectionId, SectionRecord>,
    legacy: bool,
    bins_per_group: usize,
}

/// Marker for types that may live inside the persistent segment: plain
/// old data only — no pointers/references/niches (paper §3.5: replace raw
/// pointers with offset pointers; remove references & virtual functions).
///
/// # Safety
/// Implementors guarantee `Self` is valid for any bit pattern written by
/// a previous process (fixed layout, no padding-sensitive invariants, no
/// pointers).
pub unsafe trait Persist: Copy + 'static {}

macro_rules! persist_pod {
    ($($t:ty),*) => { $(unsafe impl Persist for $t {})* };
}
persist_pod!(u8, u16, u32, u64, i8, i16, i32, i64, f32, f64, usize, isize);
unsafe impl<T: Persist, const N: usize> Persist for [T; N] {}
unsafe impl<A: Persist, B: Persist> Persist for (A, B) {}

/// The shared manager core: every field and almost every method of the
/// Metall manager. Applications hold it through the [`MetallManager`]
/// wrapper (which `Deref`s here); the background
/// [`crate::alloc::bg_sync::SyncEngine`] flusher thread holds a second
/// `Arc` so it can serialize and commit epochs off the allocation path.
/// `Sync`: share it behind `&` across threads.
pub struct ManagerCore {
    dir: PathBuf,
    opts: ManagerOptions,
    read_only: bool,
    segment: SegmentStorage,
    /// Read-mostly: `kind`/`owner` lookups take the shared side; chunk
    /// state changes (the rare serialization points) take the exclusive
    /// side.
    chunks: RwLock<ChunkDirectory>,
    shards: Vec<AllocShard>,
    shard_map: ShardMap,
    cache: ObjectCache,
    names: Mutex<NameDirectory>,
    bs: Option<Mutex<BsMsync>>,
    stats: AllocStats,
    closed: AtomicBool,
    /// Segmented-management commit bookkeeping (epoch + section records).
    mgmt: Mutex<MgmtState>,
    /// Chunk-granular dirty map of application-data writes.
    dirty_data: DirtyChunkSet,
    /// Simulated-backend account ([`ManagerOptions::netfs_profile`]);
    /// shared with the segment so `sync_ranges` charges it too.
    netfs: Option<Arc<SimNetFs>>,
    /// Last-sync observability ([`Self::sync_stats`]).
    last_sync: Mutex<SyncStats>,
    /// Background sync engine (flusher thread, epoch tickets,
    /// watermark/interval triggers, backpressure).
    bg: SyncEngine,
    /// Wound latch: set (once, first failure wins) when a permanent
    /// backend failure flips this manager to degraded read-only. The
    /// payload is the originating failure, echoed by every subsequent
    /// [`Error::Degraded`]. See [`Self::wound`].
    wounded: OnceLock<String>,
    /// Failure-health counters ([`Self::health_stats`]).
    health: HealthCounters,
    /// Latency histograms + crash-persisted flight recorder
    /// ([`crate::telemetry`]; sampling per
    /// [`ManagerOptions::telemetry_sample`]).
    tel: Telemetry,
    /// Container op-log ring state (see [`OpLogDram`]).
    oplog: Mutex<OpLogDram>,
    oplog_counters: OpLogCounters,
    /// Records at `seq >=` this are in the newest epoch's tail and are
    /// subject to [`Self::validate_containers`]; on a clean open it is
    /// set to `next_seq` so stale decided records are not re-audited.
    oplog_validate_floor: AtomicU64,
    /// Inter-process store lock: an `flock` on `<dir>/LOCK`, exclusive
    /// for read-write managers, shared for read-only opens. Held for the
    /// manager's lifetime — the kernel releases it when the fd closes
    /// (drop or death), so a crashed owner never wedges the store.
    _store_lock: std::fs::File,
}

/// The Metall manager: the application-facing owner of one datastore.
/// A thin wrapper around [`Arc<ManagerCore>`] — the full API lives on
/// [`ManagerCore`] and is reached through `Deref`; the `Arc` is what
/// lets the background flusher thread share the core safely. Dropping
/// (or [`Self::close`]-ing) the wrapper drains and joins the flusher,
/// then performs the final durable sync and marks the store `CLEAN`.
pub struct MetallManager {
    core: Arc<ManagerCore>,
}

impl Deref for MetallManager {
    type Target = ManagerCore;

    fn deref(&self) -> &ManagerCore {
        &self.core
    }
}

impl MetallManager {
    // ------------------------------------------------------ lifecycle --

    /// Create a fresh datastore at `dir` with default options.
    pub fn create(dir: impl Into<PathBuf>) -> Result<Self> {
        Self::create_with(dir, ManagerOptions::default())
    }

    pub fn create_with(dir: impl Into<PathBuf>, opts: ManagerOptions) -> Result<Self> {
        Ok(Self::wrap(ManagerCore::create_core(dir.into(), opts)?))
    }

    /// Open an existing, cleanly closed datastore read-write.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        Self::open_with(dir, ManagerOptions::default(), false, false)
    }

    /// Open read-only (paper: `metall::open_read_only` — writes to the
    /// mapping SIGSEGV; mutating APIs return errors). Multiple processes
    /// may open the same store read-only (§3.6).
    pub fn open_read_only(dir: impl Into<PathBuf>) -> Result<Self> {
        Self::open_with(dir, ManagerOptions::default(), true, false)
    }

    /// Open even if the store was not closed cleanly (the paper §3.3:
    /// after a crash the backing files may be inconsistent — the
    /// application should work on a duplicate).
    pub fn open_unclean(dir: impl Into<PathBuf>) -> Result<Self> {
        Self::open_with(dir, ManagerOptions::default(), false, true)
    }

    pub fn open_with(
        dir: impl Into<PathBuf>,
        opts: ManagerOptions,
        read_only: bool,
        allow_unclean: bool,
    ) -> Result<Self> {
        Ok(Self::wrap(ManagerCore::open_core(dir.into(), opts, read_only, allow_unclean)?))
    }

    /// Wrap a built core in its `Arc`, bind the background engine to it
    /// (via `Weak`, so the thread can reach the core without keeping a
    /// dropped manager alive), and start the flusher right away when a
    /// watermark/interval/ceiling trigger is configured. A spawn failure
    /// here (thread exhaustion) is deliberately NOT fatal: failing the
    /// whole create/open would leave a half-materialized store behind,
    /// and the degradation is self-healing — every explicit sync AND
    /// every watermark/ceiling kick retries `ensure_started`
    /// (`bg_sync_stats().engine_running` exposes the state meanwhile).
    fn wrap(core: ManagerCore) -> Self {
        let core = Arc::new_cyclic(|weak| {
            core.bg.bind(weak.clone());
            core
        });
        let m = MetallManager { core };
        if !m.core.read_only && m.core.bg.auto_start() {
            let _ = m.core.bg.ensure_started();
        }
        m
    }

    /// Sync, serialize, and mark the store cleanly closed. Drains the
    /// background engine (outstanding tickets resolve), joins the
    /// flusher thread, and runs the final full sync inline; a dead
    /// (panicked) flusher surfaces here as an error and the store is
    /// deliberately **not** marked clean.
    pub fn close(self) -> Result<()> {
        self.core.close_inner()
        // Drop runs next and is a no-op: close_inner latched `closed`.
    }
}

impl Drop for MetallManager {
    fn drop(&mut self) {
        // Best-effort clean close (explicit close() is preferred and
        // reports errors): drains + joins the flusher, final sync,
        // CLEAN marker — the same path as close().
        let _ = self.core.close_inner();
    }
}

/// Observability counters for one reader attach (exported as
/// `alloc.attach.*` by
/// [`crate::coordinator::metrics::record_attach_stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct AttachStats {
    /// Wall time of the initial attach (manifest load + lease +
    /// segment map + overlay), microseconds.
    pub attach_micros: u64,
    /// Successful `refresh()` re-pins since attach.
    pub refreshes: u64,
    /// Chunks currently resolved to epoch-side copies.
    pub chunks_overlaid: u64,
    /// Side copies this reader had to materialize itself (attach-time
    /// seeding; cumulative across refreshes).
    pub side_copies_created: u64,
    /// Side copies reused from the flusher or an earlier reader
    /// (cumulative across refreshes).
    pub side_copies_reused: u64,
    /// Committed epochs on disk ahead of the pin, measured at the last
    /// attach/refresh decision (acceptance target: < 1 at attach).
    pub staleness_epochs: u64,
}

/// A live read-only attach to a store **another process owns**: the
/// reader-epoch half of the multi-process serving tier.
///
/// Unlike [`MetallManager::open_read_only`] — which demands the `CLEAN`
/// marker and therefore a closed store — a `ReaderManager` attaches
/// while the owner keeps mutating and background-flushing. It pins the
/// **last committed manifest epoch**: the names/chunk-directory view is
/// exactly that epoch's (management-consistent by construction), and
/// every live chunk's data is resolved through an immutable epoch-side
/// copy ([`crate::alloc::readers`]) so the owner's in-place msyncs and
/// shared-page-cache writes never show through. The pin is registered
/// in the lease registry, which the owner's GC honors; a reader that
/// dies (kill-9 included) is reaped by the owner's next flush scan.
///
/// The attach performs **no on-disk mutation of the store proper** —
/// no CLEAN unlink, no `free_range`, no legacy-monolith conversion, no
/// store lock; it only writes its own lease and (at seeding time)
/// epoch-side copies. Staleness at attach is bounded by one epoch: the
/// pinned manifest is the newest committed, and the seeded data bytes
/// lie between that commit and the next.
///
/// `ReaderManager` implements [`crate::alloc::SegmentAlloc`] (the
/// mutating half returns [`Error::InvalidOp`]), so the persistent
/// containers' read paths — `PVec`, `BankedAdjacency`, the GBTL
/// algorithms — run over it unchanged.
pub struct ReaderManager {
    dir: PathBuf,
    chunk_size: usize,
    file_size: usize,
    segment: SegmentStorage,
    chunks: ChunkDirectory,
    names: NameDirectory,
    epoch: u64,
    lease: ReaderLease,
    stats: AttachStats,
    /// Attach/refresh latency histograms (no flight recorder: a reader
    /// must not write into the owner's store beyond its lease/sides).
    tel: Telemetry,
}

impl ReaderManager {
    /// Attach to the last committed epoch of the store at `dir`. Works
    /// on a live, owner-open store (no `CLEAN` marker required) and on
    /// a closed one alike; fails if the store has never committed a
    /// segmented-management epoch (a legacy or never-synced store must
    /// be synced by its writer once first).
    pub fn attach(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let t0 = Instant::now();
        let (chunk_size, file_size) = ManagerCore::read_meta(&dir)?;
        let nb = num_bins(chunk_size);
        // Lease first, at PIN_ALL: from this instant the owner's GC
        // deletes nothing epoch-like, closing the window between
        // choosing a manifest and recording the choice.
        let mut lease = ReaderLease::acquire(&dir)?;
        let (lm, epoch) = Self::load_pinned(&dir, nb)?;
        lease.pin(epoch)?;
        let opts = ManagerOptions { chunk_size, file_size, ..Default::default() };
        let segment = SegmentStorage::open(dir.join("segment"), opts.segment_options(true))?;
        let mut stats = AttachStats::default();
        Self::overlay_pinned(&dir, &segment, &lm.chunks, chunk_size, epoch, &mut stats)?;
        let mut r = Self {
            dir,
            chunk_size,
            file_size,
            segment,
            chunks: lm.chunks,
            names: lm.names,
            epoch,
            lease,
            stats,
            tel: Telemetry::new(ManagerOptions::default().telemetry_sample, 1),
        };
        r.validate()?;
        r.stats.staleness_epochs = r.staleness_epochs()?;
        r.stats.attach_micros = t0.elapsed().as_micros() as u64;
        // Attach is a rare op: always recorded (the serving tier's tail).
        r.tel.record_ns(TelOp::Attach, t0.elapsed().as_nanos() as u64);
        Ok(r)
    }

    /// Newest complete (all sections verify) manifest, parsed.
    fn load_pinned(dir: &Path, nb: usize) -> Result<(LoadedManagement, u64)> {
        let epochs = mgmt_io::list_manifest_epochs(dir)?;
        for &e in epochs.iter().rev() {
            let Some(man) = mgmt_io::read_manifest(dir, e) else { continue };
            if man.num_bins as usize != nb {
                continue;
            }
            let Some(secs) = mgmt_io::load_sections(dir, &man) else { continue };
            if let Some(mut lm) = ManagerCore::parse_sections(nb, &man, &secs) {
                lm.epoch = man.epoch;
                return Ok((lm, man.epoch));
            }
        }
        Err(Error::Datastore(format!(
            "no committed epoch to attach in {dir:?}: readers pin manifest epochs, \
             so a never-synced (or legacy-monolith) store must be synced by its \
             writer once before a reader can attach"
        )))
    }

    /// Resolve every live chunk of the pinned directory to an
    /// epoch-side copy and map it over the read-only segment. Copies
    /// the flusher (or an earlier reader) already produced are reused;
    /// missing ones are seeded from the live bytes and tagged with the
    /// pin.
    fn overlay_pinned(
        dir: &Path,
        segment: &SegmentStorage,
        chunks: &ChunkDirectory,
        chunk_size: usize,
        pin: u64,
        stats: &mut AttachStats,
    ) -> Result<()> {
        let sides = readers::index_sides(&readers::list_side_copies(dir));
        let mapped = segment.mapped_len();
        let mut overlaid = 0u64;
        for (id, kind) in chunks.iter() {
            if kind == ChunkKind::Free {
                continue;
            }
            let at = id as usize * chunk_size;
            if at + chunk_size > mapped {
                // a reservation committed past the mapped extent (the
                // owner heals these on its next open); nothing to read
                continue;
            }
            let side_epoch = match readers::resolve_side(&sides, id, pin) {
                Some(e) => {
                    stats.side_copies_reused += 1;
                    e
                }
                None => {
                    readers::write_side_copy(dir, segment, id, chunk_size, pin, false)?;
                    stats.side_copies_created += 1;
                    pin
                }
            };
            let path = readers::side_copy_path(dir, id, side_epoch);
            let f = std::fs::OpenOptions::new()
                .read(true)
                .open(&path)
                .map_err(|e| Error::io(&path, e))?;
            segment.overlay_readonly(at, &f, chunk_size)?;
            overlaid += 1;
        }
        stats.chunks_overlaid = overlaid;
        Ok(())
    }

    /// Re-pin to a newer committed epoch if one exists. Returns whether
    /// the view advanced. The lease sits at `PIN_ALL` for the duration
    /// of the transition, so GC can never collect either the old or the
    /// new epoch mid-move; on any failure the old pin is restored and
    /// the old view remains valid.
    pub fn refresh(&mut self) -> Result<bool> {
        let t0 = Instant::now();
        let r = self.refresh_inner();
        if matches!(r, Ok(true)) {
            self.tel.record_ns(TelOp::Refresh, t0.elapsed().as_nanos() as u64);
        }
        r
    }

    fn refresh_inner(&mut self) -> Result<bool> {
        let newest = mgmt_io::list_manifest_epochs(&self.dir)?.last().copied().unwrap_or(0);
        if newest <= self.epoch {
            self.stats.staleness_epochs = 0;
            return Ok(false);
        }
        self.lease.pin(readers::PIN_ALL)?;
        let nb = num_bins(self.chunk_size);
        let moved = (|| -> Result<Option<(LoadedManagement, u64, SegmentStorage)>> {
            let (lm, epoch) = Self::load_pinned(&self.dir, nb)?;
            if epoch <= self.epoch {
                // the newer manifest was torn/incomplete — stay put
                return Ok(None);
            }
            // Fresh read-only mapping (covers backing files added since
            // the last attach), then overlay the new pin on it. The old
            // mapping stays untouched until this succeeds.
            let opts = ManagerOptions {
                chunk_size: self.chunk_size,
                file_size: self.file_size,
                ..Default::default()
            };
            let segment =
                SegmentStorage::open(self.dir.join("segment"), opts.segment_options(true))?;
            let mut stats = self.stats;
            Self::overlay_pinned(
                &self.dir,
                &segment,
                &lm.chunks,
                self.chunk_size,
                epoch,
                &mut stats,
            )?;
            self.stats = stats;
            Ok(Some((lm, epoch, segment)))
        })();
        match moved {
            Ok(Some((lm, epoch, segment))) => {
                self.lease.pin(epoch)?;
                self.segment = segment;
                self.chunks = lm.chunks;
                self.names = lm.names;
                self.epoch = epoch;
                self.stats.refreshes += 1;
                self.stats.staleness_epochs = self.staleness_epochs()?;
                self.validate()?;
                Ok(true)
            }
            Ok(None) => {
                self.lease.pin(self.epoch)?;
                Ok(false)
            }
            Err(e) => {
                let _ = self.lease.pin(self.epoch);
                Err(e)
            }
        }
    }

    /// Committed epochs on disk ahead of the pin right now.
    pub fn staleness_epochs(&self) -> Result<u64> {
        let newest = mgmt_io::list_manifest_epochs(&self.dir)?.last().copied().unwrap_or(0);
        Ok(newest.saturating_sub(self.epoch))
    }

    /// Light integrity check of the pinned view: every named object
    /// must lie inside the mapped extent on non-free chunks.
    fn validate(&self) -> Result<()> {
        let mapped = self.segment.mapped_len() as u64;
        let cs = self.chunk_size as u64;
        for (name, e) in self.names.iter() {
            if e.offset + e.size > mapped {
                return Err(Error::Datastore(format!(
                    "pinned epoch {}: named object {name:?} exceeds mapped segment",
                    self.epoch
                )));
            }
            let chunk = (e.offset / cs) as u32;
            if self.chunks.kind(chunk) == ChunkKind::Free {
                return Err(Error::Datastore(format!(
                    "pinned epoch {}: named object {name:?} sits on a free chunk",
                    self.epoch
                )));
            }
        }
        Ok(())
    }

    // -------------------------------------------------- read-side API --

    /// The pinned epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn chunk_size(&self) -> usize {
        self.chunk_size
    }

    pub fn attach_stats(&self) -> AttachStats {
        self.stats
    }

    /// This reader's attach/refresh latency histograms.
    pub fn latency_snapshot(
        &self,
    ) -> Vec<(TelOp, crate::telemetry::histogram::HistogramSnapshot)> {
        self.tel.snapshot()
    }

    // plumbing for the `SegmentAlloc` impl (crate::alloc::api)
    pub(crate) fn segment_base(&self) -> *mut u8 {
        self.segment.base()
    }

    pub(crate) fn segment_mapped_len(&self) -> usize {
        self.segment.mapped_len()
    }

    /// Read a POD value at `offset` (the reader-side mirror of
    /// [`ManagerCore::read`]).
    pub fn read<T: Persist>(&self, offset: u64) -> T {
        debug_assert!(offset as usize + std::mem::size_of::<T>() <= self.segment.mapped_len());
        unsafe {
            std::ptr::read_unaligned(self.segment.base().add(offset as usize) as *const T)
        }
    }

    /// Find a named object in the pinned epoch (same type-fingerprint
    /// contract as [`ManagerCore::find`]).
    pub fn find<T: Persist>(&self, name: &str) -> Result<Option<u64>> {
        match self.names.get(name) {
            None => Ok(None),
            Some(e) => {
                if e.type_fp != type_fingerprint::<T>() {
                    return Err(Error::Name(format!(
                        "find: type mismatch for {name:?} (stored fingerprint differs)"
                    )));
                }
                Ok(Some(e.offset))
            }
        }
    }

    pub fn num_named(&self) -> usize {
        self.names.len()
    }

    pub fn named_list(&self) -> Vec<(String, u64, u64)> {
        self.names.iter().map(|(n, e)| (n.to_string(), e.offset, e.size)).collect()
    }

    /// Detach: release the lease (unpinning the epoch for the owner's
    /// GC) and unmap. Dropping does the same; this is the explicit,
    /// error-reporting spelling for symmetry with `close()`.
    pub fn detach(self) -> Result<()> {
        Ok(())
    }
}

impl ManagerCore {
    // ------------------------------------------------- core lifecycle --

    /// Take the inter-process store lock: exclusive for writers (a
    /// second `create`/`open`/`open_unclean` of a live store fails
    /// loudly instead of silently corrupting it), shared for read-only
    /// opens (they exclude writers but not each other, §3.6). The
    /// returned fd must be kept alive as long as the manager; dropping
    /// it — or the process dying — releases the lock. Live-attach
    /// readers ([`ReaderManager`]) deliberately do **not** take this
    /// lock: their lease is their registration, and the epoch protocol
    /// is what isolates them from the owner.
    fn lock_store(dir: &Path, exclusive: bool) -> Result<std::fs::File> {
        let path = dir.join(STORE_LOCK);
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(&path)
            .map_err(|e| Error::io(&path, e))?;
        if !readers::flock_try(&file, exclusive)? {
            return Err(Error::Datastore(format!(
                "datastore {dir:?} is locked by another process (the store lock is held \
                 {}; close the other manager first)",
                if exclusive { "and this open needs it exclusively" } else { "exclusively" }
            )));
        }
        Ok(file)
    }

    fn create_core(dir: PathBuf, opts: ManagerOptions) -> Result<Self> {
        if dir.join("meta.bin").exists() {
            return Err(Error::Datastore(format!("datastore already exists at {dir:?}")));
        }
        std::fs::create_dir_all(&dir).map_err(|e| Error::io(&dir, e))?;
        // single-writer exclusivity from the first byte: two concurrent
        // creates of the same directory race on this lock, not on files
        let store_lock = Self::lock_store(&dir, true)?;
        if dir.join("meta.bin").exists() {
            return Err(Error::Datastore(format!("datastore already exists at {dir:?}")));
        }
        if !opts.chunk_size.is_power_of_two() || opts.chunk_size < 4096 {
            return Err(Error::Config("chunk_size must be a power of two ≥ 4096".into()));
        }
        if opts.file_size % opts.chunk_size != 0 {
            return Err(Error::Config("file_size must be a multiple of chunk_size".into()));
        }
        Self::check_bg_sync_opts(&opts)?;
        let netfs = opts.resolved_netfs()?;
        let segment = SegmentStorage::create(dir.join("segment"), opts.segment_options(false))?;
        if let Some(fs) = &netfs {
            segment.set_netfs(fs.clone());
        }
        let nb = num_bins(opts.chunk_size);
        let topo = opts.resolved_topology();
        let nshards = opts.resolved_shards(&topo);
        let mgr = Self {
            bg: opts.sync_engine(false),
            shards: (0..nshards).map(|_| AllocShard::new(nb)).collect(),
            shard_map: ShardMap::with_topology(nshards, topo),
            cache: ObjectCache::new(nb),
            chunks: RwLock::new(ChunkDirectory::with_shards(nshards)),
            names: Mutex::new(NameDirectory::new()),
            bs: opts.private_mode.then(|| Mutex::new(BsMsync::new())),
            mgmt: Mutex::new(MgmtState {
                epoch: 0,
                sections: HashMap::new(),
                legacy: false,
                bins_per_group: mgmt_io::BINS_PER_GROUP,
                next_epoch: 1,
            }),
            dirty_data: DirtyChunkSet::new(segment.vm_len() / opts.chunk_size + 1),
            netfs,
            last_sync: Mutex::new(SyncStats::default()),
            wounded: OnceLock::new(),
            health: HealthCounters::default(),
            tel: Telemetry::with_recorder(opts.telemetry_sample, nshards, &dir, 1),
            oplog: Mutex::new(OpLogDram::absent()),
            oplog_counters: OpLogCounters::default(),
            oplog_validate_floor: AtomicU64::new(0),
            segment,
            read_only: false,
            stats: AllocStats::default(),
            closed: AtomicBool::new(false),
            opts,
            dir,
            _store_lock: store_lock,
        };
        mgr.write_meta()?;
        // store starts dirty; becomes clean on close()
        Ok(mgr)
    }

    /// Background triggers flush with **no caller** on the mutation
    /// path, but the private-mode user-level msync
    /// ([`crate::storage::bsmmap::BsMsync`]) reads, pwrites, and remaps
    /// pages under a quiescent-writers contract — a background flush
    /// racing live stores could remap a page back to stale file bytes
    /// and silently lose them. Refuse the combination loudly; explicit
    /// `sync()` keeps working under the §3.3 quiescence contract.
    fn check_bg_sync_opts(opts: &ManagerOptions) -> Result<()> {
        let triggers = opts.sync_watermark_bytes > 0
            || opts.sync_interval_ms > 0
            || opts.sync_ceiling_bytes > 0;
        if opts.private_mode && triggers {
            return Err(Error::Config(
                "background sync triggers (watermark/interval/ceiling) are incompatible \
                 with private (bs-mmap) mode: the user-level msync requires quiescent \
                 writers (§5); call sync() explicitly instead"
                    .into(),
            ));
        }
        Ok(())
    }

    fn open_core(
        dir: PathBuf,
        mut opts: ManagerOptions,
        read_only: bool,
        allow_unclean: bool,
    ) -> Result<Self> {
        if !read_only {
            Self::check_bg_sync_opts(&opts)?;
        }
        let (chunk_size, file_size) = Self::read_meta(&dir)?;
        opts.chunk_size = chunk_size;
        opts.file_size = file_size;
        // lock before the CLEAN check: "someone else holds the store"
        // is the actionable diagnosis when both would fire (a live owner
        // implies no CLEAN marker)
        let store_lock = Self::lock_store(&dir, !read_only)?;
        let clean = dir.join(CLEAN_MARKER).exists();
        if !clean && !allow_unclean {
            return Err(Error::Datastore(format!(
                "datastore {dir:?} was not closed cleanly; reattach a snapshot \
                 or use open_unclean() after duplicating it (paper §3.3)"
            )));
        }
        let netfs = opts.resolved_netfs()?;
        let segment = SegmentStorage::open(dir.join("segment"), opts.segment_options(read_only))?;
        if let Some(fs) = &netfs {
            segment.set_netfs(fs.clone());
        }
        let nb = num_bins(opts.chunk_size);
        let mut lm = Self::load_management(&dir, nb)?;
        // Parked-free recovery: slots the manifest's transient cache
        // section recorded as sitting in per-core caches / remote queues
        // are claimed in the serialized bitsets but actually free —
        // return them before the shard split so a crash between syncs
        // leaks nothing. Chunks that empty are released like any
        // serialization-point free (file space reclaimed below, once the
        // segment handle exists).
        let cs = opts.chunk_size as u64;
        let mut touched_bins: HashSet<usize> = HashSet::new();
        let mut freed_chunks: Vec<u32> = Vec::new();
        for &(bin, off) in &lm.cache {
            let chunk = (off / cs) as u32;
            if bin as usize >= nb || (chunk as usize) >= lm.chunks.len() {
                continue;
            }
            if lm.chunks.kind(chunk) != (ChunkKind::Small { bin }) {
                continue;
            }
            let class = size_of_bin(bin as usize) as u64;
            if (off % cs) % class != 0 {
                continue;
            }
            let slot = ((off % cs) / class) as u32;
            if let Some(empty) = lm.bins[bin as usize].release_cached(chunk, slot) {
                touched_bins.insert(bin as usize);
                if empty {
                    lm.bins[bin as usize].remove_chunk(chunk);
                    lm.chunks.free_small_chunk(chunk);
                    freed_chunks.push(chunk);
                }
            }
        }
        // Heal orphan large reservations: `allocate_large` reserves its
        // run under the chunk lock but performs the segment extension
        // (ftruncate) outside it, and a background epoch can durably
        // commit the reservation inside that window. If the process then
        // died before the extension, the recovered directory records a
        // LargeHead run past the mapped extent that no caller can hold
        // an offset to — roll it back to Free (the next sync persists
        // the heal; the chunk directory marks itself).
        let mapped_chunks = segment.mapped_len() / opts.chunk_size;
        let orphan_heads: Vec<u32> = lm
            .chunks
            .iter()
            .filter_map(|(id, kind)| match kind {
                ChunkKind::LargeHead { nchunks }
                    if id as usize + nchunks as usize > mapped_chunks =>
                {
                    Some(id)
                }
                _ => None,
            })
            .collect();
        for head in orphan_heads {
            lm.chunks.free_large(head);
        }
        // Rebuild the DRAM-only shard state: ownership is re-dealt
        // deterministically (`chunk % nshards`), so any shard count — and
        // any topology — reopens any store.
        let topo = opts.resolved_topology();
        let nshards = opts.resolved_shards(&topo);
        lm.chunks.set_shards(nshards);
        let shard_map = ShardMap::with_topology(nshards, topo);
        let shards: Vec<AllocShard> = (0..nshards).map(|_| AllocShard::new(nb)).collect();
        for (bin, data) in lm.bins.into_iter().enumerate() {
            for (chunk, bs) in data.into_chunks() {
                let s = shard_map.recovery_shard_of_chunk(chunk);
                shards[s].bins[bin].write().unwrap().insert_chunk(chunk, bs);
            }
        }
        let mgr = Self {
            bg: opts.sync_engine(read_only),
            shards,
            shard_map,
            cache: ObjectCache::new(nb),
            chunks: RwLock::new(lm.chunks),
            names: Mutex::new(lm.names),
            bs: (opts.private_mode && !read_only).then(|| Mutex::new(BsMsync::new())),
            mgmt: Mutex::new(MgmtState {
                epoch: lm.epoch,
                sections: lm.sections,
                legacy: lm.legacy,
                bins_per_group: lm.bins_per_group,
                next_epoch: lm.epoch + 1,
            }),
            dirty_data: DirtyChunkSet::new(segment.vm_len() / opts.chunk_size + 1),
            netfs,
            last_sync: Mutex::new(SyncStats::default()),
            wounded: OnceLock::new(),
            health: HealthCounters::default(),
            // Read-only opens must not write into the store: histograms
            // only, no flight ring.
            tel: if read_only {
                Telemetry::new(opts.telemetry_sample, nshards)
            } else {
                Telemetry::with_recorder(opts.telemetry_sample, nshards, &dir, 1)
            },
            oplog: Mutex::new(OpLogDram::absent()),
            oplog_counters: OpLogCounters::default(),
            oplog_validate_floor: AtomicU64::new(0),
            segment,
            read_only,
            stats: AllocStats::default(),
            closed: AtomicBool::new(false),
            opts,
            dir,
            _store_lock: store_lock,
        };
        // The recovery frees above diverged the DRAM state from the
        // on-disk sections: re-mark so the next sync persists them. (The
        // chunk directory marked itself inside free_small_chunk.)
        for bin in touched_bins {
            mgr.shards[0].mark_bin_dirty(bin);
        }
        if !lm.cache.is_empty() {
            // the running cache is empty now; the next sync must replace
            // the non-empty on-disk cache section
            mgr.cache.mark_dirty();
        }
        if !read_only {
            let cs = mgr.opts.chunk_size;
            let mapped = mgr.segment.mapped_len();
            let mut result = Ok(());
            for chunk in freed_chunks {
                if (chunk as usize + 1) * cs <= mapped {
                    keep_first_err(
                        &mut result,
                        mgr.segment.free_range(chunk as usize * cs, cs),
                    );
                }
            }
            result?;
        }
        mgr.validate_consistency()?;
        // Container op-log: rediscover the ring (sequence horizons, the
        // validate floor), then — on an unclean read-write open — replay
        // the newest epoch's tail: keep committed records (re-adopting
        // extents the recovered management state predates), roll unsealed
        // ones forward or back. A clean open replays nothing.
        mgr.load_oplog(clean);
        if !read_only && !clean {
            mgr.recover_containers()?;
        }
        if !read_only {
            // Mark dirty while we hold it read-write — durably: the
            // unlink is the other half of the CLEAN protocol. If it were
            // left sitting in the directory's dirty metadata, a power
            // failure after unsynced data writes could resurrect the
            // marker and a torn store would reopen as "clean".
            let p = mgr.dir.join(CLEAN_MARKER);
            match std::fs::remove_file(&p) {
                Ok(()) => mgmt_io::fsync_dir(&mgr.dir)?,
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => return Err(Error::io(&p, e)),
            }
            // A fresh read-write epoch starts healthy: clear any advisory
            // WOUNDED breadcrumb a previous degraded run left behind
            // (best-effort — it is advisory, recovery never trusts it).
            let _ = std::fs::remove_file(mgr.dir.join(WOUNDED_MARKER));
        }
        Ok(mgr)
    }

    fn write_meta(&self) -> Result<()> {
        let mut buf = Vec::new();
        buf.extend_from_slice(META_MAGIC);
        buf.extend_from_slice(&(self.opts.chunk_size as u64).to_le_bytes());
        buf.extend_from_slice(&(self.opts.file_size as u64).to_le_bytes());
        // durable: geometry is written exactly once, at create
        mgmt_io::write_section_file(&self.dir, "meta.bin", &buf)?;
        mgmt_io::fsync_dir(&self.dir)
    }

    fn read_meta(dir: &Path) -> Result<(usize, usize)> {
        let p = dir.join("meta.bin");
        let buf = std::fs::read(&p).map_err(|e| Error::io(&p, e))?;
        if buf.len() != 24 || &buf[0..8] != META_MAGIC {
            return Err(Error::Datastore(format!("bad meta.bin in {dir:?}")));
        }
        let cs = u64::from_le_bytes(buf[8..16].try_into().unwrap()) as usize;
        let fs = u64::from_le_bytes(buf[16..24].try_into().unwrap()) as usize;
        Ok((cs, fs))
    }

    /// Flush application data and management data to the backing store
    /// (the paper's snapshot-consistency point, §3.3) — **incrementally**:
    /// cost is proportional to what changed since the last sync, not to
    /// the store.
    ///
    /// 1. Cross-shard frees parked on remote queues are drained (the
    ///    owners' serialization-point work this sync is anyway).
    /// 2. Application data: only the union of chunk ranges written since
    ///    the last sync is `msync`'d, in parallel
    ///    ([`SegmentStorage::sync_ranges`]); private (bs-mmap) mode keeps
    ///    its own page-granular delta flush.
    /// 3. Management: only dirty sections are re-serialized (a flusher
    ///    pool writes them concurrently) and a new manifest is committed
    ///    by fsync'd atomic rename. Nothing dirty → nothing written.
    ///
    /// The per-core object caches are **preserved** — their free slots are
    /// recorded in the transient cache section instead of being drained,
    /// so sync costs no allocation warmth ([`Self::flush_object_caches`]
    /// is the explicit full drain). Like the monolithic format before it,
    /// the serialized image is a consistent point only when mutators are
    /// quiescent (§3.3's contract).
    ///
    /// The flush itself runs on the background engine's flusher thread:
    /// this call is exactly [`Self::sync_async`] + [`SyncTicket::wait`],
    /// returning after the covering epoch's manifest is durably
    /// committed — the durability semantics of the old inline sync,
    /// with concurrent callers coalescing onto one flush.
    pub fn sync(&self) -> Result<()> {
        if self.read_only {
            return Ok(());
        }
        self.sync_async()?.wait()
    }

    /// Request an asynchronous flush of everything dirty *now* and
    /// return a [`SyncTicket`] for its epoch; the flush runs on the
    /// background flusher thread while this caller keeps working.
    /// `wait()` blocks until the covering manifest is durably committed.
    /// Read-only stores return an already-complete ticket.
    pub fn sync_async(&self) -> Result<SyncTicket<'_>> {
        if self.read_only {
            return Ok(SyncTicket::completed());
        }
        if let Some(reason) = self.wounded.get() {
            return Err(Error::Degraded(reason.clone()));
        }
        let gen = self.bg.request()?;
        Ok(SyncTicket::pending(&self.bg, gen))
    }

    /// The background engine (flusher-thread internals; crate-private).
    pub(crate) fn engine(&self) -> &SyncEngine {
        &self.bg
    }

    /// The simulated-backend account, when a
    /// [`ManagerOptions::netfs_profile`] is active: charged ops/bytes and
    /// modelled seconds for every sync-path write this manager performed.
    pub fn netfs(&self) -> Option<&SimNetFs> {
        self.netfs.as_deref()
    }

    /// Observability snapshot of the background sync engine (triggers,
    /// flush counts, writer stalls). Exported as `alloc.bgsync.*` by
    /// [`crate::coordinator::metrics::record_bg_sync_stats`].
    pub fn bg_sync_stats(&self) -> BgSyncStats {
        self.bg.stats()
    }

    // --------------------------------------------- wounded / degraded --

    /// Flip the manager into **degraded read-only** after a permanent
    /// backend failure (or too many consecutive transient ones — the
    /// engine's call, see [`SyncEngine`]'s classification). First caller
    /// wins; repeat wounds are no-ops. Ordering matters:
    ///
    /// 1. The reason is published (`OnceLock::set`) so every mutating
    ///    API ([`Self::check_writable`], [`Self::sync_async`]) starts
    ///    returning [`Error::Degraded`] immediately.
    /// 2. A best-effort advisory `WOUNDED` breadcrumb is dropped in the
    ///    store directory for `metall doctor` — written with a *plain*
    ///    `fs::write`, deliberately outside the fault-injection sites:
    ///    when the backend is the thing that failed, the breadcrumb is
    ///    allowed to fail too.
    /// 3. The background engine is parked: in-flight tickets resolve
    ///    with the wound as their attribution, the flusher and committer
    ///    drain what they hold and exit.
    ///
    /// Reads are untouched — the mapped segment and the last committed
    /// manifest stay valid, and live [`readers::ReaderManager`] attaches
    /// keep serving the last committed epoch.
    pub(crate) fn wound(&self, reason: String) {
        if self.wounded.set(reason.clone()).is_err() {
            return; // already wounded; first reason stands
        }
        let _ = std::fs::write(self.dir.join(WOUNDED_MARKER), reason.as_bytes());
        // The wound may be this process's last interesting act: record
        // it and make the whole flight ring durable for the post-mortem
        // (`metall trace` / `doctor`).
        self.tel.event(
            EventKind::Wound,
            0,
            self.health.transient_failures.load(Ordering::Relaxed),
            0,
            0,
        );
        self.tel.flush_recorder();
        self.bg.park(format!("manager wounded (degraded read-only): {reason}"));
    }

    /// Engine-side failure bookkeeping (one failed flush/commit round).
    pub(crate) fn count_flush_failure(&self, class: FaultClass) {
        let prior = match class {
            FaultClass::Transient => {
                self.health.transient_failures.fetch_add(1, Ordering::Relaxed)
            }
            FaultClass::Permanent => {
                self.health.permanent_failures.fetch_add(1, Ordering::Relaxed)
            }
        };
        self.tel.event(
            EventKind::FlushFailure,
            match class {
                FaultClass::Transient => 0,
                FaultClass::Permanent => 1,
            },
            prior + 1,
            0,
            0,
        );
    }

    /// Has a backend failure flipped this manager to degraded read-only?
    pub fn is_degraded(&self) -> bool {
        self.wounded.get().is_some()
    }

    /// The originating failure when degraded.
    pub fn degraded_reason(&self) -> Option<String> {
        self.wounded.get().cloned()
    }

    /// Failure-health snapshot: classified flush failures, allocation
    /// rollbacks, and the degraded flag. Exported as `alloc.faults.*` /
    /// `alloc.health.degraded` by
    /// [`crate::coordinator::metrics::record_health_stats`].
    pub fn health_stats(&self) -> HealthStats {
        HealthStats {
            transient_failures: self.health.transient_failures.load(Ordering::Relaxed),
            permanent_failures: self.health.permanent_failures.load(Ordering::Relaxed),
            extend_rollbacks: self.health.extend_rollbacks.load(Ordering::Relaxed),
            degraded: self.is_degraded(),
            degraded_reason: self.degraded_reason(),
        }
    }

    /// Estimated un-synced application-data bytes (the watermark input):
    /// marked dirty chunks × chunk size.
    pub(crate) fn dirty_data_bytes(&self) -> u64 {
        self.dirty_data.dirty_chunks() * self.opts.chunk_size as u64
    }

    /// Is anything — data, management sections, or parked remote frees —
    /// dirty? The interval trigger's probe (never on the hot path).
    pub(crate) fn anything_dirty(&self) -> bool {
        let nb = self.num_bins();
        self.dirty_data.dirty_chunks() > 0
            || self.probe_any_section_dirty(nb, mgmt_io::num_groups(nb))
            || self.shards.iter().any(|s| !s.remote_free.lock().unwrap().is_empty())
    }

    /// One complete inline flush — a prepared cut committed on this
    /// thread: the serial path, run by `close()` after the engine is
    /// drained and joined (and by tests). Holds the flush gate
    /// exclusively so `snapshot()`/`doctor()` never observe a
    /// half-committed epoch and no pipelined prepare/commit overlaps it.
    pub(crate) fn sync_now(&self) -> Result<()> {
        if self.read_only {
            return Ok(());
        }
        if let Some(reason) = self.wounded.get() {
            return Err(Error::Degraded(reason.clone()));
        }
        let _gate = self.bg.gate();
        match self.prepare_epoch()? {
            Some(prep) => self.commit_epoch(&prep),
            None => {
                self.record_noop_sync();
                Ok(())
            }
        }
    }

    /// Stage 1 of a flush — the **consistent cut**: drain parked remote
    /// frees, take the dirty data chunks out of the chunk map, serialize
    /// every dirty management section to memory under one simultaneous
    /// lock acquisition, assign the cut its epoch, and freeze epoch-side
    /// copies for pinned readers. Returns `None` when nothing at all is
    /// dirty (the caller records a no-op sync).
    ///
    /// The cut takes no durable action besides the side-copy freeze: the
    /// pipelined engine may run this for epoch N+1 while epoch N's
    /// [`Self::commit_epoch`] is still doing I/O. Mutators may be running
    /// concurrently (the flusher thread's whole purpose), so per-section
    /// lock scopes are NOT enough: a fresh chunk registering between two
    /// section serializations would commit a bin that references a chunk
    /// the chunk section still calls Free — hence the simultaneous-lock
    /// serialization in [`Self::serialize_sections_cut`].
    pub(crate) fn prepare_epoch(&self) -> Result<Option<PreparedEpoch>> {
        let t0 = Instant::now();
        let r = self.prepare_epoch_inner();
        if let Ok(Some(prep)) = &r {
            self.tel.record_ns(TelOp::EpochCut, t0.elapsed().as_nanos() as u64);
            let data_bytes: usize = prep.ranges.iter().map(|rg| rg.len()).sum();
            self.tel.event(
                EventKind::EpochPrepared,
                0,
                prep.epoch,
                data_bytes as u64,
                prep.ids.len() as u64,
            );
        }
        r
    }

    fn prepare_epoch_inner(&self) -> Result<Option<PreparedEpoch>> {
        if self.read_only {
            return Ok(None);
        }
        let mut result = Ok(());
        for shard in 0..self.shards.len() {
            keep_first_err(&mut result, self.drain_remote(shard));
        }
        result?;
        let cs = self.opts.chunk_size;
        // --- op-log cut stamp ---
        // Stamp the log's cut table with (this cut's epoch, the decided-
        // record horizon) BEFORE the data cut, so the stamp's bytes ride
        // this very epoch's flush. Direct `dirty_data.mark`, never
        // `mark_data_dirty`: the flusher must not run its own watermark
        // kick / backpressure stall. An unchanged horizon is not
        // re-stamped (its mark would re-dirty the chunk every epoch —
        // a flush that never goes idle); recovery then falls back to the
        // newest older entry, which carries the same horizon.
        let cut_seq = {
            let mut lg = self.oplog.lock().unwrap();
            if lg.log_off == oplog::NONE {
                0
            } else {
                let horizon = lg.cut_horizon();
                if horizon != lg.last_cut_seq {
                    let epoch = self.mgmt.lock().unwrap().next_epoch;
                    let bytes = oplog::CutEntry { epoch, cut_seq: horizon }.to_bytes();
                    let at = oplog::cut_entry_off(lg.log_off, epoch);
                    unsafe {
                        std::ptr::copy_nonoverlapping(bytes.as_ptr(), self.ptr(at), bytes.len());
                    }
                    for c in at / cs as u64..=(at + bytes.len() as u64 - 1) / cs as u64 {
                        self.dirty_data.mark(c as usize);
                    }
                    lg.last_cut_seq = horizon;
                }
                horizon
            }
        };
        // --- data cut ---
        let mut data_flushed = None;
        let mut data_chunks: Vec<usize> = Vec::new();
        let mut ranges: Vec<std::ops::Range<usize>> = Vec::new();
        if let Some(bs) = &self.bs {
            // Private (bs-mmap) mode flushes page-granularly *at cut
            // time*: its user-level msync requires quiescent writers
            // (§5), a contract the explicit-sync caller provides right
            // now — deferring it to the committer would break it.
            let st = bs.lock().unwrap().msync(&self.segment)?;
            self.dirty_data.clear_to(self.segment.mapped_len().div_ceil(cs));
            if st.dirty_pages > 0 {
                data_flushed = Some((st.dirty_pages as u64, st.bytes_written));
            }
        } else {
            let mapped = self.segment.mapped_len();
            data_chunks = self.dirty_data.take_dirty(mapped.div_ceil(cs));
            // coalesce adjacent chunks into ranges (indices ascending)
            for &c in &data_chunks {
                let start = c * cs;
                let end = ((c + 1) * cs).min(mapped);
                match ranges.last_mut() {
                    Some(r) if r.end == start => r.end = end,
                    _ => ranges.push(start..end),
                }
            }
        }
        // --- management cut ---
        let nb = self.num_bins();
        let ngroups = mgmt_io::num_groups(nb);
        let total = (ngroups + 3) as u64; // chunks + groups + names + cache
        // Rewrite everything when there is no committed segmented state
        // (fresh store, legacy monolith) or when the loaded manifest used
        // a different bin-group width than this build — carrying its bin
        // sections forward under the new partition would corrupt the
        // chain. `next_epoch` is read (and bumped, if this cut commits a
        // manifest) under the mgmt lock; cuts themselves are serialized
        // by the engine (one flusher thread; `sync_now` holds the
        // exclusive gate), so the read-bump pair cannot race another cut.
        let (first, epoch) = {
            let st = self.mgmt.lock().unwrap();
            let first = st.legacy
                || st.sections.is_empty()
                || st.bins_per_group != mgmt_io::BINS_PER_GROUP;
            (first, st.next_epoch)
        };
        let (ids, buffers, cache_slots) =
            if !first && !self.probe_any_section_dirty(nb, ngroups) {
                // No dirty sections — decided by an unlocked probe. Sound
                // for ticket coverage: every mutation preceding the
                // covering request is visible here (the request handshake
                // synchronizes), and a mutation racing the probe simply
                // belongs to the next epoch.
                (Vec::new(), Vec::new(), self.cache.len() as u64)
            } else {
                let tser = Instant::now();
                let out = self.serialize_sections_cut(first);
                self.tel.record_ns(TelOp::EpochSerialize, tser.elapsed().as_nanos() as u64);
                out
            };
        if !ids.is_empty() {
            self.mgmt.lock().unwrap().next_epoch = epoch + 1;
        }
        if ids.is_empty() && ranges.is_empty() && data_flushed.is_none() {
            return Ok(None);
        }
        // Epoch-side preservation for attached readers: before the
        // committer's in-place msync may tear a pinned epoch's view,
        // freeze each dirty chunk as a side copy tagged with the epoch
        // this cut will commit (reflink where the fs supports it; see
        // `alloc/readers`). The scan also reaps leases of dead readers.
        if !data_chunks.is_empty() {
            let pins = readers::scan_pins(&self.dir);
            if pins.reaped > 0 {
                self.tel.event(EventKind::LeaseReap, 0, pins.reaped as u64, 0, 0);
            }
            if pins.any_live() {
                if let Err(e) =
                    readers::preserve_chunks(&self.dir, &self.segment, &data_chunks, cs, epoch)
                {
                    for &c in &data_chunks {
                        self.dirty_data.mark(c);
                    }
                    self.remark_dirty(&ids);
                    return Err(e);
                }
            }
        }
        Ok(Some(PreparedEpoch {
            epoch,
            gen: 0,
            ranges,
            data_chunks,
            data_flushed,
            ids,
            buffers,
            rewrite_all: first,
            cache_slots,
            total_sections: total,
            cut_seq,
        }))
    }

    /// Stage 2 of a flush — make one prepared cut **durable**: msync its
    /// data ranges, write its section files, commit its manifest by
    /// fsync'd atomic rename, GC superseded files, and advance the
    /// committed epoch. Runs on the committer thread under the pipelined
    /// engine (strictly in epoch order — see the monotonicity check) or
    /// inline via [`Self::sync_now`]. Any failure aborts the cut
    /// ([`Self::abort_epoch`]) so the next cut retries its changes.
    pub(crate) fn commit_epoch(&self, prep: &PreparedEpoch) -> Result<()> {
        let r = self.commit_epoch_inner(prep);
        match &r {
            Ok(()) => {
                let data_bytes = self.last_sync.lock().unwrap().data_bytes_flushed;
                self.tel
                    .event(EventKind::EpochCommitted, 0, prep.epoch, data_bytes, 0);
            }
            Err(_) => {
                // abort_epoch already restored the dirty flags
                self.tel.event(EventKind::EpochAborted, 0, prep.epoch, 0, 0);
            }
        }
        r
    }

    fn commit_epoch_inner(&self, prep: &PreparedEpoch) -> Result<()> {
        let t0 = Instant::now();
        let net = self.netfs.as_deref();
        let sim0 = net.map(|fs| fs.sim_seconds()).unwrap_or(0.0);
        // --- data flush ---
        let tdata = Instant::now();
        let (data_chunks_n, data_bytes) = if let Some((g, b)) = prep.data_flushed {
            (g, b)
        } else if prep.ranges.is_empty() {
            (0, 0)
        } else {
            if let Err(e) = self.segment.sync_ranges(&prep.ranges, self.opts.parallel_sync) {
                // nothing was committed; re-mark so the next cut retries
                self.abort_epoch(prep);
                return Err(e);
            }
            let bytes: usize = prep.ranges.iter().map(|r| r.len()).sum();
            (prep.data_chunks.len() as u64, bytes as u64)
        };
        let data_secs = tdata.elapsed().as_secs_f64();
        let sim_after_data = net.map(|fs| fs.sim_seconds()).unwrap_or(0.0);
        // --- section writes + manifest commit ---
        let tcommit = Instant::now();
        let n = prep.ids.len();
        let mut section_bytes = 0u64;
        let mut committed = false;
        if n > 0 {
            let epoch = prep.epoch;
            {
                // The ordering invariant the pipeline rests on: manifests
                // land strictly monotonically. The committer drains its
                // queue FIFO in cut order, so this cannot fire; if it
                // ever does, refusing the commit keeps the manifest chain
                // sound (a newer manifest never references state older
                // than its predecessor's).
                let st = self.mgmt.lock().unwrap();
                if epoch <= st.epoch {
                    drop(st);
                    self.abort_epoch(prep);
                    return Err(Error::BgSync(format!(
                        "manifest commit order violation: epoch {epoch} after {}",
                        self.mgmt.lock().unwrap().epoch
                    )));
                }
            }
            // Durable section writes on the shared flusher pool
            // ([`crate::util::parallel_jobs`]; a single dirty section —
            // the common incremental shape — runs inline on this thread).
            let outcomes = crate::util::parallel_jobs(n, |i| -> Result<SectionRecord> {
                let id = prep.ids[i];
                let name = id.file_name(epoch);
                mgmt_io::write_section_file_charged(&self.dir, &name, &prep.buffers[i], net)?;
                Ok(SectionRecord {
                    id,
                    file: name,
                    len: prep.buffers[i].len() as u64,
                    checksum: mgmt_io::fnv1a(&prep.buffers[i]),
                })
            });
            let mut recs = Vec::with_capacity(n);
            let mut failure: Option<Error> = None;
            for outcome in outcomes {
                match outcome {
                    Ok(rec) => {
                        section_bytes += rec.len;
                        recs.push(rec);
                    }
                    Err(e) => {
                        if failure.is_none() {
                            failure = Some(e);
                        }
                    }
                }
            }
            if let Some(e) = failure {
                self.abort_epoch(prep);
                return Err(e);
            }
            // The manifest is built *at commit time*, in commit order:
            // clean sections are carried forward from the committed state
            // as of this instant — for a pipelined epoch N+1 that is
            // epoch N's just-landed state, so its manifest never
            // references files N's failure would have orphaned. (On a
            // full `rewrite_all` cut nothing old survives — stale bin
            // groups from a different grouping width must not be
            // referenced.)
            let nb = self.num_bins();
            let (mut sections, prev) = {
                let st = self.mgmt.lock().unwrap();
                let sections =
                    if prep.rewrite_all { HashMap::new() } else { st.sections.clone() };
                // keep the predecessor manifest as the torn-sync fallback
                let prev = (!prep.rewrite_all && st.epoch > 0).then(|| Manifest {
                    epoch: st.epoch,
                    num_bins: nb as u32,
                    bins_per_group: mgmt_io::BINS_PER_GROUP as u32,
                    sections: st.sections.values().cloned().collect(),
                });
                (sections, prev)
            };
            for rec in recs {
                sections.insert(rec.id, rec);
            }
            let mut list: Vec<SectionRecord> = sections.values().cloned().collect();
            list.sort_by_key(|r| r.id);
            let manifest = Manifest {
                epoch,
                num_bins: nb as u32,
                bins_per_group: mgmt_io::BINS_PER_GROUP as u32,
                sections: list,
            };
            let tman = Instant::now();
            if let Err(e) = mgmt_io::commit_manifest_charged(&self.dir, &manifest, net) {
                self.abort_epoch(prep);
                return Err(e);
            }
            self.tel.record_ns(TelOp::EpochManifest, tman.elapsed().as_nanos() as u64);
            {
                let mut st = self.mgmt.lock().unwrap();
                st.epoch = epoch;
                st.sections = sections;
                st.legacy = false;
                st.bins_per_group = mgmt_io::BINS_PER_GROUP;
            }
            // GC the superseded files (and the legacy monolith), keeping
            // the new manifest and its fallback predecessor
            let mut keep: Vec<&Manifest> = vec![&manifest];
            if let Some(p) = prev.as_ref() {
                keep.push(p);
            }
            mgmt_io::gc(&self.dir, &keep);
            committed = true;
        }
        // The op-log reclaim horizon advances only on a *manifest*
        // commit: a data-only epoch leaves the committed management
        // state where it was, and recovery onto that older state still
        // needs every record at or above its (older) cut entry — their
        // extents are what `recover_containers` re-adopts.
        if committed && prep.cut_seq > 0 {
            let mut lg = self.oplog.lock().unwrap();
            if prep.cut_seq > lg.safe_seq {
                lg.safe_seq = prep.cut_seq;
            }
        }
        // --- stats + the adaptive-watermark sample ---
        let sim_delta = net.map(|fs| fs.sim_seconds() - sim0).unwrap_or(0.0).max(0.0);
        let unslept = sim_delta * (1.0 - net.map(|fs| fs.sleep_scale).unwrap_or(0.0)).max(0.0);
        let flush_micros = (t0.elapsed().as_secs_f64() + unslept) * 1e6;
        {
            let mut st = self.last_sync.lock().unwrap();
            *st = SyncStats {
                syncs: st.syncs + 1,
                manifest_commits: st.manifest_commits + committed as u64,
                dirty_sections: n as u64,
                total_sections: prep.total_sections,
                section_bytes_written: section_bytes,
                data_chunks_flushed: data_chunks_n,
                data_bytes_flushed: data_bytes,
                flush_micros: flush_micros as u64,
                sim_flush_micros: (sim_delta * 1e6) as u64,
                cache_slots_preserved: prep.cache_slots,
            };
        }
        // Bandwidth sample for the adaptive watermark: effective
        // bandwidth over the *data* portion of the flush with the fixed
        // per-flush round-trip delay removed, plus that delay itself.
        // Under a netfs profile the delay is the modelled op round trip
        // of the range flush (the bandwidth-independent term of the cost
        // model); locally it is the measured section+manifest commit
        // time (the per-epoch cost a bigger batch amortizes).
        if data_bytes > 0 && !prep.ranges.is_empty() {
            // Under a profile the modelled backend *replaces* the local
            // device in the cost model, so the sample is the simulated
            // time (mixing in the local msync wall time would double-
            // count the transfer); locally it is the measured wall time.
            let sim_data = (sim_after_data - sim0).max(0.0);
            let data_io_secs = if net.is_some() { sim_data } else { data_secs };
            let delay_secs = match net {
                Some(fs) => {
                    let p = &fs.profile;
                    let streams = if self.opts.parallel_sync { prep.ranges.len() } else { 1 };
                    let eff = streams.clamp(1, p.concurrency) as f64;
                    prep.ranges.len() as f64 * p.op_latency / eff
                }
                None => tcommit.elapsed().as_secs_f64(),
            };
            self.bg.record_flush_sample(data_bytes, data_io_secs, delay_secs);
        }
        self.tel.record_ns(TelOp::EpochCommit, t0.elapsed().as_nanos() as u64);
        Ok(())
    }

    /// Undo a prepared cut that failed to commit (or was abandoned when
    /// an earlier queued epoch failed): re-mark its data chunks and its
    /// sections' dirty flags so the next cut retries every change. The
    /// epoch number is simply skipped — recovery and GC tolerate gaps.
    pub(crate) fn abort_epoch(&self, prep: &PreparedEpoch) {
        for &c in &prep.data_chunks {
            self.dirty_data.mark(c);
        }
        self.remark_dirty(&prep.ids);
    }

    /// Record a sync invocation that found nothing dirty: counters move,
    /// nothing is written, no manifest commits.
    pub(crate) fn record_noop_sync(&self) {
        let nb = self.num_bins();
        let total = (mgmt_io::num_groups(nb) + 3) as u64;
        let mut st = self.last_sync.lock().unwrap();
        *st = SyncStats {
            syncs: st.syncs + 1,
            manifest_commits: st.manifest_commits,
            dirty_sections: 0,
            total_sections: total,
            section_bytes_written: 0,
            data_chunks_flushed: 0,
            data_bytes_flushed: 0,
            flush_micros: 0,
            sim_flush_micros: 0,
            cache_slots_preserved: self.cache.len() as u64,
        };
    }

    /// Unlocked fast probe for the no-op path: is any section dirty?
    fn probe_any_section_dirty(&self, nb: usize, ngroups: usize) -> bool {
        if self.chunks.read().unwrap().is_dirty() {
            return true;
        }
        for g in 0..ngroups {
            if mgmt_io::group_bins(g, nb).any(|b| self.shards.iter().any(|s| s.peek_bin_dirty(b)))
            {
                return true;
            }
        }
        self.names.lock().unwrap().is_dirty() || self.cache.peek_dirty()
    }

    /// The background engine's **consistent cut**: serialize every dirty
    /// section into a memory buffer under one simultaneous lock
    /// acquisition, so the committed epoch is the management state of a
    /// single instant even while mutators run.
    ///
    /// The lock set is kept minimal: the exclusive side of every bin in
    /// a *dirty* group — ascending (bin, shard), the allocator's own
    /// bin → chunks order, so no serialization point can deadlock
    /// against the cut — then **always** the chunk directory's write
    /// side (every structural mutation passes through it, so holding it
    /// pins the chunk↔bin structure even for unlocked clean groups),
    /// then names, with cache/remote-queue leaf locks taken inside.
    /// Because an in-flight serialization point marks its bin *before*
    /// registering its chunk (mark-first discipline in `allocate`), a
    /// re-probe of the bin flags under the chunk lock sees every group
    /// whose structure may already be in the chunk directory; when that
    /// grows the candidate set the cut releases and retries with the
    /// larger one (monotone, so it converges). Allocations in clean
    /// groups, per-core cache hits, and data writes keep flowing
    /// throughout, and the stall covers only the in-memory snapshot,
    /// never file I/O. `rewrite_all` forces every section (fresh store,
    /// legacy conversion, bin-group-width change).
    ///
    /// Returns `(dirty ids ascending, serialized images, cut-time count
    /// of parked cache slots)`. Each bin serializes as the merged union
    /// of its per-shard parts, byte-identical to an unsharded bin — the
    /// shard count stays DRAM-only.
    fn serialize_sections_cut(&self, rewrite_all: bool) -> (Vec<SectionId>, Vec<Vec<u8>>, u64) {
        let nb = self.num_bins();
        let ngroups = mgmt_io::num_groups(nb);
        let group_dirty = |g: usize| {
            mgmt_io::group_bins(g, nb).any(|b| self.shards.iter().any(|s| s.peek_bin_dirty(b)))
        };
        let mut want: Vec<bool> = (0..ngroups).map(|g| rewrite_all || group_dirty(g)).collect();
        loop {
            let bin_guards: HashMap<usize, Vec<_>> = (0..nb)
                .filter(|&b| want[b / mgmt_io::BINS_PER_GROUP])
                .map(|b| {
                    let guards: Vec<_> =
                        self.shards.iter().map(|s| s.bins[b].write().unwrap()).collect();
                    (b, guards)
                })
                .collect();
            let mut chunks = self.chunks.write().unwrap();
            let mut names = self.names.lock().unwrap();
            // Re-probe under the chunk lock: the release/acquire edge of
            // the lock publishes the mark-first stores of every
            // serialization point that already touched the directory.
            let mut grew = false;
            for g in 0..ngroups {
                if !want[g] && group_dirty(g) {
                    want[g] = true;
                    grew = true;
                }
            }
            if grew {
                continue; // guards drop; retry with the larger lock set
            }
            // -- everything below reads one instant of allocator time --
            let mut ids: Vec<SectionId> = Vec::new();
            let mut buffers: Vec<Vec<u8>> = Vec::new();
            if chunks.take_dirty() || rewrite_all {
                let mut buf = Vec::new();
                chunks.serialize_into(&mut buf);
                ids.push(SectionId::Chunks);
                buffers.push(buf);
            }
            for g in 0..ngroups {
                if !want[g] {
                    continue;
                }
                let mut dirty = rewrite_all;
                for bin in mgmt_io::group_bins(g, nb) {
                    for s in &self.shards {
                        dirty |= s.take_bin_dirty(bin);
                    }
                }
                if dirty {
                    let mut buf = Vec::new();
                    for bin in mgmt_io::group_bins(g, nb) {
                        let parts: Vec<&BinData> =
                            bin_guards[&bin].iter().map(|g| &**g).collect();
                        serialize_merged_into(&parts, &mut buf);
                    }
                    ids.push(SectionId::Bins(g as u32));
                    buffers.push(buf);
                }
            }
            if names.take_dirty() || rewrite_all {
                let mut buf = Vec::new();
                names.serialize_into(&mut buf);
                ids.push(SectionId::Names);
                buffers.push(buf);
            }
            let mut cache_slots = self.cache.len() as u64;
            if self.cache.take_dirty() || rewrite_all {
                // transient: free slots parked in caches + remote queues
                // (claimed in the bitsets; recovery returns them). A
                // cache pop racing the cut belongs to the next epoch:
                // recovery to *this* epoch correctly rolls the slot back
                // to free.
                let mut entries = self.cache.snapshot_all();
                cache_slots = entries.len() as u64;
                for sh in &self.shards {
                    entries.extend(sh.remote_free.lock().unwrap().iter().copied());
                }
                ids.push(SectionId::Cache);
                buffers.push(mgmt_io::encode_cache_section(&entries));
            }
            return (ids, buffers, cache_slots);
        }
    }

    /// Failed sync: restore the dirty marks serialization cleared, so the
    /// next sync rewrites the affected sections.
    fn remark_dirty(&self, ids: &[SectionId]) {
        for &id in ids {
            match id {
                SectionId::Chunks => self.chunks.write().unwrap().mark_dirty(),
                SectionId::Bins(g) => {
                    for bin in mgmt_io::group_bins(g as usize, self.num_bins()) {
                        self.shards[0].mark_bin_dirty(bin);
                    }
                }
                SectionId::Names => self.names.lock().unwrap().mark_dirty(),
                SectionId::Cache => self.cache.mark_dirty(),
            }
        }
    }

    /// Fresh-store management state (nothing on disk yet).
    fn empty_management(nb: usize) -> LoadedManagement {
        LoadedManagement {
            chunks: ChunkDirectory::new(),
            bins: (0..nb).map(|_| BinData::new()).collect(),
            names: NameDirectory::new(),
            cache: Vec::new(),
            epoch: 0,
            sections: HashMap::new(),
            legacy: false,
            bins_per_group: mgmt_io::BINS_PER_GROUP,
        }
    }

    /// Load the management image: the newest *complete* manifest (every
    /// section present with matching checksum), falling back through
    /// older manifests (a torn sync can only have torn the newest), then
    /// to the legacy monolithic `management.bin`, then — for stores that
    /// never synced — to the empty state.
    fn load_management(dir: &Path, nb: usize) -> Result<LoadedManagement> {
        let epochs = mgmt_io::list_manifest_epochs(dir)?;
        for &e in epochs.iter().rev() {
            let Some(man) = mgmt_io::read_manifest(dir, e) else { continue };
            if man.num_bins as usize != nb {
                continue;
            }
            let Some(secs) = mgmt_io::load_sections(dir, &man) else { continue };
            if let Some(mut lm) = Self::parse_sections(nb, &man, &secs) {
                lm.epoch = man.epoch;
                lm.sections = man.sections.iter().map(|r| (r.id, r.clone())).collect();
                lm.bins_per_group = man.bins_per_group as usize;
                return Ok(lm);
            }
        }
        let p = dir.join("management.bin");
        if p.exists() {
            let mut lm = Self::load_legacy_management(dir, &p, nb)?;
            lm.legacy = true;
            return Ok(lm);
        }
        if epochs.is_empty() {
            // never synced: empty store
            return Ok(Self::empty_management(nb));
        }
        Err(Error::Datastore(format!(
            "no complete management manifest in {dir:?} (all candidates torn or corrupt)"
        )))
    }

    /// Parse the sections of one manifest into directories. `None` on any
    /// structural mismatch (the caller then tries an older manifest).
    fn parse_sections(
        nb: usize,
        man: &Manifest,
        secs: &HashMap<SectionId, Vec<u8>>,
    ) -> Option<LoadedManagement> {
        let chunks_buf = secs.get(&SectionId::Chunks)?;
        let (chunks, used) = ChunkDirectory::deserialize_from(chunks_buf)?;
        if used != chunks_buf.len() {
            return None;
        }
        let bpg = man.bins_per_group as usize;
        let mut bins = Vec::with_capacity(nb);
        for g in 0..nb.div_ceil(bpg) {
            let buf = secs.get(&SectionId::Bins(g as u32))?;
            let mut pos = 0;
            for _ in mgmt_io::group_bins_with(g, nb, bpg) {
                let (b, used) = BinData::deserialize_from(&buf[pos..])?;
                pos += used;
                bins.push(b);
            }
            if pos != buf.len() {
                return None;
            }
        }
        let names_buf = secs.get(&SectionId::Names)?;
        let (names, used) = NameDirectory::deserialize_from(names_buf)?;
        if used != names_buf.len() {
            return None;
        }
        let cache = mgmt_io::decode_cache_section(secs.get(&SectionId::Cache)?)?;
        Some(LoadedManagement {
            chunks,
            bins,
            names,
            cache,
            epoch: 0,
            sections: HashMap::new(),
            legacy: false,
            bins_per_group: man.bins_per_group as usize,
        })
    }

    /// Read the pre-segmentation monolithic `management.bin` (still
    /// supported on open; the next sync converts the store).
    fn load_legacy_management(dir: &Path, p: &Path, nb: usize) -> Result<LoadedManagement> {
        let buf = std::fs::read(p).map_err(|e| Error::io(p, e))?;
        let bad = || Error::Datastore(format!("corrupt management.bin in {dir:?}"));
        if buf.len() < 12 || &buf[0..8] != MGMT_MAGIC {
            return Err(bad());
        }
        let file_nb = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
        if file_nb != nb {
            return Err(bad());
        }
        let mut pos = 12;
        let (chunks, used) = ChunkDirectory::deserialize_from(&buf[pos..]).ok_or_else(bad)?;
        pos += used;
        let mut bins = Vec::with_capacity(nb);
        for _ in 0..nb {
            let (b, used) = BinData::deserialize_from(&buf[pos..]).ok_or_else(bad)?;
            pos += used;
            bins.push(b);
        }
        let (names, used) = NameDirectory::deserialize_from(&buf[pos..]).ok_or_else(bad)?;
        pos += used;
        if pos != buf.len() {
            return Err(bad());
        }
        Ok(LoadedManagement {
            chunks,
            bins,
            names,
            cache: Vec::new(),
            epoch: 0,
            sections: HashMap::new(),
            legacy: false,
            bins_per_group: mgmt_io::BINS_PER_GROUP,
        })
    }

    /// Cross-check chunk directory against the sharded bin data (run on
    /// open and by `doctor`). Works on a snapshot of the chunk directory
    /// so the chunk lock is never held while bin locks are taken (the
    /// alloc path nests bin → chunks; holding them in the opposite order
    /// here could deadlock a live store).
    fn validate_consistency(&self) -> Result<()> {
        let chunks = self.chunks.read().unwrap().clone();
        let err = |m: String| Error::Datastore(format!("inconsistent management data: {m}"));
        for (id, kind) in chunks.iter() {
            if let ChunkKind::Small { bin } = kind {
                let owner = chunks.owner(id) as usize;
                let sh = self
                    .shards
                    .get(owner)
                    .ok_or_else(|| err(format!("chunk {id} has invalid shard {owner}")))?;
                let b = sh
                    .bins
                    .get(bin as usize)
                    .ok_or_else(|| err(format!("chunk {id} has invalid bin {bin}")))?;
                if b.read().unwrap().bitset(id).is_none() {
                    return Err(err(format!(
                        "chunk {id} missing bitset in shard {owner} bin {bin}"
                    )));
                }
            }
        }
        for (s, sh) in self.shards.iter().enumerate() {
            for (bin, b) in sh.bins.iter().enumerate() {
                for cid in b.read().unwrap().chunk_ids() {
                    match chunks.kind(cid) {
                        ChunkKind::Small { bin: kb }
                            if kb as usize == bin && chunks.owner(cid) as usize == s => {}
                        k => {
                            return Err(err(format!(
                                "shard {s} bin {bin} owns chunk {cid} but chunk dir says \
                                 {k:?} owned by shard {}",
                                chunks.owner(cid)
                            )))
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Snapshot the datastore to `dst` (reflink when the filesystem
    /// supports it, §3.4). The snapshot is marked CLEAN — it is
    /// consistent by construction. The directory copy runs under the
    /// flush gate: a watermark- or interval-driven background epoch can
    /// never be caught half-committed by the copy.
    pub fn snapshot(&self, dst: impl AsRef<Path>) -> Result<CopyMethod> {
        let dst = dst.as_ref();
        self.sync()?;
        let _gate = self.bg.gate();
        let (_files, _bytes, method) = reflink::copy_dir(&self.dir, dst)?;
        // durable CLEAN marker: the snapshot is consistent by construction
        mgmt_io::write_section_file(dst, CLEAN_MARKER, b"")?;
        mgmt_io::fsync_dir(dst)?;
        Ok(method)
    }

    /// Close body, shared by [`MetallManager::close`] and `Drop`: drain
    /// and join the background engine, then the final inline sync and
    /// the durable CLEAN marker. A dead (panicked) flusher aborts the
    /// close *before* the marker — the store stays "unclean" and
    /// recovery falls back to the last complete manifest instead of
    /// trusting it.
    pub(crate) fn close_inner(&self) -> Result<()> {
        let r = self.close_inner_body();
        if r.is_err() {
            // A failed close is a post-mortem trigger: the store stays
            // unclean, so leave a durable flight ring for `metall
            // trace`/`doctor` to reconstruct what the engine was doing.
            self.tel.event(EventKind::CloseFailed, 0, 0, 0, 0);
            self.tel.flush_recorder();
        }
        r
    }

    fn close_inner_body(&self) -> Result<()> {
        if self.closed.swap(true, Ordering::SeqCst) || self.read_only {
            return Ok(());
        }
        if let Some(reason) = self.wounded.get() {
            // A wounded store must NOT earn the CLEAN marker: the last
            // committed manifest is the truth, and the next open has to
            // take the recovery path to it. Join the parked engine
            // threads, then surface the wound.
            let _ = self.bg.shutdown_and_join();
            return Err(Error::Degraded(reason.clone()));
        }
        self.bg.shutdown_and_join()?;
        // The process is ending: cache warmth is moot, so drain the
        // per-core caches fully — the closed image is canonical (every
        // free slot in the bitsets, empty cache section), which also
        // keeps the on-disk bytes independent of how many syncs ran.
        self.flush_cache()?;
        self.sync_now()?;
        // durable CLEAN marker (fsync file + directory: a crash right
        // after close must not lose the marker the next open requires)
        mgmt_io::write_section_file(&self.dir, CLEAN_MARKER, b"")?;
        mgmt_io::fsync_dir(&self.dir)?;
        Ok(())
    }

    // ------------------------------------------------------ accessors --

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn chunk_size(&self) -> usize {
        self.opts.chunk_size
    }

    pub fn is_read_only(&self) -> bool {
        self.read_only
    }

    pub fn segment(&self) -> &SegmentStorage {
        &self.segment
    }

    /// Manager-wide totals with the per-shard counters aggregated in (the
    /// shard count never changes the meaning of a total).
    pub fn stats(&self) -> StatsSnapshot {
        let per_shard = self.shard_stats();
        StatsSnapshot {
            allocs: self.stats.allocs.load(Ordering::Relaxed),
            deallocs: self.stats.deallocs.load(Ordering::Relaxed),
            cache_hits: self.stats.cache_hits.load(Ordering::Relaxed),
            fast_claims: per_shard.iter().map(|s| s.fast_claims).sum(),
            fresh_chunks: per_shard.iter().map(|s| s.fresh_chunks).sum(),
            freed_chunks: self.stats.freed_large_chunks.load(Ordering::Relaxed)
                + per_shard.iter().map(|s| s.freed_chunks).sum::<u64>(),
            large_allocs: self.stats.large_allocs.load(Ordering::Relaxed),
        }
    }

    /// Per-shard contention counters.
    pub fn shard_stats(&self) -> Vec<ShardStatsSnapshot> {
        self.shards.iter().enumerate().map(|(i, s)| s.stats_snapshot(i)).collect()
    }

    /// The manager's latency histograms + flight recorder
    /// ([`crate::telemetry::Telemetry`]). Sampling is configured by
    /// [`ManagerOptions::telemetry_sample`].
    pub fn telemetry(&self) -> &Telemetry {
        &self.tel
    }

    /// Merged per-op latency snapshots (shards folded), the input to
    /// [`crate::coordinator::metrics::record_latency_stats`] and the
    /// `metall stats` exporters.
    pub fn latency_snapshot(
        &self,
    ) -> Vec<(TelOp, crate::telemetry::histogram::HistogramSnapshot)> {
        self.tel.snapshot()
    }

    /// Observability snapshot of the incremental sync path (cumulative
    /// counts + the shape of the last flush). With a watermark or
    /// interval trigger configured, "last flush" means the engine's most
    /// recent flush — which may be a background one that ran after your
    /// `sync()` returned; treat the per-flush gauges as monitoring data,
    /// not as a receipt for a specific call ([`Self::bg_sync_stats`]
    /// carries the engine-wide cumulative totals).
    pub fn sync_stats(&self) -> SyncStats {
        *self.last_sync.lock().unwrap()
    }

    /// Explicitly drain every per-core object cache (and the remote-free
    /// queues) back to the bitsets, releasing chunks that empty. `sync()`
    /// deliberately does *not* do this — it preserves cache warmth and
    /// records the parked slots in the transient cache section instead —
    /// so callers that want `used_segment_bytes()` to reflect only live
    /// allocations (tests, space audits, pre-shrink housekeeping) call
    /// this first.
    pub fn flush_object_caches(&self) -> Result<()> {
        self.check_writable()?;
        self.flush_cache()
    }

    /// Record that `[offset, offset+len)` of the segment was written.
    /// Every write API of the manager (and the `SegmentAlloc` impls the
    /// containers use) marks automatically; callers writing through raw
    /// [`Self::ptr`] pointers must mark themselves or their bytes are
    /// flushed only by the kernel's own write-back, not by `sync()`.
    #[inline]
    pub fn mark_data_dirty(&self, offset: u64, len: usize) {
        if len == 0 {
            return;
        }
        let cs = self.opts.chunk_size as u64;
        let first = offset / cs;
        let last = (offset + len as u64 - 1) / cs;
        for c in first..=last {
            self.dirty_data.mark(c as usize);
        }
        // watermark kick + backpressure stall (one relaxed load when no
        // watermark is configured). Runs with no allocator locks held —
        // every caller of this API is lock-free at this point — so a
        // stalled writer can never block the flusher.
        self.bg.on_data_marked(self);
    }

    // ------------------------------------------- container op log --
    //
    // The runtime half of [`crate::containers::oplog`]: sequence
    // allocation + ring append (`oplog_begin`), the commit seal
    // (`oplog_commit`), open-time rediscovery (`load_oplog`), unclean-
    // open replay (`recover_containers`), and the doctor-facing
    // invariant audit (`validate_containers`).

    /// Mark bytes dirty without the background engine's watermark kick /
    /// backpressure stall — for writes made during open-time recovery
    /// (the engine is not yet bound) and by the flusher itself (which
    /// must never stall on its own backpressure).
    fn recovery_mark(&self, offset: u64, len: usize) {
        if len == 0 {
            return;
        }
        let cs = self.opts.chunk_size as u64;
        for c in offset / cs..=(offset + len as u64 - 1) / cs {
            self.dirty_data.mark(c as usize);
        }
    }

    fn read_record(&self, at: u64) -> OpRecord {
        let mut b = [0u8; oplog::RECORD_SIZE];
        b.copy_from_slice(unsafe { self.bytes(at, oplog::RECORD_SIZE) });
        OpRecord::from_bytes(&b)
    }

    /// Zero-padded snapshot of `len` live header bytes at `off`.
    fn read_image(&self, off: u64, len: usize) -> [u8; oplog::IMAGE_SIZE] {
        let mut img = [0u8; oplog::IMAGE_SIZE];
        img[..len].copy_from_slice(unsafe { self.bytes(off, len) });
        img
    }

    /// Restore `len` bytes of a logged header image (recovery only —
    /// never writes the zero padding, which belongs to neighbours).
    fn write_image(&self, off: u64, img: &[u8; oplog::IMAGE_SIZE], len: usize) {
        unsafe {
            std::ptr::copy_nonoverlapping(img.as_ptr(), self.ptr(off), len);
        }
        self.recovery_mark(off, len);
    }

    fn write_recovery_u64(&self, off: u64, v: u64) {
        unsafe {
            std::ptr::write_unaligned(self.ptr(off) as *mut u64, v);
        }
        self.recovery_mark(off, 8);
    }

    /// Seal a ring slot's commit/abort mark during recovery.
    fn seal_slot(&self, slot: u64, mark: u64) {
        self.write_recovery_u64(slot + oplog::COMMIT_CRC_AT as u64, mark);
    }

    /// The log object's (offset, ring capacity), creating it on first
    /// use: one `oplog::DEFAULT_CAPACITY`-slot ring in an ordinary
    /// allocation registered under [`oplog::OPLOG_NAME`]. A losing racer
    /// waits for the winner to finish zeroing the ring before appending
    /// into it.
    fn ensure_oplog(&self) -> Result<(u64, u32)> {
        {
            let lg = self.oplog.lock().unwrap();
            if lg.log_off != oplog::NONE {
                return Ok((lg.log_off, lg.capacity));
            }
        }
        let capacity = oplog::DEFAULT_CAPACITY;
        let size = oplog::log_size(capacity);
        let off = self.allocate(size)?;
        let fresh = {
            let mut names = self.names.lock().unwrap();
            match names.get(oplog::OPLOG_NAME) {
                Some(_) => false,
                None => names.insert(
                    oplog::OPLOG_NAME,
                    NamedEntry { offset: off, size: size as u64, type_fp: 0 },
                ),
            }
        };
        if !fresh {
            self.deallocate(off)?;
            loop {
                {
                    let lg = self.oplog.lock().unwrap();
                    if lg.log_off != oplog::NONE {
                        return Ok((lg.log_off, lg.capacity));
                    }
                }
                std::thread::yield_now();
            }
        }
        // The ring must start all-zero (a reused chunk's stale bytes
        // could otherwise verify as records); publish through the DRAM
        // state only after header + zeroing are complete.
        unsafe {
            let b = self.bytes_mut(off, size);
            b[..oplog::LOG_HEADER_SIZE].copy_from_slice(&oplog::header_bytes(capacity));
            b[oplog::LOG_HEADER_SIZE..].fill(0);
        }
        self.mark_data_dirty(off, size);
        let mut lg = self.oplog.lock().unwrap();
        lg.log_off = off;
        lg.capacity = capacity;
        Ok((off, capacity))
    }

    /// Append a container-op intent record: assign its ring sequence
    /// number, seal the intent checksum, write the 192-byte record into
    /// its slot. When the ring is full past the reclaim horizon, force a
    /// manifest-committing sync to advance it (bounded retries). The
    /// ring write and its dirty mark run *outside* the oplog mutex — the
    /// mark's backpressure stall may wait on the flusher, and the
    /// flusher takes the oplog mutex for its cut stamp.
    pub(crate) fn oplog_begin(&self, mut rec: OpRecord) -> Result<OpToken> {
        let t0 = self.tel.maybe_start();
        let r = self.oplog_begin_inner(&mut rec);
        if let Some(t) = t0 {
            self.tel.record(TelOp::OplogAppend, t);
        }
        r
    }

    fn oplog_begin_inner(&self, rec: &mut OpRecord) -> Result<OpToken> {
        self.check_writable()?;
        let (log_off, capacity) = self.ensure_oplog()?;
        let mut forced = 0u32;
        let seq = loop {
            {
                let mut lg = self.oplog.lock().unwrap();
                if lg.next_seq - lg.safe_seq < capacity as u64 {
                    let s = lg.next_seq;
                    lg.next_seq += 1;
                    lg.inflight.insert(s);
                    break s;
                }
            }
            if forced >= 3 {
                return Err(Error::InvalidOp(
                    "container op log is full and syncing does not advance its reclaim \
                     horizon (an operation appears stalled in flight)"
                        .into(),
                ));
            }
            forced += 1;
            self.oplog_counters.forced_syncs.fetch_add(1, Ordering::Relaxed);
            // A data-only epoch does not advance the horizon (no manifest
            // commit) — dirty the name section so this sync commits one.
            self.names.lock().unwrap().mark_dirty();
            // A failed forced sync (fault-stalled manifest commit) is
            // tolerated here: count it and retry — after three attempts
            // the ring-full contract above reports the stall. A wounded
            // manager is the exception: its flushes can never succeed,
            // so surface the degradation immediately.
            if let Err(e) = self.sync() {
                if matches!(e, Error::Degraded(_)) {
                    return Err(e);
                }
                self.oplog_counters.forced_sync_errors.fetch_add(1, Ordering::Relaxed);
            }
        };
        rec.seq = seq;
        rec.commit_crc = 0;
        rec.seal_intent();
        let slot = oplog::slot_off(log_off, capacity, seq);
        self.write::<[u8; oplog::RECORD_SIZE]>(slot, rec.to_bytes());
        self.oplog_counters.appended.fetch_add(1, Ordering::Relaxed);
        Ok(OpToken { slot_off: slot, seq, intent_crc: rec.intent_crc })
    }

    /// Seal a record's commit mark — one 8-byte write into its ring slot
    /// — and retire its sequence number from the in-flight set that pins
    /// the epoch cut horizon. The caller runs its trailing
    /// `deallocate(free_off)` strictly *after* this returns.
    pub(crate) fn oplog_commit(&self, token: OpToken) -> Result<()> {
        self.write::<u64>(
            token.slot_off + oplog::COMMIT_CRC_AT as u64,
            oplog::commit_mark(token.intent_crc),
        );
        self.oplog_counters.committed.fetch_add(1, Ordering::Relaxed);
        self.oplog.lock().unwrap().inflight.remove(&token.seq);
        Ok(())
    }

    /// Open-time rediscovery of the log ring: decode the persistent
    /// header, scan for the highest intent-valid sequence number, and
    /// derive the replay/validate floor from the newest durable cut
    /// entry at or below the recovered manifest epoch. A clean open
    /// validates nothing (floor = next_seq): every decided record's
    /// effect is already in the committed management state.
    fn load_oplog(&self, clean: bool) {
        let entry = self.names.lock().unwrap().get(oplog::OPLOG_NAME);
        let Some(e) = entry else { return };
        if e.offset + oplog::LOG_HEADER_SIZE as u64 > self.segment.mapped_len() as u64 {
            return;
        }
        let capacity = {
            let header = unsafe { self.bytes(e.offset, oplog::LOG_HEADER_SIZE) };
            match oplog::decode_header(header) {
                Some(c) if oplog::log_size(c) as u64 <= e.size => c,
                _ => {
                    // Torn mid-creation (the name committed before the
                    // header bytes): re-initialize in place on a writable
                    // open; a reader treats the log as absent.
                    if self.read_only || (oplog::log_size(oplog::DEFAULT_CAPACITY) as u64) > e.size
                    {
                        return;
                    }
                    let c = oplog::DEFAULT_CAPACITY;
                    unsafe {
                        let b = self.segment.slice_mut(e.offset as usize, oplog::log_size(c));
                        b[..oplog::LOG_HEADER_SIZE].copy_from_slice(&oplog::header_bytes(c));
                        b[oplog::LOG_HEADER_SIZE..].fill(0);
                    }
                    self.recovery_mark(e.offset, oplog::log_size(c));
                    c
                }
            }
        };
        let ring = e.offset + oplog::LOG_HEADER_SIZE as u64;
        let mut max_seq: Option<u64> = None;
        for i in 0..capacity as u64 {
            let rec = self.read_record(ring + i * oplog::RECORD_SIZE as u64);
            if rec.intent_valid() {
                max_seq = Some(max_seq.map_or(rec.seq, |m: u64| m.max(rec.seq)));
            }
        }
        let next_seq = max_seq.map_or(0, |m| m + 1);
        let repoch = self.mgmt.lock().unwrap().epoch;
        let mut floor_entry: Option<oplog::CutEntry> = None;
        for slot in 0..oplog::CUT_SLOTS as u64 {
            let mut b = [0u8; 24];
            b.copy_from_slice(unsafe { self.bytes(oplog::cut_entry_off(e.offset, slot), 24) });
            if let Some(c) = oplog::CutEntry::from_bytes(&b) {
                if c.epoch <= repoch && floor_entry.map_or(true, |f| c.epoch > f.epoch) {
                    floor_entry = Some(c);
                }
            }
        }
        let floor = if clean { next_seq } else { floor_entry.map_or(0, |c| c.cut_seq).min(next_seq) };
        self.oplog_validate_floor.store(floor, Ordering::Relaxed);
        let mut lg = self.oplog.lock().unwrap();
        lg.log_off = e.offset;
        lg.capacity = capacity;
        lg.next_seq = next_seq;
        // Until the next manifest commit, records at or above the floor
        // are the recovery evidence for this manifest — their slots must
        // not be reused. (Clean open: everything is decided and covered.)
        lg.safe_seq = floor;
        // force the first cut to stamp a fresh entry
        lg.last_cut_seq = u64::MAX;
    }

    /// Unclean-open replay of the log tail, in ascending sequence order:
    ///
    /// - **Committed** records at or above the floor are kept; the extent
    ///   each allocated is re-adopted into the recovered allocator (the
    ///   recovered manifest predates the allocation). Their retired
    ///   extents are deliberately *not* released — a pre-cut reuse racing
    ///   the cut could make that release free live data; leaking a
    ///   ring-window of retired extents is the safe trade.
    /// - **Unsealed** records (any sequence — an op can span a cut) are
    ///   rolled *forward* when every current header cell already matches
    ///   its new image (the kill landed between the last publish and the
    ///   commit seal): seal the commit, adopt the extent, run the missing
    ///   trailing deallocate. Otherwise rolled *back*: restore the old
    ///   images, un-key a half-inserted map slot, seal an abort, and
    ///   release the never-published allocation (leak-free rollback).
    ///   Both are safe at any sequence: the trailing deallocate runs
    ///   strictly after the commit seal, so an unsealed record's old
    ///   extent is still intact.
    fn recover_containers(&self) -> Result<()> {
        let (log_off, capacity, floor) = {
            let lg = self.oplog.lock().unwrap();
            (lg.log_off, lg.capacity, lg.safe_seq)
        };
        if log_off == oplog::NONE {
            return Ok(());
        }
        let ring = log_off + oplog::LOG_HEADER_SIZE as u64;
        let mut recs: Vec<OpRecord> = Vec::new();
        for i in 0..capacity as u64 {
            let rec = self.read_record(ring + i * oplog::RECORD_SIZE as u64);
            if !rec.intent_valid() {
                continue;
            }
            match rec.state() {
                RecordState::Aborted => {}
                RecordState::Committed => {
                    if rec.seq >= floor {
                        recs.push(rec);
                    }
                }
                RecordState::Unsealed => recs.push(rec),
            }
        }
        recs.sort_by_key(|r| r.seq);
        let mapped = self.segment.mapped_len() as u64;
        for rec in &recs {
            match rec.state() {
                RecordState::Committed => {
                    if rec.alloc_off != oplog::NONE {
                        self.tel.event(EventKind::RecoveryAdopt, 0, rec.seq, rec.alloc_off, 0);
                        self.recovery_adopt(rec.alloc_off, rec.alloc_size);
                    }
                }
                RecordState::Unsealed => self.recover_unsealed(rec, log_off, capacity, mapped)?,
                RecordState::Aborted => {}
            }
        }
        Ok(())
    }

    fn recover_unsealed(
        &self,
        rec: &OpRecord,
        log_off: u64,
        capacity: u32,
        mapped: u64,
    ) -> Result<()> {
        let h1_len = rec.h1_len();
        let h2_len = (rec.h2_len as usize).min(oplog::IMAGE_SIZE);
        let slot = oplog::slot_off(log_off, capacity, rec.seq);
        // a record whose header cells lie outside the mapped extent is
        // unactionable — seal an abort so validation skips it
        if rec.h1_off == oplog::NONE
            || rec.h1_off + h1_len as u64 > mapped
            || (rec.h2_off != oplog::NONE && rec.h2_off + h2_len.max(1) as u64 > mapped)
        {
            self.oplog_counters.recovery_anomalies.fetch_add(1, Ordering::Relaxed);
            self.seal_slot(slot, oplog::abort_mark(rec.intent_crc));
            return Ok(());
        }
        let cur1 = self.read_image(rec.h1_off, h1_len);
        let forward = cur1[..h1_len] == rec.h1_new[..h1_len]
            && (rec.h2_off == oplog::NONE
                || self.read_image(rec.h2_off, h2_len)[..h2_len] == rec.h2_new[..h2_len]);
        if forward {
            self.seal_slot(slot, oplog::commit_mark(rec.intent_crc));
            self.oplog_counters.recovered_forward.fetch_add(1, Ordering::Relaxed);
            self.tel.event(EventKind::RecoveryReplay, 0, rec.seq, rec.h1_off, 0);
            if rec.alloc_off != oplog::NONE {
                self.recovery_adopt(rec.alloc_off, rec.alloc_size);
            }
            if rec.free_off != oplog::NONE {
                // the op's own trailing deallocate, which never ran
                self.recovery_release(rec.free_off)?;
            }
        } else {
            if cur1[..h1_len] != rec.h1_old[..h1_len] {
                // matches neither image: torn mid-publish — the old image
                // is still the consistent restore point, but surface it
                self.oplog_counters.recovery_anomalies.fetch_add(1, Ordering::Relaxed);
            }
            self.write_image(rec.h1_off, &rec.h1_old, h1_len);
            if rec.h2_off != oplog::NONE && h2_len > 0 {
                self.write_image(rec.h2_off, &rec.h2_old, h2_len);
            }
            // a rolled-back insert keyed its slot before the header
            // publish — un-key it or the probe chain counts a ghost
            if rec.kind == oplog::OP_MAP_INSERT
                && rec.flags & oplog::FLAG_OVERWRITE == 0
                && rec.aux != 0
                && rec.aux + 8 <= mapped
            {
                let cur_key: u64 = self.read(rec.aux);
                if cur_key == rec.aux2 {
                    self.write_recovery_u64(rec.aux, u64::MAX); // EMPTY_KEY
                }
            }
            self.seal_slot(slot, oplog::abort_mark(rec.intent_crc));
            self.oplog_counters.recovered_rollback.fetch_add(1, Ordering::Relaxed);
            self.tel.event(EventKind::RecoveryRollback, 0, rec.seq, rec.h1_off, 0);
            // the extent the op allocated was never published — release
            // it, unless it *is* the header cell being restored (a torn
            // create: something may already reference the cell)
            if rec.alloc_off != oplog::NONE && rec.alloc_off != rec.h1_off {
                self.recovery_release(rec.alloc_off)?;
            }
        }
        Ok(())
    }

    /// Adopt an extent a post-cut op allocated into the recovered
    /// allocator state (bitset + chunk directory surgery). Lenient: an
    /// extent the recovered state already accounts for — or whose
    /// geometry no longer lines up — is skipped. A skip can at worst
    /// leak; adopting blindly could hand the same bytes out twice.
    fn recovery_adopt(&self, offset: u64, size: u64) -> bool {
        let cs = self.opts.chunk_size;
        if size == 0 || size > usize::MAX as u64 {
            return false;
        }
        let size = size as usize;
        let chunk = (offset / cs as u64) as u32;
        let adopted = if is_small(size, cs) {
            if (chunk as usize + 1) * cs > self.segment.mapped_len() {
                return false;
            }
            let bin = bin_of(size) as u32;
            let class = size_of_bin(bin as usize) as u64;
            if (offset % cs as u64) % class != 0 {
                return false;
            }
            let slot = ((offset % cs as u64) / class) as u32;
            let slots = slots_per_chunk(bin as usize, cs) as u32;
            if slot >= slots {
                return false;
            }
            let kind = {
                let chunks = self.chunks.read().unwrap();
                if (chunk as usize) < chunks.len() { chunks.kind(chunk) } else { ChunkKind::Free }
            };
            match kind {
                ChunkKind::Small { bin: b } if b == bin => {
                    let owner = self.chunks.read().unwrap().owner(chunk) as usize;
                    let sh = &self.shards[owner];
                    sh.mark_bin_dirty(bin as usize);
                    sh.bins[bin as usize].write().unwrap().adopt_slot(chunk, slot)
                }
                ChunkKind::Free => {
                    let shard = self.shard_map.recovery_shard_of_chunk(chunk);
                    let sh = &self.shards[shard];
                    // mark-first discipline (see allocate())
                    sh.mark_bin_dirty(bin as usize);
                    let ok =
                        self.chunks.write().unwrap().adopt_small_chunk(chunk, bin, shard as u32);
                    if ok {
                        let bs = MlBitset::new(slots);
                        bs.set(slot);
                        sh.bins[bin as usize].write().unwrap().insert_chunk(chunk, bs);
                    }
                    ok
                }
                _ => false,
            }
        } else {
            let n = large_chunks(size, cs) as u32;
            if offset % cs as u64 != 0 || (chunk as usize + n as usize) * cs > self.segment.mapped_len()
            {
                return false;
            }
            self.chunks.write().unwrap().adopt_large(chunk, n)
        };
        if adopted {
            self.oplog_counters.recovered_adopted.fetch_add(1, Ordering::Relaxed);
        }
        adopted
    }

    /// Release an extent straight into the bitsets — never through the
    /// object cache, whose parked frees would leave the bitset claimed
    /// and make a later adopt of the same slot double-account. Lenient:
    /// an extent the recovered state does not hold as live is skipped.
    fn recovery_release(&self, offset: u64) -> Result<bool> {
        let cs = self.opts.chunk_size as u64;
        let cs_us = self.opts.chunk_size;
        let chunk = (offset / cs) as u32;
        let kind = {
            let chunks = self.chunks.read().unwrap();
            if (chunk as usize) >= chunks.len() {
                return Ok(false);
            }
            chunks.kind(chunk)
        };
        let released = match kind {
            ChunkKind::Small { bin } => {
                let class = size_of_bin(bin as usize) as u64;
                if (offset % cs) % class != 0 {
                    return Ok(false);
                }
                let slot = ((offset % cs) / class) as u32;
                let owner = self.chunks.read().unwrap().owner(chunk) as usize;
                let sh = &self.shards[owner];
                let mut b = sh.bins[bin as usize].write().unwrap();
                if !b.is_slot_used(chunk, slot) {
                    return Ok(false);
                }
                sh.mark_bin_dirty(bin as usize);
                let empty = b.free_slot(chunk, slot);
                if empty {
                    b.remove_chunk(chunk);
                    let mut chunks = self.chunks.write().unwrap();
                    chunks.free_small_chunk_on(chunk, owner as u32);
                    drop(chunks);
                    sh.stats.freed_chunks.fetch_add(1, Ordering::Relaxed);
                    if (chunk as usize + 1) * cs_us <= self.segment.mapped_len() {
                        self.segment.free_range(chunk as usize * cs_us, cs_us)?;
                    }
                }
                true
            }
            ChunkKind::LargeHead { .. } => {
                if offset % cs != 0 {
                    return Ok(false);
                }
                let n = {
                    let mut chunks = self.chunks.write().unwrap();
                    chunks.free_large(chunk)
                };
                self.stats.freed_large_chunks.fetch_add(n as u64, Ordering::Relaxed);
                if (chunk as usize + n as usize) * cs_us <= self.segment.mapped_len() {
                    self.segment.free_range(chunk as usize * cs_us, n as usize * cs_us)?;
                }
                true
            }
            ChunkKind::Free | ChunkKind::LargeBody => false,
        };
        if released {
            self.oplog_counters.recovered_released.fetch_add(1, Ordering::Relaxed);
        }
        Ok(released)
    }

    /// Container-invariant audit over the newest epoch's log tail: every
    /// intent-valid, non-aborted record at `seq >=` the validate floor,
    /// reduced to the newest record per header cell. Checks `len <= cap`,
    /// that `data_off`/`table_off` point at live allocations big enough
    /// for `cap`, that a hash table's keyed-slot population matches its
    /// `len`, and that an adjacency bank's `nedges` equals the sum of its
    /// per-vertex list lengths (no half-linked rows). Assumes quiescent
    /// mutators (the same contract as [`Self::doctor`], which runs it
    /// under the flush gate). Returns human-readable findings.
    pub fn validate_containers(&self) -> Vec<String> {
        let mut findings = Vec::new();
        let (log_off, capacity) = {
            let lg = self.oplog.lock().unwrap();
            (lg.log_off, lg.capacity)
        };
        if log_off == oplog::NONE {
            return findings;
        }
        let floor = self.oplog_validate_floor.load(Ordering::Relaxed);
        let ring = log_off + oplog::LOG_HEADER_SIZE as u64;
        let mut newest: HashMap<u64, OpRecord> = HashMap::new();
        let mut banks: HashMap<u64, OpRecord> = HashMap::new();
        let mut examined = 0u64;
        for i in 0..capacity as u64 {
            let rec = self.read_record(ring + i * oplog::RECORD_SIZE as u64);
            if !rec.intent_valid() || rec.seq < floor || rec.state() == RecordState::Aborted {
                continue;
            }
            examined += 1;
            if rec.h1_off != oplog::NONE {
                let e = newest.entry(rec.h1_off).or_insert(rec);
                if rec.seq >= e.seq {
                    *e = rec;
                }
            }
            if rec.kind == oplog::OP_EDGE && rec.h2_off != oplog::NONE {
                let e = banks.entry(rec.h2_off).or_insert(rec);
                if rec.seq >= e.seq {
                    *e = rec;
                }
            }
        }
        self.oplog_counters.validate_records.store(examined, Ordering::Relaxed);
        let mapped = self.segment.mapped_len() as u64;
        for (&h1, rec) in &newest {
            // a header cell that is no longer a live allocation belongs
            // to a destroyed container (destroy is not logged) — skip
            if self.usable_size(h1).is_err() {
                continue;
            }
            let unit = (rec.unit.max(1)) as u64;
            match rec.kind {
                oplog::OP_VEC_CREATE | oplog::OP_VEC_PUSH | oplog::OP_VEC_EXTEND
                | oplog::OP_VEC_POP | oplog::OP_VEC_GROW | oplog::OP_EDGE => {
                    self.validate_vec_header(h1, unit, &mut findings);
                }
                oplog::OP_MAP_CREATE | oplog::OP_MAP_INSERT | oplog::OP_MAP_GROW => {
                    self.validate_map_header(h1, unit, &mut findings);
                }
                oplog::OP_STR_SET => {
                    let s = oplog::str_image(&self.read_image(h1, 16));
                    if s.len > 0 {
                        match self.usable_size(s.data_off) {
                            Ok(sz) if sz as u64 >= s.len => {}
                            _ => findings.push(format!(
                                "container string @{h1}: data_off {} not a live allocation \
                                 of at least len {}B",
                                s.data_off, s.len
                            )),
                        }
                    }
                }
                _ => {}
            }
        }
        for (&h2, _rec) in &banks {
            if h2 + 16 > mapped {
                continue;
            }
            let b = oplog::bank_image(&self.read_image(h2, 16));
            // a dead bank map means the adjacency was destroyed — skip
            if self.usable_size(b.map_header_off).is_err() {
                continue;
            }
            let m = oplog::map_image(&self.read_image(b.map_header_off, oplog::IMAGE_SIZE));
            if m.cap == 0 {
                if b.nedges != 0 {
                    findings.push(format!(
                        "adjacency bank @{h2}: nedges {} but its vertex map is empty",
                        b.nedges
                    ));
                }
                continue;
            }
            // bank maps are PHashMapU64<u64 handle>: stride 16
            let stride = 16u64;
            if self
                .usable_size(m.table_off)
                .map(|sz| (sz as u64) < m.cap.saturating_mul(stride))
                .unwrap_or(true)
            {
                // the map audit above already reports the broken table
                continue;
            }
            let mut total = 0u64;
            let mut broken = false;
            for s in 0..m.cap {
                let key: u64 = self.read(m.table_off + s * stride);
                if key == u64::MAX {
                    continue;
                }
                let handle: u64 = self.read(m.table_off + s * stride + 8);
                if self.usable_size(handle).is_err() {
                    findings.push(format!(
                        "adjacency bank @{h2}: vertex {key} list header {handle} is not a \
                         live allocation (half-linked row)"
                    ));
                    broken = true;
                    continue;
                }
                total += oplog::vec_image(&self.read_image(handle, oplog::IMAGE_SIZE)).len;
            }
            if !broken && total != b.nedges {
                findings.push(format!(
                    "adjacency bank @{h2}: nedges {} != sum of per-vertex list lengths {total}",
                    b.nedges
                ));
            }
        }
        findings
    }

    fn validate_vec_header(&self, h1: u64, elem: u64, findings: &mut Vec<String>) {
        let v = oplog::vec_image(&self.read_image(h1, oplog::IMAGE_SIZE));
        if v.len > v.cap {
            findings.push(format!("container vec @{h1}: len {} > cap {}", v.len, v.cap));
            return;
        }
        if v.cap == 0 {
            if v.data_off != u64::MAX {
                findings.push(format!(
                    "container vec @{h1}: cap 0 but data_off {} is set",
                    v.data_off
                ));
            }
            return;
        }
        match self.usable_size(v.data_off) {
            Ok(sz) if (sz as u64) >= v.cap.saturating_mul(elem) => {}
            Ok(sz) => findings.push(format!(
                "container vec @{h1}: data extent {sz}B < cap {} × elem {elem}B",
                v.cap
            )),
            Err(_) => findings.push(format!(
                "container vec @{h1}: data_off {} is not a live allocation",
                v.data_off
            )),
        }
    }

    fn validate_map_header(&self, h1: u64, stride: u64, findings: &mut Vec<String>) {
        let m = oplog::map_image(&self.read_image(h1, oplog::IMAGE_SIZE));
        if m.cap == 0 {
            if m.len != 0 {
                findings.push(format!("container map @{h1}: no table but len {}", m.len));
            }
            return;
        }
        if !m.cap.is_power_of_two() {
            findings.push(format!("container map @{h1}: cap {} not a power of two", m.cap));
            return;
        }
        if m.len > m.cap {
            findings.push(format!("container map @{h1}: len {} > cap {}", m.len, m.cap));
            return;
        }
        match self.usable_size(m.table_off) {
            Ok(sz) if (sz as u64) >= m.cap.saturating_mul(stride) => {
                let mut keyed = 0u64;
                for s in 0..m.cap {
                    let key: u64 = self.read(m.table_off + s * stride);
                    if key != u64::MAX {
                        keyed += 1;
                    }
                }
                if keyed != m.len {
                    findings.push(format!(
                        "container map @{h1}: {keyed} keyed slots but len {}",
                        m.len
                    ));
                }
            }
            Ok(sz) => findings.push(format!(
                "container map @{h1}: table extent {sz}B < cap {} × stride {stride}B",
                m.cap
            )),
            Err(_) => findings.push(format!(
                "container map @{h1}: table_off {} is not a live allocation",
                m.table_off
            )),
        }
    }

    /// Cumulative op-log counters (append/commit rates, ring-full forced
    /// syncs, recovery outcomes, the last validation's record count).
    pub fn oplog_stats(&self) -> OpLogStats {
        let c = &self.oplog_counters;
        OpLogStats {
            appended: c.appended.load(Ordering::Relaxed),
            committed: c.committed.load(Ordering::Relaxed),
            forced_syncs: c.forced_syncs.load(Ordering::Relaxed),
            forced_sync_errors: c.forced_sync_errors.load(Ordering::Relaxed),
            recovered_forward: c.recovered_forward.load(Ordering::Relaxed),
            recovered_rollback: c.recovered_rollback.load(Ordering::Relaxed),
            recovered_adopted: c.recovered_adopted.load(Ordering::Relaxed),
            recovered_released: c.recovered_released.load(Ordering::Relaxed),
            recovery_anomalies: c.recovery_anomalies.load(Ordering::Relaxed),
            validate_records: c.validate_records.load(Ordering::Relaxed),
        }
    }

    /// Number of allocator shards (DRAM-only; see [`ManagerOptions::shards`]).
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The NUMA topology this manager was opened under (DRAM-only; see
    /// [`ManagerOptions::topology`]).
    pub fn topology(&self) -> &Topology {
        self.shard_map.topology()
    }

    /// Node-per-page histogram of the mapped segment, grouped by owning
    /// shard. Every mapped page is accounted exactly once
    /// ([`PlacementReport::accounted_pages`] == `total_pages`): small
    /// chunks under their owner, everything else under the large/free
    /// buckets. Attribution is kernel truth (`move_pages`) when the
    /// topology was detected and the kernel answers, else the recorded
    /// birth nodes — so the ≥ 95 %-node-local acceptance check runs
    /// identically under an injected test topology on a 1-node host. On
    /// single-node topologies every attributed page is trivially local.
    pub fn placement_report(&self) -> PlacementReport {
        let ps = page_size();
        let cs = self.opts.chunk_size;
        let pages_per_chunk = (cs / ps).max(1) as u64;
        let mapped = self.segment.mapped_len();
        let topo = self.shard_map.topology();
        let rows = self.chunks.read().unwrap().placement_rows();
        let use_kernel = topo.is_detected() && pagemap::page_node_query_supported();
        let mut per_shard: Vec<ShardPlacement> = (0..self.shards.len())
            .map(|s| ShardPlacement {
                shard: s,
                node: self.shard_map.node_of_shard(s),
                ..Default::default()
            })
            .collect();
        // One bounded-window scan of the whole extent up front: the
        // syscall count stays O(pages / 4096), not O(chunks), however
        // many chunks the store holds.
        let kernel_status: Option<Vec<i32>> = if use_kernel {
            let base = self.segment.base() as usize;
            let total = mapped / ps;
            let mut all = Vec::with_capacity(total);
            while all.len() < total {
                let n = (total - all.len()).min(4096);
                match pagemap::page_nodes(base + all.len() * ps, n) {
                    Some(mut v) => all.append(&mut v),
                    None => break,
                }
            }
            (all.len() == total).then_some(all)
        } else {
            None
        };
        let mut large_pages = 0u64;
        let mut free_pages = 0u64;
        let mapped_chunks = mapped / cs;
        for chunk in 0..mapped_chunks {
            let (kind, owner, birth) = match rows.get(chunk) {
                Some(&row) => row,
                None => (ChunkKind::Free, 0, None),
            };
            match kind {
                ChunkKind::Small { .. } => {
                    let p = &mut per_shard[owner as usize];
                    p.pages += pages_per_chunk;
                    let home = p.node;
                    match &kernel_status {
                        Some(status) => {
                            // the kernel reports physical node ids
                            let home_phys = topo.physical_node(home);
                            let start = chunk * pages_per_chunk as usize;
                            for &n in &status[start..start + pages_per_chunk as usize] {
                                if n < 0 {
                                    p.unknown_pages += 1; // not faulted in
                                } else if n as usize == home_phys {
                                    p.node_local_pages += 1;
                                } else {
                                    p.remote_pages += 1;
                                }
                            }
                        }
                        None => match birth {
                            Some(n) if n as usize == home => p.node_local_pages += pages_per_chunk,
                            Some(_) => p.remote_pages += pages_per_chunk,
                            // single node: there is nowhere else to be
                            None if topo.num_nodes() <= 1 => p.node_local_pages += pages_per_chunk,
                            None => p.unknown_pages += pages_per_chunk,
                        },
                    }
                }
                ChunkKind::LargeHead { .. } | ChunkKind::LargeBody => large_pages += pages_per_chunk,
                ChunkKind::Free => free_pages += pages_per_chunk,
            }
        }
        // file-size granularity can map a partial trailing chunk
        free_pages += ((mapped - mapped_chunks * cs) / ps) as u64;
        let source = if kernel_status.is_some() {
            PlacementSource::Kernel
        } else {
            PlacementSource::Recorded
        };
        PlacementReport {
            per_shard,
            large_pages,
            free_pages,
            total_pages: (mapped / ps) as u64,
            source,
        }
    }

    fn num_bins(&self) -> usize {
        self.shards[0].bins.len()
    }

    /// Occupied chunks × chunk size (VM-level usage).
    pub fn used_segment_bytes(&self) -> usize {
        self.chunks.read().unwrap().used_chunks() * self.opts.chunk_size
    }

    // ----------------------------------------------------- allocation --

    fn check_writable(&self) -> Result<()> {
        if self.read_only {
            return Err(Error::InvalidOp("datastore is open read-only".into()));
        }
        if let Some(reason) = self.wounded.get() {
            return Err(Error::Degraded(reason.clone()));
        }
        Ok(())
    }

    /// Allocate `size` bytes; returns the segment offset.
    pub fn allocate(&self, size: usize) -> Result<u64> {
        // Sampled latency telemetry wraps the whole path so the
        // histogram sees cache hits, CAS claims, and fresh-chunk slow
        // paths in their true mix.
        let t0 = self.tel.maybe_start();
        let r = self.allocate_inner(size);
        if let Some(t) = t0 {
            let op = if is_small(size, self.opts.chunk_size) {
                TelOp::AllocSmall
            } else {
                TelOp::AllocLarge
            };
            self.tel.record(op, t);
        }
        r
    }

    fn allocate_inner(&self, size: usize) -> Result<u64> {
        self.check_writable()?;
        if size == 0 {
            return Err(Error::Alloc("zero-size allocation".into()));
        }
        self.stats.allocs.fetch_add(1, Ordering::Relaxed);
        let cs = self.opts.chunk_size;
        if !is_small(size, cs) {
            return self.allocate_large(size);
        }
        let bin = bin_of(size) as u32;
        // one virtual-CPU resolution drives both the cache slot and the
        // home shard (the cache-slot ↔ shard binding)
        let vcpu = current_vcpu();
        if let Some(off) = self.cache.pop_at(self.cache.slot_for(vcpu), bin) {
            self.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(off);
        }
        let shard = self.shard_map.shard_of_vcpu(vcpu);
        let sh = &self.shards[shard];
        // Fast path: shared bin lock of the home shard + lock-free CAS
        // claim in an active chunk; a word-level batch is taken and the
        // surplus refills this core's object cache, so same-bin allocators
        // never serialize while any active chunk of their shard has room.
        let claims = {
            let b = sh.bins[bin as usize].read().unwrap();
            let mut claims: Vec<(u32, u32)> = Vec::with_capacity(REFILL_BATCH);
            b.try_claim_batch(REFILL_BATCH, &mut claims);
            if !claims.is_empty() {
                // dirty-epoch mark inside the critical section: releasing
                // the shared lock orders it before any sync that takes
                // the exclusive side to serialize this bin
                sh.mark_bin_dirty(bin as usize);
            }
            claims
        };
        if let Some(&(chunk, slot)) = claims.first() {
            sh.stats.fast_claims.fetch_add(claims.len() as u64, Ordering::Relaxed);
            let first = self.slot_offset(chunk, bin, slot);
            if claims.len() > 1 {
                // reversed: the cache pops LIFO, so the lowest (first-fit)
                // slot must land on top and come back out first
                let extra: Vec<u64> = claims[1..]
                    .iter()
                    .rev()
                    .map(|&(c, s)| self.slot_offset(c, bin, s))
                    .collect();
                let spill = self.cache.push_batch_at(self.cache.slot_for(vcpu), bin, &extra);
                if !spill.is_empty() {
                    // Read lock is already released — routing takes write
                    // locks. Best-effort: the allocation itself already
                    // succeeded, and a spill failure (hole-punch I/O on an
                    // emptied chunk) must not turn it into a phantom error
                    // that leaks the whole claimed batch.
                    let _ = self.route_frees(bin, &spill);
                }
            }
            return Ok(first);
        }
        // Slow path (serialization point #1, per shard): drain frees other
        // shards parked for us while we are here anyway, then exclusive
        // bin lock — heal the non-full LIFO, retry (another thread may
        // have registered a chunk while we waited), else take a fresh
        // chunk (bin → chunks lock order). Drain errors are hole-punch
        // I/O, not allocation failures.
        let _ = self.drain_remote(shard);
        sh.stats.exclusive_acquires.fetch_add(1, Ordering::Relaxed);
        let mut b = sh.bins[bin as usize].write().unwrap();
        b.prune_full();
        if let Some((chunk, slot)) = b.alloc_slot() {
            sh.mark_bin_dirty(bin as usize);
            return Ok(self.slot_offset(chunk, bin, slot));
        }
        // Mark the bin dirty BEFORE the chunk-directory mutation
        // (mark-first discipline): the flush's consistent cut holds the
        // chunk lock and re-probes the bin flags under it — a
        // serialization point that already registered its chunk must be
        // visible as dirty there (the chunk-lock release/acquire edge
        // publishes this relaxed store), or the cut could commit a chunk
        // section that owns a chunk no serialized bin knows about.
        sh.mark_bin_dirty(bin as usize);
        // Reserve the chunk id under the chunk-directory lock, but run
        // the segment extension (ftruncate + mmap syscalls) *outside* it:
        // the reserved entry is no longer Free, so no other thread can
        // claim it, and a concurrent large allocation's probe skips it —
        // the directory-wide lock must not be held across syscalls. On
        // extension failure the reservation is rolled back under a fresh
        // lock acquisition.
        let chunk = {
            let mut chunks = self.chunks.write().unwrap();
            chunks.take_small_chunk_on(bin, shard as u32)
        };
        if let Err(e) = self.segment.extend_to((chunk as usize + 1) * cs) {
            self.chunks.write().unwrap().free_small_chunk_on(chunk, shard as u32);
            self.health.extend_rollbacks.fetch_add(1, Ordering::Relaxed);
            self.tel.event(EventKind::ExtendRollback, 0, 1, 0, 0);
            return Err(e);
        }
        sh.stats.fresh_chunks.fetch_add(1, Ordering::Relaxed);
        self.place_fresh_chunk(chunk, shard);
        let slots = slots_per_chunk(bin as usize, cs) as u32;
        let slot = b.add_chunk_and_alloc(chunk, slots);
        sh.mark_bin_dirty(bin as usize);
        Ok(self.slot_offset(chunk, bin, slot))
    }

    /// NUMA placement of a fresh small chunk (multi-node topologies only;
    /// single-node managers skip this entirely — kernel first-touch is
    /// already local there). Two layers; exactly one places each chunk:
    ///
    /// 1. `mbind(MPOL_PREFERRED | MPOL_MF_MOVE)` the chunk's extent to
    ///    the owning shard's node (its *physical* kernel id): every later
    ///    fault — whichever thread triggers it — lands there, and pages
    ///    still resident from the chunk's previous life (page-cache
    ///    survivors under `free_file_space: false`) are migrated. When
    ///    the bind takes, nothing needs touching: zeroing 2 MiB here
    ///    would only dirty every page (full-chunk write amplification on
    ///    the next sync/snapshot) to establish what the policy already
    ///    guarantees.
    /// 2. **Owner first touch**, only when `mbind` is unavailable
    ///    (non-NUMA kernel under an injected test topology, seccomp'd
    ///    container): zero the whole chunk from the allocating thread —
    ///    which is homed on the owning shard, hence on the target node —
    ///    before any slot becomes visible. Without this, the kernel
    ///    places each page on whatever socket first *writes an object*
    ///    into it, which under cross-shard frees and cache refills is
    ///    routinely the wrong one. Zero-filling is safe: the chunk holds
    ///    no live allocations, and freed chunks were hole-punched (or
    ///    contain garbage from a dead life), so no data can be clobbered.
    ///    Known limit: pages still resident from a previous life are
    ///    *written*, not migrated, by this fallback — only the `mbind`
    ///    layer (or a hole punch at free time) can re-place those.
    ///
    /// The birth node recorded for [`Self::placement_report`] is the
    /// bind target in layer 1 but the *toucher's own node* in layer 2 —
    /// so if routing ever hands a shard's fresh chunk to a thread on the
    /// wrong node, the report shows real `remote_pages` instead of
    /// echoing the expectation back. Runs under the owner's exclusive
    /// bin lock, before `add_chunk_and_alloc` publishes the chunk, so no
    /// other thread can touch these pages first (bin → chunks lock order
    /// for the record).
    fn place_fresh_chunk(&self, chunk: u32, shard: usize) {
        let topo = self.shard_map.topology();
        if topo.num_nodes() <= 1 {
            return;
        }
        let cs = self.opts.chunk_size;
        let node = self.shard_map.node_of_shard(shard);
        let sh = &self.shards[shard];
        let birth;
        if self.segment.bind_range(chunk as usize * cs, cs, topo.physical_node(node)) {
            sh.stats.bound_chunks.fetch_add(1, Ordering::Relaxed);
            birth = node;
        } else {
            unsafe { self.segment.slice_mut(chunk as usize * cs, cs).fill(0) };
            // the zero-fill dirtied the whole chunk (recycled extents may
            // hold a dead life's bytes in the file)
            self.dirty_data.mark(chunk as usize);
            sh.stats.first_touch_chunks.fetch_add(1, Ordering::Relaxed);
            birth = topo.node_of_cpu(current_vcpu());
        }
        // Deliberately a second (brief) chunk-lock acquisition rather
        // than folding into the take/extend critical section: mbind may
        // migrate resident pages and the zero-fill writes a whole chunk —
        // neither belongs under the directory-wide write lock, and the
        // birth value depends on which layer placed the chunk.
        self.chunks.write().unwrap().set_birth_node(chunk, birth as u32);
    }

    fn allocate_large(&self, size: usize) -> Result<u64> {
        let cs = self.opts.chunk_size;
        let n = large_chunks(size, cs) as u32;
        self.stats.large_allocs.fetch_add(1, Ordering::Relaxed);
        // reserve the run under the lock, extend outside it (same
        // discipline as the small-chunk slow path: no ftruncate/mmap
        // syscalls under the directory-wide write lock), roll back the
        // reservation on failure
        let head = {
            let mut chunks = self.chunks.write().unwrap();
            chunks.take_large(n)
        };
        if let Err(e) = self.segment.extend_to((head + n) as usize * cs) {
            self.chunks.write().unwrap().free_large(head);
            self.health.extend_rollbacks.fetch_add(1, Ordering::Relaxed);
            self.tel.event(EventKind::ExtendRollback, 0, n as u64, 0, 0);
            return Err(e);
        }
        Ok(head as u64 * cs as u64)
    }

    #[inline]
    fn slot_offset(&self, chunk: u32, bin: u32, slot: u32) -> u64 {
        chunk as u64 * self.opts.chunk_size as u64
            + slot as u64 * size_of_bin(bin as usize) as u64
    }

    /// Deallocate a previously allocated offset. Like `free(3)`, the
    /// size is derived from the allocator's own metadata.
    pub fn deallocate(&self, offset: u64) -> Result<()> {
        let t0 = self.tel.maybe_start();
        let r = self.deallocate_inner(offset);
        if let Some(t) = t0 {
            self.tel.record(TelOp::Dealloc, t);
        }
        r
    }

    fn deallocate_inner(&self, offset: u64) -> Result<()> {
        self.check_writable()?;
        self.stats.deallocs.fetch_add(1, Ordering::Relaxed);
        let cs = self.opts.chunk_size as u64;
        let chunk = (offset / cs) as u32;
        let kind = {
            let chunks = self.chunks.read().unwrap();
            if (chunk as usize) >= chunks.len() {
                return Err(Error::Alloc(format!("deallocate: offset {offset} out of range")));
            }
            chunks.kind(chunk)
        };
        match kind {
            ChunkKind::Small { bin } => {
                let class = size_of_bin(bin as usize) as u64;
                if (offset % cs) % class != 0 {
                    return Err(Error::Alloc(format!(
                        "deallocate: offset {offset} not on a slot boundary"
                    )));
                }
                let spill = self.cache.push(bin, offset);
                if !spill.is_empty() {
                    self.route_frees(bin, &spill)?;
                }
                Ok(())
            }
            ChunkKind::LargeHead { .. } => {
                if offset % cs != 0 {
                    return Err(Error::Alloc(format!(
                        "deallocate: large offset {offset} not chunk-aligned"
                    )));
                }
                let n = {
                    let mut chunks = self.chunks.write().unwrap();
                    chunks.free_large(chunk)
                };
                // Large deallocations free physical + file space
                // immediately (§4.1).
                self.segment
                    .free_range(chunk as usize * cs as usize, n as usize * cs as usize)?;
                self.stats.freed_large_chunks.fetch_add(n as u64, Ordering::Relaxed);
                Ok(())
            }
            ChunkKind::Free | ChunkKind::LargeBody => Err(Error::Alloc(format!(
                "deallocate: offset {offset} is not the start of a live allocation"
            ))),
        }
    }

    /// Usable bytes of the allocation starting at `offset` (its internal
    /// size class for small objects, its chunk-run footprint for large
    /// ones). Errors if `offset` is not the start of an allocation.
    pub fn usable_size(&self, offset: u64) -> Result<usize> {
        let cs = self.opts.chunk_size as u64;
        let chunk = (offset / cs) as u32;
        let (kind, owner) = {
            let chunks = self.chunks.read().unwrap();
            if (chunk as usize) >= chunks.len() {
                return Err(Error::Alloc(format!("usable_size: offset {offset} out of range")));
            }
            (chunks.kind(chunk), chunks.owner(chunk) as usize)
        };
        match kind {
            ChunkKind::Small { bin } => {
                let class = size_of_bin(bin as usize) as u64;
                if (offset % cs) % class != 0 {
                    return Err(Error::Alloc(format!(
                        "usable_size: offset {offset} not on a slot boundary"
                    )));
                }
                // the slot must be claimed in the owning shard's bitset
                // (live, parked in an object cache, or queued as a remote
                // free — all count as allocated); this rejects
                // already-freed and never-allocated slots
                let slot = ((offset % cs) / class) as u32;
                let used = self.shards[owner].bins[bin as usize]
                    .read()
                    .unwrap()
                    .is_slot_used(chunk, slot);
                if !used {
                    return Err(Error::Alloc(format!(
                        "usable_size: offset {offset} is not a live allocation"
                    )));
                }
                Ok(class as usize)
            }
            ChunkKind::LargeHead { nchunks } => {
                if offset % cs != 0 {
                    return Err(Error::Alloc(format!(
                        "usable_size: large offset {offset} not chunk-aligned"
                    )));
                }
                Ok(nchunks as usize * cs as usize)
            }
            ChunkKind::Free | ChunkKind::LargeBody => Err(Error::Alloc(format!(
                "usable_size: offset {offset} is not the start of a live allocation"
            ))),
        }
    }

    /// Resize an allocation (the `realloc(3)` analogue the persistent
    /// containers' growth paths want). Returns the — possibly moved —
    /// offset; contents up to `min(old usable, new_size)` bytes are
    /// preserved. In place whenever the internal size class (small) or
    /// chunk-run footprint (large) is unchanged.
    pub fn reallocate(&self, offset: u64, new_size: usize) -> Result<u64> {
        self.check_writable()?;
        if new_size == 0 {
            return Err(Error::Alloc("zero-size reallocation".into()));
        }
        let old_usable = self.usable_size(offset)?;
        let cs = self.opts.chunk_size;
        let in_place = if is_small(new_size, cs) {
            is_small(old_usable, cs) && size_of_bin(bin_of(new_size)) == old_usable
        } else {
            !is_small(old_usable, cs) && large_chunks(new_size, cs) * cs == old_usable
        };
        if in_place {
            return Ok(offset);
        }
        let new_off = self.allocate(new_size)?;
        let copy = old_usable.min(new_size);
        // distinct live allocations never overlap
        unsafe {
            std::ptr::copy_nonoverlapping(self.ptr(offset), self.ptr(new_off), copy);
        }
        self.mark_data_dirty(new_off, copy); // after the copy (see write())
        self.deallocate(offset)?;
        Ok(new_off)
    }

    /// Route freed slots of one bin to their owning shards (cache spill
    /// path): home-shard slots are returned under the exclusive bin lock
    /// (serialization point #2), foreign slots are parked on the owner's
    /// remote-free queue — a plain mutex push, never the foreign shard's
    /// bin locks.
    fn route_frees(&self, bin: u32, offsets: &[u64]) -> Result<()> {
        if self.shards.len() == 1 {
            return self.return_slots(0, bin, offsets);
        }
        let cs = self.opts.chunk_size as u64;
        let home = self.shard_map.home_shard();
        let mut mine: Vec<u64> = Vec::new();
        let mut foreign: Vec<(usize, u64)> = Vec::new();
        {
            let chunks = self.chunks.read().unwrap();
            for &off in offsets {
                let owner = chunks.owner((off / cs) as u32) as usize;
                if owner == home {
                    mine.push(off);
                } else {
                    foreign.push((owner, off));
                }
            }
        }
        for &(owner, off) in &foreign {
            let sh = &self.shards[owner];
            sh.remote_free.lock().unwrap().push((bin, off));
            sh.stats.remote_frees.fetch_add(1, Ordering::Relaxed);
        }
        let mut result = Ok(());
        if !mine.is_empty() {
            keep_first_err(&mut result, self.return_slots(home, bin, &mine));
            // we are at our own serialization point anyway: drain what
            // other shards parked for us (no-op when the queue is empty)
            keep_first_err(&mut result, self.drain_remote(home));
        }
        result
    }

    /// Drain the cross-shard frees parked for `shard` back into its
    /// bitsets. Called by the shard itself at its serialization points
    /// and by the sync/close flush.
    fn drain_remote(&self, shard: usize) -> Result<()> {
        let sh = &self.shards[shard];
        let drained: Vec<(u32, u64)> = {
            let mut q = sh.remote_free.lock().unwrap();
            if q.is_empty() {
                return Ok(());
            }
            std::mem::take(&mut *q)
        };
        sh.stats.remote_drained.fetch_add(drained.len() as u64, Ordering::Relaxed);
        let mut by_bin: HashMap<u32, Vec<u64>> = HashMap::new();
        for (bin, off) in drained {
            by_bin.entry(bin).or_default().push(off);
        }
        let mut result = Ok(());
        for (bin, offs) in by_bin {
            keep_first_err(&mut result, self.return_slots(shard, bin, &offs));
        }
        result
    }

    /// Return freed slots of one bin — all owned by `shard` — to their
    /// bitsets (spill / remote-drain / close path). Runs under the owner
    /// shard's exclusive bin lock: chunk-empty detection and release
    /// (serialization point #2) must not race shared-path claims. Every
    /// slot is returned even if a chunk release hits hole-punch I/O
    /// errors; the first error is reported after the batch.
    fn return_slots(&self, shard: usize, bin: u32, offsets: &[u64]) -> Result<()> {
        let cs = self.opts.chunk_size as u64;
        let class = size_of_bin(bin as usize) as u64;
        let sh = &self.shards[shard];
        sh.stats.exclusive_acquires.fetch_add(1, Ordering::Relaxed);
        let mut b = sh.bins[bin as usize].write().unwrap();
        if !offsets.is_empty() {
            sh.mark_bin_dirty(bin as usize);
        }
        let mut result = Ok(());
        for &off in offsets {
            let chunk = (off / cs) as u32;
            let slot = ((off % cs) / class) as u32;
            let empty = b.free_slot(chunk, slot);
            if empty {
                // release the chunk entirely (bin → chunks order)
                b.remove_chunk(chunk);
                let mut chunks = self.chunks.write().unwrap();
                chunks.free_small_chunk_on(chunk, shard as u32);
                drop(chunks);
                sh.stats.freed_chunks.fetch_add(1, Ordering::Relaxed);
                keep_first_err(
                    &mut result,
                    self.segment.free_range(chunk as usize * cs as usize, cs as usize),
                );
            }
        }
        result
    }

    fn flush_cache(&self) -> Result<()> {
        let drained = self.cache.drain_all();
        // group by (owner shard, bin) to take each bin lock once
        let cs = self.opts.chunk_size as u64;
        let mut by_key: HashMap<(usize, u32), Vec<u64>> = HashMap::new();
        {
            let chunks = self.chunks.read().unwrap();
            for (bin, off) in drained {
                let owner = chunks.owner((off / cs) as u32) as usize;
                by_key.entry((owner, bin)).or_default().push(off);
            }
        }
        let mut result = Ok(());
        for ((shard, bin), offs) in by_key {
            keep_first_err(&mut result, self.return_slots(shard, bin, &offs));
        }
        for shard in 0..self.shards.len() {
            keep_first_err(&mut result, self.drain_remote(shard));
        }
        result
    }

    // -------------------------------------------------- memory access --

    /// Raw pointer to a segment offset.
    ///
    /// # Safety
    /// `offset` must be inside a live allocation large enough for the
    /// intended access, and aliasing rules are the caller's burden (the
    /// persistent containers uphold them structurally).
    pub unsafe fn ptr(&self, offset: u64) -> *mut u8 {
        debug_assert!((offset as usize) < self.segment.mapped_len());
        self.segment.base().add(offset as usize)
    }

    /// Read a POD value at `offset`.
    pub fn read<T: Persist>(&self, offset: u64) -> T {
        assert!(offset as usize + std::mem::size_of::<T>() <= self.segment.mapped_len());
        unsafe { std::ptr::read_unaligned(self.ptr(offset) as *const T) }
    }

    /// Write a POD value at `offset`.
    pub fn write<T: Persist>(&self, offset: u64, value: T) {
        assert!(!self.read_only, "write on read-only datastore");
        assert!(offset as usize + std::mem::size_of::<T>() <= self.segment.mapped_len());
        unsafe { std::ptr::write_unaligned(self.ptr(offset) as *mut T, value) }
        // mark AFTER the store: a sync that swallows the mark must have
        // run after the bytes landed (mark-first could msync the chunk
        // pre-store and leave the write permanently unflushed)
        self.mark_data_dirty(offset, std::mem::size_of::<T>());
    }

    /// Byte-slice view of an allocation.
    ///
    /// # Safety
    /// Same contract as [`Self::ptr`] plus no concurrent writer.
    pub unsafe fn bytes(&self, offset: u64, len: usize) -> &[u8] {
        self.segment.slice(offset as usize, len)
    }

    /// # Safety
    /// Same as [`Self::bytes`] plus exclusivity.
    ///
    /// Note on background sync: the range is marked dirty when the view
    /// is handed out (mark-before-write — see below), so a
    /// watermark-driven background flush can consume the mark while the
    /// caller is still storing through the slice; the stores after that
    /// point are covered only by the *next* mark of the chunk (any later
    /// write) or by kernel write-back. Callers that need ticket-grade
    /// durability for bulk writes should use [`Self::write`] /
    /// `write_bytes` (which mark after the store) or re-mark with
    /// [`Self::mark_data_dirty`] once the writes are done.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn bytes_mut(&self, offset: u64, len: usize) -> &mut [u8] {
        // Handing out a mutable view marks the range written — the caller
        // has it precisely to write. This is inherently mark-before-write
        // (the writes happen through the returned slice), so a sync racing
        // the caller's stores only covers them under the documented
        // quiescence contract; the value-writing APIs mark after.
        self.mark_data_dirty(offset, len);
        self.segment.slice_mut(offset as usize, len)
    }

    // ---------------------------------------------------- named (§3.2) --

    /// Allocate, zero, and register `sizeof(T)` bytes under `name`
    /// (Table 2: `construct<T>(name)`), returning the offset. Fails if
    /// the name exists.
    pub fn construct<T: Persist>(&self, name: &str, value: T) -> Result<u64> {
        self.check_writable()?;
        if std::mem::align_of::<T>() > 8 {
            return Err(Error::Alloc(format!(
                "construct: alignment {} > 8 unsupported",
                std::mem::align_of::<T>()
            )));
        }
        let size = std::mem::size_of::<T>().max(1);
        let offset = self.allocate(size)?;
        unsafe {
            self.bytes_mut(offset, size).fill(0);
        }
        self.write(offset, value);
        let entry = NamedEntry {
            offset,
            size: size as u64,
            type_fp: type_fingerprint::<T>(),
        };
        let inserted = self.names.lock().unwrap().insert(name, entry);
        if !inserted {
            self.deallocate(offset)?;
            return Err(Error::Name(format!("name {name:?} already exists")));
        }
        Ok(offset)
    }

    /// Find a previously constructed object (Table 2: `find<T>(name)`).
    pub fn find<T: Persist>(&self, name: &str) -> Result<Option<u64>> {
        let names = self.names.lock().unwrap();
        match names.get(name) {
            None => Ok(None),
            Some(e) => {
                if e.type_fp != type_fingerprint::<T>() {
                    return Err(Error::Name(format!(
                        "find: type mismatch for {name:?} (stored fingerprint differs)"
                    )));
                }
                Ok(Some(e.offset))
            }
        }
    }

    /// Destroy a named object (Table 2: `destroy(name)`): deallocates and
    /// unregisters. Returns false if the name does not exist.
    pub fn destroy(&self, name: &str) -> Result<bool> {
        self.check_writable()?;
        let entry = self.names.lock().unwrap().remove(name);
        match entry {
            None => Ok(false),
            Some(e) => {
                self.deallocate(e.offset)?;
                Ok(true)
            }
        }
    }

    /// Number of named objects.
    pub fn num_named(&self) -> usize {
        self.names.lock().unwrap().len()
    }

    /// List named objects (for the `inspect` CLI).
    pub fn named_list(&self) -> Vec<(String, u64, u64)> {
        self.names
            .lock()
            .unwrap()
            .iter()
            .map(|(n, e)| (n.to_string(), e.offset, e.size))
            .collect()
    }

    /// Datastore health check (`metall doctor`): re-runs the management
    /// consistency validation and audits every named object. Returns a
    /// list of findings (empty = healthy). This is the "program that
    /// assesses compatibility / integrity" the paper's §3.5 sketches as
    /// future work. Runs under the flush gate so it never audits a
    /// store mid-background-epoch.
    pub fn doctor(&self) -> Result<Vec<String>> {
        let _gate = self.bg.gate();
        let mut findings = Vec::new();
        if let Some(reason) = self.wounded.get() {
            findings.push(format!(
                "wounded (degraded read-only after backend failure): {reason}"
            ));
        }
        if let Err(e) = self.validate_consistency() {
            findings.push(format!("management data: {e}"));
        }
        let mapped = self.segment.mapped_len() as u64;
        let cs = self.opts.chunk_size as u64;
        let chunks = self.chunks.read().unwrap();
        for (name, e) in self.names.lock().unwrap().iter() {
            if e.offset + e.size > mapped {
                findings.push(format!(
                    "named object {name:?} [{}..{}] exceeds mapped segment ({mapped})",
                    e.offset,
                    e.offset + e.size
                ));
                continue;
            }
            // the owning chunk must be live
            let chunk = (e.offset / cs) as u32;
            match chunks.kind(chunk) {
                ChunkKind::Free => findings.push(format!(
                    "named object {name:?} points into a FREE chunk {chunk}"
                )),
                ChunkKind::LargeBody => findings.push(format!(
                    "named object {name:?} points into a large-body chunk {chunk}"
                )),
                ChunkKind::Small { bin } => {
                    let class = size_of_bin(bin as usize) as u64;
                    if e.size > class {
                        findings.push(format!(
                            "named object {name:?} ({}B) larger than its slot class ({class}B)",
                            e.size
                        ));
                    }
                }
                ChunkKind::LargeHead { .. } => {}
            }
        }
        // chunk accounting must be structurally valid
        if !chunks.validate() {
            findings.push("chunk directory structure invalid".into());
        }
        // container audit re-takes the chunk lock through usable_size —
        // release ours first (a queued writer would wedge a re-read)
        drop(chunks);
        findings.extend(self.validate_containers());
        Ok(findings)
    }

    /// Explicit user-level msync statistics (bs-mmap mode only).
    pub fn bs_msync(&self) -> Result<crate::storage::bsmmap::FlushStats> {
        match &self.bs {
            Some(bs) => bs.lock().unwrap().msync(&self.segment),
            None => Err(Error::InvalidOp("not in bs-mmap (private) mode".into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn mk(dir: &Path) -> MetallManager {
        MetallManager::create_with(dir, ManagerOptions::small_for_tests()).unwrap()
    }

    /// Logical management image of a store: the newest complete
    /// manifest's section contents concatenated in section order. Two
    /// stores with the same image hold identical management state, no
    /// matter how many sync epochs produced it (file *names* differ by
    /// epoch; the bytes must not).
    fn mgmt_image(dir: &Path) -> Vec<u8> {
        let epochs = mgmt_io::list_manifest_epochs(dir).unwrap();
        for &e in epochs.iter().rev() {
            let Some(man) = mgmt_io::read_manifest(dir, e) else { continue };
            let Some(secs) = mgmt_io::load_sections(dir, &man) else { continue };
            let mut ids: Vec<SectionId> = secs.keys().copied().collect();
            ids.sort();
            let mut image = Vec::new();
            for id in ids {
                image.extend_from_slice(&secs[&id]);
            }
            return image;
        }
        panic!("no complete manifest in {dir:?}");
    }

    #[test]
    fn allocate_roundtrip_and_reattach() {
        let d = TempDir::new("mgr1");
        let store = d.join("store");
        let off;
        {
            let m = mk(&store);
            off = m.allocate(16).unwrap();
            m.write::<u64>(off, 0xDEADBEEF);
            m.write::<u64>(off + 8, 42);
            m.close().unwrap();
        }
        {
            let m = MetallManager::open(&store).unwrap();
            assert_eq!(m.read::<u64>(off), 0xDEADBEEF);
            assert_eq!(m.read::<u64>(off + 8), 42);
            m.close().unwrap();
        }
    }

    #[test]
    fn small_allocations_share_chunk_and_classes_separate() {
        let d = TempDir::new("mgr2");
        let m = mk(&d.join("s"));
        let a = m.allocate(8).unwrap();
        let b = m.allocate(8).unwrap();
        let c = m.allocate(16).unwrap();
        // same class → same chunk, adjacent slots
        assert_eq!(b - a, 8);
        // different class → different chunk
        assert_ne!(c / 65536, a / 65536);
    }

    #[test]
    fn cache_hit_on_realloc() {
        let d = TempDir::new("mgr3");
        let m = mk(&d.join("s"));
        let a = m.allocate(64).unwrap();
        m.deallocate(a).unwrap();
        let b = m.allocate(64).unwrap();
        assert_eq!(a, b, "object cache must return the freed slot (LIFO)");
        assert_eq!(m.stats().cache_hits, 1);
    }

    #[test]
    fn large_allocation_and_free_releases_file_space() {
        let d = TempDir::new("mgr4");
        let m = mk(&d.join("s"));
        let cs = m.chunk_size();
        let off = m.allocate(3 * cs).unwrap(); // rounds to 4 chunks
        assert_eq!(off % cs as u64, 0);
        unsafe { m.bytes_mut(off, 3 * cs).fill(0xAB) };
        m.sync().unwrap();
        let before = m.segment().allocated_file_blocks().unwrap();
        m.deallocate(off).unwrap();
        let after = m.segment().allocated_file_blocks().unwrap();
        assert!(after < before, "{before} -> {after}");
        // next large alloc reuses the hole
        let off2 = m.allocate(2 * cs).unwrap();
        assert_eq!(off2, off);
    }

    #[test]
    fn named_construct_find_destroy() {
        let d = TempDir::new("mgr5");
        let store = d.join("s");
        {
            let m = mk(&store);
            let off = m.construct::<u64>("answer", 42).unwrap();
            assert_eq!(m.read::<u64>(off), 42);
            assert!(m.construct::<u64>("answer", 43).is_err(), "duplicate name");
            m.close().unwrap();
        }
        {
            let m = MetallManager::open(&store).unwrap();
            let off = m.find::<u64>("answer").unwrap().expect("must exist");
            assert_eq!(m.read::<u64>(off), 42);
            // wrong type is rejected
            assert!(m.find::<u32>("answer").is_err());
            assert!(m.destroy("answer").unwrap());
            assert!(!m.destroy("answer").unwrap());
            assert_eq!(m.find::<u64>("answer").unwrap(), None);
            m.close().unwrap();
        }
    }

    #[test]
    fn read_only_mode_blocks_mutation() {
        let d = TempDir::new("mgr6");
        let store = d.join("s");
        {
            let m = mk(&store);
            m.construct::<u64>("x", 7).unwrap();
            m.close().unwrap();
        }
        let m = MetallManager::open_read_only(&store).unwrap();
        let off = m.find::<u64>("x").unwrap().unwrap();
        assert_eq!(m.read::<u64>(off), 7);
        assert!(m.allocate(8).is_err());
        assert!(m.destroy("x").is_err());
        assert!(m.construct::<u64>("y", 1).is_err());
        // two read-only opens may coexist (§3.6)
        let m2 = MetallManager::open_read_only(&store).unwrap();
        assert_eq!(m2.read::<u64>(off), 7);
    }

    #[test]
    fn unclean_store_is_refused() {
        let d = TempDir::new("mgr7");
        let store = d.join("s");
        {
            let m = mk(&store);
            m.allocate(8).unwrap();
            m.sync().unwrap();
            // simulate crash: forget without close
            std::mem::forget(m);
        }
        assert!(MetallManager::open(&store).is_err(), "dirty store must be refused");
        let m = MetallManager::open_unclean(&store).unwrap();
        m.close().unwrap();
        // now clean again
        MetallManager::open(&store).unwrap().close().unwrap();
    }

    #[test]
    fn snapshot_is_clean_and_independent() {
        let d = TempDir::new("mgr8");
        let store = d.join("s");
        let snap = d.join("snap");
        let m = mk(&store);
        let off = m.construct::<u64>("v", 1).unwrap();
        m.snapshot(&snap).unwrap();
        // mutate original after snapshot
        m.write::<u64>(off, 2);
        m.sync().unwrap();
        // snapshot opens clean and sees the old value
        let s = MetallManager::open(&snap).unwrap();
        let soff = s.find::<u64>("v").unwrap().unwrap();
        assert_eq!(s.read::<u64>(soff), 1);
        s.close().unwrap();
        assert_eq!(m.read::<u64>(off), 2);
    }

    #[test]
    fn multithreaded_alloc_dealloc_stress() {
        let d = TempDir::new("mgr9");
        let m = mk(&d.join("s"));
        let nthreads = 8;
        let per = 500;
        std::thread::scope(|s| {
            for t in 0..nthreads {
                let m = &m;
                s.spawn(move || {
                    let mut offs = Vec::new();
                    for i in 0..per {
                        let size = 8 + ((t * 13 + i * 7) % 500);
                        let off = m.allocate(size).unwrap();
                        // write a tag, verify later
                        m.write::<u64>(off, (t * per + i) as u64);
                        offs.push((off, (t * per + i) as u64, size));
                    }
                    // verify all, free half
                    for (j, &(off, tag, _)) in offs.iter().enumerate() {
                        assert_eq!(m.read::<u64>(off), tag, "thread {t} obj {j}");
                    }
                    for &(off, _, _) in offs.iter().step_by(2) {
                        m.deallocate(off).unwrap();
                    }
                });
            }
        });
        let st = m.stats();
        assert_eq!(st.allocs, (nthreads * per) as u64);
        assert_eq!(st.deallocs, (nthreads * per / 2) as u64);
        m.close().unwrap();
    }

    #[test]
    fn no_overlap_under_concurrency() {
        use std::collections::HashSet;
        let d = TempDir::new("mgr10");
        let m = mk(&d.join("s"));
        let results: Vec<Vec<(u64, usize)>> = std::thread::scope(|s| {
            (0..4)
                .map(|_t| {
                    let m = &m;
                    s.spawn(move || {
                        (0..300)
                            .map(|i| {
                                let size = 8 << (i % 4); // 8,16,32,64
                                (m.allocate(size).unwrap(), size)
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        let mut seen = HashSet::new();
        for (off, size) in results.into_iter().flatten() {
            // class-rounded extent must not overlap any other allocation
            let class = size_of_bin(bin_of(size));
            for b in (off..off + class as u64).step_by(8) {
                assert!(seen.insert(b), "overlap at {b}");
            }
        }
        m.close().unwrap();
    }

    #[test]
    fn empty_chunk_is_released() {
        let d = TempDir::new("mgr11");
        let m = mk(&d.join("s"));
        // fill exactly one chunk of 32 KiB-class objects (64 KiB chunk → 2 slots)
        let a = m.allocate(32 << 10).unwrap();
        let b = m.allocate(32 << 10).unwrap();
        m.deallocate(a).unwrap();
        m.deallocate(b).unwrap();
        // force the cache out (sync alone preserves cache warmth now)
        m.flush_object_caches().unwrap();
        m.sync().unwrap();
        assert!(m.stats().freed_chunks >= 1);
        assert_eq!(m.used_segment_bytes(), 0);
        m.close().unwrap();
    }

    #[test]
    fn bad_deallocates_are_rejected() {
        let d = TempDir::new("mgr12");
        let m = mk(&d.join("s"));
        let off = m.allocate(8).unwrap();
        assert!(m.deallocate(off + 4).is_err(), "mid-slot offset");
        assert!(m.deallocate(10 << 20).is_err(), "out of range");
        m.deallocate(off).unwrap();
        m.close().unwrap();
    }

    #[test]
    fn zero_size_alloc_rejected() {
        let d = TempDir::new("mgr13");
        let m = mk(&d.join("s"));
        assert!(m.allocate(0).is_err());
    }

    #[test]
    fn fast_path_claims_batch_and_refills_cache() {
        let d = TempDir::new("mgr16");
        let m = mk(&d.join("s"));
        let a = m.allocate(64).unwrap(); // fresh chunk via slow path
        let b = m.allocate(64).unwrap(); // lock-free claim + batch refill
        assert_eq!(b - a, 64, "adjacent slot from the same chunk");
        let st = m.stats();
        assert!(st.fast_claims >= 2, "batch claim recorded: {}", st.fast_claims);
        // the parked surplus now serves allocations as pure cache hits
        let c = m.allocate(64).unwrap();
        assert_eq!(c - b, 64);
        assert!(m.stats().cache_hits >= 1);
        m.close().unwrap();
    }

    #[test]
    fn reallocate_in_place_and_moving() {
        let d = TempDir::new("mgr17");
        let m = mk(&d.join("s"));
        let off = m.allocate(50).unwrap(); // class 56
        m.write::<u64>(off, 0xAA55);
        // still inside the same class → in place
        let same = m.reallocate(off, 56).unwrap();
        assert_eq!(same, off);
        // grow to another class → moves, contents preserved
        let moved = m.reallocate(off, 500).unwrap();
        assert_ne!(moved, off);
        assert_eq!(m.read::<u64>(moved), 0xAA55);
        // grow to a large allocation → moves again, contents preserved
        let cs = m.chunk_size();
        let large = m.reallocate(moved, cs).unwrap();
        assert_eq!(m.read::<u64>(large), 0xAA55);
        assert_eq!(m.usable_size(large).unwrap() % cs, 0);
        // shrink back to small
        let small = m.reallocate(large, 8).unwrap();
        assert_eq!(m.read::<u64>(small), 0xAA55);
        m.deallocate(small).unwrap();
        assert!(m.reallocate(1 << 40, 8).is_err(), "bogus offset rejected");
        m.close().unwrap();
    }

    #[test]
    fn doctor_reports_healthy_after_churn() {
        let d = TempDir::new("mgr15");
        let m = mk(&d.join("s"));
        for i in 0..100u64 {
            m.construct::<u64>(&format!("k{i}"), i).unwrap();
        }
        for i in (0..100u64).step_by(2) {
            m.destroy(&format!("k{i}")).unwrap();
        }
        let big = m.allocate(200 << 10).unwrap();
        m.deallocate(big).unwrap();
        assert!(m.doctor().unwrap().is_empty(), "healthy store, no findings");
        m.close().unwrap();
    }

    #[test]
    fn shard1_layout_is_deterministic() {
        use crate::alloc::object_cache::pin_thread_vcpu;
        // Two identical traces at shards=1 must produce byte-identical
        // stores — the shard=1 equivalence guarantee (every sharded path
        // collapses to the unsharded one: pools bypassed, remote queues
        // empty, merged serialization of one part is the identity).
        let d = TempDir::new("mgr-shard-det");
        let run = |store: &Path| {
            pin_thread_vcpu(Some(0));
            let m = mk(store);
            let mut offs = Vec::new();
            for i in 0..600usize {
                let off = m.allocate(8 + (i * 37) % 2000).unwrap();
                m.write::<u64>(off, i as u64);
                offs.push(off);
                if i % 3 == 0 {
                    let victim = offs.remove((i * 7) % offs.len());
                    m.deallocate(victim).unwrap();
                }
            }
            let big = m.allocate(100 << 10).unwrap(); // large (> chunk/2)
            m.deallocate(big).unwrap();
            m.close().unwrap();
            pin_thread_vcpu(None);
        };
        run(&d.join("a"));
        run(&d.join("b"));
        let (mgmt_a, mgmt_b) = (mgmt_image(&d.join("a")), mgmt_image(&d.join("b")));
        assert_eq!(mgmt_a, mgmt_b, "management data bit-identical");
        let files = |p: &Path| {
            let mut v: Vec<_> = std::fs::read_dir(p.join("segment"))
                .unwrap()
                .map(|e| e.unwrap().path())
                .collect();
            v.sort();
            v
        };
        let (fa, fb) = (files(&d.join("a")), files(&d.join("b")));
        assert_eq!(fa.len(), fb.len(), "same backing files");
        for (a, b) in fa.iter().zip(&fb) {
            assert_eq!(a.file_name(), b.file_name());
            assert_eq!(
                std::fs::read(a).unwrap(),
                std::fs::read(b).unwrap(),
                "segment file {a:?} bit-identical"
            );
        }
    }

    #[test]
    fn cross_shard_free_routes_through_remote_queue() {
        use crate::alloc::object_cache::{pin_thread_vcpu, PER_BIN_CAP};
        let d = TempDir::new("mgr-xshard");
        let store = d.join("s");
        let mut o = ManagerOptions::small_for_tests();
        o.shards = 2;
        // explicit single-node topology: vcpu → shard stays the plain
        // modulo wherever this test runs (a detected multi-node topology
        // would route both pinned vcpus by node instead)
        o.topology = Some(Topology::fake(&[2]));
        let m = MetallManager::create_with(&store, o).unwrap();
        // allocate on shard 0…
        pin_thread_vcpu(Some(0));
        let n = 2 * PER_BIN_CAP;
        let offs: Vec<u64> = (0..n).map(|_| m.allocate(64).unwrap()).collect();
        pin_thread_vcpu(None);
        // …free everything from a thread homed on shard 1: spills must be
        // parked on shard 0's remote queue, never shard 0's bin locks
        std::thread::scope(|s| {
            let (m, offs) = (&m, &offs);
            s.spawn(move || {
                pin_thread_vcpu(Some(1));
                for &off in offs {
                    m.deallocate(off).unwrap();
                }
            });
        });
        let ss = m.shard_stats();
        assert!(ss[0].remote_frees > 0, "cross-shard frees queued: {ss:?}");
        // explicit cache flush + sync drains caches and remote queues:
        // nothing may leak
        m.flush_object_caches().unwrap();
        m.sync().unwrap();
        assert_eq!(m.used_segment_bytes(), 0, "no leaked slots");
        let agg = m.stats();
        assert_eq!(agg.allocs, n as u64);
        assert_eq!(agg.deallocs, n as u64);
        assert_eq!(
            agg.fast_claims,
            ss.iter().map(|s| s.fast_claims).sum::<u64>(),
            "totals aggregate the per-shard counters"
        );
        assert!(m.doctor().unwrap().is_empty());
        m.close().unwrap();
        let m = MetallManager::open(&store).unwrap();
        assert_eq!(m.used_segment_bytes(), 0);
        m.close().unwrap();
    }

    #[test]
    fn reopen_with_different_shard_count() {
        use crate::alloc::object_cache::pin_thread_vcpu;
        let d = TempDir::new("mgr-reshard");
        let store = d.join("s");
        let mut live: Vec<(u64, u64)> = Vec::new();
        {
            let mut o = ManagerOptions::small_for_tests();
            o.shards = 4;
            let m = MetallManager::create_with(&store, o).unwrap();
            assert_eq!(m.num_shards(), 4);
            for i in 0..400u64 {
                // rotate home shards so chunks of every bin spread over
                // all four shards and frees cross shards
                pin_thread_vcpu(Some((i % 4) as usize));
                let off = m.allocate(16 + (i as usize % 700)).unwrap();
                m.write::<u64>(off, i);
                live.push((off, i));
                if i % 4 == 3 {
                    let (voff, _) = live.remove((i as usize * 13) % live.len());
                    m.deallocate(voff).unwrap();
                }
            }
            pin_thread_vcpu(None);
            m.close().unwrap();
        }
        let golden = mgmt_image(&store);
        // a store written with 4 shards reopens and validates with any
        // shard count; closing again rewrites identical management bytes
        for reopen_shards in [1usize, 2, 4, 3] {
            let mut o = ManagerOptions::small_for_tests();
            o.shards = reopen_shards;
            let m = MetallManager::open_with(&store, o, false, false)
                .unwrap_or_else(|e| panic!("reopen with {reopen_shards} shards: {e}"));
            assert_eq!(m.num_shards(), reopen_shards);
            for &(off, tag) in &live {
                assert_eq!(m.read::<u64>(off), tag, "shards={reopen_shards} offset {off}");
                assert!(m.usable_size(off).unwrap() >= 8);
            }
            assert!(m.doctor().unwrap().is_empty());
            m.close().unwrap();
            assert_eq!(
                mgmt_image(&store),
                golden,
                "shards={reopen_shards}: persistent image unchanged by reopen"
            );
        }
        // everything frees cleanly under yet another shard count
        let mut o = ManagerOptions::small_for_tests();
        o.shards = 2;
        let m = MetallManager::open_with(&store, o, false, false).unwrap();
        pin_thread_vcpu(Some(1));
        for &(off, _) in &live {
            m.deallocate(off).unwrap();
        }
        pin_thread_vcpu(None);
        m.flush_object_caches().unwrap();
        m.sync().unwrap();
        assert_eq!(m.used_segment_bytes(), 0, "no leaked slots after reshard churn");
        m.close().unwrap();
    }

    #[test]
    fn topology_sizes_default_shard_count() {
        let d = TempDir::new("mgr-topo-size");
        // 2 nodes × 4 cpus → 4 shards (min(8, 4), already a multiple of 2)
        let mut o = ManagerOptions::small_for_tests();
        o.shards = 0;
        o.topology = Some(Topology::fake(&[4, 4]));
        let m = MetallManager::create_with(d.join("a"), o).unwrap();
        assert_eq!(m.num_shards(), 4);
        assert_eq!(m.topology().num_nodes(), 2);
        m.close().unwrap();
        // 3 nodes × 1 cpu → 3 shards, one per node
        let mut o = ManagerOptions::small_for_tests();
        o.shards = 0;
        o.topology = Some(Topology::fake(&[1, 1, 1]));
        let m = MetallManager::create_with(d.join("b"), o).unwrap();
        assert_eq!(m.num_shards(), 3);
        m.close().unwrap();
        // an explicit shard count always wins over the topology
        let mut o = ManagerOptions::small_for_tests();
        o.shards = 2;
        o.topology = Some(Topology::fake(&[4, 4]));
        let m = MetallManager::create_with(d.join("c"), o).unwrap();
        assert_eq!(m.num_shards(), 2);
        m.close().unwrap();
    }

    #[test]
    fn fake_two_node_fresh_chunks_first_touched_by_owner() {
        use crate::alloc::object_cache::pin_thread_vcpu;
        let d = TempDir::new("mgr-numa-ft");
        let mut o = ManagerOptions::small_for_tests();
        o.shards = 4;
        o.topology = Some(Topology::fake(&[4, 4])); // satellite shape
        let m = MetallManager::create_with(d.join("s"), o).unwrap();
        // vcpu 0 is node 0 → shard 0; vcpu 4 is node 1 → shard 1
        pin_thread_vcpu(Some(0));
        let a = m.allocate(64).unwrap();
        pin_thread_vcpu(Some(4));
        let b = m.allocate(64).unwrap();
        // the foreign-node thread writing into shard 0's chunk must not
        // steal its placement: the owner already first-touched every page
        m.write::<u64>(a, 0xF00D);
        pin_thread_vcpu(None);
        let ss = m.shard_stats();
        assert!(ss[0].fresh_chunks >= 1 && ss[1].fresh_chunks >= 1, "{ss:?}");
        // every fresh chunk was placed by exactly one layer: mbind when
        // the kernel has it, else owner zeroing — never left to whatever
        // foreign thread faults it first
        for s in &ss {
            assert_eq!(
                s.bound_chunks + s.first_touch_chunks,
                s.fresh_chunks,
                "shard {}: every fresh chunk bound or owner-touched",
                s.shard
            );
        }
        let r = m.placement_report();
        assert_eq!(r.source, PlacementSource::Recorded, "injected topology");
        assert_eq!(r.accounted_pages(), r.total_pages, "report is total");
        for s in &r.per_shard {
            assert_eq!(s.remote_pages, 0, "shard {}: all chunks born local", s.shard);
            assert_eq!(s.unknown_pages, 0, "shard {}: all chunks attributed", s.shard);
        }
        let frac = r.node_local_fraction().expect("live chunks attributed");
        assert!(frac >= 0.95, "≥95% node-local, got {frac}");
        // shard homes alternate nodes (round-robin deal)
        assert_eq!(
            r.per_shard.iter().map(|s| s.node).collect::<Vec<_>>(),
            vec![0, 1, 0, 1]
        );
        assert_eq!(m.read::<u64>(a), 0xF00D);
        let _ = b;
        m.close().unwrap();
    }

    #[test]
    fn single_node_skips_first_touch_and_reports_local() {
        let d = TempDir::new("mgr-numa-1n");
        let mut o = ManagerOptions::small_for_tests();
        o.topology = Some(Topology::fake(&[2]));
        let m = MetallManager::create_with(d.join("s"), o).unwrap();
        let off = m.allocate(64).unwrap();
        let big = m.allocate(3 * m.chunk_size()).unwrap();
        let ss = m.shard_stats();
        assert_eq!(ss[0].first_touch_chunks, 0, "single node: no zeroing pass");
        assert_eq!(ss[0].bound_chunks, 0, "single node: no binding either");
        let r = m.placement_report();
        assert_eq!(r.accounted_pages(), r.total_pages);
        assert!(r.large_pages > 0 && r.per_shard[0].pages > 0);
        assert_eq!(r.per_shard[0].node, 0);
        assert_eq!(r.per_shard[0].pages, r.per_shard[0].node_local_pages);
        assert_eq!(r.node_local_fraction(), Some(1.0));
        m.deallocate(big).unwrap();
        m.deallocate(off).unwrap();
        m.close().unwrap();
    }

    #[test]
    fn dirty_chunk_set_preserves_bits_past_the_limit() {
        // a mark racing a sync (segment extended after the sync read
        // mapped_len) must survive for the next sync — including in the
        // word straddling the limit
        let s = DirtyChunkSet::new(256);
        s.mark(3);
        s.mark(60);
        s.mark(62); // same word as 60, past limit 61
        s.mark(130); // wholly past the limit
        assert_eq!(s.take_dirty(61), vec![3, 60]);
        assert_eq!(s.take_dirty(256), vec![62, 130], "raced marks preserved");
        assert!(s.take_dirty(256).is_empty());
    }

    #[test]
    fn incremental_sync_rewrites_only_dirty_sections() {
        use crate::alloc::object_cache::pin_thread_vcpu;
        let d = TempDir::new("mgr-incsync");
        let store = d.join("s");
        // pinned vcpu: every cache op hits one slot, so the section byte
        // counts compared below are deterministic
        pin_thread_vcpu(Some(0));
        let m = mk(&store);
        for i in 0..100u64 {
            m.construct::<u64>(&format!("k{i}"), i).unwrap();
        }
        m.sync().unwrap();
        let st1 = m.sync_stats();
        assert_eq!(st1.dirty_sections, st1.total_sections, "first sync writes everything");
        assert_eq!(st1.manifest_commits, 1);
        assert!(st1.section_bytes_written > 0);
        // no-op sync: zero section bytes, zero data, no new manifest
        m.sync().unwrap();
        let st2 = m.sync_stats();
        assert_eq!(st2.syncs, 2);
        assert_eq!(st2.dirty_sections, 0, "nothing changed");
        assert_eq!(st2.section_bytes_written, 0, "no-op sync writes zero section bytes");
        assert_eq!(st2.data_chunks_flushed, 0);
        assert_eq!(st2.manifest_commits, 1, "no new manifest committed");
        // touch one value + one name: the next sync rewrites a strict
        // subset of the sections and flushes one data chunk
        m.write::<u64>(m.find::<u64>("k3").unwrap().unwrap(), 999);
        m.construct::<u64>("extra", 1).unwrap();
        m.sync().unwrap();
        let st3 = m.sync_stats();
        assert!(st3.dirty_sections >= 1, "{st3:?}");
        assert!(st3.dirty_sections < st3.total_sections, "{st3:?}");
        assert!(st3.section_bytes_written > 0);
        assert!(
            st3.section_bytes_written < st1.section_bytes_written,
            "delta write smaller than the full image: {st3:?} vs {st1:?}"
        );
        assert!(st3.data_chunks_flushed >= 1);
        assert_eq!(st3.manifest_commits, 2);
        m.close().unwrap();
        pin_thread_vcpu(None);
        // the incremental chain reopens with everything intact
        let m = MetallManager::open(&store).unwrap();
        assert_eq!(m.read::<u64>(m.find::<u64>("k3").unwrap().unwrap()), 999);
        assert!(m.find::<u64>("extra").unwrap().is_some());
        for i in [0u64, 42, 99] {
            let off = m.find::<u64>(&format!("k{i}")).unwrap().unwrap();
            if i != 3 {
                assert_eq!(m.read::<u64>(off), i);
            }
        }
        assert!(m.doctor().unwrap().is_empty());
        m.close().unwrap();
    }

    #[test]
    fn data_flush_narrows_to_dirty_chunks() {
        let d = TempDir::new("mgr-narrow");
        let m = mk(&d.join("s"));
        let cs = m.chunk_size();
        let big = m.allocate(3 * cs).unwrap(); // rounds to a 4-chunk run
        unsafe { m.bytes_mut(big, 3 * cs).fill(0xCD) };
        m.sync().unwrap();
        assert!(m.sync_stats().data_chunks_flushed >= 3, "{:?}", m.sync_stats());
        // one 8-byte write → exactly one chunk flushed
        m.write::<u64>(big, 7);
        m.sync().unwrap();
        let st = m.sync_stats();
        assert_eq!(st.data_chunks_flushed, 1, "{st:?}");
        assert_eq!(st.data_bytes_flushed, cs as u64, "{st:?}");
        assert_eq!(st.dirty_sections, 0, "pure data writes touch no section");
        // a write spanning a chunk boundary flushes both sides
        m.write::<u64>(big + cs as u64 - 4, 1);
        m.sync().unwrap();
        assert_eq!(m.sync_stats().data_chunks_flushed, 2);
        m.deallocate(big).unwrap();
        m.close().unwrap();
    }

    #[test]
    fn sync_preserves_cache_warmth() {
        use crate::alloc::object_cache::pin_thread_vcpu;
        let d = TempDir::new("mgr-warm");
        let m = mk(&d.join("s"));
        // pinned: the pop after the sync must hit the slot the free
        // parked into, whatever CPU the test thread migrates across
        pin_thread_vcpu(Some(0));
        let a = m.allocate(64).unwrap();
        m.deallocate(a).unwrap(); // parked in this core's cache
        let hits0 = m.stats().cache_hits;
        m.sync().unwrap();
        assert!(
            m.sync_stats().cache_slots_preserved >= 1,
            "{:?}",
            m.sync_stats()
        );
        assert!(m.used_segment_bytes() > 0, "cached slot still claims its chunk");
        let b = m.allocate(64).unwrap();
        assert_eq!(b, a, "sync left the freed slot cached (LIFO)");
        assert_eq!(m.stats().cache_hits, hits0 + 1, "served from cache, no locks");
        m.deallocate(b).unwrap();
        m.close().unwrap();
        pin_thread_vcpu(None);
    }

    #[test]
    fn crash_between_syncs_recovers_cached_slots() {
        let d = TempDir::new("mgr-cacherec");
        let store = d.join("s");
        {
            let m = mk(&store);
            let offs: Vec<u64> = (0..40).map(|_| m.allocate(64).unwrap()).collect();
            for &off in &offs {
                m.deallocate(off).unwrap(); // all parked in caches
            }
            m.sync().unwrap(); // bitsets still claim them; cache section records them
            assert!(m.used_segment_bytes() > 0);
            std::mem::forget(m); // crash without close
        }
        let m = MetallManager::open_unclean(&store).unwrap();
        assert_eq!(
            m.used_segment_bytes(),
            0,
            "recovery returned every parked slot and released the chunk"
        );
        assert!(m.doctor().unwrap().is_empty());
        m.close().unwrap();
    }

    #[test]
    fn legacy_monolithic_management_reopens_and_converts() {
        let d = TempDir::new("mgr-legacy");
        let store = d.join("s");
        {
            let m = mk(&store);
            for i in 0..30u64 {
                m.construct::<u64>(&format!("v{i}"), i * 3).unwrap();
            }
            m.close().unwrap();
        }
        // convert the segmented store to the pre-segmentation monolithic
        // format: magic + nb + chunk dir + every bin + names, then remove
        // the manifest machinery (a close()d store has an empty cache
        // section, so the monolith loses nothing)
        let nb = num_bins(ManagerOptions::small_for_tests().chunk_size);
        let epochs = mgmt_io::list_manifest_epochs(&store).unwrap();
        let man = mgmt_io::read_manifest(&store, *epochs.last().unwrap()).unwrap();
        let secs = mgmt_io::load_sections(&store, &man).unwrap();
        assert_eq!(
            mgmt_io::decode_cache_section(&secs[&SectionId::Cache]).unwrap(),
            vec![],
            "closed store has an empty cache section"
        );
        let mut legacy = Vec::new();
        legacy.extend_from_slice(MGMT_MAGIC);
        legacy.extend_from_slice(&(nb as u32).to_le_bytes());
        legacy.extend_from_slice(&secs[&SectionId::Chunks]);
        for g in 0..mgmt_io::num_groups(nb) {
            legacy.extend_from_slice(&secs[&SectionId::Bins(g as u32)]);
        }
        legacy.extend_from_slice(&secs[&SectionId::Names]);
        std::fs::write(store.join("management.bin"), &legacy).unwrap();
        for entry in std::fs::read_dir(&store).unwrap().flatten() {
            let name = entry.file_name();
            let name = name.to_str().unwrap();
            if name.starts_with("manifest-") || name.starts_with("mgmt-") {
                std::fs::remove_file(entry.path()).unwrap();
            }
        }
        // the legacy store opens; closing converts it to the segmented
        // format and removes the monolith
        {
            let m = MetallManager::open(&store).unwrap();
            for i in 0..30u64 {
                let off = m.find::<u64>(&format!("v{i}")).unwrap().unwrap();
                assert_eq!(m.read::<u64>(off), i * 3, "legacy value {i}");
            }
            assert!(m.doctor().unwrap().is_empty());
            m.close().unwrap();
        }
        assert!(!store.join("management.bin").exists(), "monolith superseded");
        assert!(!mgmt_io::list_manifest_epochs(&store).unwrap().is_empty());
        let m = MetallManager::open(&store).unwrap();
        assert_eq!(m.num_named(), 30);
        m.close().unwrap();
    }

    #[test]
    fn foreign_bin_group_width_triggers_full_rewrite() {
        // A manifest written by a build with a different BINS_PER_GROUP
        // must load correctly (the width is recorded in the manifest) and
        // the next sync must rewrite *every* section — carrying 4-wide
        // bin-group files forward into an 8-wide manifest would corrupt
        // the chain on the following open.
        let d = TempDir::new("mgr-bpg");
        let store = d.join("s");
        {
            let m = mk(&store);
            for i in 0..20u64 {
                m.construct::<u64>(&format!("w{i}"), i + 7).unwrap();
            }
            m.close().unwrap();
        }
        // Rewrite the store as if a BINS_PER_GROUP=4 build had synced it:
        // split each 8-wide group section into per-bin byte runs and
        // regroup them 4 wide, then commit a manifest declaring width 4.
        let nb = num_bins(ManagerOptions::small_for_tests().chunk_size);
        let epochs = mgmt_io::list_manifest_epochs(&store).unwrap();
        let man = mgmt_io::read_manifest(&store, *epochs.last().unwrap()).unwrap();
        let secs = mgmt_io::load_sections(&store, &man).unwrap();
        let mut per_bin: Vec<Vec<u8>> = Vec::with_capacity(nb);
        for g in 0..mgmt_io::num_groups(nb) {
            let buf = &secs[&SectionId::Bins(g as u32)];
            let mut pos = 0;
            for _ in mgmt_io::group_bins(g, nb) {
                let (_, used) = BinData::deserialize_from(&buf[pos..]).unwrap();
                per_bin.push(buf[pos..pos + used].to_vec());
                pos += used;
            }
        }
        assert_eq!(per_bin.len(), nb);
        let epoch2 = man.epoch + 1;
        let mut sections: Vec<SectionRecord> = man
            .sections
            .iter()
            .filter(|r| !matches!(r.id, SectionId::Bins(_)))
            .cloned()
            .collect();
        for (g, bins) in per_bin.chunks(4).enumerate() {
            let bytes: Vec<u8> = bins.concat();
            let id = SectionId::Bins(g as u32);
            let file = id.file_name(epoch2);
            mgmt_io::write_section_file(&store, &file, &bytes).unwrap();
            sections.push(SectionRecord {
                id,
                file,
                len: bytes.len() as u64,
                checksum: mgmt_io::fnv1a(&bytes),
            });
        }
        sections.sort_by_key(|r| r.id);
        let foreign = mgmt_io::Manifest {
            epoch: epoch2,
            num_bins: nb as u32,
            bins_per_group: 4,
            sections,
        };
        mgmt_io::commit_manifest(&store, &foreign).unwrap();
        // the foreign-width store opens and a mutating sync rewrites all
        {
            let m = MetallManager::open(&store).unwrap();
            for i in 0..20u64 {
                let off = m.find::<u64>(&format!("w{i}")).unwrap().unwrap();
                assert_eq!(m.read::<u64>(off), i + 7, "foreign-width value {i}");
            }
            m.construct::<u64>("bpg", 1).unwrap();
            m.sync().unwrap();
            let st = m.sync_stats();
            assert_eq!(
                st.dirty_sections, st.total_sections,
                "width mismatch forces a full section rewrite: {st:?}"
            );
            m.close().unwrap();
        }
        // the re-homed chain keeps reopening correctly
        let m = MetallManager::open(&store).unwrap();
        assert_eq!(m.num_named(), 21);
        assert_eq!(m.read::<u64>(m.find::<u64>("w9").unwrap().unwrap()), 16);
        assert!(m.doctor().unwrap().is_empty());
        m.close().unwrap();
    }

    #[test]
    fn torn_section_falls_back_to_previous_manifest() {
        let d = TempDir::new("mgr-torn");
        let store = d.join("s");
        {
            let m = mk(&store);
            m.construct::<u64>("a", 1).unwrap();
            m.sync().unwrap(); // epoch 1: complete
            m.construct::<u64>("b", 2).unwrap();
            m.sync().unwrap(); // epoch 2: rewrote names (among others)
            std::mem::forget(m); // crash without close
        }
        let epochs = mgmt_io::list_manifest_epochs(&store).unwrap();
        assert_eq!(epochs, vec![1, 2], "current + fallback manifests retained");
        // tear epoch 2's names section (a file the second sync wrote)
        let man2 = mgmt_io::read_manifest(&store, 2).unwrap();
        let rec = man2.section(SectionId::Names).unwrap();
        assert!(rec.file.contains("000000000002"), "names rewritten at epoch 2");
        let bytes = std::fs::read(store.join(&rec.file)).unwrap();
        std::fs::write(store.join(&rec.file), &bytes[..bytes.len() / 2]).unwrap();
        // recovery skips the torn epoch 2 and opens epoch 1's state
        let m = MetallManager::open_unclean(&store).unwrap();
        assert!(m.find::<u64>("a").unwrap().is_some(), "epoch-1 state present");
        assert!(m.find::<u64>("b").unwrap().is_none(), "torn epoch-2 state absent");
        assert!(m.doctor().unwrap().is_empty());
        // the recovered store keeps working: the next sync re-commits
        // epoch 2 over the torn leftovers
        m.construct::<u64>("c", 3).unwrap();
        m.close().unwrap();
        let m = MetallManager::open(&store).unwrap();
        assert!(m.find::<u64>("c").unwrap().is_some());
        m.close().unwrap();
    }

    #[test]
    fn orphan_large_reservation_past_mapped_extent_is_healed_on_open() {
        // Simulate the reserve-then-extend crash window: a LargeHead run
        // registered in the chunk directory (as a background epoch could
        // commit it) whose segment extension never happened. Recovery
        // must roll the run back to Free — no caller can hold its offset.
        let d = TempDir::new("mgr-orphan-large");
        let store = d.join("s");
        let small_used;
        {
            let m = mk(&store);
            m.construct::<u64>("x", 1).unwrap();
            small_used = m.used_segment_bytes();
            {
                // a 64-chunk run: far past the 1 MiB (16-chunk) first file
                let mut chunks = m.chunks.write().unwrap();
                chunks.take_large(64);
            }
            m.sync().unwrap(); // the "background epoch" committing the orphan
            std::mem::forget(m); // die before any extension
        }
        let m = MetallManager::open_unclean(&store).unwrap();
        assert_eq!(
            m.used_segment_bytes(),
            small_used,
            "orphan large run rolled back to Free"
        );
        assert!(m.doctor().unwrap().is_empty());
        // the healed space is reusable: a real large allocation works
        let off = m.allocate(2 * m.chunk_size()).unwrap();
        m.write::<u64>(off, 7);
        m.deallocate(off).unwrap();
        m.close().unwrap();
    }

    #[test]
    fn drop_without_close_performs_final_durable_sync_and_joins_flusher() {
        // The Drop-path contract (regression for the close/Drop audit):
        // dropping a manager without calling close() must still drain and
        // join the background flusher, run the final full sync, and leave
        // a CLEAN store — not a refused "unclean" one.
        let d = TempDir::new("mgr-drop");
        let store = d.join("s");
        {
            let m = mk(&store);
            let off = m.construct::<u64>("dropped", 0xD0D0).unwrap();
            m.write::<u64>(off, 0xD0D0);
            // start the engine and leave an un-waited ticket in flight:
            // Drop must resolve it, not abandon it
            let _ = m.sync_async().unwrap();
            m.allocate(128).unwrap();
            drop(m);
        }
        assert!(store.join(CLEAN_MARKER).exists(), "Drop left a durable CLEAN marker");
        let m = MetallManager::open(&store).expect("dropped store reopens cleanly");
        assert_eq!(m.read::<u64>(m.find::<u64>("dropped").unwrap().unwrap()), 0xD0D0);
        assert!(m.doctor().unwrap().is_empty());
        m.close().unwrap();
    }

    #[test]
    fn close_after_close_and_drop_are_idempotent() {
        let d = TempDir::new("mgr-close2");
        let store = d.join("s");
        let m = mk(&store);
        m.construct::<u64>("x", 1).unwrap();
        m.close().unwrap(); // close(), then the wrapper Drop: second entry is a no-op
        let m = MetallManager::open(&store).unwrap();
        assert_eq!(m.num_named(), 1);
        m.close().unwrap();
    }

    #[test]
    fn writers_during_snapshot_yield_consistent_snapshot() {
        use std::sync::atomic::AtomicBool;
        // The snapshot/doctor flush-gate contract: with a watermark-driven
        // background flusher racing writer threads, snapshot() must never
        // copy a half-committed epoch — each snapshot opens cleanly, is
        // structurally consistent, and holds the named baseline.
        let d = TempDir::new("mgr-snapwr");
        let store = d.join("s");
        let mut o = ManagerOptions::small_for_tests();
        o.sync_watermark_bytes = o.chunk_size; // flusher runs eagerly
        let m = MetallManager::create_with(&store, o).unwrap();
        let base = m.construct::<u64>("base", 42).unwrap();
        // Pre-size the working set: the writers mutate existing
        // allocations only (data writes feeding the watermark). The §3.3
        // contract still requires allocator quiescence for a consistent
        // *management* image, so the churn that moves chunks between
        // sections stays out of the race — what is under test is the
        // flush gate: watermark-driven background epochs must never be
        // caught half-committed by the snapshot copy.
        let pool: Vec<u64> = (0..64).map(|_| m.allocate(512).unwrap()).collect();
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            for t in 0..2u64 {
                let (m, pool, stop) = (&m, &pool, &stop);
                s.spawn(move || {
                    let mut i = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let off = pool[((t * 31 + i) % pool.len() as u64) as usize];
                        m.write::<u64>(off, i);
                        i += 1;
                    }
                });
            }
            for round in 0..3 {
                let snap = d.join(format!("snap{round}"));
                m.snapshot(&snap).unwrap();
                let s = MetallManager::open(&snap).expect("snapshot opens cleanly");
                assert_eq!(
                    s.read::<u64>(s.find::<u64>("base").unwrap().unwrap()),
                    42,
                    "round {round}: snapshotted baseline intact"
                );
                assert!(
                    s.doctor().unwrap().is_empty(),
                    "round {round}: snapshot structurally consistent under writers"
                );
                s.close().unwrap();
            }
            stop.store(true, Ordering::Relaxed);
        });
        assert_eq!(m.read::<u64>(base), 42);
        m.close().unwrap();
    }

    #[test]
    fn private_mode_persists_via_user_msync() {
        let d = TempDir::new("mgr14");
        let store = d.join("s");
        {
            let mut o = ManagerOptions::small_for_tests();
            o.private_mode = true;
            let m = MetallManager::create_with(&store, o).unwrap();
            let off = m.construct::<u64>("bs", 99).unwrap();
            let st = m.bs_msync().unwrap();
            assert!(st.dirty_pages > 0);
            let _ = off;
            m.close().unwrap();
        }
        let m = MetallManager::open(&store).unwrap();
        let off = m.find::<u64>("bs").unwrap().unwrap();
        assert_eq!(m.read::<u64>(off), 99);
        m.close().unwrap();
    }
}

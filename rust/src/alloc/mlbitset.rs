//! Compact multi-layer bitset (paper §4.3.1) with **lock-free slot
//! claims** (llfree-style word-level CAS).
//!
//! "Metall utilizes a compact multi-layer bitset table and built-in bit
//! operation functions to manage available slots in a chunk … It can
//! manage up to 64³ (= 2^18) slots using a three-layer structure …
//! Metall calls a built-in bit operation function at most three times to
//! find an available slot."
//!
//! Layer 2 is the actual slot bitmap (1 = occupied); layer 1 marks fully
//! occupied layer-2 words; layer 0 marks fully occupied layer-1 words.
//!
//! ## Concurrency model
//!
//! Every word is an [`AtomicU64`], so all operations take `&self`:
//!
//! - **Claims** (`find_and_set_first_zero`, `claim_batch`) are lock-free:
//!   the hint layers (l0/l1) are scanned read-only to pick a candidate
//!   layer-2 word, then the slot bit(s) are taken with a single
//!   `compare_exchange` on that word. A lost race simply retries; each
//!   failed CAS implies another thread succeeded, so the system always
//!   makes progress.
//! - **Layer-2 is authoritative; l0/l1 are hints.** After a claim fills a
//!   word, the summary bits are raised with `fetch_or` and re-validated
//!   (set-then-recheck), so a concurrent `clear` can never leave a stale
//!   "full" hint standing. If the hint scan comes up empty while `used()`
//!   says slots remain, a linear layer-2 word scan is the fallback — the
//!   paper's three-probe bound holds on the uncontended path.
//! - The exact `used` counter is maintained with atomic add/sub *after*
//!   the bit transition; it is exact at rest and conservatively lags
//!   mid-operation.
//!
//! The manager's bin directory drives claims under a shared (read) lock
//! and performs frees / chunk release under the exclusive (write) lock,
//! which keeps the two paper-listed serialization points (§4.5.1) as the
//! only exclusive sections.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

use crate::util::bits::lowest_zero;
use crate::util::div_ceil;

/// Up to 64³ slots, lazily sized for `capacity`. All operations take
/// `&self`; slot claims are word-level CAS (see module docs).
pub struct MlBitset {
    capacity: u32,
    used: AtomicU32,
    l0: AtomicU64,
    l1: Vec<AtomicU64>,
    l2: Vec<AtomicU64>,
}

pub const MAX_SLOTS: u32 = 64 * 64 * 64;

impl MlBitset {
    pub fn new(capacity: u32) -> Self {
        assert!(capacity >= 1 && capacity <= MAX_SLOTS, "capacity {capacity}");
        let n2 = div_ceil(capacity as usize, 64);
        let n1 = div_ceil(n2, 64);
        // Pre-mark the out-of-capacity tail as occupied so the scan never
        // hands out a slot ≥ capacity (tail bits are never cleared).
        let mut l2 = vec![0u64; n2];
        for slot in capacity..(n2 as u32 * 64) {
            l2[(slot / 64) as usize] |= 1 << (slot % 64);
        }
        let mut l1 = vec![0u64; n1];
        for (w2, &w) in l2.iter().enumerate() {
            if w == u64::MAX {
                l1[w2 / 64] |= 1 << (w2 % 64);
            }
        }
        let mut l0 = 0u64;
        for w1 in 0..n1 {
            let lo = w1 * 64;
            let hi = ((w1 + 1) * 64).min(n2);
            let mut word = l1[w1];
            for missing in (hi - lo)..64 {
                word |= 1 << missing;
            }
            if word == u64::MAX {
                l0 |= 1 << (w1 % 64);
            }
        }
        Self {
            capacity,
            used: AtomicU32::new(0),
            l0: AtomicU64::new(l0),
            l1: l1.into_iter().map(AtomicU64::new).collect(),
            l2: l2.into_iter().map(AtomicU64::new).collect(),
        }
    }

    pub fn capacity(&self) -> u32 {
        self.capacity
    }

    /// Number of occupied (real) slots.
    pub fn used(&self) -> u32 {
        self.used.load(Ordering::Acquire)
    }

    pub fn is_full(&self) -> bool {
        self.used() == self.capacity
    }

    pub fn is_empty(&self) -> bool {
        self.used() == 0
    }

    /// Is layer-1 word `w1` fully occupied, accounting for the virtual
    /// all-ones padding beyond the allocated l2 words?
    fn l1_word_full(&self, w1: usize) -> bool {
        let lo = w1 * 64;
        let hi = ((w1 + 1) * 64).min(self.l2.len());
        let mut word = self.l1[w1].load(Ordering::Acquire);
        for missing in (hi - lo)..64 {
            word |= 1 << missing;
        }
        word == u64::MAX
    }

    /// Raise the "full" hints for layer-2 word `w2`, then re-validate
    /// (set-then-recheck): if a concurrent `clear` reopened the word
    /// after we loaded it, withdraw the hint so it cannot go stale.
    fn mark_full_hints(&self, w2: usize) {
        let w1 = w2 / 64;
        self.l1[w1].fetch_or(1 << (w2 % 64), Ordering::AcqRel);
        if self.l2[w2].load(Ordering::Acquire) != u64::MAX {
            self.l1[w1].fetch_and(!(1 << (w2 % 64)), Ordering::AcqRel);
            return;
        }
        if self.l1_word_full(w1) {
            self.l0.fetch_or(1 << (w1 % 64), Ordering::AcqRel);
            if !self.l1_word_full(w1) {
                self.l0.fetch_and(!(1 << (w1 % 64)), Ordering::AcqRel);
            }
        }
    }

    /// Hint-guided descent l0 → l1: candidate layer-2 word with (probable)
    /// room. The paper's "at most three built-in bit operations" path.
    fn find_candidate_word(&self) -> Option<usize> {
        let mut l0 = self.l0.load(Ordering::Acquire);
        for missing in self.l1.len()..64 {
            l0 |= 1 << missing;
        }
        let w1 = lowest_zero(l0)? as usize;
        let lo = w1 * 64;
        let hi = ((w1 + 1) * 64).min(self.l2.len());
        let mut word1 = self.l1[w1].load(Ordering::Acquire);
        for missing in (hi - lo)..64 {
            word1 |= 1 << missing;
        }
        let w2rel = lowest_zero(word1)? as usize;
        Some(lo + w2rel)
    }

    /// Authoritative fallback: first layer-2 word with a zero bit. Only
    /// reached when the hints are transiently stale under contention.
    fn linear_scan(&self) -> Option<usize> {
        (0..self.l2.len()).find(|&w2| self.l2[w2].load(Ordering::Acquire) != u64::MAX)
    }

    /// Find the first free slot, mark it occupied, return its index.
    /// Lock-free: word-level CAS with retry on a lost race.
    pub fn find_and_set_first_zero(&self) -> Option<u32> {
        loop {
            if self.is_full() {
                return None;
            }
            let w2 = match self.find_candidate_word().or_else(|| self.linear_scan()) {
                Some(w) => w,
                // No zero bit anywhere right now (a racing claim may not
                // have bumped `used` yet) — treat as full.
                None => return None,
            };
            let word = self.l2[w2].load(Ordering::Acquire);
            let bit = match lowest_zero(word) {
                Some(b) => b,
                None => {
                    // Hint pointed at a word that filled up meanwhile.
                    self.mark_full_hints(w2);
                    continue;
                }
            };
            let new = word | 1 << bit;
            if self.l2[w2]
                .compare_exchange(word, new, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                self.used.fetch_add(1, Ordering::AcqRel);
                if new == u64::MAX {
                    self.mark_full_hints(w2);
                }
                let slot = (w2 as u32) * 64 + bit;
                debug_assert!(slot < self.capacity);
                return Some(slot);
            }
            // lost the CAS race — another thread claimed in this word
        }
    }

    /// Claim up to `want` free slots, appending their indices to `out`.
    /// Each iteration takes *all* the bits it can from one layer-2 word
    /// with a single CAS (the batch analogue of the llfree per-core
    /// claim), so a cache refill costs ~1 CAS instead of ~N.
    /// Returns the number of slots claimed.
    pub fn claim_batch(&self, want: usize, out: &mut Vec<u32>) -> usize {
        let mut got = 0usize;
        while got < want {
            if self.is_full() {
                break;
            }
            let w2 = match self.find_candidate_word().or_else(|| self.linear_scan()) {
                Some(w) => w,
                None => break,
            };
            let word = self.l2[w2].load(Ordering::Acquire);
            let free = !word;
            if free == 0 {
                self.mark_full_hints(w2);
                continue;
            }
            let take = (want - got).min(free.count_ones() as usize);
            let mut mask = 0u64;
            let mut m = free;
            for _ in 0..take {
                let b = m.trailing_zeros();
                mask |= 1 << b;
                m &= m - 1;
            }
            let new = word | mask;
            if self.l2[w2]
                .compare_exchange(word, new, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                self.used.fetch_add(take as u32, Ordering::AcqRel);
                let mut mm = mask;
                while mm != 0 {
                    let b = mm.trailing_zeros();
                    out.push((w2 as u32) * 64 + b);
                    mm &= mm - 1;
                }
                got += take;
                if new == u64::MAX {
                    self.mark_full_hints(w2);
                }
            }
            // on CAS failure: retry — the claimant that beat us made progress
        }
        got
    }

    /// Mark `slot` occupied (returns false if it already was).
    pub fn set(&self, slot: u32) -> bool {
        assert!(slot < self.capacity);
        let w2 = (slot / 64) as usize;
        let mask = 1u64 << (slot % 64);
        let prev = self.l2[w2].fetch_or(mask, Ordering::AcqRel);
        if prev & mask != 0 {
            return false;
        }
        self.used.fetch_add(1, Ordering::AcqRel);
        if prev | mask == u64::MAX {
            self.mark_full_hints(w2);
        }
        true
    }

    /// Free `slot` (returns false if it was not occupied).
    pub fn clear(&self, slot: u32) -> bool {
        assert!(slot < self.capacity, "slot {slot} >= capacity {}", self.capacity);
        let w2 = (slot / 64) as usize;
        let mask = 1u64 << (slot % 64);
        let prev = self.l2[w2].fetch_and(!mask, Ordering::AcqRel);
        if prev & mask == 0 {
            return false;
        }
        self.used.fetch_sub(1, Ordering::AcqRel);
        // the word now has room: withdraw the "full" hints
        let w1 = w2 / 64;
        self.l1[w1].fetch_and(!(1 << (w2 % 64)), Ordering::AcqRel);
        self.l0.fetch_and(!(1 << (w1 % 64)), Ordering::AcqRel);
        true
    }

    pub fn get(&self, slot: u32) -> bool {
        assert!(slot < self.capacity);
        self.l2[(slot / 64) as usize].load(Ordering::Acquire) & (1 << (slot % 64)) != 0
    }

    // ---- serialization (management data is persisted on close, §4.3) ----

    pub fn serialize_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.capacity.to_le_bytes());
        out.extend_from_slice(&self.used().to_le_bytes());
        for w in &self.l2 {
            out.extend_from_slice(&w.load(Ordering::Acquire).to_le_bytes());
        }
    }

    pub fn deserialize_from(buf: &[u8]) -> Option<(Self, usize)> {
        if buf.len() < 8 {
            return None;
        }
        let capacity = u32::from_le_bytes(buf[0..4].try_into().ok()?);
        let used = u32::from_le_bytes(buf[4..8].try_into().ok()?);
        if capacity == 0 || capacity > MAX_SLOTS {
            return None;
        }
        let n2 = div_ceil(capacity as usize, 64);
        if buf.len() < 8 + n2 * 8 {
            return None;
        }
        let s = Self::new(capacity);
        let mut real_used = 0;
        for (i, chunkb) in buf[8..8 + n2 * 8].chunks_exact(8).enumerate() {
            let word = u64::from_le_bytes(chunkb.try_into().ok()?);
            for b in 0..64 {
                let slot = (i * 64 + b) as u32;
                if slot < capacity && word & (1 << b) != 0 {
                    s.set(slot);
                    real_used += 1;
                }
            }
        }
        if real_used != used {
            return None; // corrupt management data
        }
        Some((s, 8 + n2 * 8))
    }
}

impl Clone for MlBitset {
    fn clone(&self) -> Self {
        Self {
            capacity: self.capacity,
            used: AtomicU32::new(self.used()),
            l0: AtomicU64::new(self.l0.load(Ordering::Acquire)),
            l1: self
                .l1
                .iter()
                .map(|w| AtomicU64::new(w.load(Ordering::Acquire)))
                .collect(),
            l2: self
                .l2
                .iter()
                .map(|w| AtomicU64::new(w.load(Ordering::Acquire)))
                .collect(),
        }
    }
}

impl PartialEq for MlBitset {
    fn eq(&self, other: &Self) -> bool {
        self.capacity == other.capacity
            && self.used() == other.used()
            && self
                .l2
                .iter()
                .zip(&other.l2)
                .all(|(a, b)| a.load(Ordering::Acquire) == b.load(Ordering::Acquire))
    }
}

impl Eq for MlBitset {}

impl std::fmt::Debug for MlBitset {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MlBitset")
            .field("capacity", &self.capacity)
            .field("used", &self.used())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256ss;

    #[test]
    fn sequential_fill_and_drain() {
        let bs = MlBitset::new(130); // crosses word boundaries
        for expect in 0..130 {
            assert_eq!(bs.find_and_set_first_zero(), Some(expect));
        }
        assert!(bs.is_full());
        assert_eq!(bs.find_and_set_first_zero(), None);
        for slot in 0..130 {
            assert!(bs.clear(slot));
        }
        assert!(bs.is_empty());
    }

    #[test]
    fn first_fit_order_after_clear() {
        let bs = MlBitset::new(256);
        for _ in 0..256 {
            bs.find_and_set_first_zero();
        }
        bs.clear(77);
        bs.clear(200);
        bs.clear(3);
        assert_eq!(bs.find_and_set_first_zero(), Some(3));
        assert_eq!(bs.find_and_set_first_zero(), Some(77));
        assert_eq!(bs.find_and_set_first_zero(), Some(200));
        assert_eq!(bs.find_and_set_first_zero(), None);
    }

    #[test]
    fn capacity_one_and_max_group() {
        let bs = MlBitset::new(1);
        assert_eq!(bs.find_and_set_first_zero(), Some(0));
        assert_eq!(bs.find_and_set_first_zero(), None);
        bs.clear(0);
        assert_eq!(bs.find_and_set_first_zero(), Some(0));

        // 2^18 slots — the paper's maximum (8 B objects in 2 MiB chunks)
        let big = MlBitset::new(MAX_SLOTS);
        for i in 0..1000 {
            assert_eq!(big.find_and_set_first_zero(), Some(i));
        }
    }

    #[test]
    fn double_set_and_clear_are_detected() {
        let bs = MlBitset::new(64);
        assert!(bs.set(10));
        assert!(!bs.set(10));
        assert!(bs.clear(10));
        assert!(!bs.clear(10));
    }

    #[test]
    fn random_workout_against_model() {
        let bs = MlBitset::new(777);
        let mut model = vec![false; 777];
        let mut rng = Xoshiro256ss::new(5);
        for _ in 0..50_000 {
            let slot = rng.gen_range(777) as u32;
            if rng.next_f64() < 0.5 {
                assert_eq!(bs.set(slot), !model[slot as usize]);
                model[slot as usize] = true;
            } else {
                assert_eq!(bs.clear(slot), model[slot as usize]);
                model[slot as usize] = false;
            }
            assert_eq!(bs.used() as usize, model.iter().filter(|&&x| x).count());
        }
        // find_and_set must return the first free slot per the model
        let first_free = model.iter().position(|&x| !x);
        assert_eq!(bs.find_and_set_first_zero(), first_free.map(|x| x as u32));
    }

    #[test]
    fn serialization_roundtrip() {
        let bs = MlBitset::new(300);
        let mut rng = Xoshiro256ss::new(8);
        for _ in 0..150 {
            let s = rng.gen_range(300) as u32;
            bs.set(s);
        }
        let mut buf = Vec::new();
        bs.serialize_into(&mut buf);
        let (de, consumed) = MlBitset::deserialize_from(&buf).unwrap();
        assert_eq!(consumed, buf.len());
        assert_eq!(de, bs);
    }

    #[test]
    fn deserialize_rejects_corruption() {
        let bs = MlBitset::new(64);
        bs.set(0);
        let mut buf = Vec::new();
        bs.serialize_into(&mut buf);
        buf[4] = 99; // wrong used count
        assert!(MlBitset::deserialize_from(&buf).is_none());
        assert!(MlBitset::deserialize_from(&[1, 2, 3]).is_none());
    }

    #[test]
    fn claim_batch_takes_first_fit_prefix() {
        let bs = MlBitset::new(200);
        let mut out = Vec::new();
        assert_eq!(bs.claim_batch(70, &mut out), 70);
        assert_eq!(out, (0..70).collect::<Vec<u32>>());
        assert_eq!(bs.used(), 70);
        // holes are refilled first
        bs.clear(5);
        bs.clear(6);
        let mut out2 = Vec::new();
        assert_eq!(bs.claim_batch(3, &mut out2), 3);
        assert_eq!(out2, vec![5, 6, 70]);
    }

    #[test]
    fn claim_batch_stops_at_capacity() {
        let bs = MlBitset::new(10);
        let mut out = Vec::new();
        assert_eq!(bs.claim_batch(64, &mut out), 10);
        assert!(bs.is_full());
        assert_eq!(bs.claim_batch(1, &mut out), 0);
    }

    #[test]
    fn concurrent_claims_never_collide() {
        use std::collections::HashSet;
        use std::sync::Arc;
        let bs = Arc::new(MlBitset::new(MAX_SLOTS));
        let nthreads = 8;
        let per = 4000;
        let mut handles = Vec::new();
        for _ in 0..nthreads {
            let bs = Arc::clone(&bs);
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::with_capacity(per);
                for _ in 0..per {
                    got.push(bs.find_and_set_first_zero().expect("capacity suffices"));
                }
                got
            }));
        }
        let mut seen = HashSet::new();
        for h in handles {
            for slot in h.join().unwrap() {
                assert!(seen.insert(slot), "slot {slot} claimed twice");
            }
        }
        assert_eq!(bs.used() as usize, nthreads * per);
    }

    #[test]
    fn concurrent_batch_claims_never_collide() {
        use std::collections::HashSet;
        use std::sync::Arc;
        let bs = Arc::new(MlBitset::new(64 * 64));
        let nthreads = 8;
        let batches = 16;
        let want = 16;
        let mut handles = Vec::new();
        for _ in 0..nthreads {
            let bs = Arc::clone(&bs);
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                for _ in 0..batches {
                    bs.claim_batch(want, &mut got);
                }
                got
            }));
        }
        let mut seen = HashSet::new();
        let mut total = 0;
        for h in handles {
            for slot in h.join().unwrap() {
                assert!(seen.insert(slot), "slot {slot} claimed twice");
                total += 1;
            }
        }
        assert_eq!(bs.used() as usize, total);
    }
}

//! Compact multi-layer bitset (paper §4.3.1).
//!
//! "Metall utilizes a compact multi-layer bitset table and built-in bit
//! operation functions to manage available slots in a chunk … It can
//! manage up to 64³ (= 2^18) slots using a three-layer structure …
//! Metall calls a built-in bit operation function at most three times to
//! find an available slot."
//!
//! Layer 2 is the actual slot bitmap (1 = occupied); layer 1 marks fully
//! occupied layer-2 words; layer 0 marks fully occupied layer-1 words.
//! `find_and_set_first_zero` descends 0→1→2 with one trailing-zeros scan
//! per layer.

use crate::util::bits::lowest_zero;
use crate::util::div_ceil;

/// Up to 64³ slots, lazily sized for `capacity`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MlBitset {
    capacity: u32,
    used: u32,
    l0: u64,
    l1: Vec<u64>,
    l2: Vec<u64>,
}

pub const MAX_SLOTS: u32 = 64 * 64 * 64;

impl MlBitset {
    pub fn new(capacity: u32) -> Self {
        assert!(capacity >= 1 && capacity <= MAX_SLOTS, "capacity {capacity}");
        let n2 = div_ceil(capacity as usize, 64);
        let n1 = div_ceil(n2, 64);
        let mut s = Self {
            capacity,
            used: 0,
            l0: 0,
            l1: vec![0; n1],
            l2: vec![0; n2],
        };
        // Pre-mark the out-of-capacity tail as occupied so the scan never
        // hands out a slot ≥ capacity.
        for slot in capacity..(n2 as u32 * 64) {
            s.set_raw(slot);
        }
        s.used = 0; // tail marking is not "use"
        s
    }

    pub fn capacity(&self) -> u32 {
        self.capacity
    }

    /// Number of occupied (real) slots.
    pub fn used(&self) -> u32 {
        self.used
    }

    pub fn is_full(&self) -> bool {
        self.used == self.capacity
    }

    pub fn is_empty(&self) -> bool {
        self.used == 0
    }

    fn set_raw(&mut self, slot: u32) {
        let w2 = (slot / 64) as usize;
        let b2 = slot % 64;
        self.l2[w2] |= 1 << b2;
        if self.l2[w2] == u64::MAX {
            let w1 = w2 / 64;
            self.l1[w1] |= 1 << (w2 % 64);
            // a partially-present last l1 word never saturates l0 falsely:
            // missing l2 words are absent, so pad virtually with ones
            let full_l1 = self.l1_word_full(w1);
            if full_l1 {
                self.l0 |= 1 << (w1 % 64);
            }
        }
    }

    /// Is layer-1 word `w1` fully occupied, accounting for the virtual
    /// all-ones padding beyond the allocated l2 words?
    fn l1_word_full(&self, w1: usize) -> bool {
        let lo = w1 * 64;
        let hi = ((w1 + 1) * 64).min(self.l2.len());
        let mut word = self.l1[w1];
        // virtually set bits for non-existent l2 words
        for missing in (hi - lo)..64 {
            word |= 1 << missing;
        }
        word == u64::MAX
    }

    /// Find the first free slot, mark it occupied, return its index.
    /// At most three word scans (the paper's bound).
    pub fn find_and_set_first_zero(&mut self) -> Option<u32> {
        if self.is_full() {
            return None;
        }
        // layer 0: find an l1 word with room (virtual padding for absent
        // l1 words)
        let mut l0 = self.l0;
        for missing in self.l1.len()..64 {
            l0 |= 1 << missing;
        }
        let w1 = lowest_zero(l0)? as usize;
        // layer 1: find an l2 word with room
        let lo = w1 * 64;
        let hi = ((w1 + 1) * 64).min(self.l2.len());
        let mut word1 = self.l1[w1];
        for missing in (hi - lo)..64 {
            word1 |= 1 << missing;
        }
        let w2rel = lowest_zero(word1)? as usize;
        let w2 = lo + w2rel;
        // layer 2: find the free slot
        let b = lowest_zero(self.l2[w2])?;
        let slot = (w2 as u32) * 64 + b;
        debug_assert!(slot < self.capacity);
        self.set_raw(slot);
        self.used += 1;
        Some(slot)
    }

    /// Mark `slot` occupied (returns false if it already was).
    pub fn set(&mut self, slot: u32) -> bool {
        assert!(slot < self.capacity);
        if self.get(slot) {
            return false;
        }
        self.set_raw(slot);
        self.used += 1;
        true
    }

    /// Free `slot` (returns false if it was not occupied).
    pub fn clear(&mut self, slot: u32) -> bool {
        assert!(slot < self.capacity, "slot {slot} >= capacity {}", self.capacity);
        let w2 = (slot / 64) as usize;
        let b2 = slot % 64;
        if self.l2[w2] & (1 << b2) == 0 {
            return false;
        }
        self.l2[w2] &= !(1 << b2);
        let w1 = w2 / 64;
        self.l1[w1] &= !(1 << (w2 % 64));
        self.l0 &= !(1 << (w1 % 64));
        self.used -= 1;
        true
    }

    pub fn get(&self, slot: u32) -> bool {
        assert!(slot < self.capacity);
        self.l2[(slot / 64) as usize] & (1 << (slot % 64)) != 0
    }

    // ---- serialization (management data is persisted on close, §4.3) ----

    pub fn serialize_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.capacity.to_le_bytes());
        out.extend_from_slice(&self.used.to_le_bytes());
        for w in &self.l2 {
            out.extend_from_slice(&w.to_le_bytes());
        }
    }

    pub fn deserialize_from(buf: &[u8]) -> Option<(Self, usize)> {
        if buf.len() < 8 {
            return None;
        }
        let capacity = u32::from_le_bytes(buf[0..4].try_into().ok()?);
        let used = u32::from_le_bytes(buf[4..8].try_into().ok()?);
        if capacity == 0 || capacity > MAX_SLOTS {
            return None;
        }
        let n2 = div_ceil(capacity as usize, 64);
        if buf.len() < 8 + n2 * 8 {
            return None;
        }
        let mut s = Self::new(capacity);
        let mut real_used = 0;
        for (i, chunkb) in buf[8..8 + n2 * 8].chunks_exact(8).enumerate() {
            let word = u64::from_le_bytes(chunkb.try_into().ok()?);
            for b in 0..64 {
                let slot = (i * 64 + b) as u32;
                if slot < capacity && word & (1 << b) != 0 {
                    s.set(slot);
                    real_used += 1;
                }
            }
        }
        if real_used != used {
            return None; // corrupt management data
        }
        Some((s, 8 + n2 * 8))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256ss;

    #[test]
    fn sequential_fill_and_drain() {
        let mut bs = MlBitset::new(130); // crosses word boundaries
        for expect in 0..130 {
            assert_eq!(bs.find_and_set_first_zero(), Some(expect));
        }
        assert!(bs.is_full());
        assert_eq!(bs.find_and_set_first_zero(), None);
        for slot in 0..130 {
            assert!(bs.clear(slot));
        }
        assert!(bs.is_empty());
    }

    #[test]
    fn first_fit_order_after_clear() {
        let mut bs = MlBitset::new(256);
        for _ in 0..256 {
            bs.find_and_set_first_zero();
        }
        bs.clear(77);
        bs.clear(200);
        bs.clear(3);
        assert_eq!(bs.find_and_set_first_zero(), Some(3));
        assert_eq!(bs.find_and_set_first_zero(), Some(77));
        assert_eq!(bs.find_and_set_first_zero(), Some(200));
        assert_eq!(bs.find_and_set_first_zero(), None);
    }

    #[test]
    fn capacity_one_and_max_group() {
        let mut bs = MlBitset::new(1);
        assert_eq!(bs.find_and_set_first_zero(), Some(0));
        assert_eq!(bs.find_and_set_first_zero(), None);
        bs.clear(0);
        assert_eq!(bs.find_and_set_first_zero(), Some(0));

        // 2^18 slots — the paper's maximum (8 B objects in 2 MiB chunks)
        let mut big = MlBitset::new(MAX_SLOTS);
        for i in 0..1000 {
            assert_eq!(big.find_and_set_first_zero(), Some(i));
        }
    }

    #[test]
    fn double_set_and_clear_are_detected() {
        let mut bs = MlBitset::new(64);
        assert!(bs.set(10));
        assert!(!bs.set(10));
        assert!(bs.clear(10));
        assert!(!bs.clear(10));
    }

    #[test]
    fn random_workout_against_model() {
        let mut bs = MlBitset::new(777);
        let mut model = vec![false; 777];
        let mut rng = Xoshiro256ss::new(5);
        for _ in 0..50_000 {
            let slot = rng.gen_range(777) as u32;
            if rng.next_f64() < 0.5 {
                assert_eq!(bs.set(slot), !model[slot as usize]);
                model[slot as usize] = true;
            } else {
                assert_eq!(bs.clear(slot), model[slot as usize]);
                model[slot as usize] = false;
            }
            assert_eq!(bs.used() as usize, model.iter().filter(|&&x| x).count());
        }
        // find_and_set must return the first free slot per the model
        let first_free = model.iter().position(|&x| !x);
        assert_eq!(bs.find_and_set_first_zero(), first_free.map(|x| x as u32));
    }

    #[test]
    fn serialization_roundtrip() {
        let mut bs = MlBitset::new(300);
        let mut rng = Xoshiro256ss::new(8);
        for _ in 0..150 {
            let s = rng.gen_range(300) as u32;
            bs.set(s);
        }
        let mut buf = Vec::new();
        bs.serialize_into(&mut buf);
        let (de, consumed) = MlBitset::deserialize_from(&buf).unwrap();
        assert_eq!(consumed, buf.len());
        assert_eq!(de, bs);
    }

    #[test]
    fn deserialize_rejects_corruption() {
        let mut bs = MlBitset::new(64);
        bs.set(0);
        let mut buf = Vec::new();
        bs.serialize_into(&mut buf);
        buf[4] = 99; // wrong used count
        assert!(MlBitset::deserialize_from(&buf).is_none());
        assert!(MlBitset::deserialize_from(&[1, 2, 3]).is_none());
    }
}

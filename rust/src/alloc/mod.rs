//! The Metall persistent memory allocator (paper §3–§4).
//!
//! Architecture (paper Fig 2): the application-data **segment** (a
//! reserved VM extent backed by on-demand files, [`crate::storage::segment`])
//! is divided into **chunks** (2 MiB by default). A chunk holds either
//! *small objects* of one internal allocation size (8 B … half a chunk,
//! tracked by a multi-layer bitset) or the head/body of a *large object*
//! spanning ≥ 1 contiguous chunks. Three management directories — chunk
//! directory, bin directory, name directory — live in **DRAM** and are
//! serialized to the datastore on close (§4.3: "Metall rarely touches
//! persistent memory when allocating memory").

pub mod api;
pub mod size_class;
pub mod mlbitset;
pub mod chunk_dir;
pub mod bin_dir;
pub mod object_cache;
pub mod name_dir;
pub mod manager;

pub use api::{MetallHandle, SegmentAlloc};
pub use manager::{ManagerOptions, MetallManager, Persist};

//! The Metall persistent memory allocator (paper §3–§4).
//!
//! Architecture (paper Fig 2): the application-data **segment** (a
//! reserved VM extent backed by on-demand files, [`crate::storage::segment`])
//! is divided into **chunks** (2 MiB by default). A chunk holds either
//! *small objects* of one internal allocation size (8 B … half a chunk,
//! tracked by a multi-layer bitset) or the head/body of a *large object*
//! spanning ≥ 1 contiguous chunks. Three management directories — chunk
//! directory, bin directory, name directory — live in **DRAM** and are
//! serialized to the datastore on close (§4.3: "Metall rarely touches
//! persistent memory when allocating memory").
//!
//! ## Shard architecture (beyond the paper)
//!
//! The DRAM bin directory is split into N CPU-affine **shards**
//! ([`ManagerOptions::shards`], default `min(num_cpus, 4)`). Each
//! [`bin_dir::AllocShard`] owns, per size class, its own non-full-chunk
//! LIFO and slot bitsets over the chunks it took from the chunk
//! directory, plus its own slice of the free-chunk pool inside
//! [`chunk_dir::ChunkDirectory`]. A thread's home shard is its virtual
//! CPU modulo N ([`bin_dir::ShardMap`], `sched_getcpu` with a thread-id
//! hash fallback), the same value that selects its
//! [`object_cache::ObjectCache`] slot — so cache slots are bound to
//! shards and the paper's two serialization points (fresh-chunk take,
//! emptied-chunk release) are contended per shard instead of per
//! manager.
//!
//! **Remote-free queue:** an object freed by a thread whose home shard
//! is not the owning shard of its chunk is parked on the owner's
//! [`bin_dir::AllocShard::remote_free`] queue (a plain mutex push; the
//! foreign shard's bin locks are never taken on the free hot path,
//! llfree-style). The owner drains the queue whenever it next reaches
//! one of its serialization points, and `sync`/`close` drain every
//! queue, so no slot is ever leaked.
//!
//! **Shard=1 equivalence:** the shard count is a DRAM-only property. The
//! persistent format is identical for every N — each bin serializes as
//! the sorted union of its per-shard bitsets
//! ([`bin_dir::serialize_merged_into`]) and chunk ownership is re-dealt
//! deterministically (`chunk % N`) on open, so a store written with N
//! shards reopens with M ≠ N. With N = 1 every sharded code path
//! collapses to the unsharded one (free pools bypassed, remote queues
//! structurally empty), reproducing the pre-sharding on-disk layout
//! bit-for-bit.
//!
//! ## NUMA placement (ROADMAP "True NUMA placement")
//!
//! On a multi-node [`crate::numa::Topology`] (detected from
//! `/sys/devices/system/node` — memory-only nodes excluded — or injected
//! by tests), the shard count is sized from the topology (a multiple of
//! the node count), shards are dealt round-robin to nodes, and a
//! thread's home shard is chosen among *its own node's* shards
//! ([`bin_dir::ShardMap`]). Each fresh chunk a shard takes is placed by
//! exactly one of two layers: `mbind(MPOL_PREFERRED)` to the shard's
//! node (kernel policy then covers every later fault, no page needs
//! touching), or — when `mbind` is unavailable — **zeroed by the owning
//! shard before any slot is published**, the first-touch discipline that
//! pins the chunk's DRAM pages to the owner's socket regardless of which
//! thread later writes objects into it
//! (`MetallManager::place_fresh_chunk`).
//!
//! Everything degrades gracefully: on single-node topologies the whole
//! layer is skipped (kernel first-touch is already local), and on kernels
//! without NUMA support `mbind`/`move_pages` report "couldn't" instead of
//! erroring — placement is an optimization, never a correctness
//! requirement. Like the shard count, placement and topology are
//! DRAM-only: nothing is serialized, and a store written under any
//! topology reopens under any other.
//!
//! Introspection: [`manager::PlacementReport`]
//! ([`MetallManager::placement_report`]) accounts every mapped page —
//! kernel truth via `move_pages(2)` on detected topologies, recorded
//! birth nodes under injected ones — and is exported as
//! `alloc.shard<N>.node_local_pages` by
//! [`crate::coordinator::metrics::record_placement`].
//!
//! ## Incremental, shard-parallel, **background** sync (the persist path)
//!
//! `sync()` — and therefore `snapshot()` and `close()` — scales with the
//! *delta* since the last sync, not with the store, and the flush work
//! runs on a dedicated background flusher thread, off the mutation path
//! entirely. The protocol, end to end:
//!
//! 1. **Dirty epochs (DRAM-only).** Every mutation of serialized state
//!    raises a flag at its own serialization point: per-shard per-bin
//!    flags in [`bin_dir::AllocShard`] (set by fast-path CAS claims
//!    inside the shared-lock critical section, by the two exclusive
//!    serialization points, and by frees), a chunk-directory mark
//!    ([`chunk_dir::ChunkDirectory::is_dirty`]), a name-directory mark,
//!    an object-cache mark, and a chunk-granular bitmap of application
//!    data writes (all manager write APIs and the `SegmentAlloc` impls
//!    mark it; raw-pointer writers call `MetallManager::mark_data_dirty`).
//!    None of these flags is ever persisted.
//!
//! 2. **Segmented management format** ([`mgmt_io`]). Management data
//!    lives in immutable per-section files — chunk directory, 8-bin bin
//!    groups, names, and a transient cache section — indexed by a small
//!    checksummed manifest committed via fsync'd atomic rename. A sync
//!    re-serializes and rewrites *only dirty sections*: the images are
//!    snapshotted at one **consistent cut** (every management lock held
//!    simultaneously, in the allocator's own bin → chunks order, for the
//!    in-memory serialization only — mutators may be running, and a
//!    committed epoch must be the state of a single instant), then a
//!    flusher pool writes the files in parallel off-lock, and clean
//!    sections are carried forward by reference. Recovery walks
//!    manifests newest-first to the last complete one; legacy monolithic
//!    `management.bin` stores are still read and converted on the next
//!    sync. Per-section bytes at `shards = 1` are byte-identical to the
//!    unsharded serialization, so the shard count remains DRAM-only.
//!
//! 3. **Narrowed data flush.** Shared-mode stores `msync` only the union
//!    of dirty chunk ranges (parallel across ranges); private (bs-mmap)
//!    stores already flush page-granular deltas via
//!    [`crate::storage::bsmmap::BsMsync`].
//!
//! 4. **Cache-preserving sync.** The per-core object caches are *not*
//!    drained: their parked-free slots (plus any remote-queue stragglers)
//!    are serialized into the transient cache section, and recovery
//!    returns them to the bitsets on open — so periodic snapshots cost no
//!    allocation warmth and a crash between syncs leaks nothing.
//!    [`MetallManager::flush_object_caches`] is the explicit full drain
//!    (and `close()` always drains, so a closed image is canonical).
//!
//! 5. **Background engine, epoch-pipelined** ([`bg_sync`]). A
//!    [`bg_sync::SyncEngine`] owned by every read-write manager runs
//!    the steps above across **two** dedicated threads. The *flusher*
//!    answers three triggers — a **dirty-byte high watermark** (fed by
//!    the chunk-granular dirty map's running byte count; see the
//!    adaptive controller below), an optional **interval timer**
//!    ([`ManagerOptions::sync_interval_ms`]), and explicit requests
//!    (`sync_async()` returns a [`bg_sync::SyncTicket`] whose `wait()`
//!    blocks until the covering flush *epoch*'s manifest is durably
//!    committed; `sync()` is exactly `sync_async()` + `wait()`, with
//!    concurrent callers coalescing) — by taking the consistent cut of
//!    step 2 and serializing its dirty sections into an in-memory
//!    prepared epoch. The *committer* pops prepared epochs from a
//!    bounded FIFO queue and makes each durable: data msync, section
//!    file writes, manifest rename. Because one thread owns the queue
//!    head, **manifests commit strictly in epoch order** — epoch N+1's
//!    rename can never land before epoch N's — while epoch N+1's cut
//!    and serialization overlap epoch N's backend writes. The queue is
//!    bounded by [`ManagerOptions::sync_pipeline_depth`] (default 2:
//!    one committing, one queued; depth 1 reproduces the strictly
//!    serial engine): a trigger that finds the pipeline full waits for
//!    a slot rather than queue further cuts, so memory for serialized
//!    sections stays bounded. Reader side-copy freezing runs at cut
//!    time, tagged with the epoch whose cut produced it. A failed
//!    commit aborts every later queued epoch (their dirty flags are
//!    restored, so nothing is lost — the next round re-cuts them) and
//!    tickets covering exactly the failed-through generations report
//!    the error; tickets whose epoch already committed still resolve
//!    `Ok`. Writers that outrun the backend stall at a hard
//!    **backpressure ceiling** ([`ManagerOptions::sync_ceiling_bytes`],
//!    counted in [`bg_sync::BgSyncStats`]); the stall ends as soon as
//!    the next *cut* clears the dirty estimate — the writer never waits
//!    for the backend write itself. A *panicking* flusher or committer
//!    marks the engine dead and every later sync call (including
//!    `close()`, which then refuses to write `CLEAN`) errors instead of
//!    silently dropping data; `close()`/`Drop` drain outstanding
//!    epochs, join both threads, and run the final full sync inline.
//!    `snapshot()` and `doctor()` hold the engine's flush gate
//!    exclusively so they never observe a half-committed background
//!    epoch.
//!
//! 6. **Bandwidth-adaptive watermark.** With
//!    [`ManagerOptions::sync_watermark_adaptive`] (default on) and a
//!    configured watermark, the engine maintains EWMAs of per-epoch
//!    effective flush bandwidth and fixed per-flush latency — measured
//!    from the commit path itself, including [`crate::storage::netfs`]
//!    charged time when a simulated backend profile
//!    ([`ManagerOptions::netfs_profile`]) is active — and moves the
//!    trigger toward the measured **bandwidth-delay product**, clamped
//!    to `[64 KiB, ceiling/2]`. Slow, latency-heavy backends (Lustre)
//!    batch dirty bytes up to what one in-flight epoch can absorb; fast
//!    local stores flush eagerly. The current value and the measured
//!    bandwidth are exported as `alloc.bgsync.adaptive_watermark_bytes`
//!    / `alloc.bgsync.measured_bandwidth_bps`.
//!
//! A sync where nothing changed writes zero bytes and commits no
//! manifest. Observability: [`manager::SyncStats`]
//! ([`MetallManager::sync_stats`]) as `alloc.sync.*` and
//! [`bg_sync::BgSyncStats`] ([`MetallManager::bg_sync_stats`]) as
//! `alloc.bgsync.*`, via [`crate::coordinator::metrics`].
//!
//! ## Multi-process attach: reader-epoch snapshot isolation ([`readers`])
//!
//! Every committed manifest epoch is a *consistent, immutable* image of
//! the management state, which makes it a natural snapshot boundary for
//! other processes. A [`ReaderManager`] attaches to a **live** store —
//! the owner keeps mutating and background-flushing — by pinning the
//! last committed epoch:
//!
//! - **Single-writer exclusivity.** Read-write managers hold an
//!   exclusive `flock` on `<store>/LOCK` for their whole lifetime
//!   (kernel-released on any death, so no stale-lock recovery is ever
//!   needed); a second RW open fails fast with a clear
//!   [`crate::error::Error::Datastore`] instead of corrupting the store.
//!   The legacy CLEAN-gated [`MetallManager::open_read_only`] takes the
//!   same lock shared. A live attach takes **no** store lock — its
//!   registration is the lease below.
//!
//! - **Lease-and-pin registry** (`<store>/readers/`). Each attach
//!   writes a checksummed lease file recording its pinned epoch and
//!   holds an exclusive `flock` on it for the attach's lifetime.
//!   Liveness is probed by try-locking: acquirable ⇒ the holder is gone
//!   (kill-9 included) and the lease is reaped; blocked ⇒ live. The
//!   owner's manifest GC ([`mgmt_io::gc`]) consults the registry and
//!   keeps every pinned epoch's manifest *and* the section files it
//!   references; a torn or unreadable lease conservatively pins
//!   everything. During attach and refresh transitions the lease sits
//!   at the `PIN_ALL` sentinel so no epoch can be collected between
//!   choosing a manifest and recording the choice.
//!
//! - **Epoch-side data copies** (`<store>/epoch-side/`). `MAP_SHARED`
//!   page-cache coherence means a reader mapping the live chunk files
//!   would see the owner's stores *immediately* — msync timing cannot
//!   isolate it. Stable views therefore come from **different inodes**:
//!   before the flusher's in-place msync may tear a pinned view, it
//!   reflinks the dirty chunks into per-`(chunk, epoch)` side files
//!   ([`crate::storage::reflink::clone_file_range`]; byte-copy fallback
//!   on ext4), and an attach seeds side copies for the chunks the
//!   flusher hasn't covered. The reader maps side files over its
//!   read-only segment reservation ([`crate::storage::segment::SegmentStorage::overlay_readonly`]),
//!   so every pinned byte is immune to owner writes; since a mapped
//!   file survives its own unlink, even a mis-timed GC can never yank
//!   pages out from under a reader. Side copies are garbage-collected
//!   with the same pin awareness as manifests.
//!
//! - **Staleness and refresh.** At attach the pin is the newest
//!   committed epoch, so a reader starts **< 1 epoch stale**.
//!   [`ReaderManager::refresh`] re-pins to a newer committed epoch in
//!   place (fresh mapping, new overlay resolution, lease moved under
//!   `PIN_ALL` protection) and reports staleness via
//!   [`AttachStats::staleness_epochs`], exported as `alloc.attach.*` by
//!   [`crate::coordinator::metrics::record_attach_stats`] and exercised
//!   end-to-end by the `metall attach` benchmark.
//!
//! ## Container op log: crash-consistent *user* data
//!
//! Manifest epochs make the allocator's *management* state recover to a
//! consistent cut, but application bytes in the segment carry no such
//! guarantee on their own — a kill-9 can land between a container's
//! element write and its header publish, or after a grow retired the
//! extent a recovered header still references. The
//! [`crate::containers::oplog`] subsystem closes that gap: every
//! mutating container operation appends a checksum-sealed **intent
//! record** (old/new header images, allocated/retired extents) to a
//! per-manager persistent ring before touching user bytes, and seals a
//! **commit mark** after its headers are published. The ring is ordinary
//! segment data — its slots ride the same dirty-chunk map and
//! background-sync epochs as the bytes they describe — and each
//! manifest cut stamps the log with the sequence horizon it covers
//! (`safe_seq` advances only on committed manifests, so ring reclaim
//! never outruns durability; a full ring forces a manifest commit).
//!
//! On `open_unclean`, `ManagerCore::recover_containers` replays the
//! tail above the recovered epoch's horizon in sequence order:
//! committed records have their allocations **adopted** into the
//! recovered bitsets (retired extents stay leaked — releasing them
//! could free pre-cut state a committed record no longer describes);
//! unsealed records roll **forward** when the current header bytes
//! already match the new images (commit-sealed, retired extent
//! released) and **back** otherwise (old images restored, half-keyed
//! map slots cleared, abort-sealed, the never-published allocation
//! released). `ManagerCore::validate_containers` — wired into
//! `doctor()` — then audits container invariants over every header the
//! replayed tail names. Counters surface as `alloc.oplog.*`
//! ([`crate::containers::oplog::OpLogStats`],
//! [`ManagerCore::oplog_stats`]).
//!
//! ## Error taxonomy & degraded mode
//!
//! Backend failures on the durability path are **classified**, not
//! uniformly fatal ([`crate::storage::faults::classify`]):
//!
//! - **Transient** (`EIO`, `EAGAIN`, `ENOSPC`, and anything
//!   unclassifiable): the background engine keeps the failed round's
//!   dirty flags, backs off (doubling retry interval, capped), and
//!   re-cuts on the next trigger. Nothing is lost — the last committed
//!   manifest stays the recovery point. `ENOSPC` on the *allocation*
//!   path is fully rolled back at the call site instead: the reserved
//!   chunk ids return to the free pool, the failure surfaces as a clean
//!   [`crate::error::Error::Alloc`], and a smaller allocation can still
//!   succeed ([`ManagerCore::health_stats`] counts the rollbacks).
//!
//! - **Permanent** (`EROFS`, `ENODEV`, `ENXIO`, `EBADF`) — or
//!   [`ManagerOptions::sync_fail_limit`] *consecutive* transient
//!   failures — **wounds** the manager (`ManagerCore::wound`): it flips
//!   atomically to **degraded read-only**. Every mutating API
//!   (`allocate`, `construct`, `sync`, …) returns
//!   [`crate::error::Error::Degraded`] with the originating failure;
//!   in-flight [`SyncTicket`]s resolve with the same attribution; the
//!   background engine parks; live [`ReaderManager`] attaches keep
//!   serving the last committed epoch (their side copies and manifests
//!   are immutable); and `close()` refuses to write the `CLEAN` marker
//!   so the next open takes the recovery path to the last committed
//!   manifest. An advisory `WOUNDED` breadcrumb (best-effort, never
//!   trusted by recovery) lets `metall doctor` report the state; any
//!   successful read-write open clears it.
//!
//! The deterministic fault-injection layer behind the classification
//! tests lives in [`crate::storage::faults`].
//!
//! Follow-on (ROADMAP): an interleave policy (`MPOL_INTERLEAVE`) for
//! read-mostly large segments shared by threads on every node.

pub mod api;
pub mod size_class;
pub mod mlbitset;
pub mod chunk_dir;
pub mod bin_dir;
pub mod bg_sync;
pub mod mgmt_io;
pub mod object_cache;
pub mod name_dir;
pub mod manager;
pub mod readers;

pub use api::{MetallHandle, SegmentAlloc};
pub use bg_sync::{BgSyncStats, SyncTicket};
pub use bin_dir::{ShardMap, ShardStatsSnapshot};
pub use manager::{
    AttachStats, HealthStats, ManagerCore, ManagerOptions, MetallManager, Persist,
    PlacementReport, PlacementSource, ReaderManager, ShardPlacement, StatsSnapshot, SyncStats,
    WOUNDED_MARKER,
};
pub use object_cache::pin_thread_vcpu;

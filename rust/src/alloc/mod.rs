//! The Metall persistent memory allocator (paper §3–§4).
//!
//! Architecture (paper Fig 2): the application-data **segment** (a
//! reserved VM extent backed by on-demand files, [`crate::storage::segment`])
//! is divided into **chunks** (2 MiB by default). A chunk holds either
//! *small objects* of one internal allocation size (8 B … half a chunk,
//! tracked by a multi-layer bitset) or the head/body of a *large object*
//! spanning ≥ 1 contiguous chunks. Three management directories — chunk
//! directory, bin directory, name directory — live in **DRAM** and are
//! serialized to the datastore on close (§4.3: "Metall rarely touches
//! persistent memory when allocating memory").
//!
//! ## Shard architecture (beyond the paper)
//!
//! The DRAM bin directory is split into N CPU-affine **shards**
//! ([`ManagerOptions::shards`], default `min(num_cpus, 4)`). Each
//! [`bin_dir::AllocShard`] owns, per size class, its own non-full-chunk
//! LIFO and slot bitsets over the chunks it took from the chunk
//! directory, plus its own slice of the free-chunk pool inside
//! [`chunk_dir::ChunkDirectory`]. A thread's home shard is its virtual
//! CPU modulo N ([`bin_dir::ShardMap`], `sched_getcpu` with a thread-id
//! hash fallback), the same value that selects its
//! [`object_cache::ObjectCache`] slot — so cache slots are bound to
//! shards and the paper's two serialization points (fresh-chunk take,
//! emptied-chunk release) are contended per shard instead of per
//! manager.
//!
//! **Remote-free queue:** an object freed by a thread whose home shard
//! is not the owning shard of its chunk is parked on the owner's
//! [`bin_dir::AllocShard::remote_free`] queue (a plain mutex push; the
//! foreign shard's bin locks are never taken on the free hot path,
//! llfree-style). The owner drains the queue whenever it next reaches
//! one of its serialization points, and `sync`/`close` drain every
//! queue, so no slot is ever leaked.
//!
//! **Shard=1 equivalence:** the shard count is a DRAM-only property. The
//! persistent format is identical for every N — each bin serializes as
//! the sorted union of its per-shard bitsets
//! ([`bin_dir::serialize_merged_into`]) and chunk ownership is re-dealt
//! deterministically (`chunk % N`) on open, so a store written with N
//! shards reopens with M ≠ N. With N = 1 every sharded code path
//! collapses to the unsharded one (free pools bypassed, remote queues
//! structurally empty), reproducing the pre-sharding on-disk layout
//! bit-for-bit.
//!
//! ## NUMA placement (ROADMAP "True NUMA placement")
//!
//! On a multi-node [`crate::numa::Topology`] (detected from
//! `/sys/devices/system/node` — memory-only nodes excluded — or injected
//! by tests), the shard count is sized from the topology (a multiple of
//! the node count), shards are dealt round-robin to nodes, and a
//! thread's home shard is chosen among *its own node's* shards
//! ([`bin_dir::ShardMap`]). Each fresh chunk a shard takes is placed by
//! exactly one of two layers: `mbind(MPOL_PREFERRED)` to the shard's
//! node (kernel policy then covers every later fault, no page needs
//! touching), or — when `mbind` is unavailable — **zeroed by the owning
//! shard before any slot is published**, the first-touch discipline that
//! pins the chunk's DRAM pages to the owner's socket regardless of which
//! thread later writes objects into it
//! (`MetallManager::place_fresh_chunk`).
//!
//! Everything degrades gracefully: on single-node topologies the whole
//! layer is skipped (kernel first-touch is already local), and on kernels
//! without NUMA support `mbind`/`move_pages` report "couldn't" instead of
//! erroring — placement is an optimization, never a correctness
//! requirement. Like the shard count, placement and topology are
//! DRAM-only: nothing is serialized, and a store written under any
//! topology reopens under any other.
//!
//! Introspection: [`manager::PlacementReport`]
//! ([`MetallManager::placement_report`]) accounts every mapped page —
//! kernel truth via `move_pages(2)` on detected topologies, recorded
//! birth nodes under injected ones — and is exported as
//! `alloc.shard<N>.node_local_pages` by
//! [`crate::coordinator::metrics::record_placement`].
//!
//! Follow-on (ROADMAP): an interleave policy (`MPOL_INTERLEAVE`) for
//! read-mostly large segments shared by threads on every node.

pub mod api;
pub mod size_class;
pub mod mlbitset;
pub mod chunk_dir;
pub mod bin_dir;
pub mod object_cache;
pub mod name_dir;
pub mod manager;

pub use api::{MetallHandle, SegmentAlloc};
pub use bin_dir::{ShardMap, ShardStatsSnapshot};
pub use manager::{
    ManagerOptions, MetallManager, Persist, PlacementReport, PlacementSource, ShardPlacement,
    StatsSnapshot,
};
pub use object_cache::pin_thread_vcpu;

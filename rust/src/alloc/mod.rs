//! The Metall persistent memory allocator (paper §3–§4).
//!
//! Architecture (paper Fig 2): the application-data **segment** (a
//! reserved VM extent backed by on-demand files, [`crate::storage::segment`])
//! is divided into **chunks** (2 MiB by default). A chunk holds either
//! *small objects* of one internal allocation size (8 B … half a chunk,
//! tracked by a multi-layer bitset) or the head/body of a *large object*
//! spanning ≥ 1 contiguous chunks. Three management directories — chunk
//! directory, bin directory, name directory — live in **DRAM** and are
//! serialized to the datastore on close (§4.3: "Metall rarely touches
//! persistent memory when allocating memory").
//!
//! ## Shard architecture (beyond the paper)
//!
//! The DRAM bin directory is split into N CPU-affine **shards**
//! ([`ManagerOptions::shards`], default `min(num_cpus, 4)`). Each
//! [`bin_dir::AllocShard`] owns, per size class, its own non-full-chunk
//! LIFO and slot bitsets over the chunks it took from the chunk
//! directory, plus its own slice of the free-chunk pool inside
//! [`chunk_dir::ChunkDirectory`]. A thread's home shard is its virtual
//! CPU modulo N ([`bin_dir::ShardMap`], `sched_getcpu` with a thread-id
//! hash fallback), the same value that selects its
//! [`object_cache::ObjectCache`] slot — so cache slots are bound to
//! shards and the paper's two serialization points (fresh-chunk take,
//! emptied-chunk release) are contended per shard instead of per
//! manager.
//!
//! **Remote-free queue:** an object freed by a thread whose home shard
//! is not the owning shard of its chunk is parked on the owner's
//! [`bin_dir::AllocShard::remote_free`] queue (a plain mutex push; the
//! foreign shard's bin locks are never taken on the free hot path,
//! llfree-style). The owner drains the queue whenever it next reaches
//! one of its serialization points, and `sync`/`close` drain every
//! queue, so no slot is ever leaked.
//!
//! **Shard=1 equivalence:** the shard count is a DRAM-only property. The
//! persistent format is identical for every N — each bin serializes as
//! the sorted union of its per-shard bitsets
//! ([`bin_dir::serialize_merged_into`]) and chunk ownership is re-dealt
//! deterministically (`chunk % N`) on open, so a store written with N
//! shards reopens with M ≠ N. With N = 1 every sharded code path
//! collapses to the unsharded one (free pools bypassed, remote queues
//! structurally empty), reproducing the pre-sharding on-disk layout
//! bit-for-bit.
//!
//! Follow-on (ROADMAP): true NUMA placement — `mbind`/first-touch of
//! each shard's chunks on its socket's memory node.

pub mod api;
pub mod size_class;
pub mod mlbitset;
pub mod chunk_dir;
pub mod bin_dir;
pub mod object_cache;
pub mod name_dir;
pub mod manager;

pub use api::{MetallHandle, SegmentAlloc};
pub use bin_dir::{ShardMap, ShardStatsSnapshot};
pub use manager::{ManagerOptions, MetallManager, Persist, StatsSnapshot};
pub use object_cache::pin_thread_vcpu;

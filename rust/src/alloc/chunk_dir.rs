//! Chunk directory (paper §4.3.1): one entry per chunk of the
//! application-data segment recording its state — free, small-object
//! chunk (with its bin number), or head/body of a large allocation.
//!
//! Slot bitsets live with the *bin* data ([`super::bin_dir`]) so that
//! small allocations of different sizes only contend on their own bin
//! mutex (§4.5.1); this directory holds the compact per-chunk kind and is
//! guarded by a single mutex, touched only when chunks change state
//! (the paper's two listed contention points) or a kind lookup is needed.
//!
//! "Metall sequentially probes the array when it needs to find empty
//! chunk(s)."
//!
//! ## Sharding (in-DRAM only)
//!
//! Alongside the persistent `entries` array the directory keeps two
//! DRAM-only structures that are **never serialized** (the on-disk format
//! is unchanged for every shard count):
//!
//! - `owners` — the allocator shard that owns each small chunk. Set when a
//!   shard takes a fresh chunk; rebuilt deterministically on open as
//!   `chunk % nshards` ([`Self::set_shards`]), so a datastore written with
//!   N shards reopens correctly with M ≠ N.
//! - `pools` — per-shard min-heaps of recently freed chunk ids, the
//!   shard's slice of the free-chunk pool. They are *hints*: a pooled id is
//!   re-validated against `entries` under the directory lock before reuse
//!   (a large allocation's sequential probe may have claimed it in the
//!   meantime), so no chunk can be handed out twice. With one shard the
//!   pools are bypassed entirely and every take goes through the same
//!   lowest-first sequential probe as the unsharded allocator — that is
//!   what keeps shard=1 byte-identical on disk.
//! - `birth` — the NUMA node each small chunk was bound and
//!   first-touched on by its owning shard (placement introspection), or
//!   "unknown" for chunks placed before this session (recovered stores)
//!   and on single-node topologies. Cleared whenever a chunk is freed or
//!   re-taken; like the shard count, placement is DRAM-only state.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Per-chunk state tag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkKind {
    Free,
    /// Small-object chunk holding objects of bin `bin`.
    Small { bin: u32 },
    /// First chunk of a large allocation spanning `nchunks` chunks.
    LargeHead { nchunks: u32 },
    /// Continuation chunk of a large allocation.
    LargeBody,
}

/// The chunk directory: a growable array of [`ChunkKind`] plus the
/// DRAM-only shard-ownership map and per-shard free pools (module docs).
#[derive(Clone, Debug)]
pub struct ChunkDirectory {
    entries: Vec<ChunkKind>,
    /// Owning shard per chunk (meaningful for `Small` chunks). Same length
    /// as `entries`; not serialized.
    owners: Vec<u32>,
    /// Per-shard min-heaps of freed chunk ids (validated hints). Length is
    /// the shard count; not serialized.
    pools: Vec<BinaryHeap<Reverse<u32>>>,
    /// Birth node per chunk ([`NO_BIRTH_NODE`] = unknown). Same length as
    /// `entries`; not serialized.
    birth: Vec<i32>,
    /// DRAM-only dirty-epoch mark: set whenever `entries` changes (a sync
    /// must rewrite the chunk section), cleared when the section is
    /// serialized. DRAM-only rekeying (`set_shards`, birth nodes) never
    /// sets it — the serialized bytes do not change.
    dirty: bool,
}

/// Sentinel for "no recorded birth node" (module docs).
const NO_BIRTH_NODE: i32 = -1;

impl Default for ChunkDirectory {
    fn default() -> Self {
        Self::with_shards(1)
    }
}

impl ChunkDirectory {
    pub fn new() -> Self {
        Self::with_shards(1)
    }

    pub fn with_shards(nshards: usize) -> Self {
        Self {
            entries: Vec::new(),
            owners: Vec::new(),
            pools: (0..nshards.max(1)).map(|_| BinaryHeap::new()).collect(),
            birth: Vec::new(),
            dirty: false,
        }
    }

    /// Has the serialized image changed since the last [`Self::take_dirty`]?
    pub fn is_dirty(&self) -> bool {
        self.dirty
    }

    /// Mark the serialized image changed (mutators call this internally;
    /// the manager re-marks after a failed sync so nothing is lost).
    pub fn mark_dirty(&mut self) {
        self.dirty = true;
    }

    /// Read-and-clear the dirty mark (called under the exclusive chunk
    /// lock while the section is serialized).
    pub fn take_dirty(&mut self) -> bool {
        std::mem::take(&mut self.dirty)
    }

    /// Re-key the DRAM-only shard state for `nshards` shards: ownership is
    /// reassigned deterministically (`chunk % nshards`, the same function
    /// the manager uses to split the bin bitsets on open) and the free
    /// pools are rebuilt from the current `Free` entries.
    pub fn set_shards(&mut self, nshards: usize) {
        let n = nshards.max(1);
        self.pools = (0..n).map(|_| BinaryHeap::new()).collect();
        for (i, o) in self.owners.iter_mut().enumerate() {
            *o = (i % n) as u32;
        }
        if n > 1 {
            for (i, e) in self.entries.iter().enumerate() {
                if *e == ChunkKind::Free {
                    self.pools[i % n].push(Reverse(i as u32));
                }
            }
        }
    }

    pub fn nshards(&self) -> usize {
        self.pools.len()
    }

    /// Owning shard of `chunk` (meaningful while the chunk is `Small`).
    pub fn owner(&self, chunk: u32) -> u32 {
        self.owners[chunk as usize]
    }

    /// Keep `owners` and `birth` in lockstep after `entries` grew; new
    /// chunks default to the deterministic recovery assignment (and no
    /// birth node) until a shard claims them.
    fn sync_owners(&mut self) {
        let n = self.pools.len();
        while self.owners.len() < self.entries.len() {
            self.owners.push((self.owners.len() % n) as u32);
        }
        self.birth.resize(self.entries.len(), NO_BIRTH_NODE);
    }

    /// Record the node the owning shard bound + first-touched `chunk` on.
    pub fn set_birth_node(&mut self, chunk: u32, node: u32) {
        self.birth[chunk as usize] = node as i32;
    }

    /// Recorded birth node of `chunk`, if its pages were placed by this
    /// session.
    pub fn birth_node(&self, chunk: u32) -> Option<u32> {
        match self.birth.get(chunk as usize) {
            Some(&n) if n >= 0 => Some(n as u32),
            _ => None,
        }
    }

    /// Cheap snapshot for placement introspection: `(kind, owner, birth)`
    /// per chunk — only the three flat arrays, none of the per-shard
    /// free-pool heaps a full `clone()` would copy.
    pub fn placement_rows(&self) -> Vec<(ChunkKind, u32, Option<u32>)> {
        self.entries
            .iter()
            .zip(&self.owners)
            .zip(&self.birth)
            .map(|((&k, &o), &b)| (k, o, (b >= 0).then_some(b as u32)))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn kind(&self, chunk: u32) -> ChunkKind {
        self.entries[chunk as usize]
    }

    /// Find the first free chunk (sequential probe), growing the
    /// directory if none exists. Marks it `Small { bin }` owned by shard 0.
    pub fn take_small_chunk(&mut self, bin: u32) -> u32 {
        self.take_small_chunk_on(bin, 0)
    }

    /// Take a free chunk for `shard`, preferring the shard's own pool of
    /// previously freed chunks (validated hints, lowest id first) and
    /// falling back to the global sequential probe. Single-shard
    /// directories always probe, matching the unsharded allocator exactly.
    pub fn take_small_chunk_on(&mut self, bin: u32, shard: u32) -> u32 {
        self.dirty = true;
        if self.pools.len() > 1 {
            while let Some(Reverse(c)) = self.pools[shard as usize].pop() {
                if self.entries[c as usize] == ChunkKind::Free {
                    self.entries[c as usize] = ChunkKind::Small { bin };
                    self.owners[c as usize] = shard;
                    return c;
                }
            }
        }
        let idx = self.find_free_run(1);
        self.sync_owners();
        self.entries[idx as usize] = ChunkKind::Small { bin };
        self.owners[idx as usize] = shard;
        idx
    }

    /// Find (growing as needed) a run of `n` contiguous free chunks and
    /// mark them as one large allocation. Returns the head index.
    pub fn take_large(&mut self, n: u32) -> u32 {
        self.dirty = true;
        let head = self.find_free_run(n as usize);
        self.sync_owners();
        self.entries[head as usize] = ChunkKind::LargeHead { nchunks: n };
        for i in 1..n {
            self.entries[(head + i) as usize] = ChunkKind::LargeBody;
        }
        head
    }

    /// Sequential probe for a run of `n` free chunks; grows the array so
    /// it always succeeds (the segment's VM reservation is the real
    /// bound, enforced by the manager when extending the segment).
    fn find_free_run(&mut self, n: usize) -> u32 {
        let mut run = 0usize;
        for i in 0..self.entries.len() {
            if self.entries[i] == ChunkKind::Free {
                run += 1;
                if run == n {
                    return (i + 1 - n) as u32;
                }
            } else {
                run = 0;
            }
        }
        // extend with what's missing (possibly continuing a trailing run)
        let start = self.entries.len() - run;
        self.entries.resize(start + n, ChunkKind::Free);
        start as u32
    }

    /// Release a small chunk back to free (pooled under its recorded
    /// owner).
    pub fn free_small_chunk(&mut self, chunk: u32) {
        let owner = self.owners.get(chunk as usize).copied().unwrap_or(0);
        self.free_small_chunk_on(chunk, owner);
    }

    /// Release a small chunk back to free, remembering it in `shard`'s
    /// pool for locality on the next take.
    pub fn free_small_chunk_on(&mut self, chunk: u32, shard: u32) {
        debug_assert!(matches!(self.entries[chunk as usize], ChunkKind::Small { .. }));
        self.dirty = true;
        self.entries[chunk as usize] = ChunkKind::Free;
        self.birth[chunk as usize] = NO_BIRTH_NODE;
        if self.pools.len() > 1 {
            self.pools[shard as usize].push(Reverse(chunk));
        }
    }

    /// Release a large allocation; returns the number of chunks freed.
    pub fn free_large(&mut self, head: u32) -> u32 {
        let n = match self.entries[head as usize] {
            ChunkKind::LargeHead { nchunks } => nchunks,
            k => panic!("free_large on non-head chunk {head}: {k:?}"),
        };
        self.dirty = true;
        for i in 0..n {
            self.entries[(head + i) as usize] = ChunkKind::Free;
            self.birth[(head + i) as usize] = NO_BIRTH_NODE;
        }
        n
    }

    /// Recovery-only adoption: force `chunk` to `Small { bin }` owned by
    /// `shard`, growing the directory when the id lies beyond the
    /// recovered length (an op-log record can describe a chunk the last
    /// committed manifest never saw). Only a `Free` (or brand-new)
    /// entry converts — anything else means newer management state
    /// already accounts for the chunk and the caller must leave it
    /// alone. Returns whether the entry converted.
    pub fn adopt_small_chunk(&mut self, chunk: u32, bin: u32, shard: u32) -> bool {
        let idx = chunk as usize;
        if idx >= self.entries.len() {
            self.entries.resize(idx + 1, ChunkKind::Free);
        }
        self.sync_owners();
        if self.entries[idx] != ChunkKind::Free {
            return false;
        }
        self.dirty = true;
        self.entries[idx] = ChunkKind::Small { bin };
        self.owners[idx] = shard;
        self.birth[idx] = NO_BIRTH_NODE;
        true
    }

    /// Recovery-only adoption of a large run: convert `head..head+n` to
    /// one large allocation when every member chunk is `Free` (or
    /// beyond the recovered length). Returns whether the run converted.
    pub fn adopt_large(&mut self, head: u32, n: u32) -> bool {
        if n == 0 {
            return false;
        }
        let end = head as usize + n as usize;
        if end > self.entries.len() {
            self.entries.resize(end, ChunkKind::Free);
        }
        self.sync_owners();
        if (head as usize..end).any(|i| self.entries[i] != ChunkKind::Free) {
            return false;
        }
        self.dirty = true;
        self.entries[head as usize] = ChunkKind::LargeHead { nchunks: n };
        for i in head as usize + 1..end {
            self.entries[i] = ChunkKind::LargeBody;
        }
        true
    }

    /// Occupied chunk count (for stats / fragmentation reporting).
    pub fn used_chunks(&self) -> usize {
        self.entries.iter().filter(|k| !matches!(k, ChunkKind::Free)).count()
    }

    pub fn iter(&self) -> impl Iterator<Item = (u32, ChunkKind)> + '_ {
        self.entries.iter().enumerate().map(|(i, &k)| (i as u32, k))
    }

    // ---- serialization ----

    pub fn serialize_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.entries.len() as u64).to_le_bytes());
        for e in &self.entries {
            match e {
                ChunkKind::Free => out.push(0),
                ChunkKind::Small { bin } => {
                    out.push(1);
                    out.extend_from_slice(&bin.to_le_bytes());
                }
                ChunkKind::LargeHead { nchunks } => {
                    out.push(2);
                    out.extend_from_slice(&nchunks.to_le_bytes());
                }
                ChunkKind::LargeBody => out.push(3),
            }
        }
    }

    pub fn deserialize_from(buf: &[u8]) -> Option<(Self, usize)> {
        if buf.len() < 8 {
            return None;
        }
        let n = u64::from_le_bytes(buf[0..8].try_into().ok()?) as usize;
        let mut entries = Vec::with_capacity(n);
        let mut pos = 8;
        for _ in 0..n {
            let tag = *buf.get(pos)?;
            pos += 1;
            let e = match tag {
                0 => ChunkKind::Free,
                1 => {
                    let bin = u32::from_le_bytes(buf.get(pos..pos + 4)?.try_into().ok()?);
                    pos += 4;
                    ChunkKind::Small { bin }
                }
                2 => {
                    let nchunks =
                        u32::from_le_bytes(buf.get(pos..pos + 4)?.try_into().ok()?);
                    pos += 4;
                    ChunkKind::LargeHead { nchunks }
                }
                3 => ChunkKind::LargeBody,
                _ => return None,
            };
            entries.push(e);
        }
        // structural validation: large bodies must follow their head
        let mut dir = Self::with_shards(1);
        dir.entries = entries;
        dir.sync_owners();
        dir.validate().then_some(())?;
        Some((dir, pos))
    }

    /// Check structural invariants (used after deserialization and by the
    /// property tests).
    pub fn validate(&self) -> bool {
        let mut i = 0;
        while i < self.entries.len() {
            match self.entries[i] {
                ChunkKind::LargeHead { nchunks } => {
                    if nchunks == 0 || i + nchunks as usize > self.entries.len() {
                        return false;
                    }
                    for j in 1..nchunks as usize {
                        if self.entries[i + j] != ChunkKind::LargeBody {
                            return false;
                        }
                    }
                    i += nchunks as usize;
                }
                ChunkKind::LargeBody => return false, // orphan body
                _ => i += 1,
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_take_and_free() {
        let mut d = ChunkDirectory::new();
        let c0 = d.take_small_chunk(3);
        let c1 = d.take_small_chunk(3);
        assert_eq!((c0, c1), (0, 1));
        assert_eq!(d.kind(0), ChunkKind::Small { bin: 3 });
        d.free_small_chunk(0);
        assert_eq!(d.kind(0), ChunkKind::Free);
        // freed chunk is reused first (sequential probe)
        assert_eq!(d.take_small_chunk(9), 0);
    }

    #[test]
    fn large_runs() {
        let mut d = ChunkDirectory::new();
        let a = d.take_large(3);
        let b = d.take_small_chunk(0);
        let c = d.take_large(2);
        assert_eq!((a, b, c), (0, 3, 4));
        assert!(d.validate());
        assert_eq!(d.free_large(0), 3);
        // the 3-chunk hole is reused for a 2-chunk run
        assert_eq!(d.take_large(2), 0);
        // but a 4-chunk run must skip the remaining 1-chunk hole
        assert_eq!(d.take_large(4), 6);
        assert!(d.validate());
    }

    #[test]
    fn trailing_run_extension() {
        let mut d = ChunkDirectory::new();
        let _ = d.take_small_chunk(0); // chunk 0
        d.free_small_chunk(0);
        // 1 free chunk exists; a 3-run should start at 0 and grow by 2
        assert_eq!(d.take_large(3), 0);
        assert_eq!(d.len(), 3);
    }

    #[test]
    fn used_chunks_stat() {
        let mut d = ChunkDirectory::new();
        d.take_large(2);
        d.take_small_chunk(1);
        assert_eq!(d.used_chunks(), 3);
        d.free_large(0);
        assert_eq!(d.used_chunks(), 1);
    }

    #[test]
    #[should_panic]
    fn free_large_on_body_panics() {
        let mut d = ChunkDirectory::new();
        d.take_large(2);
        d.free_large(1);
    }

    #[test]
    fn serialization_roundtrip() {
        let mut d = ChunkDirectory::new();
        d.take_large(2);
        d.take_small_chunk(7);
        d.take_small_chunk(2);
        d.free_small_chunk(3);
        let mut buf = Vec::new();
        d.serialize_into(&mut buf);
        let (de, used) = ChunkDirectory::deserialize_from(&buf).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(de.entries, d.entries);
    }

    #[test]
    fn sharded_take_records_owner_and_pools_reuse() {
        let mut d = ChunkDirectory::with_shards(2);
        let a = d.take_small_chunk_on(3, 0);
        let b = d.take_small_chunk_on(3, 1);
        assert_eq!((a, b), (0, 1));
        assert_eq!((d.owner(a), d.owner(b)), (0, 1));
        // shard 1 frees its chunk; the next take on shard 1 reuses it even
        // though shard 0's probe would also find it
        d.free_small_chunk_on(b, 1);
        assert_eq!(d.take_small_chunk_on(7, 1), b);
        assert_eq!(d.owner(b), 1);
    }

    #[test]
    fn stale_pool_entry_is_skipped() {
        let mut d = ChunkDirectory::with_shards(2);
        let c = d.take_small_chunk_on(0, 1);
        d.free_small_chunk_on(c, 1);
        // a large allocation's sequential probe claims the pooled chunk
        assert_eq!(d.take_large(1), c);
        // shard 1's pool hint is now stale and must be skipped
        let next = d.take_small_chunk_on(0, 1);
        assert_ne!(next, c);
        assert_eq!(d.kind(c), ChunkKind::LargeHead { nchunks: 1 });
        assert_eq!(d.kind(next), ChunkKind::Small { bin: 0 });
    }

    #[test]
    fn set_shards_reassigns_owners_deterministically() {
        let mut d = ChunkDirectory::with_shards(4);
        for i in 0..6u32 {
            d.take_small_chunk_on(0, i % 4);
        }
        d.free_small_chunk_on(4, 0);
        // reopen with a different shard count: chunk % nshards
        d.set_shards(2);
        assert_eq!(d.nshards(), 2);
        for i in 0..6u32 {
            assert_eq!(d.owner(i), i % 2, "chunk {i}");
        }
        // the rebuilt pool serves the free chunk to its recovery shard
        assert_eq!(d.take_small_chunk_on(1, 0), 4);
    }

    #[test]
    fn single_shard_matches_probe_order() {
        // with one shard the pool is bypassed: frees then takes follow the
        // exact lowest-first probe order of the unsharded directory
        let mut d = ChunkDirectory::new();
        for _ in 0..4 {
            d.take_small_chunk(0);
        }
        d.free_small_chunk(2);
        d.free_small_chunk(0);
        assert_eq!(d.take_small_chunk(0), 0, "lowest free id first");
        assert_eq!(d.take_small_chunk(0), 2);
    }

    #[test]
    fn birth_node_lifecycle() {
        let mut d = ChunkDirectory::with_shards(2);
        let c = d.take_small_chunk_on(0, 1);
        assert_eq!(d.birth_node(c), None, "fresh chunk has no birth yet");
        d.set_birth_node(c, 1);
        assert_eq!(d.birth_node(c), Some(1));
        // freeing clears the record; retake starts unknown again
        d.free_small_chunk_on(c, 1);
        assert_eq!(d.birth_node(c), None);
        let c2 = d.take_small_chunk_on(0, 1);
        assert_eq!(c2, c);
        assert_eq!(d.birth_node(c2), None);
        // large frees clear too, and deserialized stores know nothing
        d.set_birth_node(c2, 0);
        let mut buf = Vec::new();
        d.serialize_into(&mut buf);
        let (de, _) = ChunkDirectory::deserialize_from(&buf).unwrap();
        assert_eq!(de.birth_node(c2), None, "placement is DRAM-only");
        let head = d.take_large(2);
        d.free_large(head);
        assert_eq!(d.birth_node(head), None);
        // out-of-range ids are a graceful None
        assert_eq!(d.birth_node(10_000), None);
    }

    #[test]
    fn dirty_mark_tracks_serialized_mutations_only() {
        let mut d = ChunkDirectory::with_shards(2);
        assert!(!d.is_dirty(), "fresh directory is clean");
        let c = d.take_small_chunk_on(0, 1);
        assert!(d.is_dirty());
        assert!(d.take_dirty());
        assert!(!d.is_dirty(), "take clears");
        // DRAM-only mutations never dirty the serialized image
        d.set_birth_node(c, 1);
        d.set_shards(4);
        assert!(!d.is_dirty());
        d.free_small_chunk_on(c, 1);
        assert!(d.take_dirty());
        let head = d.take_large(2);
        assert!(d.take_dirty());
        d.free_large(head);
        assert!(d.is_dirty());
        // a deserialized directory starts clean (it matches the disk image)
        let mut buf = Vec::new();
        d.serialize_into(&mut buf);
        let (de, _) = ChunkDirectory::deserialize_from(&buf).unwrap();
        assert!(!de.is_dirty());
    }

    #[test]
    fn deserialize_rejects_orphan_body() {
        // craft: 1 entry of LargeBody
        let mut buf = Vec::new();
        buf.extend_from_slice(&1u64.to_le_bytes());
        buf.push(3);
        assert!(ChunkDirectory::deserialize_from(&buf).is_none());
    }
}

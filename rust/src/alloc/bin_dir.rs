//! Bin directory (paper §4.3.2), **sharded**: for each internal
//! allocation size, the set of *non-full* chunks (LIFO) plus the slot
//! bitsets of every chunk currently assigned to that bin. The manager
//! owns N [`AllocShard`]s; each shard holds its own `RwLock<BinData>` per
//! size class over the chunks that shard took from the chunk directory,
//! so the paper's two serialization points (registering a fresh chunk,
//! releasing an emptied chunk) are contended per shard, not per manager —
//! llfree-style per-core trees flattened to per-shard LIFOs.
//!
//! Within one `BinData` the concurrency model is unchanged from the
//! unsharded design: bitsets claim slots with lock-free CAS
//! ([`MlBitset`]) under the shared (read) side of the lock via
//! [`BinData::try_claim`] / [`BinData::try_claim_batch`]; the exclusive
//! (write) side is reserved for the serialization points, frees, and
//! structural healing of the LIFO.
//!
//! Cross-shard frees (an object freed by a thread whose home shard is not
//! the chunk's owner) never touch the foreign shard's bin locks: they are
//! parked in the owner's [`AllocShard::remote_free`] queue and drained by
//! the owner the next time it is at a serialization point anyway.
//!
//! [`ShardMap`] assigns threads to shards by virtual CPU
//! ([`super::object_cache::current_vcpu`]); the persistent image is
//! shard-agnostic — [`serialize_merged_into`] writes the union of the
//! per-shard bitsets in the exact byte layout of an unsharded bin.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};

use crate::alloc::mlbitset::MlBitset;
use crate::alloc::object_cache::current_vcpu;
use crate::numa::Topology;

/// Maps calling threads and recovered chunks to shards, NUMA-aware: on a
/// multi-node [`Topology`] the shards are dealt round-robin to nodes
/// (`node_of_shard(s) = s % nnodes`) and a thread's home shard is chosen
/// among *its own node's* shards — so a shard's bins, remote-free queue,
/// and (with [`super::manager`]'s first-touch discipline) the DRAM pages
/// of its chunks all live on the socket of the threads it serves. On a
/// single node every rule collapses to the pre-NUMA `vcpu % nshards`.
#[derive(Clone, Debug)]
pub struct ShardMap {
    nshards: usize,
    topo: Topology,
}

impl ShardMap {
    /// Topology-blind map (single node, every cpu): exactly the pre-NUMA
    /// `vcpu % nshards` behaviour. The manager uses
    /// [`Self::with_topology`].
    pub fn new(nshards: usize) -> Self {
        Self::with_topology(nshards, Topology::single_node())
    }

    pub fn with_topology(nshards: usize, topo: Topology) -> Self {
        Self { nshards: nshards.max(1), topo }
    }

    pub fn nshards(&self) -> usize {
        self.nshards
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Home shard of the calling thread (CPU-affine; stable under
    /// [`super::object_cache::pin_thread_vcpu`]).
    #[inline]
    pub fn home_shard(&self) -> usize {
        self.shard_of_vcpu(current_vcpu())
    }

    /// Home shard of a virtual CPU: one of its node's shards, spread
    /// within the node by the cpu's rank there. Single-node topologies
    /// (and single-shard managers) keep the plain `vcpu % nshards`.
    #[inline]
    pub fn shard_of_vcpu(&self, vcpu: usize) -> usize {
        let nnodes = self.topo.num_nodes();
        if nnodes <= 1 || self.nshards == 1 {
            return vcpu % self.nshards;
        }
        let node = self.topo.node_of_cpu(vcpu);
        let k = self.shards_of_node(node);
        if k == 0 {
            // fewer shards than nodes: wrap onto somebody's shard
            return node % self.nshards;
        }
        node + (self.topo.rank_in_node(vcpu) % k) * nnodes
    }

    /// Memory node a shard's chunks are placed on (round-robin deal of
    /// shards to nodes; node 0 on single-node topologies).
    #[inline]
    pub fn node_of_shard(&self, shard: usize) -> usize {
        let nnodes = self.topo.num_nodes();
        if nnodes <= 1 {
            0
        } else {
            shard % nnodes
        }
    }

    /// How many shards the round-robin deal gives `node`.
    fn shards_of_node(&self, node: usize) -> usize {
        let nnodes = self.topo.num_nodes();
        if node >= self.nshards {
            return 0;
        }
        (self.nshards - node).div_ceil(nnodes)
    }

    /// Deterministic shard of a recovered chunk: a store written with N
    /// shards reopens with M shards by re-dealing every small chunk as
    /// `chunk % M` (must match `ChunkDirectory::set_shards`).
    #[inline]
    pub fn recovery_shard_of_chunk(&self, chunk: u32) -> usize {
        chunk as usize % self.nshards
    }
}

/// Per-shard contention counters (DRAM-only instrumentation).
#[derive(Debug, Default)]
pub struct ShardStats {
    /// Slots claimed through the lock-free (shared lock + CAS) path.
    pub fast_claims: AtomicU64,
    /// Fresh chunks registered (serialization point #1).
    pub fresh_chunks: AtomicU64,
    /// Emptied chunks released (serialization point #2).
    pub freed_chunks: AtomicU64,
    /// Slots parked on this shard's remote-free queue by other shards.
    pub remote_frees: AtomicU64,
    /// Slots drained from the remote-free queue by this shard.
    pub remote_drained: AtomicU64,
    /// Exclusive (write) bin-lock acquisitions — the contention signal.
    pub exclusive_acquires: AtomicU64,
    /// Fresh chunks zeroed by this (owning) shard before entering its
    /// LIFO — the NUMA first-touch fallback, used when `mbind` is
    /// unavailable. On multi-node topologies every fresh chunk is placed
    /// by exactly one layer: `bound_chunks + first_touch_chunks ==
    /// fresh_chunks`.
    pub first_touch_chunks: AtomicU64,
    /// Fresh chunks whose extent `mbind` accepted (kernel policy then
    /// covers every later fault; 0 on NUMA-less kernels, where the
    /// first-touch fallback takes over).
    pub bound_chunks: AtomicU64,
}

/// Snapshot of [`ShardStats`] for one shard.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardStatsSnapshot {
    pub shard: usize,
    pub fast_claims: u64,
    pub fresh_chunks: u64,
    pub freed_chunks: u64,
    pub remote_frees: u64,
    pub remote_drained: u64,
    pub exclusive_acquires: u64,
    pub first_touch_chunks: u64,
    pub bound_chunks: u64,
}

/// One shard of the bin directory: per-size-class non-full-chunk LIFOs
/// over the chunks this shard owns, a queue of cross-shard frees parked
/// for it, and its contention counters.
pub struct AllocShard {
    /// One [`BinData`] per size class (same indexing as the unsharded
    /// design), holding only this shard's chunks.
    pub bins: Vec<RwLock<BinData>>,
    /// Cross-shard frees parked for this shard as `(bin, offset)` pairs;
    /// pushed by foreign threads without touching `bins`, drained by this
    /// shard at its serialization points.
    pub remote_free: Mutex<Vec<(u32, u64)>>,
    pub stats: ShardStats,
    /// DRAM-only per-bin dirty-epoch marks: one flag per size class, set
    /// by the manager at every point that mutates this shard's part of
    /// the bin (fast-path CAS claims under the shared lock, the two
    /// serialization points, frees), cleared when the bin's group section
    /// is serialized under the exclusive lock. A sync ORs the flags
    /// across shards per bin group to decide what to rewrite.
    dirty: Vec<AtomicBool>,
}

impl AllocShard {
    pub fn new(num_bins: usize) -> Self {
        Self {
            bins: (0..num_bins).map(|_| RwLock::new(BinData::new())).collect(),
            remote_free: Mutex::new(Vec::new()),
            stats: ShardStats::default(),
            dirty: (0..num_bins).map(|_| AtomicBool::new(false)).collect(),
        }
    }

    /// Mark bin `bin`'s serialized image changed in this shard. Relaxed
    /// store; callers invoke it inside the bin-lock critical section that
    /// performed the mutation, so the release of that lock orders the
    /// mark before any sync that serializes the bin (sync takes the
    /// exclusive side).
    #[inline]
    pub fn mark_bin_dirty(&self, bin: usize) {
        self.dirty[bin].store(true, Ordering::Relaxed);
    }

    /// Is bin `bin` dirty in this shard (non-clearing probe)?
    #[inline]
    pub fn peek_bin_dirty(&self, bin: usize) -> bool {
        self.dirty[bin].load(Ordering::Relaxed)
    }

    /// Read-and-clear bin `bin`'s dirty mark (called while the sync holds
    /// the bin's exclusive lock, just before serializing it).
    pub fn take_bin_dirty(&self, bin: usize) -> bool {
        self.dirty[bin].swap(false, Ordering::Relaxed)
    }

    pub fn stats_snapshot(&self, shard: usize) -> ShardStatsSnapshot {
        let ld = |c: &AtomicU64| c.load(Ordering::Relaxed);
        ShardStatsSnapshot {
            shard,
            fast_claims: ld(&self.stats.fast_claims),
            fresh_chunks: ld(&self.stats.fresh_chunks),
            freed_chunks: ld(&self.stats.freed_chunks),
            remote_frees: ld(&self.stats.remote_frees),
            remote_drained: ld(&self.stats.remote_drained),
            exclusive_acquires: ld(&self.stats.exclusive_acquires),
            first_touch_chunks: ld(&self.stats.first_touch_chunks),
            bound_chunks: ld(&self.stats.bound_chunks),
        }
    }
}

/// Serialize the union of per-shard [`BinData`] of one bin in the exact
/// byte layout [`BinData::serialize_into`] produces for an unsharded bin
/// (chunk ids sorted ascending) — the persistent format does not know the
/// shard count.
pub fn serialize_merged_into(parts: &[&BinData], out: &mut Vec<u8>) {
    let mut ids: Vec<(u32, &MlBitset)> = parts
        .iter()
        .flat_map(|p| p.bitsets.iter().map(|(&id, bs)| (id, bs)))
        .collect();
    ids.sort_unstable_by_key(|&(id, _)| id);
    out.extend_from_slice(&(ids.len() as u32).to_le_bytes());
    for (id, bs) in ids {
        out.extend_from_slice(&id.to_le_bytes());
        bs.serialize_into(out);
    }
}

/// Non-full chunk LIFO + per-chunk slot bitsets for one bin.
#[derive(Clone, Debug, Default)]
pub struct BinData {
    /// IDs of chunks of this bin with at least one free slot. LIFO:
    /// "A bin operates in a LIFO (last in, first out) manner."
    /// May transiently contain chunks that filled up through the shared
    /// claim path (readers cannot mutate the Vec); the exclusive path
    /// heals via [`Self::prune_full`].
    nonfull: Vec<u32>,
    /// Slot occupancy per chunk (full chunks included).
    bitsets: HashMap<u32, MlBitset>,
}

impl BinData {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lock-free slot claim under a *shared* bin lock: walk the non-full
    /// LIFO from the hot end and CAS-claim a slot in the first chunk with
    /// room. Returns `(chunk, slot)` or `None` when every listed chunk is
    /// full (the caller then falls back to the exclusive path).
    pub fn try_claim(&self) -> Option<(u32, u32)> {
        for &chunk in self.nonfull.iter().rev() {
            if let Some(bs) = self.bitsets.get(&chunk) {
                if let Some(slot) = bs.find_and_set_first_zero() {
                    return Some((chunk, slot));
                }
            }
        }
        None
    }

    /// Batch variant of [`Self::try_claim`] for the object-cache refill
    /// path: claim up to `want` slots (word-level CAS batches), appending
    /// `(chunk, slot)` pairs. A batch may span chunks. Returns the number
    /// of slots claimed.
    pub fn try_claim_batch(&self, want: usize, out: &mut Vec<(u32, u32)>) -> usize {
        let mut got = 0usize;
        let mut slots = Vec::with_capacity(want);
        for &chunk in self.nonfull.iter().rev() {
            if got >= want {
                break;
            }
            if let Some(bs) = self.bitsets.get(&chunk) {
                slots.clear();
                let n = bs.claim_batch(want - got, &mut slots);
                out.extend(slots.iter().map(|&s| (chunk, s)));
                got += n;
            }
        }
        got
    }

    /// Allocate one slot (exclusive path). Returns `(chunk, slot)` or
    /// `None` when every chunk of this bin is full (the caller then takes
    /// a fresh chunk from the chunk directory).
    pub fn alloc_slot(&mut self) -> Option<(u32, u32)> {
        loop {
            let &chunk = self.nonfull.last()?;
            let bs = self.bitsets.get(&chunk).expect("nonfull chunk has bitset");
            match bs.find_and_set_first_zero() {
                Some(slot) => {
                    if bs.is_full() {
                        self.nonfull.pop();
                    }
                    return Some((chunk, slot));
                }
                None => {
                    // chunk filled through the shared claim path — heal
                    self.nonfull.pop();
                }
            }
        }
    }

    /// Drop chunks that filled up through the shared claim path from the
    /// non-full LIFO (exclusive-path healing; keeps `try_claim` scans
    /// short).
    pub fn prune_full(&mut self) {
        let bitsets = &self.bitsets;
        self.nonfull
            .retain(|c| bitsets.get(c).map(|b| !b.is_full()).unwrap_or(false));
    }

    /// Register a fresh chunk (just taken from the chunk directory) with
    /// `slots` capacity and immediately allocate its first slot.
    pub fn add_chunk_and_alloc(&mut self, chunk: u32, slots: u32) -> u32 {
        let bs = MlBitset::new(slots);
        let slot = bs.find_and_set_first_zero().expect("fresh chunk has room");
        if !bs.is_full() {
            self.nonfull.push(chunk);
        }
        self.bitsets.insert(chunk, bs);
        slot
    }

    /// Adopt a chunk with an existing bitset (recovery split path: the
    /// manager deals deserialized chunks out to their shards). Call in
    /// ascending chunk-id order to reproduce the deserialized LIFO order.
    pub fn insert_chunk(&mut self, chunk: u32, bs: MlBitset) {
        if !bs.is_full() {
            self.nonfull.push(chunk);
        }
        self.bitsets.insert(chunk, bs);
    }

    /// Tear down into `(chunk, bitset)` pairs sorted by chunk id
    /// (recovery split path).
    pub fn into_chunks(self) -> Vec<(u32, MlBitset)> {
        let mut v: Vec<(u32, MlBitset)> = self.bitsets.into_iter().collect();
        v.sort_unstable_by_key(|&(id, _)| id);
        v
    }

    /// Free a slot. Returns `true` when the chunk became completely empty
    /// (the caller should release it to the chunk directory and drop it
    /// via [`Self::remove_chunk`]).
    pub fn free_slot(&mut self, chunk: u32, slot: u32) -> bool {
        let was_full = self
            .bitsets
            .get(&chunk)
            .expect("freeing slot in unknown chunk")
            .is_full();
        if was_full {
            // The chunk transitions full → non-full: prune while it is
            // still full, which both removes any stale LIFO entry for it
            // (so the push below cannot duplicate) and heals entries for
            // other chunks that filled via the shared claim path — the
            // exclusive lock is already held here, so this is the natural
            // healing point for fast-path-steady workloads.
            self.prune_full();
        }
        let bs = self.bitsets.get(&chunk).expect("freeing slot in unknown chunk");
        assert!(bs.clear(slot), "double free: chunk {chunk} slot {slot}");
        if was_full {
            self.nonfull.push(chunk); // becomes visible for reuse (LIFO)
        }
        bs.is_empty()
    }

    /// Recovery path: return a slot the manifest's transient cache
    /// section recorded as parked-free (claimed in the serialized bitset
    /// but actually sitting in a per-core cache or remote queue when the
    /// store was synced). Lenient by design — an unknown chunk,
    /// out-of-range slot, or already-clear bit returns `None` and the
    /// entry is skipped (the checksummed section guards real corruption;
    /// a benign mismatch can only make recovery *less* aggressive about
    /// freeing). `Some(empty)` reports whether the chunk became empty
    /// (the caller then releases it like a normal serialization-point
    /// free).
    pub fn release_cached(&mut self, chunk: u32, slot: u32) -> Option<bool> {
        let bs = self.bitsets.get(&chunk)?;
        if slot >= bs.capacity() || !bs.get(slot) {
            return None;
        }
        let was_full = bs.is_full();
        if was_full {
            // same discipline as free_slot: heal the LIFO while the chunk
            // is still listed full, then re-expose it
            self.prune_full();
        }
        let bs = self.bitsets.get(&chunk).expect("bitset still present");
        bs.clear(slot);
        let empty = bs.is_empty();
        if was_full {
            self.nonfull.push(chunk);
        }
        Some(empty)
    }

    /// Recovery-only adoption: mark `slot` of a chunk this bin already
    /// tracks as used (a committed op-log record proved the allocation
    /// outlived the last management cut). Lenient like
    /// [`Self::release_cached`] — unknown chunk, out-of-range slot, or
    /// an already-set bit returns `false` and the caller leaves the
    /// record's extent to newer management state. When the adoption
    /// fills the chunk, the stale LIFO entry is pruned.
    pub fn adopt_slot(&mut self, chunk: u32, slot: u32) -> bool {
        let Some(bs) = self.bitsets.get(&chunk) else {
            return false;
        };
        if slot >= bs.capacity() || !bs.set(slot) {
            return false;
        }
        if bs.is_full() {
            self.prune_full();
        }
        true
    }

    /// Drop a (now empty) chunk from this bin.
    pub fn remove_chunk(&mut self, chunk: u32) {
        let bs = self.bitsets.remove(&chunk).expect("removing unknown chunk");
        assert!(bs.is_empty(), "removing non-empty chunk {chunk}");
        self.nonfull.retain(|&c| c != chunk);
    }

    pub fn is_slot_used(&self, chunk: u32, slot: u32) -> bool {
        self.bitsets.get(&chunk).map(|b| b.get(slot)).unwrap_or(false)
    }

    pub fn used_slots(&self) -> u64 {
        self.bitsets.values().map(|b| b.used() as u64).sum()
    }

    pub fn chunk_ids(&self) -> impl Iterator<Item = u32> + '_ {
        self.bitsets.keys().copied()
    }

    pub fn bitset(&self, chunk: u32) -> Option<&MlBitset> {
        self.bitsets.get(&chunk)
    }

    // ---- serialization (bitsets only; the nonfull LIFO is rebuilt) ----

    pub fn serialize_into(&self, out: &mut Vec<u8>) {
        let mut ids: Vec<u32> = self.bitsets.keys().copied().collect();
        ids.sort_unstable(); // deterministic layout
        out.extend_from_slice(&(ids.len() as u32).to_le_bytes());
        for id in ids {
            out.extend_from_slice(&id.to_le_bytes());
            self.bitsets[&id].serialize_into(out);
        }
    }

    pub fn deserialize_from(buf: &[u8]) -> Option<(Self, usize)> {
        let n = u32::from_le_bytes(buf.get(0..4)?.try_into().ok()?) as usize;
        let mut pos = 4;
        let mut data = BinData::new();
        for _ in 0..n {
            let id = u32::from_le_bytes(buf.get(pos..pos + 4)?.try_into().ok()?);
            pos += 4;
            let (bs, used) = MlBitset::deserialize_from(buf.get(pos..)?)?;
            pos += used;
            if !bs.is_full() {
                data.nonfull.push(id);
            }
            data.bitsets.insert(id, bs);
        }
        Some((data, pos))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifo_reuse() {
        let mut b = BinData::new();
        assert!(b.alloc_slot().is_none());
        let s0 = b.add_chunk_and_alloc(10, 4);
        assert_eq!(s0, 0);
        // fill chunk 10
        assert_eq!(b.alloc_slot(), Some((10, 1)));
        assert_eq!(b.alloc_slot(), Some((10, 2)));
        assert_eq!(b.alloc_slot(), Some((10, 3)));
        assert!(b.alloc_slot().is_none(), "chunk 10 is full");
        // new chunk
        b.add_chunk_and_alloc(11, 4);
        // freeing in the full chunk 10 re-exposes it LIFO-last
        assert!(!b.free_slot(10, 2));
        assert_eq!(b.alloc_slot(), Some((10, 2)));
    }

    #[test]
    fn empty_detection_and_removal() {
        let mut b = BinData::new();
        b.add_chunk_and_alloc(5, 2);
        assert_eq!(b.alloc_slot(), Some((5, 1)));
        assert!(!b.free_slot(5, 0));
        assert!(b.free_slot(5, 1), "last slot freed → chunk empty");
        b.remove_chunk(5);
        assert!(b.alloc_slot().is_none());
        assert_eq!(b.used_slots(), 0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut b = BinData::new();
        b.add_chunk_and_alloc(1, 8);
        b.free_slot(1, 0);
        b.free_slot(1, 0);
    }

    #[test]
    fn serialization_roundtrip_rebuilds_nonfull() {
        let mut b = BinData::new();
        b.add_chunk_and_alloc(3, 2);
        b.alloc_slot(); // fill chunk 3
        b.add_chunk_and_alloc(9, 2); // half full
        let mut buf = Vec::new();
        b.serialize_into(&mut buf);
        let (de, used) = BinData::deserialize_from(&buf).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(de.used_slots(), 3);
        // only chunk 9 is non-full → next alloc must come from it
        let mut de = de;
        assert_eq!(de.alloc_slot(), Some((9, 1)));
        assert!(de.alloc_slot().is_none());
    }

    #[test]
    fn shared_claim_matches_exclusive_order() {
        let mut b = BinData::new();
        b.add_chunk_and_alloc(7, 8); // slot 0 taken
        assert_eq!(b.try_claim(), Some((7, 1)));
        assert_eq!(b.try_claim(), Some((7, 2)));
        // exclusive path continues where the shared path left off
        assert_eq!(b.alloc_slot(), Some((7, 3)));
    }

    #[test]
    fn shared_batch_claim_spans_chunks() {
        let mut b = BinData::new();
        b.add_chunk_and_alloc(1, 4); // 3 free (slot 0 taken)
        b.add_chunk_and_alloc(2, 4); // hot end of the LIFO, 3 free
        let mut out = Vec::new();
        assert_eq!(b.try_claim_batch(5, &mut out), 5);
        // hot chunk 2 first, then chunk 1
        assert_eq!(out, vec![(2, 1), (2, 2), (2, 3), (1, 1), (1, 2)]);
        // both now full except one slot in chunk 1
        assert_eq!(b.try_claim(), Some((1, 3)));
        assert_eq!(b.try_claim(), None);
    }

    #[test]
    fn merged_serialization_matches_unsharded_layout() {
        // one bin split over two shards must serialize byte-identically to
        // the same chunks living in a single BinData
        let mut whole = BinData::new();
        whole.add_chunk_and_alloc(2, 4);
        whole.add_chunk_and_alloc(5, 4);
        whole.add_chunk_and_alloc(9, 4);
        let mut part_a = BinData::new();
        part_a.add_chunk_and_alloc(5, 4);
        let mut part_b = BinData::new();
        part_b.add_chunk_and_alloc(9, 4);
        part_b.add_chunk_and_alloc(2, 4);
        let mut want = Vec::new();
        whole.serialize_into(&mut want);
        let mut got = Vec::new();
        serialize_merged_into(&[&part_a, &part_b], &mut got);
        assert_eq!(got, want);
        // and a single part is the identity
        let mut solo = Vec::new();
        serialize_merged_into(&[&whole], &mut solo);
        assert_eq!(solo, want);
    }

    #[test]
    fn split_and_merge_roundtrip() {
        let mut b = BinData::new();
        b.add_chunk_and_alloc(0, 2);
        b.add_chunk_and_alloc(1, 2);
        b.alloc_slot(); // fills chunk 1
        b.add_chunk_and_alloc(2, 2);
        let mut want = Vec::new();
        b.serialize_into(&mut want);
        // deal chunks to 2 shards by chunk % 2 (the recovery assignment)
        let mut shards = vec![BinData::new(), BinData::new()];
        for (id, bs) in b.into_chunks() {
            shards[id as usize % 2].insert_chunk(id, bs);
        }
        assert_eq!(shards[0].used_slots(), 2); // chunks 0, 2
        assert_eq!(shards[1].used_slots(), 2); // chunk 1 (full)
        // shard 1's only chunk is full: no claims there
        assert_eq!(shards[1].try_claim(), None);
        assert_eq!(shards[0].try_claim(), Some((2, 1)));
        assert!(!shards[0].free_slot(2, 1));
        let mut got = Vec::new();
        serialize_merged_into(&[&shards[0], &shards[1]], &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn shard_map_is_deterministic() {
        let m = ShardMap::new(4);
        assert_eq!(m.nshards(), 4);
        for vcpu in 0..16 {
            assert_eq!(m.shard_of_vcpu(vcpu), vcpu % 4);
        }
        for chunk in 0..16u32 {
            assert_eq!(m.recovery_shard_of_chunk(chunk), chunk as usize % 4);
        }
        crate::alloc::object_cache::pin_thread_vcpu(Some(7));
        assert_eq!(m.home_shard(), 3);
        crate::alloc::object_cache::pin_thread_vcpu(None);
        assert!(m.home_shard() < 4);
        // zero normalizes to one shard
        assert_eq!(ShardMap::new(0).nshards(), 1);
    }

    #[test]
    fn shard_map_routes_vcpus_to_their_nodes_shards() {
        // the satellite shape: fake 2-node / 8-cpu topology, 4 shards
        let topo = Topology::fake(&[4, 4]);
        let m = ShardMap::with_topology(4, topo.clone());
        // node 0 cpus rotate over shards {0, 2}; node 1 over {1, 3}
        assert_eq!(m.shard_of_vcpu(0), 0);
        assert_eq!(m.shard_of_vcpu(1), 2);
        assert_eq!(m.shard_of_vcpu(2), 0);
        assert_eq!(m.shard_of_vcpu(3), 2);
        assert_eq!(m.shard_of_vcpu(4), 1);
        assert_eq!(m.shard_of_vcpu(5), 3);
        for s in 0..4 {
            assert_eq!(m.node_of_shard(s), s % 2);
        }
        // the core invariant: a thread's home shard lives on its own node
        for cpu in 0..8 {
            assert_eq!(
                m.node_of_shard(m.shard_of_vcpu(cpu)),
                topo.node_of_cpu(cpu),
                "cpu {cpu}"
            );
        }
        // odd shard counts still keep threads node-local
        let m3 = ShardMap::with_topology(3, topo.clone());
        for cpu in 0..8 {
            let s = m3.shard_of_vcpu(cpu);
            assert!(s < 3);
            assert_eq!(m3.node_of_shard(s), topo.node_of_cpu(cpu), "cpu {cpu}");
        }
        // fewer shards than nodes wraps without panicking
        let m1 = ShardMap::with_topology(1, Topology::fake(&[2, 2]));
        for cpu in 0..4 {
            assert_eq!(m1.shard_of_vcpu(cpu), 0);
        }
        assert_eq!(m1.node_of_shard(0), 0);
    }

    #[test]
    fn pinned_vcpus_drive_home_shard_across_nodes() {
        use crate::alloc::object_cache::pin_thread_vcpu;
        let m = ShardMap::with_topology(4, Topology::fake(&[2, 2]));
        // vcpus 0,1 are node 0 → shards {0, 2}; vcpus 2,3 node 1 → {1, 3}
        for (vcpu, want) in [(0usize, 0usize), (1, 2), (2, 1), (3, 3)] {
            pin_thread_vcpu(Some(vcpu));
            assert_eq!(m.home_shard(), want, "vcpu {vcpu}");
        }
        pin_thread_vcpu(None);
        assert!(m.home_shard() < 4);
    }

    #[test]
    fn release_cached_is_lenient_and_reports_empty() {
        let mut b = BinData::new();
        b.add_chunk_and_alloc(4, 2); // slot 0 taken
        assert_eq!(b.try_claim(), Some((4, 1))); // now full
        // unknown chunk / clear slot / out-of-range slot are all None
        assert_eq!(b.release_cached(9, 0), None);
        assert_eq!(b.release_cached(4, 5), None);
        assert_eq!(b.release_cached(4, 1), Some(false));
        assert_eq!(b.release_cached(4, 1), None, "already clear");
        // full → non-full transition re-exposes the chunk LIFO-style
        assert_eq!(b.try_claim(), Some((4, 1)));
        assert_eq!(b.release_cached(4, 1), Some(false));
        assert_eq!(b.release_cached(4, 0), Some(true), "chunk empties");
        b.remove_chunk(4);
        assert_eq!(b.used_slots(), 0);
    }

    #[test]
    fn shard_dirty_flags_are_per_bin() {
        let s = AllocShard::new(4);
        assert!(!s.peek_bin_dirty(0));
        s.mark_bin_dirty(2);
        assert!(s.peek_bin_dirty(2));
        assert!(!s.peek_bin_dirty(1), "neighbouring bins unaffected");
        assert!(s.take_bin_dirty(2));
        assert!(!s.peek_bin_dirty(2), "take clears");
        assert!(!s.take_bin_dirty(2));
    }

    #[test]
    fn alloc_shard_snapshot_reads_counters() {
        let s = AllocShard::new(3);
        assert_eq!(s.bins.len(), 3);
        s.stats.fast_claims.fetch_add(5, Ordering::Relaxed);
        s.stats.remote_frees.fetch_add(2, Ordering::Relaxed);
        s.remote_free.lock().unwrap().push((1, 64));
        let snap = s.stats_snapshot(2);
        assert_eq!(snap.shard, 2);
        assert_eq!(snap.fast_claims, 5);
        assert_eq!(snap.remote_frees, 2);
        assert_eq!(snap.exclusive_acquires, 0);
    }

    #[test]
    fn prune_full_heals_lifo() {
        let mut b = BinData::new();
        b.add_chunk_and_alloc(4, 2);
        // fill through the shared path: nonfull still lists chunk 4
        assert_eq!(b.try_claim(), Some((4, 1)));
        assert!(b.bitset(4).unwrap().is_full());
        b.prune_full();
        assert_eq!(b.try_claim(), None);
        assert!(b.alloc_slot().is_none());
    }
}

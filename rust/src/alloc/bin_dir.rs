//! Bin directory (paper §4.3.2): for each internal allocation size, the
//! set of *non-full* chunks (LIFO) plus the slot bitsets of every chunk
//! currently assigned to that bin. One instance of [`BinData`] sits
//! behind one mutex in the manager (§4.5.1: "a mutex object per bin"), so
//! different allocation sizes proceed concurrently.

use std::collections::HashMap;

use crate::alloc::mlbitset::MlBitset;

/// Non-full chunk LIFO + per-chunk slot bitsets for one bin.
#[derive(Clone, Debug, Default)]
pub struct BinData {
    /// IDs of chunks of this bin with at least one free slot. LIFO:
    /// "A bin operates in a LIFO (last in, first out) manner."
    nonfull: Vec<u32>,
    /// Slot occupancy per chunk (full chunks included).
    bitsets: HashMap<u32, MlBitset>,
}

impl BinData {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate one slot. Returns `(chunk, slot)` or `None` when every
    /// chunk of this bin is full (the caller then takes a fresh chunk
    /// from the chunk directory).
    pub fn alloc_slot(&mut self) -> Option<(u32, u32)> {
        loop {
            let &chunk = self.nonfull.last()?;
            let bs = self.bitsets.get_mut(&chunk).expect("nonfull chunk has bitset");
            match bs.find_and_set_first_zero() {
                Some(slot) => {
                    if bs.is_full() {
                        self.nonfull.pop();
                    }
                    return Some((chunk, slot));
                }
                None => {
                    // stale entry (shouldn't happen, but heal anyway)
                    self.nonfull.pop();
                }
            }
        }
    }

    /// Register a fresh chunk (just taken from the chunk directory) with
    /// `slots` capacity and immediately allocate its first slot.
    pub fn add_chunk_and_alloc(&mut self, chunk: u32, slots: u32) -> u32 {
        let mut bs = MlBitset::new(slots);
        let slot = bs.find_and_set_first_zero().expect("fresh chunk has room");
        if !bs.is_full() {
            self.nonfull.push(chunk);
        }
        self.bitsets.insert(chunk, bs);
        slot
    }

    /// Free a slot. Returns `true` when the chunk became completely empty
    /// (the caller should release it to the chunk directory and drop it
    /// via [`Self::remove_chunk`]).
    pub fn free_slot(&mut self, chunk: u32, slot: u32) -> bool {
        let bs = self.bitsets.get_mut(&chunk).expect("freeing slot in unknown chunk");
        let was_full = bs.is_full();
        assert!(bs.clear(slot), "double free: chunk {chunk} slot {slot}");
        if was_full {
            self.nonfull.push(chunk); // becomes visible for reuse (LIFO)
        }
        bs.is_empty()
    }

    /// Drop a (now empty) chunk from this bin.
    pub fn remove_chunk(&mut self, chunk: u32) {
        let bs = self.bitsets.remove(&chunk).expect("removing unknown chunk");
        assert!(bs.is_empty(), "removing non-empty chunk {chunk}");
        self.nonfull.retain(|&c| c != chunk);
    }

    pub fn is_slot_used(&self, chunk: u32, slot: u32) -> bool {
        self.bitsets.get(&chunk).map(|b| b.get(slot)).unwrap_or(false)
    }

    pub fn used_slots(&self) -> u64 {
        self.bitsets.values().map(|b| b.used() as u64).sum()
    }

    pub fn chunk_ids(&self) -> impl Iterator<Item = u32> + '_ {
        self.bitsets.keys().copied()
    }

    pub fn bitset(&self, chunk: u32) -> Option<&MlBitset> {
        self.bitsets.get(&chunk)
    }

    // ---- serialization (bitsets only; the nonfull LIFO is rebuilt) ----

    pub fn serialize_into(&self, out: &mut Vec<u8>) {
        let mut ids: Vec<u32> = self.bitsets.keys().copied().collect();
        ids.sort_unstable(); // deterministic layout
        out.extend_from_slice(&(ids.len() as u32).to_le_bytes());
        for id in ids {
            out.extend_from_slice(&id.to_le_bytes());
            self.bitsets[&id].serialize_into(out);
        }
    }

    pub fn deserialize_from(buf: &[u8]) -> Option<(Self, usize)> {
        let n = u32::from_le_bytes(buf.get(0..4)?.try_into().ok()?) as usize;
        let mut pos = 4;
        let mut data = BinData::new();
        for _ in 0..n {
            let id = u32::from_le_bytes(buf.get(pos..pos + 4)?.try_into().ok()?);
            pos += 4;
            let (bs, used) = MlBitset::deserialize_from(buf.get(pos..)?)?;
            pos += used;
            if !bs.is_full() {
                data.nonfull.push(id);
            }
            data.bitsets.insert(id, bs);
        }
        Some((data, pos))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifo_reuse() {
        let mut b = BinData::new();
        assert!(b.alloc_slot().is_none());
        let s0 = b.add_chunk_and_alloc(10, 4);
        assert_eq!(s0, 0);
        // fill chunk 10
        assert_eq!(b.alloc_slot(), Some((10, 1)));
        assert_eq!(b.alloc_slot(), Some((10, 2)));
        assert_eq!(b.alloc_slot(), Some((10, 3)));
        assert!(b.alloc_slot().is_none(), "chunk 10 is full");
        // new chunk
        b.add_chunk_and_alloc(11, 4);
        // freeing in the full chunk 10 re-exposes it LIFO-last
        assert!(!b.free_slot(10, 2));
        assert_eq!(b.alloc_slot(), Some((10, 2)));
    }

    #[test]
    fn empty_detection_and_removal() {
        let mut b = BinData::new();
        b.add_chunk_and_alloc(5, 2);
        assert_eq!(b.alloc_slot(), Some((5, 1)));
        assert!(!b.free_slot(5, 0));
        assert!(b.free_slot(5, 1), "last slot freed → chunk empty");
        b.remove_chunk(5);
        assert!(b.alloc_slot().is_none());
        assert_eq!(b.used_slots(), 0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut b = BinData::new();
        b.add_chunk_and_alloc(1, 8);
        b.free_slot(1, 0);
        b.free_slot(1, 0);
    }

    #[test]
    fn serialization_roundtrip_rebuilds_nonfull() {
        let mut b = BinData::new();
        b.add_chunk_and_alloc(3, 2);
        b.alloc_slot(); // fill chunk 3
        b.add_chunk_and_alloc(9, 2); // half full
        let mut buf = Vec::new();
        b.serialize_into(&mut buf);
        let (de, used) = BinData::deserialize_from(&buf).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(de.used_slots(), 3);
        // only chunk 9 is non-full → next alloc must come from it
        let mut de = de;
        assert_eq!(de.alloc_slot(), Some((9, 1)));
        assert!(de.alloc_slot().is_none());
    }
}

//! Bin directory (paper §4.3.2): for each internal allocation size, the
//! set of *non-full* chunks (LIFO) plus the slot bitsets of every chunk
//! currently assigned to that bin. One instance of [`BinData`] sits
//! behind one `RwLock` in the manager (§4.5.1: "a mutex object per bin"),
//! so different allocation sizes proceed concurrently — and, since the
//! bitsets claim slots with lock-free CAS ([`MlBitset`]), *same*-bin
//! allocations proceed concurrently too, under the shared (read) side of
//! the lock via [`BinData::try_claim`] / [`BinData::try_claim_batch`].
//!
//! The exclusive (write) side is reserved for the paper's two
//! serialization points — registering a fresh chunk and releasing an
//! emptied chunk — plus frees and structural healing of the LIFO.

use std::collections::HashMap;

use crate::alloc::mlbitset::MlBitset;

/// Non-full chunk LIFO + per-chunk slot bitsets for one bin.
#[derive(Clone, Debug, Default)]
pub struct BinData {
    /// IDs of chunks of this bin with at least one free slot. LIFO:
    /// "A bin operates in a LIFO (last in, first out) manner."
    /// May transiently contain chunks that filled up through the shared
    /// claim path (readers cannot mutate the Vec); the exclusive path
    /// heals via [`Self::prune_full`].
    nonfull: Vec<u32>,
    /// Slot occupancy per chunk (full chunks included).
    bitsets: HashMap<u32, MlBitset>,
}

impl BinData {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lock-free slot claim under a *shared* bin lock: walk the non-full
    /// LIFO from the hot end and CAS-claim a slot in the first chunk with
    /// room. Returns `(chunk, slot)` or `None` when every listed chunk is
    /// full (the caller then falls back to the exclusive path).
    pub fn try_claim(&self) -> Option<(u32, u32)> {
        for &chunk in self.nonfull.iter().rev() {
            if let Some(bs) = self.bitsets.get(&chunk) {
                if let Some(slot) = bs.find_and_set_first_zero() {
                    return Some((chunk, slot));
                }
            }
        }
        None
    }

    /// Batch variant of [`Self::try_claim`] for the object-cache refill
    /// path: claim up to `want` slots (word-level CAS batches), appending
    /// `(chunk, slot)` pairs. A batch may span chunks. Returns the number
    /// of slots claimed.
    pub fn try_claim_batch(&self, want: usize, out: &mut Vec<(u32, u32)>) -> usize {
        let mut got = 0usize;
        let mut slots = Vec::with_capacity(want);
        for &chunk in self.nonfull.iter().rev() {
            if got >= want {
                break;
            }
            if let Some(bs) = self.bitsets.get(&chunk) {
                slots.clear();
                let n = bs.claim_batch(want - got, &mut slots);
                out.extend(slots.iter().map(|&s| (chunk, s)));
                got += n;
            }
        }
        got
    }

    /// Allocate one slot (exclusive path). Returns `(chunk, slot)` or
    /// `None` when every chunk of this bin is full (the caller then takes
    /// a fresh chunk from the chunk directory).
    pub fn alloc_slot(&mut self) -> Option<(u32, u32)> {
        loop {
            let &chunk = self.nonfull.last()?;
            let bs = self.bitsets.get(&chunk).expect("nonfull chunk has bitset");
            match bs.find_and_set_first_zero() {
                Some(slot) => {
                    if bs.is_full() {
                        self.nonfull.pop();
                    }
                    return Some((chunk, slot));
                }
                None => {
                    // chunk filled through the shared claim path — heal
                    self.nonfull.pop();
                }
            }
        }
    }

    /// Drop chunks that filled up through the shared claim path from the
    /// non-full LIFO (exclusive-path healing; keeps `try_claim` scans
    /// short).
    pub fn prune_full(&mut self) {
        let bitsets = &self.bitsets;
        self.nonfull
            .retain(|c| bitsets.get(c).map(|b| !b.is_full()).unwrap_or(false));
    }

    /// Register a fresh chunk (just taken from the chunk directory) with
    /// `slots` capacity and immediately allocate its first slot.
    pub fn add_chunk_and_alloc(&mut self, chunk: u32, slots: u32) -> u32 {
        let bs = MlBitset::new(slots);
        let slot = bs.find_and_set_first_zero().expect("fresh chunk has room");
        if !bs.is_full() {
            self.nonfull.push(chunk);
        }
        self.bitsets.insert(chunk, bs);
        slot
    }

    /// Free a slot. Returns `true` when the chunk became completely empty
    /// (the caller should release it to the chunk directory and drop it
    /// via [`Self::remove_chunk`]).
    pub fn free_slot(&mut self, chunk: u32, slot: u32) -> bool {
        let was_full = self
            .bitsets
            .get(&chunk)
            .expect("freeing slot in unknown chunk")
            .is_full();
        if was_full {
            // The chunk transitions full → non-full: prune while it is
            // still full, which both removes any stale LIFO entry for it
            // (so the push below cannot duplicate) and heals entries for
            // other chunks that filled via the shared claim path — the
            // exclusive lock is already held here, so this is the natural
            // healing point for fast-path-steady workloads.
            self.prune_full();
        }
        let bs = self.bitsets.get(&chunk).expect("freeing slot in unknown chunk");
        assert!(bs.clear(slot), "double free: chunk {chunk} slot {slot}");
        if was_full {
            self.nonfull.push(chunk); // becomes visible for reuse (LIFO)
        }
        bs.is_empty()
    }

    /// Drop a (now empty) chunk from this bin.
    pub fn remove_chunk(&mut self, chunk: u32) {
        let bs = self.bitsets.remove(&chunk).expect("removing unknown chunk");
        assert!(bs.is_empty(), "removing non-empty chunk {chunk}");
        self.nonfull.retain(|&c| c != chunk);
    }

    pub fn is_slot_used(&self, chunk: u32, slot: u32) -> bool {
        self.bitsets.get(&chunk).map(|b| b.get(slot)).unwrap_or(false)
    }

    pub fn used_slots(&self) -> u64 {
        self.bitsets.values().map(|b| b.used() as u64).sum()
    }

    pub fn chunk_ids(&self) -> impl Iterator<Item = u32> + '_ {
        self.bitsets.keys().copied()
    }

    pub fn bitset(&self, chunk: u32) -> Option<&MlBitset> {
        self.bitsets.get(&chunk)
    }

    // ---- serialization (bitsets only; the nonfull LIFO is rebuilt) ----

    pub fn serialize_into(&self, out: &mut Vec<u8>) {
        let mut ids: Vec<u32> = self.bitsets.keys().copied().collect();
        ids.sort_unstable(); // deterministic layout
        out.extend_from_slice(&(ids.len() as u32).to_le_bytes());
        for id in ids {
            out.extend_from_slice(&id.to_le_bytes());
            self.bitsets[&id].serialize_into(out);
        }
    }

    pub fn deserialize_from(buf: &[u8]) -> Option<(Self, usize)> {
        let n = u32::from_le_bytes(buf.get(0..4)?.try_into().ok()?) as usize;
        let mut pos = 4;
        let mut data = BinData::new();
        for _ in 0..n {
            let id = u32::from_le_bytes(buf.get(pos..pos + 4)?.try_into().ok()?);
            pos += 4;
            let (bs, used) = MlBitset::deserialize_from(buf.get(pos..)?)?;
            pos += used;
            if !bs.is_full() {
                data.nonfull.push(id);
            }
            data.bitsets.insert(id, bs);
        }
        Some((data, pos))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifo_reuse() {
        let mut b = BinData::new();
        assert!(b.alloc_slot().is_none());
        let s0 = b.add_chunk_and_alloc(10, 4);
        assert_eq!(s0, 0);
        // fill chunk 10
        assert_eq!(b.alloc_slot(), Some((10, 1)));
        assert_eq!(b.alloc_slot(), Some((10, 2)));
        assert_eq!(b.alloc_slot(), Some((10, 3)));
        assert!(b.alloc_slot().is_none(), "chunk 10 is full");
        // new chunk
        b.add_chunk_and_alloc(11, 4);
        // freeing in the full chunk 10 re-exposes it LIFO-last
        assert!(!b.free_slot(10, 2));
        assert_eq!(b.alloc_slot(), Some((10, 2)));
    }

    #[test]
    fn empty_detection_and_removal() {
        let mut b = BinData::new();
        b.add_chunk_and_alloc(5, 2);
        assert_eq!(b.alloc_slot(), Some((5, 1)));
        assert!(!b.free_slot(5, 0));
        assert!(b.free_slot(5, 1), "last slot freed → chunk empty");
        b.remove_chunk(5);
        assert!(b.alloc_slot().is_none());
        assert_eq!(b.used_slots(), 0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut b = BinData::new();
        b.add_chunk_and_alloc(1, 8);
        b.free_slot(1, 0);
        b.free_slot(1, 0);
    }

    #[test]
    fn serialization_roundtrip_rebuilds_nonfull() {
        let mut b = BinData::new();
        b.add_chunk_and_alloc(3, 2);
        b.alloc_slot(); // fill chunk 3
        b.add_chunk_and_alloc(9, 2); // half full
        let mut buf = Vec::new();
        b.serialize_into(&mut buf);
        let (de, used) = BinData::deserialize_from(&buf).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(de.used_slots(), 3);
        // only chunk 9 is non-full → next alloc must come from it
        let mut de = de;
        assert_eq!(de.alloc_slot(), Some((9, 1)));
        assert!(de.alloc_slot().is_none());
    }

    #[test]
    fn shared_claim_matches_exclusive_order() {
        let mut b = BinData::new();
        b.add_chunk_and_alloc(7, 8); // slot 0 taken
        assert_eq!(b.try_claim(), Some((7, 1)));
        assert_eq!(b.try_claim(), Some((7, 2)));
        // exclusive path continues where the shared path left off
        assert_eq!(b.alloc_slot(), Some((7, 3)));
    }

    #[test]
    fn shared_batch_claim_spans_chunks() {
        let mut b = BinData::new();
        b.add_chunk_and_alloc(1, 4); // 3 free (slot 0 taken)
        b.add_chunk_and_alloc(2, 4); // hot end of the LIFO, 3 free
        let mut out = Vec::new();
        assert_eq!(b.try_claim_batch(5, &mut out), 5);
        // hot chunk 2 first, then chunk 1
        assert_eq!(out, vec![(2, 1), (2, 2), (2, 3), (1, 1), (1, 2)]);
        // both now full except one slot in chunk 1
        assert_eq!(b.try_claim(), Some((1, 3)));
        assert_eq!(b.try_claim(), None);
    }

    #[test]
    fn prune_full_heals_lifo() {
        let mut b = BinData::new();
        b.add_chunk_and_alloc(4, 2);
        // fill through the shared path: nonfull still lists chunk 4
        assert_eq!(b.try_claim(), Some((4, 1)));
        assert!(b.bitset(4).unwrap().is_full());
        b.prune_full();
        assert_eq!(b.try_claim(), None);
        assert!(b.alloc_slot().is_none());
    }
}

//! Name directory (paper §4.3.3): "a simple key-value table. When an
//! object is constructed by construct() … some attributes (e.g., key
//! string and address) of the object are stored here."
//!
//! We store `(segment offset, byte length, type fingerprint)` per name;
//! the fingerprint lets `find::<T>` reject a type-confused reattach.

use std::collections::HashMap;

/// Attributes of one named allocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NamedEntry {
    pub offset: u64,
    pub size: u64,
    pub type_fp: u64,
}

/// The key→attributes table.
#[derive(Clone, Debug, Default)]
pub struct NameDirectory {
    map: HashMap<String, NamedEntry>,
    /// DRAM-only dirty-epoch mark: set on successful insert/remove,
    /// cleared when the names section is serialized. Never persisted.
    dirty: bool,
}

/// Compile-time-ish fingerprint of a type: FNV-1a of its name
/// ([`crate::util::fnv1a`]) folded with its size and alignment. (Rust
/// has no stable `TypeId` across builds; this is the pragmatic
/// equivalent of Metall trusting the application's `T`.)
pub fn type_fingerprint<T: 'static>() -> u64 {
    let name = std::any::type_name::<T>();
    let mut h = crate::util::fnv1a(name.as_bytes());
    h ^= std::mem::size_of::<T>() as u64;
    h = h.wrapping_mul(0x100_0000_01b3);
    h ^= std::mem::align_of::<T>() as u64;
    h
}

impl NameDirectory {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a name; fails (returns false) if it already exists —
    /// construct() with a duplicate key is an application error.
    pub fn insert(&mut self, name: &str, e: NamedEntry) -> bool {
        if self.map.contains_key(name) {
            return false;
        }
        self.map.insert(name.to_string(), e);
        self.dirty = true;
        true
    }

    /// Has the table changed since the last [`Self::take_dirty`]?
    pub fn is_dirty(&self) -> bool {
        self.dirty
    }

    pub fn mark_dirty(&mut self) {
        self.dirty = true;
    }

    /// Read-and-clear the dirty mark (serialization point).
    pub fn take_dirty(&mut self) -> bool {
        std::mem::take(&mut self.dirty)
    }

    pub fn get(&self, name: &str) -> Option<NamedEntry> {
        self.map.get(name).copied()
    }

    pub fn remove(&mut self, name: &str) -> Option<NamedEntry> {
        let e = self.map.remove(name);
        if e.is_some() {
            self.dirty = true;
        }
        e
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, NamedEntry)> {
        self.map.iter().map(|(k, v)| (k.as_str(), *v))
    }

    // ---- serialization ----

    pub fn serialize_into(&self, out: &mut Vec<u8>) {
        let mut names: Vec<&String> = self.map.keys().collect();
        names.sort(); // deterministic
        out.extend_from_slice(&(names.len() as u32).to_le_bytes());
        for name in names {
            let e = &self.map[name];
            let nb = name.as_bytes();
            out.extend_from_slice(&(nb.len() as u16).to_le_bytes());
            out.extend_from_slice(nb);
            out.extend_from_slice(&e.offset.to_le_bytes());
            out.extend_from_slice(&e.size.to_le_bytes());
            out.extend_from_slice(&e.type_fp.to_le_bytes());
        }
    }

    pub fn deserialize_from(buf: &[u8]) -> Option<(Self, usize)> {
        let n = u32::from_le_bytes(buf.get(0..4)?.try_into().ok()?) as usize;
        let mut pos = 4;
        let mut dir = Self::new();
        for _ in 0..n {
            let len = u16::from_le_bytes(buf.get(pos..pos + 2)?.try_into().ok()?) as usize;
            pos += 2;
            let name = std::str::from_utf8(buf.get(pos..pos + len)?).ok()?;
            pos += len;
            let offset = u64::from_le_bytes(buf.get(pos..pos + 8)?.try_into().ok()?);
            let size = u64::from_le_bytes(buf.get(pos + 8..pos + 16)?.try_into().ok()?);
            let type_fp = u64::from_le_bytes(buf.get(pos + 16..pos + 24)?.try_into().ok()?);
            pos += 24;
            if !dir.insert(name, NamedEntry { offset, size, type_fp }) {
                return None; // duplicate key = corruption
            }
        }
        dir.dirty = false; // matches the disk image it was read from
        Some((dir, pos))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove() {
        let mut d = NameDirectory::new();
        let e = NamedEntry { offset: 64, size: 8, type_fp: 1 };
        assert!(d.insert("graph", e));
        assert!(!d.insert("graph", e), "duplicate insert must fail");
        assert_eq!(d.get("graph"), Some(e));
        assert_eq!(d.remove("graph"), Some(e));
        assert_eq!(d.get("graph"), None);
    }

    #[test]
    fn type_fingerprints_differ() {
        assert_ne!(type_fingerprint::<u64>(), type_fingerprint::<i64>());
        assert_ne!(type_fingerprint::<u32>(), type_fingerprint::<u64>());
        assert_eq!(type_fingerprint::<u64>(), type_fingerprint::<u64>());
    }

    #[test]
    fn serialization_roundtrip() {
        let mut d = NameDirectory::new();
        d.insert("a", NamedEntry { offset: 1, size: 2, type_fp: 3 });
        d.insert("bb", NamedEntry { offset: 4, size: 5, type_fp: 6 });
        d.insert("— utf8 name ✓", NamedEntry { offset: 7, size: 8, type_fp: 9 });
        let mut buf = Vec::new();
        d.serialize_into(&mut buf);
        let (de, used) = NameDirectory::deserialize_from(&buf).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(de.len(), 3);
        assert_eq!(de.get("bb"), d.get("bb"));
        assert_eq!(de.get("— utf8 name ✓"), d.get("— utf8 name ✓"));
    }

    #[test]
    fn dirty_mark_follows_mutations() {
        let mut d = NameDirectory::new();
        assert!(!d.is_dirty());
        let e = NamedEntry { offset: 0, size: 8, type_fp: 1 };
        assert!(d.insert("k", e));
        assert!(d.take_dirty());
        assert!(!d.insert("k", e), "duplicate insert");
        assert!(!d.is_dirty(), "failed insert does not dirty");
        assert!(d.remove("missing").is_none());
        assert!(!d.is_dirty(), "failed remove does not dirty");
        assert!(d.remove("k").is_some());
        assert!(d.is_dirty());
        // a deserialized table starts clean
        d.insert("x", e);
        let mut buf = Vec::new();
        d.serialize_into(&mut buf);
        let (de, _) = NameDirectory::deserialize_from(&buf).unwrap();
        assert!(!de.is_dirty());
    }

    #[test]
    fn deserialize_rejects_truncation() {
        let mut d = NameDirectory::new();
        d.insert("abc", NamedEntry { offset: 1, size: 2, type_fp: 3 });
        let mut buf = Vec::new();
        d.serialize_into(&mut buf);
        assert!(NameDirectory::deserialize_from(&buf[..buf.len() - 3]).is_none());
    }
}

//! `SegmentAlloc` — the allocator interface the persistent containers
//! are written against (the rust analogue of Metall's STL-style
//! allocator, §3.2.3/§4.4).
//!
//! Containers never hold raw pointers: they store **segment offsets** and
//! resolve them through the allocator on every access — the same
//! position-independence discipline Metall's offset pointers give C++
//! containers (§3.5). Any allocator over a contiguous mapped segment can
//! implement this; [`crate::alloc::MetallManager`] and every baseline in
//! [`crate::baselines`] do, which is what lets the Fig-4 benchmark run
//! the identical data structure over all four allocators.

use crate::alloc::manager::Persist;
use crate::error::Result;

/// Offset-based allocation over one contiguous mapped segment.
///
/// # Safety-relevant contract
/// Live allocations never overlap, and `base() + offset` stays valid for
/// the allocation's lifetime (the segment never moves within a process).
pub trait SegmentAlloc: Sync {
    /// Allocate `size` bytes, returning a segment offset.
    fn allocate(&self, size: usize) -> Result<u64>;

    /// Release an allocation previously returned by [`Self::allocate`].
    fn deallocate(&self, offset: u64) -> Result<()>;

    /// Segment base address in this process.
    fn base(&self) -> *mut u8;

    /// Bytes currently addressable from `base()`.
    fn mapped_len(&self) -> usize;

    // ---- provided accessors ----

    /// Read a POD value at `offset`.
    #[inline]
    fn read_pod<T: Persist>(&self, offset: u64) -> T {
        debug_assert!(offset as usize + std::mem::size_of::<T>() <= self.mapped_len());
        unsafe { std::ptr::read_unaligned(self.base().add(offset as usize) as *const T) }
    }

    /// Write a POD value at `offset`.
    #[inline]
    fn write_pod<T: Persist>(&self, offset: u64, value: T) {
        debug_assert!(offset as usize + std::mem::size_of::<T>() <= self.mapped_len());
        unsafe { std::ptr::write_unaligned(self.base().add(offset as usize) as *mut T, value) }
    }

    /// Borrow `len` bytes at `offset`.
    ///
    /// # Safety
    /// Range must be inside a live allocation with no concurrent writer.
    unsafe fn bytes_at(&self, offset: u64, len: usize) -> &[u8] {
        std::slice::from_raw_parts(self.base().add(offset as usize), len)
    }

    /// # Safety
    /// As [`Self::bytes_at`] plus exclusive access.
    #[allow(clippy::mut_from_ref)]
    unsafe fn bytes_at_mut(&self, offset: u64, len: usize) -> &mut [u8] {
        std::slice::from_raw_parts_mut(self.base().add(offset as usize), len)
    }

    /// Bulk copy into the segment.
    fn write_bytes(&self, offset: u64, data: &[u8]) {
        debug_assert!(offset as usize + data.len() <= self.mapped_len());
        unsafe {
            std::ptr::copy_nonoverlapping(
                data.as_ptr(),
                self.base().add(offset as usize),
                data.len(),
            );
        }
    }

    /// Bulk copy within the segment (non-overlapping).
    fn copy_within(&self, src: u64, dst: u64, len: usize) {
        debug_assert!(src as usize + len <= self.mapped_len());
        debug_assert!(dst as usize + len <= self.mapped_len());
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.base().add(src as usize),
                self.base().add(dst as usize),
                len,
            );
        }
    }
}

impl SegmentAlloc for crate::alloc::MetallManager {
    fn allocate(&self, size: usize) -> Result<u64> {
        MetallManagerExt::allocate(self, size)
    }

    fn deallocate(&self, offset: u64) -> Result<()> {
        MetallManagerExt::deallocate(self, offset)
    }

    fn base(&self) -> *mut u8 {
        self.segment().base()
    }

    fn mapped_len(&self) -> usize {
        self.segment().mapped_len()
    }
}

/// Disambiguation shim: calls the inherent methods (which carry the
/// stats/caching logic) rather than recursing into the trait impl.
trait MetallManagerExt {
    fn allocate(&self, size: usize) -> Result<u64>;
    fn deallocate(&self, offset: u64) -> Result<()>;
}

impl MetallManagerExt for crate::alloc::MetallManager {
    fn allocate(&self, size: usize) -> Result<u64> {
        crate::alloc::MetallManager::allocate(self, size)
    }

    fn deallocate(&self, offset: u64) -> Result<()> {
        crate::alloc::MetallManager::deallocate(self, offset)
    }
}

//! `SegmentAlloc` — the allocator interface the persistent containers
//! are written against (the rust analogue of Metall's STL-style
//! allocator, §3.2.3/§4.4).
//!
//! Containers never hold raw pointers: they store **segment offsets** and
//! resolve them through the allocator on every access — the same
//! position-independence discipline Metall's offset pointers give C++
//! containers (§3.5). Any allocator over a contiguous mapped segment can
//! implement this; [`crate::alloc::MetallManager`] and every baseline in
//! [`crate::baselines`] do, which is what lets the Fig-4 benchmark run
//! the identical data structure over all four allocators.

use std::ops::Deref;
use std::sync::Arc;

use crate::alloc::bin_dir::ShardStatsSnapshot;
use crate::alloc::manager::{ManagerCore, MetallManager, Persist, StatsSnapshot};
use crate::containers::oplog::OpToken;
use crate::error::{Error, Result};

/// Offset-based allocation over one contiguous mapped segment.
///
/// # Safety-relevant contract
/// Live allocations never overlap, and `base() + offset` stays valid for
/// the allocation's lifetime (the segment never moves within a process).
pub trait SegmentAlloc: Sync {
    /// Allocate `size` bytes, returning a segment offset.
    fn allocate(&self, size: usize) -> Result<u64>;

    /// Release an allocation previously returned by [`Self::allocate`].
    fn deallocate(&self, offset: u64) -> Result<()>;

    /// Segment base address in this process.
    fn base(&self) -> *mut u8;

    /// Bytes currently addressable from `base()`.
    fn mapped_len(&self) -> usize;

    // ---- provided accessors ----

    /// Read a POD value at `offset`.
    #[inline]
    fn read_pod<T: Persist>(&self, offset: u64) -> T {
        debug_assert!(offset as usize + std::mem::size_of::<T>() <= self.mapped_len());
        unsafe { std::ptr::read_unaligned(self.base().add(offset as usize) as *const T) }
    }

    /// Write a POD value at `offset`.
    #[inline]
    fn write_pod<T: Persist>(&self, offset: u64, value: T) {
        debug_assert!(offset as usize + std::mem::size_of::<T>() <= self.mapped_len());
        unsafe { std::ptr::write_unaligned(self.base().add(offset as usize) as *mut T, value) }
    }

    /// Borrow `len` bytes at `offset`.
    ///
    /// # Safety
    /// Range must be inside a live allocation with no concurrent writer.
    unsafe fn bytes_at(&self, offset: u64, len: usize) -> &[u8] {
        std::slice::from_raw_parts(self.base().add(offset as usize), len)
    }

    /// # Safety
    /// As [`Self::bytes_at`] plus exclusive access.
    #[allow(clippy::mut_from_ref)]
    unsafe fn bytes_at_mut(&self, offset: u64, len: usize) -> &mut [u8] {
        std::slice::from_raw_parts_mut(self.base().add(offset as usize), len)
    }

    /// Bulk copy into the segment.
    fn write_bytes(&self, offset: u64, data: &[u8]) {
        debug_assert!(offset as usize + data.len() <= self.mapped_len());
        unsafe {
            std::ptr::copy_nonoverlapping(
                data.as_ptr(),
                self.base().add(offset as usize),
                data.len(),
            );
        }
    }

    /// Bulk copy within the segment (non-overlapping).
    fn copy_within(&self, src: u64, dst: u64, len: usize) {
        debug_assert!(src as usize + len <= self.mapped_len());
        debug_assert!(dst as usize + len <= self.mapped_len());
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.base().add(src as usize),
                self.base().add(dst as usize),
                len,
            );
        }
    }

    // ---- container operation log (crash-atomic mutations) ----

    /// Append a container-operation intent record to the persistent op
    /// log *before* the operation touches user bytes (see the protocol
    /// in [`crate::containers`]). Returns the token
    /// [`Self::oplog_commit`] seals, or `None` on allocators without a
    /// log (baselines, read-only attaches): the containers then run
    /// unlogged, exactly as before the log existed.
    fn oplog_begin(&self, _rec: crate::containers::oplog::OpRecord) -> Result<Option<OpToken>> {
        Ok(None)
    }

    /// Seal the commit mark of a record begun by [`Self::oplog_begin`]
    /// — called after the new header image(s) are published and before
    /// any trailing `deallocate`. `None` tokens are a no-op.
    fn oplog_commit(&self, _token: Option<OpToken>) -> Result<()> {
        Ok(())
    }
}

impl SegmentAlloc for crate::alloc::MetallManager {
    fn allocate(&self, size: usize) -> Result<u64> {
        MetallManagerExt::allocate(self, size)
    }

    fn deallocate(&self, offset: u64) -> Result<()> {
        MetallManagerExt::deallocate(self, offset)
    }

    fn base(&self) -> *mut u8 {
        self.segment().base()
    }

    fn mapped_len(&self) -> usize {
        self.segment().mapped_len()
    }

    // The write accessors are overridden to record chunk-granular dirty
    // marks ([`MetallManager::mark_data_dirty`]), which is what lets
    // `sync()` flush only the chunk ranges the containers actually wrote
    // instead of msync'ing the whole mapped extent.

    fn write_pod<T: Persist>(&self, offset: u64, value: T) {
        ManagerCore::write(self, offset, value)
    }

    #[allow(clippy::mut_from_ref)]
    unsafe fn bytes_at_mut(&self, offset: u64, len: usize) -> &mut [u8] {
        ManagerCore::bytes_mut(self, offset, len)
    }

    fn write_bytes(&self, offset: u64, data: &[u8]) {
        debug_assert!(offset as usize + data.len() <= self.segment().mapped_len());
        unsafe {
            std::ptr::copy_nonoverlapping(
                data.as_ptr(),
                self.segment().base().add(offset as usize),
                data.len(),
            );
        }
        // after the copy: a sync must not consume the mark pre-store
        self.mark_data_dirty(offset, data.len());
    }

    fn copy_within(&self, src: u64, dst: u64, len: usize) {
        debug_assert!(src as usize + len <= self.segment().mapped_len());
        debug_assert!(dst as usize + len <= self.segment().mapped_len());
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.segment().base().add(src as usize),
                self.segment().base().add(dst as usize),
                len,
            );
        }
        // after the copy: a sync must not consume the mark pre-store
        self.mark_data_dirty(dst, len);
    }

    fn oplog_begin(&self, rec: crate::containers::oplog::OpRecord) -> Result<Option<OpToken>> {
        ManagerCore::oplog_begin(self, rec).map(Some)
    }

    fn oplog_commit(&self, token: Option<OpToken>) -> Result<()> {
        match token {
            Some(t) => ManagerCore::oplog_commit(self, t),
            None => Ok(()),
        }
    }
}

// A reader attach is the read half of the same interface: containers
// opened over a `ReaderManager` traverse the pinned epoch's bytes with
// the exact code paths they use against the owning manager. The two
// mutating methods refuse — an attach never writes the store.
impl SegmentAlloc for crate::alloc::ReaderManager {
    fn allocate(&self, _size: usize) -> Result<u64> {
        Err(Error::InvalidOp(
            "reader attach is read-only: allocate is not available on a pinned epoch".into(),
        ))
    }

    fn deallocate(&self, _offset: u64) -> Result<()> {
        Err(Error::InvalidOp(
            "reader attach is read-only: deallocate is not available on a pinned epoch".into(),
        ))
    }

    fn base(&self) -> *mut u8 {
        self.segment_base()
    }

    fn mapped_len(&self) -> usize {
        self.segment_mapped_len()
    }

    // The trait's default write accessors store through `base()`, which
    // here is a PROT_READ mapping — that would SIGSEGV. Override them to
    // fail loudly with the reason instead of dying on a wild fault.

    fn write_pod<T: Persist>(&self, _offset: u64, _value: T) {
        panic!("reader attach is read-only: write_pod on a pinned epoch");
    }

    #[allow(clippy::mut_from_ref)]
    unsafe fn bytes_at_mut(&self, _offset: u64, _len: usize) -> &mut [u8] {
        panic!("reader attach is read-only: bytes_at_mut on a pinned epoch");
    }

    fn write_bytes(&self, _offset: u64, _data: &[u8]) {
        panic!("reader attach is read-only: write_bytes on a pinned epoch");
    }

    fn copy_within(&self, _src: u64, _dst: u64, _len: usize) {
        panic!("reader attach is read-only: copy_within on a pinned epoch");
    }
}

/// Cloneable, `Send + Sync` handle to a shared [`MetallManager`] — the
/// ergonomic face of the thread-scalable allocation path. Each worker
/// thread clones a handle and allocates independently; the manager's
/// per-core caches, CPU-affine allocator shards
/// ([`crate::alloc::manager::ManagerOptions::shards`]), and lock-free bin
/// claims keep them off each other's locks. Derefs to the manager, so the
/// full API (`construct`, `find`, `snapshot`, `shard_stats`, …) is
/// available through it.
///
/// ```no_run
/// use metall_rs::alloc::{MetallHandle, MetallManager};
///
/// let h = MetallHandle::new(MetallManager::create("/tmp/shared").unwrap());
/// let workers: Vec<_> = (0..8)
///     .map(|_| {
///         let h = h.clone();
///         std::thread::spawn(move || h.allocate(64).unwrap())
///     })
///     .collect();
/// for w in workers {
///     w.join().unwrap();
/// }
/// h.try_close().unwrap();
/// ```
#[derive(Clone)]
pub struct MetallHandle(Arc<MetallManager>);

impl MetallHandle {
    pub fn new(manager: MetallManager) -> Self {
        Self(Arc::new(manager))
    }

    /// The underlying manager (also available through `Deref`).
    pub fn manager(&self) -> &MetallManager {
        &self.0
    }

    /// Aggregate totals plus the per-shard contention counters in one
    /// call (workers report both after a run; the totals are the same
    /// counters the unsharded allocator exposed).
    pub fn stats_with_shards(&self) -> (StatsSnapshot, Vec<ShardStatsSnapshot>) {
        (self.0.stats(), self.0.shard_stats())
    }

    /// Number of live handles to this manager.
    pub fn handle_count(&self) -> usize {
        Arc::strong_count(&self.0)
    }

    /// Recover exclusive ownership of the manager when this is the last
    /// handle; otherwise hands the handle back unchanged so the caller
    /// can retry once the other handles drop.
    pub fn try_into_inner(self) -> std::result::Result<MetallManager, Self> {
        Arc::try_unwrap(self.0).map_err(Self)
    }

    /// Close the datastore if this is the last handle; errors while other
    /// handles are still alive. On that error this handle is forfeited —
    /// the store stays open, kept alive by the remaining handles, and the
    /// last of them to drop closes it (silently, via `Drop`). Use
    /// [`Self::try_into_inner`] when you need to keep the handle and
    /// retry with error reporting.
    pub fn try_close(self) -> Result<()> {
        match self.try_into_inner() {
            Ok(m) => m.close(),
            Err(h) => Err(Error::InvalidOp(format!(
                "cannot close: {} other handle(s) still alive",
                h.handle_count() - 1
            ))),
        }
    }
}

impl Deref for MetallHandle {
    type Target = MetallManager;

    fn deref(&self) -> &MetallManager {
        &self.0
    }
}

impl SegmentAlloc for MetallHandle {
    fn allocate(&self, size: usize) -> Result<u64> {
        MetallManagerExt::allocate(&*self.0, size)
    }

    fn deallocate(&self, offset: u64) -> Result<()> {
        MetallManagerExt::deallocate(&*self.0, offset)
    }

    fn base(&self) -> *mut u8 {
        self.0.segment().base()
    }

    fn mapped_len(&self) -> usize {
        self.0.segment().mapped_len()
    }

    // delegate to the manager's dirty-marking overrides

    fn write_pod<T: Persist>(&self, offset: u64, value: T) {
        <MetallManager as SegmentAlloc>::write_pod(&self.0, offset, value)
    }

    #[allow(clippy::mut_from_ref)]
    unsafe fn bytes_at_mut(&self, offset: u64, len: usize) -> &mut [u8] {
        <MetallManager as SegmentAlloc>::bytes_at_mut(&self.0, offset, len)
    }

    fn write_bytes(&self, offset: u64, data: &[u8]) {
        <MetallManager as SegmentAlloc>::write_bytes(&self.0, offset, data)
    }

    fn copy_within(&self, src: u64, dst: u64, len: usize) {
        <MetallManager as SegmentAlloc>::copy_within(&self.0, src, dst, len)
    }

    fn oplog_begin(&self, rec: crate::containers::oplog::OpRecord) -> Result<Option<OpToken>> {
        <MetallManager as SegmentAlloc>::oplog_begin(&self.0, rec)
    }

    fn oplog_commit(&self, token: Option<OpToken>) -> Result<()> {
        <MetallManager as SegmentAlloc>::oplog_commit(&self.0, token)
    }
}

// The whole point of the handle: it crosses threads.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<MetallHandle>();
    assert_send_sync::<MetallManager>();
    assert_send_sync::<crate::alloc::ReaderManager>();
};

/// Disambiguation shim: calls the inherent methods (which carry the
/// stats/caching logic) rather than recursing into the trait impl.
trait MetallManagerExt {
    fn allocate(&self, size: usize) -> Result<u64>;
    fn deallocate(&self, offset: u64) -> Result<()>;
}

impl MetallManagerExt for crate::alloc::MetallManager {
    fn allocate(&self, size: usize) -> Result<u64> {
        ManagerCore::allocate(self, size)
    }

    fn deallocate(&self, offset: u64) -> Result<()> {
        ManagerCore::deallocate(self, offset)
    }
}

#[cfg(test)]
mod handle_tests {
    use super::*;
    use crate::alloc::ManagerOptions;
    use crate::util::tmp::TempDir;

    #[test]
    fn handle_shares_and_closes_last() {
        let d = TempDir::new("handle1");
        let store = d.join("s");
        let h = MetallHandle::new(
            MetallManager::create_with(&store, ManagerOptions::small_for_tests()).unwrap(),
        );
        let h2 = h.clone();
        assert_eq!(h.handle_count(), 2);
        let off = h.construct::<u64>("x", 9).unwrap();
        assert_eq!(h2.read::<u64>(off), 9);
        // close refused while h2 is alive
        assert!(h.try_close().is_err());
        h2.try_close().unwrap();
        let m = MetallManager::open(&store).unwrap();
        assert_eq!(m.read::<u64>(m.find::<u64>("x").unwrap().unwrap()), 9);
        m.close().unwrap();
    }

    #[test]
    fn try_into_inner_returns_handle_for_retry() {
        let d = TempDir::new("handle3");
        let h = MetallHandle::new(
            MetallManager::create_with(d.join("s"), ManagerOptions::small_for_tests())
                .unwrap(),
        );
        let h2 = h.clone();
        let h = match h.try_into_inner() {
            Err(h) => h, // two handles alive: handed back for retry
            Ok(_) => panic!("must not unwrap while h2 is alive"),
        };
        drop(h2);
        let m = match h.try_into_inner() {
            Ok(m) => m,
            Err(_) => panic!("exclusive now, must unwrap"),
        };
        m.close().unwrap();
    }

    #[test]
    fn handle_exposes_per_shard_stats() {
        use crate::alloc::object_cache::{pin_thread_vcpu, PER_BIN_CAP};
        let d = TempDir::new("handle4");
        let mut o = ManagerOptions::small_for_tests();
        o.shards = 2;
        // single-node topology pinned: vcpu → shard must stay the plain
        // modulo on NUMA hosts too
        o.topology = Some(crate::numa::Topology::fake(&[2]));
        let h = MetallHandle::new(MetallManager::create_with(d.join("s"), o).unwrap());
        assert_eq!(h.num_shards(), 2);
        // more allocations than a cache queue can hold, so each worker is
        // guaranteed at least one cache miss — and the first miss takes a
        // fresh chunk on the worker's own shard — even when both pinned
        // vcpus share one cache slot (single-core machine)
        let per_worker = PER_BIN_CAP + 16;
        let workers: Vec<_> = (0..2usize)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    pin_thread_vcpu(Some(t));
                    let offs: Vec<u64> = (0..per_worker)
                        .map(|_| SegmentAlloc::allocate(&h, 32).unwrap())
                        .collect();
                    for off in offs {
                        SegmentAlloc::deallocate(&h, off).unwrap();
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        let (totals, shards) = h.stats_with_shards();
        assert_eq!(shards.len(), 2);
        assert_eq!(totals.allocs, 2 * per_worker as u64);
        assert_eq!(totals.fast_claims, shards.iter().map(|s| s.fast_claims).sum());
        // both shards took at least one fresh chunk: the workers were
        // homed on different shards
        assert!(shards.iter().all(|s| s.fresh_chunks >= 1), "{shards:?}");
        h.try_close().unwrap();
    }

    #[test]
    fn handles_allocate_from_threads() {
        let d = TempDir::new("handle2");
        let h = MetallHandle::new(
            MetallManager::create_with(d.join("s"), ManagerOptions::small_for_tests())
                .unwrap(),
        );
        let workers: Vec<_> = (0..4u64)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    let off = SegmentAlloc::allocate(&h, 32).unwrap();
                    h.write::<u64>(off, t);
                    (off, t)
                })
            })
            .collect();
        for w in workers {
            let (off, t) = w.join().unwrap();
            assert_eq!(h.read::<u64>(off), t);
        }
        h.try_close().unwrap();
    }
}
